"""Benchmark harness: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV lines; the fig3 suite additionally
writes BENCH_ftfi_runtime.json so the perf trajectory accumulates across PRs.

  python -m benchmarks.run [--quick] [--only fig3,fig4,...]
          [--backend host,plan,pallas]
"""
import argparse
import json
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller sizes for CI-speed runs")
    ap.add_argument("--only", default=None)
    ap.add_argument("--backend", default="host",
                    help="comma list of Integrator backends for fig3/tab1")
    args = ap.parse_args()
    backends = tuple(args.backend.split(","))

    from benchmarks import (bench_ftfi_runtime, bench_graph_classification,
                            bench_gw, bench_learnable_f,
                            bench_mesh_interpolation, bench_roofline,
                            bench_topo_attention)

    suites = {
        "fig3": lambda: bench_ftfi_runtime.run(
            sizes=(1000, 4000) if args.quick else (1000, 4000, 10000, 20000),
            mesh_subdiv=(3,) if args.quick else (3, 4),
            backends=backends),
        "fig4": lambda: bench_mesh_interpolation.run(),
        "fig5": lambda: bench_graph_classification.run(
            n_per_class=15 if args.quick else 30),
        "fig6": lambda: bench_learnable_f.run(steps=150 if args.quick else 300),
        "tab1": lambda: bench_topo_attention.run(
            backends=tuple(b for b in backends if b != "host") or ("plan",)),
        "fig10": lambda: bench_gw.run(n=800 if args.quick else 5000),
        "roofline": lambda: bench_roofline.run(),
    }
    only = set(args.only.split(",")) if args.only else set(suites)
    print("name,us_per_call,derived")
    failed = []
    for name, fn in suites.items():
        if name not in only:
            continue
        try:
            result = fn()
            if name == "fig3":
                with open("BENCH_ftfi_runtime.json", "w") as fh:
                    json.dump({"suite": "fig3", "rows": result}, fh, indent=1)
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"# FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
