"""Benchmark harness: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV lines; the fig3 suite additionally
writes BENCH_ftfi_runtime.json, the fig5 suite writes
BENCH_graph_classification.json, the fig6 suite writes
BENCH_learnable_f.json (incl. the ftfi.reweight --train-edges rows) and the
tab1 suite writes BENCH_topo_attention.json so the perf trajectory
accumulates across PRs.

  python -m benchmarks.run [--quick] [--only fig3,fig4,...]
          [--backend host,plan,pallas] [--baseline prev_BENCH.json]
"""
import argparse
import json
import sys
import traceback


def _load_baseline(baseline_path):
    """Read the baseline rows up front — BENCH_ftfi_runtime.json is a valid
    baseline path, and fig3 overwrites it before the deltas print."""
    try:
        with open(baseline_path) as fh:
            return json.load(fh)["rows"]
    except (OSError, KeyError, json.JSONDecodeError) as e:
        print(f"# --baseline: cannot read {baseline_path}: {e}",
              file=sys.stderr)
        return None


def _print_baseline_deltas(rows, base_rows, baseline_path):
    """Per-row deltas of the fig3 suite against a previous
    BENCH_ftfi_runtime.json (rows matched by case/n/backend)."""
    base = {(r["case"], r["n"], r["backend"]): r for r in base_rows}
    print(f"# deltas vs {baseline_path} (negative = faster now)")
    print("case,n,backend,pre_s_old,pre_s_new,pre_x,int_s_old,int_s_new,"
          "int_x,speedup_total_old,speedup_total_new")
    for r in rows:
        b = base.get((r["case"], r["n"], r["backend"]))
        if b is None:
            print(f"{r['case']},{r['n']},{r['backend']},<no baseline row>")
            continue
        pre_x = b["pre_s"] / max(r["pre_s"], 1e-12)
        int_x = b["int_s"] / max(r["int_s"], 1e-12)
        print(f"{r['case']},{r['n']},{r['backend']},"
              f"{b['pre_s']:.4f},{r['pre_s']:.4f},{pre_x:.2f}x,"
              f"{b['int_s']:.5f},{r['int_s']:.5f},{int_x:.2f}x,"
              f"{b['speedup_total']:.2f},{r['speedup_total']:.2f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller sizes for CI-speed runs")
    ap.add_argument("--only", default=None)
    ap.add_argument("--backend", default="host",
                    help="comma list of Integrator backends for fig3/tab1")
    ap.add_argument("--fig5-backend", default="host,forest",
                    help="comma list of host,plan,pallas,forest for the "
                         "graph-classification suite (plan/pallas are "
                         "per-graph jit loops: slow by design)")
    ap.add_argument("--baseline", default=None,
                    help="previous BENCH_ftfi_runtime.json to diff fig3 "
                         "rows against")
    args = ap.parse_args()
    backends = tuple(args.backend.split(","))
    baseline_rows = _load_baseline(args.baseline) if args.baseline else None

    from benchmarks import (bench_ftfi_runtime, bench_graph_classification,
                            bench_gw, bench_learnable_f,
                            bench_mesh_interpolation, bench_roofline,
                            bench_topo_attention)

    suites = {
        "fig3": lambda: bench_ftfi_runtime.run(
            sizes=(1000, 4000) if args.quick else (1000, 4000, 10000, 20000),
            mesh_subdiv=(3,) if args.quick else (3, 4),
            backends=backends),
        "fig4": lambda: bench_mesh_interpolation.run(),
        "fig5": lambda: bench_graph_classification.run(
            n_per_class=15 if args.quick else 30,
            backends=tuple(b for b in args.fig5_backend.split(",") if b),
            repeat=3 if args.quick else 6),
        "fig6": lambda: bench_learnable_f.run(
            steps=150 if args.quick else 300, train_edges=True),
        "tab1": lambda: bench_topo_attention.run(
            backends=tuple(b for b in backends if b != "host") or ("plan",),
            quick=args.quick),
        "fig10": lambda: bench_gw.run(n=800 if args.quick else 5000),
        "roofline": lambda: bench_roofline.run(),
    }
    only = set(args.only.split(",")) if args.only else set(suites)
    print("name,us_per_call,derived")
    failed = []
    for name, fn in suites.items():
        if name not in only:
            continue
        try:
            result = fn()
            if name == "fig3":
                with open("BENCH_ftfi_runtime.json", "w") as fh:
                    json.dump({"suite": "fig3", "rows": result}, fh, indent=1)
                if baseline_rows is not None:
                    _print_baseline_deltas(result, baseline_rows,
                                           args.baseline)
            elif name == "fig5":
                with open("BENCH_graph_classification.json", "w") as fh:
                    json.dump({"suite": "fig5", "rows": result}, fh, indent=1)
            elif name == "fig6":
                with open("BENCH_learnable_f.json", "w") as fh:
                    json.dump({"suite": "fig6", "rows": result}, fh, indent=1)
            elif name == "tab1":
                with open("BENCH_topo_attention.json", "w") as fh:
                    json.dump({"suite": "tab1", "rows": result}, fh, indent=1)
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"# FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
