"""Paper Table 1 proxy: Topological Performer attention.

(ImageNet-scale accuracy cannot be reproduced offline; this measures the
three claims that transfer: (a) Algorithm-1 masked attention is numerically
exact vs brute force, (b) it scales near-linearly in L vs the O(L^2)
materialized mask, (c) the 3-parameter learnable mask gives a quality gain
over the unmasked Performer on a controlled task — see also
examples/train_topological_lm.py for the end-to-end version.)"""
from __future__ import annotations

import argparse
import pathlib
import sys

import numpy as np
import jax
import jax.numpy as jnp

if __package__ in (None, ""):  # `python benchmarks/bench_topo_attention.py`
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from benchmarks.common import emit, timeit
from repro.core import masks as MK
from repro.core.engines import Integrator
from repro.core.toeplitz import toeplitz_dense


def impl_sweep(rng, quick=False):
    """The cfg.topo_attn_impl axis on the sequence path -> JSON rows.

    Default bench config: the TopoViT mask family (g=exp, degree 2 — the
    general low-degree-polynomial path, i.e. the fft CHUNK-LOOP vs the fused
    kernel), B=2, H=4, m=hd=64, causal. `fft` is the exact Toeplitz-FFT
    column-chunk path; `pallas` is the fused kernels/topo_linear_attention
    step (compiled Pallas on TPU, its XLA chunked-scan twin elsewhere —
    measured steady-state after jit warmup). rel_err is vs the dense ref
    oracle where it fits, vs the exact fft path at large L.
    """
    import types

    import jax.numpy as jnp

    from repro.kernels.topo_linear_attention.ops import topo_linear_attention
    from repro.kernels.topo_linear_attention.ref import (
        topo_linear_attention_ref)
    from repro.models.attention import _topo_fft_attention

    B, H, m, hd = 2, 4, 64, 64
    g, degree = "exp", 2
    rows = []
    for L in (512, 1024) if quick else (512, 4096):
        s = 1.0 / L
        cfg = types.SimpleNamespace(topo_g=g, topo_dist_scale=s)
        cs = jnp.asarray([[0.0, -0.5, -0.25]] * H, jnp.float32)
        qf = jnp.asarray(np.abs(rng.normal(size=(B, L, H, m))), jnp.float32)
        kf = jnp.asarray(np.abs(rng.normal(size=(B, L, H, m))), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, L, H, hd)), jnp.float32)
        qt, kt, vt = (t.transpose(0, 2, 1, 3) for t in (qf, kf, v))
        fft_fn = jax.jit(
            lambda q, k, w: _topo_fft_attention(cfg, q, k, w, cs, True))
        fused_fn = jax.jit(
            lambda q, k, w: topo_linear_attention(
                q, k, w, cs, g=g, dist_scale=s, causal=True))
        out_fft = jax.block_until_ready(fft_fn(qf, kf, v)).transpose(0, 2, 1, 3)
        out_fused = jax.block_until_ready(fused_fn(qt, kt, vt))
        if L <= 512:
            anchor = topo_linear_attention_ref(qt, kt, vt, cs, g=g,
                                               dist_scale=s, causal=True)
        else:
            anchor = out_fft  # the fft path is exact at any L
        nrm = float(jnp.max(jnp.abs(anchor)))
        t_fft = timeit(lambda: jax.block_until_ready(fft_fn(qf, kf, v)))
        t_fused = timeit(lambda: jax.block_until_ready(fused_fn(qt, kt, vt)))
        for impl, t, out in (("fft", t_fft, out_fft),
                             ("pallas", t_fused, out_fused)):
            err = float(jnp.max(jnp.abs(out - anchor))) / nrm
            rows.append({"case": "seq_topo", "L": L, "impl": impl,
                         "g": g, "degree": degree, "causal": True,
                         "t_s": t, "rel_err": err,
                         "speedup_vs_fft": t_fft / t})
            emit(f"tab1/impl/L{L}/{impl}", t,
                 f"rel_err={err:.2e} speedup_vs_fft={t_fft/t:.2f}x")
    return rows


def exactness(rng):
    L, d, m = 128, 16, 8
    qf = jnp.asarray(np.abs(rng.normal(size=(2, L, m))), jnp.float32)
    kf = jnp.asarray(np.abs(rng.normal(size=(2, L, m))), jnp.float32)
    V = jnp.asarray(rng.normal(size=(2, L, d)), jnp.float32)
    for g, coeffs in [("exp", [0.0, -0.4]), ("exp", [0.0, -0.3, -0.2]),
                      ("identity", [1.0, 0.5, 0.1])]:
        cs = jnp.asarray(coeffs, jnp.float32)
        fm = MK.make_sequence_fastmult(g, cs, L, causal=True, dist_scale=1 / L)
        got = MK.masked_linear_attention(qf, kf, V, fm)
        Fv = MK.sequence_mask_values(g, cs, L, 1 / L)
        ref = MK.masked_attention_bruteforce(qf, kf, V,
                                             toeplitz_dense(Fv, L, True))
        err = float(jnp.max(jnp.abs(got - ref)))
        emit(f"tab1/exactness/{g}_t{len(coeffs)-1}", 0.0, f"maxerr={err:.2e}")


def scaling(rng):
    d, m = 32, 16
    cs = jnp.asarray([0.0, -0.3, -0.1], jnp.float32)
    for L in (512, 2048, 8192):
        qf = jnp.asarray(np.abs(rng.normal(size=(1, L, m))), jnp.float32)
        kf = jnp.asarray(np.abs(rng.normal(size=(1, L, m))), jnp.float32)
        V = jnp.asarray(rng.normal(size=(1, L, d)), jnp.float32)
        fm = MK.make_sequence_fastmult("exp", cs, L, causal=True,
                                       dist_scale=1 / L)
        fast = jax.jit(lambda q, k, v: MK.masked_linear_attention(q, k, v, fm))
        t_fast = timeit(lambda: jax.block_until_ready(fast(qf, kf, V)))
        if L <= 2048:
            Fv = MK.sequence_mask_values("exp", cs, L, 1 / L)
            mask = toeplitz_dense(Fv, L, True)
            brute = jax.jit(lambda q, k, v: MK.masked_attention_bruteforce(
                q, k, v, mask))
            t_brute = timeit(lambda: jax.block_until_ready(brute(qf, kf, V)))
            emit(f"tab1/latency/L{L}/alg1_fft", t_fast,
                 f"brute={t_brute*1e6:.0f}us speedup={t_brute/t_fast:.2f}x")
        else:
            emit(f"tab1/latency/L{L}/alg1_fft", t_fast, "brute=OOM-skip")


def tree_attention(rng, backends=("plan",), side=8):
    """Grid-MST topological masking (the ViT path) per Integrator backend:
    exactness vs the dense mask and per-call latency of Algorithm 1."""
    from repro.graphs.graph import grid_graph
    from repro.graphs.mst import minimum_spanning_tree
    from repro.graphs.traverse import tree_all_pairs

    L, d, m = side * side, 16, 8
    g, coeffs = "exp", jnp.asarray([0.0, -0.25, -0.05], jnp.float32)
    mst = minimum_spanning_tree(grid_graph(side, side))
    D = tree_all_pairs(mst)
    qf = jnp.asarray(np.abs(rng.normal(size=(2, L, m))), jnp.float32)
    kf = jnp.asarray(np.abs(rng.normal(size=(2, L, m))), jnp.float32)
    V = jnp.asarray(rng.normal(size=(2, L, d)), jnp.float32)
    mask = MK.mask_f(g, coeffs, 1.0 / L)(jnp.asarray(D))
    ref = MK.masked_attention_bruteforce(qf, kf, V, mask)
    for backend in backends:
        integ = Integrator(mst, backend=backend, leaf_size=16)
        fm = MK.make_tree_fastmult(integ, g, coeffs, 1.0 / L)
        attn = lambda: jax.block_until_ready(
            MK.masked_linear_attention(qf, kf, V, fm))
        got = attn()
        err = float(jnp.max(jnp.abs(got - ref)))
        t = timeit(attn)
        engine = integ.describe(MK.mask_f(g, coeffs, 1.0 / L))["cross_engine"]
        emit(f"tab1/tree/L{L}/{backend}", t,
             f"maxerr={err:.2e} engine={engine}")


def run(backends=("plan",), quick=False):
    """Returns the impl-sweep rows (written to BENCH_topo_attention.json by
    benchmarks.run) after the exactness/scaling/tree sections print."""
    rng = np.random.default_rng(0)
    exactness(rng)
    scaling(rng)
    tree_attention(rng, backends=backends)
    return impl_sweep(rng, quick=quick)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="plan",
                    help="comma list of plan,pallas (tree-mask section)")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(backends=tuple(args.backend.split(",")), quick=args.quick)


if __name__ == "__main__":
    main()
