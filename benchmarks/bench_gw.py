"""Paper Fig. 10: FTFI inside Gromov-Wasserstein-style conditional-gradient
iterations — the inner loop is repeated multiplication of transport plans by
f-distance matrices; FTFI replaces the materialized (BGFI) kernel."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import Exponential, FTFI
from repro.core.integrate import BTFI
from repro.graphs.graph import synthetic_graph
from repro.graphs.mst import minimum_spanning_tree


def gw_inner_loop(mult_a, mult_b, n1, n2, iters=5, seed=0):
    """Simplified entropic-GW conditional gradient: T <- rownorm(exp-like
    update using C1 @ T @ C2 products). mult_a/mult_b apply the two graphs'
    f-distance matrices."""
    rng = np.random.default_rng(seed)
    T = np.full((n1, n2), 1.0 / (n1 * n2), dtype=np.float32)
    for _ in range(iters):
        G = mult_a(mult_b(T.T).T)  # C1 @ T @ C2 (the O(n^2)/O(n log n) core)
        T = np.exp(-G / (np.abs(G).max() + 1e-9)).astype(np.float32)
        T /= T.sum(axis=1, keepdims=True) * n1
    return T


def run(n=5000, iters=2):
    fn = Exponential(-0.5)
    g1 = minimum_spanning_tree(synthetic_graph(n, n // 3, seed=1))
    g2 = minimum_spanning_tree(synthetic_graph(n, n // 3, seed=2))

    # exp kernels admit the two-pass message-passing integrator (exact,
    # bandwidth-optimal — core.integrate.ExpMP, beyond-paper); general
    # cordial f falls back to the IT-based FTFI
    from repro.core.integrate import ExpMP

    mp1, mp2 = ExpMP(g1), ExpMP(g2)
    btfi1, btfi2 = BTFI(g1, dtype=np.float32), BTFI(g2, dtype=np.float32)

    fm1 = lambda X: mp1.integrate(-0.5, X)
    fm2 = lambda X: mp2.integrate(-0.5, X)
    bm1 = lambda X: btfi1.integrate(fn, X)
    bm2 = lambda X: btfi2.integrate(fn, X)

    t_f = timeit(lambda: gw_inner_loop(fm1, fm2, n, n, iters), repeat=1)
    t_b = timeit(lambda: gw_inner_loop(bm1, bm2, n, n, iters), repeat=1)
    Tf = gw_inner_loop(fm1, fm2, n, n, iters)
    Tb = gw_inner_loop(bm1, bm2, n, n, iters)
    err = np.max(np.abs(Tf - Tb)) / max(np.max(np.abs(Tb)), 1e-12)
    emit(f"fig10/gw_ftfi/n{n}", t_f, f"speedup={t_b/t_f:.2f}x relerr={err:.1e}")
    emit(f"fig10/gw_bgfi/n{n}", t_b)
    return t_b / t_f


if __name__ == "__main__":
    run()
