"""Serving loadgen: threaded submit against a live ServeEngine, fused vs
replay prefill, latency percentiles + split token throughput.

A feeder thread submits requests on an open-loop schedule while the main
thread ticks the engine — the engine itself is single-threaded (one lock
serializes submit/step), so this exercises the real serving pattern:
requests arriving WHILE earlier waves decode, which only the fused-prefill
engine can admit mid-wave.

Per (mode, prompt_len) cell the harness records end-to-end latency and
time-to-first-token percentiles (p50/p99) plus tokens/s split into prefill
(prompt processing) and decode (generation) — the numbers the old launch
CLI over-reported by assuming every request produced `max_new` tokens.
Replay mode runs prompts through per-token decode ticks, so its prompt
throughput is attributed from the uniform per-tick decode cost; fused mode
measures its prefill calls directly. Both modes serve IDENTICAL prompts
and the harness cross-checks greedy parity (`parity_ok`): fused must
reproduce replay's token streams bit-for-bit.

    PYTHONPATH=src python -m benchmarks.bench_serve --json BENCH_serve.json

Gated in CI by `check_bench --suite serve`: fused prompt throughput must
beat replay at prompt_len >= 32, p99s must be recorded, parity must hold.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import threading
import time

if __package__ in (None, ""):
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))


def serve_workload(cfg, params, prompts, mode, *, slots, max_len, max_new,
                   submit_interval_s=0.0):
    """Serve `prompts` through one engine; returns (row dict, token outs)."""
    import numpy as np

    from repro.serve.engine import Request, ServeEngine

    eng = ServeEngine(cfg, params, batch_slots=slots, max_len=max_len,
                      prefill_mode=mode)
    # warm the jit caches (prefill bucket + decode) outside the timed window
    warm = [Request(rid=-1 - i, prompt=list(p), max_new_tokens=2)
            for i, p in enumerate(prompts[:2])]
    for r in warm:
        eng.submit(r)
    eng.run()
    base = {k: eng.stats()[k] for k in ("prefill_tokens", "decode_tokens",
                                        "prefill_s", "decode_s")}

    reqs = [Request(rid=i, prompt=list(p), max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    lock = threading.Lock()
    marks = {r.rid: {} for r in reqs}

    def feeder():
        for r in reqs:
            with lock:
                marks[r.rid]["submit"] = time.perf_counter()
                eng.submit(r)
            if submit_interval_s:
                time.sleep(submit_interval_s)

    th = threading.Thread(target=feeder)
    t0 = time.perf_counter()
    th.start()
    while True:
        with lock:
            busy = eng.step()
            now = time.perf_counter()
            for r in reqs:
                m = marks[r.rid]
                if "submit" not in m:
                    continue
                if r.out and "first" not in m:
                    m["first"] = now
                if r.done and "done" not in m:
                    m["done"] = now
            drained = not th.is_alive() and all(r.done for r in reqs)
        if drained:
            break
        if not busy:
            time.sleep(0.001)
    th.join()
    wall = time.perf_counter() - t0

    st = eng.stats()
    pf_tok = st["prefill_tokens"] - base["prefill_tokens"]
    dc_tok = st["decode_tokens"] - base["decode_tokens"]
    pf_s = st["prefill_s"] - base["prefill_s"]
    dc_s = st["decode_s"] - base["decode_s"]
    if mode == "replay":
        # prompts replay through decode ticks: split the (uniform per-tick)
        # decode time by token share to attribute prompt-processing cost
        total = max(pf_tok + dc_tok, 1)
        pf_s = dc_s * pf_tok / total
        dc_s = dc_s * dc_tok / total
    e2e = np.array([(marks[r.rid]["done"] - marks[r.rid]["submit"]) * 1e3
                    for r in reqs])
    ttft = np.array([(marks[r.rid]["first"] - marks[r.rid]["submit"]) * 1e3
                     for r in reqs])
    gen = sum(len(r.out) for r in reqs)
    row = {
        "mode": mode,
        "prompt_len": len(prompts[0]),
        "requests": len(reqs),
        "slots": slots,
        "max_new": max_new,
        "completed": st["completed"] - 2,  # minus warmup
        "failed": st["failed"],
        "truncated": st["truncated"],
        "wall_s": wall,
        "p50_ms": float(np.percentile(e2e, 50)),
        "p99_ms": float(np.percentile(e2e, 99)),
        "ttft_p50_ms": float(np.percentile(ttft, 50)),
        "ttft_p99_ms": float(np.percentile(ttft, 99)),
        "gen_tok_s": gen / wall,
        "prefill_tok_s": pf_tok / max(pf_s, 1e-9),
        "decode_tok_s": dc_tok / max(dc_s, 1e-9),
        "prefill_tokens": pf_tok,
        "decode_tokens": dc_tok,
    }
    return row, [list(r.out) for r in reqs]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_1_5b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--prompt-lens", default="8,32",
                    help="comma-separated prompt lengths; 32 is the CI "
                         "fused-vs-replay throughput gate point")
    ap.add_argument("--submit-interval-ms", type=float, default=2.0,
                    help="feeder-thread gap between submissions (open-loop "
                         "arrivals land mid-wave)")
    ap.add_argument("--json", default="BENCH_serve.json")
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.configs.base import get_smoke_config
    from repro.models import api

    cfg = get_smoke_config(args.arch).replace(dtype="float32")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    rows = []
    for pl in [int(x) for x in args.prompt_lens.split(",")]:
        if pl + args.max_new >= args.max_len:
            print(f"skip prompt_len={pl}: prompt+max_new would truncate at "
                  f"max_len={args.max_len}")
            continue
        prompts = [rng.integers(0, cfg.vocab_size, size=pl).tolist()
                   for _ in range(args.requests)]
        outs = {}
        for mode in ("fused", "replay"):
            row, out = serve_workload(
                cfg, params, prompts, mode, slots=args.slots,
                max_len=args.max_len, max_new=args.max_new,
                submit_interval_s=args.submit_interval_ms * 1e-3)
            outs[mode] = out
            rows.append(row)
            print(f"{mode:6s} pl={pl:3d}: p50 {row['p50_ms']:7.1f}ms "
                  f"p99 {row['p99_ms']:7.1f}ms ttft_p50 "
                  f"{row['ttft_p50_ms']:6.1f}ms prefill "
                  f"{row['prefill_tok_s']:8.1f} tok/s decode "
                  f"{row['decode_tok_s']:8.1f} tok/s", flush=True)
        parity = outs["fused"] == outs["replay"]
        for row in rows[-2:]:
            row["parity_ok"] = bool(parity)
        if not parity:
            print(f"PARITY MISMATCH at prompt_len={pl}: fused != replay")

    with open(args.json, "w") as fh:
        json.dump({"suite": "serve", "arch": args.arch, "rows": rows}, fh,
                  indent=2)
    print(f"wrote {args.json} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
