"""Paper Fig. 4: mesh vertex-normal interpolation — preprocessing time vs
cosine similarity for FTFI / BTFI / random-spanning-tree baselines."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import FTFI, Rational
from repro.core.integrate import BTFI
from repro.graphs.graph import WeightedTree
from repro.graphs.meshes import icosphere, mesh_graph, torus_mesh, vertex_normals
from repro.graphs.mst import minimum_spanning_tree
from repro.graphs.traverse import tree_bfs_order


def random_spanning_tree(g, seed=0):
    """Random-weight spanning tree (low-stretch baseline stand-in)."""
    rng = np.random.default_rng(seed)
    from repro.graphs.graph import Graph

    g2 = Graph(g.num_vertices, g.edges_u, g.edges_v,
               rng.uniform(0.1, 1.0, g.num_edges))
    t = minimum_spanning_tree(g2)
    # restore true edge lengths on the chosen edges
    key = {(min(u, v), max(u, v)): w for u, v, w in
           zip(g.edges_u, g.edges_v, g.weights)}
    w = np.array([key[(min(u, v), max(u, v))]
                  for u, v in zip(t.edges_u, t.edges_v)])
    return WeightedTree(t.num_vertices, t.edges_u, t.edges_v, w)


def _interpolate(integrator, fn, normals, known):
    F = np.where(known[:, None], normals, 0.0)
    pred = integrator.integrate(fn, F)
    pred /= np.maximum(np.linalg.norm(pred, axis=1, keepdims=True), 1e-12)
    cos = np.sum(pred[~known] * normals[~known], axis=1)
    return float(np.mean(cos))


def run(meshes=None, lambdas=(1.0, 4.0, 16.0)):
    meshes = meshes or [("ico3", *icosphere(3)), ("ico4", *icosphere(4)),
                        ("torus", *torus_mesh(48, 24))]
    rng = np.random.default_rng(0)
    results = []
    for name, verts, faces in meshes:
        normals = vertex_normals(verts, faces)
        g = mesh_graph(verts, faces)
        n = verts.shape[0]
        known = rng.random(n) < 0.2
        for method, mk in [
            ("ftfi_mst", lambda: FTFI(minimum_spanning_tree(g), leaf_size=128)),
            ("btfi_mst", lambda: BTFI(minimum_spanning_tree(g),
                                      dtype=np.float32)),
            ("ftfi_rst", lambda: FTFI(random_spanning_tree(g), leaf_size=128)),
        ]:
            t_pre = timeit(mk, repeat=1, warmup=0)
            integ = mk()
            best = -1.0
            for lam in lambdas:
                fn = Rational((1.0,), (1.0, 0.0, lam))
                best = max(best, _interpolate(integ, fn, normals, known))
            emit(f"fig4/{name}/n{n}/{method}", t_pre, f"cos={best:.4f}")
            results.append((name, method, t_pre, best))
        # FRT tree baseline (paper's Fig-4 comparison; O(N^2) preprocessing)
        if n <= 3000:
            import time as _t

            from repro.core.integrate import FTFI as _FTFI
            from repro.graphs.frt import frt_tree
            from repro.graphs.traverse import graph_all_pairs

            t0 = _t.perf_counter()
            Dg = graph_all_pairs(g)  # seed-independent; shared with the
            ft, leaf = frt_tree(g, seed=0, D=Dg)  # forest row below
            integ = _FTFI(ft, leaf_size=128)
            t_pre = _t.perf_counter() - t0
            best = -1.0
            for lam in lambdas:
                fn = Rational((1.0,), (1.0, 0.0, lam))
                F = np.where(known[:, None], normals, 0.0)
                Ffull = np.zeros((ft.num_vertices, 3))
                Ffull[leaf] = F
                pred = integ.integrate(fn, Ffull)[leaf]
                pred /= np.maximum(np.linalg.norm(pred, axis=1, keepdims=True),
                                   1e-12)
                cos = float(np.mean(np.sum(pred[~known] * normals[~known], 1)))
                best = max(best, cos)
            emit(f"fig4/{name}/n{n}/ftfi_frt", t_pre, f"cos={best:.4f}")
            results.append((name, "ftfi_frt", t_pre, best))
            # FRT FOREST: Fig 4's expectation estimate — k sampled trees as
            # ONE fused forest integration, per-tree outputs averaged.
            # Forest construction is hoisted out of the lambda sweep (it is
            # lambda-independent), mirroring the single-tree row above.
            from repro.core.engines import Integrator
            from repro.graphs.frt import forest_leaf_integrate, frt_forest

            k = 4
            t0 = _t.perf_counter()
            forest, leaf = frt_forest(g, k, seed=0, D=Dg)
            finteg = Integrator.from_forest(forest, backend="plan",
                                            leaf_size=128)
            t_forest_pre = _t.perf_counter() - t0
            best = -1.0
            for lam in lambdas:
                fn = Rational((1.0,), (1.0, 0.0, lam))
                F = np.where(known[:, None], normals, 0.0)
                pred = forest_leaf_integrate(forest, leaf, finteg, fn, F)
                pred /= np.maximum(np.linalg.norm(pred, axis=1, keepdims=True),
                                   1e-12)
                cos = float(np.mean(np.sum(pred[~known] * normals[~known], 1)))
                best = max(best, cos)
            emit(f"fig4/{name}/n{n}/ftfi_frt_forest{k}", t_forest_pre,
                 f"cos={best:.4f}")
            results.append((name, f"ftfi_frt_forest{k}", t_forest_pre, best))
    return results


if __name__ == "__main__":
    run()
