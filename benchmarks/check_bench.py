"""CI smoke gate over the benchmark JSON artifacts.

--suite ftfi (default) gates BENCH_ftfi_runtime.json + IT-build wall clock +
the fused forest plan. Fails (exit 1) when:
  * any exact-engine row reports rel_err > --max-rel-err (default 1e-4) —
    chebyshev rows are approximate by design and only get a loose sanity
    bound;
  * the flat IT build at n=2000 on path / star / caterpillar / synthetic-MST
    topologies exceeds --it-ceiling seconds (a deliberately generous bound:
    the vectorized builder runs in tens of milliseconds, so tripping it
    means the hot path got re-pythonized) or loses Lemma-3.1 balance;
  * the fused forest plan diverges from the per-tree host loop by more than
    --forest-rel-err (default 1e-5) on a small mixed-size forest.

  * a plan-backend row's cold plan assembly (pre_plan_s) exceeds its
    --plan-ceiling (vectorized assembly runs in single-digit milliseconds;
    tripping the ceiling means the per-node Python loop came back), or the
    incremental-update speedup (upd_speedup, warm `ftfi.update_plan` vs a
    cold reweightable recompile) falls under --upd-speedup;
  * the disk plan cache fails its live round-trip: a cold-process rebuild
    (memory caches cleared) with a populated FTFI_PLAN_CACHE directory must
    hit the cache, return a digest-identical plan, and stay under
    --cache-warm-ceiling seconds.

--suite topo gates BENCH_topo_attention.json: every topo_attn_impl row must
stay within --topo-rel-err (default 1e-3) of its exactness anchor, and the
fused impl must not be slower than the fft chunk-loop path it replaces.

--suite robustness runs the live fault matrix (no input JSON) and writes it
to --robustness-json (default BENCH_robustness.json). Fails when:
  * plan-guard validation of a warm n=4000 plan costs more than
    --guard-overhead (default 5%) of the warm-IT plan assembly time
    (pre_plan_s), with a small absolute floor against timer noise;
  * the degradation ladder's fallback output (pallas rung forced to fail)
    diverges from the host oracle by more than --ladder-rel-err (1e-5);
  * any fault-matrix row — corrupt artifact (truncated / bit-flipped),
    flipped index, NaN field, kernel raise, non-finite kernel output,
    post-write disk-cache corruption, serve slot/step crash — fails to
    recover or degrade to the host-exact output.

--suite serve gates BENCH_serve.json (written by bench_serve): every row
must carry a recorded p99 (end-to-end AND time-to-first-token) and pass the
fused-vs-replay greedy parity cross-check, and at every prompt length >=
--serve-gate-len (default 32) the fused engine's prompt-processing
throughput (prefill_tok_s) must be at least --serve-min-speedup x replay's
— tripping it means the fused prefill-into-cache path regressed to (or
below) token-by-token replay.

--suite sharding gates the weak-scaling rows bench_ftfi_runtime --devices
wrote into BENCH_ftfi_runtime.json: every sharded row's parity rel_err vs
the single-device jitted executor must stay under --sharding-rel-err
(default 1e-5), and every multi-device partition must reduce per-device
work (padded per-device gather length under --max-work-frac of the global
plan's flat entries).

  PYTHONPATH=src python -m benchmarks.check_bench BENCH_ftfi_runtime.json
  PYTHONPATH=src python -m benchmarks.check_bench --suite topo BENCH_topo_attention.json
  PYTHONPATH=src python -m benchmarks.check_bench --suite robustness
  PYTHONPATH=src python -m benchmarks.check_bench --suite sharding BENCH_ftfi_runtime.json
  PYTHONPATH=src python -m benchmarks.check_bench --suite serve BENCH_serve.json
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

if __package__ in (None, ""):
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

APPROX_ENGINES = {"chebyshev"}
APPROX_REL_ERR = 1e-2


def check_json(path: str, max_rel_err: float) -> list[str]:
    with open(path) as fh:
        rows = json.load(fh)["rows"]
    errors = []
    if not rows:
        errors.append(f"{path}: no benchmark rows")
    for r in rows:
        bound = (APPROX_REL_ERR if r["engine"] in APPROX_ENGINES
                 else max_rel_err)
        if r["rel_err"] > bound:
            errors.append(
                f"{r['case']}/n{r['n']}/{r['backend']} ({r['engine']}): "
                f"rel_err {r['rel_err']:.2e} > {bound:.0e}")
    return errors


def check_it_build(n: int, ceiling: float) -> list[str]:
    import numpy as np  # noqa: F401  (env sanity before heavy imports)
    from repro.core import build_flat_it, clear_flat_cache, flat_stats
    from repro.graphs.graph import (caterpillar_tree, path_graph, star_tree,
                                    synthetic_graph)
    from repro.graphs.mst import minimum_spanning_tree

    cases = {
        "path": path_graph(n),
        "star": star_tree(n, seed=0),
        "caterpillar": caterpillar_tree(n, seed=0),
        "synthetic_mst": minimum_spanning_tree(
            synthetic_graph(n, n // 2, seed=1)),
    }
    errors = []
    for name, tree in cases.items():
        clear_flat_cache()
        t0 = time.perf_counter()
        flat = build_flat_it(tree, leaf_size=64)
        dt = time.perf_counter() - t0
        stats = flat_stats(flat)
        if dt > ceiling:
            errors.append(f"IT build {name} n={n}: {dt:.2f}s > {ceiling}s "
                          "ceiling (re-pythonized hot path?)")
        if not stats["balance_ok"]:
            errors.append(f"IT build {name} n={n}: balance_ok=False")
    return errors


def check_forest(max_rel_err: float) -> list[str]:
    """Forest smoke: the fused forest plan must equal the per-tree host loop
    on a small mixed-size forest, for an exact family AND a general f."""
    import numpy as np
    from repro.core import AnyFn, Exponential, Forest, Integrator
    from repro.graphs.graph import (caterpillar_tree, path_graph, random_tree,
                                    star_tree)

    rng = np.random.default_rng(0)
    trees = [random_tree(int(s), seed=i)
             for i, s in enumerate(rng.integers(8, 48, size=12))]
    trees += [path_graph(40), star_tree(30, seed=1),
              caterpillar_tree(36, seed=2)]
    forest = Forest(trees)
    X = rng.normal(size=(forest.num_vertices, 3))
    loop = Integrator.from_forest(forest, backend="host")
    errors = []
    for fn, label in ((Exponential(-0.6, 1.2), "exp"),
                      (AnyFn(lambda z: 1.0 / (1.0 + z)), "anyfn")):
        ref = np.asarray(loop.integrate(fn, X))
        got = np.asarray(Integrator.from_forest(
            forest, backend="plan", leaf_size=16).integrate(fn, X))
        err = float(np.max(np.abs(got - ref)) / np.max(np.abs(ref)))
        if err > max_rel_err:
            errors.append(
                f"forest plan vs per-tree loop ({label}): rel_err "
                f"{err:.2e} > {max_rel_err:.0e}")
    return errors


def check_cold_compile(path: str, plan_ceiling: float,
                       upd_speedup: float) -> list[str]:
    """Plan-compile latency gate over the benchmark JSON: cold vectorized
    assembly must stay in the milliseconds, and the incremental-update path
    must beat a cold recompile by a wide margin (it exists for nothing
    else). Rows without the update columns (non-plan backends, forest) are
    skipped."""
    with open(path) as fh:
        rows = json.load(fh)["rows"]
    errors = []
    for r in rows:
        if r["backend"] not in ("plan", "pallas", "ftfi", "forest"):
            continue
        if r["pre_plan_s"] > plan_ceiling:
            errors.append(
                f"{r['case']}/n{r['n']}/{r['backend']}: cold pre_plan_s "
                f"{r['pre_plan_s']*1e3:.1f}ms > {plan_ceiling*1e3:.0f}ms "
                "ceiling (plan assembly re-pythonized?)")
        if "upd_speedup" in r and r["upd_speedup"] < upd_speedup:
            errors.append(
                f"{r['case']}/n{r['n']}/{r['backend']}: upd_speedup "
                f"{r['upd_speedup']:.1f}x < {upd_speedup:.0f}x (incremental "
                "update no longer beats recompiling)")
    if not any("upd_speedup" in r for r in rows):
        errors.append(f"{path}: no rows carry upd_speedup — bench suite "
                      "predates the incremental-update columns; regenerate")
    return errors


def check_disk_cache(warm_ceiling: float) -> list[str]:
    """Live disk-cache round trip: populate a temp FTFI_PLAN_CACHE via one
    build, clear the in-memory caches (simulating a fresh process), and
    require the rebuild to hit the disk cache, match digests, and come back
    well under compile cost."""
    import tempfile

    import numpy as np  # noqa: F401
    from repro import ftfi
    from repro.core import clear_flat_cache, clear_plan_cache, plan_cache
    from repro.graphs.graph import synthetic_graph
    from repro.graphs.mst import minimum_spanning_tree

    tree = minimum_spanning_tree(synthetic_graph(2000, 1000, seed=1))
    errors = []
    with tempfile.TemporaryDirectory() as d:
        plan_cache.configure(d, max_mb=64)
        try:
            spec1, _ = ftfi.build(tree, leaf_size=64, reweightable=True)
            st = plan_cache.stats()
            if st["stores"] < 1 or st["entries"] < 1:
                errors.append(f"disk cache: build did not populate the "
                              f"cache dir ({st})")
            clear_flat_cache()
            clear_plan_cache()
            t0 = time.perf_counter()
            spec2, _ = ftfi.build(tree, leaf_size=64, reweightable=True)
            dt = time.perf_counter() - t0
            st = plan_cache.stats()
            if st["hits"] < 1:
                errors.append(f"disk cache: cold-process rebuild missed "
                              f"the populated cache ({st})")
            if spec1.digest != spec2.digest:
                errors.append("disk cache: cached plan digest differs from "
                              "the freshly compiled one")
            if dt > warm_ceiling:
                errors.append(
                    f"disk cache: warm rebuild took {dt:.2f}s > "
                    f"{warm_ceiling}s ceiling (cache load slower than "
                    "recompiling?)")
        finally:
            plan_cache.reset_to_env()
            clear_flat_cache()
            clear_plan_cache()
    return errors


def check_sharding_json(path: str, max_rel_err: float,
                        max_work_frac: float) -> list[str]:
    """Weak-scaling gate over the sharded rows of BENCH_ftfi_runtime.json
    (`bench_ftfi_runtime --devices 1,2,4,8`): every row's parity rel_err
    against the single-device jitted executor must stay under
    --sharding-rel-err, and every multi-device partition must actually
    reduce per-device work — the padded per-device gather length under
    --max-work-frac of the global plan's flat entries."""
    with open(path) as fh:
        rows = json.load(fh)["rows"]
    rows = [r for r in rows if r.get("backend") == "sharded"]
    errors = []
    if not rows:
        errors.append(f"{path}: no sharded rows — run "
                      "bench_ftfi_runtime --devices 1,2,4,8 first")
    if not any(r["devices"] > 1 for r in rows):
        errors.append(f"{path}: sharded rows cover only 1 device — the "
                      "weak-scaling sweep did not run (too few visible "
                      "devices?)")
    for r in rows:
        where = f"{r['case']}/n{r['n']}/devices{r['devices']}"
        if r["rel_err"] > max_rel_err:
            errors.append(f"{where}: sharded parity rel_err "
                          f"{r['rel_err']:.2e} > {max_rel_err:.0e}")
        if r["devices"] > 1:
            frac = r["device_rows"] / max(r["global_rows"], 1)
            if frac > max_work_frac:
                errors.append(
                    f"{where}: per-device work {r['device_rows']} rows is "
                    f"{frac:.0%} of the global plan ({r['global_rows']}) > "
                    f"{max_work_frac:.0%} — the partition is not reducing "
                    "work")
            n_pad = r["block"] * r["devices"]
            if n_pad < r["n"]:
                errors.append(f"{where}: block {r['block']} x {r['devices']}"
                              f" devices < n={r['n']} (vertices dropped)")
    return errors


def check_topo_json(path: str, max_rel_err: float) -> list[str]:
    """Topo-attention impl parity gate: every impl row within max_rel_err of
    its anchor, and the fused impl at least as fast as the fft chunk-loop."""
    with open(path) as fh:
        rows = json.load(fh)["rows"]
    errors = []
    if not rows:
        errors.append(f"{path}: no benchmark rows")
    for r in rows:
        if r["rel_err"] > max_rel_err:
            errors.append(
                f"{r['case']}/L{r['L']}/{r['impl']}: rel_err "
                f"{r['rel_err']:.2e} > {max_rel_err:.0e}")
        if r["impl"] == "pallas" and r["speedup_vs_fft"] < 1.0:
            errors.append(
                f"{r['case']}/L{r['L']}/pallas: fused path slower than the "
                f"fft chunk-loop ({r['speedup_vs_fft']:.2f}x)")
    return errors


def check_serve_json(path: str, gate_len: int,
                     min_speedup: float) -> list[str]:
    """Serving gate over bench_serve rows: latency percentiles recorded,
    fused==replay greedy parity, and fused prompt throughput >= min_speedup
    x replay's at every prompt length >= gate_len."""
    with open(path) as fh:
        rows = json.load(fh)["rows"]
    errors = []
    if not rows:
        errors.append(f"{path}: no benchmark rows")
    by = {}
    for r in rows:
        where = f"{r['mode']}/pl{r['prompt_len']}"
        by[(r["mode"], r["prompt_len"])] = r
        for k in ("p99_ms", "ttft_p99_ms", "p50_ms", "ttft_p50_ms"):
            if r.get(k) is None:
                errors.append(f"{where}: {k} not recorded")
        if not r.get("parity_ok", False):
            errors.append(f"{where}: fused-vs-replay greedy parity failed "
                          "(or was not cross-checked)")
        if r.get("failed", 0):
            errors.append(f"{where}: {r['failed']} requests failed")
    gated = [pl for (m, pl) in by if m == "fused" and pl >= gate_len
             and ("replay", pl) in by]
    if not gated:
        errors.append(f"{path}: no fused/replay pair at prompt_len >= "
                      f"{gate_len} — the throughput gate did not run")
    for pl in gated:
        f, rp = by[("fused", pl)], by[("replay", pl)]
        if f["prefill_tok_s"] < min_speedup * rp["prefill_tok_s"]:
            errors.append(
                f"fused/pl{pl}: prefill {f['prefill_tok_s']:.0f} tok/s < "
                f"{min_speedup:.1f}x replay's {rp['prefill_tok_s']:.0f} "
                "tok/s (fused prefill-into-cache regressed to replay "
                "speed)")
    return errors


def check_robustness(out_path: str, guard_overhead: float,
                     ladder_rel_err: float) -> list[str]:
    """Live robustness gate + fault-matrix artifact. Every row must either
    recover (retry reproduces the answer) or degrade to the host-exact
    output; the artifact records what happened for each fault class."""
    import tempfile
    import warnings

    import numpy as np
    from repro import ftfi
    from repro.core import clear_flat_cache, clear_plan_cache
    from repro.core import cordial as C
    from repro.core import ladder, plan_cache, plan_guard
    from repro.core.itree_flat import build_flat_it
    from repro.core.plan_guard import PlanValidationError
    from repro.graphs.graph import synthetic_graph
    from repro.graphs.mst import minimum_spanning_tree
    from repro.testing import faults

    errors: list[str] = []
    rows: list[dict] = []

    def row(fault: str, recovered: bool, outcome: str,
            rel_err: float | None = None, **extra) -> None:
        rows.append({"fault": fault, "recovered": bool(recovered),
                     "outcome": outcome, "rel_err": rel_err, **extra})
        if not recovered:
            errors.append(f"robustness matrix: {fault}: {outcome}")

    # -- validation overhead vs warm plan assembly (the pre_plan_s analogue:
    # cold plan assembly on a warm flat-IT cache, min over rounds)
    tree = minimum_spanning_tree(synthetic_graph(4000, 2000, seed=1))
    build_flat_it(tree, leaf_size=256)
    t_plan = float("inf")
    for _ in range(3):
        clear_plan_cache()
        t0 = time.perf_counter()
        ftfi.build(tree, leaf_size=256)
        t_plan = min(t_plan, time.perf_counter() - t0)
    spec, params = ftfi.build(tree, leaf_size=256)
    t_val = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        issues = plan_guard.check_spec(spec, params)
        t_val = min(t_val, time.perf_counter() - t0)
    if issues:
        errors.append(f"robustness: healthy n=4000 plan failed validation: "
                      f"{issues[:3]}")
    budget = max(guard_overhead * t_plan, 2e-3)  # 2ms timer-noise floor
    if t_val > budget:
        errors.append(
            f"robustness: plan-guard validation {t_val*1e3:.2f}ms > "
            f"{guard_overhead:.0%} of warm pre_plan_s "
            f"({t_plan*1e3:.2f}ms)")
    rows.append({"fault": "none (overhead)", "recovered": t_val <= budget,
                 "outcome": f"validation {t_val*1e3:.3f}ms on warm "
                            f"pre_plan_s {t_plan*1e3:.2f}ms",
                 "rel_err": None, "validate_s": t_val, "pre_plan_s": t_plan})

    fn = C.Exponential(-0.5)
    X = np.random.default_rng(0).normal(size=(spec.n, 4)).astype(np.float32)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        ref = np.asarray(ftfi.apply(spec, params, fn, X, backend="host"))

        # -- ladder: forced kernel raise -> fallback parity vs host oracle
        ladder.reset_stats()
        with faults.injected("ladder.pallas", faults.always_raise(
                RuntimeError, "injected kernel launch failure")):
            got = np.asarray(ftfi.apply_resilient(spec, params, fn, X,
                                                  backend="pallas"))
        err = float(np.max(np.abs(got - ref)) / max(np.max(np.abs(ref)),
                                                    1e-12))
        st = ladder.stats()
        ok = err <= ladder_rel_err and st["demotions"] >= 1
        row("kernel raise (ladder.pallas)", ok,
            f"demoted {st['demotions']}x, rel_err {err:.1e} vs host",
            rel_err=err)
        if err > ladder_rel_err:
            errors.append(f"robustness: ladder fallback rel_err {err:.2e} > "
                          f"{ladder_rel_err:.0e} vs host oracle")

        # -- ladder: non-finite kernel output -> demotes through to parity
        with faults.injected("ladder.out.pallas", faults.nan_output()):
            got = np.asarray(ftfi.apply_resilient(spec, params, fn, X,
                                                  backend="pallas"))
        err = float(np.max(np.abs(got - ref)) / max(np.max(np.abs(ref)),
                                                    1e-12))
        row("non-finite kernel output (ladder.out.pallas)",
            err <= ladder_rel_err,
            f"rel_err {err:.1e} vs host after demotion", rel_err=err)

    # -- corrupt artifact: truncated / bit-flipped npz must be rejected
    with tempfile.TemporaryDirectory() as d:
        p = pathlib.Path(d) / "plan.npz"
        ftfi.save_plan(p, spec, params)
        blob = p.read_bytes()
        for fault, corrupt in (
                ("truncated artifact",
                 lambda: faults.corrupt_file(p, truncate_to=len(blob) // 2)),
                ("bit-flipped artifact",
                 lambda: faults.corrupt_file(p, flip_bytes=64, seed=7))):
            p.write_bytes(blob)
            corrupt()
            try:
                ftfi.load_plan(p)
                row(fault, False, "load_plan accepted a damaged artifact")
            except PlanValidationError as e:
                row(fault, True, f"rejected: {str(e)[:80]}")
            except Exception as e:  # anything else is an unhandled leak
                row(fault, False,
                    f"unstructured {type(e).__name__}: {str(e)[:80]}")

    # -- flipped index / NaN field caught by the guard before dispatch
    bad = faults.flip_index(spec, field="src_gather")
    try:
        ftfi.validate(bad, params)
        row("flipped index (src_gather)", False, "guard missed OOB index")
    except PlanValidationError as e:
        row("flipped index (src_gather)", True, f"rejected: {str(e)[:80]}")
    import dataclasses
    dists = list(params.cross_src_d)
    if dists:
        d0 = np.array(dists[0], copy=True)
        d0.reshape(-1)[:1] = np.nan
        nan_params = dataclasses.replace(
            params, cross_src_d=(d0,) + tuple(dists[1:]))
        try:
            ftfi.validate(spec, nan_params)
            row("NaN field (cross_src_d)", False, "guard missed NaN params")
        except PlanValidationError as e:
            row("NaN field (cross_src_d)", True, f"rejected: {str(e)[:80]}")

    # -- disk cache post-write corruption: strict reject -> rebuild
    with tempfile.TemporaryDirectory() as d:
        plan_cache.configure(d, max_mb=64)
        try:
            clear_flat_cache()
            clear_plan_cache()
            ftfi.build(tree, leaf_size=64)
            [artifact] = list(pathlib.Path(d).glob("ftfi-plan-*.npz"))
            faults.corrupt_file(artifact, flip_bytes=48, seed=3)
            clear_flat_cache()
            clear_plan_cache()
            before = plan_cache.stats()
            spec2, pp2 = ftfi.build(tree, leaf_size=64)
            after = plan_cache.stats()
            ok = (after["misses"] > before["misses"]
                  and after["errors"] > before["errors"]
                  and plan_guard.check_spec(spec2, pp2) == [])
            row("disk-cache post-write corruption", ok,
                f"hit rejected -> rebuilt (errors +"
                f"{after['errors'] - before['errors']})")
        except Exception as e:
            row("disk-cache post-write corruption", False,
                f"unhandled {type(e).__name__}: {str(e)[:80]}")
        finally:
            plan_cache.reset_to_env()
            clear_flat_cache()
            clear_plan_cache()

    # -- serving: slot crash at tick k and a whole-step crash must both
    # complete every request with retries recorded, zero exceptions
    try:
        import jax
        from repro.configs.base import get_smoke_config
        from repro.models import api
        from repro.serve.engine import Request, ServeEngine

        cfg = get_smoke_config("qwen2_1_5b").replace(dtype="float32")
        mp = api.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab_size, size=k).tolist()
                   for k in (3, 5)]
        for fault, point, handler in (
                ("serve slot crash (NaN logits row @ tick 2)",
                 "serve.logits", faults.nan_slot_at_tick(slot=1, k=2)),
                ("serve step crash (raise @ tick 3)",
                 "serve.step", faults.raise_at_tick(3))):
            eng = ServeEngine(cfg, mp, batch_slots=2, max_len=64)
            reqs = [Request(rid=i, prompt=p, max_new_tokens=4)
                    for i, p in enumerate(prompts)]
            for r in reqs:
                eng.submit(r)
            with warnings.catch_warnings():
                warnings.simplefilter("ignore")
                with faults.injected(point, handler):
                    eng.run()
            st = eng.stats()
            ok = (all(r.done and r.error is None for r in reqs)
                  and st["retries"] >= 1 and st["failed"] == 0)
            row(fault, ok,
                f"completed={st['completed']} retries={st['retries']} "
                f"evictions={st['evictions']}", engine_stats={
                    k: st[k] for k in ("completed", "failed", "retries",
                                       "evictions", "step_failures",
                                       "slot_faults")})
    except Exception as e:
        row("serve fault rows", False,
            f"unhandled {type(e).__name__}: {str(e)[:120]}")

    with open(out_path, "w") as fh:
        json.dump({"suite": "robustness", "rows": rows}, fh, indent=2)
    print(f"wrote {out_path} ({len(rows)} fault-matrix rows)")
    return errors


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("json", nargs="?", default="BENCH_ftfi_runtime.json")
    ap.add_argument("--suite",
                    choices=("ftfi", "topo", "robustness", "sharding",
                             "serve"),
                    default="ftfi")
    ap.add_argument("--max-rel-err", type=float, default=1e-4)
    ap.add_argument("--it-n", type=int, default=2000)
    ap.add_argument("--it-ceiling", type=float, default=5.0)
    ap.add_argument("--forest-rel-err", type=float, default=1e-5)
    ap.add_argument("--topo-rel-err", type=float, default=1e-3)
    ap.add_argument("--plan-ceiling", type=float, default=0.25,
                    help="max cold pre_plan_s for jit-backend rows (s); "
                    "generous vs the ~ms vectorized assembly, trips when "
                    "the per-node Python loop comes back")
    ap.add_argument("--upd-speedup", type=float, default=5.0,
                    help="min upd_speedup (warm update_plan vs cold "
                    "reweightable recompile) on rows that carry it")
    ap.add_argument("--cache-warm-ceiling", type=float, default=2.0,
                    help="max seconds for a cold-process rebuild served "
                    "from a populated disk plan cache")
    ap.add_argument("--guard-overhead", type=float, default=0.05,
                    help="max plan-guard validation time as a fraction of "
                    "the warm-IT plan assembly time (pre_plan_s)")
    ap.add_argument("--ladder-rel-err", type=float, default=1e-5,
                    help="max rel_err of a ladder fallback output vs the "
                    "host oracle")
    ap.add_argument("--robustness-json", default="BENCH_robustness.json",
                    help="fault-matrix artifact written by "
                    "--suite robustness")
    ap.add_argument("--sharding-rel-err", type=float, default=1e-5,
                    help="max parity rel_err of a sharded row vs the "
                    "single-device jitted executor")
    ap.add_argument("--max-work-frac", type=float, default=0.75,
                    help="max per-device flat work as a fraction of the "
                    "global plan on multi-device sharded rows")
    ap.add_argument("--serve-gate-len", type=int, default=32,
                    help="prompt length from which fused prefill must beat "
                    "replay throughput (--suite serve)")
    ap.add_argument("--serve-min-speedup", type=float, default=1.0,
                    help="min fused/replay prefill tok/s ratio at gated "
                    "prompt lengths (--suite serve)")
    args = ap.parse_args()

    if args.suite == "serve":
        errors = check_serve_json(args.json, args.serve_gate_len,
                                  args.serve_min_speedup)
    elif args.suite == "robustness":
        errors = check_robustness(args.robustness_json, args.guard_overhead,
                                  args.ladder_rel_err)
    elif args.suite == "sharding":
        errors = check_sharding_json(args.json, args.sharding_rel_err,
                                     args.max_work_frac)
    elif args.suite == "topo":
        errors = check_topo_json(args.json, args.topo_rel_err)
    else:
        errors = check_json(args.json, args.max_rel_err)
        errors += check_it_build(args.it_n, args.it_ceiling)
        errors += check_forest(args.forest_rel_err)
        errors += check_cold_compile(args.json, args.plan_ceiling,
                                     args.upd_speedup)
        errors += check_disk_cache(args.cache_warm_ceiling)
    if errors:
        for e in errors:
            print(f"GATE FAIL: {e}", file=sys.stderr)
        sys.exit(1)
    print("perf smoke gate: OK")


if __name__ == "__main__":
    main()
