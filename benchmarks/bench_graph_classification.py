"""Paper Fig. 5 / Tables 3-4: graph classification with f-distance spectral
features — FTFI (tree kernel) vs BGFI (exact graph kernel): accuracy and
feature-processing time. Procedural graph families stand in for TUDatasets
(no network access; DESIGN §7)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core import FTFI, Polynomial
from repro.graphs.graph import random_graph_family
from repro.graphs.mst import minimum_spanning_tree
from repro.graphs.traverse import graph_all_pairs, tree_all_pairs

FAMILIES = ["ring_lattice", "pref_attach", "community"]


def _spectral_features(D, k=8):
    """k smallest eigenvalues of the f-distance kernel (de Lara & Pineau)."""
    M = np.exp(-0.5 * D)
    evals = np.linalg.eigvalsh(M.astype(np.float64))
    return evals[:k]


def make_dataset(n_per_class=30, size_range=(24, 60), seed=0):
    rng = np.random.default_rng(seed)
    graphs, labels = [], []
    for ci, fam in enumerate(FAMILIES):
        for i in range(n_per_class):
            n = int(rng.integers(*size_range))
            graphs.append(random_graph_family(fam, n, seed * 977 + i))
            labels.append(ci)
    return graphs, np.array(labels)


def features_ftfi(graphs, k=8):
    t0 = time.perf_counter()
    feats = []
    for g in graphs:
        mst = minimum_spanning_tree(g)
        D = tree_all_pairs(mst)  # small graphs: explicit spectrum of M_f^T
        feats.append(_spectral_features(D, k))
    return np.array(feats), time.perf_counter() - t0


def features_bgfi(graphs, k=8):
    t0 = time.perf_counter()
    feats = []
    for g in graphs:
        D = graph_all_pairs(g)
        feats.append(_spectral_features(D, k))
    return np.array(feats), time.perf_counter() - t0


def _logreg(Xtr, ytr, Xte, classes=3, steps=400, lr=0.5):
    """Multinomial logistic regression in numpy."""
    mu, sd = Xtr.mean(0), Xtr.std(0) + 1e-9
    Xtr = (Xtr - mu) / sd
    Xte = (Xte - mu) / sd
    W = np.zeros((Xtr.shape[1] + 1, classes))
    Xb = np.c_[Xtr, np.ones(len(Xtr))]
    Y = np.eye(classes)[ytr]
    for _ in range(steps):
        logits = Xb @ W
        p = np.exp(logits - logits.max(1, keepdims=True))
        p /= p.sum(1, keepdims=True)
        W -= lr * Xb.T @ (p - Y) / len(Xb)
    return np.argmax(np.c_[Xte, np.ones(len(Xte))] @ W, axis=1)


def cross_val_accuracy(feats, labels, folds=5, seed=0):
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(labels))
    accs = []
    for f in range(folds):
        te = idx[f::folds]
        tr = np.setdiff1d(idx, te)
        pred = _logreg(feats[tr], labels[tr], feats[te])
        accs.append(np.mean(pred == labels[te]))
    return float(np.mean(accs)), float(np.std(accs))


def run(n_per_class=30):
    graphs, labels = make_dataset(n_per_class)
    fa, ta = features_ftfi(graphs)
    fb, tb = features_bgfi(graphs)
    acc_a, std_a = cross_val_accuracy(fa, labels)
    acc_b, std_b = cross_val_accuracy(fb, labels)
    emit("fig5/ftfi_features", ta, f"acc={acc_a:.3f}+-{std_a:.3f}")
    emit("fig5/bgfi_features", tb,
         f"acc={acc_b:.3f}+-{std_b:.3f} fp_time_reduction="
         f"{(tb-ta)/tb*100:.1f}%")
    return {"ftfi": (acc_a, ta), "bgfi": (acc_b, tb)}


if __name__ == "__main__":
    run()
