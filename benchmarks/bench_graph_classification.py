"""Paper Fig. 5 / Tables 3-4: graph classification with f-distance spectral
features — FTFI (tree kernel) vs BGFI (exact graph kernel): accuracy and
feature-processing time. Procedural graph families stand in for TUDatasets
(no network access; DESIGN §7).

The tree-kernel features have a --backend axis:

  host    per-graph Python loop: MST -> tree_all_pairs -> exp -> eigvalsh
          (the pre-forest baseline every other backend is timed against)
  plan    per-graph Integrator loop (one jit dispatch PER graph — exists to
          show why the forest path is the right unit of work)
  pallas  same loop on the Pallas backend
  forest  ALL graphs' MSTs packed into ONE Forest: a single fused plan
          execution on a block-diagonal identity field returns every
          graph's dense kernel M_f in one dispatch, then the per-graph
          spectra are read off the packed output

  PYTHONPATH=src python benchmarks/bench_graph_classification.py \
      --backend host,forest

Timing methodology matches bench_ftfi_runtime: feat_s is steady-state (one
warmup call absorbs jit compilation and warms the content-hash plan caches);
cold_s is the first call, preprocessing included. Rows are written to
BENCH_graph_classification.json by benchmarks/run.py (fig5 suite)."""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time
from functools import partial

import numpy as np

if __package__ in (None, ""):  # `python benchmarks/bench_graph_classification.py`
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from benchmarks.common import emit, timeit
from repro.core import Exponential, Forest, Integrator
from repro.graphs.graph import random_graph_family
from repro.graphs.mst import minimum_spanning_forest, minimum_spanning_tree
from repro.graphs.traverse import graph_all_pairs, tree_all_pairs

FAMILIES = ["ring_lattice", "pref_attach", "community"]
LAM = -0.5  # f(s) = exp(LAM * s): the de Lara & Pineau heat-kernel features


try:  # scipy ships with jax; the raw syevx binding computes ONLY the k
    # requested eigenvalues with none of the high-level wrapper overhead
    from scipy.linalg.lapack import ssyevx as _ssyevx

    def _eigvals_smallest(M, k):
        w, _, m, _, info = _ssyevx(M, range="I", il=1, iu=k, compute_v=0)
        if info != 0 or m < k:  # pragma: no cover - degenerate fallback
            return np.linalg.eigvalsh(M)[:k]
        return w[:k]
except ImportError:  # pragma: no cover - scipy is a jax dependency
    def _eigvals_smallest(M, k):
        return np.linalg.eigvalsh(M)[:k]


def _kernel_spectrum(M, k=8):
    """k smallest eigenvalues of the f-distance kernel matrix M (symmetric
    by construction; only one triangle is read)."""
    M = np.asarray(M, dtype=np.float32)
    return _eigvals_smallest(M, min(k, M.shape[0]))


def make_dataset(n_per_class=30, size_range=(24, 60), seed=0):
    rng = np.random.default_rng(seed)
    graphs, labels = [], []
    for ci, fam in enumerate(FAMILIES):
        for i in range(n_per_class):
            n = int(rng.integers(*size_range))
            graphs.append(random_graph_family(fam, n, seed * 977 + i))
            labels.append(ci)
    return graphs, np.array(labels)


def features_ftfi(graphs, k=8):
    """Per-graph host loop — the pre-forest baseline, kept verbatim (full
    float64 eigvalsh, per-graph Kruskal + tree_all_pairs): every other
    backend's speedup is measured against exactly this pipeline."""
    feats = []
    for g in graphs:
        mst = minimum_spanning_tree(g)
        D = tree_all_pairs(mst)  # small graphs: explicit spectrum of M_f^T
        M = np.exp(LAM * D)
        feats.append(np.linalg.eigvalsh(M.astype(np.float64))[:k])
    return np.array(feats)


def features_integrator(graphs, k=8, backend="plan"):
    """Per-graph Integrator loop: one plan compile + jit dispatch PER graph.

    Every graph size is a distinct set of bucket shapes, so this pays N
    dispatches (and, cold, N compilations) — the anti-pattern the packed
    forest path exists to remove."""
    fn = Exponential(LAM)
    feats = []
    for g in graphs:
        mst = minimum_spanning_tree(g)
        n = mst.num_vertices
        integ = Integrator(mst, backend=backend)
        M = np.asarray(integ.integrate(fn, np.eye(n, dtype=np.float32)))
        feats.append(_kernel_spectrum(M, k))
    return np.array(feats)


def features_forest(graphs, k=8, backend="plan"):
    """Packed forest path: ONE fused plan execution for the whole dataset.

    Every per-graph Python stage is replaced by its batched counterpart:
    MSTs come from the vectorized Borůvka `minimum_spanning_forest` (one
    sweep over the disjoint union), and the packed field is the
    block-diagonal identity (N, n_max) — one forest matvec returns every
    graph's dense kernel M_f = [exp(LAM d_T(i,j))] in a single jit dispatch;
    spectra are read off the per-tree blocks."""
    msts = minimum_spanning_forest(graphs)
    forest = Forest(msts)
    sizes = forest.tree_sizes
    off = forest.offsets
    N, nmax = forest.num_vertices, int(sizes.max())
    E = np.zeros((N, nmax), dtype=np.float32)
    E[np.arange(N), np.concatenate([np.arange(s) for s in sizes])] = 1.0
    integ = Integrator.from_forest(forest, backend=backend)
    M = np.asarray(integ.integrate(Exponential(LAM), E))  # (N, nmax)
    return np.array([
        _kernel_spectrum(M[off[t]:off[t] + s, :s], k)
        for t, s in enumerate(sizes)])


def features_bgfi(graphs, k=8):
    """Exact graph kernel (all-pairs Dijkstra) — the accuracy reference."""
    feats = []
    for g in graphs:
        D = graph_all_pairs(g)
        feats.append(_kernel_spectrum(np.exp(LAM * D), k))
    return np.array(feats)


FEATURE_FNS = {
    "host": features_ftfi,
    "plan": partial(features_integrator, backend="plan"),
    "pallas": partial(features_integrator, backend="pallas"),
    "forest": features_forest,
}


def _logreg(Xtr, ytr, Xte, classes=3, steps=400, lr=0.5):
    """Multinomial logistic regression in numpy."""
    mu, sd = Xtr.mean(0), Xtr.std(0) + 1e-9
    Xtr = (Xtr - mu) / sd
    Xte = (Xte - mu) / sd
    W = np.zeros((Xtr.shape[1] + 1, classes))
    Xb = np.c_[Xtr, np.ones(len(Xtr))]
    Y = np.eye(classes)[ytr]
    for _ in range(steps):
        logits = Xb @ W
        p = np.exp(logits - logits.max(1, keepdims=True))
        p /= p.sum(1, keepdims=True)
        W -= lr * Xb.T @ (p - Y) / len(Xb)
    return np.argmax(np.c_[Xte, np.ones(len(Xte))] @ W, axis=1)


def cross_val_accuracy(feats, labels, folds=5, seed=0):
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(labels))
    accs = []
    for f in range(folds):
        te = idx[f::folds]
        tr = np.setdiff1d(idx, te)
        pred = _logreg(feats[tr], labels[tr], feats[te])
        accs.append(np.mean(pred == labels[te]))
    return float(np.mean(accs)), float(np.std(accs))


def run(n_per_class=30, backends=("host", "forest"), k=8, repeat=2):
    graphs, labels = make_dataset(n_per_class)
    rows = []

    # exact graph kernel (paper's BGFI comparison row)
    t0 = time.perf_counter()
    fb = features_bgfi(graphs, k)
    t_bgfi = time.perf_counter() - t0
    acc_b, std_b = cross_val_accuracy(fb, labels)
    emit("fig5/bgfi_features", t_bgfi, f"acc={acc_b:.3f}+-{std_b:.3f}")
    rows.append({"case": "fig5", "n": len(graphs), "backend": "bgfi",
                 "engine": "graph_all_pairs", "feat_s": t_bgfi,
                 "cold_s": t_bgfi, "acc": acc_b, "acc_std": std_b,
                 "speedup_vs_host_loop": None, "rel_err": 0.0})

    # host loop always runs: it is the reference features AND the speedup
    # denominator for every other backend
    order = ["host"] + [b for b in backends if b != "host"]
    ref_feats, t_host = None, None
    for backend in order:
        fn_feat = partial(FEATURE_FNS[backend], k=k)
        t0 = time.perf_counter()
        feats = fn_feat(graphs)
        cold_s = time.perf_counter() - t0
        # steady state: caches + jit now warm (host has no cache: same time)
        feat_s = timeit(lambda: fn_feat(graphs), repeat=repeat, warmup=0)
        acc, std = cross_val_accuracy(feats, labels)
        if backend == "host":
            ref_feats, t_host = feats, feat_s
        rel_err = float(np.max(np.abs(feats - ref_feats))
                        / max(np.max(np.abs(ref_feats)), 1e-12))
        speedup = t_host / max(feat_s, 1e-12)
        emit(f"fig5/ftfi_features/{backend}", feat_s,
             f"acc={acc:.3f}+-{std:.3f} cold={cold_s:.2f}s "
             f"speedup_vs_host_loop={speedup:.2f}x relerr={rel_err:.1e}")
        rows.append({"case": "fig5", "n": len(graphs), "backend": backend,
                     "engine": ("forest_plan" if backend == "forest"
                                else "per_graph_loop"),
                     "feat_s": feat_s, "cold_s": cold_s, "acc": acc,
                     "acc_std": std, "speedup_vs_host_loop": speedup,
                     "rel_err": rel_err})
    emit("fig5/fp_time_reduction", max(t_bgfi - t_host, 0.0),
         f"ftfi_vs_bgfi={(t_bgfi - t_host) / t_bgfi * 100:.1f}%")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="host,forest",
                    help="comma list of host,plan,pallas,forest")
    ap.add_argument("--n-per-class", type=int, default=30)
    ap.add_argument("--repeat", type=int, default=2)
    ap.add_argument("--json", default=None,
                    help="write rows to this path (run.py uses "
                         "BENCH_graph_classification.json)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    rows = run(n_per_class=args.n_per_class,
               backends=tuple(b for b in args.backend.split(",") if b),
               repeat=args.repeat)
    if args.json:
        with open(args.json, "w") as fh:
            json.dump({"suite": "fig5", "rows": rows}, fh, indent=1)


if __name__ == "__main__":
    main()
