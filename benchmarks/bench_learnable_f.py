"""Paper Fig. 6 / 8-9: learnable rational f — relative Frobenius error vs
training iterations for different numerator/denominator degrees — plus the
functional-API extension: `--train-edges` trains the TREE METRIC itself
(edge weights) through `ftfi.reweight` and records the fit-error delta.

  PYTHONPATH=src python benchmarks/bench_learnable_f.py --train-edges

Rows land in BENCH_learnable_f.json via benchmarks.run (fig6 suite).
"""
from __future__ import annotations

import argparse
import pathlib
import sys
import time

import numpy as np

if __package__ in (None, ""):  # `python benchmarks/bench_learnable_f.py`
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from benchmarks.common import emit
from repro.core.fit import (fit_rational_f, relative_frobenius_error,
                            tree_metric_frobenius_error)
from repro.graphs.graph import synthetic_graph
from repro.graphs.meshes import icosphere, mesh_graph
from repro.graphs.mst import minimum_spanning_tree


def _train_edges_case(name, g, steps=50, seed=0, leaf_size=32, lr=5e-2):
    """Train edge weights end-to-end through `ftfi.reweight`.

    Objective: make the tree kernel's ACTION match the graph kernel's —
    ||M_f(d_T(w)) X - M_f(d_G) X||_F / ||M_f(d_G) X||_F over random probe
    fields, with f = exp(lam s). Gradients flow jax.grad -> reweight ->
    PlanParams -> the fused plan executor, i.e. exactly the learnable-
    tree-metric path the functional API unlocks."""
    import jax
    import jax.numpy as jnp

    from repro import ftfi
    from repro.core.cordial import Exponential
    from repro.graphs.traverse import graph_all_pairs
    from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

    rng = np.random.default_rng(seed)
    tree = minimum_spanning_tree(g)
    spec, _ = ftfi.build(tree, leaf_size=leaf_size, reweightable=True)
    D_g = graph_all_pairs(g)
    lam = -2.0 / float(np.mean(D_g))
    fn = Exponential(lam)
    X = rng.normal(size=(g.num_vertices, 8)).astype(np.float32)
    Yt = jnp.asarray(np.exp(lam * D_g).astype(np.float32) @ X)
    Xj = jnp.asarray(X)
    y_norm = float(np.linalg.norm(np.asarray(Yt)))

    fm = jax.jit(ftfi.fastmult(spec, fn))
    # softplus keeps weights positive; init reproduces the MST metric
    w0 = np.asarray(tree.weights, np.float32)
    theta = jnp.asarray(np.log(np.expm1(w0)))

    def rel_err(th):
        pred = fm(ftfi.reweight(spec, jax.nn.softplus(th)), Xj)
        return jnp.linalg.norm(pred - Yt) / y_norm

    def loss(th):
        return rel_err(th) ** 2

    cfg = AdamWConfig(lr=lr, weight_decay=0.0, warmup_steps=5,
                      total_steps=steps, clip_norm=10.0)
    state = adamw_init(theta)

    @jax.jit
    def step(th, st):
        val, grads = jax.value_and_grad(loss)(th)
        th, st, _ = adamw_update(grads, st, th, cfg)
        return th, st, val

    err0 = float(rel_err(theta))
    t0 = time.perf_counter()
    for _ in range(steps):
        theta, state, _ = step(theta, state)
    dt = time.perf_counter() - t0
    errT = float(rel_err(theta))
    emit(f"fig6/{name}/train_edges", dt,
         f"err0={err0:.4f} errT={errT:.4f} delta={err0 - errT:.4f} "
         f"steps={steps}")
    return {"case": name, "mode": "train_edges", "steps": steps,
            "err0": err0, "errT": errT, "delta": err0 - errT,
            "train_s": dt, "n": g.num_vertices,
            "num_edges": int(spec.num_edges)}


def run(steps=300, train_edges=False, edge_steps=50):
    cases = [
        ("synthetic_n400", synthetic_graph(400, 300, seed=2)),
        ("mesh_ico2", mesh_graph(*icosphere(2))),
    ]
    rows = []
    for name, g in cases:
        tree = minimum_spanning_tree(g)
        base = tree_metric_frobenius_error(g, tree)
        emit(f"fig6/{name}/identity_f", 0.0, f"frob_err={base:.4f}")
        for num_deg, den_deg in [(1, 1), (2, 2), (3, 3)]:
            t0 = time.perf_counter()
            res = fit_rational_f(g, tree, num_deg=num_deg, den_deg=den_deg,
                                 num_pairs=100, steps=steps,
                                 eval_frobenius=True)
            dt = time.perf_counter() - t0
            emit(f"fig6/{name}/rational_{num_deg}_{den_deg}", dt,
                 f"frob_err={res.rel_frobenius:.4f} "
                 f"loss0={res.losses[0]:.4f} lossT={res.losses[-1]:.5f}")
            rows.append({"case": name, "mode": f"rational_{num_deg}_{den_deg}",
                         "steps": steps, "frob_err": res.rel_frobenius,
                         "identity_frob_err": base,
                         "train_s": dt, "n": g.num_vertices})
        if train_edges:
            rows.append(_train_edges_case(name, g, steps=edge_steps))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--train-edges", action="store_true",
                    help="also train edge weights through ftfi.reweight "
                         "(50 steps) and report the fit-error delta")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(steps=args.steps, train_edges=args.train_edges)


if __name__ == "__main__":
    main()
