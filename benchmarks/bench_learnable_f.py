"""Paper Fig. 6 / 8-9: learnable rational f — relative Frobenius error vs
training iterations for different numerator/denominator degrees."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.fit import (fit_rational_f, relative_frobenius_error,
                            tree_metric_frobenius_error)
from repro.graphs.graph import synthetic_graph
from repro.graphs.meshes import icosphere, mesh_graph
from repro.graphs.mst import minimum_spanning_tree


def run(steps=300):
    cases = [
        ("synthetic_n400", synthetic_graph(400, 300, seed=2)),
        ("mesh_ico2", mesh_graph(*icosphere(2))),
    ]
    out = {}
    for name, g in cases:
        tree = minimum_spanning_tree(g)
        base = tree_metric_frobenius_error(g, tree)
        emit(f"fig6/{name}/identity_f", 0.0, f"frob_err={base:.4f}")
        for num_deg, den_deg in [(1, 1), (2, 2), (3, 3)]:
            t0 = time.perf_counter()
            res = fit_rational_f(g, tree, num_deg=num_deg, den_deg=den_deg,
                                 num_pairs=100, steps=steps,
                                 eval_frobenius=True)
            dt = time.perf_counter() - t0
            emit(f"fig6/{name}/rational_{num_deg}_{den_deg}", dt,
                 f"frob_err={res.rel_frobenius:.4f} "
                 f"loss0={res.losses[0]:.4f} lossT={res.losses[-1]:.5f}")
            out[(name, num_deg)] = res.rel_frobenius
    return out


if __name__ == "__main__":
    run()
