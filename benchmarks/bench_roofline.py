"""Framework roofline table: renders §Roofline from results/dryrun.json
(produced by repro.launch.dryrun). No compilation here — pure reporting."""
from __future__ import annotations

import json
import os

from benchmarks.common import emit


def run(path="results/dryrun.json"):
    if not os.path.exists(path):
        print(f"# {path} missing — run: PYTHONPATH=src python -m "
              "repro.launch.dryrun", flush=True)
        return []
    with open(path) as f:
        rows = json.load(f)
    out = []
    for r in rows:
        if r.get("status") != "ok" or r.get("mesh") != "16x16":
            continue
        name = f"roofline/{r['arch']}/{r['shape']}"
        bound = r.get("roofline_bound_s", 0.0)
        emit(name, bound,
             f"dominant={r.get('dominant')} compute={r.get('compute_s', 0):.4f}s "
             f"memory={r.get('memory_s', 0):.4f}s "
             f"collective={r.get('collective_s', 0):.4f}s "
             f"useful={r.get('useful_flops_ratio', 0):.3f} "
             f"gib_dev={r.get('peak_bytes_per_device', 0)/2**30:.2f}")
        out.append(r)
    return out


if __name__ == "__main__":
    run()
