"""Paper Fig. 3: FTFI vs BTFI runtime (preprocessing + integration) as a
function of N, on synthetic path+random-edge graphs and mesh graphs."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import Exponential, FTFI, Polynomial, Rational
from repro.core.integrate import BTFI
from repro.graphs.graph import synthetic_graph
from repro.graphs.meshes import icosphere, mesh_graph
from repro.graphs.mst import minimum_spanning_tree


def run(sizes=(1000, 4000, 10000), mesh_subdiv=(3, 4), repeat=2):
    rng = np.random.default_rng(0)
    fn = Exponential(-0.5)
    rows = []
    cases = [("synthetic", n, lambda n=n: minimum_spanning_tree(
        synthetic_graph(n, n // 2, seed=1))) for n in sizes]
    for sub in mesh_subdiv:
        verts, faces = icosphere(sub)
        cases.append((f"mesh_ico{sub}", verts.shape[0],
                      lambda v=verts, f=faces: minimum_spanning_tree(
                          mesh_graph(v, f))))
    for name, n, mk in cases:
        tree = mk()
        X = rng.normal(size=(tree.num_vertices, 4))
        t_pre_ftfi = timeit(lambda: FTFI(tree, leaf_size=256), repeat=1,
                            warmup=0)
        ftfi = FTFI(tree, leaf_size=256)
        t_int_ftfi = timeit(lambda: ftfi.integrate(fn, X), repeat=repeat)
        t_pre_btfi = timeit(lambda: BTFI(tree, dtype=np.float32), repeat=1,
                            warmup=0)
        btfi = BTFI(tree, dtype=np.float32)
        t_int_btfi = timeit(lambda: btfi.integrate(fn, X), repeat=repeat)
        # exactness guard: same result
        err = np.max(np.abs(ftfi.integrate(fn, X) - btfi.integrate(fn, X))
                     ) / max(np.max(np.abs(btfi.integrate(fn, X))), 1e-9)
        total_f = t_pre_ftfi + t_int_ftfi
        total_b = t_pre_btfi + t_int_btfi
        emit(f"fig3/{name}/n{n}/ftfi_pre", t_pre_ftfi)
        emit(f"fig3/{name}/n{n}/ftfi_int", t_int_ftfi)
        emit(f"fig3/{name}/n{n}/btfi_pre", t_pre_btfi)
        emit(f"fig3/{name}/n{n}/btfi_int", t_int_btfi,
             f"speedup_total={total_b/total_f:.2f}x "
             f"speedup_int={t_int_btfi/t_int_ftfi:.2f}x relerr={err:.1e}")
        rows.append((name, n, total_b / total_f))
    return rows


if __name__ == "__main__":
    run()
