"""Paper Fig. 3 / Sec 5: BTFI vs FTFI runtime (preprocessing + integration)
as a function of N, on synthetic path+random-edge graphs and mesh graphs —
with a --backend axis so the BTFI-vs-host-vs-plan-vs-pallas speedup is
reproducible from one command:

  PYTHONPATH=src python benchmarks/bench_ftfi_runtime.py \
      --backend host,plan,pallas --sizes 1000,4000

Methodology:
  * a tiny jitted op runs before any timing so one-time JAX/XLA backend
    initialization never leaks into the first cold-build number (it used to
    inflate pre_it_s of whichever row ran first by ~40ms);
  * the disk plan cache is disabled for the duration of the run — cold
    numbers must measure compilation, not npz reads;
  * pre_it_s / pre_plan_s are COLD builds: every round clears the flat-IT
    and plan caches and the minimum over `repeat` rounds is reported, so a
    stray GC pause can't masquerade as a compile regression;
  * int_s is measured after a jit warmup call, so compile time never leaks
    into the steady-state integration number;
  * plan-backend rows additionally time the incremental-update path
    (`ftfi.update_plan`, single leaf insert) against a cold reweightable
    recompile: upd_s / upd_rebuild_s / upd_speedup.
"""
from __future__ import annotations

import argparse
import pathlib
import sys
import time

import numpy as np

if __package__ in (None, ""):  # `python benchmarks/bench_ftfi_runtime.py`
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from benchmarks.common import emit, timeit
from repro import ftfi
from repro.core import (BTFI, Exponential, Forest, Integrator, build_flat_it,
                        clear_flat_cache, clear_plan_cache)
from repro.core.itree_flat import build_flat_forest
from repro.graphs.graph import random_tree, synthetic_graph
from repro.graphs.meshes import icosphere, mesh_graph
from repro.graphs.mst import minimum_spanning_tree


def _jax_warmup():
    """Absorb one-time JAX/XLA initialization before any timed region."""
    import jax.numpy as jnp

    (jnp.zeros(8) + 1).block_until_ready()


def _cold(fn, rounds: int, clear=None):
    """Min wall-clock over `rounds` cold runs; `clear` resets caches first."""
    best = float("inf")
    for _ in range(max(1, rounds)):
        if clear is not None:
            clear()
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _clear_all():
    clear_flat_cache()
    clear_plan_cache()


def _update_stats(tree, leaf_size: int, repeat: int):
    """(upd_s, upd_rebuild_s): warm single-leaf `ftfi.update_plan` vs a cold
    reweightable recompile — the number the incremental path exists for."""
    spec, pp = ftfi.build(tree, leaf_size=leaf_size, reweightable=True)
    ops = [("insert_leaf", tree.num_vertices // 2, 1.0)]
    t_upd = timeit(lambda: ftfi.update_plan(spec, pp, ops),
                   repeat=max(repeat, 3), warmup=1)
    t_reb = _cold(lambda: ftfi.build(tree, leaf_size=leaf_size,
                                     reweightable=True),
                  rounds=repeat, clear=_clear_all)
    return t_upd, t_reb


def run(sizes=(1000, 4000, 10000), mesh_subdiv=(3, 4), repeat=2,
        backends=("host", "plan", "pallas"), leaf_size=256):
    from repro.core import plan_cache

    _jax_warmup()
    plan_cache.configure(None)  # cold numbers must measure compilation
    try:
        return _run(sizes, mesh_subdiv, repeat, backends, leaf_size)
    finally:
        plan_cache.reset_to_env()


def _run(sizes, mesh_subdiv, repeat, backends, leaf_size):
    rng = np.random.default_rng(0)
    fn = Exponential(-0.5)
    rows = []
    cases = [("synthetic", n, lambda n=n: minimum_spanning_tree(
        synthetic_graph(n, n // 2, seed=1))) for n in sizes]
    for sub in mesh_subdiv:
        verts, faces = icosphere(sub)
        cases.append((f"mesh_ico{sub}", verts.shape[0],
                      lambda v=verts, f=faces: minimum_spanning_tree(
                          mesh_graph(v, f))))
    for name, n, mk in cases:
        tree = mk()
        X = rng.normal(size=(tree.num_vertices, 4))
        t_pre_btfi = timeit(lambda: BTFI(tree, dtype=np.float32), repeat=1,
                            warmup=0)
        btfi = BTFI(tree, dtype=np.float32)
        t_int_btfi = timeit(lambda: btfi.integrate(fn, X), repeat=repeat)
        ref = btfi.integrate(fn, X)
        emit(f"fig3/{name}/n{n}/btfi_pre", t_pre_btfi)
        emit(f"fig3/{name}/n{n}/btfi_int", t_int_btfi)
        for backend in backends:
            # fig3 measures the paper's FTFI algorithm: disable the host
            # backend's ExpMP fast path so exp f doesn't bypass the IT walk
            opts = {"use_expmp": False} if backend == "host" else {}
            if backend == "ftfi":
                # functional plan API row: jitted pure (params, X) -> Y —
                # params cross the jit boundary explicitly, so this is the
                # retrace-free serving/vmap/shard path
                mk_pre = lambda: ftfi.build(tree, leaf_size=leaf_size)
            else:
                mk_pre = lambda: Integrator(tree, backend=backend,
                                            leaf_size=leaf_size, **opts)
            # cold IT build, then backend assembly on the now-warm IT cache:
            # the two add up to a full cold preprocessing pass
            t_pre_it = _cold(
                lambda: build_flat_it(tree, leaf_size=leaf_size),
                rounds=repeat, clear=_clear_all)
            build_flat_it(tree, leaf_size=leaf_size)  # warm the IT cache
            t_pre_plan = _cold(mk_pre, rounds=repeat,
                               clear=clear_plan_cache)
            t_pre = t_pre_it + t_pre_plan
            if backend == "ftfi":
                import jax

                spec, pp = ftfi.build(tree, leaf_size=leaf_size)
                engine = ftfi.describe(spec, fn)["cross_engine"]
                fm = jax.jit(ftfi.fastmult(spec, fn))
                run_once = lambda: np.asarray(fm(pp, X))
            else:
                integ = mk_pre()
                engine = integ.describe(fn)["cross_engine"]
                run_once = lambda: np.asarray(integ.integrate(fn, X))
            # timeit's warmup call absorbs jit compilation before timing
            t_int = timeit(run_once, repeat=repeat, warmup=1)
            got = run_once()
            err = (np.max(np.abs(got - ref))
                   / max(np.max(np.abs(ref)), 1e-9))
            total_f = t_pre + t_int
            total_b = t_pre_btfi + t_int_btfi
            emit(f"fig3/{name}/n{n}/{backend}_pre", t_pre,
                 f"it={t_pre_it*1e3:.1f}ms plan={t_pre_plan*1e3:.1f}ms")
            emit(f"fig3/{name}/n{n}/{backend}_int", t_int,
                 f"speedup_total={total_b/total_f:.2f}x "
                 f"speedup_int={t_int_btfi/t_int:.2f}x relerr={err:.1e} "
                 f"engine={engine}")
            row = {
                "case": name, "n": n, "backend": backend, "engine": engine,
                "pre_s": t_pre, "pre_it_s": t_pre_it,
                "pre_plan_s": t_pre_plan, "int_s": t_int,
                "btfi_pre_s": t_pre_btfi, "btfi_int_s": t_int_btfi,
                "speedup_total": total_b / total_f,
                "speedup_int": t_int_btfi / t_int, "rel_err": float(err),
            }
            if backend == "plan":
                t_upd, t_reb = _update_stats(tree, leaf_size, repeat)
                row["upd_s"] = t_upd
                row["upd_rebuild_s"] = t_reb
                row["upd_speedup"] = t_reb / t_upd
                emit(f"fig3/{name}/n{n}/plan_update", t_upd,
                     f"rebuild={t_reb*1e3:.1f}ms "
                     f"upd_speedup={t_reb/t_upd:.1f}x")
            rows.append(row)
    # the forest row exercises the fused plan path: skip it for host-only
    # runs (e.g. jax-free debugging) that asked for no jit backend at all
    if set(backends) & {"plan", "pallas", "forest", "ftfi"}:
        rows.append(_forest_row(rng, fn, repeat=repeat))
    return rows


def _forest_row(rng, fn, num_trees=90, repeat=2):
    """Forest row: one fused plan over a mixed-size forest vs the per-tree
    host loop (the baseline occupies the btfi_* columns)."""
    del rng  # dedicated stream: the row must not depend on which other
    rng = np.random.default_rng(90)  # cases ran (stable case/n for --baseline)
    trees = [random_tree(int(s), seed=i)
             for i, s in enumerate(rng.integers(24, 96, size=num_trees))]
    forest = Forest(trees)
    n = forest.num_vertices
    X = rng.normal(size=(n, 4))
    # baseline: per-tree host loop (ExpMP off: measure the IT walk, as above)
    mk_loop = lambda: Integrator.from_forest(forest, backend="host",
                                             use_expmp=False)
    _clear_all()
    t_pre_loop = timeit(mk_loop, repeat=1, warmup=0)
    loop = mk_loop()
    t_int_loop = timeit(lambda: np.asarray(loop.integrate(fn, X)),
                        repeat=repeat)
    ref = np.asarray(loop.integrate(fn, X))
    emit(f"fig3/forest{num_trees}/n{n}/loop_pre", t_pre_loop)
    emit(f"fig3/forest{num_trees}/n{n}/loop_int", t_int_loop)
    # fused forest plan, with the same cold pre_it / pre_plan split as the
    # single-tree rows: forest flat-IT build, then fused-plan assembly on
    # the warm IT cache
    mk_forest = lambda: Integrator.from_forest(forest, backend="plan")
    t_pre_it = _cold(lambda: build_flat_forest(forest.trees, leaf_size=64),
                     rounds=repeat, clear=_clear_all)
    build_flat_forest(forest.trees, leaf_size=64)  # warm the IT cache
    t_pre_plan = _cold(mk_forest, rounds=repeat, clear=clear_plan_cache)
    t_pre = t_pre_it + t_pre_plan
    integ = mk_forest()
    engine = integ.describe(fn)["cross_engine"]
    t_int = timeit(lambda: np.asarray(integ.integrate(fn, X)), repeat=repeat,
                   warmup=1)
    got = np.asarray(integ.integrate(fn, X))
    err = np.max(np.abs(got - ref)) / max(np.max(np.abs(ref)), 1e-9)
    total_f, total_b = t_pre + t_int, t_pre_loop + t_int_loop
    emit(f"fig3/forest{num_trees}/n{n}/forest_pre", t_pre,
         f"it={t_pre_it*1e3:.1f}ms plan={t_pre_plan*1e3:.1f}ms")
    emit(f"fig3/forest{num_trees}/n{n}/forest_int", t_int,
         f"speedup_total={total_b/total_f:.2f}x "
         f"speedup_int={t_int_loop/t_int:.2f}x relerr={err:.1e} "
         f"engine={engine}")
    return {
        "case": f"forest{num_trees}", "n": n, "backend": "forest",
        "engine": engine, "pre_s": t_pre, "pre_it_s": t_pre_it,
        "pre_plan_s": t_pre_plan, "int_s": t_int, "btfi_pre_s": t_pre_loop,
        "btfi_int_s": t_int_loop, "speedup_total": total_b / total_f,
        "speedup_int": t_int_loop / t_int, "rel_err": float(err),
    }


def run_sharding(sizes=(4000,), devices=(1, 2, 4, 8), repeat=3,
                 leaf_size=256):
    """Weak-scaling rows for the shard_map plan executor: one jitted
    integrate per device count on a 1-D data submesh over the first D
    visible devices, parity-checked against the single-device jitted plan
    executor. Rows carry a `devices` column plus the partition's
    halo/per-device-work stats (`check_bench --suite sharding` gates
    rel_err and the per-device work reduction). Device counts beyond
    `jax.device_count()` are skipped WITH a printed note — never silently
    (force 8 host devices via
    XLA_FLAGS=--xla_force_host_platform_device_count=8)."""
    import jax
    from jax.sharding import Mesh

    _jax_warmup()
    rng = np.random.default_rng(0)
    fn = Exponential(-0.5)
    avail = jax.device_count()
    rows = []
    for n in sizes:
        tree = minimum_spanning_tree(synthetic_graph(n, n // 2, seed=1))
        spec, pp = ftfi.build(tree, leaf_size=leaf_size)
        engine = ftfi.describe(spec, fn)["cross_engine"]
        X = rng.normal(size=(spec.n, 4)).astype(np.float32)
        fm1 = jax.jit(ftfi.fastmult(spec, fn))
        ref = np.asarray(fm1(pp, X))
        refmax = max(float(np.max(np.abs(ref))), 1e-9)
        t1 = None
        for D in devices:
            if D > avail:
                print(f"# sharding: devices={D} skipped — only {avail} "
                      "visible (set XLA_FLAGS="
                      "--xla_force_host_platform_device_count=8)")
                continue
            if D == 1:
                run_once = lambda: np.asarray(fm1(pp, X))
                stats = {"block": spec.n, "halo_width": 0, "halo_total": 0,
                         "src_rows": int(spec.src_gather.size),
                         "tgt_rows": int(spec.tgt_gather.size)}
            else:
                mesh = Mesh(np.asarray(jax.devices()[:D]).reshape(D),
                            ("data",))
                fms = jax.jit(ftfi.sharded_fastmult(spec, fn, mesh=mesh))
                run_once = lambda: np.asarray(fms(pp, X))
                stats = ftfi.shard_stats(spec, D)
            t_int = timeit(run_once, repeat=repeat, warmup=1)
            err = float(np.max(np.abs(run_once() - ref)) / refmax)
            if D == 1:
                t1 = t_int
            scaling = (t1 / t_int) if t1 else 1.0
            emit(f"sharding/synthetic/n{n}/d{D}_int", t_int,
                 f"scaling={scaling:.2f}x relerr={err:.1e} "
                 f"block={stats['block']} halo={stats['halo_total']}")
            rows.append({
                "case": "synthetic", "n": n, "backend": "sharded",
                "engine": engine, "devices": D, "int_s": t_int,
                "rel_err": err, "scaling": scaling,
                "block": int(stats["block"]),
                "halo_width": int(stats["halo_width"]),
                "halo_total": int(stats["halo_total"]),
                "device_rows": int(stats["src_rows"] + stats["tgt_rows"]),
                "global_rows": int(spec.src_gather.size
                                   + spec.tgt_gather.size),
            })
    return rows


def _merge_sharding_rows(path: str, rows: list) -> None:
    """Replace the sharded rows of an existing BENCH_ftfi_runtime.json (or
    start a fresh artifact) so `--devices` runs compose with the fig3 suite
    instead of clobbering it."""
    import json

    try:
        with open(path) as fh:
            doc = json.load(fh)
    except (OSError, ValueError):
        doc = {"suite": "fig3", "rows": []}
    doc["rows"] = [r for r in doc.get("rows", [])
                   if r.get("backend") != "sharded"] + rows
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=1)
    print(f"# wrote {len(rows)} sharded rows to {path}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="host,plan,pallas,ftfi",
                    help="comma list of host,plan,pallas,ftfi (ftfi = the "
                         "functional plan API: jitted pure (params, X) -> Y)")
    ap.add_argument("--sizes", default="1000,4000")
    ap.add_argument("--mesh-subdiv", default="3")
    ap.add_argument("--repeat", type=int, default=2)
    ap.add_argument("--devices", default=None,
                    help="comma list of device counts (e.g. 1,2,4,8): run "
                         "ONLY the weak-scaling shard_map rows and merge "
                         "them into --json")
    ap.add_argument("--json", default="BENCH_ftfi_runtime.json",
                    help="artifact the --devices rows merge into")
    args = ap.parse_args()
    if args.devices:
        devices = tuple(int(s) for s in args.devices.split(",") if s)
        # force enough fake host devices BEFORE the jax backend initializes
        # (safe: nothing above touched a device; plain import does not)
        import os

        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{max(devices)}").strip()
        print("name,us_per_call,derived")
        rows = run_sharding(
            sizes=tuple(int(s) for s in args.sizes.split(",") if s),
            devices=devices, repeat=args.repeat)
        _merge_sharding_rows(args.json, rows)
        return
    print("name,us_per_call,derived")
    run(sizes=tuple(int(s) for s in args.sizes.split(",") if s),
        mesh_subdiv=tuple(int(s) for s in args.mesh_subdiv.split(",") if s),
        repeat=args.repeat,
        backends=tuple(args.backend.split(",")))


if __name__ == "__main__":
    main()
