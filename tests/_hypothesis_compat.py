"""Degrade-gracefully shim for `hypothesis`.

When hypothesis is installed (see requirements-dev.txt) this module just
re-exports it. In minimal environments the property tests still collect and
run against a deterministic set of representative examples: the boundary
values of every strategy plus a few seeded random draws. That keeps tier-1
green without the dependency while preserving the property-test shape.

Usage in tests:  from _hypothesis_compat import given, settings, st
"""
from __future__ import annotations

try:  # pragma: no cover - exercised implicitly by either branch
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import inspect
    import random

    HAVE_HYPOTHESIS = False
    _N_RANDOM_EXAMPLES = 5

    class _Strategy:
        """Minimal stand-in: boundary examples + seeded random draws."""

        def __init__(self, boundaries, sampler):
            self.boundaries = list(boundaries)
            self.sampler = sampler

        def examples(self, rng):
            out = list(self.boundaries)
            out += [self.sampler(rng) for _ in range(_N_RANDOM_EXAMPLES)]
            return out

    class st:  # noqa: N801 - mirrors `hypothesis.strategies` usage
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                [min_value, max_value],
                lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                [min_value, max_value],
                lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy([False, True], lambda rng: rng.random() < 0.5)

    def settings(*_a, **_kw):  # accepts max_examples=, deadline=, ...
        return lambda f: f

    def given(**strategies):
        names = sorted(strategies)

        def deco(f):
            def wrapper(*args, **kwargs):
                rng = random.Random(0)
                cols = {k: strategies[k].examples(rng) for k in names}
                rounds = max(len(v) for v in cols.values())
                for i in range(rounds):
                    drawn = {k: cols[k][i % len(cols[k])] for k in names}
                    f(*args, **drawn, **kwargs)

            # expose only the non-strategy params (pytest fixtures) so pytest
            # does not try to inject the drawn arguments as fixtures
            sig = inspect.signature(f)
            remaining = [p for n, p in sig.parameters.items()
                         if n not in strategies]
            wrapper.__signature__ = sig.replace(parameters=remaining)
            wrapper.__name__ = f.__name__
            wrapper.__doc__ = f.__doc__
            return wrapper

        return deco
