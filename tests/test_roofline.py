"""Roofline extraction: collective-bytes HLO parsing + term arithmetic."""
import numpy as np

from repro.roofline.analysis import (HBM_BW, ICI_BW, PEAK_FLOPS,
                                     collective_breakdown,
                                     collective_bytes_from_hlo,
                                     roofline_terms)

HLO = """
HloModule test
  %all-reduce.5 = bf16[16,512]{1,0} all-reduce(bf16[16,512]{1,0} %x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = f32[64,128]{1,0} all-gather(%y), replica_groups=[2,8]<=[16], dimensions={0}
  %rs = f32[8,128]{1,0} reduce-scatter(%z), replica_groups=[2,8]<=[16], to_apply=%add
  %a2a = bf16[32,32]{1,0} all-to-all(%w), replica_groups={{0,1}}
  %cp = u32[4]{0} collective-permute(%v), source_target_pairs={{0,1}}
  %ard = bf16[16,512]{1,0} all-reduce-done(%start)
  %ags = (f32[4,4]{1,0}, f32[16,4]{1,0}) all-gather-start(%q), replica_groups=[4,4]<=[16], dimensions={0}
"""


def test_collective_bytes_parsing():
    b = collective_breakdown(HLO)
    # all-reduce: result 16*512*2 = 16384 bytes (operand == result)
    assert b["bytes"]["all-reduce"] == 16 * 512 * 2
    # all-gather: result 64*128*4; operand = result / group(8);
    # the async start tuple contributes its operand entry f32[4,4] directly
    assert b["bytes"]["all-gather"] == (64 * 128 * 4) // 8 + 4 * 4 * 4
    # reduce-scatter: operand = result * group(8)
    assert b["bytes"]["reduce-scatter"] == 8 * 128 * 4 * 8
    assert b["bytes"]["all-to-all"] == 32 * 32 * 2
    assert b["bytes"]["collective-permute"] == 4 * 4
    # -done skipped; -start tuple handled (halved), counted under all-gather
    assert b["counts"]["all-reduce"] == 1
    total = collective_bytes_from_hlo(HLO)
    assert total == sum(b["bytes"].values())


def test_roofline_terms_arithmetic():
    from repro.configs.base import SHAPES, get_config

    cfg = get_config("llama3_2_1b")
    rec = {"flops": PEAK_FLOPS, "bytes_accessed": HBM_BW,
           "collective_bytes": ICI_BW * 2}
    out = roofline_terms(rec, cfg, SHAPES["train_4k"], 256)
    assert abs(out["compute_s"] - 1.0) < 1e-9
    assert abs(out["memory_s"] - 1.0) < 1e-9
    assert abs(out["collective_s"] - 2.0) < 1e-9
    assert out["dominant"] == "collective"
    assert out["roofline_bound_s"] == 2.0
    assert 0 < out["useful_flops_ratio"] < 10


def test_model_flops_sanity():
    from repro.configs.base import SHAPES, get_config
    from repro.roofline.analysis import model_flops

    cfg = get_config("llama3_2_1b")
    train = model_flops(cfg, SHAPES["train_4k"])
    prefill = model_flops(cfg, SHAPES["prefill_32k"])
    decode = model_flops(cfg, SHAPES["decode_32k"])
    assert train > prefill > decode > 0
    # train ~ 6/2 x prefill adjusted for batch/seq: just sanity bounds
    assert decode < 1e-3 * prefill
    # MoE active < total
    v3 = get_config("deepseek_v3_671b")
    from repro.roofline.analysis import count_params
    total, active = count_params(v3)
    assert active < 0.15 * total  # 37B activated of 671B
