"""Serving robustness fault matrix: plan-artifact validation (plan guard),
backend degradation ladder, and ServeEngine fault isolation.

Every fault class injected through `repro.testing.faults` must either
recover (retry/re-queue reproduces the exact greedy output — decode is
deterministic) or degrade to the host-exact result (all FTFI backends
compute the same M_f X, so lower rungs are free correctness oracles).
Nothing here may escape as an unhandled exception.
"""
import warnings

import numpy as np
import pytest
import jax

from repro import ftfi
from repro.configs.base import get_smoke_config
from repro.core import cordial as C
from repro.core import ladder, plan_cache, plan_guard
from repro.core.ladder import BackendDemotionWarning, LadderExhaustedError
from repro.core.plan_guard import PlanGuardWarning, PlanValidationError
from repro.core import clear_flat_cache, clear_plan_cache
from repro.graphs.graph import random_tree
from repro.models import api
from repro.serve.engine import Request, ServeEngine
from repro.testing import faults


@pytest.fixture(autouse=True)
def _clean_robustness_state():
    """Faults disarmed, ladder unblocked, guard policy strict, per test."""
    faults.clear()
    ladder.unblock_backends()
    old = plan_guard.policy()
    plan_guard.set_policy("strict")
    try:
        yield
    finally:
        faults.clear()
        ladder.unblock_backends()
        plan_guard.set_policy(old)


@pytest.fixture(scope="module")
def plan_pair():
    return ftfi.build(random_tree(60, seed=7), leaf_size=8)


def _rel_err(got, ref):
    got, ref = np.asarray(got), np.asarray(ref)
    return float(np.max(np.abs(got - ref))
                 / max(np.max(np.abs(ref)), 1e-12))


# ----------------------------------------------------------------------------
# plan guard: artifact validation
# ----------------------------------------------------------------------------


def test_guard_accepts_healthy_plan(plan_pair):
    spec, params = plan_pair
    assert plan_guard.check_spec(spec, params) == []
    assert ftfi.validate(spec, params) is True


@pytest.mark.parametrize("field", ["src_gather", "tgt_scatter", "pivots",
                                   "src_seg", "tgt_gather"])
def test_guard_catches_flipped_index(plan_pair, field):
    spec, params = plan_pair
    bad = faults.flip_index(spec, field=field)
    with pytest.raises(PlanValidationError, match=field):
        ftfi.validate(bad, params)


def test_guard_catches_nan_params(plan_pair):
    spec, params = plan_pair
    import dataclasses

    dists = list(params.cross_src_d)
    d0 = np.array(dists[0], copy=True)
    d0.reshape(-1)[0] = np.nan
    dists[0] = d0
    bad = dataclasses.replace(params, cross_src_d=tuple(dists))
    with pytest.raises(PlanValidationError, match="finite"):
        ftfi.validate(spec, bad)


def test_guard_warn_policy_rejects_without_raising(plan_pair):
    spec, params = plan_pair
    bad = faults.flip_index(spec, field="src_gather")
    before = plan_guard.stats()
    with pytest.warns(PlanGuardWarning):
        ok = plan_guard.validate(bad, params, policy_override="warn")
    assert ok is False
    after = plan_guard.stats()
    assert after["failures"] == before["failures"] + 1
    assert after["warned"] == before["warned"] + 1


def test_guard_off_policy_skips(plan_pair):
    spec, params = plan_pair
    bad = faults.flip_index(spec, field="src_gather")
    assert plan_guard.validate(bad, params, policy_override="off") is True


# ----------------------------------------------------------------------------
# load_plan on damaged artifacts (satellite: truncated / bit-flipped npz)
# ----------------------------------------------------------------------------


def test_load_plan_truncated_artifact(tmp_path, plan_pair):
    spec, params = plan_pair
    p = tmp_path / "plan.npz"
    ftfi.save_plan(p, spec, params)
    faults.corrupt_file(p, truncate_to=p.stat().st_size // 2)
    with pytest.raises(PlanValidationError, match="corrupt or truncated"):
        ftfi.load_plan(p)


def test_load_plan_bitflipped_artifact(tmp_path, plan_pair):
    spec, params = plan_pair
    p = tmp_path / "plan.npz"
    ftfi.save_plan(p, spec, params)
    faults.corrupt_file(p, flip_bytes=64, seed=11)
    # either the parse fails (wrapped) or the semantic validation trips —
    # both surface as PlanValidationError, never bad indices to the executor
    with pytest.raises(PlanValidationError):
        ftfi.load_plan(p)


def test_load_plan_roundtrip_still_validates(tmp_path, plan_pair):
    spec, params = plan_pair
    p = tmp_path / "plan.npz"
    ftfi.save_plan(p, spec, params)
    spec2, params2 = ftfi.load_plan(p)  # validate=True default
    X = np.random.default_rng(0).normal(size=(spec.n, 2)).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(ftfi.apply(spec, params, C.Exponential(-0.5), X)),
        np.asarray(ftfi.apply(spec2, params2, C.Exponential(-0.5), X)))


def test_update_plan_output_is_validated():
    spec, params = ftfi.build(random_tree(40, seed=9), leaf_size=8,
                              reweightable=True)
    before = plan_guard.stats()["validations"]
    spec2, params2 = ftfi.update_plan(spec, params, [("insert_leaf", 0, 0.5)])
    assert plan_guard.stats()["validations"] == before + 1
    assert plan_guard.check_spec(spec2, params2) == []


def test_integrator_from_plan_guards_artifact_pairs(plan_pair):
    from repro.core import Integrator

    spec, params = plan_pair
    integ = Integrator.from_plan(spec, params)  # healthy pair passes
    assert integ.spec is spec
    with pytest.raises(PlanValidationError):
        Integrator.from_plan(faults.flip_index(spec), params)


def test_disk_cache_hit_validates_and_rejects_corruption(tmp_path):
    plan_cache.configure(tmp_path / "plans", max_mb=64)
    clear_flat_cache()
    clear_plan_cache()
    try:
        tree = random_tree(200, seed=5)
        spec1, pp1 = ftfi.build(tree, leaf_size=16)
        [artifact] = list((tmp_path / "plans").glob("ftfi-plan-*.npz"))
        faults.corrupt_file(artifact, flip_bytes=48, seed=3)

        clear_flat_cache()
        clear_plan_cache()
        before = plan_cache.stats()
        spec2, pp2 = ftfi.build(tree, leaf_size=16)  # corrupt hit -> rebuild
        after = plan_cache.stats()
        assert after["hits"] == before["hits"]
        assert after["misses"] == before["misses"] + 1
        assert after["errors"] == before["errors"] + 1
        assert not artifact.exists() or after["stores"] > before["stores"]
        # rebuilt plan is the real one
        X = np.random.default_rng(1).normal(size=(200, 2)).astype(np.float32)
        np.testing.assert_array_equal(
            np.asarray(ftfi.apply(spec1, pp1, C.Exponential(-0.4), X)),
            np.asarray(ftfi.apply(spec2, pp2, C.Exponential(-0.4), X)))
    finally:
        plan_cache.reset_to_env()
        clear_flat_cache()
        clear_plan_cache()


def test_cache_max_mb_env_parse_is_defensive(monkeypatch, tmp_path):
    monkeypatch.setenv("FTFI_PLAN_CACHE_MAX_MB", "not-a-number")
    plan_cache.configure(tmp_path / "p")
    try:
        with pytest.warns(UserWarning, match="FTFI_PLAN_CACHE_MAX_MB"):
            assert plan_cache.stats()["max_bytes"] == int(512e6)
    finally:
        plan_cache.reset_to_env()


# ----------------------------------------------------------------------------
# degradation ladder
# ----------------------------------------------------------------------------


def test_ladder_kernel_raise_demotes_with_parity(plan_pair):
    spec, params = plan_pair
    fn = C.Exponential(-0.5)
    X = np.random.default_rng(2).normal(size=(spec.n, 3)).astype(np.float32)
    ref = np.asarray(ftfi.apply(spec, params, fn, X, backend="plan"))
    ladder.reset_stats()
    with faults.injected("ladder.pallas", faults.always_raise(
            RuntimeError, "kernel launch failed")):
        with pytest.warns(BackendDemotionWarning, match="pallas.*plan"):
            Y = ftfi.apply_resilient(spec, params, fn, X, backend="pallas")
    assert _rel_err(Y, ref) <= 1e-5
    st = ladder.stats()
    assert st["errors"] == 1 and st["demotions"] == 1


def test_ladder_nan_output_reaches_host_exact(plan_pair):
    spec, params = plan_pair
    fn = C.Exponential(-0.5)
    X = np.random.default_rng(3).normal(size=(spec.n, 2)).astype(np.float32)
    ref = np.asarray(ftfi.apply(spec, params, fn, X, backend="plan"))
    with faults.injected("ladder.pallas", faults.always_raise()), \
            faults.injected("ladder.out.plan", faults.nan_output()), \
            warnings.catch_warnings():
        warnings.simplefilter("ignore", BackendDemotionWarning)
        Y = ftfi.apply_resilient(spec, params, fn, X, backend="pallas")
    assert _rel_err(Y, ref) <= 1e-5  # host rung result, exact


def test_ladder_demotion_is_sticky(plan_pair):
    spec, params = plan_pair
    fm = ftfi.resilient_fastmult(spec, C.Exponential(-0.5), backend="pallas")
    X = np.random.default_rng(4).normal(size=(spec.n, 2)).astype(np.float32)
    ladder.reset_stats()
    with faults.injected("ladder.pallas", faults.always_raise()), \
            warnings.catch_warnings():
        warnings.simplefilter("ignore", BackendDemotionWarning)
        fm(params, X)
        fm(params, X)  # second call starts at "plan": no second error
    assert ladder.stats()["errors"] == 1
    assert fm.level == "plan"
    assert fm.demotions == [("pallas", "plan",
                             "RuntimeError: injected fault")]


def test_ladder_exhaustion_is_structured(plan_pair):
    spec, params = plan_pair
    X = np.zeros((spec.n, 1), np.float32)
    with faults.injected("ladder.pallas", faults.always_raise()), \
            faults.injected("ladder.plan", faults.always_raise()), \
            faults.injected("ladder.host", faults.always_raise()), \
            warnings.catch_warnings():
        warnings.simplefilter("ignore", BackendDemotionWarning)
        with pytest.raises(LadderExhaustedError, match="every backend rung"):
            ftfi.apply_resilient(spec, params, C.Exponential(-0.5), X,
                                 backend="pallas")


def test_block_backend_steers_dispatch():
    from repro.models import attention as A

    cfg = get_smoke_config("qwen2_1_5b").replace(topo_backend="pallas")
    assert A.resolve_topo_backend(cfg) == "pallas"
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", BackendDemotionWarning)
        ladder.block_backend("pallas", "probe failed (test)")
    assert ladder.effective_backend("pallas") == "plan"
    assert A.resolve_topo_backend(cfg) == "plan"
    with pytest.raises(ValueError, match="terminal"):
        ladder.block_backend("host", "nope")
    ladder.unblock_backends()
    assert A.resolve_topo_backend(cfg) == "pallas"


def test_probe_backend_reports_failure(plan_pair):
    spec, params = plan_pair
    assert ladder.probe_backend(spec, params, "plan") is None
    with faults.injected("ladder.pallas", faults.always_raise(
            RuntimeError, "no TPU")):
        reason = ladder.probe_backend(spec, params, "pallas")
    assert reason is not None and "no TPU" in reason
    with faults.injected("ladder.out.plan", faults.nan_output()):
        assert "non-finite" in ladder.probe_backend(spec, params, "plan")


# ----------------------------------------------------------------------------
# ServeEngine isolation (fault matrix rows: slot crash, step crash, retry
# exhaustion, deadlines) + the fresh-wave admission regression
# ----------------------------------------------------------------------------


@pytest.fixture(scope="module")
def serve_setup():
    cfg = get_smoke_config("qwen2_1_5b").replace(dtype="float32")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=int(n)).tolist()
               for n in (3, 7, 5)]
    # single-slot reference outputs (greedy decode is deterministic)
    refs = []
    for p in prompts:
        eng = ServeEngine(cfg, params, batch_slots=1, max_len=64)
        r = Request(rid=0, prompt=p, max_new_tokens=4)
        eng.submit(r)
        eng.run()
        refs.append(list(r.out))
    return cfg, params, prompts, refs


def test_mixed_length_waves_match_reference(serve_setup):
    """Batch-size independence: three mixed-length prompts through 2 slots
    (the third admits MID-WAVE into whichever slot frees first — legal under
    fused prefill + per-slot decode positions) must reproduce their
    single-slot outputs exactly."""
    cfg, params, prompts, refs = serve_setup
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=64)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=4)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r, ref in zip(reqs, refs):
        assert r.done and r.error is None
        assert r.out == ref
    assert eng.stats()["completed"] == 3


def test_slot_fault_retries_only_that_request(serve_setup):
    cfg, params, prompts, refs = serve_setup
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=64)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=4)
            for i, p in enumerate(prompts[:2])]
    for r in reqs:
        eng.submit(r)
    with faults.injected("serve.logits", faults.nan_slot_at_tick(slot=1, k=2)):
        eng.run()
    st = eng.stats()
    assert all(r.done and r.error is None for r in reqs)
    assert reqs[0].retries == 0 and reqs[1].retries == 1
    assert reqs[0].out == refs[0]
    assert reqs[1].out == refs[1]  # replayed bit-identically
    assert st["slot_faults"] == 1 and st["evictions"] == 1
    assert st["retries"] == 1 and st["failed"] == 0


def test_step_crash_requeues_wave_engine_survives(serve_setup):
    cfg, params, prompts, refs = serve_setup
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=64)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=4)
            for i, p in enumerate(prompts[:2])]
    for r in reqs:
        eng.submit(r)
    with faults.injected("serve.step", faults.raise_at_tick(3)):
        eng.run()
    st = eng.stats()
    assert all(r.done and r.error is None for r in reqs)
    for r, ref in zip(reqs, refs):
        assert r.out == ref
    assert st["step_failures"] == 1 and st["evictions"] == 2
    assert st["failed"] == 0


def test_retry_budget_exhaustion_fails_request_not_engine(serve_setup):
    cfg, params, prompts, refs = serve_setup
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=64, max_retries=1)
    doomed = Request(rid=0, prompt=prompts[0], max_new_tokens=4)
    eng.submit(doomed)
    with faults.injected("serve.logits", faults.nan_output()):
        eng.run()
    assert doomed.done and doomed.error is not None
    assert "retries" in doomed.error
    assert eng.stats()["failed"] == 1
    # the engine is still serviceable after exhausting a request
    healthy = Request(rid=1, prompt=prompts[1], max_new_tokens=4)
    eng.submit(healthy)
    eng.run()
    assert healthy.done and healthy.error is None
    assert healthy.out == refs[1]


def test_deadline_expires_queued_request(serve_setup):
    cfg, params, prompts, refs = serve_setup
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=64)
    a = Request(rid=0, prompt=prompts[0], max_new_tokens=4)
    b = Request(rid=1, prompt=prompts[1], max_new_tokens=4, deadline_ticks=2)
    eng.submit(a)
    eng.submit(b)  # stuck behind a's wave, expires in queue
    eng.run()
    assert a.done and a.error is None and a.out == refs[0]
    assert b.done and b.error is not None and "deadline" in b.error
    assert eng.stats()["deadline_expired"] == 1


def test_engine_rejects_corrupt_preloaded_plan(serve_setup, plan_pair):
    cfg, params, _, _ = serve_setup
    spec, pp = plan_pair
    bad = faults.flip_index(spec, field="src_gather")
    with pytest.raises(PlanValidationError):
        ServeEngine(cfg, params, batch_slots=1, max_len=32, plan=(bad, pp))


def test_health_banner_mentions_counters(serve_setup):
    cfg, params, prompts, _ = serve_setup
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=64)
    r = Request(rid=0, prompt=prompts[0], max_new_tokens=4)
    eng.submit(r)
    eng.run()
    line = eng.health_banner()
    assert "done=1" in line and "retries=" in line and "demotions=" in line
