"""Forest integration: ONE fused plan across many trees == the per-tree
loop — container semantics, batched flat-IT structure, backend equivalence
on a mixed-size 50+ graph forest, grid reconciliation, the batched Borůvka
spanning forest, FRT-forest averaging, and per-graph forest masks."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import cordial as C
from repro.core.engines import Integrator
from repro.core.itree_flat import build_flat_forest, build_flat_it
from repro.graphs.graph import (Forest, caterpillar_tree, path_graph,
                                random_tree, star_tree, synthetic_graph)


def _mixed_forest(num=55, seed=0, lo=8, hi=60):
    rng = np.random.default_rng(seed)
    trees = [random_tree(int(s), seed=seed + i)
             for i, s in enumerate(rng.integers(lo, hi, size=num - 3))]
    trees += [path_graph(34), star_tree(27, seed=seed + 1),
              caterpillar_tree(41, seed=seed + 2)]
    return Forest(trees)


# ---------------------------------------------------------------------------
# container
# ---------------------------------------------------------------------------


def test_forest_container_pack_unpack_broadcast(rng):
    forest = _mixed_forest(10)
    fields = [rng.normal(size=(int(s), 3)) for s in forest.tree_sizes]
    X = forest.pack(fields)
    assert X.shape == (forest.num_vertices, 3)
    back = forest.unpack(X)
    for a, b in zip(back, fields):
        assert np.array_equal(a, b)
    w = rng.normal(size=forest.num_trees)
    wv = forest.broadcast(w)
    assert wv.shape == (forest.num_vertices,)
    off = forest.offsets
    for t in range(forest.num_trees):
        assert np.all(wv[off[t]:off[t + 1]] == w[t])
    with pytest.raises(ValueError):
        forest.pack(fields[:-1])
    with pytest.raises(ValueError):
        forest.unpack(X[:-1])
    with pytest.raises(ValueError):
        Forest([])
    with pytest.raises(TypeError):
        Forest([synthetic_graph(20, 5, seed=0)])  # not a tree


# ---------------------------------------------------------------------------
# batched flat-IT build == per-tree builds (with offsets)
# ---------------------------------------------------------------------------


def test_build_flat_forest_matches_per_tree_builds():
    forest = _mixed_forest(12, seed=3)
    flat = build_flat_forest(forest.trees, leaf_size=16, use_cache=False)
    per = [build_flat_it(t, leaf_size=16, use_cache=False)
           for t in forest.trees]
    off = forest.offsets
    assert flat.n == forest.num_vertices
    assert flat.num_internal == sum(p.num_internal for p in per)
    assert flat.num_leaves == sum(p.num_leaves for p in per)
    exp_piv = np.sort(np.concatenate(
        [p.pivots + off[i] for i, p in enumerate(per)]))
    assert np.array_equal(np.sort(flat.pivots), exp_piv)
    # every vertex appears in exactly the leaves covering it
    leaf_verts = np.sort(np.concatenate(flat.leaf_ids))
    exp_leaf = np.sort(np.concatenate(
        [ids + off[i] for i, p in enumerate(per) for ids in p.leaf_ids]))
    assert np.array_equal(leaf_verts, exp_leaf)
    # per-tree roots are recorded (one ref per tree, valid encoding)
    assert flat.root_refs is not None and flat.root_refs.size == 12


# ---------------------------------------------------------------------------
# acceptance: fused forest plan == per-tree loop on a mixed 50+ graph forest
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["plan", "pallas"])
def test_forest_plan_equals_per_tree_loop(backend, rng):
    forest = _mixed_forest(55)
    X = rng.normal(size=(forest.num_vertices, 3))
    loop = Integrator.from_forest(forest, backend="host")
    for fn in (C.Exponential(-0.7, 1.3), C.Polynomial((0.5, -0.2, 0.1)),
               C.AnyFn(lambda z: (z + 1.0) ** -0.5)):
        ref = np.asarray(loop.integrate(fn, X))
        integ = Integrator.from_forest(forest, backend=backend, leaf_size=16)
        got = np.asarray(integ.integrate(fn, X))
        scale = max(np.max(np.abs(ref)), 1e-12)
        assert np.max(np.abs(got - ref)) / scale < 1e-5, type(fn).__name__
    assert loop.num_trees == 55
    assert Integrator.from_forest(forest, backend=backend).num_trees == 55


def test_forest_is_block_diagonal(rng):
    """A field supported on one tree never leaks into another tree's rows."""
    forest = _mixed_forest(8, seed=5)
    off = forest.offsets
    X = np.zeros((forest.num_vertices, 2))
    t = 3
    X[off[t]:off[t + 1]] = rng.normal(size=(off[t + 1] - off[t], 2))
    out = np.asarray(Integrator.from_forest(forest, leaf_size=16)
                     .integrate(C.Exponential(-0.5), X))
    mask = np.zeros(forest.num_vertices, bool)
    mask[off[t]:off[t + 1]] = True
    assert np.max(np.abs(out[~mask])) < 1e-6 * max(np.max(np.abs(out)), 1e-9)


def test_forest_single_fused_dispatch(rng):
    """The whole forest runs as one cached jitted executor: no retrace on
    repeated calls, num_trees-independent dispatch structure."""
    forest = _mixed_forest(20, seed=7)
    X = rng.normal(size=(forest.num_vertices, 2))
    integ = Integrator.from_forest(forest, backend="plan", leaf_size=16)
    fm = integ.fastmult(C.Exponential(-0.4))
    np.asarray(fm(X))
    assert fm.trace_count == 1
    np.asarray(fm(X))
    assert fm.trace_count == 1  # same shapes: no retrace
    plan = integ._impl.plan
    # buckets are merged across trees by size class: far fewer buckets than
    # trees (the whole point of the shared index space)
    assert len(plan.cross_buckets) + len(plan.leaf_buckets) < 12


@pytest.mark.filterwarnings("ignore::DeprecationWarning")  # facade path
def test_forest_fastmult_shared_across_instances(rng):
    """Content-cached plans share their compiled fastmult closures: a new
    Integrator over an identical forest reuses the jitted executor."""
    forest = _mixed_forest(6, seed=11)
    i1 = Integrator.from_forest(forest, backend="plan", leaf_size=16)
    fm1 = i1.fastmult(C.Exponential(-0.3, 1.1))
    twin = Forest([type(t)(t.num_vertices, t.edges_u.copy(),
                           t.edges_v.copy(), t.weights.copy())
                   for t in forest.trees])
    i2 = Integrator.from_forest(twin, backend="plan", leaf_size=16)
    assert i2._impl.plan is i1._impl.plan  # content-hash plan hit
    assert i2.fastmult(C.Exponential(-0.3, 1.1)) is fm1


def test_forest_grid_h_reconciliation(rng):
    """All-unit-weight forest -> grid_h == 1.0 and the exact Hankel engine
    for general f; one off-grid tree poisons the whole forest to None."""
    unit = Forest([path_graph(40), path_graph(25),
                   path_graph(33)])
    general = C.AnyFn(lambda z: np.sin(z) * np.exp(-0.1 * z) + 1.0)
    X = rng.normal(size=(unit.num_vertices, 2))
    integ = Integrator.from_forest(unit, backend="plan", leaf_size=8)
    assert integ.grid_h == pytest.approx(1.0)
    assert integ.describe(general)["cross_engine"] == "hankel_fft"
    ref = np.asarray(Integrator.from_forest(unit, backend="host")
                     .integrate(general, X))
    got = np.asarray(integ.integrate(general, X))
    assert np.max(np.abs(got - ref)) / np.max(np.abs(ref)) < 1e-5
    mixed = Forest([path_graph(40), random_tree(30, seed=2)])
    assert Integrator.from_forest(mixed, backend="plan",
                                  leaf_size=8).grid_h is None


# ---------------------------------------------------------------------------
# batched Borůvka spanning forest == per-graph Kruskal
# ---------------------------------------------------------------------------


def test_minimum_spanning_forest_matches_kruskal():
    from repro.graphs.mst import (minimum_spanning_forest,
                                  minimum_spanning_tree)

    graphs = [synthetic_graph(int(n), int(n) // 2, seed=i)
              for i, n in enumerate(np.random.default_rng(0)
                                    .integers(10, 80, size=25))]
    msf = minimum_spanning_forest(graphs)
    for got, g in zip(msf, graphs):
        ref = minimum_spanning_tree(g)
        ka = sorted(zip(got.edges_u.tolist(), got.edges_v.tolist(),
                        got.weights.tolist()))
        kb = sorted(zip(ref.edges_u.tolist(), ref.edges_v.tolist(),
                        ref.weights.tolist()))
        assert ka == kb
    # disconnected member raises
    bad = synthetic_graph(10, 0, seed=0)
    bad = type(bad)(11, bad.edges_u, bad.edges_v, bad.weights)  # isolated v
    with pytest.raises(ValueError, match="disconnected"):
        minimum_spanning_forest([graphs[0], bad])


# ---------------------------------------------------------------------------
# FRT forest averaging
# ---------------------------------------------------------------------------


def test_frt_integrate_forest_equals_mean_of_single_trees(rng):
    from repro.graphs.frt import frt_integrate, frt_integrate_forest

    g = synthetic_graph(60, 30, seed=4)
    X = rng.normal(size=(60, 2))
    fn = C.Exponential(-0.5)
    k = 4
    got = frt_integrate_forest(g, fn, X, num_trees=k, seed=7, leaf_size=16)
    # frt_forest samples tree t with seed = seed + 977 * t
    ref = np.mean(np.stack([
        frt_integrate(g, fn, X, seed=7 + 977 * t, leaf_size=16)
        for t in range(k)]), axis=0)
    assert np.max(np.abs(got - ref)) / np.max(np.abs(ref)) < 1e-5


# ---------------------------------------------------------------------------
# per-graph masks over a packed forest
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("weights", [None, "per_tree"])
def test_make_forest_fastmult_block_diag_mask(weights, rng):
    from repro.core import masks as MK
    from repro.graphs.traverse import tree_all_pairs

    forest = _mixed_forest(5, seed=9, lo=8, hi=24)
    off = forest.offsets
    N = forest.num_vertices
    integ = Integrator.from_forest(forest, backend="plan", leaf_size=8)
    coeffs = jnp.asarray([0.0, -0.3], jnp.float32)
    tw = (rng.uniform(0.5, 1.5, size=forest.num_trees)
          if weights == "per_tree" else None)
    fm = MK.make_forest_fastmult(integ, forest, "exp", coeffs,
                                 dist_scale=1.0, tree_weights=tw)
    X = jnp.asarray(rng.normal(size=(2, N, 4)), jnp.float32)  # batched field
    # dense block-diagonal reference
    M = np.zeros((N, N))
    for t, tree in enumerate(forest.trees):
        D = tree_all_pairs(tree)
        blk = np.exp(-0.3 * D)
        if tw is not None:
            blk = tw[t] * blk
        M[off[t]:off[t + 1], off[t]:off[t + 1]] = blk
    ref = np.einsum("lk,bkd->bld", M, np.asarray(X))
    got = np.asarray(fm(X))
    assert np.max(np.abs(got - ref)) / np.max(np.abs(ref)) < 1e-5
