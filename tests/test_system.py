"""End-to-end behaviour tests for the paper's system."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import get_smoke_config
from repro.core import Exponential, FTFI, Rational
from repro.core.fit import fit_rational_f, tree_metric_frobenius_error
from repro.graphs.graph import synthetic_graph
from repro.graphs.meshes import icosphere, mesh_graph, vertex_normals
from repro.graphs.mst import minimum_spanning_tree
from repro.models import api
from repro.serve.engine import Request, ServeEngine


def test_mesh_interpolation_pipeline(rng):
    """The paper's Sec-4.2 vertex-normal task end to end: FTFI-interpolated
    normals align with ground truth (cosine similarity)."""
    verts, faces = icosphere(2)  # 162 vertices
    normals = vertex_normals(verts, faces)
    g = mesh_graph(verts, faces)
    mst = minimum_spanning_tree(g)
    n = verts.shape[0]
    known = rng.random(n) < 0.2
    F = np.where(known[:, None], normals, 0.0)
    fn = Rational((1.0,), (1.0, 0.0, 4.0))  # f = 1/(1+4 x^2)
    pred = FTFI(mst, leaf_size=16).integrate(fn, F)
    norms = np.linalg.norm(pred, axis=1, keepdims=True)
    pred = pred / np.maximum(norms, 1e-9)
    cos = np.sum(pred[~known] * normals[~known], axis=1)
    assert np.mean(cos) > 0.80, np.mean(cos)


def test_learnable_f_improves_metric_approx():
    """Sec 4.3: trained rational f beats the identity tree metric."""
    g = synthetic_graph(150, 100, seed=3)
    t = minimum_spanning_tree(g)
    base = tree_metric_frobenius_error(g, t)
    res = fit_rational_f(g, t, num_deg=2, den_deg=2, num_pairs=100,
                         steps=200, eval_frobenius=True)
    assert res.rel_frobenius < base * 0.5, (base, res.rel_frobenius)
    assert res.losses[-1] < res.losses[0]


def test_serve_engine_generates(rng):
    cfg = get_smoke_config("qwen2_1_5b").replace(dtype="float32")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=64)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 5).tolist(),
                    max_new_tokens=6) for i in range(4)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r in reqs:
        assert r.done and len(r.out) >= 6
        assert all(0 <= t < cfg.padded_vocab() for t in r.out)


def test_topovit_forward(rng):
    """The paper's own architecture: TopoViT forward with grid-MST masking."""
    from repro.configs.base import get_smoke_config
    from repro.models import vit

    cfg = get_smoke_config("topovit_b16").replace(dtype="float32")
    integ = vit.build_grid_integrator(cfg)
    params = vit.init_params(cfg, jax.random.PRNGKey(0), num_classes=10,
                             patch_dim=48)
    patches = jnp.asarray(
        rng.normal(size=(2, cfg.num_prefix_embeddings, 48)), jnp.float32)
    logits = vit.forward(cfg, params, patches, integ)
    assert logits.shape == (2, 10)
    assert np.isfinite(np.asarray(logits)).all()

    # gradients flow into the 3 mask parameters
    def loss(p):
        lg = vit.forward(cfg, p, patches, integ)
        return jnp.sum(lg ** 2)

    g = jax.grad(loss)(params)
    gsum = sum(float(jnp.sum(jnp.abs(x)))
               for x in jax.tree.leaves(g["blocks"]["topo"]))
    assert gsum > 0
