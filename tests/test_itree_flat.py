"""Flat vectorized IT builder: oracle exactness, Lemma-3.1 balance on
degenerate topologies at n=2000 (the old `_centroid_split` re-rooting walk
relied on stale subtree sizes — the flat builder picks a true centroid via a
segmented argmin, so these must terminate AND balance), structural
invariants, and the IT/plan content-hash caches."""
import numpy as np
import pytest

from repro.core import cordial as C
from repro.core.integrate import (BTFI, FTFI, clear_plan_cache, compile_plan)
from repro.core.integrator_tree import build_integrator_tree, it_stats
from repro.core.itree_flat import (build_flat_it, clear_flat_cache,
                                   flat_stats, tree_fingerprint)
from repro.graphs.graph import (caterpillar_tree, grid_graph, path_graph,
                                random_tree, star_tree)
from repro.graphs.meshes import icosphere, mesh_graph
from repro.graphs.mst import minimum_spanning_tree
from repro.graphs.traverse import TreeLCA


TREES = [
    ("random_weighted", lambda: random_tree(300, seed=3)),
    ("mesh_mst", lambda: minimum_spanning_tree(
        mesh_graph(*icosphere(2)))),
    ("path", lambda: path_graph(180)),
    ("star", lambda: star_tree(150, seed=5)),
    ("caterpillar", lambda: caterpillar_tree(200, seed=6)),
    ("grid_mst", lambda: minimum_spanning_tree(grid_graph(12, 12, seed=7))),
]


@pytest.mark.parametrize("name,mk", TREES, ids=[t[0] for t in TREES])
def test_flat_builder_matches_btfi_oracle(name, mk, rng):
    tree = mk()
    n = tree.num_vertices
    X = rng.normal(size=(n, 3))
    for fn in (C.Exponential(-0.6), C.Polynomial((0.4, -0.1, 0.05)),
               C.AnyFn(lambda z: np.log1p(z) * np.exp(-0.3 * z))):
        ref = BTFI(tree).integrate(fn, X)
        got = FTFI(tree, leaf_size=16).integrate(fn, X)
        scale = max(np.max(np.abs(ref)), 1e-12)
        assert np.max(np.abs(got - ref)) / scale < 1e-5


@pytest.mark.parametrize("mk", [lambda: path_graph(2000),
                                lambda: star_tree(2000, seed=0),
                                lambda: caterpillar_tree(2000, seed=0)],
                         ids=["path2000", "star2000", "caterpillar2000"])
def test_degenerate_topologies_balance_at_n2000(mk):
    """Regression for the stale-size re-rooting bug: the build must
    terminate and satisfy the Lemma-3.1 balance bound on adversarial
    shapes."""
    flat = build_flat_it(mk(), leaf_size=64, use_cache=False)
    stats = flat_stats(flat)
    assert stats["balance_ok"]
    assert stats["max_depth"] <= 4 * int(np.ceil(np.log2(2000)))
    # materialized view agrees
    st2 = it_stats(build_integrator_tree(mk(), leaf_size=64))
    assert st2["balance_ok"]
    assert st2["internal"] == stats["internal"]
    assert st2["leaves"] == stats["leaves"]


def test_flat_side_arrays_are_true_pivot_distances():
    tree = random_tree(257, seed=11)
    flat = build_flat_it(tree, leaf_size=16, use_cache=False)
    lca = TreeLCA(tree)
    for i in range(flat.num_internal):
        p = flat.pivots[i]
        for side in (flat.left[i], flat.right[i]):
            assert side.ids[0] == p
            assert side.d[0] == 0.0
            # id_d is monotone (ids are emitted in ascending-distance order,
            # so the segment layout is the identity permutation)
            assert np.all(np.diff(side.id_d) >= 0)
            assert side.seg_starts[0] == 0
            ref = lca.distance(np.full(side.ids.size, p), side.ids)
            assert np.allclose(side.d[side.id_d], ref, atol=1e-9)
        both = set(flat.left[i].ids) & set(flat.right[i].ids)
        assert both == {int(p)}


def test_flat_it_cache_and_fingerprint():
    tree = random_tree(120, seed=2)
    clear_flat_cache()
    f1 = build_flat_it(tree, leaf_size=16)
    f2 = build_flat_it(tree, leaf_size=16)
    assert f1 is f2  # content-hash hit
    assert build_flat_it(tree, leaf_size=32) is not f1
    # an identical copy of the tree hits the same cache entry
    twin = type(tree)(tree.num_vertices, tree.edges_u.copy(),
                      tree.edges_v.copy(), tree.weights.copy())
    assert tree_fingerprint(twin) == tree_fingerprint(tree)
    assert build_flat_it(twin, leaf_size=16) is f1
    # different weights -> different key
    other = type(tree)(tree.num_vertices, tree.edges_u.copy(),
                       tree.edges_v.copy(), tree.weights * 2.0)
    assert tree_fingerprint(other) != tree_fingerprint(tree)
    clear_flat_cache()
    assert build_flat_it(tree, leaf_size=16) is not f1


def test_cache_keys_include_seed():
    """Regression: differently-seeded builds must never alias to the first
    build via the content-hash caches (the key used to omit `seed`)."""
    tree = random_tree(90, seed=1)
    clear_plan_cache()
    clear_flat_cache()
    p0 = compile_plan(tree, leaf_size=16, seed=0)
    p1 = compile_plan(tree, leaf_size=16, seed=1)
    assert p0 is not p1
    assert compile_plan(tree, leaf_size=16, seed=0) is p0
    assert compile_plan(tree, leaf_size=16, seed=1) is p1
    f0 = build_flat_it(tree, leaf_size=16, seed=0)
    f1 = build_flat_it(tree, leaf_size=16, seed=1)
    assert f0 is not f1
    assert build_flat_it(tree, leaf_size=16, seed=0) is f0
    # forest builds carry the seed in their key too
    from repro.core.itree_flat import build_flat_forest

    trees = [tree, random_tree(40, seed=2)]
    ff0 = build_flat_forest(trees, leaf_size=16, seed=0)
    ff1 = build_flat_forest(trees, leaf_size=16, seed=1)
    assert ff0 is not ff1
    assert build_flat_forest(trees, leaf_size=16, seed=0) is ff0


def test_plan_cache_amortizes_recompilation():
    tree = random_tree(150, seed=4)
    clear_plan_cache()
    clear_flat_cache()
    p1 = compile_plan(tree, leaf_size=16)
    p2 = compile_plan(tree, leaf_size=16)
    assert p1 is p2
    assert compile_plan(tree, leaf_size=32) is not p1
    clear_plan_cache()
    assert compile_plan(tree, leaf_size=16) is not p1


def test_plan_flat_index_arrays_consistent():
    tree = random_tree(200, seed=9)
    plan = compile_plan(tree, leaf_size=16, use_cache=False)
    n = tree.num_vertices
    # gather/scatter vertex ids are real vertices (padding-free by design)
    assert plan.src_gather.min() >= 0 and plan.src_gather.max() < n
    assert plan.tgt_scatter.min() >= 0 and plan.tgt_scatter.max() < n
    assert plan.src_seg.max() < plan.n_src_groups
    assert plan.tgt_gather.max() < plan.n_tgt_groups
    # each (node, direction) job contributes its non-pivot targets once:
    # total scatter size == sum over internal nodes of (kL-1) + (kR-1)
    assert plan.num_jobs() == 2 * plan.pivots.size
