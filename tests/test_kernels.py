"""Per-kernel Pallas validation (interpret mode) vs pure-jnp oracles,
sweeping shapes and dtypes."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels.fdist_matvec.kernel import fdist_matvec_pallas
from repro.kernels.fdist_matvec.ref import fdist_matvec_ref
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.linear_attention.kernel import linear_attention_pallas
from repro.kernels.linear_attention.ref import linear_attention_ref
from repro.kernels.selective_scan.kernel import selective_scan_pallas
from repro.kernels.selective_scan.ref import selective_scan_ref


@pytest.mark.parametrize("a,b,d", [(300, 200, 8), (128, 128, 4), (97, 33, 3),
                                   (64, 257, 16)])
@pytest.mark.parametrize("mode,coeffs", [
    ("poly", (0.5, -0.2, 0.1)),
    ("exp", (-0.7, 1.3)),
    ("expq", (-0.05, -0.2, 0.1)),
    ("rational", (0.8,)),
])
def test_fdist_matvec(a, b, d, mode, coeffs, rng):
    x = jnp.asarray(rng.uniform(0, 3, a), jnp.float32)
    y = jnp.asarray(rng.uniform(0, 3, b), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    cs = jnp.asarray(coeffs, jnp.float32)
    got = fdist_matvec_pallas(x, y, v, cs, mode=mode, blk_a=64, blk_b=64,
                              interpret=True)
    ref = fdist_matvec_ref(x, y, v, cs, mode)
    err = float(jnp.max(jnp.abs(got - ref))) / max(
        float(jnp.max(jnp.abs(ref))), 1e-9)
    assert err < 3e-6


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fdist_matvec_dtypes(dtype, rng):
    x = jnp.asarray(rng.uniform(0, 2, 128), jnp.float32)
    y = jnp.asarray(rng.uniform(0, 2, 96), jnp.float32)
    v = jnp.asarray(rng.normal(size=(96, 8)), dtype)
    cs = jnp.asarray([-0.5, 1.0], jnp.float32)
    got = fdist_matvec_pallas(x, y, v, cs, mode="exp", blk_a=32, blk_b=32,
                              interpret=True)
    ref = fdist_matvec_ref(x, y, v, cs, "exp")
    tol = 3e-6 if dtype == jnp.float32 else 3e-2
    err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    assert err < tol * max(float(jnp.max(jnp.abs(ref.astype(jnp.float32)))), 1)


@pytest.mark.parametrize("L,hd,blk", [(128, 32, 32), (256, 64, 64), (64, 16, 16)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(L, hd, blk, causal, rng):
    B, H = 2, 2
    q = jnp.asarray(rng.normal(size=(B, H, L, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, H, L, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, H, L, hd)), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=causal, blk_q=blk, blk_k=blk,
                                 interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    assert float(jnp.max(jnp.abs(got - ref))) < 2e-5


@pytest.mark.parametrize("L,din,N,chunk,blkd", [(64, 32, 8, 16, 16),
                                                (128, 64, 16, 32, 32)])
def test_selective_scan(L, din, N, chunk, blkd, rng):
    Bt = 2
    u = jnp.asarray(rng.normal(size=(Bt, L, din)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(Bt, L, din))) * 0.1, jnp.float32)
    A = jnp.asarray(-np.abs(rng.normal(size=(din, N))) - 0.1, jnp.float32)
    B = jnp.asarray(rng.normal(size=(Bt, L, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(Bt, L, N)), jnp.float32)
    D = jnp.asarray(rng.normal(size=(din,)), jnp.float32)
    got = selective_scan_pallas(u, dt, A, B, Cm, D, chunk=chunk, blk_d=blkd,
                                interpret=True)
    ref = selective_scan_ref(u, dt, A, B, Cm, D)
    assert float(jnp.max(jnp.abs(got - ref))) < 2e-5


@pytest.mark.parametrize("L,m,hd,chunk", [(128, 16, 32, 32), (64, 8, 8, 16)])
@pytest.mark.parametrize("lg", [0.0, -0.05])
def test_linear_attention(L, m, hd, chunk, lg, rng):
    B, H = 2, 3
    qf = jnp.asarray(np.abs(rng.normal(size=(B, H, L, m))), jnp.float32)
    kf = jnp.asarray(np.abs(rng.normal(size=(B, H, L, m))), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, H, L, hd)), jnp.float32)
    lgv = jnp.full((H,), lg, jnp.float32)
    num, den = linear_attention_pallas(qf, kf, v, lgv, chunk=chunk,
                                       interpret=True)
    rnum, rden = linear_attention_ref(qf, kf, v, lgv)
    assert float(jnp.max(jnp.abs(num - rnum))) / float(jnp.max(jnp.abs(rnum))) < 1e-5
    assert float(jnp.max(jnp.abs(den - rden))) / float(jnp.max(jnp.abs(rden))) < 1e-5


# ----------------------------------------------------------------------------
# shared ref-vs-ops parity fixture: every kernel family (including future
# ones added to _KERNEL_FAMILY_CASES) gets ops-layer parity coverage for free
# ----------------------------------------------------------------------------


def _case_fdist_matvec(rng):
    from repro.kernels.fdist_matvec.ops import fdist_matvec
    from repro.kernels.fdist_matvec.ref import fdist_matvec_ref

    x = jnp.asarray(rng.uniform(0, 3, 120), jnp.float32)
    y = jnp.asarray(rng.uniform(0, 3, 75), jnp.float32)
    v = jnp.asarray(rng.normal(size=(75, 6)), jnp.float32)
    cs = jnp.asarray([0.4, -0.3, 0.1], jnp.float32)
    return {"out": (fdist_matvec(x, y, v, cs, mode="poly", blk_a=32, blk_b=32),
                    fdist_matvec_ref(x, y, v, cs, "poly"))}


def _case_flash_attention(rng):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref

    q, k, v = (jnp.asarray(rng.normal(size=(1, 2, 64, 16)), jnp.float32)
               for _ in range(3))
    return {"out": (flash_attention(q, k, v, causal=True),
                    attention_ref(q, k, v, causal=True))}


def _case_linear_attention(rng):
    from repro.kernels.linear_attention.ops import linear_attention

    qf = jnp.asarray(np.abs(rng.normal(size=(1, 2, 64, 8))), jnp.float32)
    kf = jnp.asarray(np.abs(rng.normal(size=(1, 2, 64, 8))), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 64, 8)), jnp.float32)
    lg = jnp.asarray([-0.04, 0.0], jnp.float32)
    num, den = linear_attention(qf, kf, v, lg, chunk=16)
    rnum, rden = linear_attention_ref(qf, kf, v, lg)
    return {"num": (num, rnum), "den": (den, rden)}


def _case_selective_scan(rng):
    from repro.kernels.selective_scan.ops import selective_scan
    from repro.kernels.selective_scan.ref import selective_scan_ref

    u = jnp.asarray(rng.normal(size=(1, 64, 16)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(1, 64, 16))) * 0.1, jnp.float32)
    A = jnp.asarray(-np.abs(rng.normal(size=(16, 8))) - 0.1, jnp.float32)
    B = jnp.asarray(rng.normal(size=(1, 64, 8)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(1, 64, 8)), jnp.float32)
    D = jnp.asarray(rng.normal(size=(16,)), jnp.float32)
    return {"out": (selective_scan(u, dt, A, B, Cm, D, chunk=16, blk_d=16),
                    selective_scan_ref(u, dt, A, B, Cm, D))}


def _case_topo_linear_attention(rng):
    from repro.kernels.topo_linear_attention.ops import topo_linear_attention
    from repro.kernels.topo_linear_attention.ref import (
        topo_linear_attention_ref)

    qf = jnp.asarray(np.abs(rng.normal(size=(1, 2, 60, 6))), jnp.float32)
    kf = jnp.asarray(np.abs(rng.normal(size=(1, 2, 60, 6))), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 60, 8)), jnp.float32)
    cs = jnp.asarray([[0.1, -0.5, -0.2], [0.0, -0.3, -0.4]], jnp.float32)
    kw = dict(g="exp", dist_scale=1.0 / 60, causal=False)
    return {"out": (topo_linear_attention(qf, kf, v, cs, chunk=16,
                                          use_kernel=True, interpret=True,
                                          **kw),
                    topo_linear_attention_ref(qf, kf, v, cs, **kw))}


_KERNEL_FAMILY_CASES = {
    "fdist_matvec": _case_fdist_matvec,
    "flash_attention": _case_flash_attention,
    "linear_attention": _case_linear_attention,
    "selective_scan": _case_selective_scan,
    "topo_linear_attention": _case_topo_linear_attention,
}


@pytest.mark.parametrize("family", sorted(_KERNEL_FAMILY_CASES))
def test_kernel_family_ops_vs_ref(family, rng):
    """ops-layer entry point (interpret mode off-TPU) == pure-jnp oracle,
    one uniform check per kernel family."""
    for name, (got, ref) in _KERNEL_FAMILY_CASES[family](rng).items():
        got = jnp.asarray(got, jnp.float32)
        ref = jnp.asarray(ref, jnp.float32)
        scale = max(float(jnp.max(jnp.abs(ref))), 1e-6)
        err = float(jnp.max(jnp.abs(got - ref))) / scale
        assert err < 2e-5, (family, name, err)


def test_kernel_xla_equivalence(rng):
    """Pallas linear-attention kernel == the model's XLA chunked path."""
    from repro.models.attention import causal_linear_attention

    B, H, L, m, hd = 1, 2, 128, 16, 16
    qf = jnp.asarray(np.abs(rng.normal(size=(B, L, H, m))), jnp.float32)
    kf = jnp.asarray(np.abs(rng.normal(size=(B, L, H, m))), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, L, H, hd)), jnp.float32)
    lg = jnp.asarray([-0.03, 0.0], jnp.float32)
    num_x, den_x = causal_linear_attention(qf, kf, v, lg, chunk=32)
    num_p, den_p = linear_attention_pallas(
        qf.transpose(0, 2, 1, 3), kf.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), lg, chunk=32, interpret=True)
    assert float(jnp.max(jnp.abs(num_x.transpose(0, 2, 1, 3) - num_p))) < 1e-3
    assert float(jnp.max(jnp.abs(den_x.transpose(0, 2, 1) - den_p))) < 1e-3
