"""Sharding-rule unit tests (single device: specs only, no mesh compute)."""
import os
import subprocess
import sys


def test_sharding_specs_in_subprocess():
    """Rules produce divisibility-safe PartitionSpecs for every arch."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from jax.sharding import PartitionSpec as P
from repro.configs.base import ARCHS, get_config
from repro.launch import sharding as SH
from repro.launch.specs import params_shapes

mesh = jax.make_mesh((2, 4), ("data", "model"))
sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
for arch in ARCHS:
    if arch == "topovit_b16":
        continue
    cfg = get_config(arch)
    with SH.use_sharding(mesh):
        shapes = params_shapes(cfg)
        specs = SH.tree_param_specs(shapes)
        flat_s = jax.tree_util.tree_leaves_with_path(shapes)
        flat_p = jax.tree_util.tree_leaves(specs)
        n_sharded = 0
        for (path, leaf), spec in zip(flat_s, flat_p):
            for i, ax in enumerate(spec):
                if ax is None:
                    continue
                total = 1
                for a in (ax if isinstance(ax, tuple) else (ax,)):
                    total *= sizes[a]
                assert leaf.shape[i] % total == 0, (arch, path, leaf.shape, spec)
                n_sharded += 1
        assert n_sharded > 0, f"{arch}: nothing sharded"
print("SPECS_OK")
"""
    env = dict(os.environ, PYTHONPATH=os.path.abspath("src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert "SPECS_OK" in out.stdout, (out.stdout[-800:], out.stderr[-2000:])


def test_logical_rules_no_double_axis():
    """A mesh axis may appear at most once per spec (jax requirement)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.launch import sharding as SH

mesh = jax.make_mesh((2, 4), ("data", "model"))
with SH.use_sharding(mesh):
    # heads and ff both map to model; only the first position may take it
    spec = SH.logical_to_spec(("batch", "heads", "ff"))
    flat = []
    for ax in spec:
        flat += list(ax) if isinstance(ax, tuple) else ([ax] if ax else [])
    assert len(flat) == len(set(flat)), spec
print("NO_DOUBLE_OK")
"""
    env = dict(os.environ, PYTHONPATH=os.path.abspath("src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert "NO_DOUBLE_OK" in out.stdout, (out.stdout[-800:], out.stderr[-2000:])


def test_ftfi_logical_axes():
    """The FTFI plan axes resolve to the data axis (leaf blocks / cross
    groups / trees shard together), field_batch to the batch axes, and
    `plan_axis` survives meshes without a data axis."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from jax.sharding import PartitionSpec as P
from repro.launch import sharding as SH

for name in ("plan_leaves", "cross_src", "cross_tgt", "tree"):
    assert SH.DEFAULT_RULES[name] == "data", name
assert "data" in SH.DEFAULT_RULES["field_batch"]

mesh = jax.make_mesh((2, 4), ("data", "model"))
with SH.use_sharding(mesh):
    assert SH.logical_to_spec(("plan_leaves",)) == P("data")
    assert SH.logical_to_spec(("cross_src",)) == P("data")
    assert SH.logical_to_spec(("field_batch", None)) == P(("data",), None)
    # plan_leaves and cross_tgt both bind data: second occurrence drops
    spec = SH.logical_to_spec(("plan_leaves", "cross_tgt"))
    assert spec == P("data", None), spec
    assert SH.plan_axis() == "data"
assert SH.plan_axis(mesh) == "data"
m2 = jax.make_mesh((8,), ("model",))
assert SH.plan_axis(m2) == "model"  # no data axis: first axis fallback
print("FTFI_AXES_OK")
"""
    env = dict(os.environ, PYTHONPATH=os.path.abspath("src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert "FTFI_AXES_OK" in out.stdout, (out.stdout[-800:], out.stderr[-2000:])
