"""Fused prefill-into-cache + forest-masked continuous batching.

Covers the serving tentpole: (a) fused prefill is bit-identical to the
legacy decode-replay path under greedy argmax; (b) mid-wave admission into
freed slots reproduces single-slot outputs exactly (per-slot decode
positions); (c) faults in the fused prefill path retry deterministically;
(d) the silent-truncation and hung-request bugs stay fixed (truncated
marker, "engine stopped" errors); (e) per-request topological masks served
from ONE packed forest plan match per-request plans, across admission
repacks and incremental evictions, with every swap plan-guard validated.
"""
import numpy as np
import pytest
import jax

from repro.configs.base import get_smoke_config
from repro.core import plan_guard
from repro.core.masks import make_tree_fastmult
from repro.graphs.graph import random_tree
from repro.models import api
from repro.serve.engine import Request, ServeEngine
from repro.serve.forest_masks import ForestMaskManager, PlanRegistry
from repro.testing import faults


@pytest.fixture(autouse=True)
def _disarm_faults():
    faults.clear()
    yield
    faults.clear()


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_smoke_config("qwen2_1_5b").replace(dtype="float32")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=int(n)).tolist()
               for n in (3, 7, 5, 4, 6)]
    return cfg, params, prompts


@pytest.fixture(scope="module")
def topo_setup():
    cfg = get_smoke_config("qwen2_1_5b").replace(
        dtype="float32", attention_variant="topo")
    params = api.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=int(n)).tolist()
               for n in (5, 7)]
    trees = [random_tree(len(p), seed=i) for i, p in enumerate(prompts)]
    return cfg, params, prompts, trees


def _serve(cfg, params, reqs, **kw):
    eng = ServeEngine(cfg, params, **kw)
    for r in reqs:
        eng.submit(r)
    eng.run()
    return eng


# ----------------------------------------------------------------------------
# fused prefill == replay
# ----------------------------------------------------------------------------


def test_fused_matches_replay_bit_identical(dense_setup):
    cfg, params, prompts = dense_setup
    fused = [Request(rid=i, prompt=p, max_new_tokens=4)
             for i, p in enumerate(prompts[:3])]
    replay = [Request(rid=i, prompt=p, max_new_tokens=4)
              for i, p in enumerate(prompts[:3])]
    ef = _serve(cfg, params, fused, batch_slots=3, max_len=64,
                prefill_mode="fused")
    er = _serve(cfg, params, replay, batch_slots=3, max_len=64,
                prefill_mode="replay")
    for f, r in zip(fused, replay):
        assert f.done and f.error is None
        assert f.out == r.out  # greedy argmax: bit-identical token streams
    assert ef.stats()["prefill_calls"] >= 1
    assert ef.stats()["prefill_tokens"] == sum(len(p) for p in prompts[:3])
    assert er.stats()["prefill_calls"] == 0


def test_mid_wave_admission_matches_single_slot(dense_setup):
    """Five mixed-length prompts with staggered budgets through 2 slots:
    later requests admit mid-wave into whichever slot frees first, each
    decoding at its OWN position. Outputs must equal the single-slot runs."""
    cfg, params, prompts = dense_setup
    budgets = [4, 8, 4, 6, 3]
    refs = []
    for p, mn in zip(prompts, budgets):
        r = Request(rid=0, prompt=p, max_new_tokens=mn)
        _serve(cfg, params, [r], batch_slots=1, max_len=64)
        refs.append(list(r.out))
    reqs = [Request(rid=i, prompt=p, max_new_tokens=mn)
            for i, (p, mn) in enumerate(zip(prompts, budgets))]
    eng = _serve(cfg, params, reqs, batch_slots=2, max_len=64)
    for r, ref in zip(reqs, refs):
        assert r.done and r.error is None
        assert r.out == ref
    st = eng.stats()
    assert st["completed"] == 5 and st["failed"] == 0
    # staggered budgets force at least one admission into a mid-wave batch
    assert st["prefill_calls"] >= 3


def test_eos_as_first_generated_token(dense_setup):
    cfg, params, prompts = dense_setup
    probe = Request(rid=0, prompt=prompts[0], max_new_tokens=1)
    _serve(cfg, params, [probe], batch_slots=1, max_len=64)
    first = probe.out[0]
    r = Request(rid=0, prompt=prompts[0], max_new_tokens=8)
    eng = _serve(cfg, params, [r], batch_slots=1, max_len=64, eos_id=first)
    assert r.done and r.error is None and not r.truncated
    assert r.out == [first]  # EOS straight out of prefill: no decode ticks
    assert eng.stats()["completed"] == 1
    assert eng.stats()["decode_tokens"] == 0


# ----------------------------------------------------------------------------
# fault containment through the fused path
# ----------------------------------------------------------------------------


def test_prefill_crash_requeues_group_deterministically(dense_setup):
    cfg, params, prompts = dense_setup
    ref = Request(rid=0, prompt=prompts[0], max_new_tokens=4)
    _serve(cfg, params, [ref], batch_slots=1, max_len=64)
    r = Request(rid=0, prompt=prompts[0], max_new_tokens=4)
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=64)
    eng.submit(r)
    with faults.injected("serve.prefill", faults.raise_at_tick(1)):
        eng.run()
    st = eng.stats()
    assert r.done and r.error is None
    assert r.out == ref.out  # retried through prefill, bit-identical
    assert st["prefill_failures"] == 1 and st["retries"] == 1
    assert st["failed"] == 0


def test_nonfinite_prefill_logits_evict_only_that_slot(dense_setup):
    cfg, params, prompts = dense_setup
    refs = []
    for p in prompts[:2]:
        r = Request(rid=0, prompt=p, max_new_tokens=4)
        _serve(cfg, params, [r], batch_slots=1, max_len=64)
        refs.append(list(r.out))
    reqs = [Request(rid=i, prompt=p, max_new_tokens=4)
            for i, p in enumerate(prompts[:2])]
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=64)
    for r in reqs:
        eng.submit(r)
    with faults.injected("serve.prefill_logits",
                         faults.nan_slot_at_tick(slot=1, k=1)):
        eng.run()
    st = eng.stats()
    assert all(r.done and r.error is None for r in reqs)
    assert reqs[0].retries == 0 and reqs[1].retries == 1
    for r, ref in zip(reqs, refs):
        assert r.out == ref
    assert st["slot_faults"] == 1 and st["failed"] == 0


# ----------------------------------------------------------------------------
# silent truncation + hung requests (the bugfixes)
# ----------------------------------------------------------------------------


def test_cache_bound_truncation_is_marked(dense_setup):
    cfg, params, prompts = dense_setup
    S = 16
    r = Request(rid=0, prompt=prompts[1], max_new_tokens=32)  # 7 + 32 > 16
    eng = _serve(cfg, params, [r], batch_slots=1, max_len=S)
    assert r.done and r.error is None
    assert r.truncated is True
    assert len(r.out) == S - 1 - len(r.prompt) + 1  # stopped at the bound
    assert len(r.out) < r.max_new_tokens
    st = eng.stats()
    assert st["truncated"] == 1 and st["completed"] == 1
    assert "truncated=1" in eng.health_banner()


def test_full_answers_are_not_marked_truncated(dense_setup):
    cfg, params, prompts = dense_setup
    r = Request(rid=0, prompt=prompts[0], max_new_tokens=4)
    eng = _serve(cfg, params, [r], batch_slots=1, max_len=64)
    assert r.done and not r.truncated and eng.stats()["truncated"] == 0


def test_run_exhaustion_fails_inflight_and_queued(dense_setup):
    cfg, params, prompts = dense_setup
    inflight = Request(rid=0, prompt=prompts[0], max_new_tokens=32)
    queued = Request(rid=1, prompt=prompts[1], max_new_tokens=32)
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=64)
    eng.submit(inflight)
    eng.submit(queued)
    eng.run(max_ticks=2)
    for r in (inflight, queued):
        assert r.done and r.error is not None
        assert "engine stopped" in r.error and "max_ticks=2" in r.error
    st = eng.stats()
    assert st["stopped_inflight"] == 2 and st["failed"] == 2
    assert "stopped=2" in eng.health_banner()
    # the engine itself is still serviceable
    again = Request(rid=2, prompt=prompts[0], max_new_tokens=4)
    eng.submit(again)
    eng.run()
    assert again.done and again.error is None


def test_oversized_prompt_fails_cleanly(dense_setup):
    cfg, params, prompts = dense_setup
    rng = np.random.default_rng(3)
    big = Request(rid=0, prompt=rng.integers(
        0, cfg.vocab_size, size=16).tolist(), max_new_tokens=4)
    ok = Request(rid=1, prompt=prompts[0], max_new_tokens=4)
    eng = _serve(cfg, params, [big, ok], batch_slots=1, max_len=16)
    assert big.done and big.error is not None
    assert "prompt length 16 >= max_len 16" in big.error
    assert ok.done and ok.error is None
    assert eng.stats()["failed"] == 1 and eng.stats()["completed"] == 1


# ----------------------------------------------------------------------------
# forest-masked serving
# ----------------------------------------------------------------------------


def test_forest_packed_vs_per_request_plan_parity(topo_setup):
    """ONE packed two-tree forest prefill must match two per-request
    single-tree prefills to numerical noise (block-diagonal mask: zero
    cross-tree coupling)."""
    cfg, params, prompts, trees = topo_setup
    S, Lp, B = 32, 8, 2

    def masked_prefill(mgr, slots, batch, toks, lens):
        pack, unpack = mgr.pack_maps(Lp, slots, batch)
        tree_mask = {
            "make_fastmult": lambda coeffs: make_tree_fastmult(
                (mgr.spec, mgr.params), cfg.topo_g, coeffs,
                cfg.topo_dist_scale),
            "pack": jax.numpy.asarray(pack),
            "unpack": jax.numpy.asarray(unpack),
        }
        cache = api.init_cache(cfg, batch, S)
        logits, _ = api.prefill_into_cache(
            cfg, params, cache, jax.numpy.asarray(toks),
            jax.numpy.asarray(lens), S, tree_mask=tree_mask)
        return np.asarray(logits, np.float64)

    mgr = ForestMaskManager(B, leaf_size=4)
    mgr.admit(0, trees[0])
    mgr.admit(1, trees[1])
    toks = np.zeros((B, Lp), np.int32)
    lens = np.zeros((B,), np.int32)
    for i, p in enumerate(prompts):
        toks[i, :len(p)] = p
        lens[i] = len(p)
    packed = masked_prefill(mgr, [0, 1], B, toks, lens)
    for i, (p, t) in enumerate(zip(prompts, trees)):
        solo = ForestMaskManager(1, leaf_size=4)
        solo.admit(0, t)
        st = np.zeros((1, Lp), np.int32)
        st[0, :len(p)] = p
        single = masked_prefill(solo, [0], 1, st,
                                np.asarray([len(p)], np.int32))
        err = (np.max(np.abs(packed[i] - single[0]))
               / max(np.max(np.abs(single[0])), 1e-12))
        assert err <= 1e-5, f"row {i}: packed-vs-solo rel_err {err:.2e}"
    assert mgr.stats["swaps_validated"] >= 2


def test_tree_masked_serving_end_to_end(topo_setup):
    """Tree-masked requests through the engine: single-slot vs batched-
    with-membership-churn produce identical greedy tokens, and every plan
    swap went through the guard."""
    cfg, params, prompts, trees = topo_setup
    refs = []
    for p, t in zip(prompts, trees):
        r = Request(rid=0, prompt=p, max_new_tokens=4, tree=t)
        _serve(cfg, params, [r], batch_slots=1, max_len=32)
        refs.append(list(r.out))
    reqs = [Request(rid=i, prompt=p, max_new_tokens=mn, tree=t)
            for i, (p, t, mn) in enumerate(zip(prompts, trees, (3, 4)))]
    eng = _serve(cfg, params, reqs, batch_slots=2, max_len=32)
    for r, ref in zip(reqs, refs):
        assert r.done and r.error is None
        assert r.out == ref[:r.max_new_tokens]
    fm = eng.stats()["forest_masks"]
    assert fm["builds"] >= 1 and fm["swaps_validated"] >= fm["builds"]


def test_mask_manager_incremental_eviction():
    trees = [random_tree(n, seed=n) for n in (5, 7, 6)]
    mgr = ForestMaskManager(3, leaf_size=4)
    for s, t in enumerate(trees):
        mgr.admit(s, t)
    offsets_before = mgr.slot_offset.copy()
    mgr.evict(1)
    assert mgr.stats["incremental_evictions"] == 1
    assert plan_guard.check_spec(mgr.spec, mgr.params) == []
    # survivors keep their packed offsets (ghost rows stay allocated)
    assert mgr.slot_offset[0] == offsets_before[0]
    assert mgr.slot_offset[2] == offsets_before[2]
    assert mgr.slot_offset[1] == -1
    ghosts = mgr.spec.ghosts
    assert ghosts is not None and len(ghosts) == trees[1].num_vertices - 1
    pack, unpack = mgr.pack_maps(8, [0, 2], 3)
    assert (pack >= 0).sum() == trees[0].num_vertices + trees[2].num_vertices
    mgr.evict(0)
    mgr.evict(2)
    assert mgr.spec is None and not mgr.any_active()


def test_plan_registry_roundtrip_and_sha_serving(tmp_path, topo_setup):
    cfg, params, prompts, trees = topo_setup
    reg = PlanRegistry(tmp_path / "reg", leaf_size=4)
    sha = reg.put(trees[0])
    assert reg.put(trees[0]) == sha  # idempotent
    spec, pp = reg.resolve(sha)  # validated load
    assert spec.fingerprint[:12] == sha
    t2 = reg.resolve_tree(sha)
    assert t2.num_vertices == trees[0].num_vertices
    by_tree = Request(rid=0, prompt=prompts[0], max_new_tokens=4,
                      tree=trees[0])
    _serve(cfg, params, [by_tree], batch_slots=1, max_len=32)
    by_sha = Request(rid=0, prompt=prompts[0], max_new_tokens=4,
                     plan_sha=sha)
    _serve(cfg, params, [by_sha], batch_slots=1, max_len=32,
           registry=str(tmp_path / "reg"))
    assert by_sha.done and by_sha.error is None
    assert by_sha.out == by_tree.out


def test_tree_request_rejected_on_non_topo_engine(dense_setup):
    cfg, params, prompts = dense_setup
    r = Request(rid=0, prompt=prompts[0], max_new_tokens=4,
                tree=random_tree(len(prompts[0]), seed=0))
    eng = _serve(cfg, params, [r], batch_slots=1, max_len=32)
    assert r.done and r.error is not None
    assert "attention_variant='topo'" in r.error
    assert eng.stats()["failed"] == 1


def test_plan_sha_without_registry_rejected(topo_setup):
    cfg, params, prompts, _ = topo_setup
    r = Request(rid=0, prompt=prompts[0], max_new_tokens=4,
                plan_sha="deadbeef0123")
    eng = _serve(cfg, params, [r], batch_slots=1, max_len=32)
    assert r.done and "no plan registry" in r.error
    assert eng.stats()["failed"] == 1
