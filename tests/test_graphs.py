"""Graph substrate: MST, traversals, meshes."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.graphs.graph import Graph, random_tree, synthetic_graph
from repro.graphs.meshes import icosphere, mesh_graph, torus_mesh, vertex_normals
from repro.graphs.mst import minimum_spanning_tree
from repro.graphs.traverse import (TreeLCA, dijkstra, graph_all_pairs,
                                   tree_all_pairs, tree_distances_from,
                                   tree_pair_distances)


def test_mst_weight_matches_bruteforce(rng):
    # tiny graph: compare against exhaustive spanning-tree minimum via
    # Prim-from-scratch cross check (same weight, possibly different tree)
    g = synthetic_graph(30, 40, seed=3)
    mst = minimum_spanning_tree(g)
    assert mst.num_edges == g.num_vertices - 1
    # Prim reference
    indptr, indices, data = g.csr()
    import heapq
    seen = {0}
    heap = [(data[e], indices[e]) for e in range(indptr[0], indptr[1])]
    heapq.heapify(heap)
    total = 0.0
    while len(seen) < g.num_vertices:
        w, v = heapq.heappop(heap)
        if v in seen:
            continue
        seen.add(v)
        total += w
        for e in range(indptr[v], indptr[v + 1]):
            if indices[e] not in seen:
                heapq.heappush(heap, (data[e], indices[e]))
    assert abs(total - mst.weights.sum()) < 1e-9


def test_tree_all_pairs_vs_single_source(rng):
    tree = random_tree(60, seed=5)
    D = tree_all_pairs(tree)
    assert np.allclose(D, D.T)
    assert np.allclose(np.diag(D), 0.0)
    for s in [0, 13, 59]:
        assert np.allclose(D[s], tree_distances_from(tree, s))


def test_lca_pair_distances(rng):
    tree = random_tree(80, seed=6)
    D = tree_all_pairs(tree)
    us = rng.integers(0, 80, 50)
    vs = rng.integers(0, 80, 50)
    got = tree_pair_distances(tree, us, vs)
    assert np.allclose(got, D[us, vs])


def test_dijkstra_on_tree_equals_tree_distance():
    tree = random_tree(70, seed=8)
    assert np.allclose(dijkstra(tree, 3), tree_distances_from(tree, 3))


def test_meshes():
    for verts, faces in [icosphere(2), torus_mesh(16, 8)]:
        vn = vertex_normals(verts, faces)
        assert np.allclose(np.linalg.norm(vn, axis=1), 1.0, atol=1e-6)
        g = mesh_graph(verts, faces)
        assert g.num_edges > g.num_vertices  # meshes have cycles
        mst = minimum_spanning_tree(g)
        assert mst.num_edges == g.num_vertices - 1
    # icosphere normals point outward (== vertex direction for a sphere)
    verts, faces = icosphere(2)
    vn = vertex_normals(verts, faces)
    assert np.mean(np.sum(vn * verts, axis=1)) > 0.9


@settings(max_examples=10, deadline=None)
@given(n=st.integers(10, 60), extra=st.integers(5, 30), seed=st.integers(0, 1000))
def test_mst_distances_upper_bound_graph(n, extra, seed):
    """Tree metric dominates the graph metric (spanning subgraph)."""
    g = synthetic_graph(n, extra, seed=seed)
    mst = minimum_spanning_tree(g)
    Dg = graph_all_pairs(g)
    Dt = tree_all_pairs(mst)
    assert (Dt + 1e-9 >= Dg).all()


def test_frt_tree_dominates_and_integrates(rng):
    """FRT tree metric dominates the graph metric; FTFI runs on it exactly."""
    from repro.core import Exponential
    from repro.core.integrate import BTFI
    from repro.graphs.frt import frt_integrate, frt_tree

    g = synthetic_graph(80, 50, seed=2)
    t, leaf = frt_tree(g, seed=1)
    Dg = graph_all_pairs(g)
    Dt = tree_all_pairs(t)[np.ix_(leaf, leaf)]
    assert (Dt + 1e-9 >= Dg).all()
    off = ~np.eye(80, dtype=bool)
    assert np.mean(Dt[off] / np.maximum(Dg[off], 1e-12)) < 30  # O(log n)-ish

    X = rng.normal(size=(80, 2))
    fn = Exponential(-0.5)
    got = frt_integrate(g, fn, X, seed=1, leaf_size=16)
    Xf = np.zeros((t.num_vertices, 2))
    Xf[leaf] = X
    ref = BTFI(t).integrate(fn, Xf)[leaf]
    assert np.max(np.abs(got - ref)) < 1e-8
