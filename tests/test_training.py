"""Training substrate: loop convergence, compression, watchdog, optimizer."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import get_smoke_config
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.optim.compress import compress_grads, compressor_init
from repro.train.loop import StragglerWatchdog, TrainLoopConfig, run_training


def test_adamw_quadratic_convergence():
    params = {"w": jnp.asarray([3.0, -2.0])}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                      total_steps=300, clip_norm=10.0)
    state = adamw_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(g, state, params, cfg)
    assert float(loss(params)) < 1e-3


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(cosine_schedule(jnp.asarray(s), cfg)) for s in range(101)]
    assert lrs[0] < 0.2 and abs(lrs[10] - 1.0) < 1e-6
    assert abs(lrs[100] - 0.1) < 1e-6
    assert all(a >= b - 1e-9 for a, b in zip(lrs[10:], lrs[11:]))  # decay


def test_compression_error_feedback_converges():
    """int8 EF compression still drives the quadratic to zero."""
    params = {"w": jnp.asarray(np.linspace(-2, 2, 16), jnp.float32)}
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=1,
                      total_steps=500, clip_norm=100.0)
    state = adamw_init(params)
    cstate = compressor_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(500):
        g = jax.grad(loss)(params)
        g, cstate = compress_grads(g, cstate)
        params, state, _ = adamw_update(g, state, params, cfg)
    assert float(loss(params)) < 1e-2


def test_training_loss_decreases(tmp_path):
    cfg = get_smoke_config("llama3_2_1b").replace(dtype="float32")
    loop = TrainLoopConfig(steps=150, batch_size=8, seq_len=64,
                           ckpt_dir=str(tmp_path / "ck"), ckpt_every=1000,
                           log_every=1000)
    opt = AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=150,
                      weight_decay=0.0)
    res = run_training(cfg, loop, opt, verbose=False)
    first = np.mean(res["losses"][:5])
    last = np.mean(res["losses"][-5:])
    assert last < first - 0.3, f"{first} -> {last}"


def test_training_with_microbatches_and_compression(tmp_path):
    cfg = get_smoke_config("qwen2_1_5b").replace(dtype="float32")
    loop = TrainLoopConfig(steps=10, batch_size=4, seq_len=32, microbatches=2,
                           ckpt_dir=str(tmp_path / "ck"), ckpt_every=50,
                           compress_grads=True, log_every=100)
    res = run_training(cfg, loop, verbose=False)
    assert np.isfinite(res["losses"]).all()


def test_straggler_watchdog():
    wd = StragglerWatchdog(factor=2.0, warmup=3)
    for s in range(10):
        wd.observe(s, 0.1)
    assert wd.observe(10, 0.5)  # 5x the EMA -> flagged
    assert wd.events and wd.events[-1][0] == 10
    assert not wd.observe(11, 0.11)
