"""Vectorized plan assembly vs the per-node loop oracle.

`_assemble_plan` (level-wide numpy array ops over the FlatIT) must be
BITWISE identical to `_assemble_plan_ref` (the original per-internal-node
Python loop, kept in-tree as the oracle): same buckets in the same order,
same padded arrays, same flat gather/segment/scatter plans, same update
tables. The battery sweeps topologies x leaf sizes x expand_groups and the
fused forest path.
"""
import dataclasses
import hashlib

import numpy as np
import pytest

from repro.core.integrate import _assemble_plan, _assemble_plan_ref
from repro.core.itree_flat import build_flat_forest, build_flat_it
from repro.graphs.graph import (Forest, WeightedTree, caterpillar_tree,
                                path_graph, random_tree, star_tree)


def _mix(h, x):
    if x is None:
        h.update(b"\x00none")
    elif isinstance(x, np.ndarray):
        a = np.ascontiguousarray(x)
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    elif isinstance(x, dict):
        for k in sorted(x):
            h.update(str(k).encode())
            _mix(h, x[k])
    elif isinstance(x, (list, tuple)):
        h.update(f"[{len(x)}".encode())
        for v in x:
            _mix(h, v)
    elif dataclasses.is_dataclass(x):
        for f in dataclasses.fields(x):
            h.update(f.name.encode())
            _mix(h, getattr(x, f.name))
    else:
        h.update(repr(x).encode())


def plan_digest(plan) -> str:
    """Content hash over EVERY dataclass field of an IntegrationPlan
    (buckets, flat index arrays, provenance, rw/upd tables)."""
    h = hashlib.sha1()
    _mix(h, plan)
    return h.hexdigest()


def _trees():
    cases = [
        ("path12", path_graph(12)),
        ("path100", path_graph(100)),
        ("star40", star_tree(40, seed=3)),
        ("caterpillar64", caterpillar_tree(64, seed=1)),
        ("two", WeightedTree(2, [0], [1], [0.5])),
    ]
    cases += [(f"random{n}s{s}", random_tree(n, seed=s))
              for n, s in ((30, 0), (77, 1), (128, 2), (200, 5))]
    return cases


@pytest.mark.parametrize("leaf_size", [4, 8, 64])
@pytest.mark.parametrize("expand_groups", [False, True])
def test_vectorized_assembly_bitwise_equals_oracle(leaf_size, expand_groups):
    for name, tree in _trees():
        flat = build_flat_it(tree, leaf_size=leaf_size, use_cache=False)
        ref = _assemble_plan_ref(flat, tree.num_vertices,
                                 detect_grid_spacing=not expand_groups,
                                 expand_groups=expand_groups)
        got = _assemble_plan(flat, tree.num_vertices,
                             detect_grid_spacing=not expand_groups,
                             expand_groups=expand_groups)
        assert plan_digest(got) == plan_digest(ref), (
            f"{name}: vectorized assembly diverges from the loop oracle "
            f"(leaf_size={leaf_size}, expand_groups={expand_groups})")


@pytest.mark.parametrize("expand_groups", [False, True])
def test_forest_assembly_bitwise_equals_oracle(expand_groups):
    rng = np.random.default_rng(4)
    trees = [random_tree(int(s), seed=i)
             for i, s in enumerate(rng.integers(6, 40, size=9))]
    trees.append(path_graph(25))
    n = sum(t.num_vertices for t in trees)
    flat = build_flat_forest(trees, leaf_size=8, use_cache=False)
    ref = _assemble_plan_ref(flat, n, detect_grid_spacing=not expand_groups,
                             expand_groups=expand_groups)
    got = _assemble_plan(flat, n, detect_grid_spacing=not expand_groups,
                         expand_groups=expand_groups)
    assert plan_digest(got) == plan_digest(ref)


def test_update_tables_shapes_and_consistency():
    """The upd tables must index every cross job and leaf: job j lives at
    (job_bucket[j], job_row[j]) with matching pivot, and the IT skeleton's
    refs cover exactly the internal nodes + leaves."""
    tree = random_tree(90, seed=7)
    flat = build_flat_it(tree, leaf_size=8, use_cache=False)
    plan = _assemble_plan(flat, 90, detect_grid_spacing=False,
                          expand_groups=True)
    upd = plan.upd
    I = plan.pivots.shape[0]
    assert upd["children"].shape == (I, 2)
    assert upd["job_bucket"].shape == (2 * I,)
    assert upd["job_row"].shape == (2 * I,)
    assert upd["leaf_bucket"].shape == (flat.num_leaves,)
    for j in range(2 * I):
        bi, row = int(upd["job_bucket"][j]), int(upd["job_row"][j])
        cb = plan.cross_buckets[bi]
        assert 0 <= row < cb.tgt_d.shape[0]
        assert int(cb.piv[row]) == int(plan.pivots[j // 2])
    for li in range(flat.num_leaves):
        bi, row = int(upd["leaf_bucket"][li]), int(upd["leaf_row"][li])
        lb = plan.leaf_buckets[bi]
        assert 0 <= row < lb.ids.shape[0]
    # the skeleton reaches every internal node and every leaf exactly once
    seen_nodes, seen_leaves = set(), set()
    stack = list(upd["root_refs"])
    while stack:
        ref = int(stack.pop())
        if ref < 0:
            seen_leaves.add(-ref - 1)
        else:
            assert ref not in seen_nodes
            seen_nodes.add(ref)
            stack += [int(upd["children"][ref, 0]),
                      int(upd["children"][ref, 1])]
    assert seen_nodes == set(range(I))
    assert seen_leaves == set(range(flat.num_leaves))


def test_assembly_plans_execute_identically():
    """Belt and braces on top of the digest: both plans integrate to the
    same output through the real executor."""
    from repro.core import plan_api
    from repro.core.cordial import Exponential

    tree = random_tree(64, seed=9)
    flat = build_flat_it(tree, leaf_size=8, use_cache=False)
    rng = np.random.default_rng(0)
    X = rng.normal(size=(64, 3)).astype(np.float32)
    outs = []
    for assemble in (_assemble_plan_ref, _assemble_plan):
        plan = assemble(flat, 64, detect_grid_spacing=True)
        plan.tree_sizes = (64,)
        spec, params = plan_api.specialize(plan)
        outs.append(np.asarray(plan_api.apply(
            spec, params, Exponential(-0.5), X)))
    np.testing.assert_array_equal(outs[0], outs[1])
