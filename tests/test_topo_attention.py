"""Cross-impl parity + gradient harness for topological attention.

Sweeps {causal, bidirectional} x {exp deg<=1, general deg 2-3} x {synced,
per-head} x odd shapes (L not a multiple of the kernel block, H != KV) over
the three sequence impls (ref / fft / pallas), checks the fused Pallas kernel
in interpret mode against the dense oracle, gradcheck's d(loss)/d(mask
scalars) through every impl, and asserts decode cordial states reproduce
train prefill token-by-token.  Marker: `topo` (CI shard: pytest -m topo).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.configs.base import ModelConfig
from repro.kernels.topo_linear_attention.ops import topo_linear_attention
from repro.kernels.topo_linear_attention.ref import topo_linear_attention_ref
from repro.models import attention as A

pytestmark = pytest.mark.topo

IMPLS = ("ref", "fft", "pallas")


def _cfg(L, g="exp", degree=1, synced=True, H=2, KV=None, impl="fft",
         hd=8):
    return ModelConfig(
        name="topo-test", family="dense", num_layers=1, d_model=H * hd,
        num_heads=H, num_kv_heads=KV or H, head_dim=hd, d_ff=16,
        vocab_size=64, attention_variant="topo", performer_phi="relu",
        topo_g=g, topo_degree=degree, topo_synced=synced,
        topo_dist_scale=1.0 / L, topo_attn_impl=impl, dtype="float32")


def _topo_params(cfg, seed, spread=0.5):
    """attn + topo params with randomized (non-degenerate) mask scalars."""
    r = np.random.default_rng(seed)
    p = A.attn_init(jax.random.PRNGKey(seed), cfg)
    p_topo = A.topo_init(jax.random.PRNGKey(seed + 1), cfg)
    lead = () if cfg.topo_synced else (cfg.num_heads,)
    p_topo = {
        "coeffs": jnp.asarray(
            r.uniform(-spread, spread, lead + (cfg.topo_degree + 1,)),
            jnp.float32),
        "logit_scale": jnp.asarray(r.uniform(-0.3, 0.3, lead), jnp.float32),
    }
    return p, p_topo


def _run(cfg, impl, p, p_topo, x, causal):
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    return A.topo_attention_train(cfg.replace(topo_attn_impl=impl), p,
                                  p_topo, x, positions, causal=causal)


# ----------------------------------------------------------------------------
# model-level impl parity sweep
# ----------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10**6), L=st.integers(33, 80),
       causal=st.booleans(), dmode=st.integers(0, 2), perhead=st.booleans(),
       gqa=st.booleans())
def test_impl_parity_sweep(seed, L, causal, dmode, perhead, gqa):
    """ref / fft / pallas agree <= 1e-3 across the full parity matrix.
    L in [33, 80) is deliberately not a multiple of any kernel block; gqa
    exercises H != KV (grouped KV expansion before the mask)."""
    degree = [1, 2, 3][dmode]
    H = 4 if gqa else 2
    cfg = _cfg(L, degree=degree, synced=not perhead, H=H,
               KV=(2 if gqa else None))
    p, p_topo = _topo_params(cfg, seed)
    r = np.random.default_rng(seed + 7)
    x = jnp.asarray(r.normal(size=(2, L, cfg.d_model)) * 0.5, jnp.float32)
    outs = {impl: _run(cfg, impl, p, p_topo, x, causal) for impl in IMPLS}
    scale = float(jnp.max(jnp.abs(outs["ref"]))) + 1e-6
    for impl in ("fft", "pallas"):
        err = float(jnp.max(jnp.abs(outs[impl] - outs["ref"]))) / scale
        assert err <= 1e-3, (impl, degree, causal, perhead, gqa, err)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10**6), L=st.integers(17, 50),
       causal=st.booleans(), dmode=st.integers(0, 2), perhead=st.booleans())
def test_pallas_kernel_interpret_parity(seed, L, causal, dmode, perhead):
    """The Pallas kernel body itself (interpret mode, so it runs anywhere)
    matches the dense oracle and its XLA twin on odd L with chunk 16."""
    g, degree = [("exp", 1), ("exp", 2), ("identity", 2)][dmode]
    H, m, hd = 2, 4, 8
    r = np.random.default_rng(seed)
    qf = jnp.asarray(np.abs(r.normal(size=(1, H, L, m))), jnp.float32)
    kf = jnp.asarray(np.abs(r.normal(size=(1, H, L, m))), jnp.float32)
    v = jnp.asarray(r.normal(size=(1, H, L, hd)), jnp.float32)
    shape = (H, degree + 1) if perhead else (degree + 1,)
    cs = r.uniform(-0.5, 0.5, shape).astype(np.float32)
    cs[..., 0] = r.uniform(1.5, 2.5, shape[:-1])  # keep f (and den) positive
    cs = jnp.asarray(cs)
    ref = topo_linear_attention_ref(
        qf, kf, v, jnp.broadcast_to(jnp.atleast_2d(cs), (H, degree + 1)),
        g=g, dist_scale=1.0 / L, causal=causal)
    kw = dict(g=g, dist_scale=1.0 / L, causal=causal, chunk=16)
    ker = topo_linear_attention(qf, kf, v, cs, use_kernel=True,
                                interpret=True, **kw)
    twin = topo_linear_attention(qf, kf, v, cs, use_kernel=False, **kw)
    scale = float(jnp.max(jnp.abs(ref))) + 1e-6
    assert float(jnp.max(jnp.abs(ker - ref))) / scale <= 1e-3
    assert float(jnp.max(jnp.abs(twin - ref))) / scale <= 1e-3
    assert float(jnp.max(jnp.abs(ker - twin))) / scale <= 1e-4


def test_vit_grid_impl_parity(rng):
    """The ViT grid path rides the impl axis too: ref (dense tree mask
    oracle) == plan-backed Alg. 1 (fft) == the pallas fdist executor."""
    from repro.configs.base import get_smoke_config
    from repro.models import vit

    cfg = get_smoke_config("topovit_b16").replace(dtype="float32")
    params = vit.init_params(cfg, jax.random.PRNGKey(0), num_classes=10,
                             patch_dim=32)
    patches = jnp.asarray(
        rng.normal(size=(2, cfg.num_prefix_embeddings, 32)), jnp.float32)
    outs = {}
    for impl in IMPLS:
        c = cfg.replace(topo_attn_impl=impl)
        outs[impl] = vit.forward(c, params, patches,
                                 vit.build_grid_integrator(c))
    scale = float(jnp.max(jnp.abs(outs["ref"]))) + 1e-6
    for impl in ("fft", "pallas"):
        err = float(jnp.max(jnp.abs(outs[impl] - outs["ref"]))) / scale
        assert err <= 1e-3, (impl, err)


# ----------------------------------------------------------------------------
# decode cordial states == train prefill, token by token
# ----------------------------------------------------------------------------


@pytest.mark.parametrize("degree,impl", [(1, "fft"), (1, "pallas"),
                                         (2, "fft"), (2, "pallas")])
def test_decode_matches_prefill_tokenwise(degree, impl, rng):
    L = 24
    cfg = _cfg(L, degree=degree, impl=impl)
    p, p_topo = _topo_params(cfg, seed=3)
    x = jnp.asarray(rng.normal(size=(2, L, cfg.d_model)) * 0.5, jnp.float32)
    train = _run(cfg, impl, p, p_topo, x, causal=True)  # (B, L, d)
    cache = A.topo_decode_init(cfg, 2, L)
    tol = 2e-3 if degree <= 1 else 6e-3  # deg>=2 decode: Chebyshev rank-24
    for t in range(L):
        out, cache = A.topo_attention_decode(cfg, p, p_topo, x[:, t:t + 1],
                                             t, cache, L=L)
        step = float(jnp.max(jnp.abs(out[:, 0] - train[:, t])))
        scale = float(jnp.max(jnp.abs(train[:, t]))) + 1e-6
        assert step / scale <= tol, (impl, degree, t, step / scale)


# ----------------------------------------------------------------------------
# gradients: finite-difference gradcheck through every impl
# ----------------------------------------------------------------------------


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("degree,causal", [(1, True), (2, False)])
def test_gradcheck_mask_scalars(impl, degree, causal, rng):
    """d(loss)/d(raw topo coeffs + logit_scale) via jax.grad matches central
    finite differences for every impl (the pallas impl differentiates through
    its custom-VJP XLA twin)."""
    L = 20
    cfg = _cfg(L, degree=degree, impl=impl)
    p, p_topo = _topo_params(cfg, seed=11)
    x = jnp.asarray(rng.normal(size=(1, L, cfg.d_model)) * 0.5, jnp.float32)
    w = jnp.asarray(rng.normal(size=(1, L, cfg.d_model)), jnp.float32)

    def loss(pt):
        return jnp.mean(w * _run(cfg, impl, p, pt, x, causal))

    grads = jax.grad(loss)(p_topo)
    h = 3e-3
    for key in ("coeffs", "logit_scale"):
        flat = np.asarray(p_topo[key]).reshape(-1)
        gflat = np.asarray(grads[key]).reshape(-1)
        for i in range(flat.size):
            e = np.zeros_like(flat)
            e[i] = h
            pert = lambda sgn: dict(
                p_topo, **{key: jnp.asarray((flat + sgn * e).reshape(
                    np.asarray(p_topo[key]).shape))})
            fd = (float(loss(pert(+1))) - float(loss(pert(-1)))) / (2 * h)
            ref_scale = max(abs(fd), float(np.max(np.abs(gflat))), 1e-4)
            assert abs(gflat[i] - fd) / ref_scale < 7e-2, (impl, key, i)


def test_mask_scalars_receive_gradient(rng):
    """Every one of the 3 learnable mask scalars gets a nonzero gradient
    (logit_scale was historically initialized but never wired in)."""
    L = 16
    cfg = _cfg(L, degree=1, impl="fft")
    p, p_topo = _topo_params(cfg, seed=5)
    x = jnp.asarray(rng.normal(size=(1, L, cfg.d_model)) * 0.5, jnp.float32)

    def loss(pt):
        out = _run(cfg, "fft", p, pt, x, causal=True)
        return jnp.mean(jnp.square(out))

    g = jax.grad(loss)(p_topo)
    assert float(jnp.max(jnp.abs(g["coeffs"]))) > 0.0
    assert float(jnp.max(jnp.abs(g["logit_scale"]))) > 0.0


def test_train_smoke_mask_scalars_move(tmp_path):
    """20-step train/loop.py smoke on synthetic data: loss decreases and the
    topo mask scalars (coeffs + logit_scale) actually move."""
    from repro.models import api
    from repro.optim.adamw import AdamWConfig
    from repro.train.loop import TrainLoopConfig, run_training

    cfg = ModelConfig(
        name="topo-smoke", family="dense", num_layers=2, d_model=32,
        num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64, vocab_size=128,
        attention_variant="topo", performer_phi="relu", topo_g="exp",
        topo_degree=1, topo_synced=True, topo_dist_scale=1.0 / 32,
        dtype="float32", tie_embeddings=True)
    loop = TrainLoopConfig(steps=20, batch_size=4, seq_len=32,
                           ckpt_dir=str(tmp_path / "ckpt"), ckpt_every=20,
                           log_every=50, seed=0)
    opt = AdamWConfig(lr=3e-3, total_steps=20, warmup_steps=2)
    init = api.init_params(cfg, jax.random.PRNGKey(loop.seed))
    res = run_training(cfg, loop, opt, verbose=False)
    losses = res["losses"]
    assert float(np.mean(losses[-5:])) < float(np.mean(losses[:5]))

    def topo_leaves(params):
        out = {}
        def walk(node, path):
            if isinstance(node, dict):
                for k_, v_ in node.items():
                    walk(v_, path + (k_,))
            elif "topo" in path:
                out[path] = np.asarray(node)
        walk(params, ())
        return out

    before, after = topo_leaves(init), topo_leaves(res["params"])
    assert before, "topo params missing from the dense topo model"
    for path, b in before.items():
        delta = float(np.max(np.abs(after[path] - b)))
        assert delta > 1e-5, f"mask scalar {path} did not move ({delta})"


# ----------------------------------------------------------------------------
# fft-path regressions
# ----------------------------------------------------------------------------


def test_fft_path_stays_fp32_on_bf16_inputs(rng):
    """No silent fp32->bf16 downcast inside the chunked fft path: bf16
    features must be upcast once and accumulated in fp32."""
    cfg = _cfg(32, degree=2)
    B, L, H, m, hd = 1, 32, cfg.num_heads, 8, 8
    qf32 = jnp.asarray(np.abs(rng.normal(size=(B, L, H, m))), jnp.float32)
    kf32 = jnp.asarray(np.abs(rng.normal(size=(B, L, H, m))), jnp.float32)
    v32 = jnp.asarray(rng.normal(size=(B, L, H, hd)), jnp.float32)
    coeffs = jnp.asarray([[0.1, -0.4, -0.2]] * H, jnp.float32)
    ref = A._topo_fft_attention(cfg, qf32, kf32, v32, coeffs, causal=True)
    got = A._topo_fft_attention(cfg, qf32.astype(jnp.bfloat16),
                                kf32.astype(jnp.bfloat16),
                                v32.astype(jnp.bfloat16), coeffs, causal=True)
    assert got.dtype == jnp.float32
    scale = float(jnp.max(jnp.abs(ref))) + 1e-6
    assert float(jnp.max(jnp.abs(got - ref))) / scale < 3e-2  # bf16 inputs


def test_bidirectional_diagonal_counted_once(rng):
    """Regression: the separable bidirectional path subtracts the diagonal
    (counted by both the forward and backward sweeps) exactly once."""
    L = 28
    cfg = _cfg(L, degree=1)
    p, p_topo = _topo_params(cfg, seed=9)
    x = jnp.asarray(rng.normal(size=(2, L, cfg.d_model)) * 0.5, jnp.float32)
    got = _run(cfg, "fft", p, p_topo, x, causal=False)
    ref = _run(cfg, "ref", p, p_topo, x, causal=False)
    scale = float(jnp.max(jnp.abs(ref))) + 1e-6
    assert float(jnp.max(jnp.abs(got - ref))) / scale <= 1e-3
