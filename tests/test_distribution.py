"""Distribution integration tests on an 8-fake-device CPU mesh (subprocess,
so the device-count flag never leaks into the main test session)."""
import os
import subprocess
import sys

import pytest

_ENV = lambda: dict(os.environ, PYTHONPATH=os.path.abspath("src"))


def _run(code: str, timeout=560):
    env = _ENV()
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    return out


def test_sharded_train_step_matches_single_device():
    """pjit train step on a (2,4) mesh == the same step on 1 device."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import get_smoke_config
from repro.launch import sharding as SH
from repro.launch.steps import make_train_step
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.models import api

cfg = get_smoke_config("llama3_2_1b").replace(dtype="float32")
ocfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10, weight_decay=0.0)
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32)}
params = api.init_params(cfg, jax.random.PRNGKey(0))
opt = adamw_init(params)
step = make_train_step(cfg, ocfg)

# single device reference
p1, o1, m1 = jax.jit(step)(params, opt, batch)

mesh = jax.make_mesh((2, 4), ("data", "model"))
with SH.use_sharding(mesh):
    pspecs = SH.tree_param_specs(params)
    pshard = jax.tree.map(SH.named_sharding, pspecs)
    params_s = jax.device_put(params, pshard)
    opt_s = adamw_init(params_s)
    batch_s = jax.device_put(batch, {"tokens": NamedSharding(mesh, P("data", None))})
    p2, o2, m2 = jax.jit(step)(params_s, opt_s, batch_s)

assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4, (m1["loss"], m2["loss"])
d = max(float(jnp.max(jnp.abs(a - b))) for a, b in
        zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
assert d < 1e-4, d
print("DIST_TRAIN_OK", float(m1["loss"]))
"""
    out = _run(code)
    assert "DIST_TRAIN_OK" in out.stdout, (out.stdout[-1500:], out.stderr[-3000:])


@pytest.mark.parametrize("arch", ["deepseek_v2_lite_16b", "falcon_mamba_7b"])
def test_sharded_smoke_archs(arch):
    """MoE (expert-parallel dispatch) and SSM smoke configs lower + run on
    the 8-device mesh; loss matches the 1-device value."""
    code = rf"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import get_smoke_config
from repro.launch import sharding as SH
from repro.models import api

cfg = get_smoke_config("{arch}").replace(dtype="float32")
rng = np.random.default_rng(0)
batch = {{"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32)}}
params = api.init_params(cfg, jax.random.PRNGKey(0))
l1 = float(api.loss_fn(cfg, params, batch)[0])
mesh = jax.make_mesh((2, 4), ("data", "model"))
with SH.use_sharding(mesh):
    pshard = jax.tree.map(SH.named_sharding, SH.tree_param_specs(params))
    params_s = jax.device_put(params, pshard)
    batch_s = jax.device_put(batch, {{"tokens": NamedSharding(mesh, P("data", None))}})
    l2 = float(jax.jit(lambda p, b: api.loss_fn(cfg, p, b)[0])(params_s, batch_s))
assert abs(l1 - l2) < 1e-3, (l1, l2)
print("DIST_ARCH_OK", l1)
"""
    out = _run(code)
    assert "DIST_ARCH_OK" in out.stdout, (out.stdout[-1500:], out.stderr[-3000:])


def test_topovit_pjit_sharded_topo_path():
    """TopoViT forward under pjit with cfg.topo_shard_plan=True: the grid
    plan executor runs under shard_map on the (2,4) mesh, logits match the
    single-device forward, and the forward jaxpr shows exactly the sharded
    executor's collectives — halo all_to_all + reduce_scatter, never an
    all-gather of the field or the plan index arrays."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import get_smoke_config
from repro.launch import sharding as SH
from repro.models import vit

cfg = get_smoke_config("topovit_b16").replace(dtype="float32")
integ = vit.build_grid_integrator(cfg)
params = vit.init_params(cfg, jax.random.PRNGKey(0), num_classes=10,
                         patch_dim=48)
rng = np.random.default_rng(0)
patches = jnp.asarray(rng.normal(size=(4, cfg.num_prefix_embeddings, 48)),
                      jnp.float32)
ref = vit.forward(cfg, params, patches, integ)

cfg_s = cfg.replace(topo_shard_plan=True)
mesh = jax.make_mesh((2, 4), ("data", "model"))
with SH.use_sharding(mesh):
    fwd = lambda p, x: vit.forward(cfg_s, p, x, integ)
    # structured census (repro.analysis): each of the 2 layers runs 2 mask
    # fastmults (numerator + denominator), each with the two-collective
    # discipline — and never an all_gather of the field or the index arrays
    from repro.analysis import jaxpr_audit
    rep = jaxpr_audit.assert_clean(
        fwd, params, patches, name="topovit.sharded",
        budget={"collectives": {"all_to_all": 4, "psum_scatter": 4}})
    assert rep.collectives == {"all_to_all": 4, "reduce_scatter": 4}, rep.collectives
    assert rep.prim_counts.get("shard_map", 0) >= 1, "topo path not under shard_map"
    patches_s = jax.device_put(
        patches, NamedSharding(mesh, P("data", None, None)))
    out = jax.jit(fwd)(params, patches_s)
d = float(jnp.max(jnp.abs(out - ref)))
assert d < 1e-4, d

# grads (incl. the 3 mask scalars) survive the sharded path
with SH.use_sharding(mesh):
    g = jax.jit(jax.grad(lambda p, x: jnp.sum(fwd(p, x) ** 2)))(
        params, patches_s)
gsum = sum(float(jnp.sum(jnp.abs(x)))
           for x in jax.tree.leaves(g["blocks"]["topo"]))
assert np.isfinite(gsum) and gsum > 0
print("TOPOVIT_PJIT_OK", d)
"""
    out = _run(code)
    assert "TOPOVIT_PJIT_OK" in out.stdout, (out.stdout[-1500:],
                                             out.stderr[-3000:])


def test_topolm_sharded_train_step():
    """Topological-LM pjit train step on the (2,4) mesh == 1 device: the
    topo attention path's field_batch/heads shard constraints compose with
    the standard param rules."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import get_smoke_config
from repro.launch import sharding as SH
from repro.launch.steps import make_train_step
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.models import api

cfg = get_smoke_config("llama3_2_1b").replace(
    dtype="float32", attention_variant="topo", topo_attn_impl="fft")
ocfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10, weight_decay=0.0)
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)),
                               jnp.int32)}
params = api.init_params(cfg, jax.random.PRNGKey(0))
opt = adamw_init(params)
step = make_train_step(cfg, ocfg)
p1, o1, m1 = jax.jit(step)(params, opt, batch)

mesh = jax.make_mesh((2, 4), ("data", "model"))
with SH.use_sharding(mesh):
    pshard = jax.tree.map(SH.named_sharding, SH.tree_param_specs(params))
    params_s = jax.device_put(params, pshard)
    opt_s = adamw_init(params_s)
    batch_s = jax.device_put(
        batch, {"tokens": NamedSharding(mesh, P("data", None))})
    p2, o2, m2 = jax.jit(step)(params_s, opt_s, batch_s)

assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3, (m1["loss"],
                                                           m2["loss"])
d = max(float(jnp.max(jnp.abs(a - b))) for a, b in
        zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
assert d < 1e-3, d
print("TOPOLM_DIST_OK", float(m1["loss"]))
"""
    out = _run(code)
    assert "TOPOLM_DIST_OK" in out.stdout, (out.stdout[-1500:],
                                            out.stderr[-3000:])


def test_dryrun_cell_small_mesh():
    """The dry-run machinery itself (lower+compile+roofline terms) on a tiny
    mesh with a smoke config — exercises analyze-cell wiring end to end."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.configs.base import get_smoke_config
from repro.launch import sharding as SH
from repro.launch.dryrun import lower_cell_cfg
from repro.roofline.analysis import collective_bytes_from_hlo

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = get_smoke_config("llama3_2_1b")
# smoke decode cell: shrink the assigned shape via a fake SHAPES entry
from repro.configs import base
base.SHAPES["tiny_train"] = dict(seq_len=64, global_batch=8, kind="train")
lowered, compiled, _, _ = lower_cell_cfg(cfg, "tiny_train", mesh)
mem = compiled.memory_analysis()
cost = compiled.cost_analysis()
if isinstance(cost, (list, tuple)):  # jax < 0.5 returns [dict]
    cost = cost[0] if cost else {}
coll = collective_bytes_from_hlo(compiled.as_text())
assert cost.get("flops", 0) > 0
assert coll > 0, "expected collectives on a (2,4) mesh"
print("DRYRUN_OK", cost["flops"], coll)
"""
    out = _run(code)
    assert "DRYRUN_OK" in out.stdout, (out.stdout[-1500:], out.stderr[-3000:])
