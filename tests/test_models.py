"""Per-arch smoke tests (reduced configs): forward + one train step on CPU,
shape and finiteness assertions; decode-path consistency checks."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import ARCHS, get_smoke_config
from repro.models import api
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

SMOKE_ARCHS = [a for a in ARCHS if a != "topovit_b16"]


def _batch(cfg, rng, B=2, L=32):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, L)),
                                   jnp.int32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_prefix_embeddings, 1024)), jnp.float32)
    if cfg.is_encdec:
        batch["src_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.max_source_len, 1024)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", SMOKE_ARCHS)
def test_smoke_forward_and_train_step(arch, rng):
    cfg = get_smoke_config(arch).replace(dtype="float32")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    loss, metrics = api.loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss)) and float(loss) > 0
    # one optimizer step moves the loss
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=1e-2, warmup_steps=1, total_steps=10,
                       weight_decay=0.0)
    grads = jax.grad(lambda p: api.loss_fn(cfg, p, batch)[0])(params)
    params2, opt, m = adamw_update(grads, opt, params, ocfg)
    assert float(m["grad_norm"]) > 0
    loss2, _ = api.loss_fn(cfg, params2, batch)
    assert np.isfinite(float(loss2))
    assert float(loss2) < float(loss)


@pytest.mark.parametrize("arch", SMOKE_ARCHS)
def test_smoke_decode(arch, rng):
    cfg = get_smoke_config(arch).replace(dtype="float32")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    S, B = 40, 2
    cache = api.init_cache(cfg, B, S)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
    for pos in range(3):
        logits, cache = api.decode_fn(cfg, params, cache, tok,
                                      jnp.asarray(pos, jnp.int32), S)
    assert logits.shape == (B, 1, cfg.padded_vocab())
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("variant", ["performer", "topo"])
def test_attention_variants(variant, rng):
    cfg = get_smoke_config("llama3_2_1b").replace(
        dtype="float32", attention_variant=variant, topo_dist_scale=1.0 / 40)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    loss, _ = api.loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ["falcon_mamba_7b", "recurrentgemma_2b",
                                  "qwen2_1_5b"])
def test_decode_matches_prefill_logits(arch, rng):
    """Streaming decode over a prompt == teacher-forced forward logits."""
    cfg = get_smoke_config(arch).replace(dtype="float32")
    params = api.init_params(cfg, jax.random.PRNGKey(1))
    B, L = 1, 12
    toks = rng.integers(0, cfg.vocab_size, (B, L)).astype(np.int32)
    # full forward logits at last position
    from repro.models import lm
    full_logits = lm.forward_prefill(cfg, params, {"tokens": jnp.asarray(toks)})
    # streaming decode
    cache = api.init_cache(cfg, B, L + 4)
    for pos in range(L):
        logits, cache = api.decode_fn(cfg, params, cache,
                                      jnp.asarray(toks[:, pos:pos + 1]),
                                      jnp.asarray(pos, jnp.int32), L + 4)
    diff = float(jnp.max(jnp.abs(logits - full_logits)))
    scale = float(jnp.max(jnp.abs(full_logits))) + 1e-6
    assert diff / scale < 5e-3, f"decode/prefill mismatch: {diff/scale}"


def test_topo_decode_matches_prefill(rng):
    """The paper-variant decode (cordial states) == its prefill logits."""
    cfg = get_smoke_config("llama3_2_1b").replace(
        dtype="float32", attention_variant="topo", topo_degree=1,
        topo_dist_scale=1.0 / 16)
    params = api.init_params(cfg, jax.random.PRNGKey(2))
    B, L = 1, 12
    toks = rng.integers(0, cfg.vocab_size, (B, L)).astype(np.int32)
    from repro.models import lm
    full_logits = lm.forward_prefill(cfg, params, {"tokens": jnp.asarray(toks)})
    cache = api.init_cache(cfg, B, L)
    for pos in range(L):
        logits, cache = api.decode_fn(cfg, params, cache,
                                      jnp.asarray(toks[:, pos:pos + 1]),
                                      jnp.asarray(pos, jnp.int32), L)
    diff = float(jnp.max(jnp.abs(logits - full_logits)))
    scale = float(jnp.max(jnp.abs(full_logits))) + 1e-6
    assert diff / scale < 5e-3


def test_moe_dispatch_matches_dense_experts(rng):
    """Sort-based capacity dispatch == explicit per-token expert compute
    (with capacity large enough that nothing drops)."""
    from repro.models.moe import moe_block, moe_init

    cfg = get_smoke_config("deepseek_v2_lite_16b").replace(
        dtype="float32", capacity_factor=8.0)
    key = jax.random.PRNGKey(3)
    p = moe_init(key, cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)), jnp.float32)
    out, aux = moe_block(cfg, p, x)
    # dense reference: route every token through its top-k experts explicitly
    xt = np.asarray(x).reshape(-1, cfg.d_model)
    logits = xt @ np.asarray(p["router"])
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    order = np.argsort(-probs, axis=-1)[:, :cfg.top_k]

    def expert(e, xv):
        a = xv @ p["experts_w_gate"][e]
        b = xv @ p["experts_w_in"][e]
        return (jax.nn.silu(a) * b) @ p["experts_w_out"][e]

    ref = np.zeros_like(xt)
    for t in range(xt.shape[0]):
        g = probs[t, order[t]]
        g = g / g.sum()
        for kk, e in enumerate(order[t]):
            ref[t] += g[kk] * np.asarray(expert(int(e), jnp.asarray(xt[t])))
        a = xt[t] @ np.asarray(p["shared_w_gate"])
        b = xt[t] @ np.asarray(p["shared_w_in"])
        ref[t] += np.asarray((jax.nn.silu(jnp.asarray(a)) * b)
                             @ p["shared_w_out"])
    got = np.asarray(out).reshape(-1, cfg.d_model)
    assert np.max(np.abs(got - ref)) < 1e-3


def test_mla_decode_matches_train_attention(rng):
    """Absorbed-matmul MLA decode == the naive train-path attention."""
    cfg = get_smoke_config("deepseek_v3_671b").replace(dtype="float32")
    from repro.models import attention as A

    p = A.mla_init(jax.random.PRNGKey(4), cfg, jnp.float32)
    B, L = 1, 10
    x = jnp.asarray(rng.normal(size=(B, L, cfg.d_model)) * 0.1, jnp.float32)
    positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32)[None], (B, L))
    ref = A.mla_attention_train(cfg, p, x, positions, causal=True)
    cache = {"ckv": jnp.zeros((B, L, cfg.kv_lora_rank), jnp.float32),
             "krope": jnp.zeros((B, L, cfg.qk_rope_dim), jnp.float32)}
    outs = []
    for pos in range(L):
        y, cache = A.mla_attention_decode(cfg, p, x[:, pos:pos + 1],
                                          jnp.asarray(pos, jnp.int32), cache)
        outs.append(y)
    got = jnp.concatenate(outs, axis=1)
    assert float(jnp.max(jnp.abs(got - ref))) < 1e-4


def test_param_counts_full_configs():
    """Full configs match their nameplate sizes (within tolerance)."""
    import jax

    from repro.configs.base import get_config
    from repro.roofline.analysis import count_params

    expected = {
        "falcon_mamba_7b": (7.3e9, 0.15),
        "llama3_2_1b": (1.3e9, 0.2),
        "qwen2_1_5b": (1.6e9, 0.25),
        "gemma_7b": (8.5e9, 0.15),
        # the assignment specifies "llama-arch" (gated 3-matrix MLP) with
        # these dims -> 47B; the real granite-34b-code is gpt-bigcode with a
        # 2-matrix MLP at 34B. We follow the assignment's arch directive.
        "granite_34b": (47e9, 0.15),
        "llava_next_34b": (34e9, 0.15),
        "deepseek_v2_lite_16b": (16e9, 0.2),
        "deepseek_v3_671b": (671e9, 0.15),
    }
    for arch, (target, tol) in expected.items():
        total, active = count_params(get_config(arch))
        assert abs(total - target) / target < tol, (
            f"{arch}: {total/1e9:.2f}B vs nameplate {target/1e9:.0f}B")
        assert active <= total
