"""The analyzer analyzed: every pass must flag its synthetic known-bad
program and pass every registered clean entry point.

Five violation fixtures (the acceptance matrix):
  1. hidden all_gather on a shard_map path      -> jaxpr_audit collective
  2. f64 constant / f64 compute                 -> jaxpr_audit wide_dtype
  3. ~12 MB float array baked into the trace    -> jaxpr_audit big_const
  4. int64 PlanSpec index array                 -> plan_guard dtype check
  5. retracing closure on a stable entry point  -> trace_guard RetraceError
plus the AST lint's frozen-field mutation (and friends).
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.ftfi as ftfi
from repro.analysis import jaxpr_audit, lint, trace_guard
from repro.analysis import entry_points, runner
from repro.core import cordial as C
from repro.core import plan_guard
from repro.graphs.graph import random_tree


def _kinds(rep):
    return {f.kind for f in rep.findings}


# ---------------------------------------------------------------------------
# fixture 1: hidden collective
# ---------------------------------------------------------------------------


def test_hidden_all_gather_flagged():
    """An all_gather smuggled into a shard_map body is a structured
    collective finding naming the primitive — even on a 1-device mesh,
    where the string would also appear but wall-clock tests never notice."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((1,), ("i",))

    def fwd(x):
        def body(xs):
            return jax.lax.all_gather(xs, "i", tiled=True)

        return shard_map(body, mesh=mesh, in_specs=P("i"), out_specs=P(),
                         check_rep=False)(x)

    rep = jaxpr_audit.audit(fwd, jnp.ones((8, 2)), name="bad.allgather",
                            budget={"collectives": {}})
    assert not rep.ok
    assert "collective" in _kinds(rep)
    assert any("all_gather" in f.detail for f in rep.findings), rep.summary()
    # the declared-budget path: the same program is CLEAN if the gather is
    # budgeted, so intentional collectives never fight the gate
    rep2 = jaxpr_audit.audit(fwd, jnp.ones((8, 2)), name="ok.allgather",
                             budget={"collectives": {"all_gather": 1}})
    assert rep2.ok, rep2.summary()


def test_wrong_collective_count_flagged():
    """A second psum where the budget declares one is a count mismatch, not
    a pass — exact census, both directions."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((1,), ("i",))

    def fwd(x):
        def body(xs):
            a = jax.lax.psum(xs, "i")
            return a + jax.lax.psum(xs * 2, "i")

        return shard_map(body, mesh=mesh, in_specs=P("i"), out_specs=P("i"))(x)

    rep = jaxpr_audit.audit(fwd, jnp.ones((8,)), name="bad.count",
                            budget={"collectives": {"psum": 1}})
    assert not rep.ok and "collective" in _kinds(rep), rep.summary()


# ---------------------------------------------------------------------------
# fixture 2: f64 leak
# ---------------------------------------------------------------------------


def test_f64_leak_flagged():
    """Under x64, a float64 constant (and the f64 compute it forces) is a
    wide_dtype finding; the same program audits clean in f32."""
    with jax.experimental.enable_x64():
        big = jnp.asarray(np.random.default_rng(0).standard_normal((4, 64)))
        assert big.dtype == jnp.float64

        def fwd(x):
            return (x @ big.T).sum()

        rep = jaxpr_audit.audit(fwd, jnp.ones((2, 64), jnp.float64),
                                name="bad.f64", budget={})
    assert not rep.ok
    assert "wide_dtype" in _kinds(rep), rep.summary()
    assert any("float64" in f.detail for f in rep.findings)


def test_int64_compute_flagged():
    with jax.experimental.enable_x64():
        def fwd(x):
            return x.astype(jnp.int64) + 1

        rep = jaxpr_audit.audit(fwd, jnp.ones((4,), jnp.int32),
                                name="bad.i64", budget={})
    assert not rep.ok and "wide_dtype" in _kinds(rep), rep.summary()


# ---------------------------------------------------------------------------
# fixture 3: weights traced as constants
# ---------------------------------------------------------------------------


def test_captured_big_array_flagged():
    """A ~12 MB float array riding the closure (instead of the arg list) is
    the classic silent retrace/memory bug; the report names the size."""
    W = jnp.asarray(np.zeros((3_000_000,), np.float32))  # 12 MB

    def fwd(x):
        return x * W.sum()

    rep = jaxpr_audit.audit(fwd, jnp.ones((4,)), name="bad.const", budget={})
    assert not rep.ok
    assert "big_const" in _kinds(rep), rep.summary()
    assert any("12000000" in f.detail for f in rep.findings), rep.summary()
    # int32 plan index arrays of the same size are NOT the weights bug:
    # only the float-const gate fires at this threshold
    idx = jnp.asarray(np.zeros((3_000_000,), np.int32))
    rep2 = jaxpr_audit.audit(lambda x: x * idx.sum(), jnp.ones((4,), jnp.int32),
                             name="ok.idxconst", budget={})
    assert rep2.ok, rep2.summary()


def test_callback_flagged():
    def fwd(x):
        jax.debug.print("x={}", x)
        return x + 1

    rep = jaxpr_audit.audit(fwd, jnp.ones((2,)), name="bad.debug", budget={})
    assert not rep.ok and "callback" in _kinds(rep), rep.summary()


# ---------------------------------------------------------------------------
# fixture 4: int64 index arrays (the day-one violation, now fixed)
# ---------------------------------------------------------------------------


def test_plan_spec_index_arrays_are_int32():
    """Freshly built plans (incl. the update/reweight tables) carry int32
    indices end-to-end — the auditor's day-one finding, fixed at source."""
    spec, params = ftfi.build(random_tree(64, seed=0), reweightable=True,
                              use_cache=False)
    assert plan_guard.check_index_dtypes(spec) == []
    assert spec.children.dtype == np.int32
    assert spec.root_refs.dtype == np.int32
    assert plan_guard.check_spec(spec, params) == []


def test_int64_index_array_flagged_and_coerced(tmp_path):
    # > leaf_size vertices so the plan has cross jobs (non-empty src_gather)
    spec, params = ftfi.build(random_tree(200, seed=1), use_cache=False)
    bad = dataclasses.replace(spec,
                              src_gather=spec.src_gather.astype(np.int64))
    issues = plan_guard.check_spec(bad)
    assert any("src_gather" in i and "int64" in i for i in issues), issues

    fixed, coerced = plan_guard.coerce_index_dtypes(bad)
    assert coerced == ["src_gather"]
    assert fixed.src_gather.dtype == np.int32
    assert plan_guard.check_spec(fixed) == []

    # an out-of-range value is a corrupt artifact, never a silent wrap
    evil = dataclasses.replace(
        spec, src_gather=spec.src_gather.astype(np.int64) + 2**40)
    with pytest.raises(plan_guard.PlanValidationError, match="int32"):
        plan_guard.coerce_index_dtypes(evil)


def test_load_plan_canonicalizes_old_int64_artifacts(tmp_path):
    """Artifacts saved before schema 4 carried int64 update tables;
    load_plan downcasts them (bounds-guarded) so every consumer sees the
    canonical int32 layout."""
    spec, params = ftfi.build(random_tree(48, seed=2), use_cache=False)
    old = dataclasses.replace(spec,
                              children=spec.children.astype(np.int64),
                              root_refs=spec.root_refs.astype(np.int64))
    path = tmp_path / "old.npz"
    ftfi.save_plan(path, old, params)
    spec2, params2 = ftfi.load_plan(path)
    assert spec2.children.dtype == np.int32
    assert spec2.root_refs.dtype == np.int32
    X = np.random.default_rng(0).standard_normal((48, 2)).astype(np.float32)
    a = ftfi.apply(spec, params, C.Exponential(-0.5), X)
    b = ftfi.apply(spec2, params2, C.Exponential(-0.5), X)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


# ---------------------------------------------------------------------------
# fixture 5: retracing closure
# ---------------------------------------------------------------------------


def test_retrace_sentinel_fires():
    """A closure that retraces per call (shape-keyed here) trips
    expect_stable with the per-site compile delta in the error."""
    site = "test.retrace_fixture"

    @jax.jit
    def f(x):
        trace_guard.record(site)
        return x * 2

    f(jnp.ones((4,)))
    with pytest.raises(trace_guard.RetraceError, match=site):
        with trace_guard.expect_stable(site):
            f(jnp.ones((5,)))  # new shape -> retrace

    # stable workload passes the same gate
    with trace_guard.expect_stable(site):
        f(jnp.ones((4,)))
        f(jnp.ones((5,)))


def test_retrace_budget_check():
    site = "test.budgeted_fixture"
    for _ in range(3):
        trace_guard.record(site)
    issues = trace_guard.check({site: 2})
    assert issues and "3x" in issues[0] and site in issues[0], issues
    assert trace_guard.check({site: 3}) == []


def test_ftfi_fastmult_declared_stable():
    """The instrumented production site: repeated jitted calls with stable
    shapes never retrace; a changed field width is one (allowed) recompile."""
    spec, params = ftfi.build(random_tree(48, seed=3), use_cache=False)
    fm = jax.jit(ftfi.fastmult(spec, C.Exponential(-0.5)))
    rng = np.random.default_rng(0)
    X = rng.standard_normal((48, 2)).astype(np.float32)
    fm(params, X)
    with trace_guard.expect_stable("ftfi.fastmult"):
        for _ in range(3):
            fm(params, X)
    with trace_guard.expect_stable("ftfi.fastmult", max_compiles=1):
        X3 = rng.standard_normal((48, 3)).astype(np.float32)
        fm(params, X3)
        fm(params, X3)


# ---------------------------------------------------------------------------
# lint fixtures
# ---------------------------------------------------------------------------


def test_lint_frozen_mutation_flagged():
    src = (
        "def patch(spec, x):\n"
        "    spec.pivots = x\n"
        "    object.__setattr__(spec, 'src_gather', x)\n"
    )
    errs = lint.check_source(src, "src/repro/core/patcher.py")
    rules = [e.rule for e in errs]
    assert rules.count("frozen-mutation") == 2, errs
    assert errs[0].line == 2

    # noqa suppresses, and plan_api.py itself may __setattr__ (digest memo)
    src_ok = src.replace("spec.pivots = x",
                         "spec.pivots = x  # noqa: repro-lint")
    errs2 = lint.check_source(src_ok, "src/repro/core/plan_api.py")
    assert errs2 == [], errs2


def test_lint_legacy_np_random_flagged():
    errs = lint.check_source(
        "import numpy as np\n"
        "a = np.random.randn(4)\n"
        "rng = np.random.default_rng(0)\n"
        "b = rng.standard_normal(4)\n",
        "src/repro/models/foo.py")
    assert [e.rule for e in errs] == ["legacy-np-random"], errs
    assert errs[0].line == 2


def test_lint_traced_host_read_flagged():
    src = (
        "import jax.numpy as jnp\n"
        "def f(x):\n"
        "    s = float(jnp.sum(x))\n"
        "    t = x.item()\n"
        "    return s + t\n"
    )
    errs = lint.check_source(src, "src/repro/core/bad.py")
    assert [e.rule for e in errs] == ["traced-host-read"] * 2, errs
    # the same host reads are legal outside the traced subpackages
    assert lint.check_source(src, "src/repro/launch/ok.py") == []


def test_lint_x64_flip_flagged():
    errs = lint.check_source(
        "import jax\n"
        "jax.config.update('jax_enable_x64', True)\n",
        "src/repro/core/bad64.py")
    assert [e.rule for e in errs] == ["x64-flip"], errs
    # tests may flip freely
    assert lint.check_source(
        "import jax\njax.config.update('jax_enable_x64', True)\n",
        "tests/test_something.py") == []


def test_lint_clean_on_repo_src():
    out = runner.run_lint()
    assert out["issues"] == [], out["issues"][:10]


# ---------------------------------------------------------------------------
# clean entry points + budget coverage
# ---------------------------------------------------------------------------


def test_budgets_cover_every_registered_entry_point():
    budgets = runner.load_budgets()
    declared = set(budgets["entry_points"])
    registered = set(entry_points.REGISTRY)
    assert registered <= declared, (
        f"entries missing from ANALYSIS_BUDGETS.json: "
        f"{sorted(registered - declared)}")


@pytest.mark.parametrize("section", ["core", "kernels", "serve"])
def test_clean_entry_points_pass(section):
    """Every registered entry point audits clean against its declared
    budget (sharded/models sections ride the CI static-analysis job and the
    subprocess distribution tests — too slow for tier-1)."""
    budgets = runner.load_budgets()
    out = runner.run_audits(budgets, sections=[section])
    assert out["issues"] == [], out["issues"]
    assert out["reports"], f"no entry points audited for section {section}"
    for rep in out["reports"]:
        assert rep["ok"], rep


def test_audit_walks_nested_call_eqns():
    """The walker recurses through pjit/scan/cond rather than reading the
    pretty-printed string: a collective hidden two levels down is found."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((1,), ("i",))

    def fwd(x):
        def body(xs):
            def step(c, t):
                return c + jax.lax.psum(t, "i"), t

            out, _ = jax.lax.scan(step, jnp.zeros_like(xs[0]), xs)
            return out

        return shard_map(body, mesh=mesh, in_specs=P(None, "i"),
                         out_specs=P("i"), check_rep=False)(x)

    rep = jaxpr_audit.audit(jax.jit(fwd), jnp.ones((4, 1)),
                            name="nested", budget={"collectives": {}})
    assert rep.collectives.get("psum", 0) >= 1, rep.prim_counts
    assert not rep.ok and "collective" in _kinds(rep)
