"""Topological masking: Algorithm 1, Toeplitz fastmult, cordial decode."""
import numpy as np
import pytest
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.core import masks as MK
from repro.core.toeplitz import (causal_toeplitz_matvec,
                                 symmetric_toeplitz_matvec, toeplitz_dense)


@settings(max_examples=15, deadline=None)
@given(L=st.integers(4, 96), d=st.integers(1, 5), seed=st.integers(0, 10**6),
       causal=st.booleans())
def test_toeplitz_fastmult_property(L, d, seed, causal):
    r = np.random.default_rng(seed)
    F = jnp.asarray(r.normal(size=L), jnp.float32)
    V = jnp.asarray(r.normal(size=(L, d)), jnp.float32)
    M = toeplitz_dense(F, L, causal=causal)
    ref = M @ V
    got = (causal_toeplitz_matvec if causal else symmetric_toeplitz_matvec)(F, V)
    assert float(jnp.max(jnp.abs(got - ref))) < 1e-4 * max(
        1.0, float(jnp.max(jnp.abs(ref))))


@pytest.mark.parametrize("g,coeffs", [("exp", [0.1, -0.4]),
                                      ("exp", [0.0, -0.2, -0.1]),
                                      ("identity", [1.0, 0.3, 0.05]),
                                      ("recip", [0.0, 1.0])])
def test_algorithm1_vs_bruteforce(g, coeffs, rng):
    L, d, m = 64, 8, 6
    qf = jnp.asarray(np.abs(rng.normal(size=(2, L, m))), jnp.float32)
    kf = jnp.asarray(np.abs(rng.normal(size=(2, L, m))), jnp.float32)
    V = jnp.asarray(rng.normal(size=(2, L, d)), jnp.float32)
    cs = jnp.asarray(coeffs, jnp.float32)
    fm = MK.make_sequence_fastmult(g, cs, L, causal=True, dist_scale=1 / L)
    got = MK.masked_linear_attention(qf, kf, V, fm)
    Fv = MK.sequence_mask_values(g, cs, L, 1 / L)
    mask = toeplitz_dense(Fv, L, causal=True)
    ref = MK.masked_attention_bruteforce(qf, kf, V, mask)
    assert float(jnp.max(jnp.abs(got - ref))) < 1e-4


@pytest.mark.parametrize("g,coeffs", [("exp", [0.1, -0.4]),
                                      ("identity", [1.0, 0.3, 0.05])])
def test_cordial_decode_equals_prefill(g, coeffs, rng):
    L, d, m = 48, 4, 6
    qf = jnp.asarray(np.abs(rng.normal(size=(2, L, m))), jnp.float32)
    kf = jnp.asarray(np.abs(rng.normal(size=(2, L, m))), jnp.float32)
    V = jnp.asarray(rng.normal(size=(2, L, d)), jnp.float32)
    cs = np.asarray(coeffs, np.float32)
    Fv = MK.sequence_mask_values(g, jnp.asarray(cs), L, 1 / L)
    ref = MK.masked_attention_bruteforce(qf, kf, V,
                                         toeplitz_dense(Fv, L, causal=True))
    dec = MK.cordial_decomposition(g, cs, dist_scale=1 / L)
    state = MK.decode_state_init(dec, m, d, batch_shape=(2,))
    outs = []
    for t in range(L):
        state = MK.decode_state_update(dec, state, t, kf[:, t], V[:, t])
        outs.append(MK.decode_state_read(dec, state, t, qf[:, t]))
    got = jnp.stack(outs, axis=1)
    assert float(jnp.max(jnp.abs(got - ref))) < 2e-4


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 10**6), L=st.integers(3, 40),
       dmode=st.integers(0, 3), perhead=st.booleans())
def test_cordial_decode_property(seed, L, dmode, perhead):
    """Property (satellite): decode_state_init/update/read reproduces
    masked_attention_bruteforce TOKEN-BY-TOKEN for every exactly-separable
    family — g="exp" with deg <= 1 and g="identity" polynomials — with both
    synced and per-head (asynced) coefficient batches."""
    g, T = [("exp", 0), ("exp", 1), ("identity", 1), ("identity", 2)][dmode]
    r = np.random.default_rng(seed)
    H, m, d = 2, 3, 4
    shape = (H, T + 1) if perhead else (T + 1,)
    coeffs = r.uniform(-0.6, 0.6, size=shape).astype(np.float32)
    # keep f positive (identity masks must stay away from zero denominators)
    coeffs[..., 0] = r.uniform(1.5, 2.5, size=shape[:-1])
    dist_scale = 1.0 / L
    qf = jnp.asarray(np.abs(r.normal(size=(H, L, m))), jnp.float32)
    kf = jnp.asarray(np.abs(r.normal(size=(H, L, m))), jnp.float32)
    V = jnp.asarray(r.normal(size=(H, L, d)), jnp.float32)

    # per-head dense causal mask oracle
    cs = coeffs if perhead else np.broadcast_to(coeffs, (H, T + 1))
    diff = (np.arange(L)[:, None] - np.arange(L)[None, :]) * dist_scale
    z = np.zeros((H, L, L))
    for t in range(T, -1, -1):
        z = z * diff[None] + cs[:, t][:, None, None]
    f = np.exp(z) if g == "exp" else z
    mask = jnp.asarray(f * np.tril(np.ones((L, L))), jnp.float32)
    ref = MK.masked_attention_bruteforce(qf, kf, V, mask)

    dec = MK.cordial_decomposition(g, coeffs, dist_scale=dist_scale)
    state = MK.decode_state_init(dec, m, d, batch_shape=(H,))
    for t in range(L):
        state = MK.decode_state_update(dec, state, t, kf[:, t], V[:, t])
        out = MK.decode_state_read(dec, state, t, qf[:, t])
        step_ref = ref[:, t]
        tol = 5e-4 * max(1.0, float(jnp.max(jnp.abs(step_ref))))
        assert float(jnp.max(jnp.abs(out - step_ref))) < tol, (g, T, t)


def test_chebyshev_separable_decode(rng):
    """Non-separable mask (g=exp, degree 2): the Chebyshev rank-R expansion
    decodes streaming with spectral accuracy (beyond-paper, DESIGN §3)."""
    from repro.configs.base import get_smoke_config
    from repro.models import attention as A

    cfg = get_smoke_config("llama3_2_1b").replace(
        dtype="float32", attention_variant="topo", topo_degree=2,
        topo_dist_scale=1.0 / 48, topo_synced=True)
    coeffs = jnp.asarray(np.array([[0.1, -1.2, -0.7]] * cfg.num_heads),
                         jnp.float32)
    L = 48
    alpha, beta, R = A.topo_decomposition(cfg, coeffs, L, rank=24)
    # reconstruct f(i-j) from the decomposition and compare
    from repro.core.masks import GS
    ii = np.arange(L, dtype=np.float32)
    errs = []
    for i in range(0, L, 7):
        for j in range(0, i + 1, 5):
            a = alpha(jnp.asarray(float(i)))
            b = beta(jnp.asarray(float(j)))
            approx_v = float(jnp.sum(a[0] * b[0]))
            z = (i - j) * cfg.topo_dist_scale
            exact = float(np.exp(0.1 - 1.2 * z - 0.7 * z * z))
            errs.append(abs(approx_v - exact) / max(abs(exact), 1e-9))
    assert max(errs) < 1e-4


@pytest.mark.parametrize("backend", ["plan", "pallas"])
def test_grid_mask_fastmult(backend, rng):
    """ViT grid masks through the Integrator == dense mask multiply, with
    batch/head axes folded by the tree fastmult factory."""
    from repro.core.engines import Integrator
    from repro.graphs.graph import grid_graph
    from repro.graphs.mst import minimum_spanning_tree
    from repro.graphs.traverse import tree_all_pairs

    g = grid_graph(6, 6)
    mst = minimum_spanning_tree(g)
    integ = Integrator(mst, backend=backend, leaf_size=8)
    D = tree_all_pairs(mst)
    coeffs = jnp.asarray([0.0, -0.3], jnp.float32)
    X = jnp.asarray(rng.normal(size=(2, 36, 5)), jnp.float32)  # batched field
    ref = np.einsum("lk,bkd->bld", np.exp(-0.3 * D), np.asarray(X))
    fm = MK.make_tree_fastmult(integ, "exp", coeffs, dist_scale=1.0)
    got = np.asarray(fm(X))
    assert np.max(np.abs(got - ref)) / np.max(np.abs(ref)) < 1e-5
