"""Fault tolerance: crash -> restart resumes bit-identically; checkpoint
atomicity; elastic restore under a different sharding."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import get_smoke_config
from repro.train.loop import TrainLoopConfig, run_training


def test_crash_restart_bit_identical(tmp_path):
    cfg = get_smoke_config("llama3_2_1b").replace(dtype="float32")
    common = dict(batch_size=4, seq_len=32, ckpt_every=5, log_every=1000)

    # uninterrupted run
    loopA = TrainLoopConfig(steps=14, ckpt_dir=str(tmp_path / "A"), **common)
    resA = run_training(cfg, loopA, verbose=False)

    # interrupted at step 9 (after the step-5 checkpoint), then restarted
    loopB1 = TrainLoopConfig(steps=14, ckpt_dir=str(tmp_path / "B"),
                             fail_at_step=9, **common)
    with pytest.raises(RuntimeError, match="injected failure"):
        run_training(cfg, loopB1, verbose=False)
    loopB2 = TrainLoopConfig(steps=14, ckpt_dir=str(tmp_path / "B"), **common)
    resB = run_training(cfg, loopB2, verbose=False)

    # identical final params (deterministic data keyed by global step)
    for a, b in zip(jax.tree.leaves(resA["params"]),
                    jax.tree.leaves(resB["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and the post-resume loss trajectory matches the uninterrupted one
    np.testing.assert_allclose(resA["losses"][10:], resB["losses"][-4:],
                               rtol=1e-6)


def test_checkpoint_atomicity_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    params = {"a": jnp.arange(5, dtype=jnp.float32),
              "b": {"c": jnp.ones((2, 3))}}
    for s in (5, 10, 15, 20):
        mgr.save(s, params)
    assert mgr.all_steps() == [15, 20]  # keep=2 collected older ones
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))
    res = mgr.restore(params)
    assert res["step"] == 20
    np.testing.assert_array_equal(np.asarray(res["params"]["a"]),
                                  np.arange(5, dtype=np.float32))


def test_restore_roundtrip_structure(tmp_path):
    """NamedTuple opt state + nested dict params roundtrip exactly."""
    from repro.optim.adamw import adamw_init

    params = {"blocks": {"w": jnp.ones((3, 4)), "b": jnp.zeros(4)},
              "embed": {"table": jnp.full((7, 2), 0.5)}}
    opt = adamw_init(params)
    mgr = CheckpointManager(str(tmp_path), keep=1)
    mgr.save(3, params, opt)
    res = mgr.restore(params, opt)
    assert res["step"] == 3
    for a, b in zip(jax.tree.leaves(res["opt"]), jax.tree.leaves(opt)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_restore_resharding(tmp_path):
    """Save on one device layout, restore under a 8-device mesh sharding —
    the elastic-scaling path. Runs in a subprocess so the 8 fake devices
    don't leak into this test session."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.manager import CheckpointManager

d = os.environ["CKPT_DIR"]
params = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
mgr = CheckpointManager(d, keep=1)
mgr.save(1, params)
mesh = jax.make_mesh((2, 4), ("data", "model"))
shardings = {"w": NamedSharding(mesh, P("data", "model"))}
res = mgr.restore(params, shardings=shardings)
w = res["params"]["w"]
assert len(w.sharding.device_set) == 8, w.sharding
np.testing.assert_array_equal(np.asarray(w),
                              np.arange(64, dtype=np.float32).reshape(8, 8))
print("ELASTIC_OK")
"""
    env = dict(os.environ, CKPT_DIR=str(tmp_path),
               PYTHONPATH=os.path.abspath("src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]
