"""Disk-persistent plan cache: round trips, corruption tolerance, eviction.

All tests configure the cache programmatically onto a tmp_path and restore
the environment-driven configuration afterwards; the module-level stats
counters are cumulative, so assertions diff them around each operation.
"""
import numpy as np
import pytest

from repro import ftfi
from repro.core import clear_flat_cache, clear_plan_cache, plan_cache
from repro.core import cordial as C
from repro.core.plan_api import load_plan, save_plan
from repro.graphs.graph import random_tree


@pytest.fixture
def cache_dir(tmp_path):
    d = tmp_path / "plans"
    plan_cache.configure(d, max_mb=64)
    clear_flat_cache()
    clear_plan_cache()
    try:
        yield d
    finally:
        plan_cache.reset_to_env()
        clear_flat_cache()
        clear_plan_cache()


def _delta(fn):
    """Run fn, return (result, stats-counter deltas)."""
    before = plan_cache.stats()
    out = fn()
    after = plan_cache.stats()
    keys = ("hits", "misses", "stores", "evictions", "errors")
    return out, {k: after[k] - before[k] for k in keys}


def test_build_stores_then_cold_process_rebuild_hits(cache_dir):
    tree = random_tree(300, seed=0)
    (spec1, pp1), d = _delta(
        lambda: ftfi.build(tree, leaf_size=16, reweightable=True))
    assert d["stores"] == 1 and d["hits"] == 0
    assert plan_cache.stats()["entries"] == 1

    # simulate a fresh process: memory caches gone, disk cache populated
    clear_flat_cache()
    clear_plan_cache()
    (loaded, d) = _delta(
        lambda: ftfi.build(tree, leaf_size=16, reweightable=True))
    spec2, pp2 = loaded
    assert d["hits"] == 1 and d["stores"] == 0
    assert spec2.digest == spec1.digest
    assert spec2.fingerprint == spec1.fingerprint

    # parity through the executor
    rng = np.random.default_rng(1)
    X = rng.normal(size=(300, 3)).astype(np.float32)
    fn = C.Exponential(-0.5)
    np.testing.assert_array_equal(
        np.asarray(ftfi.apply(spec1, pp1, fn, X)),
        np.asarray(ftfi.apply(spec2, pp2, fn, X)))


def test_distinct_compile_keys_get_distinct_artifacts(cache_dir):
    tree = random_tree(120, seed=3)
    ftfi.build(tree, leaf_size=8)
    ftfi.build(tree, leaf_size=16)               # different leaf_size
    ftfi.build(tree, leaf_size=8, reweightable=True)  # different tables
    assert plan_cache.stats()["entries"] == 3


def test_corrupt_artifact_is_deleted_and_rebuilt(cache_dir):
    tree = random_tree(200, seed=5)
    spec1, _ = ftfi.build(tree, leaf_size=16)
    [artifact] = list(cache_dir.glob("ftfi-plan-*.npz"))
    artifact.write_bytes(b"this is not an npz")

    clear_flat_cache()
    clear_plan_cache()
    (rebuilt, d) = _delta(lambda: ftfi.build(tree, leaf_size=16))
    spec2, _ = rebuilt
    # torn artifact -> counted error, treated as miss, deleted, re-stored
    assert d["errors"] == 1 and d["hits"] == 0
    assert d["misses"] >= 1 and d["stores"] == 1
    assert spec2.digest == spec1.digest


def test_lru_eviction_under_tiny_budget(cache_dir):
    plan_cache.configure(cache_dir, max_mb=0.05)  # ~50 KB: a couple plans
    _, d = _delta(lambda: [ftfi.build(random_tree(150, seed=s), leaf_size=8)
                           for s in range(6)])
    assert d["stores"] == 6
    st = plan_cache.stats()
    assert d["evictions"] >= 1
    assert st["bytes"] <= st["max_bytes"]
    assert 0 < st["entries"] < 6


def test_clear_and_disable(cache_dir):
    tree = random_tree(100, seed=7)
    ftfi.build(tree, leaf_size=8)
    assert plan_cache.stats()["entries"] == 1
    plan_cache.clear()
    assert plan_cache.stats()["entries"] == 0

    plan_cache.configure(None)
    assert not plan_cache.enabled()
    clear_flat_cache()
    clear_plan_cache()
    _, d = _delta(lambda: ftfi.build(tree, leaf_size=8))
    # disabled: no disk traffic at all
    assert d == {"hits": 0, "misses": 0, "stores": 0, "evictions": 0,
                 "errors": 0}


@pytest.mark.parametrize("reweightable", [False, True])
def test_save_load_round_trip_update_tables(tmp_path, reweightable):
    """save_plan/load_plan must round-trip the reweight/update tables when
    present and reconstruct None fields when absent (non-reweightable)."""
    tree = random_tree(90, seed=11)
    spec, pp = ftfi.build(tree, leaf_size=8, reweightable=reweightable)
    path = tmp_path / "plan.npz"
    save_plan(path, spec, pp)
    spec2, pp2 = load_plan(path)
    assert spec2.digest == spec.digest
    assert (spec2.edges_u is None) == (spec.edges_u is None)
    assert (spec2.edge_w0 is None) == (spec.edge_w0 is None)
    if reweightable:
        # ...and the loaded plan is actually updatable
        s3, p3 = ftfi.update_plan(spec2, pp2, [("insert_leaf", 4, 0.9)])
        assert s3.n == spec.n + 1
    else:
        with pytest.raises(ValueError, match="reweightable"):
            ftfi.update_plan(spec2, pp2, [("insert_leaf", 4, 0.9)])
