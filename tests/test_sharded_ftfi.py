"""Multi-device FTFI: shard_map executor parity and collective discipline.

Device-count tests run in a subprocess (8 fake CPU devices via XLA_FLAGS)
so the flag never leaks into the main test session — the
tests/test_distribution.py pattern. Single-device concerns (the auto
backend threshold, mesh provenance rejection) run in-process.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

_ENV = lambda: dict(os.environ, PYTHONPATH=os.path.abspath("src"))


def _run(code: str, timeout=560):
    env = _ENV()
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    return out


_PRELUDE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_local_mesh
import repro.ftfi as ftfi
from repro.core import cordial as C

mesh = make_local_mesh(data=2, model=4)
rng = np.random.RandomState(0)

def relerr(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return np.abs(a - b).max() / (np.abs(b).max() + 1e-30)
"""


def test_sharded_apply_parity_tree_and_forest():
    """apply_sharded on the (2,4) mesh == single-device apply at 1e-6 across
    tree + forest plans, exp + Chebyshev crosses, reweighted params,
    update_plan-edited plans, and tree weights."""
    code = _PRELUDE + r"""
from repro.graphs.graph import Forest, random_tree

t = random_tree(257, seed=3)
spec, params = ftfi.build(t, reweightable=True)
X = rng.randn(257, 4).astype(np.float32)
# Exponential rides the structured exp cross engine; the raw callable
# rides the Chebyshev-approximation cross engine
for fn in (C.Exponential(-0.4), lambda s: 1.0 / (1.0 + s * s)):
    Y0 = ftfi.apply(spec, params, fn, X)
    Ys = ftfi.apply_sharded(spec, params, fn, X, mesh=mesh)
    e = relerr(Ys, Y0)
    assert e < 1e-6, (type(fn).__name__, e)

# reweighted params (learnable tree metric)
ew = np.abs(rng.randn(256)).astype(np.float32) + 0.05
pr = ftfi.reweight(spec, jnp.asarray(ew))
fn = C.Exponential(-0.4)
e = relerr(ftfi.apply_sharded(spec, pr, fn, X, mesh=mesh),
           ftfi.apply(spec, pr, fn, X))
assert e < 1e-5, e

# incrementally updated plan (insert + reweight)
s2, p2 = ftfi.update_plan(spec, params, [("insert_leaf", 5, 0.8)])
s2, p2 = ftfi.update_plan(s2, p2, [("reweight",
                                    np.abs(rng.randn(257)).astype(np.float32) + 0.05)])
X2 = rng.randn(s2.n, 4).astype(np.float32)
e = relerr(ftfi.apply_sharded(s2, p2, fn, X2, mesh=mesh),
           ftfi.apply(s2, p2, fn, X2))
assert e < 1e-5, e

# forest: whole trees land per shard; tree weights ride outside shard_map
fo = Forest([random_tree(40 + 7 * i, seed=i) for i in range(5)])
fs, fp = ftfi.build(fo)
import dataclasses
fp = dataclasses.replace(fp, tree_w=jnp.asarray(
    rng.randn(5).astype(np.float32)))
Xf = rng.randn(fs.n, 3).astype(np.float32)
e = relerr(ftfi.apply_sharded(fs, fp, fn, Xf, mesh=mesh),
           ftfi.apply(fs, fp, fn, Xf))
assert e < 1e-6, e
print("PARITY_OK")
"""
    out = _run(code)
    assert "PARITY_OK" in out.stdout, (out.stdout[-1500:], out.stderr[-3000:])


def test_sharded_forward_collectives():
    """Collective discipline, asserted on the forward jaxpr: the shard_map
    body moves halo rows with all_to_all and reduces partial outputs with
    reduce_scatter (psum_scatter) — and never all-gathers the field or the
    plan index arrays."""
    code = _PRELUDE + r"""
from repro.graphs.graph import random_tree

t = random_tree(120, seed=1)
spec, params = ftfi.build(t)
X = rng.randn(120, 2).astype(np.float32)
fm = ftfi.sharded_fastmult(spec, C.Exponential(-0.5), mesh=mesh)
# structured census over the walked jaxpr (not string matching): exactly
# one halo all_to_all + one output psum_scatter, zero all_gather
from repro.analysis import jaxpr_audit
rep = jaxpr_audit.assert_clean(
    fm, params, X, name="sharded_fastmult",
    budget={"collectives": {"all_to_all": 1, "psum_scatter": 1}})
assert rep.collectives == {"all_to_all": 1, "reduce_scatter": 1}, rep.collectives
assert rep.prim_counts.get("shard_map", 0) >= 1, "not under shard_map"
# grad still matches (the transpose MAY all-gather; only forward is gated)
def loss_s(p, x):
    return jnp.sum(fm(p, x) ** 2)
def loss_d(p, x):
    return jnp.sum(ftfi.apply(spec, p, C.Exponential(-0.5), x) ** 2)
gs = jax.grad(loss_s, argnums=1)(params, X)
gd = jax.grad(loss_d, argnums=1)(params, X)
assert relerr(gs, gd) < 1e-5
print("COLLECTIVES_OK")
"""
    out = _run(code)
    assert "COLLECTIVES_OK" in out.stdout, (out.stdout[-1500:],
                                            out.stderr[-3000:])


def test_sharded_kernel_variants():
    """shard_map faces of both kernel families match their single-device
    wrappers bit-for-bit (no collectives in either: bucket/batch/head slabs
    are independent)."""
    code = _PRELUDE + r"""
from repro.kernels.fdist_matvec.ops import (fdist_matvec_batched,
                                            fdist_matvec_batched_sharded)
from repro.kernels.topo_linear_attention.ops import (
    topo_linear_attention, topo_linear_attention_sharded)

B, a, b, d = 5, 16, 24, 3  # ragged bucket count: exercises the pad path
x = rng.randn(B, a).astype(np.float32)
y = rng.randn(B, b).astype(np.float32)
v = rng.randn(B, b, d).astype(np.float32)
coef = np.array([0.3, -0.7], np.float32)
e = relerr(fdist_matvec_batched_sharded(x, y, v, coef, mesh=mesh, mode="exp"),
           fdist_matvec_batched(x, y, v, coef, mode="exp"))
assert e < 1e-6, e

Bq, H, L, m, hd = 4, 8, 64, 8, 16
qf = np.abs(rng.randn(Bq, H, L, m)).astype(np.float32)
kf = np.abs(rng.randn(Bq, H, L, m)).astype(np.float32)
vv = rng.randn(Bq, H, L, hd).astype(np.float32)
co = (rng.randn(H, 2) * 0.1).astype(np.float32)
for causal in (True, False):
    e = relerr(topo_linear_attention_sharded(qf, kf, vv, co, mesh=mesh,
                                             g="exp", causal=causal),
               topo_linear_attention(qf, kf, vv, co, g="exp", causal=causal))
    assert e < 1e-6, (causal, e)
# head count not divisible by model=4: head axis drops, still exact
e = relerr(topo_linear_attention_sharded(qf[:, :3], kf[:, :3], vv[:, :3],
                                         co[:3], mesh=mesh, g="exp"),
           topo_linear_attention(qf[:, :3], kf[:, :3], vv[:, :3], co[:3],
                                 g="exp"))
assert e < 1e-6, e
print("KERNELS_OK")
"""
    out = _run(code)
    assert "KERNELS_OK" in out.stdout, (out.stdout[-1500:],
                                        out.stderr[-3000:])


def test_update_plan_preserves_named_sharding():
    """Mesh-placed PlanParams keep their NamedSharding through update_plan
    (shape-preserving edits re-upload with the same placement)."""
    code = _PRELUDE + r"""
from repro.graphs.graph import random_tree

t = random_tree(130, seed=1)
spec, params = ftfi.build(t, reweightable=True)
params_m = jax.device_put(params, NamedSharding(mesh, P()))
s2, p2 = ftfi.update_plan(spec, params_m, [
    ("reweight", np.abs(rng.randn(129)).astype(np.float32) + 0.1)])
for leaf in jax.tree.leaves(p2):
    assert isinstance(leaf.sharding, NamedSharding), leaf.sharding
# and the sharded executor consumes the surviving placement exactly
fn = C.Exponential(-0.5)
X = rng.randn(130, 3).astype(np.float32)
e = relerr(ftfi.apply_sharded(s2, p2, fn, X, mesh=mesh),
           ftfi.apply(s2, jax.device_get(p2), fn, X))
assert e < 1e-5, e
print("SHARDING_SURVIVES_OK")
"""
    out = _run(code)
    assert "SHARDING_SURVIVES_OK" in out.stdout, (out.stdout[-1500:],
                                                  out.stderr[-3000:])


def test_mesh_mismatch_rejected():
    """A sharded artifact whose recorded mesh cannot be formed here fails
    plan-guard validation with a clear PlanValidationError."""
    import dataclasses

    import jax

    import repro.ftfi as ftfi
    from repro.graphs.graph import random_tree

    t = random_tree(40, seed=0)
    spec, params = ftfi.build(t)
    bad = dataclasses.replace(spec, shard_layout=ftfi.SHARD_LAYOUT_VERSION,
                              mesh_devices=jax.device_count() + 63,
                              mesh_axes=("data", "model"))
    with pytest.raises(ftfi.PlanValidationError, match="mesh_devices"):
        ftfi.validate(bad, params, where="test")
    newer = dataclasses.replace(
        spec, shard_layout=ftfi.SHARD_LAYOUT_VERSION + 1, mesh_devices=1)
    with pytest.raises(ftfi.PlanValidationError, match="shard_layout"):
        ftfi.validate(newer, params, where="test")
    # a matching mesh passes
    ok = dataclasses.replace(spec, shard_layout=ftfi.SHARD_LAYOUT_VERSION,
                             mesh_devices=1, mesh_axes=("data",))
    assert ftfi.validate(ok, params, where="test")


def test_auto_backend_size_threshold():
    """backend="auto" picks the plan executor below AUTO_PALLAS_MIN_N
    (pallas loses there: speedup_int 0.88 at n=1000) and pallas above."""
    from repro.core import cordial as C
    from repro.core import ladder
    from repro.core.engines.spec import spec_of
    from repro.core.plan_api import build, select_cross
    from repro.graphs.graph import random_tree

    assert ladder.effective_backend("auto", n=1000) == "plan"
    assert ladder.effective_backend("auto",
                                    n=ladder.AUTO_PALLAS_MIN_N) == "pallas"
    assert ladder.effective_backend("auto") == "plan"  # unknown size: safe

    spec, params = build(random_tree(64, seed=0))
    name, _ = select_cross(spec, spec_of(C.Exponential(-0.5)), backend="auto")
    assert "fdist" not in name, name  # small n resolved to the plan engine


def test_save_plan_records_mesh_provenance(tmp_path):
    import repro.ftfi as ftfi
    from repro.graphs.graph import random_tree

    t = random_tree(40, seed=0)
    spec, params = ftfi.build(t)
    import jax
    from jax.sharding import Mesh

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))
    path = tmp_path / "plan.npz"
    ftfi.save_plan(str(path), spec, params, mesh=mesh)
    s2, _ = ftfi.load_plan(str(path))
    assert s2.mesh_devices == 1
    assert s2.mesh_axes == ("data",)
    assert s2.shard_layout == ftfi.SHARD_LAYOUT_VERSION
    assert "mesh_devices" in s2.provenance
