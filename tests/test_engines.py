"""The pluggable-backend Integrator: host == plan == pallas == BTFI oracle,
engine auto-selection (Pallas families, Hankel on grids), grid_h surfacing,
ITNode immutability, and jit-ability of fastmult."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import cordial as C
from repro.core.engines import Integrator, available_backends, spec_of
from repro.core.integrate import BTFI, ExpMP
from repro.core.integrator_tree import build_integrator_tree
from repro.graphs.graph import (caterpillar_tree, path_graph, random_tree,
                                star_tree)

BACKENDS = ["host", "plan", "pallas"]

# one fn per in-kernel family + one general f (chebyshev/hankel fallback)
KERNEL_FAMILY_FNS = [
    C.Polynomial((0.5, -0.2, 0.1)),
    C.Exponential(-0.7, 1.3),
    C.ExpQuadratic(-0.05, -0.2, 0.1),
    C.Rational((2.0,), (1.0, 0.0, 0.8)),
]
GENERAL_FNS = [
    C.ExpPoly(-0.5, (1.0, 0.3)),
    C.AnyFn(lambda z: (z + 1.0) ** -0.5),
]


def test_backend_registry():
    for b in BACKENDS:
        assert b in available_backends()
    with pytest.raises(ValueError, match="unknown backend"):
        Integrator(random_tree(20, seed=0), backend="nope")


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("fn", KERNEL_FAMILY_FNS + GENERAL_FNS,
                         ids=lambda f: type(f).__name__)
def test_integrator_equals_btfi(backend, fn, rng):
    tree = random_tree(157, seed=1)
    X = rng.normal(size=(157, 3))
    ref = BTFI(tree).integrate(fn, X)
    integ = Integrator(tree, backend=backend, leaf_size=16)
    got = np.asarray(integ.integrate(fn, X))
    scale = max(np.max(np.abs(ref)), 1e-12)
    assert np.max(np.abs(got - ref)) / scale < 1e-5


@pytest.mark.parametrize("fn", KERNEL_FAMILY_FNS,
                         ids=lambda f: type(f).__name__)
def test_pallas_backend_uses_fdist_kernel(fn):
    tree = random_tree(60, seed=2)
    integ = Integrator(tree, backend="pallas", leaf_size=16)
    engine = integ.describe(fn)["cross_engine"]
    assert engine.startswith("fdist_matvec:"), engine
    mode = spec_of(fn).mode
    assert engine == f"fdist_matvec:{mode}"


def test_backends_agree_pairwise(rng):
    """host == plan == pallas on the same field (tighter than vs-oracle)."""
    tree = caterpillar_tree(90, seed=3)
    X = rng.normal(size=(90, 2))
    fn = C.ExpQuadratic(-0.03, -0.1, 0.0)
    outs = [np.asarray(Integrator(tree, backend=b, leaf_size=16)
                       .integrate(fn, X)) for b in BACKENDS]
    for o in outs[1:]:
        assert np.max(np.abs(o - outs[0])) / np.max(np.abs(outs[0])) < 1e-5


# ---------------------------------------------------------------------------
# grid_h surfacing: unit-weight trees auto-select the exact Hankel/FFT engine
# ---------------------------------------------------------------------------


def test_grid_h_on_unit_weight_path(rng):
    tree = path_graph(64)  # unit weights -> integer distance grid
    general = C.AnyFn(lambda z: np.sin(z) * np.exp(-0.1 * z) + 1.0 / (1 + z))
    X = rng.normal(size=(64, 2))
    ref = BTFI(tree).integrate(general, X)
    for backend in BACKENDS:
        integ = Integrator(tree, backend=backend, leaf_size=8)
        assert integ.grid_h == pytest.approx(1.0)
        if backend in ("plan", "pallas"):
            assert integ.describe(general)["cross_engine"] == "hankel_fft"
        got = np.asarray(integ.integrate(general, X))
        assert np.max(np.abs(got - ref)) / np.max(np.abs(ref)) < 1e-5


def test_grid_h_none_on_irrational_weights():
    tree = random_tree(50, seed=5)  # uniform random weights: no common grid
    integ = Integrator(tree, backend="plan", leaf_size=8)
    assert integ.grid_h is None
    assert integ.describe(C.AnyFn(np.cos))["cross_engine"] == "chebyshev"


# ---------------------------------------------------------------------------
# ExpMP vs the BTFI oracle (host backend dispatches exp to it)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mk", [lambda: random_tree(157, seed=1),
                                lambda: star_tree(80, seed=3),
                                lambda: path_graph(100)])
@pytest.mark.parametrize("lam,scale", [(-0.4, 0.7), (-1.1, 1.0), (0.2, 0.3)])
def test_expmp_equals_btfi(mk, lam, scale, rng):
    tree = mk()
    n = tree.num_vertices
    X = rng.normal(size=(n, 3))
    ref = BTFI(tree).integrate(lambda z: scale * np.exp(lam * z), X)
    got = ExpMP(tree).integrate(lam, X, scale=scale)
    # growing exponentials (lam > 0) span ~9 decades on long paths; 1e-7
    # relative still certifies exactness up to float64 cancellation
    assert np.max(np.abs(got - ref)) / np.max(np.abs(ref)) < 1e-7
    # and the host backend routes Exponential through it
    integ = Integrator(tree, backend="host", leaf_size=16)
    fn = C.Exponential(lam, scale)
    assert integ.describe(fn)["cross_engine"] == "exp_message_passing"
    got2 = integ.integrate(fn, X)
    assert np.max(np.abs(got2 - ref)) / np.max(np.abs(ref)) < 1e-7


# ---------------------------------------------------------------------------
# immutability + jit
# ---------------------------------------------------------------------------


def test_itnode_is_immutable():
    root = build_integrator_tree(random_tree(80, seed=7), leaf_size=16)
    with pytest.raises(dataclasses.FrozenInstanceError):
        root.pivot = 0
    # segment layouts are precomputed at build time on internal nodes
    assert root.left_sorted_ids is not None
    assert root.left_seg_starts is not None
    assert root.left_seg_starts[0] == 0
    assert set(root.left_sorted_ids) == set(root.left_ids)


# the facade's fastmult is the deprecated closure-capturing path (asserted
# in test_plan_api); these tests cover its caching semantics, so silence it
@pytest.mark.filterwarnings("ignore::DeprecationWarning")
@pytest.mark.parametrize("backend", ["plan", "pallas"])
def test_fastmult_cache_hit_no_retrace(backend, rng):
    """Satellite: the jitted fastmult closure is cached per family spec —
    the second fastmult() returns the same object (even for an equal-valued
    new fn instance) and back-to-back integrate calls do not re-trace."""
    tree = random_tree(70, seed=4)
    X = rng.normal(size=(70, 3))
    integ = Integrator(tree, backend=backend, leaf_size=16)
    fm1 = integ.fastmult(C.Exponential(-0.7, 1.3))
    fm2 = integ.fastmult(C.Exponential(-0.7, 1.3))  # equal, distinct object
    assert fm1 is fm2
    assert fm1.jitted
    np.asarray(fm1(X))
    assert fm1.trace_count == 1
    np.asarray(fm1(X))  # same shapes: cache hit, no retrace
    assert fm1.trace_count == 1
    np.asarray(integ.integrate(C.Exponential(-0.7, 1.3), X))
    assert fm1.trace_count == 1
    # different family spec -> different compiled closure
    assert integ.fastmult(C.Exponential(-0.2)) is not fm1


@pytest.mark.filterwarnings("ignore::DeprecationWarning")
@pytest.mark.parametrize("backend", ["plan", "pallas"])
def test_fastmult_is_jittable_and_differentiable(backend, rng):
    tree = random_tree(60, seed=9)
    X = jnp.asarray(rng.normal(size=(60, 2)), jnp.float32)
    integ = Integrator(tree, backend=backend, leaf_size=16)
    coeffs = jnp.asarray([0.3, -0.1, 0.05])

    def apply(c, X):
        fm = integ.fastmult(lambda z: c[0] + c[1] * z + c[2] * z * z)
        return fm(X)

    got = np.asarray(jax.jit(apply)(coeffs, X))
    ref = BTFI(tree).integrate(C.Polynomial((0.3, -0.1, 0.05)),
                               np.asarray(X))
    assert np.max(np.abs(got - ref)) / np.max(np.abs(ref)) < 1e-5

    g = jax.grad(lambda c: jnp.sum(apply(c, X) ** 2))(coeffs)
    assert np.all(np.isfinite(np.asarray(g)))
    assert float(jnp.sum(jnp.abs(g))) > 0.0
