"""Incremental plan updates (`ftfi.update_plan`) vs full rebuilds.

The oracle is exhaustive: after any sequence of insert_leaf / delete_leaf /
reweight ops, integrating through the patched plan must match a
from-scratch `ftfi.build` of the edited tree/forest (ghost rows excluded:
they must be exactly zero and ignore their input). Differences are f32-eps
scale only (the distance derivations sum in different orders before the
float32 executor), so the comparisons use a small relative tolerance.
"""
import numpy as np
import pytest

from repro import ftfi
from repro.core import Integrator
from repro.core import cordial as C
from repro.graphs.graph import Forest, WeightedTree, random_tree

FNS = [C.Exponential(-0.5, 1.1), C.Polynomial((0.4, -0.15, 0.05))]
TOL = 2e-5


def _rel_err(got, ref):
    return float(np.max(np.abs(np.asarray(got) - np.asarray(ref)))
                 / max(np.max(np.abs(np.asarray(ref))), 1e-12))


class _Model:
    """Pure-python mirror of update_plan's id/edge semantics, used to build
    the rebuild oracle: per-tree local edge lists (inserts append, deletes
    remove), per-tree sizes, per-tree ghost sets. Global id of local vertex
    v of tree t is offset_t + v; an insert into tree t appends local id
    size_t (shifting later trees' global ids up by one)."""

    def __init__(self, trees):
        self.sizes = [t.num_vertices for t in trees]
        self.edges = [[(int(u), int(v), float(w)) for u, v, w in
                       zip(t.edges_u, t.edges_v, t.weights)] for t in trees]
        self.ghosts = [set() for _ in trees]

    def offsets(self):
        return np.concatenate([[0], np.cumsum(self.sizes)])

    def locate(self, g):
        off = self.offsets()
        t = int(np.searchsorted(off, g, side="right")) - 1
        return t, int(g - off[t])

    def insert(self, parent_g, w):
        t, p = self.locate(parent_g)
        v = self.sizes[t]
        self.edges[t].append((p, v, float(w)))
        self.sizes[t] += 1
        return int(self.offsets()[t]) + v  # new global id

    def degree(self, t, v):
        return sum(v in (u, x) for u, x, _ in self.edges[t])

    def delete(self, g):
        t, v = self.locate(g)
        assert self.degree(t, v) == 1 and v != 0
        self.edges[t] = [e for e in self.edges[t] if v not in e[:2]]
        self.ghosts[t].add(v)

    def reweight(self, rng):
        w = rng.uniform(0.1, 2.0, sum(len(e) for e in self.edges))
        i = 0
        for t in range(len(self.edges)):
            self.edges[t] = [(u, v, float(w[i + j]))
                             for j, (u, v, _) in enumerate(self.edges[t])]
            i += len(self.edges[t])
        return w

    def live_leaves(self):
        """Global ids of deletable vertices: degree 1, not the tree root."""
        out = []
        off = self.offsets()
        for t in range(len(self.edges)):
            deg = {}
            for u, v, _ in self.edges[t]:
                deg[u] = deg.get(u, 0) + 1
                deg[v] = deg.get(v, 0) + 1
            out += [int(off[t]) + v for v, d in deg.items()
                    if d == 1 and v != 0 and v not in self.ghosts[t]]
        return out

    def live_vertices(self):
        off = self.offsets()
        return [int(off[t]) + v for t in range(len(self.sizes))
                for v in range(self.sizes[t]) if v not in self.ghosts[t]]

    def rebuild(self):
        """(tree_or_forest, live_global_rows): compacted rebuild oracle."""
        trees, rows = [], []
        off = self.offsets()
        for t in range(len(self.sizes)):
            live = [v for v in range(self.sizes[t])
                    if v not in self.ghosts[t]]
            relab = {v: i for i, v in enumerate(live)}
            eu = [relab[u] for u, v, _ in self.edges[t]]
            ev = [relab[v] for _, v, _ in self.edges[t]]
            w = [x for _, _, x in self.edges[t]]
            trees.append(WeightedTree(len(live), eu, ev, w))
            rows += [int(off[t]) + v for v in live]
        obj = trees[0] if len(trees) == 1 else Forest(trees)
        return obj, np.asarray(rows)


def _apply_rows(spec, params, fn, X):
    return np.asarray(ftfi.apply(spec, params, fn, X))


def _check_vs_rebuild(spec, params, model, rng, label):
    obj, rows = model.rebuild()
    rspec, rparams = ftfi.build(obj, leaf_size=8, reweightable=True)
    X = rng.normal(size=(spec.n, 3)).astype(np.float32)
    for fn in FNS:
        got = _apply_rows(spec, params, fn, X)
        ref = _apply_rows(rspec, rparams, fn, X[rows])
        assert _rel_err(got[rows], ref) < TOL, label
        # ghost rows produce exactly zero output
        ghost_rows = np.setdiff1d(np.arange(spec.n), rows)
        if ghost_rows.size:
            assert float(np.max(np.abs(got[ghost_rows]))) == 0.0, label
            # ...and their input is ignored
            X2 = X.copy()
            X2[ghost_rows] = 1e6
            got2 = _apply_rows(spec, params, fn, X2)
            assert _rel_err(got2[rows], ref) < TOL, label


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_single_ops_match_rebuild_tree(seed):
    rng = np.random.default_rng(seed)
    tree = random_tree(48 + 11 * seed, seed=seed)
    spec0, pp0 = ftfi.build(tree, leaf_size=8, reweightable=True)
    model0 = _Model([tree])

    # insert
    model = _Model([tree])
    parent = int(rng.choice(model.live_vertices()))
    model.insert(parent, 0.7)
    s, p = ftfi.update_plan(spec0, pp0, [("insert_leaf", parent, 0.7)])
    _check_vs_rebuild(s, p, model, rng, "insert")

    # delete
    model = _Model([tree])
    leaf = int(rng.choice(model0.live_leaves()))
    model.delete(leaf)
    s, p = ftfi.update_plan(spec0, pp0, [("delete_leaf", leaf)])
    _check_vs_rebuild(s, p, model, rng, "delete")

    # reweight
    model = _Model([tree])
    w = model.reweight(rng)
    s, p = ftfi.update_plan(spec0, pp0, [("reweight", w)])
    _check_vs_rebuild(s, p, model, rng, "reweight")


@pytest.mark.parametrize("forest,seed", [(False, 3), (False, 4),
                                         (True, 5), (True, 6)])
def test_random_op_sweep_matches_rebuild(forest, seed):
    """Property-style sweep: a random mixed sequence of ops, applied both
    one-at-a-time (chained update_plan generations) and as one batch, must
    match the compacted rebuild — on trees AND forests."""
    rng = np.random.default_rng(seed)
    if forest:
        trees = [random_tree(int(s), seed=seed * 10 + i)
                 for i, s in enumerate(rng.integers(10, 30, size=4))]
    else:
        trees = [random_tree(40, seed=seed)]
    obj = trees[0] if len(trees) == 1 else Forest(trees)
    spec, pp = ftfi.build(obj, leaf_size=8, reweightable=True)
    model = _Model(trees)
    ops = []
    for _ in range(8):
        kind = rng.choice(["insert", "insert", "delete", "reweight"])
        if kind == "insert":
            parent = int(rng.choice(model.live_vertices()))
            w = float(rng.uniform(0.2, 1.5))
            model.insert(parent, w)
            op = ("insert_leaf", parent, w)
        elif kind == "delete":
            leaves = model.live_leaves()
            if not leaves:
                continue
            v = int(rng.choice(leaves))
            model.delete(v)
            op = ("delete_leaf", v)
        else:
            op = ("reweight", model.reweight(rng))
        ops.append(op)
        # chained: each op patches the previous generation
        spec, pp = ftfi.update_plan(spec, pp, [op])
    _check_vs_rebuild(spec, pp, model, rng, f"chained seed={seed}")

    # batch: all ops in one update_plan call on the original plan. The
    # op-chained fingerprint is call-batching invariant; the content digest
    # is NOT asserted equal because masked (harmless) slots may carry
    # different garbage depending on when a mid-sequence reweight re-derived
    # the distance tables.
    spec0, pp0 = ftfi.build(obj, leaf_size=8, reweightable=True)
    sb, pb = ftfi.update_plan(spec0, pp0, ops)
    assert sb.fingerprint == spec.fingerprint
    _check_vs_rebuild(sb, pb, model, rng, f"batch seed={seed}")


def test_updated_plan_runs_on_pallas_backend():
    tree = random_tree(40, seed=11)
    spec, pp = ftfi.build(tree, leaf_size=8, reweightable=True)
    s, p = ftfi.update_plan(spec, pp, [("insert_leaf", 7, 0.9),
                                       ("delete_leaf", 39)])
    rng = np.random.default_rng(0)
    X = rng.normal(size=(s.n, 3)).astype(np.float32)
    fn = C.Exponential(-0.4)
    ref = _apply_rows(s, p, fn, X)
    got = np.asarray(Integrator.from_plan(s, p, backend="pallas",
                                          interpret=True).integrate(fn, X))
    assert _rel_err(got, ref) < TOL


def test_update_preserves_tree_w_and_chains_fingerprint():
    tree = random_tree(30, seed=2)
    spec, pp = ftfi.build(tree, leaf_size=8, reweightable=True)
    ops = [("insert_leaf", 5, 0.8), ("delete_leaf", 29)]
    s1, p1 = ftfi.update_plan(spec, pp, ops)
    s2, p2 = ftfi.update_plan(spec, pp, ops)
    # deterministic: identical edit histories -> identical provenance AND
    # identical content digest
    assert s1.fingerprint == s2.fingerprint
    assert s1.fingerprint != spec.fingerprint
    assert s1.digest == s2.digest
    assert p1.tree_w is pp.tree_w or np.array_equal(
        np.asarray(p1.tree_w), np.asarray(pp.tree_w))


def test_update_error_cases():
    tree = random_tree(30, seed=8)
    spec, pp = ftfi.build(tree, leaf_size=8, reweightable=True)
    model = _Model([tree])
    leaf = model.live_leaves()[0]

    # non-reweightable plans carry no update tables
    s0, p0 = ftfi.build(tree, leaf_size=8)
    with pytest.raises(ValueError, match="reweightable"):
        ftfi.update_plan(s0, p0, [("insert_leaf", 0, 1.0)])

    with pytest.raises(ValueError, match="degree"):
        # vertex 0 is the BFS root: never degree-1-deletable in these trees,
        # and internal vertices are rejected the same way
        internal = next(v for v in range(30)
                        if model.degree(0, v) > 1)
        ftfi.update_plan(spec, pp, [("delete_leaf", internal)])
    with pytest.raises(ValueError, match="out of range"):
        ftfi.update_plan(spec, pp, [("insert_leaf", 30, 1.0)])
    with pytest.raises(ValueError, match="already deleted"):
        ftfi.update_plan(spec, pp, [("delete_leaf", leaf),
                                    ("delete_leaf", leaf)])
    with pytest.raises(ValueError, match="was deleted"):
        ftfi.update_plan(spec, pp, [("delete_leaf", leaf),
                                    ("insert_leaf", leaf, 1.0)])
    with pytest.raises(ValueError, match="edge weights"):
        ftfi.update_plan(spec, pp, [("reweight", np.ones(7))])
    with pytest.raises(ValueError, match="unknown update op"):
        ftfi.update_plan(spec, pp, [("frobnicate", 3)])


def test_deleting_all_but_root_leaves_zero_plan():
    """Degenerate stress: peel a small tree down to its root; every output
    row except the root must be exactly zero, the root row must equal the
    single-vertex integral f(0) * x."""
    tree = random_tree(10, seed=13)
    spec, pp = ftfi.build(tree, leaf_size=4, reweightable=True)
    model = _Model([tree])
    while True:
        leaves = model.live_leaves()
        if not leaves:
            break
        v = leaves[0]
        model.delete(v)
        spec, pp = ftfi.update_plan(spec, pp, [("delete_leaf", v)])
    assert sorted(model.live_vertices()) == [0]
    fn = C.Exponential(-0.3, 2.0)
    X = np.ones((spec.n, 2), np.float32)
    out = _apply_rows(spec, pp, fn, X)
    np.testing.assert_allclose(out[0], fn.f0 * X[0], rtol=1e-6)
    assert float(np.max(np.abs(out[1:]))) == 0.0
