"""Functional plan API: PlanSpec/PlanParams pytree registration, jit/vmap
parity vs the legacy Integrator facade on trees and forests (all three
backends), differentiable `ftfi.reweight` (exact under new weights,
finite-difference gradcheck), save/load round trip with zero IT rebuild,
the clear_plan_cache fastmult-memo purge, and the facade deprecation."""
import dataclasses
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import ftfi
from repro.core import Integrator, clear_plan_cache
from repro.core import cordial as C
from repro.graphs.graph import (Forest, WeightedTree, path_graph, random_tree,
                                star_tree)

BACKENDS = ["host", "plan", "pallas"]

PARITY_FNS = [
    C.Exponential(-0.7, 1.3),
    C.Polynomial((0.5, -0.2, 0.1)),
    C.AnyFn(lambda z: 1.0 / (1.0 + z)),
]


def _rel_err(got, ref):
    return float(np.max(np.abs(np.asarray(got) - np.asarray(ref)))
                 / max(np.max(np.abs(np.asarray(ref))), 1e-12))


# ----------------------------------------------------------------------------
# pytree registration
# ----------------------------------------------------------------------------


def test_pytree_roundtrip_identity():
    """tree_flatten((spec, params)) puts every distance/weight array in the
    leaves and the spec in the aux data; unflatten reproduces both."""
    spec, params = ftfi.build(random_tree(60, seed=2), leaf_size=16)
    leaves, treedef = jax.tree_util.tree_flatten((spec, params))
    assert leaves, "params must contribute pytree leaves"
    assert all(hasattr(leaf, "dtype") for leaf in leaves)
    spec2, params2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert spec2 is spec  # the spec IS the (hashable) aux data
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(params2)):
        assert a is b
    # spec alone flattens to zero leaves; content digest keys retraces
    sl, _ = jax.tree_util.tree_flatten(spec)
    assert sl == []
    assert hash(spec) == hash(spec2) and spec == spec2


def test_params_tree_map():
    """PlanParams is a real pytree: tree_map reaches every distance array."""
    spec, params = ftfi.build(random_tree(40, seed=3), leaf_size=8)
    doubled = jax.tree_util.tree_map(lambda a: a * 2.0, params)
    for a, b in zip(params.cross_tgt_d, doubled.cross_tgt_d):
        assert np.allclose(np.asarray(b), 2.0 * np.asarray(a))


# ----------------------------------------------------------------------------
# jit / vmap parity vs the facade
# ----------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("fn", PARITY_FNS, ids=lambda f: type(f).__name__)
def test_apply_jit_parity_tree(backend, fn, rng):
    tree = random_tree(130, seed=1)
    X = rng.normal(size=(130, 3))
    ref = Integrator(tree, backend=backend, leaf_size=16).integrate(fn, X)
    spec, params = ftfi.build(tree, leaf_size=16)
    engine = "pallas" if backend == "pallas" else "plan"
    fm = jax.jit(ftfi.fastmult(spec, fn, backend=engine))
    got = fm(params, jnp.asarray(X))
    assert _rel_err(got, ref) < 1e-5


@pytest.mark.parametrize("backend", BACKENDS)
def test_apply_jit_parity_forest(backend, rng):
    trees = [random_tree(18 + 3 * i, seed=i) for i in range(5)]
    trees += [path_graph(24), star_tree(20, seed=7)]
    forest = Forest(trees)
    X = rng.normal(size=(forest.num_vertices, 2))
    fn = C.Exponential(-0.5, 1.2)
    ref = Integrator.from_forest(forest, backend=backend,
                                 leaf_size=8).integrate(fn, X)
    spec, params = ftfi.build(forest, leaf_size=8)
    assert spec.num_trees == forest.num_trees
    engine = "pallas" if backend == "pallas" else "plan"
    got = jax.jit(ftfi.fastmult(spec, fn, backend=engine))(params, X)
    assert _rel_err(got, ref) < 1e-5


def test_vmap_over_batched_fields(rng):
    """The pure executor vmaps over a leading batch axis of fields — the
    thing the closure-capturing API could not express."""
    tree = random_tree(50, seed=4)
    spec, params = ftfi.build(tree, leaf_size=8)
    fn = C.Exponential(-0.4)
    Xb = jnp.asarray(rng.normal(size=(6, 50, 2)), jnp.float32)
    fm = ftfi.fastmult(spec, fn)
    got = jax.vmap(fm, in_axes=(None, 0))(params, Xb)
    for b in range(Xb.shape[0]):
        assert _rel_err(got[b], fm(params, Xb[b])) < 1e-6


def test_forest_tree_w_output_weights(rng):
    """params.tree_w scales each tree's output block (== scaling its mask)."""
    forest = Forest([random_tree(15, seed=i) for i in range(4)])
    spec, params = ftfi.build(forest, leaf_size=8)
    fn = C.Exponential(-0.6)
    X = rng.normal(size=(forest.num_vertices, 2))
    w = rng.uniform(0.5, 2.0, size=forest.num_trees)
    ref = np.asarray(ftfi.apply(spec, params, fn, X))
    ref = ref * forest.broadcast(w)[:, None]
    pw = dataclasses.replace(params, tree_w=jnp.asarray(w, jnp.float32))
    got = ftfi.apply(spec, pw, fn, X)
    assert _rel_err(got, ref) < 1e-6


# ----------------------------------------------------------------------------
# reweight: learnable tree metrics
# ----------------------------------------------------------------------------


def test_reweight_identity_matches_birth_params(rng):
    tree = random_tree(45, seed=5)
    spec, params = ftfi.build(tree, leaf_size=8, reweightable=True)
    fn = C.Exponential(-0.6, 1.1)
    X = rng.normal(size=(45, 2))
    a = ftfi.apply(spec, params, fn, X)
    b = ftfi.apply(spec, ftfi.reweight(spec, tree.weights), fn, X)
    assert _rel_err(b, a) < 1e-5


def test_reweight_exact_under_new_weights(rng):
    """The IT decomposition is combinatorial, so reweighted params give the
    TRUE integration for any positive weights on the same topology."""
    tree = random_tree(40, seed=3)
    spec, _ = ftfi.build(tree, leaf_size=8, reweightable=True)
    w1 = rng.uniform(0.2, 2.0, size=tree.num_edges)
    t1 = WeightedTree(tree.num_vertices, tree.edges_u, tree.edges_v, w1)
    fn = C.Exponential(-0.6, 1.1)
    X = rng.normal(size=(40, 2))
    ref = Integrator(t1, backend="host", leaf_size=8).integrate(fn, X)
    got = ftfi.apply(spec, ftfi.reweight(spec, w1), fn, X)
    assert _rel_err(got, ref) < 1e-5


def test_reweight_gradcheck_finite_differences(rng):
    """jax.grad through ftfi.reweight edge weights matches central finite
    differences (the acceptance-criterion gradcheck)."""
    tree = random_tree(16, seed=8)
    spec, _ = ftfi.build(tree, leaf_size=6, reweightable=True)
    fn = C.Exponential(-0.8)
    X = jnp.asarray(rng.normal(size=(16, 2)), jnp.float32)
    R = jnp.asarray(rng.normal(size=(16, 2)), jnp.float32)
    w0 = jnp.asarray(tree.weights, jnp.float32)

    def loss(w):
        return jnp.sum(R * ftfi.apply(spec, ftfi.reweight(spec, w), fn, X))

    g = np.asarray(jax.grad(loss)(w0))
    assert np.all(np.isfinite(g)) and np.sum(np.abs(g)) > 0
    h = 3e-3
    for i in range(w0.shape[0]):
        e = np.zeros(w0.shape, np.float32)
        e[i] = h
        fd = (float(loss(w0 + e)) - float(loss(w0 - e))) / (2 * h)
        ref_scale = max(abs(fd), float(np.max(np.abs(g))), 1e-4)
        assert abs(g[i] - fd) / ref_scale < 5e-2, (i, g[i], fd)


def test_reweight_requires_reweightable_spec():
    tree = random_tree(20, seed=1)
    spec, _ = ftfi.build(tree, leaf_size=8)
    with pytest.raises(ValueError, match="reweightable"):
        ftfi.reweight(spec, tree.weights)
    rspec, _ = ftfi.build(tree, leaf_size=8, reweightable=True)
    assert rspec.grid_h is None  # a trained metric has no static grid
    with pytest.raises(ValueError, match="edge_w"):
        ftfi.reweight(rspec, np.ones(3))


def test_reweight_forest_packed_edges(rng):
    """Forest reweight: one packed edge vector re-derives every tree's
    block, and per-tree output weights ride along."""
    trees = [random_tree(12, seed=i) for i in range(3)]
    forest = Forest(trees)
    spec, _ = ftfi.build(forest, leaf_size=6, reweightable=True)
    w1 = rng.uniform(0.3, 1.5, size=spec.num_edges)
    off = 0
    new_trees = []
    for t in trees:
        new_trees.append(WeightedTree(t.num_vertices, t.edges_u, t.edges_v,
                                      w1[off:off + t.num_edges]))
        off += t.num_edges
    fn = C.Exponential(-0.5)
    X = rng.normal(size=(forest.num_vertices, 2))
    ref = Integrator.from_forest(Forest(new_trees), backend="host",
                                 leaf_size=6).integrate(fn, X)
    got = ftfi.apply(spec, ftfi.reweight(spec, w1), fn, X)
    assert _rel_err(got, ref) < 1e-5


# ----------------------------------------------------------------------------
# save / load
# ----------------------------------------------------------------------------


def test_save_load_bitwise_roundtrip(tmp_path, rng, monkeypatch):
    tree = random_tree(70, seed=6)
    spec, params = ftfi.build(tree, leaf_size=16)
    fn = C.Exponential(-0.5)
    X = jnp.asarray(rng.normal(size=(70, 3)), jnp.float32)
    a = np.asarray(ftfi.apply(spec, params, fn, X))
    a_jit = np.asarray(jax.jit(ftfi.fastmult(spec, fn))(params, X))
    path = os.path.join(tmp_path, "plan.npz")
    ftfi.save_plan(path, spec, params)

    # loading must NEVER rebuild the IT (the whole point of the artifact)
    import repro.core.itree_flat as itree_flat

    def _boom(*args, **kwargs):
        raise AssertionError("load_plan triggered an IT rebuild")

    monkeypatch.setattr(itree_flat, "_build", _boom)
    spec2, params2 = ftfi.load_plan(path)
    # bit-for-bit in both execution modes: identical arrays in, identical
    # (eager or jitted) program, identical bits out
    b = np.asarray(ftfi.apply(spec2, params2, fn, X))
    assert np.array_equal(a, b)
    b_jit = np.asarray(jax.jit(ftfi.fastmult(spec2, fn))(params2, X))
    assert np.array_equal(a_jit, b_jit)
    assert spec2 == spec and hash(spec2) == hash(spec)
    # the facade path over the artifact is also rebuild-free and exact
    integ = Integrator.from_plan(spec2, params2, backend="plan")
    c = np.asarray(integ.integrate(fn, X))
    assert _rel_err(c, a) < 1e-6


def test_save_load_reweightable_keeps_tables(tmp_path, rng):
    tree = random_tree(24, seed=2)
    spec, params = ftfi.build(tree, leaf_size=8, reweightable=True)
    path = os.path.join(tmp_path, "rw_plan.npz")
    ftfi.save_plan(path, spec, params)
    spec2, _ = ftfi.load_plan(path)
    w1 = rng.uniform(0.4, 1.4, size=tree.num_edges)
    X = rng.normal(size=(24, 2))
    a = ftfi.apply(spec, ftfi.reweight(spec, w1), C.Exponential(-0.7), X)
    b = ftfi.apply(spec2, ftfi.reweight(spec2, w1), C.Exponential(-0.7), X)
    assert np.array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------------------------------
# cache semantics + facade deprecation
# ----------------------------------------------------------------------------


def test_clear_plan_cache_drops_fastmult_memos(rng):
    """Satellite fix: clearing the plan cache must also purge the fastmult
    memos living ON the cached plan objects — a live Integrator previously
    kept every compiled closure reachable after a 'clear'."""
    clear_plan_cache()
    tree = random_tree(30, seed=9)
    integ = Integrator(tree, backend="plan", leaf_size=8)
    with pytest.warns(DeprecationWarning):
        integ.fastmult(C.Exponential(-0.3))
    plan = integ._impl.plan
    assert len(plan._fm_cache) == 1
    assert plan._spec_params is not None
    clear_plan_cache()
    assert len(plan._fm_cache) == 0
    assert plan._spec_params is None
    # the integrator itself keeps working (it holds spec/params directly)
    out = integ.integrate(C.Exponential(-0.3), rng.normal(size=(30, 2)))
    assert np.all(np.isfinite(np.asarray(out)))


def test_facade_fastmult_deprecation_warns():
    integ = Integrator(random_tree(20, seed=0), backend="plan", leaf_size=8)
    with pytest.warns(DeprecationWarning, match="ftfi.fastmult"):
        integ.fastmult(C.Exponential(-0.5))


def test_masks_accept_functional_pair(rng):
    """make_tree_fastmult rides a raw (spec, params) pair — no Integrator,
    no deprecated path."""
    from repro.core import masks as MK
    from repro.graphs.graph import grid_graph
    from repro.graphs.mst import minimum_spanning_tree

    mst = minimum_spanning_tree(grid_graph(5, 5))
    pair = ftfi.build(mst, leaf_size=8)
    integ = Integrator(mst, backend="plan", leaf_size=8)
    coeffs = np.asarray([0.1, -0.4], np.float32)
    X = jnp.asarray(rng.normal(size=(25, 3)), jnp.float32)
    a = MK.make_tree_fastmult(pair, "exp", coeffs, 0.5)(X)
    b = MK.make_tree_fastmult(integ, "exp", coeffs, 0.5)(X)
    assert _rel_err(a, b) < 1e-5
    # memoized per spec for concrete coeffs
    assert (MK.make_tree_fastmult(pair, "exp", coeffs, 0.5)
            is MK.make_tree_fastmult(pair, "exp", coeffs, 0.5))


def test_masks_pair_memo_distinguishes_reweighted_params(rng):
    """Regression: the (spec, params) memo must key on the params too —
    a reweighted PlanParams over the SAME spec is a different mask."""
    from repro.core import masks as MK

    tree = random_tree(30, seed=1)
    spec, p0 = ftfi.build(tree, leaf_size=8, reweightable=True)
    coeffs = np.asarray([0.1, -0.4], np.float32)
    X = jnp.asarray(rng.normal(size=(30, 2)), jnp.float32)
    fm0 = MK.make_tree_fastmult((spec, p0), "exp", coeffs, 0.5)
    p1 = ftfi.reweight(
        spec, rng.uniform(0.3, 1.5, size=tree.num_edges).astype(np.float32))
    fm1 = MK.make_tree_fastmult((spec, p1), "exp", coeffs, 0.5)
    assert fm1 is not fm0
    ref1 = ftfi.apply(spec, p1, MK.mask_f("exp", coeffs, 0.5), X)
    assert _rel_err(fm1(X), ref1) < 1e-6
    assert MK.make_tree_fastmult((spec, p0), "exp", coeffs, 0.5) is fm0
