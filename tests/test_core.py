"""FTFI core: exactness vs the dense oracle — the paper's central claim."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.core import cordial as C
from repro.core.engines import execute_plan, polynomial_batched_matvec
from repro.core.integrate import BTFI, FTFI, compile_plan
from repro.core.integrator_tree import build_integrator_tree, it_stats
from repro.core import approx
from repro.graphs.graph import (caterpillar_tree, grid_graph, path_graph,
                                random_tree, star_tree)
from repro.graphs.mst import minimum_spanning_tree

TREES = [
    lambda: random_tree(157, seed=1),
    lambda: caterpillar_tree(120, seed=2),
    lambda: star_tree(80, seed=3),
    lambda: path_graph(100),
    lambda: minimum_spanning_tree(grid_graph(10, 10, seed=4)),
]

FNS = [
    C.Polynomial((0.5, -0.2, 0.1)),
    C.Exponential(-0.7),
    C.ExpPoly(-0.5, (1.0, 0.3)),
    C.Trigonometric(0.9, 0.1, "cos"),
    C.Trigonometric(1.3, 0.0, "sin"),
    C.Rational((1.0,), (1.0, 0.0, 0.5)),
    C.ExpQuadratic(-0.02, -0.1, 0.0),
    C.ExpRational(-0.3, 0.8),
    C.AnyFn(lambda z: np.log1p(z) * np.exp(-0.2 * z)),
]


@pytest.mark.parametrize("mk", TREES)
@pytest.mark.parametrize("fn", FNS, ids=[type(f).__name__ for f in FNS])
def test_ftfi_equals_btfi(mk, fn, rng):
    tree = mk()
    n = tree.num_vertices
    X = rng.normal(size=(n, 3))
    ref = BTFI(tree).integrate(fn, X)
    got = FTFI(tree, leaf_size=16).integrate(fn, X)
    scale = max(np.max(np.abs(ref)), 1e-12)
    assert np.max(np.abs(got - ref)) / scale < 1e-8


def test_integrator_tree_invariants():
    tree = random_tree(400, seed=7)
    root = build_integrator_tree(tree, leaf_size=16)
    stats = it_stats(root)
    assert stats["balance_ok"]
    assert stats["max_depth"] <= 4 * int(np.ceil(np.log2(400)))

    # pivot sharing + vertex partition at every node
    def walk(node):
        if node.is_leaf:
            return
        assert node.left_ids[0] == node.pivot == node.right_ids[0]
        both = set(node.left_ids) & set(node.right_ids)
        assert both == {node.pivot}
        assert (set(node.left_ids) | set(node.right_ids)
                == set(node.vertex_ids))
        assert node.left_d[0] == 0.0 and node.right_d[0] == 0.0
        walk(node.left)
        walk(node.right)

    walk(root)


def test_plan_matches_recursive_and_grad(rng):
    tree = random_tree(150, seed=5)
    X = rng.normal(size=(150, 2))
    fn = C.Polynomial((0.3, -0.1, 0.05))
    ref = BTFI(tree).integrate(fn, X)
    plan = compile_plan(tree, leaf_size=16)
    coeffs = jnp.array([0.3, -0.1, 0.05])
    bm = lambda *a: polynomial_batched_matvec(coeffs, *a)
    f_eval = lambda z: coeffs[0] + coeffs[1] * z + coeffs[2] * z * z
    got = np.asarray(execute_plan(plan, jnp.asarray(X), f_eval,
                                  batched_matvec=bm))
    assert np.max(np.abs(got - ref)) / np.max(np.abs(ref)) < 1e-5

    # gradient wrt coefficients matches finite differences
    def loss(c):
        bmv = lambda *a: polynomial_batched_matvec(c, *a)
        fe = lambda z: c[0] + c[1] * z + c[2] * z * z
        return jnp.sum(execute_plan(plan, jnp.asarray(X, jnp.float32), fe,
                                    batched_matvec=bmv) ** 2)

    g = jax.grad(loss)(coeffs)
    eps = 1e-3
    for i in range(3):
        fd = (loss(coeffs.at[i].add(eps)) - loss(coeffs.at[i].add(-eps))) / (2 * eps)
        assert abs(float(fd) - float(g[i])) / (abs(float(fd)) + 1e-3) < 5e-2


def test_chebyshev_engine_spectral(rng):
    tree = random_tree(120, seed=9)
    X = rng.normal(size=(120, 2))
    f_np = lambda z: np.exp(-0.4 * z) / (1 + 0.3 * z)
    f_j = lambda z: jnp.exp(-0.4 * z) / (1 + 0.3 * z)
    ref = BTFI(tree).integrate(f_np, X)
    plan = compile_plan(tree, leaf_size=16)
    got = np.asarray(execute_plan(plan, jnp.asarray(X), f_j, degree=32))
    assert np.max(np.abs(got - ref)) / np.max(np.abs(ref)) < 1e-5


# ---------------------------------------------------------------------------
# property-based: structured multiplies == dense, arbitrary inputs
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(a=st.integers(2, 40), b=st.integers(2, 40), seed=st.integers(0, 10**6),
       deg=st.integers(0, 4))
def test_polynomial_matvec_property(a, b, seed, deg):
    r = np.random.default_rng(seed)
    x = r.uniform(0, 5, a)
    y = r.uniform(0, 5, b)
    V = r.normal(size=(b, 2))
    coeffs = r.normal(size=deg + 1)
    got = C.polynomial_matvec(coeffs, x, y, V)
    f = lambda z: sum(c * z**t for t, c in enumerate(coeffs))
    ref = C.dense_matvec(f, x, y, V)
    assert np.allclose(got, ref, rtol=1e-8, atol=1e-8 * max(1, np.abs(ref).max()))


@settings(max_examples=25, deadline=None)
@given(a=st.integers(2, 40), b=st.integers(2, 40), seed=st.integers(0, 10**6),
       lam=st.floats(-2.0, 0.5))
def test_exponential_matvec_property(a, b, seed, lam):
    r = np.random.default_rng(seed)
    x = r.uniform(0, 4, a)
    y = r.uniform(0, 4, b)
    V = r.normal(size=(b, 3))
    got = C.exponential_matvec(lam, x, y, V)
    ref = C.dense_matvec(lambda z: np.exp(lam * z), x, y, V)
    assert np.allclose(got, ref, rtol=1e-9, atol=1e-9 * max(1, np.abs(ref).max()))


@settings(max_examples=15, deadline=None)
@given(a=st.integers(2, 30), b=st.integers(2, 30), seed=st.integers(0, 10**6),
       q=st.integers(1, 4))
def test_hankel_fft_property(a, b, seed, q):
    r = np.random.default_rng(seed)
    h = 1.0 / q
    x = r.integers(0, 30, a) * h
    y = r.integers(0, 30, b) * h
    V = r.normal(size=(b, 2))
    f = lambda z: np.cos(z) / (1 + z)  # arbitrary f: exact on grids
    got = C.hankel_fft_matvec(f, x, y, V, h)
    ref = C.dense_matvec(f, x, y, V)
    assert np.allclose(got, ref, rtol=1e-9, atol=1e-9)


def test_unit_weight_tree_any_f_exact(rng):
    """Paper A.2.3: unit weights -> Hankel -> exact for ANY f."""
    tree = random_tree(200, seed=11, weight_range=(1.0, 1.0))
    X = rng.normal(size=(200, 2))
    fn = C.AnyFn(lambda z: np.sin(z) * np.exp(-0.1 * z) + 1.0 / (1 + z))
    ref = BTFI(tree).integrate(fn, X)
    got = FTFI(tree, leaf_size=16).integrate(fn, X)
    assert np.max(np.abs(got - ref)) / np.max(np.abs(ref)) < 1e-9


def test_cauchy_matvec(rng):
    p = rng.uniform(0.5, 4, 80)
    q = rng.uniform(0.5, 4, 70)
    V = rng.normal(size=(70, 2))
    got = C.cauchy_matvec(p, q, V)
    ref = (1.0 / (p[:, None] + q[None, :])) @ V
    assert np.max(np.abs(got - ref)) / np.max(np.abs(ref)) < 1e-8


def test_rff_and_nufft(rng):
    a, b = 150, 140
    x = rng.uniform(0, 3, a)
    y = rng.uniform(0, 3, b)
    V = rng.normal(size=(b, 2))
    f = lambda z: np.exp(-0.5 * z * z)
    ref = f(x[:, None] + y[None, :]) @ V
    got_nufft = approx.nufft_integrate(f, x, y, V, n_quad=256)
    assert np.max(np.abs(got_nufft - ref)) / np.max(np.abs(ref)) < 1e-6
    got_rff = approx.gaussian_rff_matvec(x, y, V, sigma=1.0, m=4000, seed=1)
    assert np.max(np.abs(got_rff - ref)) / np.max(np.abs(ref)) < 0.1


def test_exp_message_passing_integrator(rng):
    """Beyond-paper: two-pass message passing == BTFI for exponential f."""
    from repro.core.integrate import ExpMP

    for mk in TREES[:3]:
        tree = mk()
        n = tree.num_vertices
        X = rng.normal(size=(n, 3))
        ref = BTFI(tree).integrate(lambda z: 0.7 * np.exp(-0.4 * z), X)
        got = ExpMP(tree).integrate(-0.4, X, scale=0.7)
        assert np.max(np.abs(got - ref)) / np.max(np.abs(ref)) < 1e-10
