"""Deterministic synthetic LM data, host-sharded, with background prefetch.

The stream is a pure function of (seed, host_id, num_hosts, step) so that a
restarted job consumes *exactly* the same batches — the property the
fault-tolerance test asserts (bit-identical resume). The generator mixes a
Markov bigram component with copy spans so that a real LM can actually reduce
loss on it (used by the end-to-end example).
"""
from __future__ import annotations

import queue
import threading

import numpy as np


class SyntheticLMStream:
    def __init__(self, vocab_size: int, batch_size: int, seq_len: int,
                 seed: int = 0, host_id: int = 0, num_hosts: int = 1,
                 prefetch: int = 2, vlm_prefix: int = 0, encdec_src: int = 0,
                 branching: int = 8):
        assert batch_size % num_hosts == 0
        self.vocab = vocab_size
        self.local_batch = batch_size // num_hosts
        self.seq_len = seq_len
        self.seed = seed
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.vlm_prefix = vlm_prefix
        self.encdec_src = encdec_src
        # fixed bigram table (shared across hosts); low branching keeps the
        # transition structure learnable within a few hundred steps
        rng = np.random.default_rng(seed)
        k = min(branching, vocab_size)
        self._succ = rng.integers(0, vocab_size, size=(vocab_size, k))
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._step = 0
        self._thread = None

    def batch_at(self, step: int) -> dict:
        """Deterministic batch for a given global step (resume-safe)."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 4096 + self.host_id)
        B, L = self.local_batch, self.seq_len
        toks = np.empty((B, L), dtype=np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=B)
        choice = rng.integers(0, self._succ.shape[1], size=(B, L))
        for t in range(1, L):
            toks[:, t] = self._succ[toks[:, t - 1], choice[:, t]]
        # copy spans: repeat a chunk to create learnable long-range structure
        span = max(2, L // 8)
        for b in range(B):
            s = rng.integers(0, L - 2 * span)
            toks[b, s + span:s + 2 * span] = toks[b, s:s + span]
        out = {"tokens": toks}
        if self.vlm_prefix:
            out["patch_embeds"] = rng.normal(
                size=(B, self.vlm_prefix, 1024)).astype(np.float32)
        if self.encdec_src:
            out["src_embeds"] = rng.normal(
                size=(B, self.encdec_src, 1024)).astype(np.float32)
        return out

    # -- background prefetch ---------------------------------------------
    def start(self, step: int = 0):
        self._step = step
        self._stop.clear()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()
        return self

    def _worker(self):
        s = self._step
        while not self._stop.is_set():
            try:
                self._q.put((s, self.batch_at(s)), timeout=0.5)
                s += 1
            except queue.Full:
                continue

    def next(self):
        step, batch = self._q.get()
        return step, batch

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2.0)
