from repro.data.synthetic import SyntheticLMStream  # noqa: F401
