"""Sec 4.3: learnable f-distance matrices on tree metrics.

Given a graph G and a spanning tree T, learn a rational f so that
f(d_T(v,w)) ~= d_G(v,w), training on a tiny sample of vertex pairs
(O(100) data points, as in the paper) and evaluating with the relative
Frobenius error eps = ||M_f^T - M_id^G||_F / ||M_id^G||_F.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.graphs.graph import Graph, WeightedTree
from repro.graphs.mst import minimum_spanning_tree
from repro.graphs.traverse import TreeLCA, dijkstra, tree_all_pairs, graph_all_pairs
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update


def rational_apply(params, x):
    """f(x) = poly(num)(x) / (softplus-stabilized poly(den)(x))."""
    num, den = params["num"], params["den"]
    n = jnp.zeros_like(x)
    for c in num[::-1]:
        n = n * x + c
    d = jnp.zeros_like(x)
    for c in den[::-1]:
        d = d * x + c
    return n / (1e-6 + jax.nn.softplus(d))


def sample_training_pairs(g: Graph, tree: WeightedTree, num_pairs: int,
                          seed: int = 0):
    """Tuples (v, w, d_G(v,w), d_T(v,w)). d_G from Dijkstra on sampled
    sources (each data point is O(N log N), as the paper notes)."""
    rng = np.random.default_rng(seed)
    lca = TreeLCA(tree)
    srcs = rng.integers(0, g.num_vertices, size=max(1, num_pairs // 8))
    vs, ws, dg, dt = [], [], [], []
    per_src = int(np.ceil(num_pairs / srcs.size))
    for s in srcs:
        dist_s = dijkstra(g, int(s))
        tgts = rng.integers(0, g.num_vertices, size=per_src)
        for t in tgts:
            if t == s:
                continue
            vs.append(int(s)); ws.append(int(t)); dg.append(dist_s[t])
    vs, ws = np.array(vs), np.array(ws)
    dt = lca.distance(vs, ws)
    return vs, ws, np.array(dg), dt


@dataclasses.dataclass
class FitResult:
    params: dict
    losses: np.ndarray
    rel_frobenius: float | None = None


def fit_rational_f(g: Graph, tree: WeightedTree | None = None,
                   num_deg: int = 2, den_deg: int = 2, num_pairs: int = 100,
                   steps: int = 500, lr: float = 5e-2, seed: int = 0,
                   eval_frobenius: bool = False) -> FitResult:
    if tree is None:
        tree = minimum_spanning_tree(g)
    vs, ws, d_g, d_t = sample_training_pairs(g, tree, num_pairs, seed)
    scale = max(float(d_t.max()), 1e-9)
    xs = jnp.asarray(d_t / scale, jnp.float32)
    ys = jnp.asarray(d_g / scale, jnp.float32)

    params = {
        "num": jnp.asarray(np.r_[0.0, 1.0, np.zeros(max(num_deg - 1, 0))], jnp.float32),
        "den": jnp.asarray(np.r_[1.0, np.zeros(den_deg)], jnp.float32),
    }

    cfg = AdamWConfig(lr=lr, weight_decay=0.0, warmup_steps=10, total_steps=steps,
                      clip_norm=10.0)
    state = adamw_init(params)

    def loss_fn(p):
        pred = rational_apply(p, xs)
        return jnp.mean((pred - ys) ** 2)

    @jax.jit
    def step(p, s):
        l, grads = jax.value_and_grad(loss_fn)(p)
        p, s, _ = adamw_update(grads, s, p, cfg)
        return p, s, l

    losses = []
    for _ in range(steps):
        params, state, l = step(params, state)
        losses.append(float(l))

    res = FitResult(params={k: np.asarray(v) for k, v in params.items()},
                    losses=np.array(losses))
    if eval_frobenius:
        res.rel_frobenius = relative_frobenius_error(g, tree, params, scale)
    return res


def relative_frobenius_error(g: Graph, tree: WeightedTree, params, scale: float
                             ) -> float:
    """eps = ||f(D_T) - D_G||_F / ||D_G||_F (O(N^2): evaluation only)."""
    D_t = tree_all_pairs(tree)
    D_g = graph_all_pairs(g)
    pred = np.asarray(rational_apply(
        {k: jnp.asarray(v) for k, v in params.items()},
        jnp.asarray(D_t / scale, jnp.float32))) * 1.0
    return float(np.linalg.norm(pred * scale - D_g) / np.linalg.norm(D_g))


def tree_metric_frobenius_error(g: Graph, tree: WeightedTree) -> float:
    """Baseline: identity f (raw tree metric) error."""
    D_t = tree_all_pairs(tree)
    D_g = graph_all_pairs(g)
    return float(np.linalg.norm(D_t - D_g) / np.linalg.norm(D_g))
