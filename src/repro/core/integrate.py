"""Tree-field integration: BTFI oracle, recursive FTFI, ExpMP, and the plan
data (compile_plan). The jit plan *executor* lives in repro.core.engines.plan;
the public entry point is repro.core.engines.Integrator.

Correctness invariant (proved in comments below, tested in tests/test_core.py):
the *additive* decomposition counts every ordered pair (v, j) exactly once.

  At internal node nu with children L, R sharing pivot p:
    - recursion on L covers pairs L x L; on R covers R x R;
    - the two cross jobs cover (L\\{p}) x (R\\{p}) and (R\\{p}) x (L\\{p})
      (targets and sources both exclude the pivot);
    - the only overlap is the diagonal pair (p, p), counted twice ->
      one correction of -f(0) X[p] per internal node.
  Across the whole IT: two distinct leaves intersect in at most one vertex
  (a shared pivot), so off-diagonal pairs are never double counted by leaves;
  a pair (u, v), u != v is separated at exactly one IT node (their "meet"),
  so it is covered by exactly one cross job or exactly one leaf; diagonal
  pairs (v, v) appear once per leaf containing v = 1 + #(nodes where v is
  pivot), matched by the per-node corrections.

The recursive evaluator follows the paper's Eq. 2-4 verbatim (pivot kept in
the source group, subtracted via the f(left-d[tau(v)]) X'[0] correction); the
plan executor uses the optimized masked-source form. Both are validated
against the dense BTFI oracle.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.cordial import CordialFn
from repro.core.integrator_tree import ITNode, build_integrator_tree
from repro.core.lru import BoundedLRU
from repro.graphs.graph import WeightedTree
from repro.graphs.traverse import tree_all_pairs


# ----------------------------------------------------------------------------
# BTFI: brute-force oracle (paper's baseline)
# ----------------------------------------------------------------------------


class BTFI:
    """Materialize M_f = f(all-pairs tree distances); multiply densely."""

    def __init__(self, tree: WeightedTree, dtype=np.float64):
        self.dists = tree_all_pairs(tree, dtype=dtype)  # O(N^2) preprocessing

    def integrate(self, fn: Callable, X: np.ndarray) -> np.ndarray:
        return fn(self.dists) @ X


# ----------------------------------------------------------------------------
# FTFI: recursive exact integrator (host / numpy)
# ----------------------------------------------------------------------------


class FTFI:
    """Fast tree-field integrator. Preprocessing = IT construction (once);
    `integrate(fn, X)` is exact for any CordialFn."""

    def __init__(self, tree: WeightedTree, leaf_size: int = 64, seed: int = 0):
        self.n = tree.num_vertices
        self.root = build_integrator_tree(tree, leaf_size=leaf_size, seed=seed)

    def integrate(self, fn: CordialFn, X: np.ndarray) -> np.ndarray:
        X = np.asarray(X)
        squeeze = X.ndim == 1
        if squeeze:
            X = X[:, None]
        # preserve float32 fields: the walk is bandwidth-bound, so dtype
        # halves/doubles end-to-end time for wide fields (e.g. GW plans)
        acc_dtype = X.dtype if X.dtype in (np.float32, np.float64) else np.float64
        out = np.zeros_like(X, dtype=acc_dtype)
        self._walk(self.root, fn, X, out)
        return out[:, 0] if squeeze else out

    def _walk(self, node: ITNode, fn: CordialFn, X: np.ndarray, out: np.ndarray):
        if node.is_leaf:
            out[node.vertex_ids] += fn(node.leaf_dists) @ X[node.vertex_ids]
            return
        p = node.pivot
        # segment layouts are precomputed in build_integrator_tree: ITNode is
        # immutable, so the walk is thread-safe and plans can share one IT
        for src_sorted, starts, tgt_ids, tgt_id_d, tgt_d, src_d in (
            (node.right_sorted_ids, node.right_seg_starts,
             node.left_ids, node.left_id_d, node.left_d, node.right_d),
            (node.left_sorted_ids, node.left_seg_starts,
             node.right_ids, node.right_id_d, node.right_d, node.left_d),
        ):
            # X'[u] = sum over source vertices in distance-group u (Eq. 3);
            # the pivot IS included (group 0), per the paper.
            Xp = np.add.reduceat(X[src_sorted], starts, axis=0).astype(out.dtype)
            # cross values per target distance-group: C @ X' (Eq. 4)
            cross = fn.matvec(tgt_d, src_d, Xp)  # (U_tgt, d)
            # Eq. 4 correction: remove the source-pivot column f(tgt_d) X'[0]
            corr = fn(tgt_d)[:, None] * Xp[0][None, :]
            vals = cross - corr
            # targets exclude the pivot (tgt_ids[0] == pivot by construction)
            out[tgt_ids[1:]] += vals[tgt_id_d[1:]]
        out[p] -= fn.f0 * X[p]  # diagonal (p, p) double-count correction
        self._walk(node.left, fn, X, out)
        self._walk(node.right, fn, X, out)


# ----------------------------------------------------------------------------
# Exponential-kernel specialization: two-pass message passing (beyond-paper)
# ----------------------------------------------------------------------------


class ExpMP:
    """Exact integrator for f(x) = scale * exp(lam * x) on a weighted tree
    via the classic up/down sweep:

      up[v]   = X_v + sum_c e^{lam w_c} up[c]          (subtree mass)
      down[c] = e^{lam w_c} (down[p] + up[p] - e^{lam w_c} up[c])
      out[v]  = up[v] + down[v]

    Two passes over (N, d) — bandwidth-optimal, O(N d) time, no IT needed.
    The multiplicative decomposability that makes this possible is exactly
    the paper's rank-1 cordiality of exp, pushed to its limit."""

    def __init__(self, tree: WeightedTree, root: int = 0):
        from repro.graphs.traverse import tree_bfs_order

        order, parent, parent_w = tree_bfs_order(tree, root)
        self.order = order
        self.parent = parent
        self.parent_w = parent_w

    def integrate(self, lam: float, X: np.ndarray, scale: float = 1.0):
        X = np.asarray(X)
        squeeze = X.ndim == 1
        if squeeze:
            X = X[:, None]
        order, parent, w = self.order, self.parent, self.parent_w
        e = np.exp(lam * w)  # per-vertex edge factor to parent
        up = X.astype(X.dtype if X.dtype in (np.float32, np.float64)
                      else np.float64).copy()
        for v in order[::-1]:
            pv = parent[v]
            if pv >= 0:
                up[pv] += e[v] * up[v]
        down = np.zeros_like(up)
        for v in order[1:]:
            pv = parent[v]
            down[v] = e[v] * (down[pv] + up[pv] - e[v] * up[v])
        out = scale * (up + down)
        return out[:, 0] if squeeze else out


# ----------------------------------------------------------------------------
# Plan compilation: flatten the IT into padded, bucketed, static arrays plus
# concatenated gather/segment/scatter index plans for the fused executor
# ----------------------------------------------------------------------------


@dataclasses.dataclass
class CrossBucket:
    """Group-distance arrays for one size bucket, padded to the bucket maxima
    (the cross-engine inputs). The per-vertex gather/scatter plumbing lives in
    the flat index arrays on `IntegrationPlan`; `src_off`/`tgt_off` locate
    this bucket's (B*U) group block inside those flat layouts.

    `piv` / `tgt_rep` / `src_rep` record, per job row, the pivot vertex and
    one representative vertex per distance group (padding repeats the
    pivot, whose pivot-distance is 0 like the original padding). They let
    the functional API re-derive every distance from edge weights:
    d[b, u] = dist(piv[b], rep[b, u])."""

    tgt_d: np.ndarray  # (B, U_t) float
    tgt_d_mask: np.ndarray  # (B, U_t) bool
    src_d: np.ndarray  # (B, U_s) float
    src_d_mask: np.ndarray  # (B, U_s) bool
    src_off: int = 0  # offset of this bucket's B*U_s groups in the flat X'
    tgt_off: int = 0  # offset of this bucket's B*U_t groups in the flat cross
    piv: np.ndarray | None = None  # (B,) pivot vertex per job row
    tgt_rep: np.ndarray | None = None  # (B, U_t) group representative vertex
    src_rep: np.ndarray | None = None  # (B, U_s)


@dataclasses.dataclass
class LeafBucket:
    ids: np.ndarray  # (B, K)
    mask: np.ndarray  # (B, K)
    dists: np.ndarray  # (B, K, K)


@dataclasses.dataclass
class IntegrationPlan:
    """Static integration plan. Beyond the padded per-bucket engine inputs,
    the whole executor data-flow is precompiled into four flat index arrays:

      X'_flat  = segment_sum(Xpad[src_gather], src_seg)   # one gather+segsum
      cross    = per-bucket engine on X'_flat slices       # one dispatch each
      out     += scatter_add at tgt_scatter of cross[tgt_gather]

    so `execute_plan` is a handful of fused array ops, not a Python loop
    re-wrapping numpy arrays per bucket."""

    n: int
    cross_buckets: list
    leaf_buckets: list
    pivots: np.ndarray  # (P,) vertex ids, one per internal node (with repeats)
    grid_h: float | None = None  # common distance grid (if any) for hankel engine
    # fused executor index arrays (real entries only — no padding, no masks)
    src_gather: np.ndarray | None = None  # (S,) vertex ids into Xpad
    src_seg: np.ndarray | None = None  # (S,) flat source-group index
    n_src_groups: int = 0  # sum over buckets of B*U_s
    tgt_gather: np.ndarray | None = None  # (T,) flat cross-group index
    tgt_scatter: np.ndarray | None = None  # (T,) vertex ids into out
    n_tgt_groups: int = 0  # sum over buckets of B*U_t
    num_cross_jobs: int = 0
    # provenance (stamped by compile_plan / compile_forest_plan): the
    # functional PlanSpec carries these across process/device boundaries
    fingerprint: str = ""
    leaf_size: int = 0
    seed: int = 0
    tree_sizes: tuple = ()
    reweightable: bool = False
    rw: dict | None = None  # reweight tables (LCA + root-path CSR)
    # update tables (stamped by _assemble_plan): IT skeleton + the
    # (bucket, row) coordinates of every cross job and leaf, so
    # `ftfi.update_plan` can patch individual slots without re-deriving the
    # bucketing
    upd: dict | None = None

    def num_jobs(self):
        return self.num_cross_jobs


_PLAN_CACHE = BoundedLRU(32)


def clear_plan_cache() -> None:
    """Drop cached plans AND the memos that live on them.

    Plans carry their jitted-fastmult memo (`_fm_cache`) and functional
    (spec, params) pair (`_spec_params`) so construction amortizes across
    Integrator instances — which also means a live Integrator sharing a
    cached plan keeps those memos alive. Clearing only the LRU would leave
    every compiled closure (and the device arrays it pins) reachable
    through such instances; purge the per-plan memos explicitly so a
    cleared cache actually frees them."""
    for _, plan in _PLAN_CACHE.items():
        fm = getattr(plan, "_fm_cache", None)
        if fm is not None:
            fm.clear()
        if getattr(plan, "_spec_params", None) is not None:
            plan._spec_params = None
    _PLAN_CACHE.clear()


def _side_job_arrays(side, expand_groups: bool):
    """(ids, id_d, d, rep) for one job side. Default: distance-collapsed
    groups, rep=None (only reweightable builds consume representatives, so
    the hot construction path pays nothing for them). Expanded (reweightable
    builds): every vertex is its own group/representative, so re-deriving
    distances per representative stays exact under ANY edge reweighting —
    two vertices that tie under the build weights need not tie under new
    ones."""
    if not expand_groups:
        return side.ids, side.id_d, side.d, None
    k = side.ids.size
    return (side.ids, np.arange(k, dtype=np.int64), side.d[side.id_d],
            side.ids)


def _upd_tables(flat, job_bucket, job_row, leaf_bucket, leaf_row) -> dict:
    """Update tables shared by both assembly paths: the IT skeleton
    (children refs + per-tree roots) and the (bucket, row) coordinate of
    every cross job / leaf, which is all `ftfi.update_plan` needs to walk a
    vertex's IT chain and patch the affected slots in place."""
    root_refs = (flat.root_refs if flat.root_refs is not None
                 else np.array([flat.root_ref], np.int64))
    # int32 end-to-end: node refs are bounded by the node count, and every
    # other PlanSpec index array is already int32 — int64 here doubled the
    # artifact/update-table footprint for nothing (caught by repro.analysis)
    return {"children": flat.children.astype(np.int32),
            "root_refs": np.asarray(root_refs).astype(np.int32),
            "job_bucket": np.asarray(job_bucket, np.int32),
            "job_row": np.asarray(job_row, np.int32),
            "leaf_bucket": np.asarray(leaf_bucket, np.int32),
            "leaf_row": np.asarray(leaf_row, np.int32)}


def _assemble_plan_ref(flat, n: int, detect_grid_spacing: bool,
                       expand_groups: bool = False) -> IntegrationPlan:
    """Reference (per-node Python loop) plan assembly. Kept as the oracle
    the vectorized `_assemble_plan` is tested bitwise-equal against; all
    production paths go through the vectorized assembly."""
    # one job per (node, direction): targets/sources both exclude the pivot
    # (masked-source optimization); distance arrays keep the pivot group 0
    jobs = []
    for i in range(flat.num_internal):
        L, R = flat.left[i], flat.right[i]
        piv = int(L.ids[0])
        for t, s in ((L, R), (R, L)):
            t_ids, t_idd, t_d, t_rep = _side_job_arrays(t, expand_groups)
            s_ids, s_idd, s_d, s_rep = _side_job_arrays(s, expand_groups)
            jobs.append((t_ids[1:], t_idd[1:], t_d, s_ids[1:], s_idd[1:],
                         s_d, t_rep, s_rep, piv))

    # --- bucket cross jobs by ceil(log2(max dim)) => <=2x padding waste
    def bkey(job):
        m = max(job[0].size, job[3].size, 2)
        return int(np.ceil(np.log2(m)))

    buckets: dict[int, list] = {}
    for ji, job in enumerate(jobs):
        buckets.setdefault(bkey(job), []).append((ji, job))

    job_bucket = np.zeros(len(jobs), np.int32)
    job_row = np.zeros(len(jobs), np.int32)
    cross_buckets = []
    src_gather_parts, src_seg_parts = [], []
    tgt_gather_parts, tgt_scatter_parts = [], []
    src_goff = tgt_goff = 0
    for bi, key_b in enumerate(sorted(buckets)):
        bjobs = buckets[key_b]
        Ut = max(j[2].size for _, j in bjobs)
        Us = max(j[5].size for _, j in bjobs)
        B = len(bjobs)
        cb = CrossBucket(
            tgt_d=np.zeros((B, Ut), dtype=np.float64),
            tgt_d_mask=np.zeros((B, Ut), dtype=bool),
            src_d=np.zeros((B, Us), dtype=np.float64),
            src_d_mask=np.zeros((B, Us), dtype=bool),
            src_off=src_goff, tgt_off=tgt_goff,
        )
        if expand_groups:  # only reweightable builds consume rep tables
            cb.piv = np.zeros(B, dtype=np.int32)
            cb.tgt_rep = np.zeros((B, Ut), dtype=np.int32)
            cb.src_rep = np.zeros((B, Us), dtype=np.int32)
        for b, (ji, (t_ids, t_idd, t_d, s_ids, s_idd, s_d, t_rep, s_rep,
                     piv)) in enumerate(bjobs):
            job_bucket[ji] = bi
            job_row[ji] = b
            cb.tgt_d[b, :t_d.size] = t_d
            cb.tgt_d_mask[b, :t_d.size] = True
            cb.src_d[b, :s_d.size] = s_d
            cb.src_d_mask[b, :s_d.size] = True
            if expand_groups:
                cb.piv[b] = piv
                cb.tgt_rep[b, :] = piv  # padding: dist(piv, piv) == 0
                cb.tgt_rep[b, :t_rep.size] = t_rep
                cb.src_rep[b, :] = piv
                cb.src_rep[b, :s_rep.size] = s_rep
            src_gather_parts.append(s_ids)
            src_seg_parts.append(src_goff + b * Us + s_idd)
            tgt_gather_parts.append(tgt_goff + b * Ut + t_idd)
            tgt_scatter_parts.append(t_ids)
        src_goff += B * Us
        tgt_goff += B * Ut
        cross_buckets.append(cb)

    def _cat(parts, dtype):
        return (np.concatenate(parts).astype(dtype) if parts
                else np.zeros(0, dtype))

    # --- leaf buckets by ceil(log2(k)): a mixed-size forest pads each leaf
    # to its size class, not to the global maximum (K^2 padding waste would
    # dominate leaf-heavy forest plans)
    leaf_groups: dict[int, list] = {}
    for li, (ids, D) in enumerate(zip(flat.leaf_ids, flat.leaf_dists)):
        leaf_groups.setdefault(
            int(np.ceil(np.log2(max(ids.size, 2)))), []).append((li, ids, D))
    leaf_bucket = np.zeros(len(flat.leaf_ids), np.int32)
    leaf_row = np.zeros(len(flat.leaf_ids), np.int32)
    leaf_buckets = []
    for bi, key_b in enumerate(sorted(leaf_groups)):
        leaves = leaf_groups[key_b]
        K = max(ids.size for _, ids, _ in leaves)
        B = len(leaves)
        lb = LeafBucket(
            ids=np.full((B, K), n, dtype=np.int32),
            mask=np.zeros((B, K), dtype=bool),
            dists=np.zeros((B, K, K), dtype=np.float64),
        )
        for b, (li, ids, D) in enumerate(leaves):
            leaf_bucket[li] = bi
            leaf_row[li] = b
            k = ids.size
            lb.ids[b, :k] = ids
            lb.mask[b, :k] = True
            lb.dists[b, :k, :k] = D
        leaf_buckets.append(lb)

    h = None
    if detect_grid_spacing:
        from repro.core.cordial import detect_grid
        # one detection over the merged distances reconciles per-tree grids:
        # the common h of a forest is the gcd of its trees' spacings (None if
        # any tree is off-grid or the joint span is FFT-impractical)
        all_d = np.unique(np.concatenate(
            [s.d for i in range(flat.num_internal)
             for s in (flat.left[i], flat.right[i])] or [np.zeros(1)]))
        h = detect_grid(all_d, np.zeros(1))
    return IntegrationPlan(
        n=n, cross_buckets=cross_buckets, leaf_buckets=leaf_buckets,
        pivots=flat.pivots.astype(np.int32), grid_h=h,
        src_gather=_cat(src_gather_parts, np.int32),
        src_seg=_cat(src_seg_parts, np.int32),
        n_src_groups=src_goff,
        tgt_gather=_cat(tgt_gather_parts, np.int32),
        tgt_scatter=_cat(tgt_scatter_parts, np.int32),
        n_tgt_groups=tgt_goff,
        num_cross_jobs=len(jobs),
        upd=_upd_tables(flat, job_bucket, job_row, leaf_bucket, leaf_row),
    )


def _assemble_plan(flat, n: int, detect_grid_spacing: bool,
                   expand_groups: bool = False) -> IntegrationPlan:
    """Flatten a (tree or forest) FlatIT into one IntegrationPlan: cross jobs
    and leaves from EVERY tree share one global index space and are merged
    into the same size-class buckets, so the executor's dispatch count is a
    function of size diversity, not of how many trees the plan covers.

    Vectorized: the per-internal-node Python loop, per-job tuple appends and
    dict-of-lists bucketing of `_assemble_plan_ref` are replaced by array
    ops over the IT's concatenated side CSR (`FlatIT.side_cat` /
    `leaf_cat`) — one stable argsort groups jobs into size-class buckets,
    `np.maximum.reduceat` yields the bucket maxima, and every padded bucket
    array plus all four flat executor index arrays fill through `_ranges`
    scatters, bitwise-identical to the reference output (tested)."""
    from repro.core.itree_flat import _ranges

    num_i = flat.num_internal
    J = 2 * num_i
    sc = flat.side_cat
    k, u = sc["k"], sc["u"]
    kptr, uptr = sc["kptr"], sc["uptr"]
    ids_c, idd_c, d_c = sc["ids"], sc["id_d"], sc["d"]
    # job j's target side IS side j (side 2i = left, 2i+1 = right); its
    # source side is the sibling j ^ 1; both jobs of node i share its pivot
    piv_job = np.repeat(flat.pivots, 2)
    g = k if expand_groups else u  # distance-group count per side (incl piv)
    mem = k - 1  # member count per side (targets/sources exclude the pivot)

    cross_buckets = []
    job_bucket = np.zeros(J, np.int32)
    job_row = np.zeros(J, np.int32)
    src_gather = src_seg = tgt_gather = tgt_scatter = np.zeros(0, np.int64)
    src_goff = tgt_goff = 0
    if J:
        # bucket by ceil(log2(max member count)) => <=2x padding waste;
        # stable sort keeps insertion order within each bucket, matching ref
        bkey = np.ceil(np.log2(np.maximum(
            np.maximum(mem, mem[np.arange(J) ^ 1]), 2))).astype(np.int64)
        order = np.argsort(bkey, kind="stable")
        sib = order ^ 1  # source side of each sorted job
        _, bstarts = np.unique(bkey[order], return_index=True)
        nb = bstarts.size
        bcounts = np.diff(np.r_[bstarts, J])
        Ut = np.maximum.reduceat(g[order], bstarts)
        Us = np.maximum.reduceat(g[sib], bstarts)
        tgt_off = np.zeros(nb + 1, np.int64)
        np.cumsum(bcounts * Ut, out=tgt_off[1:])
        src_off = np.zeros(nb + 1, np.int64)
        np.cumsum(bcounts * Us, out=src_off[1:])
        row = np.arange(J) - np.repeat(bstarts, bcounts)
        bix = np.repeat(np.arange(nb), bcounts)
        job_bucket[order] = bix
        job_row[order] = row

        if expand_groups:  # per-vertex distances: d[id_d], all sides at once
            dvert = d_c[np.repeat(uptr[:-1], k) + idd_c]
        for bi in range(nb):
            lo = int(bstarts[bi])
            hi = lo + int(bcounts[bi])
            js, ss = order[lo:hi], sib[lo:hi]
            B, Utb, Usb = hi - lo, int(Ut[bi]), int(Us[bi])
            cb = CrossBucket(
                tgt_d=np.zeros((B, Utb), dtype=np.float64),
                tgt_d_mask=np.zeros((B, Utb), dtype=bool),
                src_d=np.zeros((B, Usb), dtype=np.float64),
                src_d_mask=np.zeros((B, Usb), dtype=bool),
                src_off=int(src_off[bi]), tgt_off=int(tgt_off[bi]),
            )
            gt, gs = g[js], g[ss]
            rt = np.repeat(np.arange(B), gt)
            ct = _ranges(np.zeros(B, np.int64), gt)
            rs = np.repeat(np.arange(B), gs)
            cs = _ranges(np.zeros(B, np.int64), gs)
            if expand_groups:
                cb.tgt_d[rt, ct] = dvert[_ranges(kptr[js], gt)]
                cb.src_d[rs, cs] = dvert[_ranges(kptr[ss], gs)]
            else:
                cb.tgt_d[rt, ct] = d_c[_ranges(uptr[js], gt)]
                cb.src_d[rs, cs] = d_c[_ranges(uptr[ss], gs)]
            cb.tgt_d_mask[rt, ct] = True
            cb.src_d_mask[rs, cs] = True
            if expand_groups:  # rep tables: padding repeats the pivot
                pj = piv_job[js]
                cb.piv = pj.astype(np.int32)
                cb.tgt_rep = np.repeat(pj, Utb).reshape(B, Utb).astype(
                    np.int32)
                cb.src_rep = np.repeat(pj, Usb).reshape(B, Usb).astype(
                    np.int32)
                cb.tgt_rep[rt, ct] = ids_c[_ranges(kptr[js], gt)]
                cb.src_rep[rs, cs] = ids_c[_ranges(kptr[ss], gs)]
            cross_buckets.append(cb)
        src_goff, tgt_goff = int(src_off[-1]), int(tgt_off[-1])

        # flat executor arrays in (bucket, job) order — one concatenation
        # pass per kind instead of per-job list appends
        mem_t, mem_s = mem[order], mem[sib]
        tjob = tgt_off[bix] + row * Ut[bix]
        sjob = src_off[bix] + row * Us[bix]
        tgt_scatter = ids_c[_ranges(kptr[order] + 1, mem_t)]
        src_gather = ids_c[_ranges(kptr[sib] + 1, mem_s)]
        if expand_groups:  # expanded group index of vertex j is j itself
            tidd = _ranges(np.ones(J, np.int64), mem_t)
            sidd = _ranges(np.ones(J, np.int64), mem_s)
        else:
            tidd = idd_c[_ranges(kptr[order] + 1, mem_t)]
            sidd = idd_c[_ranges(kptr[sib] + 1, mem_s)]
        tgt_gather = np.repeat(tjob, mem_t) + tidd
        src_seg = np.repeat(sjob, mem_s) + sidd

    # --- leaf buckets by ceil(log2(k)): a mixed-size forest pads each leaf
    # to its size class, not to the global maximum (K^2 padding waste would
    # dominate leaf-heavy forest plans)
    lc = flat.leaf_cat
    lk, lptr, ldptr = lc["k"], lc["ptr"], lc["dptr"]
    Lf = lk.size
    leaf_bucket = np.zeros(Lf, np.int32)
    leaf_row = np.zeros(Lf, np.int32)
    leaf_buckets = []
    if Lf:
        lkey = np.ceil(np.log2(np.maximum(lk, 2))).astype(np.int64)
        lorder = np.argsort(lkey, kind="stable")
        _, lstarts = np.unique(lkey[lorder], return_index=True)
        lcounts = np.diff(np.r_[lstarts, Lf])
        leaf_bucket[lorder] = np.repeat(np.arange(lstarts.size), lcounts)
        leaf_row[lorder] = np.arange(Lf) - np.repeat(lstarts, lcounts)
        for bi in range(lstarts.size):
            lv = lorder[int(lstarts[bi]):int(lstarts[bi]) + int(lcounts[bi])]
            ks = lk[lv]
            B, K = lv.size, int(ks.max())
            lb = LeafBucket(
                ids=np.full((B, K), n, dtype=np.int32),
                mask=np.zeros((B, K), dtype=bool),
                dists=np.zeros((B, K, K), dtype=np.float64),
            )
            r = np.repeat(np.arange(B), ks)
            c = _ranges(np.zeros(B, np.int64), ks)
            lb.ids[r, c] = lc["ids"][_ranges(lptr[lv], ks)]
            lb.mask[r, c] = True
            # raveled (row, col) targets of every k_i x k_i block at once
            pw = _ranges(np.zeros(B, np.int64), ks * ks)
            kk = np.repeat(ks, ks * ks)
            pos = (np.repeat(np.arange(B) * K * K, ks * ks)
                   + (pw // kk) * K + pw % kk)
            lb.dists.reshape(-1)[pos] = lc["dflat"][_ranges(ldptr[lv],
                                                            ks * ks)]
            leaf_buckets.append(lb)

    h = None
    if detect_grid_spacing:
        from repro.core.cordial import detect_grid
        # one detection over the merged distances reconciles per-tree grids:
        # the common h of a forest is the gcd of its trees' spacings (None if
        # any tree is off-grid or the joint span is FFT-impractical)
        all_d = np.unique(d_c) if d_c.size else np.zeros(1)
        h = detect_grid(all_d, np.zeros(1))
    return IntegrationPlan(
        n=n, cross_buckets=cross_buckets, leaf_buckets=leaf_buckets,
        pivots=flat.pivots.astype(np.int32), grid_h=h,
        src_gather=src_gather.astype(np.int32),
        src_seg=src_seg.astype(np.int32),
        n_src_groups=src_goff,
        tgt_gather=tgt_gather.astype(np.int32),
        tgt_scatter=tgt_scatter.astype(np.int32),
        n_tgt_groups=tgt_goff,
        num_cross_jobs=J,
        upd=_upd_tables(flat, job_bucket, job_row, leaf_bucket, leaf_row),
    )


def _disk_cache_load(key) -> IntegrationPlan | None:
    """Consult the disk-persistent plan cache (see repro.core.plan_cache):
    a hit reconstructs the plan via `plan_from_spec` — one file read, zero
    IT rebuild. Disabled (None) unless a cache directory is configured."""
    from repro.core import plan_cache

    if not plan_cache.enabled():
        return None
    hit = plan_cache.load(plan_cache.key_str(key))
    if hit is None:
        return None
    from repro.core import plan_api

    return plan_api.plan_from_spec(*hit)


def _disk_cache_store(key, plan: IntegrationPlan) -> None:
    from repro.core import plan_cache

    if not plan_cache.enabled():
        return
    from repro.core import plan_api

    spec, params = plan_api.specialize(plan)
    plan_cache.store(plan_cache.key_str(key), spec, params)


def compile_plan(tree: WeightedTree, leaf_size: int = 64, seed: int = 0,
                 detect_grid_spacing: bool = True, use_cache: bool = True,
                 reweightable: bool = False) -> IntegrationPlan:
    """Compile (or fetch from the content-hash cache) the integration plan.

    Plans are immutable after construction, so repeated `Integrator`
    construction over the same topology (serving, benchmarks, ViT mask
    rebuilds) amortizes to a dict lookup. `seed` is part of the cache key:
    differently-seeded builds must never alias to the first build.

    Cache hierarchy: in-memory BoundedLRU first, then (when the
    `FTFI_PLAN_CACHE` directory is configured) the disk-persistent artifact
    cache — so cold *process* starts over a known topology pay one npz read
    instead of an O(N log N) decomposition. `use_cache=False` bypasses both.

    `reweightable=True` expands distance groups to per-vertex slots, skips
    grid detection (an integer grid would not survive weight training) and
    attaches the LCA / root-path tables `ftfi.reweight` re-derives
    distances from."""
    from repro.core.itree_flat import build_flat_it, tree_fingerprint

    if reweightable:
        detect_grid_spacing = False
    fp = tree_fingerprint(tree)
    if use_cache:
        key = (fp, max(int(leaf_size), 6), int(seed), detect_grid_spacing,
               reweightable)
        hit = _PLAN_CACHE.get(key)
        if hit is not None:
            return hit
        hit = _disk_cache_load(key)
        if hit is not None:
            _PLAN_CACHE.put(key, hit)
            return hit

    flat = build_flat_it(tree, leaf_size=leaf_size, seed=seed,
                         use_cache=use_cache)
    plan = _assemble_plan(flat, tree.num_vertices, detect_grid_spacing,
                          expand_groups=reweightable)
    plan.fingerprint = fp
    plan.leaf_size = max(int(leaf_size), 6)
    plan.seed = int(seed)
    plan.tree_sizes = (tree.num_vertices,)
    plan.reweightable = reweightable
    if reweightable:
        _attach_reweight_tables(plan, [tree])
    if use_cache:
        _PLAN_CACHE.put(key, plan)
        _disk_cache_store(key, plan)
    return plan


def compile_forest_plan(forest, leaf_size: int = 64, seed: int = 0,
                        detect_grid_spacing: bool = True,
                        use_cache: bool = True,
                        reweightable: bool = False) -> IntegrationPlan:
    """Compile a whole `Forest` into ONE IntegrationPlan.

    Per-tree plans are never materialized: the batched flat-IT build decomposes
    all trees in one level sweep, and `_assemble_plan` concatenates their cross
    jobs and leaves into a single global index space (shared `src_gather` /
    `src_seg` / `tgt_gather` / `tgt_scatter`, buckets merged across trees by
    size class, grid_h reconciled over the merged distances). `execute_plan`
    then runs the ENTIRE forest as the same handful of fused gather /
    segment-sum / scatter ops — one jit dispatch for N graphs instead of N.

    The packed field layout is `Forest`'s: vertex v of tree t at row
    `forest.offsets[t] + v`; the multiply is block-diagonal by construction
    (no index from one tree ever references another tree's rows)."""
    import hashlib

    from repro.core.itree_flat import build_flat_forest, tree_fingerprint

    if reweightable:
        detect_grid_spacing = False
    fps = tuple(tree_fingerprint(t) for t in forest.trees)
    if use_cache:
        key = ("forest", fps, max(int(leaf_size), 6), int(seed),
               detect_grid_spacing, reweightable)
        hit = _PLAN_CACHE.get(key)
        if hit is not None:
            return hit
        hit = _disk_cache_load(key)
        if hit is not None:
            _PLAN_CACHE.put(key, hit)
            return hit

    flat = build_flat_forest(forest.trees, leaf_size=leaf_size, seed=seed,
                             use_cache=use_cache)
    plan = _assemble_plan(flat, forest.num_vertices, detect_grid_spacing,
                          expand_groups=reweightable)
    plan.fingerprint = hashlib.sha1(
        "".join(fps).encode()).hexdigest()
    plan.leaf_size = max(int(leaf_size), 6)
    plan.seed = int(seed)
    plan.tree_sizes = tuple(int(s) for s in forest.tree_sizes)
    plan.reweightable = reweightable
    if reweightable:
        _attach_reweight_tables(plan, forest.trees)
    if use_cache:
        _PLAN_CACHE.put(key, plan)
        _disk_cache_store(key, plan)
    return plan


# ----------------------------------------------------------------------------
# reweight tables: everything a differentiable edge_w -> distances map needs
# ----------------------------------------------------------------------------


def _root_path_pairs(trees):
    """(rows, edges): for every vertex v (global packed id), one entry per
    edge on v's root path — so depth[v] = sum of edge_w over v's entries is
    one gather + segment-sum. Edges are numbered in packed per-tree order
    (the concatenation of each tree's `weights` arrays)."""
    from repro.graphs.traverse import tree_bfs_order

    rows_parts, edge_parts = [], []
    voff = eoff = 0
    for t in trees:
        n = t.num_vertices
        _, parent, _ = tree_bfs_order(t, 0)
        eu = t.edges_u.astype(np.int64)
        ev = t.edges_v.astype(np.int64)
        idx = np.arange(eu.size, dtype=np.int64)
        pe = np.full(n, -1, np.int64)  # edge to parent, per non-root vertex
        m = parent[ev] == eu
        pe[ev[m]] = idx[m]
        m = parent[eu] == ev
        pe[eu[m]] = idx[m]
        a = np.flatnonzero(parent >= 0)
        origin = a.copy()
        while a.size:  # climb all root paths one ancestor level at a time
            rows_parts.append(origin + voff)
            edge_parts.append(pe[a] + eoff)
            a = parent[a]
            keep = parent[a] >= 0  # pe[a] valid only for non-root ancestors
            origin, a = origin[keep], a[keep]
        voff += n
        eoff += eu.size
    if not rows_parts:
        return np.zeros(0, np.int32), np.zeros(0, np.int32)
    return (np.concatenate(rows_parts).astype(np.int32),
            np.concatenate(edge_parts).astype(np.int32))


def _forest_lca_query(lcas, offsets, u, v):
    """Elementwise LCA of global vertex pairs (each pair within one tree)."""
    shape = u.shape
    u = np.asarray(u, np.int64).ravel()
    v = np.asarray(v, np.int64).ravel()
    out = np.empty(u.shape, np.int64)
    tid = np.searchsorted(offsets, u, side="right") - 1
    for t in np.unique(tid):
        sel = tid == t
        off = int(offsets[t])
        out[sel] = lcas[t].lca(u[sel] - off, v[sel] - off) + off
    return out.reshape(shape)


def _attach_reweight_tables(plan: IntegrationPlan, trees) -> None:
    """Stamp the LCA tables (cross + leaf) and root-path CSR onto the plan:
    with these, every distance slot is depth[u] + depth[v] - 2 depth[lca],
    a pure (differentiable) function of the edge weights."""
    from repro.graphs.traverse import TreeLCA

    sizes = np.array([t.num_vertices for t in trees], np.int64)
    offsets = np.zeros(sizes.size + 1, np.int64)
    np.cumsum(sizes, out=offsets[1:])
    lcas = [TreeLCA(t) for t in trees]
    n = plan.n

    ctl, csl = [], []
    for cb in plan.cross_buckets:
        pv_t = np.broadcast_to(cb.piv[:, None], cb.tgt_rep.shape)
        pv_s = np.broadcast_to(cb.piv[:, None], cb.src_rep.shape)
        ctl.append(_forest_lca_query(lcas, offsets, pv_t,
                                     cb.tgt_rep).astype(np.int32))
        csl.append(_forest_lca_query(lcas, offsets, pv_s,
                                     cb.src_rep).astype(np.int32))
    ll = []
    for lb in plan.leaf_buckets:
        B, K = lb.ids.shape
        u = np.broadcast_to(lb.ids[:, :, None].astype(np.int64), (B, K, K))
        v = np.broadcast_to(lb.ids[:, None, :].astype(np.int64), (B, K, K))
        valid = (u < n) & (v < n)
        out = np.full((B, K, K), n, np.int64)  # pad -> sentinel depth row
        if valid.any():
            out[valid] = _forest_lca_query(lcas, offsets, u[valid], v[valid])
        ll.append(out.astype(np.int32))
    rows, edges = _root_path_pairs(trees)
    # packed global edge endpoints + build weights: `update_plan` needs the
    # live edge list to validate leaf deletions and to re-derive distances
    # host-side after structural edits
    eu_parts, ev_parts, ew_parts = [], [], []
    for t, off in zip(trees, offsets[:-1]):
        eu_parts.append(t.edges_u.astype(np.int64) + off)
        ev_parts.append(t.edges_v.astype(np.int64) + off)
        ew_parts.append(t.weights.astype(np.float64))
    plan.rw = {"cross_tgt_lca": ctl, "cross_src_lca": csl, "leaf_lca": ll,
               "path_rows": rows, "path_edges": edges,
               "num_edges": int(sum(t.num_edges for t in trees)),
               "edges_u": (np.concatenate(eu_parts).astype(np.int32)
                           if eu_parts else np.zeros(0, np.int32)),
               "edges_v": (np.concatenate(ev_parts).astype(np.int32)
                           if ev_parts else np.zeros(0, np.int32)),
               "edge_w0": (np.concatenate(ew_parts)
                           if ew_parts else np.zeros(0, np.float64))}


# The jax plan *executor* lives in repro.core.engines.plan (execute_plan and
# the batched structured-multiply engines); this module owns only the host-side
# integrators and the plan *data* (compile_plan).
