"""`pallas` backend: plan executor with cross multiplies on the fused
fdist_matvec TPU kernel.

Per-bucket cross jobs (B, U_t) x (B, U_s) are batched straight into
`fdist_matvec_batched` for the in-kernel f families (poly / exp / expq /
rational) — each tile of M is built in VMEM and fed to the MXU, never
materialized in HBM. Engine selection and the executor live in the
functional core (`plan_api.select_cross` routes these families to the
kernel whenever backend == "pallas"); this subclass only carries the kernel
options and keys the shared fastmult memo with them. The kernel consumes
the *params* distance arrays, so it is traceable — and differentiates —
through `ftfi.reweight`ed distances. General f falls back to the exact
Hankel/FFT engine on grid-aligned trees, else batched Chebyshev. Off-TPU
the kernel runs in interpret mode, so results (and tests) are
platform-independent.
"""
from __future__ import annotations

from repro.core.engines.base import register_backend
from repro.core.engines.plan import PlanBackend
from repro.core.plan_api import KERNEL_MODES  # noqa: F401  (legacy location)


@register_backend("pallas")
class PallasBackend(PlanBackend):
    name = "pallas"

    def __init__(self, tree, leaf_size: int = 64, seed: int = 0,
                 degree: int = 32, detect_grid_spacing: bool = True,
                 reweightable: bool = False, use_cache: bool = True,
                 plan=None, blk_a: int = 128, blk_b: int = 128,
                 interpret: bool | None = None):
        super().__init__(tree, leaf_size=leaf_size, seed=seed, degree=degree,
                         detect_grid_spacing=detect_grid_spacing,
                         reweightable=reweightable, use_cache=use_cache,
                         plan=plan)
        self.blk_a = blk_a
        self.blk_b = blk_b
        self.interpret = interpret  # None -> auto (TPU compiled, else interp)

    def _fm_opts_key(self) -> tuple:
        return (self.blk_a, self.blk_b, self.interpret)

    def _pallas_opts(self) -> dict:
        return {"blk_a": self.blk_a, "blk_b": self.blk_b,
                "interpret": self.interpret}
