"""`pallas` backend: plan executor with cross multiplies on the fused
fdist_matvec TPU kernel.

Per-bucket cross jobs (B, U_t) x (B, U_s) are batched straight into
`fdist_matvec_batched` for the in-kernel f families (poly / exp / expq /
rational) — each tile of M is built in VMEM and fed to the MXU, never
materialized in HBM. The segment-summed source field Xp arrives as a static
slice of the executor's single fused segment-sum (see engines.plan), and the
jitted fastmult closure is cached per family spec via the inherited
PlanBackend machinery. General f falls back to the exact Hankel/FFT engine
on grid-aligned trees, else batched Chebyshev. Off-TPU the kernel runs in
interpret mode, so results (and tests) are platform-independent.
"""
from __future__ import annotations

from functools import partial

import numpy as np

from repro.core.engines.base import register_backend
from repro.core.engines.plan import PlanBackend
from repro.core.engines.spec import FamilySpec
from repro.kernels.fdist_matvec.ops import fdist_matvec_batched

KERNEL_MODES = ("poly", "exp", "expq", "rational")


@register_backend("pallas")
class PallasBackend(PlanBackend):
    name = "pallas"

    def __init__(self, tree, leaf_size: int = 64, seed: int = 0,
                 degree: int = 32, detect_grid_spacing: bool = True,
                 blk_a: int = 128, blk_b: int = 128,
                 interpret: bool | None = None):
        super().__init__(tree, leaf_size=leaf_size, seed=seed, degree=degree,
                         detect_grid_spacing=detect_grid_spacing)
        self.blk_a = blk_a
        self.blk_b = blk_b
        self.interpret = interpret  # None -> auto (TPU compiled, else interp)

    def _fm_opts_key(self) -> tuple:
        return (self.blk_a, self.blk_b, self.interpret)

    def select_cross(self, spec: FamilySpec):
        if spec.mode in KERNEL_MODES:
            return (f"fdist_matvec:{spec.mode}",
                    partial(self._fdist_cross, spec))
        return super().select_cross(spec)  # hankel_fft on grids, chebyshev

    def _fdist_cross(self, spec: FamilySpec, cb, Xp):
        import jax.numpy as jnp

        out = fdist_matvec_batched(
            jnp.asarray(cb.tgt_d, jnp.float32),
            jnp.asarray(cb.src_d, jnp.float32),
            Xp.astype(jnp.float32),
            jnp.asarray(np.asarray(spec.coeffs, np.float32)),
            mode=spec.mode, blk_a=self.blk_a, blk_b=self.blk_b,
            interpret=self.interpret)
        # the kernel's rational family is unit-scaled: 1 / (1 + c0 s^2)
        return out * spec.scale if spec.mode == "rational" else out
