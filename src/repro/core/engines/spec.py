"""FamilySpec: one normalized description of f shared by every backend.

Backends dispatch on `mode` — the structured-multiply family of f:

  mode        f(s)                          exact engines available
  ----------  ----------------------------  --------------------------------
  "poly"      sum_t coeffs[t] s^t           polynomial LDR, Pallas in-kernel
  "exp"       coeffs[1] * exp(coeffs[0] s)  rank-1, Pallas in-kernel, ExpMP
  "expq"      exp(c0 s^2 + c1 s + c2)       Pallas in-kernel, Hankel on grids
  "rational"  scale / (1 + c0 s^2)          Pallas in-kernel, Hankel on grids
  None        anything                      Hankel on grids, else Chebyshev

`coeffs` follows the layout of kernels/fdist_matvec (`_f_tile`); `scale` is a
scalar multiplier applied OUTSIDE the kernel families that don't carry one.
`fn_eval` is an xp-traceable evaluation of the full f (scale included) used
for leaf blocks, pivot corrections and the Chebyshev/Hankel fallbacks.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core import cordial as C


@dataclasses.dataclass(frozen=True)
class FamilySpec:
    mode: str | None
    coeffs: tuple
    fn_eval: Callable  # traceable full f (jnp in, jnp out)
    cordial: C.CordialFn  # host-side strategy object (FTFI path)
    scale: float = 1.0


def _horner(coeffs):
    def f(z):
        acc = 0.0
        for c in reversed(coeffs):
            acc = acc * z + c
        return acc

    return f


def spec_of(fn) -> FamilySpec:
    """Classify `fn` (a CordialFn or a plain traceable callable)."""
    import jax.numpy as jnp

    if isinstance(fn, C.Polynomial):
        cs = tuple(float(c) for c in fn.coeffs)
        return FamilySpec("poly", cs, _horner(cs), fn)
    if isinstance(fn, C.Exponential):
        lam, s = float(fn.lam), float(fn.scale)
        return FamilySpec("exp", (lam, s), lambda z: s * jnp.exp(lam * z), fn)
    if isinstance(fn, C.ExpQuadratic):
        u, v, w = float(fn.u), float(fn.v), float(fn.w)
        return FamilySpec(
            "expq", (u, v, w), lambda z: jnp.exp(u * z * z + v * z + w), fn)
    if isinstance(fn, C.Rational):
        num, den = tuple(map(float, fn.num)), tuple(map(float, fn.den))
        if (len(num) == 1 and len(den) == 3 and den[0] > 0.0 and den[1] == 0.0
                and den[2] >= 0.0):
            # a / (d0 + d2 s^2) = (a/d0) * 1/(1 + (d2/d0) s^2)
            c0 = den[2] / den[0]
            scale = num[0] / den[0]
            return FamilySpec(
                "rational", (c0,),
                lambda z: scale / (1.0 + c0 * z * z), fn, scale=scale)
        pn, pd = _horner(num), _horner(den)
        return FamilySpec(None, (), lambda z: pn(z) / pd(z), fn)
    if isinstance(fn, C.ExpPoly):
        lam, cs = float(fn.lam), tuple(map(float, fn.coeffs))
        p = _horner(cs)
        return FamilySpec(None, (), lambda z: jnp.exp(lam * z) * p(z), fn)
    if isinstance(fn, C.Trigonometric):
        om, ph = float(fn.omega), float(fn.phi)
        trig = jnp.cos if fn.kind == "cos" else jnp.sin
        return FamilySpec(None, (), lambda z: trig(om * z + ph), fn)
    if isinstance(fn, C.ExpRational):
        lam, c = float(fn.lam), float(fn.c)
        return FamilySpec(None, (), lambda z: jnp.exp(lam * z) / (z + c), fn)
    if isinstance(fn, C.AnyFn):
        return FamilySpec(None, (), fn.fn, fn)
    if isinstance(fn, C.CordialFn):
        return FamilySpec(None, (), fn, fn)
    if callable(fn):  # plain traceable callable: wrap for the host path
        return FamilySpec(None, (), fn, C.AnyFn(fn))
    raise TypeError(f"cannot build a FamilySpec from {type(fn).__name__}")
