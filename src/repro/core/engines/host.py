"""`host` backend: the recursive numpy FTFI (exact per-node LDR engines).

Per-node structured multiplies come from each CordialFn's own `matvec`
strategy (see core.cordial's engine table). Pure-exponential f additionally
dispatches to the two-pass ExpMP message-passing integrator — O(N d), no IT
walk at all. ITNode is immutable, so one backend instance is thread-safe.
"""
from __future__ import annotations

from typing import Callable

import numpy as np

from repro.core import cordial as C
from repro.core.engines.base import register_backend
from repro.core.engines.spec import spec_of
from repro.core.integrate import FTFI, ExpMP


@register_backend("host")
class HostBackend:
    name = "host"

    def __init__(self, tree, leaf_size: int = 64, seed: int = 0,
                 use_expmp: bool = True):
        self.ftfi = FTFI(tree, leaf_size=leaf_size, seed=seed)
        self._expmp = ExpMP(tree) if use_expmp else None
        self._grid_h = self._detect_grid_h(tree)

    @staticmethod
    def _detect_grid_h(tree):
        """Same semantics as IntegrationPlan.grid_h: grid-aligned edge
        weights AND an FFT-practical span (detect_grid's cap applied to the
        realized distance scale, bounded here by the tree diameter)."""
        from repro.graphs.traverse import tree_distances_from

        h = C.detect_grid(tree.weights, np.zeros(1))
        if h is None or tree.num_vertices < 2:
            return h
        far = int(np.argmax(tree_distances_from(tree, 0)))
        diameter = float(np.max(tree_distances_from(tree, far)))
        return None if diameter / h > 5e6 else h

    @property
    def grid_h(self):
        return self._grid_h

    def describe(self, fn) -> dict:
        spec = spec_of(fn)
        engine = ("exp_message_passing"
                  if spec.mode == "exp" and self._expmp is not None
                  else "recursive_ftfi")
        return {"backend": self.name, "cross_engine": engine,
                "grid_h": self.grid_h}

    def integrate(self, fn, X):
        spec = spec_of(fn)
        if spec.mode == "exp" and self._expmp is not None:
            lam, scale = spec.coeffs
            return self._expmp.integrate(lam, np.asarray(X), scale=scale)
        return self.ftfi.integrate(spec.cordial, np.asarray(X))

    def fastmult(self, fn) -> Callable:
        return lambda X: self.integrate(fn, X)
