"""`host` backend: the recursive numpy FTFI (exact per-node LDR engines).

Per-node structured multiplies come from each CordialFn's own `matvec`
strategy (see core.cordial's engine table). Pure-exponential f additionally
dispatches to the two-pass ExpMP message-passing integrator — O(N d), no IT
walk at all. ITNode is immutable, so one backend instance is thread-safe.
"""
from __future__ import annotations

from typing import Callable

import numpy as np

from repro.core import cordial as C
from repro.core.engines.base import register_backend
from repro.core.engines.spec import spec_of
from repro.core.integrate import FTFI, ExpMP
from repro.graphs.graph import Forest


@register_backend("host")
class HostBackend:
    name = "host"

    def __init__(self, tree, leaf_size: int = 64, seed: int = 0,
                 use_expmp: bool = True):
        # Forests run as a per-tree Python loop here: the host backend is the
        # reference (and the baseline the fused forest plan is benchmarked
        # against), not a fused path.
        self.forest = tree if isinstance(tree, Forest) else None
        if self.forest is not None:
            self._ftfis = [FTFI(t, leaf_size=leaf_size, seed=seed)
                           for t in self.forest.trees]
            self._expmps = ([ExpMP(t) for t in self.forest.trees]
                            if use_expmp else None)
            hs = [self._detect_grid_h(t) for t in self.forest.trees]
            if any(h is None for h in hs):
                self._grid_h = None
            else:
                # the forest's common grid is the gcd of per-tree spacings
                self._grid_h = C.detect_grid(np.asarray(hs), np.zeros(1))
            return
        self.ftfi = FTFI(tree, leaf_size=leaf_size, seed=seed)
        self._expmp = ExpMP(tree) if use_expmp else None
        self._grid_h = self._detect_grid_h(tree)

    @staticmethod
    def _detect_grid_h(tree):
        """Same semantics as IntegrationPlan.grid_h: grid-aligned edge
        weights AND an FFT-practical span (detect_grid's cap applied to the
        realized distance scale, bounded here by the tree diameter)."""
        from repro.graphs.traverse import tree_distances_from

        h = C.detect_grid(tree.weights, np.zeros(1))
        if h is None or tree.num_vertices < 2:
            return h
        far = int(np.argmax(tree_distances_from(tree, 0)))
        diameter = float(np.max(tree_distances_from(tree, far)))
        return None if diameter / h > 5e6 else h

    @property
    def grid_h(self):
        return self._grid_h

    def describe(self, fn) -> dict:
        spec = spec_of(fn)
        use_expmp = (self._expmps if self.forest is not None
                     else self._expmp) is not None
        engine = ("exp_message_passing" if spec.mode == "exp" and use_expmp
                  else "recursive_ftfi")
        d = {"backend": self.name, "cross_engine": engine,
             "grid_h": self.grid_h}
        if self.forest is not None:
            d["num_trees"] = self.forest.num_trees
        return d

    def integrate(self, fn, X):
        spec = spec_of(fn)
        X = np.asarray(X)
        if self.forest is not None:
            off = self.forest.offsets
            outs = []
            for i in range(self.forest.num_trees):
                Xi = X[off[i]:off[i + 1]]
                if spec.mode == "exp" and self._expmps is not None:
                    lam, scale = spec.coeffs
                    outs.append(self._expmps[i].integrate(lam, Xi,
                                                          scale=scale))
                else:
                    outs.append(self._ftfis[i].integrate(spec.cordial, Xi))
            return np.concatenate(outs, axis=0)
        if spec.mode == "exp" and self._expmp is not None:
            lam, scale = spec.coeffs
            return self._expmp.integrate(lam, X, scale=scale)
        return self.ftfi.integrate(spec.cordial, X)

    def fastmult(self, fn) -> Callable:
        return lambda X: self.integrate(fn, X)
