"""`plan` backend: the jit-able IT-plan executor, now a facade over the
functional core (`repro.core.plan_api`).

The executor and the batched cross engines (polynomial / exponential /
hankel_fft / chebyshev) live in `plan_api`; this module keeps the legacy
entry points working on top of them:

  execute_plan(plan, X, fn_eval, ...)   derives the plan's (spec, params)
                                        pair and runs the pure executor
  PlanBackend                           derives (spec, params) lazily from
                                        the compiled plan and caches jitted
                                        closures over plan_api.apply

so every Integrator — and everything stacked on it (masks, ViT grids,
forests, serving) — executes through the same pure
`_execute(spec, params, ...)` path that `ftfi.apply` exposes directly.
"""
from __future__ import annotations

from typing import Callable

from repro.analysis import trace_guard
from repro.core import plan_api
from repro.core.engines.base import register_backend
from repro.core.engines.spec import FamilySpec, spec_of
from repro.core.integrate import (IntegrationPlan, compile_forest_plan,
                                  compile_plan)
# legacy import locations (tests, masks, attention import these from here)
from repro.core.plan_api import (  # noqa: F401
    _lagrange_batched, chebyshev_batched_matvec, exponential_batched_matvec,
    hankel_batched_matvec, polynomial_batched_matvec)
from repro.graphs.graph import Forest


# ----------------------------------------------------------------------------
# executor (legacy entry point over the functional core)
# ----------------------------------------------------------------------------


def execute_plan(plan: IntegrationPlan, X, fn_eval: Callable,
                 batched_matvec: Callable | None = None, degree: int = 32,
                 cross_multiply: Callable | None = None):
    """Integrate field X (n, d) with scalar function `fn_eval` (jnp-traceable).

    Thin shim: splits the plan into its functional (spec, params) pair and
    runs `plan_api._execute`. `cross_multiply(cb, Xp)` (legacy CrossBucket
    form) and `batched_matvec(tgt_d, tgt_mask, src_d, src_mask, Xp)` are
    still accepted; both default to batched Chebyshev interpolation
    (spectral-exact for smooth fn_eval, differentiable w.r.t. fn_eval
    parameters).
    """
    spec, params = plan_api.specialize(plan)
    if cross_multiply is not None:
        legacy = cross_multiply

        def cross(i, tgt_d, tgt_mask, src_d, src_mask, Xp):
            return legacy(plan.cross_buckets[i], Xp)

    elif batched_matvec is not None:
        bm = batched_matvec

        def cross(i, tgt_d, tgt_mask, src_d, src_mask, Xp):
            return bm(tgt_d, tgt_mask, src_d, src_mask, Xp)

    else:
        _, cross = plan_api.select_cross(
            spec, FamilySpec(None, (), fn_eval, None), degree=degree)
    return plan_api._execute(spec, params, fn_eval, cross, X)


# ----------------------------------------------------------------------------
# backend
# ----------------------------------------------------------------------------


def _trace_state_clean() -> bool:
    """True when no jax trace is currently active (safe to memoize)."""
    try:
        import jax

        return jax.core.trace_state_clean()
    except Exception:
        return True


class _PlanFastMult:
    """One cached X -> M_f X closure per (plan, f-family).

    `trace_count` increments once per executor trace (jitted path) or per
    call (eager path): back-to-back jitted calls with the same shapes leave
    it unchanged, which is exactly the no-retrace property the fastmult
    cache exists for."""

    def __init__(self, eager: Callable, jit_compile: bool):
        import jax

        self.trace_count = 0
        self.jitted = bool(jit_compile)

        def counted(X):
            self.trace_count += 1
            if isinstance(X, jax.core.Tracer):  # compile, not an eager call
                trace_guard.record("engines.plan.fastmult")
            return eager(X)

        if jit_compile:
            self._call = jax.jit(counted)
        else:
            self._call = counted

    def __call__(self, X):
        return self._call(X)


@register_backend("plan")
class PlanBackend:
    """Bucketed static-shape executor; cross engine chosen per f family:
    exact polynomial/exponential LDR engines, the exact Hankel/FFT engine on
    grid-aligned trees, Chebyshev interpolation otherwise.

    The (content-cached) plan splits lazily into the functional
    (spec, params) pair — exposed as `.spec` / `.params` for the pure
    `ftfi` entry points — and `fastmult` closures are jitted (when the f
    family is traceable) and cached per family spec, so repeated
    `integrate` calls pay zero re-dispatch/re-trace overhead."""

    name = "plan"

    def __init__(self, tree, leaf_size: int = 64, seed: int = 0,
                 degree: int = 32, detect_grid_spacing: bool = True,
                 reweightable: bool = False, use_cache: bool = True,
                 plan: IntegrationPlan | None = None):
        from repro.core.lru import BoundedLRU

        # a Forest compiles into ONE fused plan over the packed vertex space:
        # the executor below is oblivious to how many trees it covers
        self.forest = tree if isinstance(tree, Forest) else None
        if plan is not None:  # facade-from-artifact path: zero IT rebuild
            self.plan = plan
        elif self.forest is not None:
            self.plan = compile_forest_plan(
                self.forest, leaf_size=leaf_size, seed=seed,
                detect_grid_spacing=detect_grid_spacing,
                use_cache=use_cache, reweightable=reweightable)
        else:
            self.plan = compile_plan(tree, leaf_size=leaf_size, seed=seed,
                                     detect_grid_spacing=detect_grid_spacing,
                                     use_cache=use_cache,
                                     reweightable=reweightable)
        self.degree = degree
        # the semantically-keyed fastmult memo lives ON the plan object:
        # plans are content-hash cached, so repeated Integrator construction
        # over the same topology (bench steady state, serving, mask rebuilds)
        # reuses the compiled closures instead of re-tracing per instance.
        # Keys are prefixed with the backend name + opts (see fastmult), so
        # differently-configured backends sharing one plan never serve each
        # other's closures. Opaque id()-keyed fns stay in a per-instance
        # memo: sharing them would pin arbitrary closures (and whatever they
        # capture) for the plan-cache lifetime instead of the Integrator's.
        cache = getattr(self.plan, "_fm_cache", None)
        if cache is None:
            cache = BoundedLRU(64)
            self.plan._fm_cache = cache
        self._fm_cache = cache
        self._fm_cache_local = BoundedLRU(64)

    # (spec, params) derive lazily from the plan: construction stays pure
    # host-side bookkeeping, and the first integrate/fastmult call (which
    # pays a jit trace anyway) absorbs the one-time specialize + device
    # transfer. `specialize` memoizes on the plan object, so every property
    # access after the first is a tuple unpack.
    @property
    def spec(self):
        return plan_api.specialize(self.plan)[0]

    @property
    def params(self):
        return plan_api.specialize(self.plan)[1]

    @property
    def grid_h(self):
        return self.spec.grid_h

    def _pallas_opts(self) -> dict | None:
        """Kernel options for plan_api.select_cross (pallas subclass)."""
        return None

    def select_cross(self, spec: FamilySpec):
        """(engine_name, cross_multiply) for this f family."""
        return plan_api.select_cross(self.spec, spec, backend=self.name,
                                     degree=self.degree,
                                     pallas_opts=self._pallas_opts())

    def describe(self, fn) -> dict:
        name, _ = self.select_cross(spec_of(fn))
        d = {"backend": self.name, "cross_engine": name,
             "grid_h": self.grid_h}
        # match the host backend: every Forest-built integrator reports its
        # tree count (incl. single-tree forests); from_plan facades report
        # it whenever the spec covers more than one tree
        if self.forest is not None or self.spec.num_trees > 1:
            d["num_trees"] = self.spec.num_trees
        return d

    def integrate(self, fn, X):
        return self.fastmult(fn)(X)

    def _fm_opts_key(self) -> tuple:
        """Backend-specific options that must key the shared per-plan
        fastmult memo (subclasses with extra knobs override)."""
        return ()

    @staticmethod
    def _jit_ok(fn) -> bool:
        """Jit only f families whose fn_eval is built from concrete floats:
        AnyFn / raw callables may close over numpy-only code (or tracers from
        an enclosing jit), so they stay eager — which is still traceable
        inline by an outer jit."""
        from repro.core import cordial as C

        return (isinstance(fn, C.CordialFn)
                and not isinstance(fn, C.AnyFn)
                and type(fn) is not C.CordialFn)

    def _bind(self, fspec: FamilySpec) -> Callable:
        """X -> M_f X over this backend's own (spec, params): the closure
        form of ftfi.fastmult(spec, fn)(params, X)."""
        _, cross = self.select_cross(fspec)
        fe = fspec.fn_eval
        spec, params = self.spec, self.params

        def eager(X):
            return plan_api._execute(spec, params, fe, cross, X)

        return eager

    def fastmult(self, fn) -> Callable:
        """Cached, jit-compiled closure X -> M_f X (plan arrays are
        trace-time constants). Keyed semantically by (mode, coeffs, scale)
        for the structured families — equal f objects share one compiled
        executor — and by object identity for opaque callables. Opaque
        callables built inside an active jit trace (e.g. mask closures over
        traced coefficients) are NOT cached: pinning them would retain the
        trace's tracers, and their id can never produce a future hit."""
        spec = spec_of(fn)
        jit_ok = self._jit_ok(fn)
        if spec.mode is None and not _trace_state_clean():
            return _PlanFastMult(self._bind(spec), jit_compile=False)
        prefix = (self.name,) + self._fm_opts_key()
        if spec.mode is not None:  # semantic key: shared across instances
            cache = self._fm_cache
            key = prefix + (spec.mode, spec.coeffs, spec.scale, self.degree)
        else:  # id key: per instance, freed with this backend
            cache = self._fm_cache_local
            key = prefix + (None, id(fn), self.degree)
        hit = cache.get(key)
        if hit is not None:
            return hit[0]
        fm = _PlanFastMult(self._bind(spec), jit_compile=jit_ok)
        # pin `fn` alongside: id-based keys must not outlive their object
        cache.put(key, (fm, fn))
        return fm
