"""`plan` backend: the jit-able IT-plan executor with pluggable cross engines.

`execute_plan` walks the compiled `IntegrationPlan` buckets (static shapes,
differentiable). The per-bucket cross multiply is a dispatch point:
`cross_multiply(cb, Xp) -> (B, U_t, d)` receives the (numpy) CrossBucket and
the segment-summed source field, so engines can exploit host-side structure
(e.g. the integer grid indices of the Hankel/FFT path) at trace time.

Engines provided here:
  polynomial_batched_matvec   exact, differentiable in coeffs (LDR rank B+1)
  exponential_batched_matvec  exact rank-1 with numerical shift
  hankel_batched_matvec       exact for ANY f when distances are grid-aligned
                              (consumes IntegrationPlan.grid_h)
  chebyshev_batched_matvec    spectral fallback for smooth general f
"""
from __future__ import annotations

import math
from functools import partial
from typing import Callable

import numpy as np

from repro.core.engines.base import register_backend
from repro.core.engines.spec import FamilySpec, spec_of
from repro.core.integrate import (CrossBucket, IntegrationPlan,
                                  compile_forest_plan, compile_plan)
from repro.graphs.graph import Forest


# ----------------------------------------------------------------------------
# executor
# ----------------------------------------------------------------------------


def execute_plan(plan: IntegrationPlan, X, fn_eval: Callable,
                 batched_matvec: Callable | None = None, degree: int = 32,
                 cross_multiply: Callable | None = None):
    """Integrate field X (n, d) with scalar function `fn_eval` (jnp-traceable).

    The cross data-flow is fully precompiled into the plan's flat index
    arrays, so the executor is a single gather + segment-sum (Eq. 3), one
    cross-multiply dispatch per size bucket, and a single gather +
    scatter-add (Eq. 4) — no per-bucket Python re-wrapping of index arrays.

    cross_multiply(cb: CrossBucket, Xp (B, U_s, d)) -> (B, U_t, d): structured
    multiply per bucket. `batched_matvec(tgt_d, tgt_mask, src_d, src_mask, Xp)`
    is the legacy array-level form; both default to batched Chebyshev
    interpolation (spectral-exact for smooth fn_eval, differentiable w.r.t.
    fn_eval parameters).
    """
    import jax
    import jax.numpy as jnp

    if cross_multiply is None:
        if batched_matvec is None:
            batched_matvec = partial(chebyshev_batched_matvec, fn_eval,
                                     degree=degree)
        bm = batched_matvec

        def cross_multiply(cb, Xp):
            return bm(jnp.asarray(cb.tgt_d), jnp.asarray(cb.tgt_d_mask),
                      jnp.asarray(cb.src_d), jnp.asarray(cb.src_d_mask), Xp)

    X = jnp.asarray(X)
    squeeze = X.ndim == 1
    if squeeze:
        X = X[:, None]
    d = X.shape[1]
    Xpad = jnp.concatenate([X, jnp.zeros((1, d), X.dtype)], axis=0)
    out = jnp.zeros_like(Xpad)

    for lb in plan.leaf_buckets:
        Xl = Xpad[lb.ids]  # (B, K, d)
        M = fn_eval(jnp.asarray(lb.dists))  # (B, K, K)
        pair_mask = lb.mask[:, :, None] & lb.mask[:, None, :]
        M = jnp.where(jnp.asarray(pair_mask), M, 0.0)
        contrib = jnp.einsum("bij,bjd->bid", M, Xl)
        out = out.at[lb.ids].add(contrib * lb.mask[:, :, None])

    if plan.cross_buckets:
        # Eq. 3 for every node at once: X'[g] = sum of source-vertex fields
        # per distance group (pivot/pad groups are empty -> zero)
        Xp_flat = jax.ops.segment_sum(Xpad[plan.src_gather], plan.src_seg,
                                      num_segments=plan.n_src_groups)
        parts = []
        for cb in plan.cross_buckets:
            B, Us = cb.src_d.shape
            Ut = cb.tgt_d.shape[1]
            Xp = Xp_flat[cb.src_off:cb.src_off + B * Us].reshape(B, Us, d)
            parts.append(cross_multiply(cb, Xp).reshape(B * Ut, d))
        cross_flat = (jnp.concatenate(parts, axis=0) if len(parts) > 1
                      else parts[0])
        # Eq. 4 for every node at once: gather each target's group value and
        # scatter-add into the output field
        out = out.at[plan.tgt_scatter].add(cross_flat[plan.tgt_gather])

    # diagonal corrections: -f(0) X[p] once per internal node
    f0 = fn_eval(jnp.zeros((1,)))[0]
    out = out.at[plan.pivots].add(-f0 * Xpad[plan.pivots])

    res = out[:-1]
    return res[:, 0] if squeeze else res


# ----------------------------------------------------------------------------
# batched cross engines
# ----------------------------------------------------------------------------


def chebyshev_batched_matvec(fn_eval, tgt_d, tgt_mask, src_d, src_mask, Xp,
                             degree: int = 32):
    """Batched low-rank multiply via per-node 2D Chebyshev interpolation."""
    import jax.numpy as jnp

    big = 1e30
    x_lo = jnp.min(jnp.where(tgt_mask, tgt_d, big), axis=1)  # (B,)
    x_hi = jnp.max(jnp.where(tgt_mask, tgt_d, -big), axis=1)
    y_lo = jnp.min(jnp.where(src_mask, src_d, big), axis=1)
    y_hi = jnp.max(jnp.where(src_mask, src_d, -big), axis=1)
    r = degree
    k = np.arange(r)
    t = np.cos((2 * k + 1) * np.pi / (2 * r))  # (r,)
    xc = (x_lo[:, None] + x_hi[:, None]) / 2 + (x_hi - x_lo)[:, None] / 2 * t  # (B, r)
    yc = (y_lo[:, None] + y_hi[:, None]) / 2 + (y_hi - y_lo)[:, None] / 2 * t
    Bmat = fn_eval(xc[:, :, None] + yc[:, None, :])  # (B, r, r)
    Lx = _lagrange_batched(tgt_d, xc)  # (B, Kx, r)
    Ly = _lagrange_batched(src_d, yc)  # (B, Ky, r)
    tmp = jnp.einsum("bkr,bkd->brd", Ly, Xp)
    tmp = jnp.einsum("bqr,brd->bqd", Bmat, tmp)
    return jnp.einsum("bkq,bqd->bkd", Lx, tmp)


def _lagrange_batched(pts, nodes):
    import jax.numpy as jnp

    r = nodes.shape[1]
    k = np.arange(r)
    w = ((-1.0) ** k) * np.sin((2 * k + 1) * np.pi / (2 * r))  # (r,)
    diff = pts[:, :, None] - nodes[:, None, :]  # (B, K, r)
    small = jnp.abs(diff) < 1e-12
    diff = jnp.where(small, 1.0, diff)
    terms = w[None, None, :] / diff
    L = terms / jnp.sum(terms, axis=-1, keepdims=True)
    any_small = jnp.any(small, axis=-1, keepdims=True)
    return jnp.where(any_small, small.astype(L.dtype), L)


def polynomial_batched_matvec(coeffs, tgt_d, tgt_mask, src_d, src_mask, Xp):
    """Exact batched multiply for f = polynomial(coeffs) — differentiable
    w.r.t. coeffs. O((Kt+Ks) * deg) per node."""
    import jax.numpy as jnp

    coeffs = jnp.asarray(coeffs)
    Bdeg = coeffs.shape[0] - 1
    xpow = _powers_b(tgt_d, Bdeg)  # (B, Kt, deg+1)
    ypow = _powers_b(src_d, Bdeg)  # (B, Ks, deg+1)
    ypow = ypow * src_mask[:, :, None]
    S = jnp.einsum("bku,bkd->bud", ypow, Xp)  # (B, deg+1, d)
    Wrows = []
    for l in range(Bdeg + 1):
        acc = 0.0
        for tt in range(l, Bdeg + 1):
            acc = acc + coeffs[tt] * math.comb(tt, l) * S[:, tt - l]
        Wrows.append(acc)
    W = jnp.stack(Wrows, axis=1)  # (B, deg+1, d)
    return jnp.einsum("bkl,bld->bkd", xpow, W)


def _powers_b(x, B):
    import jax.numpy as jnp

    pows = [jnp.ones_like(x)]
    for _ in range(B):
        pows.append(pows[-1] * x)
    return jnp.stack(pows, axis=-1)


def exponential_batched_matvec(lam, scale, tgt_d, tgt_mask, src_d, src_mask,
                               Xp):
    """Exact rank-1 multiply for f = scale * exp(lam s), numerically shifted.
    Padded source groups carry zero mass in Xp, so no source mask is needed."""
    import jax.numpy as jnp

    ly = lam * src_d  # (B, Us)
    m = jnp.max(jnp.where(src_mask, ly, -jnp.inf), axis=1, keepdims=True)
    t = jnp.einsum("bu,bud->bd", jnp.exp(ly - m) * src_mask, Xp)  # (B, d)
    return scale * jnp.exp(lam * tgt_d + m)[:, :, None] * t[:, None, :]


def hankel_batched_matvec(fn_eval, h: float, cb: CrossBucket, Xp):
    """Exact multiply for ANY f on grid-aligned distances (spacing h).

    The integer grid indices come from the host-side (numpy) bucket arrays,
    so every shape below is static under jit: M embeds into a Hankel matrix
    and the multiply becomes an FFT correlation with F[k] = f(k h) — the
    paper's rational-weight embedding (App. A.2.3), batched over IT nodes.
    """
    import jax.numpy as jnp

    it = np.rint(cb.tgt_d / h).astype(np.int64)  # (B, Ut); padded -> 0
    isrc = np.rint(cb.src_d / h).astype(np.int64)  # (B, Us)
    Ms = int(isrc.max()) + 1 if isrc.size else 1
    L = (int(it.max()) if it.size else 0) + Ms  # covers all k + m
    F = fn_eval(h * jnp.arange(L, dtype=Xp.dtype))  # (L,)
    B, Us, d = Xp.shape
    bidx = np.arange(B)[:, None]
    # scatter source mass onto the grid: P[b, m] = sum_{u: isrc[b,u]=m} Xp[b,u]
    P = jnp.zeros((B, Ms, d), Xp.dtype).at[bidx, isrc].add(Xp)
    n = 1 << int(np.ceil(np.log2(L + Ms)))
    Ff = jnp.fft.rfft(F, n=n)  # (n//2+1,)
    Pf = jnp.fft.rfft(P[:, ::-1], n=n, axis=1)  # (B, n//2+1, d)
    full = jnp.fft.irfft(Ff[None, :, None] * Pf, n=n, axis=1)
    out_full = full[:, Ms - 1 : Ms - 1 + L]  # (B, L, d): out[b,k]=sum F[k+m]P[m]
    return jnp.take_along_axis(out_full, jnp.asarray(it)[:, :, None], axis=1)


# ----------------------------------------------------------------------------
# backend
# ----------------------------------------------------------------------------


def _trace_state_clean() -> bool:
    """True when no jax trace is currently active (safe to memoize)."""
    try:
        import jax

        return jax.core.trace_state_clean()
    except Exception:
        return True


class _PlanFastMult:
    """One cached X -> M_f X closure per (plan, f-family).

    `trace_count` increments once per executor trace (jitted path) or per
    call (eager path): back-to-back jitted calls with the same shapes leave
    it unchanged, which is exactly the no-retrace property the fastmult
    cache exists for."""

    def __init__(self, eager: Callable, jit_compile: bool):
        self.trace_count = 0
        self.jitted = bool(jit_compile)

        def counted(X):
            self.trace_count += 1
            return eager(X)

        if jit_compile:
            import jax

            self._call = jax.jit(counted)
        else:
            self._call = counted

    def __call__(self, X):
        return self._call(X)


@register_backend("plan")
class PlanBackend:
    """Bucketed static-shape executor; cross engine chosen per f family:
    exact polynomial/exponential LDR engines, the exact Hankel/FFT engine on
    grid-aligned trees, Chebyshev interpolation otherwise.

    `fastmult` closures are jitted (when the f family is traceable) and
    cached per family spec, so repeated `integrate` calls pay zero
    re-dispatch/re-trace overhead."""

    name = "plan"

    def __init__(self, tree, leaf_size: int = 64, seed: int = 0,
                 degree: int = 32, detect_grid_spacing: bool = True):
        from repro.core.lru import BoundedLRU

        # a Forest compiles into ONE fused plan over the packed vertex space:
        # the executor below is oblivious to how many trees it covers
        self.forest = tree if isinstance(tree, Forest) else None
        if self.forest is not None:
            self.plan = compile_forest_plan(
                self.forest, leaf_size=leaf_size, seed=seed,
                detect_grid_spacing=detect_grid_spacing)
        else:
            self.plan = compile_plan(tree, leaf_size=leaf_size, seed=seed,
                                     detect_grid_spacing=detect_grid_spacing)
        self.degree = degree
        # the semantically-keyed fastmult memo lives ON the plan object:
        # plans are content-hash cached, so repeated Integrator construction
        # over the same topology (bench steady state, serving, mask rebuilds)
        # reuses the compiled closures instead of re-tracing per instance.
        # Keys are prefixed with the backend name + opts (see fastmult), so
        # differently-configured backends sharing one plan never serve each
        # other's closures. Opaque id()-keyed fns stay in a per-instance
        # memo: sharing them would pin arbitrary closures (and whatever they
        # capture) for the plan-cache lifetime instead of the Integrator's.
        cache = getattr(self.plan, "_fm_cache", None)
        if cache is None:
            cache = BoundedLRU(64)
            self.plan._fm_cache = cache
        self._fm_cache = cache
        self._fm_cache_local = BoundedLRU(64)

    @property
    def grid_h(self):
        return self.plan.grid_h

    def select_cross(self, spec: FamilySpec):
        """(engine_name, cross_multiply) for this f family."""
        if spec.mode == "poly":
            return "polynomial", partial(self._bm, partial(
                polynomial_batched_matvec, spec.coeffs))
        if spec.mode == "exp":
            return "exponential", partial(self._bm, partial(
                exponential_batched_matvec, spec.coeffs[0], spec.coeffs[1]))
        if self.grid_h is not None:
            return "hankel_fft", partial(hankel_batched_matvec, spec.fn_eval,
                                         self.grid_h)
        return "chebyshev", partial(self._bm, partial(
            chebyshev_batched_matvec, spec.fn_eval, degree=self.degree))

    @staticmethod
    def _bm(batched_matvec, cb, Xp):
        import jax.numpy as jnp

        return batched_matvec(jnp.asarray(cb.tgt_d),
                              jnp.asarray(cb.tgt_d_mask),
                              jnp.asarray(cb.src_d),
                              jnp.asarray(cb.src_d_mask), Xp)

    def describe(self, fn) -> dict:
        name, _ = self.select_cross(spec_of(fn))
        d = {"backend": self.name, "cross_engine": name,
             "grid_h": self.grid_h}
        if self.forest is not None:
            d["num_trees"] = self.forest.num_trees
        return d

    def integrate(self, fn, X):
        return self.fastmult(fn)(X)

    def _fm_opts_key(self) -> tuple:
        """Backend-specific options that must key the shared per-plan
        fastmult memo (subclasses with extra knobs override)."""
        return ()

    @staticmethod
    def _jit_ok(fn) -> bool:
        """Jit only f families whose fn_eval is built from concrete floats:
        AnyFn / raw callables may close over numpy-only code (or tracers from
        an enclosing jit), so they stay eager — which is still traceable
        inline by an outer jit."""
        from repro.core import cordial as C

        return (isinstance(fn, C.CordialFn)
                and not isinstance(fn, C.AnyFn)
                and type(fn) is not C.CordialFn)

    def fastmult(self, fn) -> Callable:
        """Cached, jit-compiled closure X -> M_f X (plan arrays are
        trace-time constants). Keyed semantically by (mode, coeffs, scale)
        for the structured families — equal f objects share one compiled
        executor — and by object identity for opaque callables. Opaque
        callables built inside an active jit trace (e.g. mask closures over
        traced coefficients) are NOT cached: pinning them would retain the
        trace's tracers, and their id can never produce a future hit."""
        spec = spec_of(fn)
        jit_ok = self._jit_ok(fn)
        if spec.mode is None and not _trace_state_clean():
            _, cross = self.select_cross(spec)
            return _PlanFastMult(
                partial(execute_plan, self.plan, fn_eval=spec.fn_eval,
                        cross_multiply=cross, degree=self.degree),
                jit_compile=False)
        prefix = (self.name,) + self._fm_opts_key()
        if spec.mode is not None:  # semantic key: shared across instances
            cache = self._fm_cache
            key = prefix + (spec.mode, spec.coeffs, spec.scale, self.degree)
        else:  # id key: per instance, freed with this backend
            cache = self._fm_cache_local
            key = prefix + (None, id(fn), self.degree)
        hit = cache.get(key)
        if hit is not None:
            return hit[0]
        _, cross = self.select_cross(spec)
        eager = partial(execute_plan, self.plan, fn_eval=spec.fn_eval,
                        cross_multiply=cross, degree=self.degree)
        fm = _PlanFastMult(eager, jit_compile=jit_ok)
        # pin `fn` alongside: id-based keys must not outlive their object
        cache.put(key, (fm, fn))
        return fm
