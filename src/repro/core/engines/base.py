"""Backend registry + the `Integrator` entry point.

Every integration backend registers itself under a short name and implements:

    __init__(tree, leaf_size=..., seed=..., **opts)
    integrate(fn, X) -> out          # fn: CordialFn or traceable callable
    fastmult(fn) -> Callable[X, out] # jit-able where the backend allows
    describe(fn) -> dict             # chosen cross engine etc. (introspection)
    grid_h -> float | None           # common distance grid, if any

`Integrator(tree, backend="plan").integrate(fn, X)` is the one public API;
`Integrator.from_forest(forest, ...)` is the same API over a packed Forest
of trees (one fused plan, block-diagonal multiply). Later PRs (sharded
plans, GPU backends) plug in as additional registered backends.
"""
from __future__ import annotations

from typing import Callable, Type

_REGISTRY: dict[str, type] = {}


def register_backend(name: str) -> Callable[[type], type]:
    def deco(cls: type) -> type:
        _REGISTRY[name] = cls
        return cls

    return deco


def get_backend(name: str) -> Type:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; available: {available_backends()}"
        ) from None


def available_backends() -> list[str]:
    return sorted(_REGISTRY)


class Integrator:
    """Unified tree-field integrator with swappable structured-multiply
    backends.

    >>> integ = Integrator(tree, backend="pallas")
    >>> out = integ.integrate(Exponential(-0.5), X)   # == BTFI, fast
    >>> fm = integ.fastmult(fn_eval)                  # jit-able X -> M_f X
    """

    def __init__(self, tree, backend: str = "plan", *, leaf_size: int = 64,
                 seed: int = 0, **opts):
        self.backend = backend
        self._impl = get_backend(backend)(tree, leaf_size=leaf_size,
                                          seed=seed, **opts)

    @classmethod
    def from_forest(cls, forest, backend: str = "plan", *,
                    leaf_size: int = 64, seed: int = 0, **opts):
        """Integrator over a whole `Forest` of trees with the packed-field
        API: fields are (sum_t n_t, d), vertex v of tree t at row
        `forest.offsets[t] + v` (see `Forest.pack`/`unpack`/`broadcast`).

        On the plan/pallas backends the forest compiles into ONE fused
        IntegrationPlan — `integrate`/`fastmult` run every tree in the same
        handful of gather/segment-sum/scatter dispatches (one jit call for N
        graphs instead of N). The host backend runs a per-tree reference
        loop, which is also the baseline the fused path is benchmarked
        against.

        >>> forest = Forest([mst(g) for g in graphs])
        >>> integ = Integrator.from_forest(forest, backend="plan")
        >>> out = integ.integrate(Exponential(-0.5), forest.pack(fields))
        """
        from repro.graphs.graph import Forest

        if not isinstance(forest, Forest):
            raise TypeError(
                f"from_forest expects a Forest, got {type(forest).__name__}; "
                "wrap your trees: Integrator.from_forest(Forest(trees))")
        return cls(forest, backend=backend, leaf_size=leaf_size, seed=seed,
                   **opts)

    @property
    def num_trees(self):
        """Number of trees (1 for single-tree integrators)."""
        forest = getattr(self._impl, "forest", None)
        return forest.num_trees if forest is not None else 1

    @property
    def grid_h(self):
        """Common grid spacing of all IT distances (None if not grid-aligned).
        Grid-weight trees (e.g. unit-weight MSTs) auto-select the exact
        Hankel/FFT cross engine for otherwise-unstructured f."""
        return self._impl.grid_h

    def integrate(self, fn, X):
        return self._impl.integrate(fn, X)

    def fastmult(self, fn) -> Callable:
        return self._impl.fastmult(fn)

    def describe(self, fn) -> dict:
        return self._impl.describe(fn)

    def __repr__(self):
        return f"Integrator(backend={self.backend!r}, grid_h={self.grid_h})"
