"""Backend registry + the `Integrator` entry point.

Every integration backend registers itself under a short name and implements:

    __init__(tree, leaf_size=..., seed=..., **opts)
    integrate(fn, X) -> out          # fn: CordialFn or traceable callable
    fastmult(fn) -> Callable[X, out] # jit-able where the backend allows
    describe(fn) -> dict             # chosen cross engine etc. (introspection)
    grid_h -> float | None           # common distance grid, if any

`Integrator(tree, backend="plan").integrate(fn, X)` is the one public API;
`Integrator.from_forest(forest, ...)` is the same API over a packed Forest
of trees (one fused plan, block-diagonal multiply). Later PRs (sharded
plans, GPU backends) plug in as additional registered backends.
"""
from __future__ import annotations

import warnings
from typing import Callable, Type

_REGISTRY: dict[str, type] = {}


def register_backend(name: str) -> Callable[[type], type]:
    def deco(cls: type) -> type:
        _REGISTRY[name] = cls
        return cls

    return deco


def get_backend(name: str) -> Type:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; available: {available_backends()}"
        ) from None


def available_backends() -> list[str]:
    return sorted(_REGISTRY)


class Integrator:
    """Unified tree-field integrator with swappable structured-multiply
    backends.

    >>> integ = Integrator(tree, backend="pallas")
    >>> out = integ.integrate(Exponential(-0.5), X)   # == BTFI, fast
    >>> fm = integ.fastmult(fn_eval)                  # jit-able X -> M_f X
    """

    def __init__(self, tree, backend: str = "plan", *, leaf_size: int = 64,
                 seed: int = 0, **opts):
        self.backend = backend
        self._impl = get_backend(backend)(tree, leaf_size=leaf_size,
                                          seed=seed, **opts)

    @classmethod
    def from_forest(cls, forest, backend: str = "plan", *,
                    leaf_size: int = 64, seed: int = 0, **opts):
        """Integrator over a whole `Forest` of trees with the packed-field
        API: fields are (sum_t n_t, d), vertex v of tree t at row
        `forest.offsets[t] + v` (see `Forest.pack`/`unpack`/`broadcast`).

        On the plan/pallas backends the forest compiles into ONE fused
        IntegrationPlan — `integrate`/`fastmult` run every tree in the same
        handful of gather/segment-sum/scatter dispatches (one jit call for N
        graphs instead of N). The host backend runs a per-tree reference
        loop, which is also the baseline the fused path is benchmarked
        against.

        >>> forest = Forest([mst(g) for g in graphs])
        >>> integ = Integrator.from_forest(forest, backend="plan")
        >>> out = integ.integrate(Exponential(-0.5), forest.pack(fields))
        """
        from repro.graphs.graph import Forest

        if not isinstance(forest, Forest):
            raise TypeError(
                f"from_forest expects a Forest, got {type(forest).__name__}; "
                "wrap your trees: Integrator.from_forest(Forest(trees))")
        return cls(forest, backend=backend, leaf_size=leaf_size, seed=seed,
                   **opts)

    @classmethod
    def from_plan(cls, spec, params=None, backend: str = "plan", **opts):
        """Facade over a functional (spec, params) pair — e.g. an
        `ftfi.load_plan` artifact. Never touches the IT/plan builders, so a
        serving restart pays one file read instead of an O(N log N)
        decomposition. The pair is passed through the plan guard first
        (FTFI_PLAN_GUARD policy): this is the other door untrusted
        artifacts enter through, and the fused executor does no bounds
        checking of its own."""
        if backend not in ("plan", "pallas"):
            raise ValueError(
                f"from_plan supports the plan/pallas backends, not "
                f"{backend!r} (the host backend has no plan to load)")
        from repro.core import plan_api, plan_guard

        plan_guard.validate(spec, params, where="Integrator.from_plan")

        obj = cls.__new__(cls)
        obj.backend = backend
        obj._impl = get_backend(backend)(
            None, plan=plan_api.plan_from_spec(spec, params), **opts)
        return obj

    @property
    def spec(self):
        """Static `PlanSpec` of the compiled plan (None on the host
        backend) — the functional half consumed by `ftfi.apply`."""
        return getattr(self._impl, "spec", None)

    @property
    def params(self):
        """Dynamic `PlanParams` (None on the host backend)."""
        return getattr(self._impl, "params", None)

    @property
    def num_trees(self):
        """Number of trees (1 for single-tree integrators)."""
        forest = getattr(self._impl, "forest", None)
        if forest is not None:
            return forest.num_trees
        spec = getattr(self._impl, "spec", None)
        return spec.num_trees if spec is not None else 1

    @property
    def grid_h(self):
        """Common grid spacing of all IT distances (None if not grid-aligned).
        Grid-weight trees (e.g. unit-weight MSTs) auto-select the exact
        Hankel/FFT cross engine for otherwise-unstructured f."""
        return self._impl.grid_h

    def integrate(self, fn, X):
        return self._impl.integrate(fn, X)

    def fastmult(self, fn) -> Callable:
        """Deprecated closure-capturing path: the returned X -> M_f X
        closure captures plan state invisibly to jit/grad/vmap. Migrate to
        the functional API — `ftfi.fastmult(integ.spec, fn)(integ.params,
        X)` — which passes params explicitly (differentiable, shardable,
        serializable)."""
        warnings.warn(
            "Integrator.fastmult returns a plan-capturing closure; use "
            "ftfi.fastmult(spec, fn) with (spec, params) = ftfi.build(tree) "
            "instead", DeprecationWarning, stacklevel=2)
        return self._impl.fastmult(fn)

    def describe(self, fn) -> dict:
        return self._impl.describe(fn)

    def __repr__(self):
        return f"Integrator(backend={self.backend!r}, grid_h={self.grid_h})"
