"""Pluggable integration backends behind one `Integrator` API.

    graphs -> IntegratorTree -> IntegrationPlan -> engines -> kernels

Backends (see each module's docstring for the engine matrix):
  host    recursive numpy FTFI + ExpMP       exact, thread-safe, no jax
  plan    bucketed jit-able plan executor    exact LDR engines + Chebyshev
  pallas  plan executor on fdist_matvec      fused TPU kernel for poly/exp/
                                             expq/rational, Hankel on grids
"""
from repro.core.engines.base import (  # noqa: F401
    Integrator, available_backends, get_backend, register_backend,
)
from repro.core.engines.spec import FamilySpec, spec_of  # noqa: F401
from repro.core.engines.plan import (  # noqa: F401
    PlanBackend, chebyshev_batched_matvec, execute_plan,
    exponential_batched_matvec, hankel_batched_matvec,
    polynomial_batched_matvec,
)
from repro.core.engines.host import HostBackend  # noqa: F401
from repro.core.engines.pallas import PallasBackend  # noqa: F401
