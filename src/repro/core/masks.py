"""Topological RPE masks for linear attention (paper Sec 4.4 + Alg. 1, App. C).

The mask is M = [f(dist(i,j))] with f = g(sum_t a_t x^t) and (a_t) learnable —
**3 extra scalars** per layer (synced) or per head (asynced). FastMult_M:
  - sequences (LM archs): Toeplitz FFT, exact for any f (core.toeplitz);
  - grids/graphs (ViT):   IT-plan executor, exact engines (core.integrate);
  - many graphs at once:  make_forest_fastmult over a packed Forest — each
    request's own mask applied block-diagonally in ONE fused dispatch.

Decode: for separable f (g=exp & t<=1, or g=identity polynomial), the cross
term f(i-j) = sum_r alpha_r(i) beta_r(j) splits, so masked linear attention
admits an O(1)-per-token recurrent state (beyond-paper; DESIGN §3).
"""
from __future__ import annotations

import dataclasses
import math
import weakref
from typing import Callable

import numpy as np
import jax
import jax.numpy as jnp

from repro.analysis import trace_guard
from repro.core.lru import BoundedLRU
from repro.core.toeplitz import causal_toeplitz_matvec, symmetric_toeplitz_matvec


# ----------------------------------------------------------------------------
# learnable f
# ----------------------------------------------------------------------------

GS = {
    "exp": lambda z: jnp.exp(z),
    "recip": lambda z: 1.0 / (1.0 + z * z),  # stabilized z -> z^{-1} family
    "identity": lambda z: z,
}


def mask_f(g: str, coeffs, dist_scale: float = 1.0) -> Callable:
    """f(x) = g(sum_t coeffs[..., t] * (x * dist_scale)^t). coeffs may carry
    leading batch (head) dims; result broadcasts accordingly."""

    def f(x):
        z = 0.0
        xs = x * dist_scale
        c = jnp.asarray(coeffs)
        for t in range(c.shape[-1] - 1, -1, -1):
            z = z * xs + c[..., t, None] if c.ndim > 1 else z * xs + c[..., t]
        return GS[g](z)

    return f


def sequence_mask_values(g: str, coeffs, L: int, dist_scale: float = 1.0):
    """F[..., k] = f(k) for k = 0..L-1 (token path metric)."""
    ks = jnp.arange(L, dtype=jnp.float32) * dist_scale
    c = jnp.asarray(coeffs)
    z = jnp.zeros(c.shape[:-1] + (L,), jnp.float32)
    for t in range(c.shape[-1] - 1, -1, -1):
        z = z * ks + c[..., t : t + 1]
    return GS[g](z)


def chebyshev_nodes(L: int, rank: int):
    """Chebyshev nodes on [0, L] (numpy, static)."""
    kk = np.arange(rank)
    t = np.cos((2 * kk + 1) * np.pi / (2 * rank))
    return ((L / 2.0) + (L / 2.0) * t).astype(np.float32)  # (rank,)


def _poly_mask_eval(g: str, coeffs, zs):
    """f = g(poly(coeffs)) evaluated on a 2-trailing-dim grid `zs` (already
    dist-scaled); coeffs (..., t+1) broadcasts its leading (head) dims."""
    c = jnp.asarray(coeffs, jnp.float32)
    acc = jnp.zeros(c.shape[:-1] + zs.shape, jnp.float32)
    for t in range(c.shape[-1] - 1, -1, -1):
        acc = acc * zs + c[..., t][..., None, None]
    return GS[g](acc)


def chebyshev_separable_expansion(g: str, coeffs, L: int,
                                  dist_scale: float = 1.0, rank: int = 16):
    """Node grid + node-pair mask values of the rank-R Chebyshev expansion
    of (i, j) -> f(i - j) on [0, L)^2. Shared by the table builder below and
    the O(1)-state decode (attention.topo_decomposition), so train/prefill
    and decode use ONE expansion. Returns (nodes (rank,) np, Bmat
    (..., rank, rank) differentiable in coeffs)."""
    nodes = chebyshev_nodes(L, rank)
    zs = jnp.asarray(nodes[:, None] - nodes[None, :]) * dist_scale  # (r, r)
    return nodes, _poly_mask_eval(g, coeffs, zs)


def chebyshev_separable_tables(g: str, coeffs, L: int, dist_scale: float = 1.0,
                               rank: int = 16):
    """Rank-R separable expansion of the sequence mask, tabulated per position:

        f(i - j) ~= sum_r alpha[..., i, r] * beta[..., j, r]

    for i, j in [0, L) via 2-D Chebyshev interpolation of (i, j) -> f(i - j)
    (spectral accuracy for the paper's smooth g(poly) masks). `coeffs` carries
    leading head dims (H, t+1) and the tables are differentiable in it — this
    is what lets the fused attention kernels train the 3 mask scalars.

    Returns (alpha (..., L, rank), beta (..., L, rank))."""
    nodes, Bmat = chebyshev_separable_expansion(g, coeffs, L, dist_scale, rank)
    from repro.core.engines.plan import _lagrange_batched
    pos = np.arange(L, dtype=np.float32)
    Lg = _lagrange_batched(pos[None, :], nodes[None, :])[0]  # (L, r)
    Lg = jnp.asarray(Lg, jnp.float32)
    alpha = jnp.einsum("lq,...qr->...lr", Lg, Bmat)
    beta = jnp.broadcast_to(Lg, Bmat.shape[:-2] + Lg.shape)
    return alpha, beta


def sequence_mask_matrix(g: str, coeffs, C: int, dist_scale: float = 1.0,
                         strict: bool = False):
    """Lower-triangular (..., C, C) tile of the causal sequence mask:
    f(i - j) where i > j (>= unless `strict`), zero above the diagonal.
    This is the exact within-chunk mask the fused attention kernels apply;
    differentiable in `coeffs` (leading head dims broadcast)."""
    d = np.arange(C)[:, None] - np.arange(C)[None, :]
    vals = _poly_mask_eval(g, coeffs, jnp.asarray(d, jnp.float32) * dist_scale)
    keep = jnp.asarray(d > 0 if strict else d >= 0)
    return jnp.where(keep, vals, 0.0)


# ----------------------------------------------------------------------------
# Algorithm 1 (App. C): general efficient low-rank masked attention
# ----------------------------------------------------------------------------


def masked_linear_attention(q_feat, k_feat, v, fastmult: Callable, eps=1e-6):
    """Alg. 1. q_feat/k_feat: (..., L, m) nonneg features, v: (..., L, d);
    fastmult(X): applies M to the L axis of X (..., L, c). Returns (..., L, d).
    """
    L, m = q_feat.shape[-2], q_feat.shape[-1]
    d = v.shape[-1]
    v1 = (k_feat[..., :, :, None] * v[..., :, None, :]).reshape(
        v.shape[:-1] + (m * d,))  # rows vec(phi(k_i) v_i^T)
    d1 = fastmult(v1)  # (..., L, m*d)
    d2 = fastmult(k_feat)  # (..., L, m)
    num = jnp.einsum("...lm,...lmd->...ld",
                     q_feat, d1.reshape(d1.shape[:-1] + (m, d)))
    den = jnp.einsum("...lm,...lm->...l", q_feat, d2)
    den = jnp.where(jnp.abs(den) < eps, eps, den)
    return num / den[..., None]


def masked_attention_bruteforce(q_feat, k_feat, v, mask, eps=1e-6):
    """Oracle: A = M ⊙ (phi(Q) phi(K)^T); O(L^2 d). Tests only."""
    A = jnp.einsum("...lm,...km->...lk", q_feat, k_feat) * mask
    den = jnp.sum(A, axis=-1)
    den = jnp.where(jnp.abs(den) < eps, eps, den)
    return jnp.einsum("...lk,...kd->...ld", A, v) / den[..., None]


# ----------------------------------------------------------------------------
# sequence (Toeplitz) fastmult factories
# ----------------------------------------------------------------------------


def make_sequence_fastmult(g: str, coeffs, L: int, causal: bool,
                           dist_scale: float = 1.0) -> Callable:
    F = sequence_mask_values(g, coeffs, L, dist_scale)  # (..., L)

    def fastmult(X):
        if causal:
            return causal_toeplitz_matvec(F, X)
        return symmetric_toeplitz_matvec(F, X)

    return fastmult


# ----------------------------------------------------------------------------
# tree / grid (IT-plan) fastmult factory
# ----------------------------------------------------------------------------


_TREE_FM_CACHE = BoundedLRU(64)


def _purge_dead_tree_fm_entries():
    """Drop entries whose Integrator has been garbage collected: their
    id-based key can never hit again, and keeping them would pin the plan
    arrays and compiled closures of dead integrators. Peeks (no recency
    promotion) so the scan doesn't scramble LRU eviction order."""
    for key, entry in _TREE_FM_CACHE.items():
        if entry[1]() is None:
            _TREE_FM_CACHE.discard(key)


def _resolve_plan_handle(integrator):
    """(impl, spec, params) for an `Integrator` facade, a raw backend, or a
    functional (spec, params) pair. `impl` is the object whose
    (non-deprecated, memoizing) `fastmult` the mask closure rides; it is
    None for the pure-pair form, which executes through `plan_api.fastmult`
    directly."""
    if isinstance(integrator, (tuple, list)) and len(integrator) == 2:
        spec, params = integrator
        return None, spec, params
    impl = getattr(integrator, "_impl", integrator)
    return (impl, getattr(impl, "spec", None), getattr(impl, "params", None))


def make_tree_fastmult(integrator, g: str, coeffs,
                       dist_scale: float = 1.0, *, sharded: bool = False,
                       mesh=None) -> Callable:
    """FastMult_M for M = [f(dist_T(i,j))] via the functional plan API.

    Works on fields with arbitrary leading batch/head axes: the mask multiply
    is linear in the field, so everything folds into the trailing field dim of
    one plan execution. `integrator` is a repro.core.engines.Integrator (any
    backend with a jit-able fastmult, i.e. plan or pallas) OR a functional
    `(spec, params)` pair from `ftfi.build` / `ftfi.load_plan`.

    `sharded=True` rides the multi-device shard_map executor
    (`plan_shard.sharded_fastmult`) over `mesh` (default: the active
    `launch.sharding` mesh): leaf blocks over the plan axis, halo exchange +
    psum_scatter, exact to the single-device path. With no mesh (or one
    device) it falls back to the single-device executor, so model code can
    pass `sharded=cfg.topo_shard_plan` unconditionally.

    For concrete (non-traced) coefficients the closure is memoized per
    (integrator-or-spec, g, coeffs, dist_scale[, mesh]), so repeated mask
    rebuilds (serving, eval loops) reuse one compiled executor; traced
    coeffs (training under jit) bypass the cache and trace inline as
    before."""
    impl, p_spec, p_params = _resolve_plan_handle(integrator)
    if sharded and mesh is None:
        from repro.launch import sharding

        mesh = sharding.current_mesh()
    use_shard = (bool(sharded) and mesh is not None
                 and int(mesh.devices.size) > 1
                 and p_spec is not None and p_params is not None)
    ref_target = integrator if impl is not None else p_spec
    key = None
    traced = any(isinstance(leaf, jax.core.Tracer)
                 for leaf in jax.tree_util.tree_leaves(coeffs))
    if impl is None or use_shard:
        # reweighted params may themselves be traced (training edge weights
        # under an enclosing jit): never cache a tracer-capturing closure
        traced = traced or any(
            isinstance(leaf, jax.core.Tracer)
            for leaf in jax.tree_util.tree_leaves(p_params))
    if not traced:
        _purge_dead_tree_fm_entries()
        c = np.asarray(coeffs)
        # the pair path keys on the PARAMS object too: the same spec serves
        # many PlanParams (ftfi.reweight), and each deserves its own bound
        # closure — the entry pins `p_params` so its id stays valid for the
        # entry's lifetime
        key = (id(ref_target),
               id(p_params) if (impl is None or use_shard) else None,
               g, float(dist_scale), c.shape, c.tobytes(),
               id(mesh) if use_shard else 0)
        hit = _TREE_FM_CACHE.get(key)
        if hit is not None and hit[1]() is ref_target:
            trace_guard.record("masks.tree_fastmult", event="hit")
            return hit[0]
        trace_guard.record("masks.tree_fastmult", event="miss")
    f_eval = mask_f(g, coeffs, dist_scale)
    if use_shard:
        # multi-device path: shard_map executor over the mesh; the closure
        # pins `mesh`, so the id() in the memo key stays valid for the
        # entry's lifetime
        from repro.core import plan_shard

        sfm = plan_shard.sharded_fastmult(p_spec, f_eval, mesh=mesh)
        if traced:
            base = lambda X: sfm(p_params, X)  # noqa: E731
        else:
            jfm = jax.jit(sfm)
            base = lambda X: jfm(p_params, X)  # noqa: E731
    elif impl is not None:
        # backend path: the impl's fastmult memoizes/jits over ITS OWN
        # (spec, params) through the same pure executor as plan_api.apply
        base = impl.fastmult(f_eval)
    else:
        from repro.core import plan_api

        fm = plan_api.fastmult(p_spec, f_eval)
        if traced:  # inside an enclosing jit: trace inline, never pin
            base = lambda X: fm(p_params, X)  # noqa: E731
        else:
            jfm = jax.jit(fm)
            base = lambda X: jfm(p_params, X)  # noqa: E731

    def fastmult(X):  # X: (..., L, c)
        shape = X.shape
        L = shape[-2]
        Xf = jnp.moveaxis(X.reshape(-1, L, shape[-1]), 0, -1)  # (L, c, B*)
        Xf = Xf.reshape(L, -1)
        out = base(Xf.astype(jnp.float32))
        out = out.reshape(L, shape[-1], -1)
        return jnp.moveaxis(out, -1, 0).reshape(shape)

    if key is not None:
        try:
            ref = weakref.ref(ref_target)
        except TypeError:
            ref = None
        if ref is not None:
            # weakly referenced: the purge above drops the entry (and the
            # plan/closure memory it pins) once the integrator/spec dies.
            # p_params rides along strongly so the id() in the key cannot
            # be recycled while the entry lives (None on the impl path).
            _TREE_FM_CACHE.put(key, (fastmult, ref, p_params))
    return fastmult


def make_forest_fastmult(integrator, forest, g: str, coeffs,
                         dist_scale: float = 1.0,
                         tree_weights=None) -> Callable:
    """Per-graph FastMult over a packed `Forest` field (..., sum_t n_t, c).

    `integrator` is `Integrator.from_forest(forest, ...)`: its plan is
    block-diagonal across trees, so ONE fused execution applies each graph's
    own mask M_t = [f(dist_{T_t}(i,j))] to its own rows — per-request
    topological masks under serving load ride a single jit dispatch instead
    of a Python loop over requests.

    `tree_weights` (K,) optionally broadcasts a per-tree coefficient onto
    each tree's output block (the multiply is linear, so scaling the output
    rows of tree t equals scaling its mask) — e.g. FRT-forest averaging
    weights or per-request temperature. Shares the concrete-coeff memo with
    `make_tree_fastmult`; traced coeffs bypass caching exactly as there."""
    base = make_tree_fastmult(integrator, g, coeffs, dist_scale)
    if tree_weights is None:
        return base
    w = jnp.asarray(forest.broadcast(
        np.asarray(tree_weights, np.float32)))[:, None]  # (N, 1)

    def fastmult(X):  # X: (..., N, c)
        return base(X) * w

    return fastmult


# ----------------------------------------------------------------------------
# cordial decode states: O(1)-per-token masked linear attention (causal)
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CordialDecomposition:
    """f(i - j) = sum_r alpha_r(i) beta_r(j): per-term callables evaluated on
    integer positions (float32)."""

    num_terms: int
    alpha: Callable  # (pos (...,),) -> (..., R)
    beta: Callable


def cordial_decomposition(g: str, coeffs, dist_scale: float = 1.0
                          ) -> CordialDecomposition:
    coeffs = np.asarray(coeffs, dtype=np.float32)
    T = coeffs.shape[-1] - 1
    if g == "exp" and T <= 1:
        # exp(a0 + a1 (i-j)s) = [e^{a0} e^{a1 s i}] * [e^{-a1 s j}]
        a0 = coeffs[..., 0]
        a1 = coeffs[..., 1] if T == 1 else np.zeros_like(coeffs[..., 0])

        def alpha(pos):
            return (np.exp(a0) * jnp.exp(a1 * dist_scale * pos))[..., None]

        def beta(pos):
            return jnp.exp(-a1 * dist_scale * pos)[..., None]

        return CordialDecomposition(1, alpha, beta)
    if g == "identity":
        # poly(i-j) = sum_t a_t sum_l C(t,l) i^l (-j)^{t-l}: terms (l, t-l)
        # consolidated by l: alpha_l(i) = i^l, beta_l(j) = sum_{t>=l} a_t C(t,l) (-j)^{t-l}
        R = T + 1

        def alpha(pos):
            ps = pos * dist_scale
            return jnp.stack([ps ** l for l in range(R)], axis=-1)

        def beta(pos):
            ps = pos * dist_scale
            outs = []
            for l in range(R):
                acc = 0.0
                for t in range(l, T + 1):
                    acc = acc + coeffs[..., t] * math.comb(t, l) * (-ps) ** (t - l)
                outs.append(acc)
            return jnp.stack(outs, axis=-1)

        return CordialDecomposition(R, alpha, beta)
    raise ValueError(
        f"g={g!r}, degree={T}: not exactly separable; use the Toeplitz path "
        "(chunked prefill) or g in {'exp' (deg<=1), 'identity'}")


def decode_state_init(decomp: CordialDecomposition, m: int, d: int,
                      batch_shape=(), dtype=jnp.float32):
    """S: (..., R, m, d) cross-moment states; z: (..., R, m) normalizers."""
    R = decomp.num_terms
    return (jnp.zeros(batch_shape + (R, m, d), dtype),
            jnp.zeros(batch_shape + (R, m), dtype))


def decode_state_update(decomp, state, pos, k_feat, v):
    """Absorb token at integer position `pos`: k_feat (..., m), v (..., d)."""
    S, z = state
    b = decomp.beta(jnp.asarray(pos, jnp.float32))  # (R,) or (..., R)
    b = jnp.broadcast_to(b, S.shape[:-2])  # (..., R)
    S = S + b[..., None, None] * (k_feat[..., None, :, None] * v[..., None, None, :])
    z = z + b[..., None] * k_feat[..., None, :]
    return (S, z)


def decode_state_read(decomp, state, pos, q_feat, eps=1e-6):
    """Masked linear attention output for the query at position `pos`."""
    S, z = state
    a = decomp.alpha(jnp.asarray(pos, jnp.float32))
    a = jnp.broadcast_to(a, S.shape[:-2])  # (..., R)
    num = jnp.einsum("...m,...rmd,...r->...d", q_feat, S, a)
    den = jnp.einsum("...m,...rm,...r->...", q_feat, z, a)
    den = jnp.where(jnp.abs(den) < eps, eps, den)
    return num / den[..., None]
