"""Multi-device FTFI: leaf-block partitioner + shard_map plan executor.

The fused executor in `plan_api._execute` is a single-device program: one
gather + segment-sum over the whole source index space, one cross dispatch
per size bucket, one gather + scatter-add over the whole target space. This
module partitions that global index space into per-device *leaf blocks* and
re-expresses the same computation as a shard_map program whose collectives
are exact:

  - the vertex space [0, n) is cut into `num_shards` equal contiguous
    blocks (the `plan_leaves` logical axis). Trees in a packed `Forest`
    occupy contiguous id ranges, so forest plans shard naturally per tree —
    only trees straddling a block boundary contribute halo traffic;
  - every *contribution* (leaf-bucket row, cross job, pivot correction) is
    assigned to the shard owning its output vertices, so scatter-adds stay
    block-local up to the final reduction;
  - cross buckets / leaf rows that straddle shards read remote field rows
    through a host-precomputed **halo/exchange table**: each device gathers
    the rows its neighbours need, one `all_to_all` swaps them, and local
    indices into the received pool are baked into the per-shard index
    arrays (no full-field gather, ever);
  - per-shard partial outputs meet in one `psum_scatter` over the block
    axis — an exact reduction, so `apply_sharded` matches the single-device
    `plan_api.apply` to float round-off (tests pin 1e-6 relative).

Everything the partitioner emits is static numpy, stacked per shard along a
leading `(num_shards, ...)` axis that shard_map splits — each device only
ever holds its own slice of the plan index arrays.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.lru import BoundedLRU
from repro.core.plan_api import PlanParams, PlanSpec, _fspec, select_cross

# bumped whenever the per-shard table layout below changes: recorded into
# sharded artifacts' provenance and rejected by plan_guard when a newer
# artifact meets an older codebase
SHARD_LAYOUT_VERSION = 1

_PART_CACHE = BoundedLRU(8)


# ----------------------------------------------------------------------------
# ShardPlan: host-side per-device tables
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False, repr=False)
class ShardPlan:
    """Per-device decomposition of one PlanSpec. All arrays are numpy and
    stacked along a leading (D,) shard axis; `block` is the per-device
    vertex count (the padded global field is (D * block, d)). Index
    conventions inside a shard's local field buffer `xfull`:

      [0, block)                     owned vertex rows
      block                          zero pad row
      [block + 1, block + 1 + D*Emax) halo rows received via all_to_all
    """

    num_shards: int
    block: int
    halo_width: int  # Emax: max rows exchanged per (sender, receiver) pair
    halo_total: int  # sum of remote rows referenced across shards
    send_idx: np.ndarray  # (D, D, Emax) local row ids to send (pad=block)
    # leaf buckets (tuples over bucket index)
    leaf_sel: tuple  # (D, Rmax_i) row ids into the bucket (pad=0)
    leaf_gather: tuple  # (D, Rmax_i, K) xfull indices (pad=block)
    leaf_mask: tuple  # (D, Rmax_i, K) bool
    leaf_scatter: tuple  # (D, Rmax_i, K) out rows (pad/masked=dump)
    # cross buckets
    job_sel: tuple  # (D, Jmax_i) row ids into the bucket (pad=0)
    job_tmask: tuple  # (D, Jmax_i, Ut)
    job_smask: tuple  # (D, Jmax_i, Us)
    loff_src: tuple  # local flat source-group offset per bucket
    loff_tgt: tuple
    n_src_loc: int
    n_tgt_loc: int
    src_gather_l: np.ndarray  # (D, Smax) xfull indices (pad=block)
    src_seg_l: np.ndarray  # (D, Smax) local groups (pad=n_src_loc)
    tgt_gather_l: np.ndarray  # (D, Tmax) local target groups (pad=0)
    tgt_scatter_l: np.ndarray  # (D, Tmax) out rows (pad=dump)
    # pivot diagonal corrections
    piv_gather_l: np.ndarray  # (D, Pmax) xfull indices (pad=block)
    piv_scatter_l: np.ndarray  # (D, Pmax) out rows (pad=dump)
    # grid/Hankel engine: per-shard static integer grid indices + global
    # (shard-invariant) transform sizes; None unless the spec is grid-aligned
    hankel_it: tuple | None
    hankel_isrc: tuple | None
    hankel_LM: tuple | None  # of (L_i, Ms_i)

    @property
    def stats(self) -> dict:
        return {"num_shards": self.num_shards, "block": self.block,
                "halo_width": self.halo_width,
                "halo_total": self.halo_total,
                # per-device flat work (padded gather lengths): the
                # weak-scaling gate checks these shrink vs the global plan
                "src_rows": int(self.src_gather_l.shape[1]),
                "tgt_rows": int(self.tgt_gather_l.shape[1]),
                "shard_layout": SHARD_LAYOUT_VERSION}


def _owner(v, block, D):
    return np.minimum(np.asarray(v, np.int64) // block, D - 1)


def _greedy_assign(w, D):
    """LPT scheduling: heaviest item first onto the least-loaded shard.
    Deterministic (stable sort, lowest-index tie-break); near-optimal
    makespan, which is what bounds the padded per-shard table width."""
    import heapq
    w = np.asarray(w, np.int64)
    out = np.zeros(w.size, np.int64)
    if D <= 1 or not w.size:
        return out
    heap = [(0, k) for k in range(D)]
    heapq.heapify(heap)
    for j in np.argsort(-w, kind="stable"):
        load, k = heapq.heappop(heap)
        out[j] = k
        heapq.heappush(heap, (load + int(w[j]), k))
    return out


def partition_plan(spec: PlanSpec, num_shards: int) -> ShardPlan:
    """Split `spec`'s global index space into `num_shards` leaf blocks.

    Pure host-side numpy; memoized on (spec digest, num_shards). Cross jobs
    and leaf rows are load-balanced across shards by their flat entry
    counts (greedy LPT — vertex ids carry no locality, so ownership-based
    placement would pile everything on the low blocks); every remote
    *input* row a shard needs is routed through the exchange table, and the
    partial outputs meet in one exact psum_scatter."""
    key = (spec.digest, int(num_shards))
    hit = _PART_CACHE.get(key)
    if hit is not None:
        return hit
    D = int(num_shards)
    n = spec.n
    block = max(-(-n // D), 1)
    dump = D * block  # scatter row that is dropped before the reduction

    nb = len(spec.cross_src_mask)
    Bs = np.array([m.shape[0] for m in spec.cross_src_mask], np.int64)
    Us = np.array([m.shape[1] for m in spec.cross_src_mask], np.int64)
    Ut = np.array([m.shape[1] for m in spec.cross_tgt_mask], np.int64)
    soff = np.asarray(spec.cross_src_off, np.int64)
    toff = np.asarray(spec.cross_tgt_off, np.int64)
    jbase = np.zeros(nb + 1, np.int64)
    np.cumsum(Bs, out=jbase[1:])
    total_jobs = int(jbase[-1])

    # ---- decompose the global flat entry tables -------------------------
    tg = np.asarray(spec.tgt_gather, np.int64)
    tv = np.asarray(spec.tgt_scatter, np.int64)
    tb = np.searchsorted(toff, tg, side="right") - 1 if tg.size else tg
    trel = tg - toff[tb] if tg.size else tg
    trow = trel // Ut[tb] if tg.size else tg
    tcol = trel - trow * Ut[tb] if tg.size else tg

    sg = np.asarray(spec.src_gather, np.int64)
    ss = np.asarray(spec.src_seg, np.int64)
    sb = np.searchsorted(soff, ss, side="right") - 1 if ss.size else ss
    srel = ss - soff[sb] if ss.size else ss
    srow = srel // Us[sb] if ss.size else ss
    scol = srel - srow * Us[sb] if ss.size else ss

    # ---- assign jobs to shards: greedy balance on flat entry counts -----
    w_job = np.ones(total_jobs, np.int64)  # +1 spreads zero-weight jobs
    if tg.size:
        w_job += np.bincount(jbase[tb] + trow, minlength=total_jobs)
    if sg.size:
        w_job += np.bincount(jbase[sb] + srow, minlength=total_jobs)
    job_shard = _greedy_assign(w_job, D)

    # per-bucket shard membership -> padded (D, Jmax) selections
    job_sel, job_valid, job_slot = [], [], np.zeros(total_jobs, np.int64)
    Jmax = np.zeros(nb, np.int64)
    for i in range(nb):
        shards = job_shard[jbase[i]:jbase[i + 1]]
        counts = np.bincount(shards, minlength=D)
        Jmax[i] = max(int(counts.max()) if counts.size else 0, 1)
        sel = np.zeros((D, Jmax[i]), np.int32)
        val = np.zeros((D, Jmax[i]), bool)
        order = np.argsort(shards, kind="stable")
        slot = np.arange(shards.size) - np.concatenate(
            [[0], np.cumsum(counts)])[shards[order]]
        job_slot[jbase[i] + order] = slot
        sel[shards[order], slot] = order.astype(np.int32)
        val[shards[order], slot] = True
        job_sel.append(sel)
        job_valid.append(val)

    loff_src = np.zeros(nb + 1, np.int64)
    np.cumsum(Jmax * Us, out=loff_src[1:])
    loff_tgt = np.zeros(nb + 1, np.int64)
    np.cumsum(Jmax * Ut, out=loff_tgt[1:])
    n_src_loc = int(loff_src[-1])
    n_tgt_loc = int(loff_tgt[-1])

    # ---- leaf rows: greedy balance on live-entry counts -----------------
    nlb = len(spec.leaf_ids)
    leaf_live, leaf_w = [], []
    for i in range(nlb):
        mask = np.asarray(spec.leaf_mask[i], bool)
        rows = np.flatnonzero(mask.any(axis=1))
        leaf_live.append(rows)
        leaf_w.append(mask[rows].sum(axis=1).astype(np.int64) + 1)
    lsh = _greedy_assign(np.concatenate(leaf_w) if nlb else
                         np.zeros(0, np.int64), D)
    leaf_rows, off = [], 0  # (rows, shard) per leaf bucket
    for rows in leaf_live:
        leaf_rows.append((rows, lsh[off:off + rows.size]))
        off += rows.size

    # ---- halo: remote vertex rows each shard reads ----------------------
    need = [[] for _ in range(D)]  # remote global vertex ids per shard
    if sg.size:
        esh = job_shard[jbase[sb] + srow]
        rem = (sg < n) & (_owner(sg, block, D) != esh)
        for k in range(D):
            m = rem & (esh == k)
            if m.any():
                need[k].append(sg[m])
    for i in range(nlb):
        rows, rs = leaf_rows[i]
        if not rows.size:
            continue
        ids = np.asarray(spec.leaf_ids[i], np.int64)[rows]
        mask = np.asarray(spec.leaf_mask[i], bool)[rows]
        own = _owner(ids, block, D)
        for k in range(D):
            m = mask & (own != k) & (rs[:, None] == k) & (ids < n)
            if m.any():
                need[k].append(ids[m])
    need = [np.unique(np.concatenate(v)) if v else np.zeros(0, np.int64)
            for v in need]
    halo_total = int(sum(v.size for v in need))

    # send lists per (owner j -> shard k); Emax pads the exchange uniform
    send_lists = [[None] * D for _ in range(D)]
    Emax = 0
    for k in range(D):
        own = _owner(need[k], block, D)
        for j in range(D):
            sl = need[k][own == j]
            send_lists[j][k] = sl
            Emax = max(Emax, sl.size)
    send_idx = np.full((D, D, Emax), block, np.int32)
    for j in range(D):
        for k in range(D):
            sl = send_lists[j][k]
            send_idx[j, k, :sl.size] = (sl - j * block).astype(np.int32)

    def xidx(k, vs):
        """xfull indices on shard k for global vertex ids `vs` (pad id n
        and out-of-range -> the zero row)."""
        vs = np.asarray(vs, np.int64)
        res = np.full(vs.shape, block, np.int32)
        pad = vs >= n
        own = _owner(vs, block, D)
        mine = (own == k) & ~pad
        res[mine] = (vs[mine] - k * block).astype(np.int32)
        rem = ~mine & ~pad
        for j in range(D):
            mj = rem & (own == j)
            if mj.any():
                pos = np.searchsorted(send_lists[j][k], vs[mj])
                res[mj] = (block + 1 + j * Emax + pos).astype(np.int32)
        return res

    # ---- per-shard flat source entries ----------------------------------
    if sg.size:
        esh = job_shard[jbase[sb] + srow]
        lseg = loff_src[sb] + job_slot[jbase[sb] + srow] * Us[sb] + scol
        counts = np.bincount(esh, minlength=D)
        Smax = max(int(counts.max()), 1)
        src_gather_l = np.full((D, Smax), block, np.int32)
        src_seg_l = np.full((D, Smax), n_src_loc, np.int32)
        for k in range(D):
            m = esh == k
            src_gather_l[k, :int(m.sum())] = xidx(k, sg[m])
            src_seg_l[k, :int(m.sum())] = lseg[m].astype(np.int32)
    else:
        src_gather_l = np.full((D, 1), block, np.int32)
        src_seg_l = np.full((D, 1), n_src_loc, np.int32)

    # ---- per-shard flat target entries ----------------------------------
    if tg.size:
        esh = job_shard[jbase[tb] + trow]
        lgat = loff_tgt[tb] + job_slot[jbase[tb] + trow] * Ut[tb] + tcol
        lsca = np.where(tv < n, tv, dump)
        counts = np.bincount(esh, minlength=D)
        Tmax = max(int(counts.max()), 1)
        tgt_gather_l = np.zeros((D, Tmax), np.int32)
        tgt_scatter_l = np.full((D, Tmax), dump, np.int32)
        for k in range(D):
            m = esh == k
            tgt_gather_l[k, :int(m.sum())] = lgat[m].astype(np.int32)
            tgt_scatter_l[k, :int(m.sum())] = lsca[m].astype(np.int32)
    else:
        tgt_gather_l = np.zeros((D, 1), np.int32)
        tgt_scatter_l = np.full((D, 1), dump, np.int32)

    # ---- pivots (always owned by their shard) ---------------------------
    piv = np.asarray(spec.pivots, np.int64)
    live_p = piv[piv < n]
    psh = _owner(live_p, block, D)
    counts = np.bincount(psh, minlength=D) if live_p.size else np.zeros(
        D, np.int64)
    Pmax = max(int(counts.max()) if live_p.size else 0, 1)
    piv_gather_l = np.full((D, Pmax), block, np.int32)
    piv_scatter_l = np.full((D, Pmax), dump, np.int32)
    for k in range(D):
        pv = live_p[psh == k]
        piv_gather_l[k, :pv.size] = (pv - k * block).astype(np.int32)
        piv_scatter_l[k, :pv.size] = pv.astype(np.int32)

    # ---- leaf tables ----------------------------------------------------
    leaf_sel, leaf_gather, leaf_mask_sh, leaf_scatter = [], [], [], []
    for i in range(nlb):
        rows, rs = leaf_rows[i]
        ids = np.asarray(spec.leaf_ids[i], np.int64)
        mask = np.asarray(spec.leaf_mask[i], bool)
        K = ids.shape[1]
        counts = np.bincount(rs, minlength=D) if rows.size else np.zeros(
            D, np.int64)
        Rmax = max(int(counts.max()) if rows.size else 0, 1)
        sel = np.zeros((D, Rmax), np.int32)
        gat = np.full((D, Rmax, K), block, np.int32)
        msk = np.zeros((D, Rmax, K), bool)
        sca = np.full((D, Rmax, K), dump, np.int32)
        for k in range(D):
            rk = rows[rs == k]
            sel[k, :rk.size] = rk.astype(np.int32)
            if rk.size:
                gat[k, :rk.size] = xidx(k, ids[rk])
                msk[k, :rk.size] = mask[rk]
                sca[k, :rk.size] = np.where(mask[rk], ids[rk],
                                            dump).astype(np.int32)
        leaf_sel.append(sel)
        leaf_gather.append(gat)
        leaf_mask_sh.append(msk)
        leaf_scatter.append(sca)

    # ---- cross masks (padded job rows keep slot 0 live so the engines'
    # masked reductions stay finite; their outputs are never gathered) ----
    job_tmask, job_smask = [], []
    for i in range(nb):
        tm = np.asarray(spec.cross_tgt_mask[i], bool)[job_sel[i]]
        sm = np.asarray(spec.cross_src_mask[i], bool)[job_sel[i]]
        pad = ~job_valid[i]
        tm[pad] = False
        sm[pad] = False
        tm[pad, 0] = True
        sm[pad, 0] = True
        job_tmask.append(tm)
        job_smask.append(sm)

    # ---- grid/Hankel static integer indices -----------------------------
    hankel_it = hankel_isrc = hankel_LM = None
    if spec.grid_h is not None and not spec.reweightable:
        h = spec.grid_h
        hankel_it, hankel_isrc, hankel_LM = [], [], []
        for i in range(nb):
            it_g = np.rint(np.asarray(spec.cross_tgt_d0[i]) / h).astype(
                np.int64)
            is_g = np.rint(np.asarray(spec.cross_src_d0[i]) / h).astype(
                np.int64)
            Ms = int(is_g.max()) + 1 if is_g.size else 1
            L = (int(it_g.max()) if it_g.size else 0) + Ms
            hankel_it.append(it_g[job_sel[i]].astype(np.int32))
            hankel_isrc.append(is_g[job_sel[i]].astype(np.int32))
            hankel_LM.append((L, Ms))
        hankel_it = tuple(hankel_it)
        hankel_isrc = tuple(hankel_isrc)
        hankel_LM = tuple(hankel_LM)

    sp = ShardPlan(
        num_shards=D, block=block, halo_width=int(Emax),
        halo_total=halo_total, send_idx=send_idx,
        leaf_sel=tuple(leaf_sel), leaf_gather=tuple(leaf_gather),
        leaf_mask=tuple(leaf_mask_sh), leaf_scatter=tuple(leaf_scatter),
        job_sel=tuple(job_sel), job_tmask=tuple(job_tmask),
        job_smask=tuple(job_smask),
        loff_src=tuple(int(o) for o in loff_src[:-1]),
        loff_tgt=tuple(int(o) for o in loff_tgt[:-1]),
        n_src_loc=n_src_loc, n_tgt_loc=n_tgt_loc,
        src_gather_l=src_gather_l, src_seg_l=src_seg_l,
        tgt_gather_l=tgt_gather_l, tgt_scatter_l=tgt_scatter_l,
        piv_gather_l=piv_gather_l, piv_scatter_l=piv_scatter_l,
        hankel_it=hankel_it, hankel_isrc=hankel_isrc, hankel_LM=hankel_LM)
    _PART_CACHE.put(key, sp)
    return sp


# ----------------------------------------------------------------------------
# sharded cross engine for the grid/Hankel path (traced integer indices)
# ----------------------------------------------------------------------------


def _hankel_sharded(fn_eval, h, it, isrc, Xp, L, Ms):
    """`plan_api.hankel_batched_matvec` with *traced* per-shard integer grid
    indices; the transform sizes (L, Ms) are global and static, so the same
    SPMD program runs on every device."""
    F = fn_eval(h * jnp.arange(L, dtype=Xp.dtype))
    B, Us, d = Xp.shape
    bidx = jnp.arange(B)[:, None]
    Pm = jnp.zeros((B, Ms, d), Xp.dtype).at[bidx, isrc].add(Xp)
    nfft = 1 << int(np.ceil(np.log2(max(L + Ms, 2))))
    Ff = jnp.fft.rfft(F, n=nfft)
    Pf = jnp.fft.rfft(Pm[:, ::-1], n=nfft, axis=1)
    full = jnp.fft.irfft(Ff[None, :, None] * Pf, n=nfft, axis=1)
    out_full = full[:, Ms - 1:Ms - 1 + L]
    return jnp.take_along_axis(out_full, it[:, :, None], axis=1)


# ----------------------------------------------------------------------------
# the shard_map executor
# ----------------------------------------------------------------------------


def _plan_axis(mesh):
    from repro.launch import sharding

    return sharding.plan_axis(mesh)


def check_mesh(spec: PlanSpec, mesh) -> None:
    """Reject a sharded artifact on a mismatched mesh with a clear error
    (instead of a gather-time crash deep inside the executor)."""
    from repro.core.plan_guard import PlanValidationError

    if getattr(spec, "shard_layout", 0) > SHARD_LAYOUT_VERSION:
        raise PlanValidationError(
            f"plan artifact uses shard layout v{spec.shard_layout}, this "
            f"codebase supports <= v{SHARD_LAYOUT_VERSION}")
    nd = getattr(spec, "mesh_devices", 0)
    if nd and mesh is not None and mesh.devices.size != nd:
        raise PlanValidationError(
            f"sharded plan artifact was laid out for {nd} devices "
            f"(axes {tuple(getattr(spec, 'mesh_axes', ()) or ())}), but the "
            f"target mesh has {mesh.devices.size} devices "
            f"(axes {tuple(mesh.axis_names)}); re-save the artifact on the "
            f"serving mesh or pass a matching mesh")


def _execute_sharded(spec, sp: ShardPlan, params: PlanParams, fn_eval,
                     cross_multiply, use_hankel, X, mesh, axis):
    from jax.experimental.shard_map import shard_map

    X = jnp.asarray(X)
    squeeze = X.ndim == 1
    if squeeze:
        X = X[:, None]
    d = X.shape[1]
    D, block, Emax = sp.num_shards, sp.block, sp.halo_width
    nb = len(sp.job_sel)
    nlb = len(sp.leaf_sel)
    Us = [m.shape[1] for m in spec.cross_src_mask]
    Ut = [m.shape[1] for m in spec.cross_tgt_mask]
    dump = D * block

    Xg = jnp.zeros((dump, d), X.dtype).at[:spec.n].set(X)
    ops = {
        "x": Xg,
        "send": sp.send_idx,
        "sgl": sp.src_gather_l, "ssl": sp.src_seg_l,
        "tgl": sp.tgt_gather_l, "tsl": sp.tgt_scatter_l,
        "pvg": sp.piv_gather_l, "pvs": sp.piv_scatter_l,
        # per-shard slices of the dynamic distances: a row-gather on the
        # (replicated) params, stacked along the shard axis
        "leaf_d": tuple(params.leaf_dists[i][sp.leaf_sel[i]]
                        for i in range(nlb)),
        "leaf_g": sp.leaf_gather, "leaf_m": sp.leaf_mask,
        "leaf_s": sp.leaf_scatter,
        "tgt_d": tuple(params.cross_tgt_d[i][sp.job_sel[i]]
                       for i in range(nb)),
        "src_d": tuple(params.cross_src_d[i][sp.job_sel[i]]
                       for i in range(nb)),
        "tmask": sp.job_tmask, "smask": sp.job_smask,
    }
    if use_hankel:
        ops["h_it"] = sp.hankel_it
        ops["h_isrc"] = sp.hankel_isrc
    in_specs = jax.tree.map(lambda a: P(axis), ops)

    def local_fn(o):
        x = o["x"]  # (block, d)
        xl = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
        if Emax:
            send = xl[o["send"][0]]  # (D, Emax, d)
            recv = jax.lax.all_to_all(send, axis, 0, 0)
            xfull = jnp.concatenate([xl, recv.reshape(D * Emax, d)], axis=0)
        else:
            xfull = xl
        outp = jnp.zeros((dump + 1, d), x.dtype)

        for i in range(nlb):
            m = jnp.asarray(o["leaf_m"][i][0])
            Xl = xfull[o["leaf_g"][i][0]]  # (Rmax, K, d)
            M = fn_eval(o["leaf_d"][i][0])
            pm = m[:, :, None] & m[:, None, :]
            M = jnp.where(pm, M, 0.0)
            contrib = jnp.einsum("bij,bjd->bid", M, Xl)
            outp = outp.at[o["leaf_s"][i][0]].add(contrib * m[:, :, None])

        if sp.n_src_loc:
            Xp_loc = jax.ops.segment_sum(
                xfull[o["sgl"][0]], o["ssl"][0],
                num_segments=sp.n_src_loc + 1)[:-1]
            parts = []
            for i in range(nb):
                J = sp.job_sel[i].shape[1]
                off = sp.loff_src[i]
                Xp = Xp_loc[off:off + J * Us[i]].reshape(J, Us[i], d)
                if use_hankel:
                    L_i, Ms_i = sp.hankel_LM[i]
                    res = _hankel_sharded(fn_eval, spec.grid_h,
                                          o["h_it"][i][0], o["h_isrc"][i][0],
                                          Xp, L_i, Ms_i)
                else:
                    res = cross_multiply(
                        i, o["tgt_d"][i][0], jnp.asarray(o["tmask"][i][0]),
                        o["src_d"][i][0], jnp.asarray(o["smask"][i][0]), Xp)
                parts.append(res.reshape(J * Ut[i], d))
            cflat = (jnp.concatenate(parts, axis=0) if len(parts) > 1
                     else parts[0])
            outp = outp.at[o["tsl"][0]].add(cflat[o["tgl"][0]])

        f0 = fn_eval(jnp.zeros((1,), x.dtype))[0]
        outp = outp.at[o["pvs"][0]].add(-f0 * xfull[o["pvg"][0]])
        # exact meeting point of all cross-shard contributions
        return jax.lax.psum_scatter(outp[:-1], axis, scatter_dimension=0,
                                    tiled=True)

    out = shard_map(local_fn, mesh=mesh, in_specs=(in_specs,),
                    out_specs=P(axis), check_rep=False)(ops)
    res = out[:spec.n]
    if params.tree_w is not None:
        w = jnp.repeat(jnp.asarray(params.tree_w),
                       np.asarray(spec.tree_sizes, np.int64),
                       total_repeat_length=spec.n)
        res = res * w[:, None].astype(res.dtype)
    return res[:, 0] if squeeze else res


def apply_sharded(spec: PlanSpec, params: PlanParams, fn, X, *,
                  mesh=None, axis: str | None = None, backend: str = "plan",
                  degree: int = 32, pallas_opts: dict | None = None):
    """Multi-device `plan_api.apply`: Y = M_f X with the plan's index space
    partitioned into per-device leaf blocks under shard_map.

    `mesh` defaults to the active `launch.sharding.use_sharding` mesh;
    `axis` to the mesh axis bound to the `plan_leaves` logical axis (the
    `data` axis on the standard meshes). Exact: halo rows move through one
    all_to_all, partial outputs through one psum_scatter — parity with the
    single-device executor is float round-off only. Differentiable in
    `params` and `X` like `apply`."""
    from repro.launch import sharding

    if mesh is None:
        mesh = sharding.current_mesh()
    if mesh is None:
        raise ValueError(
            "apply_sharded needs a mesh: pass mesh=... or call under "
            "launch.sharding.use_sharding(mesh)")
    check_mesh(spec, mesh)
    if axis is None:
        axis = _plan_axis(mesh)
    D = int(mesh.shape[axis])
    sp = partition_plan(spec, D)
    fspec = _fspec(fn)
    name, cross = select_cross(spec, fspec, backend=backend, degree=degree,
                               pallas_opts=pallas_opts)
    use_hankel = name == "hankel_fft"
    if use_hankel and sp.hankel_it is None:  # pragma: no cover - guard
        raise ValueError("grid engine selected but shard plan lacks grid "
                         "tables")
    return _execute_sharded(spec, sp, params, fspec.fn_eval, cross,
                            use_hankel, X, mesh, axis)


def sharded_fastmult(spec: PlanSpec, fn, *, mesh, axis: str | None = None,
                     backend: str = "plan", degree: int = 32,
                     pallas_opts: dict | None = None):
    """Jittable (params, X) -> Y closure over `apply_sharded` with the mesh
    and engine choice baked in (the sharded face of `plan_api.fastmult`)."""

    def fm(params, X):
        if isinstance(X, jax.core.Tracer):
            from repro.analysis import trace_guard

            trace_guard.record("ftfi.sharded_fastmult",
                               detail=spec.digest[:12])
        return apply_sharded(spec, params, fn, X, mesh=mesh, axis=axis,
                             backend=backend, degree=degree,
                             pallas_opts=pallas_opts)

    return fm


def shard_stats(spec: PlanSpec, num_shards: int) -> dict:
    """Partition diagnostics: per-device block size, halo width/total (the
    halo-exchange cost model's inputs: one all_to_all moves
    `num_shards * halo_width` rows per device)."""
    return partition_plan(spec, num_shards).stats
