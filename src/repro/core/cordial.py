"""Cordial functions: fast multiplication with matrices M = [f(x_i + y_j)].

This is the LDR/structured-matrix heart of the paper (Sec 3.2.1):

  engine        f class                         exact?   complexity
  ------------  ------------------------------  -------  -----------------
  dense         any                             yes      O(a·b·d)
  polynomial    sum_t c_t x^t                   yes      O((a+b)·B·d)
  exponential   s·exp(λx)                       yes      O((a+b)·d)      (rank 1)
  exp_poly      poly(x)·exp(λx)                 yes      O((a+b)·B·d)
  trigonometric cos/sin(ωx+φ)                   yes      O((a+b)·d)      (rank 2)
  hankel_fft    ANY f, grid-aligned x,y         yes      O(L log L·d), L=grid span
                (unit/rational tree weights —
                 subsumes the paper's
                 Vandermonde D1·V·D2 case)
  chebyshev     any f analytic near [lo,hi]     ~eps     O((a+b)·r·d + r²·d)
                (covers rational f and
                 exp(λx)/(x+c) Cauchy-LDR —
                 spectral convergence)

All engines are written against an array namespace `xp` (numpy or jax.numpy) so
the same code drives host-side graph workloads and the jit'ed in-model plan
executor. Shapes: x (a,), y (b,), V (b, d) -> out (a, d).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Callable

import numpy as np

# ----------------------------------------------------------------------------
# low-level engines
# ----------------------------------------------------------------------------


def dense_matvec(f: Callable, x, y, V, xp=np):
    M = f(x[:, None] + y[None, :])
    return M @ V


def polynomial_matvec(coeffs, x, y, V, xp=np):
    """f(z) = sum_t coeffs[t] z^t. Exact low-rank outer-product decomposition.

    M = sum_t c_t sum_l C(t,l) x^l (y^{t-l})  =>  out = Xpow @ W,
      S[u]  = sum_j y_j^u V[j]
      W[l]  = sum_{t>=l} c_t C(t,l) S[t-l]
    """
    coeffs = xp.asarray(coeffs)
    B = coeffs.shape[0] - 1
    # powers: (n, B+1)
    xp_pows = _powers(x, B, xp)  # (a, B+1)
    yp_pows = _powers(y, B, xp)  # (b, B+1)
    S = yp_pows.T @ V  # (B+1, d)
    # binomial table
    binom = _binom_table(B, xp, like=coeffs)
    # W[l] = sum_t c_t binom[t, l] S[t-l]  for t in [l, B]
    d = V.shape[1:]
    W = xp.zeros((B + 1,) + d, dtype=V.dtype)
    for l in range(B + 1):
        acc = 0.0
        for t in range(l, B + 1):
            acc = acc + coeffs[t] * binom[t, l] * S[t - l]
        W = _set_row(W, l, acc, xp)
    return xp_pows @ W.reshape(B + 1, -1) if len(d) > 1 else xp_pows @ W


def _powers(x, B, xp):
    pows = [xp.ones_like(x)]
    for _ in range(B):
        pows.append(pows[-1] * x)
    return xp.stack(pows, axis=-1)


def _binom_table(B, xp, like=None):
    tbl = np.zeros((B + 1, B + 1))
    for t in range(B + 1):
        for l in range(t + 1):
            tbl[t, l] = math.comb(t, l)
    return xp.asarray(tbl)


def _set_row(W, l, val, xp):
    if xp is np:
        W[l] = val
        return W
    return W.at[l].set(val)


def exponential_matvec(lam, x, y, V, xp=np, scale=1.0):
    """f(z) = scale * exp(lam * z). Rank-1, numerically shifted."""
    ly = lam * y
    m = xp.max(ly) if y.shape[0] else 0.0
    t = xp.exp(ly - m) @ V  # (d,)
    return scale * xp.exp(lam * x + m)[:, None] * t[None, :]


def exp_poly_matvec(lam, coeffs, x, y, V, xp=np):
    """f(z) = exp(lam z) * poly(z). Hadamard of rank-1 and low-rank (A.2.3)."""
    ly = lam * y
    m = xp.max(ly) if y.shape[0] else 0.0
    Vexp = xp.exp(ly - m)[:, None] * V
    out = polynomial_matvec(coeffs, x, y, Vexp, xp=xp)
    return xp.exp(lam * x + m)[:, None] * out


def trig_matvec(omega, phi, x, y, V, kind="cos", xp=np):
    """f(z) = cos(w z + phi) (or sin). Rank-2 via angle addition."""
    cx, sx = xp.cos(omega * x + phi), xp.sin(omega * x + phi)
    cy, sy = xp.cos(omega * y), xp.sin(omega * y)
    Sc = cy @ V
    Ss = sy @ V
    if kind == "cos":  # cos(A+B) = cosA cosB - sinA sinB
        return cx[:, None] * Sc[None, :] - sx[:, None] * Ss[None, :]
    # sin(A+B) = sinA cosB + cosA sinB
    return sx[:, None] * Sc[None, :] + cx[:, None] * Ss[None, :]


def snap_to_grid(x, h, xp=np, tol=1e-6):
    """Integer grid indices of x w.r.t. spacing h; raises if not grid-aligned."""
    ix = x / h
    ri = xp.round(ix)
    if xp is np and np.max(np.abs(ix - ri)) > tol:
        raise ValueError("values are not aligned to the grid")
    return ri.astype(xp.int32 if xp is not np else np.int64)


def detect_grid(x, y, tol=1e-9) -> float | None:
    """Find spacing h such that all x,y are (close to) integer multiples of h.

    Uses a float-gcd; returns None if no reasonable grid exists (h too small).
    """
    vals = np.abs(np.concatenate([np.asarray(x).ravel(), np.asarray(y).ravel()]))
    vals = np.unique(vals[vals > tol])  # dedupe: the gcd loop is per-value
    if vals.size == 0:
        return 1.0
    # fast path: the smallest value divides everything (unit/rational-weight
    # trees) — one vectorized residual check instead of the gcd loop. Below
    # the 1e-7 noise floor the residual test is meaningless (tol-scale
    # values pass it spuriously), so such inputs take the gcd loop, which
    # rejects them exactly as before.
    h = float(vals[0])
    mult = vals / h
    if h >= 1e-7 and float(np.max(np.abs(vals - np.round(mult) * h))) <= tol:
        return None if float(vals[-1] / h) > 5e6 else h
    g = h
    for v in vals[1:]:
        g = _fgcd(g, float(v), tol)
        if g < 1e-7:
            return None
    span = float(vals.max() / g)
    if span > 5e6:  # FFT length would be impractical
        return None
    return g


def _fgcd(a, b, tol):
    while b > tol:
        a, b = b, a % b
        if b > tol and b / a > 1 - 1e-12:
            b = 0.0
    return a


def hankel_fft_matvec(f: Callable, x, y, V, h: float, xp=np):
    """Exact multiply for ANY f when x, y lie on a common grid of spacing h.

    This is the paper's 'trees with positive rational weights' embedding
    (App. A.2.3) and subsumes the Vandermonde case used by its best ViT
    variants: M embeds into a Hankel matrix; multiplication by correlation
    with the sampled kernel F[k] = f(k·h) via FFT, O(L log L).
    """
    if xp is not np:  # static shapes required under jit: see core.toeplitz
        raise NotImplementedError("hankel_fft_matvec is the host/numpy path")
    ix = snap_to_grid(x, h, xp=xp)  # (a,)
    iy = snap_to_grid(y, h, xp=xp)  # (b,)
    max_ix = int(ix.max()) if ix.size else 0
    max_iy = int(iy.max()) if iy.size else 0
    L = max_ix + max_iy + 1
    F = f(h * np.arange(L, dtype=np.float64))  # (L,)
    # scatter V by iy:  P[m] = sum_{j: iy[j]=m} V[j]
    d = V.shape[1]
    P = np.zeros((max_iy + 1, d), dtype=np.result_type(V.dtype, np.float64))
    np.add.at(P, iy, V)
    out_full = fft_correlate(F, P, xp=np)  # (L, d) ; out_full[k] = sum_m F[k+m] P[m]
    return out_full[ix].astype(V.dtype)


def fft_correlate(F, P, xp=np):
    """out[k] = sum_m F[k+m] P[m] for k in [0, len(F)-1]; zero-padded FFT."""
    L = F.shape[0]
    m = P.shape[0]
    n = 1 << int(np.ceil(np.log2(L + m)))
    Ff = xp.fft.rfft(F, n=n)
    # correlation = conv with reversed P
    Pf = xp.fft.rfft(P[::-1], n=n, axis=0)
    prod = Ff[:, None] * Pf
    full = xp.fft.irfft(prod, n=n, axis=0)
    # index k of correlation sits at position k + m - 1 of the convolution
    return full[m - 1 : m - 1 + L]


def chebyshev_points(lo, hi, r, xp=np):
    k = np.arange(r)
    t = np.cos((2 * k + 1) * np.pi / (2 * r))  # Chebyshev nodes of 1st kind
    return xp.asarray((lo + hi) / 2.0 + (hi - lo) / 2.0 * t)


def _barycentric_weights(nodes):
    # for Chebyshev 1st-kind nodes: w_k = (-1)^k sin((2k+1)pi/(2r))
    r = nodes.shape[0]
    k = np.arange(r)
    return (-1.0) ** k * np.sin((2 * k + 1) * np.pi / (2 * r))


def lagrange_matrix(pts, nodes, xp=np):
    """L[i, k] = k-th Lagrange cardinal function at pts[i] (barycentric)."""
    w = xp.asarray(_barycentric_weights(np.asarray(nodes)))
    diff = pts[:, None] - nodes[None, :]
    # handle exact hits
    small = xp.abs(diff) < 1e-14
    diff = xp.where(small, 1.0, diff)
    terms = w[None, :] / diff
    L = terms / xp.sum(terms, axis=1, keepdims=True)
    any_small = xp.any(small, axis=1, keepdims=True)
    L = xp.where(any_small, small.astype(L.dtype), L)
    return L


def chebyshev_matvec(f: Callable, x, y, V, degree: int = 32, xp=np,
                     tol: float | None = None, _depth: int = 0):
    """Low-rank multiply via 2D Chebyshev interpolation of f(x+y).

    f(x_i+y_j) ~= sum_{k,l} B[k,l] Lx[i,k] Ly[j,l],  B[k,l] = f(xc_k + yc_l).
    Spectral accuracy for f analytic in a neighbourhood of [x_lo+y_lo, x_hi+y_hi].
    If `tol` is given (numpy path only), the x/y boxes are bisected adaptively
    (H-matrix style) until the sampled interpolation error is below tol —
    this covers sharply-peaked rational f and Cauchy-like kernels.
    """
    if x.shape[0] == 0 or y.shape[0] == 0:
        return xp.zeros((x.shape[0],) + V.shape[1:], dtype=V.dtype)
    x_lo, x_hi = xp.min(x), xp.max(x)
    y_lo, y_hi = xp.min(y), xp.max(y)
    if xp is np:
        x_lo, x_hi, y_lo, y_hi = float(x_lo), float(x_hi), float(y_lo), float(y_hi)
    xc = chebyshev_points(x_lo, x_hi + 1e-12, degree, xp)
    yc = chebyshev_points(y_lo, y_hi + 1e-12, degree, xp)
    B = f(xc[:, None] + yc[None, :])  # (r, r)
    Lx = lagrange_matrix(x, xc, xp)  # (a, r)
    Ly = lagrange_matrix(y, yc, xp)  # (b, r)
    out = Lx @ (B @ (Ly.T @ V))
    if tol is not None and xp is np and _depth < 12:
        # sample a few entries to estimate error; bisect if too large
        rng = np.random.default_rng(0)
        na = min(16, x.shape[0])
        nb = min(16, y.shape[0])
        ii = rng.integers(0, x.shape[0], size=na)
        jj = rng.integers(0, y.shape[0], size=nb)
        approx = (Lx[ii] @ B @ Ly[jj].T)
        exact = f(x[ii][:, None] + y[jj][None, :])
        scale = max(np.max(np.abs(exact)), 1e-30)
        if np.max(np.abs(approx - exact)) / scale > tol:
            if x.shape[0] >= y.shape[0] and x.shape[0] > 2 * degree:
                mid = (x_lo + x_hi) / 2.0
                sel = x <= mid
                out = np.empty((x.shape[0],) + V.shape[1:], dtype=out.dtype)
                out[sel] = chebyshev_matvec(f, x[sel], y, V, degree, xp, tol, _depth + 1)
                out[~sel] = chebyshev_matvec(f, x[~sel], y, V, degree, xp, tol, _depth + 1)
            elif y.shape[0] > 2 * degree:
                mid = (y_lo + y_hi) / 2.0
                sel = y <= mid
                out = chebyshev_matvec(f, x, y[sel], V[sel], degree, xp, tol, _depth + 1)
                out = out + chebyshev_matvec(f, x, y[~sel], V[~sel], degree, xp, tol, _depth + 1)
            else:  # small block: fall back to dense (exact)
                out = dense_matvec(f, x, y, V, xp)
    return out


def cauchy_matvec(p, q, V, xp=np, degree: int = 24, tol: float = 1e-10):
    """out_i = sum_j V_j / (p_i + q_j); p_i + q_j > 0 required.

    The Cauchy-like LDR workhorse for f(x) = exp(lam x)/(x+c) (Sec 3.2.1):
    adaptive Chebyshev H-multiply, machine-precision configurable.
    """
    return chebyshev_matvec(lambda s: 1.0 / s, p, q, V, degree=degree, xp=xp, tol=tol)


# ----------------------------------------------------------------------------
# CordialFn: f + a multiply strategy (host/numpy API used by the integrator)
# ----------------------------------------------------------------------------


@dataclasses.dataclass
class CordialFn:
    """A scalar function f plus the structured-multiply strategy for
    M = [f(x_i+y_j)]. Base class multiplies densely."""

    def __call__(self, z):
        raise NotImplementedError

    def matvec(self, x, y, V, xp=np):
        return dense_matvec(self, x, y, V, xp=xp)

    @property
    def f0(self):
        """f(0) — used by the integrator's pivot correction."""
        return float(self(np.zeros(1))[0])


@dataclasses.dataclass
class Polynomial(CordialFn):
    coeffs: tuple  # c_0..c_B

    def __call__(self, z):
        out = 0.0
        for c in reversed(self.coeffs):
            out = out * z + c
        return out

    def matvec(self, x, y, V, xp=np):
        return polynomial_matvec(np.asarray(self.coeffs, dtype=np.float64), x, y, V, xp=xp)


@dataclasses.dataclass
class Exponential(CordialFn):
    lam: float
    scale: float = 1.0

    def __call__(self, z):
        return self.scale * np.exp(self.lam * z)

    def matvec(self, x, y, V, xp=np):
        return exponential_matvec(self.lam, x, y, V, xp=xp, scale=self.scale)


@dataclasses.dataclass
class ExpPoly(CordialFn):
    """f(z) = exp(lam z) * poly(z)."""

    lam: float
    coeffs: tuple

    def __call__(self, z):
        p = 0.0
        for c in reversed(self.coeffs):
            p = p * z + c
        return np.exp(self.lam * z) * p

    def matvec(self, x, y, V, xp=np):
        return exp_poly_matvec(self.lam, np.asarray(self.coeffs), x, y, V, xp=xp)


@dataclasses.dataclass
class Trigonometric(CordialFn):
    omega: float
    phi: float = 0.0
    kind: str = "cos"

    def __call__(self, z):
        fn = np.cos if self.kind == "cos" else np.sin
        return fn(self.omega * z + self.phi)

    def matvec(self, x, y, V, xp=np):
        return trig_matvec(self.omega, self.phi, x, y, V, kind=self.kind, xp=xp)


@dataclasses.dataclass
class Rational(CordialFn):
    """f(z) = poly_num(z) / poly_den(z) (Sec 4.3's learnable family).

    Strategy: exact Hankel/FFT when distances are grid-aligned (rational tree
    weights), else adaptive Chebyshev to `tol`.
    """

    num: tuple
    den: tuple
    tol: float = 1e-10
    degree: int = 32

    def __call__(self, z):
        n = 0.0
        for c in reversed(self.num):
            n = n * z + c
        d = 0.0
        for c in reversed(self.den):
            d = d * z + c
        return n / d

    def matvec(self, x, y, V, xp=np):
        h = detect_grid(x, y) if xp is np else None
        if h is not None:
            return hankel_fft_matvec(self, x, y, V, h, xp=xp)
        return chebyshev_matvec(self, x, y, V, degree=self.degree, xp=xp, tol=self.tol)


@dataclasses.dataclass
class ExpQuadratic(CordialFn):
    """f(z) = exp(u z^2 + v z + w) — the paper's best ViT-variant family.

    Exact via the rational-weight Hankel embedding (== the paper's
    D1·Vandermonde·D2 route); Chebyshev fallback for irrational weights.
    """

    u: float
    v: float
    w: float = 0.0
    tol: float = 1e-10
    degree: int = 48

    def __call__(self, z):
        return np.exp(self.u * z * z + self.v * z + self.w)

    def matvec(self, x, y, V, xp=np):
        h = detect_grid(x, y) if xp is np else None
        if h is not None:
            return hankel_fft_matvec(self, x, y, V, h, xp=xp)
        return chebyshev_matvec(self, x, y, V, degree=self.degree, xp=xp, tol=self.tol)


@dataclasses.dataclass
class ExpRational(CordialFn):
    """f(z) = exp(lam z) / (z + c), c > 0 — the paper's Cauchy-LDR example."""

    lam: float
    c: float
    tol: float = 1e-11
    degree: int = 32

    def __call__(self, z):
        return np.exp(self.lam * z) / (z + self.c)

    def matvec(self, x, y, V, xp=np):
        # M(i,j) = exp(lam x_i) exp(lam y_j) / ((x_i + c/2) + (y_j + c/2)):
        # diagonal-scaled Cauchy (low displacement rank).
        dx = np.exp(self.lam * np.asarray(x))
        dy = np.exp(self.lam * np.asarray(y))
        out = cauchy_matvec(np.asarray(x) + self.c / 2.0, np.asarray(y) + self.c / 2.0,
                            dy[:, None] * V, xp=xp, degree=self.degree, tol=self.tol)
        return dx[:, None] * out


@dataclasses.dataclass
class AnyFn(CordialFn):
    """Arbitrary callable f; Hankel-exact on grids, else Chebyshev(tol)."""

    fn: Callable
    tol: float = 1e-9
    degree: int = 48

    def __call__(self, z):
        return self.fn(z)

    def matvec(self, x, y, V, xp=np):
        h = detect_grid(x, y) if xp is np else None
        if h is not None:
            return hankel_fft_matvec(self.fn, x, y, V, h, xp=xp)
        return chebyshev_matvec(self.fn, x, y, V, degree=self.degree, xp=xp, tol=self.tol)
