"""FTFI core: the paper's contribution as composable JAX modules."""
from repro.core.cordial import (  # noqa: F401
    AnyFn, CordialFn, ExpPoly, ExpQuadratic, ExpRational, Exponential,
    Polynomial, Rational, Trigonometric,
)
from repro.core.integrate import (  # noqa: F401
    BTFI, FTFI, IntegrationPlan, compile_plan, execute_plan,
    chebyshev_batched_matvec, polynomial_batched_matvec,
)
from repro.core.integrator_tree import build_integrator_tree, it_stats  # noqa: F401
from repro.core.toeplitz import (  # noqa: F401
    causal_toeplitz_matvec, symmetric_toeplitz_matvec, toeplitz_dense,
)
from repro.core import masks  # noqa: F401
