"""FTFI core: the paper's contribution as composable JAX modules."""
from repro.core.cordial import (  # noqa: F401
    AnyFn, CordialFn, ExpPoly, ExpQuadratic, ExpRational, Exponential,
    Polynomial, Rational, Trigonometric,
)
from repro.core.integrate import (  # noqa: F401
    BTFI, ExpMP, FTFI, IntegrationPlan, clear_plan_cache,
    compile_forest_plan, compile_plan,
)
from repro.core.itree_flat import (  # noqa: F401
    FlatIT, build_flat_forest, build_flat_it, clear_flat_cache, flat_stats,
    tree_fingerprint,
)
from repro.graphs.graph import Forest  # noqa: F401
from repro.core.engines import (  # noqa: F401
    Integrator, available_backends, chebyshev_batched_matvec, execute_plan,
    polynomial_batched_matvec, register_backend,
)
from repro.core.plan_api import (  # noqa: F401
    PlanParams, PlanSpec,
)
from repro.core.plan_guard import (  # noqa: F401
    PlanGuardWarning, PlanValidationError,
)
from repro.core.ladder import (  # noqa: F401
    BackendDemotionWarning, LadderExhaustedError,
)
from repro.core.integrator_tree import build_integrator_tree, it_stats  # noqa: F401
from repro.core.toeplitz import (  # noqa: F401
    causal_toeplitz_matvec, symmetric_toeplitz_matvec, toeplitz_dense,
)
from repro.core import masks  # noqa: F401
