"""IntegratorTree (IT): the paper's Sec-3.1 data structure.

Built once per input tree (host-side numpy, O(N log N)); reused for any number
of tensor fields. Each non-leaf node stores the balanced-separator split
(T_left, T_right, pivot) from Lemma 3.1 plus the distance-group arrays
(left-ids / left-d / left-id-d — right-s is represented implicitly by
left_id_d-based segment sums).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graphs.graph import WeightedTree


@dataclasses.dataclass(frozen=True)
class ITNode:
    """Immutable IT node: every array an integrator needs is computed once at
    build time, so the same IT can be walked concurrently from many threads
    and reused across plans without integrate-time mutation."""

    vertex_ids: np.ndarray  # (k,) global ids of this sub-tree's vertices
    depth: int
    # leaf payload: raw pairwise distances for the sub-tree (f applied lazily)
    leaf_dists: np.ndarray | None = None
    # internal payload
    pivot: int | None = None  # global id
    left: "ITNode | None" = None
    right: "ITNode | None" = None
    left_ids: np.ndarray | None = None  # (kL,) global ids (incl. pivot)
    right_ids: np.ndarray | None = None
    left_d: np.ndarray | None = None  # (uL,) unique pivot distances (left_d[0]=0)
    right_d: np.ndarray | None = None
    left_id_d: np.ndarray | None = None  # (kL,) index into left_d per vertex
    right_id_d: np.ndarray | None = None
    # segment-sum layout per side: vertex ids sorted by distance group (stable)
    # plus the run boundaries of equal groups — np.add.reduceat over these is
    # ~50x faster than np.add.at for wide fields (e.g. GW transport plans)
    left_sorted_ids: np.ndarray | None = None  # (kL,) ids ordered by left_id_d
    left_seg_starts: np.ndarray | None = None  # (uL,) run starts in the order
    right_sorted_ids: np.ndarray | None = None
    right_seg_starts: np.ndarray | None = None

    @property
    def is_leaf(self) -> bool:
        return self.leaf_dists is not None


def _adjacency(tree: WeightedTree):
    return tree.csr()


def _subtree_local(indptr, indices, data, vertices, glob_to_loc):
    """Local CSR restricted to `vertices` (assumed connected)."""
    return indptr, indices, data  # we traverse with membership checks instead


def _centroid_split(indptr, indices, data, vertices: np.ndarray,
                    member: np.ndarray, rng: np.random.Generator):
    """Lemma 3.1: find pivot p and a partition of p's branch components into
    (left, right) with each side >= n/4 (plus the shared pivot).

    `member` is a global boolean mask selecting this sub-tree's vertices.
    Returns (pivot, left_ids, right_ids) — both include the pivot.
    """
    n = vertices.size
    root = int(vertices[0])
    # iterative DFS to get order & parent within the sub-tree
    order = np.empty(n, dtype=np.int64)
    parent = {}
    stack = [root]
    seen = {root}
    k = 0
    while stack:
        u = stack.pop()
        order[k] = u
        k += 1
        for ei in range(indptr[u], indptr[u + 1]):
            v = int(indices[ei])
            if member[v] and v not in seen:
                seen.add(v)
                parent[v] = u
                stack.append(v)
    assert k == n, "sub-tree is disconnected"
    # subtree sizes via reverse order
    size = {int(u): 1 for u in order}
    for u in order[::-1]:
        u = int(u)
        if u != root:
            size[parent[u]] += size[u]
    # centroid: vertex whose removal leaves all components <= n/2
    pivot = root
    while True:
        best_child, best_size = None, -1
        for ei in range(indptr[pivot], indptr[pivot + 1]):
            v = int(indices[ei])
            if member[v] and (v in parent and parent[v] == pivot):
                if size[v] > best_size:
                    best_child, best_size = v, size[v]
        up_size = n - size[pivot]  # component through the parent
        if best_size <= n // 2 and up_size <= n // 2:
            break
        if up_size > best_size:
            # re-root: walking towards parent; easiest is to recompute by
            # moving pivot to parent side. Classic trick: move to the heavy side.
            pivot = parent[pivot]
            # recompute sizes w.r.t. re-rooted orientation lazily: instead of
            # re-rooting, use the standard invariant: moving towards the heavy
            # component strictly decreases its size; sizes w.r.t. original root
            # still identify the heavy side via up/down test above.
            # (size[] stays rooted at `root`; up_size formula handles it.)
        else:
            pivot = best_child
    # components around pivot: each neighbour branch
    comp_ids: list[list[int]] = []
    for ei in range(indptr[pivot], indptr[pivot + 1]):
        v = int(indices[ei])
        if not member[v]:
            continue
        # collect branch through v (excluding pivot)
        branch = []
        bstack = [v]
        bseen = {pivot, v}
        while bstack:
            u = bstack.pop()
            branch.append(u)
            for ej in range(indptr[u], indptr[u + 1]):
                wv = int(indices[ej])
                if member[wv] and wv not in bseen:
                    bseen.add(wv)
                    bstack.append(wv)
        comp_ids.append(branch)
    # greedy balanced partition (largest-first into the lighter side)
    comp_ids.sort(key=len, reverse=True)
    left: list[int] = []
    right: list[int] = []
    for branch in comp_ids:
        (left if len(left) <= len(right) else right).extend(branch)
    left_ids = np.array([pivot] + left, dtype=np.int64)
    right_ids = np.array([pivot] + right, dtype=np.int64)
    return pivot, left_ids, right_ids


def _pivot_distances(indptr, indices, data, pivot: int, ids: np.ndarray,
                     member_side: np.ndarray):
    """Distances from pivot to each vertex of `ids` (restricted traversal)."""
    dist = {pivot: 0.0}
    stack = [pivot]
    while stack:
        u = stack.pop()
        for ei in range(indptr[u], indptr[u + 1]):
            v = int(indices[ei])
            if member_side[v] and v not in dist:
                dist[v] = dist[u] + float(data[ei])
                stack.append(v)
    return np.array([dist[int(i)] for i in ids], dtype=np.float64)


def _leaf_distance_matrix(indptr, indices, data, ids: np.ndarray,
                          member: np.ndarray) -> np.ndarray:
    k = ids.size
    loc = {int(v): i for i, v in enumerate(ids)}
    D = np.zeros((k, k), dtype=np.float64)
    for si, s in enumerate(ids):
        dist = {int(s): 0.0}
        stack = [int(s)]
        while stack:
            u = stack.pop()
            for ei in range(indptr[u], indptr[u + 1]):
                v = int(indices[ei])
                if member[v] and v not in dist:
                    dist[v] = dist[u] + float(data[ei])
                    stack.append(v)
        for v, dv in dist.items():
            D[si, loc[v]] = dv
    return D


def _segment_layout(ids: np.ndarray, id_d: np.ndarray):
    """Sorted order + run boundaries for distance-group segment sums."""
    order = np.argsort(id_d, kind="stable")
    sorted_idd = id_d[order]
    starts = np.flatnonzero(np.r_[True, sorted_idd[1:] != sorted_idd[:-1]])
    return ids[order], starts


def build_integrator_tree(tree: WeightedTree, leaf_size: int = 64,
                          seed: int = 0) -> ITNode:
    """Construct the IT for `tree` (paper Sec 3.1). leaf_size = t (>=6)."""
    leaf_size = max(int(leaf_size), 6)
    indptr, indices, data = _adjacency(tree)
    rng = np.random.default_rng(seed)
    n = tree.num_vertices
    member_buf = np.zeros(n, dtype=bool)

    def build(vertex_ids: np.ndarray, depth: int) -> ITNode:
        member = np.zeros(n, dtype=bool)
        member[vertex_ids] = True
        if vertex_ids.size <= leaf_size:
            D = _leaf_distance_matrix(indptr, indices, data, vertex_ids, member)
            return ITNode(vertex_ids=vertex_ids, depth=depth, leaf_dists=D)
        pivot, left_ids, right_ids = _centroid_split(
            indptr, indices, data, vertex_ids, member, rng)
        mleft = np.zeros(n, dtype=bool)
        mleft[left_ids] = True
        mright = np.zeros(n, dtype=bool)
        mright[right_ids] = True
        dl = _pivot_distances(indptr, indices, data, pivot, left_ids, mleft)
        dr = _pivot_distances(indptr, indices, data, pivot, right_ids, mright)
        left_d, left_id_d = np.unique(dl, return_inverse=True)
        right_d, right_id_d = np.unique(dr, return_inverse=True)
        assert left_d[0] == 0.0 and right_d[0] == 0.0  # pivot group
        lso, lst = _segment_layout(left_ids, left_id_d)
        rso, rst = _segment_layout(right_ids, right_id_d)
        return ITNode(
            vertex_ids=vertex_ids, depth=depth, pivot=pivot,
            left=build(left_ids, depth + 1),
            right=build(right_ids, depth + 1),
            left_ids=left_ids, right_ids=right_ids,
            left_d=left_d, right_d=right_d,
            left_id_d=left_id_d.astype(np.int64),
            right_id_d=right_id_d.astype(np.int64),
            left_sorted_ids=lso, left_seg_starts=lst,
            right_sorted_ids=rso, right_seg_starts=rst,
        )

    return build(np.arange(n, dtype=np.int64), 0)


def it_stats(root: ITNode) -> dict:
    """Diagnostics: depth, node counts, balance check."""
    stats = {"max_depth": 0, "internal": 0, "leaves": 0, "balance_ok": True}

    def walk(node: ITNode):
        stats["max_depth"] = max(stats["max_depth"], node.depth)
        if node.is_leaf:
            stats["leaves"] += 1
            return
        stats["internal"] += 1
        nn = node.vertex_ids.size
        for side in (node.left_ids, node.right_ids):
            if not (nn / 4.0 <= side.size):
                stats["balance_ok"] = False
        walk(node.left)
        walk(node.right)

    walk(root)
    return stats
