"""IntegratorTree (IT): the paper's Sec-3.1 data structure.

This module is now a thin compatibility shim over the flat, vectorized
builder in `repro.core.itree_flat` (frontier-at-a-time numpy, content-hash
cached). `build_integrator_tree` materializes the recursive `ITNode` view
that the host FTFI walks; the plan compiler consumes the flat form directly.

Each non-leaf node stores the balanced-separator split (T_left, T_right,
pivot) from Lemma 3.1 plus the distance-group arrays (left-ids / left-d /
left-id-d); vertex ids are ordered by ascending pivot distance, so the
segment-sum layout (`left_sorted_ids`, `left_seg_starts`) coincides with the
id arrays themselves.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.itree_flat import FlatIT, build_flat_it
from repro.graphs.graph import WeightedTree


@dataclasses.dataclass(frozen=True)
class ITNode:
    """Immutable IT node: every array an integrator needs is computed once at
    build time, so the same IT can be walked concurrently from many threads
    and reused across plans without integrate-time mutation."""

    vertex_ids: np.ndarray  # (k,) global ids of this sub-tree's vertices
    depth: int
    # leaf payload: raw pairwise distances for the sub-tree (f applied lazily)
    leaf_dists: np.ndarray | None = None
    # internal payload
    pivot: int | None = None  # global id
    left: "ITNode | None" = None
    right: "ITNode | None" = None
    left_ids: np.ndarray | None = None  # (kL,) global ids (incl. pivot)
    right_ids: np.ndarray | None = None
    left_d: np.ndarray | None = None  # (uL,) unique pivot distances (left_d[0]=0)
    right_d: np.ndarray | None = None
    left_id_d: np.ndarray | None = None  # (kL,) index into left_d per vertex
    right_id_d: np.ndarray | None = None
    # segment-sum layout per side: vertex ids sorted by distance group (stable)
    # plus the run boundaries of equal groups — np.add.reduceat over these is
    # ~50x faster than np.add.at for wide fields (e.g. GW transport plans)
    left_sorted_ids: np.ndarray | None = None  # (kL,) ids ordered by left_id_d
    left_seg_starts: np.ndarray | None = None  # (uL,) run starts in the order
    right_sorted_ids: np.ndarray | None = None
    right_seg_starts: np.ndarray | None = None

    @property
    def is_leaf(self) -> bool:
        return self.leaf_dists is not None


def _materialize(flat: FlatIT, ref: int) -> ITNode:
    if ref < 0:
        li = -ref - 1
        return ITNode(vertex_ids=flat.leaf_ids[li],
                      depth=int(flat.leaf_depth[li]),
                      leaf_dists=flat.leaf_dists[li])
    L, R = flat.left[ref], flat.right[ref]
    return ITNode(
        vertex_ids=np.concatenate([L.ids, R.ids[1:]]),
        depth=int(flat.node_depth[ref]),
        pivot=int(flat.pivots[ref]),
        left=_materialize(flat, int(flat.children[ref, 0])),
        right=_materialize(flat, int(flat.children[ref, 1])),
        left_ids=L.ids, right_ids=R.ids,
        left_d=L.d, right_d=R.d,
        left_id_d=L.id_d, right_id_d=R.id_d,
        # ids are emitted in ascending-distance order, so the segment layout
        # is the identity permutation
        left_sorted_ids=L.ids, left_seg_starts=L.seg_starts,
        right_sorted_ids=R.ids, right_seg_starts=R.seg_starts,
    )


def build_integrator_tree(tree: WeightedTree, leaf_size: int = 64,
                          seed: int = 0) -> ITNode:
    """Construct the IT for `tree` (paper Sec 3.1). leaf_size = t (>=6).

    Delegates to the flat vectorized builder (cached per tree content hash)
    and materializes the recursive node view on top of its arrays.
    """
    flat = build_flat_it(tree, leaf_size=leaf_size, seed=seed)
    return _materialize(flat, flat.root_ref)


def it_stats(root: ITNode) -> dict:
    """Diagnostics: depth, node counts, balance check."""
    stats = {"max_depth": 0, "internal": 0, "leaves": 0, "balance_ok": True}

    def walk(node: ITNode):
        stats["max_depth"] = max(stats["max_depth"], node.depth)
        if node.is_leaf:
            stats["leaves"] += 1
            return
        stats["internal"] += 1
        nn = node.vertex_ids.size
        for side in (node.left_ids, node.right_ids):
            if not (nn / 4.0 <= side.size):
                stats["balance_ok"] = False
        walk(node.left)
        walk(node.right)

    walk(root)
    return stats
