"""Flat, vectorized IntegratorTree builder (paper Sec 3.1, Lemma 3.1).

Replaces the per-node recursive construction of `integrator_tree.py` with a
frontier-at-a-time sweep: every decomposition level processes ALL active
subtrees in one batch of numpy array passes over CSR adjacency —

  1. one restricted BFS per level (all subtree roots at once) gives order,
     parents, hop depths and root distances for every active subtree;
  2. subtree sizes come from a reverse level-by-level `np.add.at`, the heavy
     child per vertex from one `np.maximum.at`, and the pivot of every
     subtree from a segmented argmin of max(heavy, n_sub - size) — a TRUE
     centroid (all components <= n_sub/2) with no re-rooting walk, so the
     stale-size hand-wave of the old `_centroid_split` is gone by
     construction;
  3. a second joint BFS rooted at the pivots yields pivot distances and
     branch (component) labels; a greedy largest-first pass over components
     (O(#components), not O(#vertices)) splits each subtree into the
     balanced (left, right) sides of Lemma 3.1;
  4. distance groups for all nodes of the level come from ONE lexsort over
     (group, distance) — unique distances, inverse indices and segment-sum
     run boundaries all fall out of the same run-length pass;
  5. leaf pairwise distances are computed in one shot per level from
     root-distance + LCA prefix arrays, d(u,v) = d(u) + d(v) - 2 d(lca),
     via batched binary lifting over the level's BFS forest — no per-leaf,
     per-source traversals.

Because every level already batches an arbitrary number of independent
subtrees, a whole FOREST of trees builds in the same sweep: `build_flat_forest`
seeds level 0 with one subtree per tree (vertex ids offset into the packed
forest layout) and ONE frontier loop decomposes all trees' levels together —
90 small graphs cost the same handful of numpy passes as one graph.

Results are cached per (content hash, leaf_size, seed) in one shared
BoundedLRU for trees and forests: repeated Integrator construction over the
same topology (serving, benchmarks, ViT mask rebuilds) amortizes to a dict
lookup. `seed` must be part of the key even though the current builder is
deterministic — a seeded builder variant must never alias differently-seeded
builds to the first one built.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib

import numpy as np

from repro.core.lru import BoundedLRU
from repro.graphs.graph import WeightedTree


@dataclasses.dataclass(frozen=True)
class FlatSide:
    """One side of an internal IT node. `ids[0]` is the pivot; the remaining
    ids are ordered by ascending pivot distance, so `ids` IS the segment-sum
    layout (`seg_starts` are the run boundaries of equal distance groups)."""

    ids: np.ndarray  # (k,) global vertex ids, pivot first
    id_d: np.ndarray  # (k,) index into `d` per vertex (monotone)
    d: np.ndarray  # (u,) unique pivot distances, d[0] == 0.0
    seg_starts: np.ndarray  # (u,) run starts of equal distance groups in ids


@dataclasses.dataclass(frozen=True)
class FlatIT:
    """Flat IT: internal nodes + leaves as parallel arrays/lists.

    `children[i]` holds two refs: >= 0 is an internal node index, < 0 is a
    leaf encoded as -(leaf_index + 1). `root_ref` uses the same encoding.
    For forest builds, `root_refs[t]` is tree t's root in the same encoding
    and all vertex ids are global (offset into the packed forest layout);
    `root_ref` stays the first tree's root for single-tree compatibility.
    """

    n: int
    leaf_size: int
    root_ref: int
    pivots: np.ndarray  # (I,) global pivot ids
    node_depth: np.ndarray  # (I,)
    children: np.ndarray  # (I, 2)
    left: list  # list[FlatSide]
    right: list  # list[FlatSide]
    leaf_ids: list  # list[np.ndarray]
    leaf_dists: list  # list[np.ndarray (k,k)]
    leaf_depth: np.ndarray  # (L,)
    root_refs: np.ndarray | None = None  # (K,) per-tree roots (forest builds)

    @property
    def num_internal(self) -> int:
        return int(self.pivots.size)

    @property
    def num_leaves(self) -> int:
        return len(self.leaf_ids)

    # cached_property writes the instance __dict__ directly, which bypasses
    # the frozen-dataclass __setattr__ — the concatenated views below are
    # derived data, so caching them on the (immutable) instance is safe and
    # amortizes across repeated plan assemblies over one IT
    @functools.cached_property
    def side_cat(self) -> dict:
        """Concatenated CSR over ALL job sides, interleaved as side 2i =
        left[i], side 2i+1 = right[i]. `kptr`/`uptr` are the exclusive
        prefix sums of per-side vertex / unique-distance counts, so the
        vectorized plan assembly addresses every side with array ops
        instead of re-walking the per-node FlatSide objects."""
        sides: list = []
        for i in range(self.num_internal):
            sides.append(self.left[i])
            sides.append(self.right[i])
        k = np.array([s.ids.size for s in sides], np.int64)
        u = np.array([s.d.size for s in sides], np.int64)
        kptr = np.zeros(k.size + 1, np.int64)
        np.cumsum(k, out=kptr[1:])
        uptr = np.zeros(u.size + 1, np.int64)
        np.cumsum(u, out=uptr[1:])

        def cat(arrs, dtype):
            return (np.concatenate(arrs) if arrs
                    else np.zeros(0, dtype))

        return {
            "k": k, "u": u, "kptr": kptr, "uptr": uptr,
            "ids": cat([s.ids for s in sides], np.int64),
            "id_d": cat([s.id_d for s in sides], np.int64),
            "d": cat([s.d for s in sides], np.float64),
        }

    @functools.cached_property
    def leaf_cat(self) -> dict:
        """Concatenated leaf arrays: ids CSR plus the raveled distance
        matrices (`dptr` is the exclusive prefix sum of k_i^2)."""
        k = np.array([ids.size for ids in self.leaf_ids], np.int64)
        ptr = np.zeros(k.size + 1, np.int64)
        np.cumsum(k, out=ptr[1:])
        dptr = np.zeros(k.size + 1, np.int64)
        np.cumsum(k * k, out=dptr[1:])
        ids = (np.concatenate(self.leaf_ids) if self.leaf_ids
               else np.zeros(0, np.int64))
        dflat = (np.concatenate([D.ravel() for D in self.leaf_dists])
                 if self.leaf_dists else np.zeros(0, np.float64))
        return {"k": k, "ptr": ptr, "dptr": dptr, "ids": ids,
                "dflat": dflat}


# ----------------------------------------------------------------------------
# content-hash cache
# ----------------------------------------------------------------------------

_CACHE = BoundedLRU(32)


def tree_fingerprint(tree: WeightedTree) -> str:
    """Content hash of a tree's topology + weights (plan/IT cache key)."""
    h = hashlib.sha1()
    h.update(np.int64(tree.num_vertices).tobytes())
    h.update(np.ascontiguousarray(tree.edges_u).tobytes())
    h.update(np.ascontiguousarray(tree.edges_v).tobytes())
    h.update(np.ascontiguousarray(tree.weights).tobytes())
    return h.hexdigest()


def clear_flat_cache() -> None:
    _CACHE.clear()


def build_flat_it(tree: WeightedTree, leaf_size: int = 64, seed: int = 0,
                  use_cache: bool = True) -> FlatIT:
    """Build (or fetch from cache) the flat IT for `tree`.

    `seed` is kept for API compatibility with the old recursive builder (the
    current construction is fully deterministic) but is still part of the
    cache key: differently-seeded builds must never alias.
    """
    leaf_size = max(int(leaf_size), 6)
    if use_cache:
        key = (tree_fingerprint(tree), leaf_size, int(seed))
        hit = _CACHE.get(key)
        if hit is not None:
            return hit
    flat = _build([tree], leaf_size)
    if use_cache:
        _CACHE.put(key, flat)
    return flat


def build_flat_forest(trees, leaf_size: int = 64, seed: int = 0,
                      use_cache: bool = True) -> FlatIT:
    """Build (or fetch from cache) ONE flat IT covering every tree of a
    forest: level 0 starts with one active subtree per tree (vertex ids
    offset into the packed layout), so a single frontier loop decomposes all
    trees' levels together. Shares the content-hash cache with
    `build_flat_it` (keyed by the tuple of per-tree fingerprints)."""
    trees = list(getattr(trees, "trees", trees))
    if not trees:
        raise ValueError("build_flat_forest needs at least one tree")
    leaf_size = max(int(leaf_size), 6)
    if use_cache:
        key = (tuple(tree_fingerprint(t) for t in trees), leaf_size,
               int(seed))
        hit = _CACHE.get(key)
        if hit is not None:
            return hit
    flat = _build(trees, leaf_size)
    if use_cache:
        _CACHE.put(key, flat)
    return flat


# ----------------------------------------------------------------------------
# vectorized primitives
# ----------------------------------------------------------------------------


def _ranges(starts: np.ndarray, counts: np.ndarray) -> np.ndarray:
    """Concatenation of arange(starts[i], starts[i]+counts[i]) without loops."""
    total = int(counts.sum())
    if total == 0:
        return np.empty(0, np.int64)
    nz = counts > 0
    starts, counts = starts[nz], counts[nz]
    res = np.ones(total, np.int64)
    res[0] = starts[0]
    cs = np.cumsum(counts)[:-1]
    res[cs] = starts[1:] - (starts[:-1] + counts[:-1] - 1)
    return np.cumsum(res)


def _slot_csr(eu, ev, ew, S):
    """Symmetric CSR over slot ids from an undirected edge list."""
    deg = np.bincount(eu, minlength=S) + np.bincount(ev, minlength=S)
    indptr = np.zeros(S + 1, np.int64)
    np.cumsum(deg, out=indptr[1:])
    src = np.concatenate([eu, ev])
    dst = np.concatenate([ev, eu])
    w = np.concatenate([ew, ew])
    o = np.argsort(src, kind="stable")
    return indptr, dst[o], w[o]


def _forest_bfs(indptr, nbr, nw, roots, S):
    """Joint BFS over a forest restricted to the slot adjacency.

    Returns (parent, hop_depth, root_dist, levels); slots unreachable from
    `roots` keep parent == -1 and depth == -1. On a tree no vertex can be
    discovered twice in one frontier expansion, so no dedup is needed.
    """
    parent = np.full(S, -1, np.int64)
    dep = np.full(S, -1, np.int64)
    dist = np.zeros(S, np.float64)
    dep[roots] = 0
    levels = [roots]
    frontier = roots
    while frontier.size:
        counts = indptr[frontier + 1] - indptr[frontier]
        idx = _ranges(indptr[frontier], counts)
        if idx.size == 0:
            break
        nb = nbr[idx]
        src = np.repeat(frontier, counts)
        m = dep[nb] < 0
        nxt = nb[m]
        if nxt.size == 0:
            break
        psrc = src[m]
        parent[nxt] = psrc
        dep[nxt] = dep[psrc] + 1
        dist[nxt] = dist[psrc] + nw[idx][m]
        levels.append(nxt)
        frontier = nxt
    return parent, dep, dist, levels


def _leaf_distance_matrices(sub_ptr, leaf_subs, parent, dep, droot, size, sub):
    """All leaves of a level in one shot via the Euler-interval recurrence

        dist(v, .) = dist(parent(v), .) + w(v)   (minus 2 w(v) inside
                                                  subtree(v))

    computed level-synchronously across every leaf at once: one preorder
    (tin/tout) pass and one row-block update per BFS depth — O(sum k^2) work
    in a handful of numpy passes, no per-leaf per-source traversals."""
    num_sub = sub_ptr.size - 1
    leaf_idx = np.full(num_sub, -1, np.int64)
    leaf_idx[leaf_subs] = np.arange(leaf_subs.size, dtype=np.int64)
    ks = (sub_ptr[leaf_subs + 1] - sub_ptr[leaf_subs]).astype(np.int64)
    kmax = int(ks.max())
    rowbase = np.zeros(leaf_subs.size, np.int64)
    np.cumsum(ks[:-1], out=rowbase[1:])

    ls = _ranges(sub_ptr[leaf_subs], ks)  # all leaf slots
    # preorder tin within each leaf: children get consecutive subranges of
    # the parent interval, ordered by slot id (segmented exclusive scan)
    S = parent.size
    tin = np.zeros(S, np.int64)
    order = np.lexsort((ls, parent[ls], dep[ls]))
    ls_sorted = ls[order]
    dep_sorted = dep[ls_sorted]
    bounds = np.searchsorted(dep_sorted, np.arange(dep_sorted[-1] + 2))
    levels = [ls_sorted[bounds[d]:bounds[d + 1]]
              for d in range(bounds.size - 1)]
    for lv in levels[1:]:
        par = parent[lv]
        cs = np.cumsum(size[lv]) - size[lv]
        gstart = np.r_[True, par[1:] != par[:-1]]
        excl = cs - cs[np.flatnonzero(gstart)][np.cumsum(gstart) - 1]
        tin[lv] = tin[par] + 1 + excl
    tout = tin + size

    D_e = np.zeros((int(ks.sum()), kmax), np.float64)
    # root rows: distances from each leaf root, laid out in euler order
    D_e[rowbase[leaf_idx[sub[ls]]], tin[ls]] = droot[ls]
    cols = np.arange(kmax)[None, :]
    for lv in levels[1:]:
        rb = rowbase[leaf_idx[sub[lv]]]
        w = droot[lv] - droot[parent[lv]]
        blk = D_e[rb + tin[parent[lv]]] + w[:, None]
        inside = (cols >= tin[lv][:, None]) & (cols < tout[lv][:, None])
        blk -= 2.0 * w[:, None] * inside
        D_e[rb + tin[lv]] = blk
    mats = []
    for i, s in enumerate(leaf_subs):
        sl = np.arange(sub_ptr[s], sub_ptr[s + 1], dtype=np.int64)
        perm = tin[sl]
        mats.append(D_e[rowbase[i] + perm][:, perm])
    return mats


# ----------------------------------------------------------------------------
# the level sweep
# ----------------------------------------------------------------------------


def _build(trees: list, leaf_size: int) -> FlatIT:
    # level 0 has one active subtree per tree; vertex ids are offsets into
    # the packed forest layout (single trees are the K == 1 special case)
    sizes0 = np.array([t.num_vertices for t in trees], np.int64)
    offsets = np.zeros(sizes0.size + 1, np.int64)
    np.cumsum(sizes0, out=offsets[1:])
    n = int(offsets[-1])
    verts = np.arange(n, dtype=np.int64)
    sub = np.repeat(np.arange(sizes0.size, dtype=np.int64), sizes0)
    eu = np.concatenate([t.edges_u.astype(np.int64) + offsets[i]
                         for i, t in enumerate(trees)])
    ev = np.concatenate([t.edges_v.astype(np.int64) + offsets[i]
                         for i, t in enumerate(trees)])
    ew = np.concatenate([t.weights.astype(np.float64) for t in trees])
    num_sub = sizes0.size
    pend_parent = np.full(num_sub, -1, np.int64)
    pend_side = np.zeros(num_sub, np.int64)
    depth = 0

    pivots, node_depth, children = [], [], []
    lefts, rights = [], []
    leaf_ids, leaf_dists, leaf_depth = [], [], []
    root_refs = None

    while num_sub:
        S = verts.size
        sub_ptr = np.searchsorted(sub, np.arange(num_sub + 1))
        sizes = np.diff(sub_ptr)
        split_mask = sizes > leaf_size
        split_subs = np.flatnonzero(split_mask)
        leaf_subs = np.flatnonzero(~split_mask)

        # record refs for this level's subtrees (creation order matches)
        int_rank = np.cumsum(split_mask) - split_mask
        leaf_rank = np.cumsum(~split_mask) - (~split_mask)
        ref = np.where(split_mask, len(pivots) + int_rank,
                       -(len(leaf_ids) + leaf_rank) - 1)
        if root_refs is None:
            root_refs = ref.astype(np.int64).copy()  # level 0: tree roots
        for s in range(num_sub):
            if pend_parent[s] >= 0:
                children[pend_parent[s]][pend_side[s]] = int(ref[s])

        indptr, nbr, nw = _slot_csr(eu, ev, ew, S)
        parent1, dep1, droot1, levels1 = _forest_bfs(
            indptr, nbr, nw, sub_ptr[:-1].copy(), S)
        size = np.ones(S, np.int64)
        for lev in levels1[:0:-1]:
            np.add.at(size, parent1[lev], size[lev])

        if leaf_subs.size:
            mats = _leaf_distance_matrices(sub_ptr, leaf_subs, parent1, dep1,
                                           droot1, size, sub)
            for s, D in zip(leaf_subs, mats):
                leaf_ids.append(verts[sub_ptr[s]:sub_ptr[s + 1]].copy())
                leaf_dists.append(D)
                leaf_depth.append(depth)

        if not split_subs.size:
            break

        # --- heavy child, centroid (segmented argmin) ----------------------
        heavy = np.zeros(S, np.int64)
        nonroot = parent1 >= 0
        np.maximum.at(heavy, parent1[nonroot], size[nonroot])
        maxcomp = np.maximum(heavy, sizes[sub] - size)
        minval = np.minimum.reduceat(maxcomp, sub_ptr[:-1])
        pos = np.flatnonzero(maxcomp == minval[sub])
        _, first = np.unique(sub[pos], return_index=True)
        pivot_slot = pos[first]  # (num_sub,) centroid slot per subtree

        # --- BFS from pivots: distances + branch (component) labels -------
        parent2, _, pdist, levels2 = _forest_bfs(
            indptr, nbr, nw, pivot_slot[split_subs], S)
        branch = np.full(S, -1, np.int64)
        pc = levels2[1]  # children of pivots == component roots
        branch[pc] = pc
        for lev in levels2[2:]:
            branch[lev] = branch[parent2[lev]]
        comp_size = np.bincount(branch[branch >= 0], minlength=S)

        # --- greedy balanced partition, largest component first ------------
        pc_sub, pc_size = sub[pc], comp_size[pc]
        order = np.lexsort((-pc_size, pc_sub))
        side_of_branch = np.zeros(S, np.int8)
        cur, lt, rt = -1, 0, 0
        for i in order:
            if pc_sub[i] != cur:
                cur, lt, rt = pc_sub[i], 0, 0
            if lt <= rt:
                lt += pc_size[i]
            else:
                side_of_branch[pc[i]] = 1
                rt += pc_size[i]
        side = np.zeros(S, np.int8)
        nonpiv = branch >= 0  # within split subtrees: everything but the pivot
        side[nonpiv] = side_of_branch[branch[nonpiv]]

        # --- distance groups for ALL nodes of the level in one lexsort ----
        slots_np = np.flatnonzero(nonpiv)
        gkey = sub[slots_np] * 2 + side[slots_np]
        ds = pdist[slots_np]
        o2 = np.lexsort((ds, gkey))
        sslots, gs, dsort = slots_np[o2], gkey[o2], ds[o2]
        gchange = np.r_[True, gs[1:] != gs[:-1]]
        rstart = gchange | np.r_[True, dsort[1:] != dsort[:-1]]
        run_id = np.cumsum(rstart) - 1
        gidx = np.cumsum(gchange) - 1
        inv = run_id - run_id[np.flatnonzero(gchange)][gidx]
        gstarts = np.flatnonzero(gchange)
        gends = np.r_[gstarts[1:], gs.size]
        gvals = gs[gchange]

        def _emit_side(s, side_val):
            gi = np.searchsorted(gvals, 2 * s + side_val)
            lo, hi = gstarts[gi], gends[gi]
            pg = verts[pivot_slot[s]]
            ids = np.concatenate(([pg], verts[sslots[lo:hi]]))
            id_d = np.concatenate(([0], inv[lo:hi] + 1))
            d = np.concatenate(([0.0], dsort[lo:hi][rstart[lo:hi]]))
            seg = np.concatenate(([0], np.flatnonzero(rstart[lo:hi]) + 1))
            return FlatSide(ids=ids, id_d=id_d.astype(np.int64), d=d,
                            seg_starts=seg.astype(np.int64))

        for s in split_subs:
            pivots.append(int(verts[pivot_slot[s]]))
            node_depth.append(depth)
            children.append([0, 0])
            lefts.append(_emit_side(s, 0))
            rights.append(_emit_side(s, 1))

        # --- next-level state: split edges/slots, duplicate pivots --------
        child_base = np.full(num_sub, -1, np.int64)
        child_base[split_subs] = np.arange(split_subs.size, dtype=np.int64) * 2
        keep = slots_np  # non-pivot slots of split subtrees
        piv_slots = pivot_slot[split_subs]
        entry_sub = np.concatenate([
            child_base[sub[keep]] + side[keep],
            child_base[split_subs], child_base[split_subs] + 1])
        entry_vert = np.concatenate(
            [verts[keep], verts[piv_slots], verts[piv_slots]])
        o3 = np.argsort(entry_sub, kind="stable")
        pos_arr = np.empty(entry_sub.size, np.int64)
        pos_arr[o3] = np.arange(entry_sub.size, dtype=np.int64)
        K, P = keep.size, piv_slots.size
        old2new = np.full(S, -1, np.int64)
        old2new[keep] = pos_arr[:K]
        piv_left = np.full(S, -1, np.int64)
        piv_left[piv_slots] = pos_arr[K:K + P]
        piv_right = np.full(S, -1, np.int64)
        piv_right[piv_slots] = pos_arr[K + P:]

        in_split_e = split_mask[sub[eu]]
        a, b, w = eu[in_split_e], ev[in_split_e], ew[in_split_e]
        a_piv = branch[a] < 0  # only the pivot has no branch in a split sub
        b_piv = branch[b] < 0
        # a pivot-incident edge follows the side of its non-pivot endpoint;
        # all other edges stay inside one branch, hence one side
        eu = np.where(a_piv,
                      np.where(side[b] == 0, piv_left[a], piv_right[a]),
                      old2new[a])
        ev = np.where(b_piv,
                      np.where(side[a] == 0, piv_left[b], piv_right[b]),
                      old2new[b])
        ew = w
        verts = entry_vert[o3]
        sub = entry_sub[o3]
        num_new = 2 * split_subs.size
        pend_parent = np.empty(num_new, np.int64)
        pend_side = np.empty(num_new, np.int64)
        new_refs = ref[split_subs]
        pend_parent[0::2] = new_refs
        pend_parent[1::2] = new_refs
        pend_side[0::2] = 0
        pend_side[1::2] = 1
        num_sub = num_new
        depth += 1

    return FlatIT(
        n=n, leaf_size=leaf_size, root_ref=int(root_refs[0]),
        pivots=np.asarray(pivots, np.int64),
        node_depth=np.asarray(node_depth, np.int64),
        children=(np.asarray(children, np.int64).reshape(-1, 2)
                  if children else np.zeros((0, 2), np.int64)),
        left=lefts, right=rights,
        leaf_ids=leaf_ids, leaf_dists=leaf_dists,
        leaf_depth=np.asarray(leaf_depth, np.int64),
        root_refs=root_refs,
    )


def flat_stats(flat: FlatIT) -> dict:
    """Diagnostics matching `integrator_tree.it_stats` without materializing
    ITNodes: max depth, node counts, Lemma-3.1 balance check."""
    stats = {
        "max_depth": int(max(
            [0] + list(flat.node_depth) + list(flat.leaf_depth))),
        "internal": flat.num_internal,
        "leaves": flat.num_leaves,
        "balance_ok": True,
    }
    for i in range(flat.num_internal):
        nn = flat.left[i].ids.size + flat.right[i].ids.size - 1
        for s in (flat.left[i], flat.right[i]):
            if not (nn / 4.0 <= s.ids.size):
                stats["balance_ok"] = False
    return stats
