"""One bounded LRU mapping for every host-side memo in the package (flat-IT
builds, compiled plans, jitted fastmult closures, mask/ViT integrators), so
the eviction/recency rules live in exactly one place."""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Hashable


class BoundedLRU:
    def __init__(self, maxsize: int):
        self.maxsize = int(maxsize)
        self._d: OrderedDict = OrderedDict()

    def get(self, key: Hashable, default: Any = None) -> Any:
        try:
            val = self._d[key]
        except KeyError:
            return default
        self._d.move_to_end(key)
        return val

    def put(self, key: Hashable, value: Any) -> None:
        self._d[key] = value
        self._d.move_to_end(key)
        while len(self._d) > self.maxsize:
            self._d.popitem(last=False)

    def peek(self, key: Hashable, default: Any = None) -> Any:
        """Read without promoting — for maintenance scans that must not
        disturb the recency order."""
        return self._d.get(key, default)

    def discard(self, key: Hashable) -> None:
        self._d.pop(key, None)

    def keys(self):
        return list(self._d.keys())

    def items(self):
        return list(self._d.items())

    def clear(self) -> None:
        self._d.clear()

    def __len__(self) -> int:
        return len(self._d)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._d
