"""Approximate fast integrators (paper App. A.2): RFF and NU-FFT.

These trade exactness for generality: any f with a usable Fourier transform
gets an O((a+b)·m)-style multiply. Both are validated against the dense
oracle at moderate tolerance in tests/test_core.py.
"""
from __future__ import annotations

import numpy as np


# ----------------------------------------------------------------------------
# A.2.1: Random Fourier Features
# ----------------------------------------------------------------------------


def rff_matvec(x, y, V, omegas, tau_over_p):
    """M ~= U W^T with mu(t)_l = sqrt(tau(w_l)/p(w_l)/m) exp(2 pi i w_l t).

    Unbiased: E[mu(x)^T mu(y)] = f(x+y). Returns Re(U (W^T V)), O((a+b) m d).
    """
    m = omegas.shape[0]
    su = np.sqrt(np.abs(tau_over_p) / m)
    U = su[None, :] * np.exp(2j * np.pi * np.outer(x, omegas))  # (a, m)
    W = (np.sign(tau_over_p) * su)[None, :] * np.exp(2j * np.pi * np.outer(y, omegas))
    return np.real(U @ (W.T @ V))


def gaussian_rff_matvec(x, y, V, sigma: float, m: int, seed: int = 0):
    """f(z) = exp(-z^2 / (2 sigma^2)). FT tau is Gaussian; sample p = tau/|tau|_1
    => tau/p = |tau|_1 = 1 (f normalized so f(0)=1 has unit-mass FT ratio)."""
    rng = np.random.default_rng(seed)
    omegas = rng.normal(0.0, 1.0 / (2.0 * np.pi * sigma), size=m)
    return rff_matvec(x, y, V, omegas, np.ones(m))


# ----------------------------------------------------------------------------
# Gaussian-gridding NUFFT (Greengard & Lee 2004), type 1 and 2
# points in [0, 2*pi); modes k = -M/2 .. M/2-1
# ----------------------------------------------------------------------------


def nufft1(points, values, n_modes: int, eps: float = 1e-10):
    """F[k] = sum_j values[j] exp(-i k points[j]), O(N·w + Mr log Mr)."""
    M = n_modes
    Mr = 2 * M
    msp = max(4, int(np.ceil(-np.log(eps) / 2.0)))  # spreading half width
    tau = (np.pi / M**2) * msp / (2.0 * (2.0 - 0.5))
    grid = np.zeros(Mr, dtype=np.complex128)
    xs = np.mod(points, 2 * np.pi)
    h = 2 * np.pi / Mr
    base = np.floor(xs / h).astype(np.int64)
    for dk in range(-msp, msp + 1):
        idx = np.mod(base + dk, Mr)
        z = xs - (base + dk) * h
        np.add.at(grid, idx, values * np.exp(-z * z / (4.0 * tau)))
    Fg = np.fft.fft(grid)  # Fg[k] = sum_m grid[m] e^{-2pi i k m / Mr}
    ks = np.arange(-(M // 2), (M + 1) // 2)
    Fk = Fg[np.mod(ks, Mr)]
    # deconvolve: sum_m g_tau(x - m h) e^{-i k m h} ~ (1/h) sqrt(4 pi tau) e^{-k^2 tau} e^{-i k x}
    corr = h / np.sqrt(4.0 * np.pi * tau) * np.exp(ks.astype(np.float64) ** 2 * tau)
    return Fk * corr, ks


def nufft2(points, Fk, ks, eps: float = 1e-10):
    """g(x_i) = sum_k Fk[k] exp(i k x_i) — type-2 via gridding (adjoint)."""
    M = ks.shape[0]
    Mr = 2 * M
    msp = max(4, int(np.ceil(-np.log(eps) / 2.0)))
    tau = (np.pi / M**2) * msp / (2.0 * (2.0 - 0.5))
    h = 2 * np.pi / Mr
    # pre-deconvolve so that post-spreading reproduces sum_k Fk e^{ikx}
    corr = np.exp(ks.astype(np.float64) ** 2 * tau) * h / np.sqrt(4.0 * np.pi * tau)
    padded = np.zeros(Mr, dtype=np.complex128)
    padded[np.mod(ks, Mr)] = Fk * corr
    grid = np.fft.ifft(padded) * Mr  # grid[m] = sum_k padded_k e^{+i k m h}
    xs = np.mod(points, 2 * np.pi)
    base = np.floor(xs / h).astype(np.int64)
    out = np.zeros(points.shape[0], dtype=np.complex128)
    for dk in range(-msp, msp + 1):
        idx = np.mod(base + dk, Mr)
        z = xs - (base + dk) * h
        out += grid[idx] * np.exp(-z * z / (4.0 * tau))
    return out


def nufft_integrate(f, x, y, V, n_quad: int = 512):
    """A.2.2: out_i = sum_j f(x_i + y_j) V_j via Fourier quadrature + NUFFTs.

    f is sampled on [0, 2*span]; its FT rho(w) is computed by FFT quadrature;
    R(w) = sum_j V_j e^{2 pi i w (-y_j)} via type-1 NUFFT; g(x) via type-2.
    Accuracy is governed by n_quad (band-limit of f).
    """
    x = np.asarray(x, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    span = float((np.max(x) if x.size else 0.0) + (np.max(y) if y.size else 0.0))
    span = max(span, 1e-9)
    # Period 2x the span with an even (mirror) extension: the periodized
    # function is continuous at the wrap point, so the truncated Fourier
    # series converges fast (no Gibbs ringing from f(0) != f(P^-)).
    P = 2.0 * span * 1.10
    nz = 4 * n_quad
    zs = np.arange(nz) * (P / nz)
    zfold = np.minimum(zs, P - zs)
    cz = np.fft.fft(f(zfold)) / nz  # f(z) = sum_k cz[k] e^{+2 pi i k z / P}
    ks = np.arange(-(n_quad // 2), (n_quad + 1) // 2)
    rho = cz[np.mod(ks, nz)]  # truncated band
    out = np.zeros((x.shape[0],) + V.shape[1:], dtype=np.float64)
    theta_y = 2 * np.pi * y / P
    theta_x = 2 * np.pi * x / P
    for c in range(V.shape[1]):
        # R_k = sum_j V_j e^{+i k theta_y}: nufft1 computes sum v e^{-i k p} -> p = -theta_y
        Rk, _ = nufft1(-theta_y, V[:, c].astype(np.complex128), n_quad)
        # g(x_i) = sum_k rho_k R_k e^{+i k theta_x}
        gx = nufft2(theta_x, rho * Rk, ks)
        out[:, c] = np.real(gx)
    return out
