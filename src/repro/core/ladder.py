"""Backend degradation ladder: supervised fallback `pallas -> plan -> host`.

The paper's headline is that FTFIs are *exact* — every backend computes the
same M_f X — which makes the slower backends free correctness fallbacks:
a Pallas kernel that fails to compile/launch, or returns non-finite
garbage, should demote to the next rung with a structured warning, never
tear down the request (or the whole continuous-batching tick) it was
serving.

Rungs, from fastest to most conservative:

  pallas   fused fdist_matvec kernel executor (interpret-mode off TPU)
  plan     the jitted XLA gather/segment-sum/scatter executor
  host     the SAME pure executor run eagerly under `jax.disable_jit()` —
           no Pallas, no XLA compilation, op-by-op on host: the terminal
           rung shares no failure domain with the compiled paths

Two failure classes trigger demotion:
  * any exception out of a rung (kernel compile/launch failure, jit
    compile error) — counted in `stats()['errors']`;
  * a non-finite output, caught by a cheap jit-compatible gate
    (`jnp.all(jnp.isfinite(Y))` fused into the rung's jitted closure, one
    scalar read on host) — counted in `stats()['nonfinite']`.

Demotion is sticky per closure (`ResilientFastMult`) so a broken rung is
not retried every call, and can be made global (`block_backend`) so
dispatch sites — `attention.resolve_topo_backend`, the ViT grid
integrator, serving — stop selecting a rung that already failed a probe.
The terminal rung never demotes: a non-finite output there is faithfully
returned with a warning (garbage input, not a backend fault).
"""
from __future__ import annotations

import os
import warnings
from typing import Callable

import numpy as np
import jax
import jax.numpy as jnp

from repro.testing import faults

LADDER = ("pallas", "plan", "host")

# backend="auto" size threshold: below this vertex count the fused pallas
# kernel LOSES to the plan executor (BENCH_ftfi_runtime.json: speedup_int
# 0.88 at n=1000) — kernel launch + padding overheads dominate until the
# cross buckets are wide enough to feed it
AUTO_PALLAS_MIN_N = int(os.environ.get("FTFI_AUTO_PALLAS_MIN_N", "4000"))

_stats = {"demotions": 0, "errors": 0, "nonfinite": 0}
_blocked: dict[str, str] = {}


class BackendDemotionWarning(UserWarning):
    """A backend rung failed and the computation fell through to the next
    one. The message carries (from, to, reason)."""


class LadderExhaustedError(RuntimeError):
    """Every rung failed, including the eager host path."""


def stats() -> dict:
    return {**_stats, "blocked": dict(_blocked)}


def reset_stats() -> None:
    for k in _stats:
        _stats[k] = 0


def chain_from(backend: str) -> tuple:
    """The fallback chain starting at `backend` (host is always terminal)."""
    if backend not in LADDER:
        raise ValueError(f"unknown ladder backend {backend!r}; "
                         f"expected one of {LADDER}")
    return LADDER[LADDER.index(backend):]


def block_backend(name: str, reason: str) -> None:
    """Globally stop selecting rung `name` (e.g. after a failed probe):
    `effective_backend` and every new ladder closure skip it."""
    if name == "host":
        raise ValueError("the host rung is the terminal oracle and cannot "
                         "be blocked")
    if name not in _blocked:
        _blocked[name] = reason
        warnings.warn(f"backend {name!r} blocked for this process: {reason}",
                      BackendDemotionWarning, stacklevel=2)


def unblock_backends() -> None:
    _blocked.clear()


def effective_backend(backend: str, n: int | None = None) -> str:
    """First non-blocked rung at or below `backend` — what dispatch sites
    (topo attention, ViT grids, serving) should actually build with.

    `backend="auto"` resolves by problem size first: pallas at or above
    `AUTO_PALLAS_MIN_N` vertices, else plan (pass `n`; without it auto is
    conservative and picks plan). The resolved rung still rides the blocked
    chain like any explicit choice."""
    if backend == "auto":
        backend = ("pallas" if n is not None and n >= AUTO_PALLAS_MIN_N
                   else "plan")
    for level in chain_from(backend):
        if level not in _blocked:
            return level
    return "host"


def _demote(frm: str, to: str, reason: str, where: str) -> None:
    _stats["demotions"] += 1
    warnings.warn(
        f"{where}: backend {frm!r} demoted to {to!r}: {reason}",
        BackendDemotionWarning, stacklevel=3)


class ResilientFastMult:
    """(params, X) -> Y closure with the fallback chain baked in.

    Each rung's executor is built lazily: the structured f families are
    jitted with the finiteness gate fused in (one extra scalar output), the
    host rung runs the identical pure executor eagerly. Demotion is sticky:
    once rung i fails, calls start at rung i+1 (`reset()` re-arms the full
    chain; `demotions` records (from, to, reason) history)."""

    def __init__(self, spec, fn, *, backend: str = "pallas",
                 degree: int = 32, pallas_opts: dict | None = None,
                 name: str = "ftfi"):
        from repro.core import plan_api

        self._plan_api = plan_api
        self.spec = spec
        self.fn = fn
        self.degree = degree
        self.pallas_opts = pallas_opts
        self.name = name
        self.levels = tuple(
            l for l in chain_from(effective_backend(backend))
            if l == "host" or l not in _blocked)
        self._idx = 0
        self._runners: dict[str, Callable] = {}
        self.demotions: list[tuple] = []

    @property
    def level(self) -> str:
        return self.levels[self._idx]

    def reset(self) -> None:
        self._idx = 0

    def _jit_ok(self) -> bool:
        # mirror PlanBackend._jit_ok: only the structured concrete-float
        # families are safe to jit from here; everything else runs eagerly
        # (still traceable inline by an enclosing jit)
        from repro.core import cordial as C
        from repro.core.engines.spec import FamilySpec

        if isinstance(self.fn, FamilySpec):
            return self.fn.mode is not None
        return (isinstance(self.fn, C.CordialFn)
                and not isinstance(self.fn, C.AnyFn)
                and type(self.fn) is not C.CordialFn)

    def _runner(self, level: str) -> Callable:
        run = self._runners.get(level)
        if run is not None:
            return run
        if level == "host":
            def run(params, X):
                with jax.disable_jit():
                    Y = self._plan_api.apply(self.spec, params, self.fn, X,
                                             backend="plan",
                                             degree=self.degree)
                return Y, True
        else:
            fm = self._plan_api.fastmult(
                self.spec, self.fn, backend=level, degree=self.degree,
                pallas_opts=self.pallas_opts)

            def gated(params, X):
                Y = fm(params, X)
                # the jit-compatible NaN/Inf gate: fused into the compiled
                # step, costs one all-reduce + one scalar device->host read
                return Y, jnp.all(jnp.isfinite(Y))

            run = jax.jit(gated) if self._jit_ok() else gated
        self._runners[level] = run
        return run

    def __call__(self, params, X):
        last = len(self.levels) - 1
        while True:
            level = self.levels[self._idx]
            point = f"ladder.{level}"
            try:
                faults.fire(point)
                Y, ok = self._runner(level)(params, X)
                if faults.active(f"ladder.out.{level}"):
                    Y = faults.transform(f"ladder.out.{level}", Y)
                    ok = bool(np.isfinite(np.asarray(Y)).all())
                else:
                    ok = bool(ok)
            except Exception as e:
                _stats["errors"] += 1
                if self._idx >= last:
                    raise LadderExhaustedError(
                        f"{self.name}: every backend rung failed; terminal "
                        f"rung {level!r} raised {type(e).__name__}: {e}"
                    ) from e
                reason = f"{type(e).__name__}: {e}"
                self._record_demotion(level, reason)
                continue
            if ok:
                return Y
            _stats["nonfinite"] += 1
            if self._idx >= last:
                # the host rung IS the oracle: non-finite here means the
                # inputs are bad, which is the caller's (per-request
                # isolation) problem, not a backend fault
                warnings.warn(
                    f"{self.name}: non-finite output at the terminal host "
                    "rung — inputs are non-finite, returning as-is",
                    BackendDemotionWarning, stacklevel=2)
                return Y
            self._record_demotion(level, "non-finite output")

    def _record_demotion(self, frm: str, reason: str) -> None:
        self._idx += 1
        to = self.levels[self._idx]
        self.demotions.append((frm, to, reason))
        _demote(frm, to, reason, self.name)


def resilient_fastmult(spec, fn, *, backend: str = "pallas",
                       degree: int = 32, pallas_opts: dict | None = None,
                       name: str = "ftfi") -> ResilientFastMult:
    """The ladder-supervised twin of `ftfi.fastmult`: same (params, X) -> Y
    signature, but kernel failures and non-finite outputs demote down the
    chain instead of propagating."""
    return ResilientFastMult(spec, fn, backend=backend, degree=degree,
                             pallas_opts=pallas_opts, name=name)


def apply_resilient(spec, params, fn, X, *, backend: str = "pallas",
                    degree: int = 32, pallas_opts: dict | None = None):
    """One-shot `ftfi.apply` under ladder supervision (fresh chain per
    call; use `resilient_fastmult` to keep demotions sticky)."""
    return ResilientFastMult(spec, fn, backend=backend, degree=degree,
                             pallas_opts=pallas_opts)(params, X)


def probe_backend(spec, params, backend: str, *, fn=None) -> str | None:
    """Try one tiny integrate on `backend`; return None when healthy, else
    the failure reason. Dispatch sites use this at build time to demote
    BEFORE a broken rung reaches live traffic."""
    from repro.core import cordial as C

    fn = fn if fn is not None else C.Exponential(-1.0)
    X = np.zeros((spec.n, 1), np.float32)
    X[0, 0] = 1.0
    try:
        faults.fire(f"ladder.{backend}")
        from repro.core import plan_api

        Y = plan_api.apply(spec, params, fn, X, backend=backend)
        Y = faults.transform(f"ladder.out.{backend}", Y)
        if not np.isfinite(np.asarray(Y)).all():
            return "non-finite probe output"
    except Exception as e:
        return f"{type(e).__name__}: {e}"
    return None
