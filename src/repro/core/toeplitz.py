"""FFT fastmult for sequence (path-metric) f-distance masks.

For the 10 assigned LM architectures the token metric is dist(i,j) = |i-j|
(path graph = its own MST), so M = [f(|i-j|)] is symmetric Toeplitz and
M_causal = [f(i-j)]_{i>=j} is lower-triangular Toeplitz. Both multiply in
O(L log L) exactly for ANY f via circulant embedding — the TPU-native
specialization of the paper's Hankel/unit-weight result (App. A.2.3).

All functions operate on the -2 axis of V (..., L, d) with mask values
F (..., L) broadcastable against V's batch dims, and are differentiable in F
(so the paper's learnable-f masks train end-to-end).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def _next_pow2(n: int) -> int:
    return 1 << int(np.ceil(np.log2(max(n, 2))))


def causal_toeplitz_matvec(F, V):
    """out[..., i, :] = sum_{j<=i} F[..., i-j] V[..., j, :].

    Lower-triangular Toeplitz multiply == causal convolution (FFT, exact).
    """
    L = V.shape[-2]
    n = _next_pow2(2 * L)
    Ff = jnp.fft.rfft(F, n=n, axis=-1)  # (..., n//2+1)
    Vf = jnp.fft.rfft(V, n=n, axis=-2)  # (..., n//2+1, d)
    out = jnp.fft.irfft(Ff[..., None] * Vf, n=n, axis=-2)
    return out[..., :L, :].astype(V.dtype)


def symmetric_toeplitz_matvec(F, V):
    """out[..., i, :] = sum_j F[..., |i-j|] V[..., j, :] (bidirectional mask)."""
    L = V.shape[-2]
    n = _next_pow2(2 * L)
    # circulant first column: c[k] = F[k] (k < L), c[n-k] = F[k] (1 <= k < L)
    zeros_mid = jnp.zeros(F.shape[:-1] + (n - 2 * L + 1,), F.dtype)
    c = jnp.concatenate([F, zeros_mid, F[..., :0:-1]], axis=-1)  # (..., n)
    Cf = jnp.fft.rfft(c, axis=-1)
    Vf = jnp.fft.rfft(V, n=n, axis=-2)
    out = jnp.fft.irfft(Cf[..., None] * Vf, n=n, axis=-2)
    return out[..., :L, :].astype(V.dtype)


def toeplitz_dense(F, L: int, causal: bool):
    """Dense mask materialization — oracle for tests / tiny L."""
    idx = jnp.arange(L)
    dist = idx[:, None] - idx[None, :]
    if causal:
        vals = jnp.take(F, jnp.clip(dist, 0, F.shape[-1] - 1), axis=-1)
        return jnp.where(dist >= 0, vals, 0.0)
    return jnp.take(F, jnp.abs(dist), axis=-1)
