"""Incremental plan updates: patch a compiled (PlanSpec, PlanParams) pair
under single-leaf edits without re-running the IT decomposition.

`update_plan(spec, params, ops)` applies a sequence of

  ("insert_leaf", parent, weight)   attach a new leaf under `parent`
  ("delete_leaf", vertex)           remove a degree-1 non-root vertex
  ("reweight", edge_w)              replace ALL edge weights at once

and returns a fresh (spec', params') whose integration output equals a
from-scratch `ftfi.build(edited_tree, reweightable=True)` — the equality
oracle tests/test_plan_update.py sweeps randomly.

Why this is exact, in brief:

- A new leaf v under `parent` has the same IT chain as `parent` (v's set
  membership mirrors its only neighbor all the way down), so walking the
  canonical IT skeleton (`spec.children` / `spec.root_refs`) from the root
  and adding v to parent's side at every internal node — one target slot in
  that side's job, one source slot in the sibling job — plus parent's leaf
  block reproduces exactly the cross/leaf coverage a rebuild would emit:
  every pair (v, x) is covered once, at the meet node of (parent, x), or in
  parent's leaf.
- A deleted degree-1 vertex is on no path between other vertices, so at
  every node where it was the pivot one whole side is the singleton {v}:
  after blanking v's slots both cross jobs of such a node carry zero mass,
  and the remaining plan is a valid decomposition of the smaller tree. The
  deleted row keeps its index (recorded in `spec.ghosts`): its output row
  is exactly zero and its input row is ignored, so plans stay statically
  shaped under deletion — re-compact via a full rebuild when desired.
- Structural edits never move existing vertices in the metric, so every
  pre-existing distance slot keeps its value: only the new leaf's slots
  need fresh distances, d(p, v) = depth[p] + depth[v] - 2 depth[lca] from
  the root-path CSR. A `reweight` op invalidates everything and triggers
  the same full re-derivation `ftfi.reweight` performs.

Cost model (the reason this beats recompiling): per structural edit the
work is O(IT depth) slot claims plus O(changed rows) distance fills. The
expensive bookkeeping is batched per `update_plan` call, not per edit:
new flat cross entries are materialized (and existing ones remapped, if
any bucket grew) once in `finish`, and only the buckets an edit touched
are re-uploaded to device — untouched buckets keep the input params'
arrays. No IT build, no LCA recomputation, no content hashing (the spec
digest stays lazy).

Requires `build(..., reweightable=True)` (per-vertex slots + LCA tables)
compiled by this codebase version (update tables present).
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np
import jax.numpy as jnp


def _i32(a):
    """int32 view-or-cast (no copy when already int32)."""
    return np.asarray(a, np.int32)


def _remap_flat(vals, old_off, old_U, new_off, new_U):
    """Re-express flat group indices (off_b + row * U_b + col) after some
    buckets' group widths U changed: decompose against the old layout,
    recompose against the new one."""
    if vals.size == 0:
        return vals
    b = np.searchsorted(old_off[1:-1], vals, side="right")
    rel = vals - old_off[b]
    row = rel // old_U[b]
    col = rel - row * old_U[b]
    return (new_off[b] + row * new_U[b] + col).astype(vals.dtype)


class _State:
    """Mutable working copy of every spec table an edit can touch.

    Distance arrays are copy-on-write: buckets an edit never touches keep
    referencing the input spec's arrays (and, at `finish`, the input
    params' device arrays). New flat cross entries are kept in (bucket,
    row, col, vertex) form and materialized once in `finish`, so bucket
    growth never triggers per-edit remaps of the big flat arrays."""

    def __init__(self, spec):
        if (spec.path_rows is None or spec.children is None
                or spec.edges_u is None):
            raise ValueError(
                "update_plan requires a reweightable plan with update "
                "tables: rebuild via ftfi.build(tree, reweightable=True) "
                "with this codebase version (older artifacts lack the IT "
                "skeleton / edge tables)")
        self.n = spec.n
        self.tree_sizes = list(spec.tree_sizes)
        self.fingerprint = spec.fingerprint
        self.pivots = spec.pivots.copy()  # per internal node
        self.children = spec.children
        self.root_refs = spec.root_refs
        self.job_bucket = spec.job_bucket
        self.job_row = spec.job_row
        self.leaf_bucket = spec.leaf_bucket
        self.leaf_row = spec.leaf_row
        self.ghosts = list(np.asarray(
            spec.ghosts if spec.ghosts is not None else [], np.int64))
        self.piv = [p.copy() for p in spec.cross_piv]
        self.tgt_rep = [r.copy() for r in spec.cross_tgt_rep]
        self.src_rep = [r.copy() for r in spec.cross_src_rep]
        self.tgt_lca = [a.copy() for a in spec.cross_tgt_lca]
        self.src_lca = [a.copy() for a in spec.cross_src_lca]
        self.tgt_mask = [m.copy() for m in spec.cross_tgt_mask]
        self.src_mask = [m.copy() for m in spec.cross_src_mask]
        self.leaf_ids = [a.copy() for a in spec.leaf_ids]
        self.leaf_mask = [m.copy() for m in spec.leaf_mask]
        self.leaf_lca = [a.copy() for a in spec.leaf_lca]
        self.tgt_gather = spec.tgt_gather.copy()
        self.tgt_scatter = spec.tgt_scatter.copy()
        self.src_gather = spec.src_gather.copy()
        self.src_seg = spec.src_seg.copy()
        self.path_rows = spec.path_rows.copy()
        self.path_edges = spec.path_edges.copy()
        self.edges_u = spec.edges_u.copy()
        self.edges_v = spec.edges_v.copy()
        self.edge_w = spec.edge_w0.astype(np.float64).copy()
        # flat-layout snapshot for the single deferred remap in finish()
        self.tgt_off0, self.tgt_U0 = self._offs(self.tgt_mask)
        self.src_off0, self.src_U0 = self._offs(self.src_mask)
        self.grew_cross = False
        # pending flat entries: (bucket, row, col, vertex), materialized
        # against the FINAL layout in finish()
        self.new_tgt: list[tuple] = []
        self.new_src: list[tuple] = []
        # distances: copy-on-write views of the spec's build-time arrays
        self.tgt_d = list(spec.cross_tgt_d0)
        self.src_d = list(spec.cross_src_d0)
        self.leaf_d = list(spec.leaf_dists0)
        self._owned_cross = set()
        self._owned_leaf = set()
        self.cross_touched = set()  # buckets whose params need re-upload
        self.leaf_touched = set()
        self.dirty_weights = False  # reweight op: re-derive everything
        self._depth = None  # lazy depth cache, invalidated per op

    # -- layout helpers -----------------------------------------------------

    def _voffs(self):
        off = np.zeros(len(self.tree_sizes) + 1, np.int64)
        np.cumsum(self.tree_sizes, out=off[1:])
        return off

    def _offs(self, masks):
        U = np.array([m.shape[1] for m in masks], np.int64)
        cnt = np.array([m.shape[0] for m in masks], np.int64)
        off = np.zeros(U.size + 1, np.int64)
        np.cumsum(cnt * U, out=off[1:])
        return off, U

    def depth(self):
        """Root-path depth per vertex (index n = pad sentinel, 0)."""
        if self._depth is None:
            d = np.zeros(self.n + 1, np.float64)
            np.add.at(d, self.path_rows, self.edge_w[self.path_edges])
            self._depth = d
        return self._depth

    def _own_cross(self, bi):
        if bi not in self._owned_cross:
            self.tgt_d[bi] = self.tgt_d[bi].copy()
            self.src_d[bi] = self.src_d[bi].copy()
            self._owned_cross.add(bi)
        self.cross_touched.add(bi)

    def _own_leaf(self, bi):
        if bi not in self._owned_leaf:
            self.leaf_d[bi] = self.leaf_d[bi].copy()
            self._owned_leaf.add(bi)
        self.leaf_touched.add(bi)

    def _grow_cross(self, bi, tgt: bool):
        """Add one pad column to bucket bi's target (or source) side. The
        flat arrays are NOT remapped here — finish() remaps once against
        the final layout."""
        masks = self.tgt_mask if tgt else self.src_mask
        reps = self.tgt_rep if tgt else self.src_rep
        lcas = self.tgt_lca if tgt else self.src_lca
        ds = self.tgt_d if tgt else self.src_d
        B = masks[bi].shape[0]
        pad = self.piv[bi][:, None]
        self._own_cross(bi)
        masks[bi] = np.concatenate([masks[bi], np.zeros((B, 1), bool)], 1)
        reps[bi] = np.concatenate([reps[bi], pad], 1)
        lcas[bi] = np.concatenate([lcas[bi], pad], 1)
        ds[bi] = np.concatenate([ds[bi], np.zeros((B, 1))], 1)
        self.grew_cross = True

    def _claim_cross(self, job, v, lca_val, d_val, tgt: bool):
        """Give vertex v a live slot in `job`'s target (or source) side:
        reuse the first pad column, else widen the bucket. The flat entry
        is queued for finish()."""
        bi = int(self.job_bucket[job])
        row = int(self.job_row[job])
        masks = self.tgt_mask if tgt else self.src_mask
        free = np.flatnonzero(~masks[bi][row])
        if free.size:
            c = int(free[0])
            self._own_cross(bi)
        else:
            c = masks[bi].shape[1]
            self._grow_cross(bi, tgt)
        masks = self.tgt_mask if tgt else self.src_mask
        (self.tgt_rep if tgt else self.src_rep)[bi][row, c] = v
        (self.tgt_lca if tgt else self.src_lca)[bi][row, c] = lca_val
        (self.tgt_d if tgt else self.src_d)[bi][row, c] = d_val
        masks[bi][row, c] = True
        (self.new_tgt if tgt else self.new_src).append((bi, row, c, v))

    def _grow_leaf(self, bi):
        B, K = self.leaf_ids[bi].shape
        self.leaf_ids[bi] = np.concatenate(
            [self.leaf_ids[bi], np.full((B, 1), self.n,
                                        self.leaf_ids[bi].dtype)], 1)
        self.leaf_mask[bi] = np.concatenate(
            [self.leaf_mask[bi], np.zeros((B, 1), bool)], 1)
        lca = np.full((B, K + 1, K + 1), self.n, self.leaf_lca[bi].dtype)
        lca[:, :K, :K] = self.leaf_lca[bi]
        self.leaf_lca[bi] = lca
        self._own_leaf(bi)
        d = np.zeros((B, K + 1, K + 1))
        d[:, :K, :K] = self.leaf_d[bi]
        self.leaf_d[bi] = d

    # -- ops ----------------------------------------------------------------

    def insert_leaf(self, parent: int, weight: float):
        parent = int(parent)
        if not (0 <= parent < self.n):
            raise ValueError(f"insert_leaf: parent {parent} out of range")
        if parent in self.ghosts:
            raise ValueError(f"insert_leaf: parent {parent} was deleted")
        voffs = self._voffs()
        t = int(np.searchsorted(voffs, parent, side="right")) - 1
        pos = int(voffs[t + 1])  # new vertex id: end of tree t's block
        # edge slot: end of tree t's packed edge block (computed BEFORE the
        # vertex shift so endpoint->tree mapping uses the current offsets)
        etree = np.searchsorted(voffs, self.edges_u, side="right") - 1
        epos = int(np.searchsorted(etree, t, side="right"))

        # shift every vertex-id table: ids >= pos move up one (this carries
        # the pad sentinel n -> n+1 along with the real ids above pos). When
        # the new id lands at the END of the id space — the last (or only)
        # tree — every real id is < pos, so only the sentinel-bearing tables
        # need the scan.
        shift = [self.pivots] + self.leaf_ids + self.leaf_lca
        if pos < self.n:
            shift += ([self.tgt_scatter, self.src_gather, self.path_rows,
                       self.edges_u, self.edges_v]
                      + self.piv + self.tgt_rep + self.src_rep
                      + self.tgt_lca + self.src_lca)
            self.ghosts = [g + 1 if g >= pos else g for g in self.ghosts]
            self.new_tgt = [(b, r, c, v + 1 if v >= pos else v)
                            for b, r, c, v in self.new_tgt]
            self.new_src = [(b, r, c, v + 1 if v >= pos else v)
                            for b, r, c, v in self.new_src]
        for arr in shift:
            arr[arr >= pos] += 1

        v = pos
        self.edges_u = np.insert(self.edges_u, epos, parent)
        self.edges_v = np.insert(self.edges_v, epos, v)
        self.edge_w = np.insert(self.edge_w, epos, float(weight))
        self.path_edges[self.path_edges >= epos] += 1
        # v's root path = parent's root path + the new edge
        pe = self.path_edges[self.path_rows == parent]
        self.path_rows = np.concatenate(
            [self.path_rows, np.full(pe.size + 1, v, self.path_rows.dtype)])
        self.path_edges = np.concatenate(
            [self.path_edges, pe, np.asarray([epos], self.path_edges.dtype)])
        self.tree_sizes[t] += 1
        self.n += 1
        self._depth = None
        depth = self.depth()

        # walk parent's IT chain: at each internal node v joins parent's
        # side — one target slot in that side's job, one source slot in the
        # sibling job — and finally parent's leaf block
        ref = int(self.root_refs[t])
        while ref >= 0:
            i = ref
            p = int(self.pivots[i])
            if parent == p:
                side = 0  # pivot belongs to both sides; descend left
                lca_val = p  # lca(p, v) = p when v hangs off the pivot
            else:
                jt = 2 * i  # job 2i targets the LEFT side
                bi, row = int(self.job_bucket[jt]), int(self.job_row[jt])
                hit = np.flatnonzero(
                    (self.tgt_rep[bi][row] == parent)
                    & self.tgt_mask[bi][row])
                if hit.size:
                    side = 0
                    lca_val = int(self.tgt_lca[bi][row, hit[0]])
                else:
                    jt = 2 * i + 1
                    bi, row = (int(self.job_bucket[jt]),
                               int(self.job_row[jt]))
                    hit = np.flatnonzero(
                        (self.tgt_rep[bi][row] == parent)
                        & self.tgt_mask[bi][row])
                    side = 1
                    # v hangs off parent, so lca(p, v) = lca(p, parent)
                    lca_val = int(self.tgt_lca[bi][row, hit[0]])
            d_val = depth[p] + depth[v] - 2.0 * depth[lca_val]
            self._claim_cross(2 * i + side, v, lca_val, d_val, tgt=True)
            self._claim_cross(2 * i + 1 - side, v, lca_val, d_val, tgt=False)
            ref = int(self.children[i, side])
        li = -ref - 1
        bi, row = int(self.leaf_bucket[li]), int(self.leaf_row[li])
        free = np.flatnonzero(~self.leaf_mask[bi][row])
        if free.size:
            c = int(free[0])
            self._own_leaf(bi)
        else:
            c = self.leaf_ids[bi].shape[1]
            self._grow_leaf(bi)
        cp = int(np.flatnonzero(self.leaf_ids[bi][row] == parent)[0])
        self.leaf_ids[bi][row, c] = v
        self.leaf_mask[bi][row, c] = True
        # lca(v, u) = lca(parent, u) for every other member u (v is a leaf
        # below parent); the copied diagonal entry lca(parent, parent) =
        # parent doubles as lca(v, parent), and v's own diagonal is v
        lca = self.leaf_lca[bi]
        lca[row, c, :] = lca[row, cp, :]
        lca[row, :, c] = lca[row, :, cp]
        lca[row, c, c] = v
        # distances for v's leaf row/col (pad members hit the sentinel
        # depth row -> masked garbage, same as a full re-derivation)
        dv = (depth[v] + depth[self.leaf_ids[bi][row]]
              - 2.0 * depth[lca[row, c, :]])
        self.leaf_d[bi][row, c, :] = dv
        self.leaf_d[bi][row, :, c] = dv
        return v

    def delete_leaf(self, v: int):
        v = int(v)
        if not (0 <= v < self.n):
            raise ValueError(f"delete_leaf: vertex {v} out of range")
        if v in self.ghosts:
            raise ValueError(f"delete_leaf: vertex {v} already deleted")
        inc = np.flatnonzero((self.edges_u == v) | (self.edges_v == v))
        if inc.size != 1:
            raise ValueError(
                f"delete_leaf: vertex {v} has degree {inc.size}, only "
                "degree-1 leaves can be deleted incrementally")
        if not np.any(self.path_rows == v):
            raise ValueError(
                f"delete_leaf: vertex {v} is a tree root; re-root via a "
                "full rebuild instead")
        e = int(inc[0])
        # blank every cross slot representing v (pad: rep/lca -> pivot).
        # Where v itself was a pivot, one whole side was the singleton {v},
        # so both jobs of that node now carry zero mass and their (stale)
        # distances are multiplied by empty sources — harmless by design.
        # Distance values at blanked slots stay stale on purpose: they are
        # masked out AND carry no flat entries, exactly like build padding.
        for bi in range(len(self.piv)):
            for rep, lca, mask in ((self.tgt_rep, self.tgt_lca,
                                    self.tgt_mask),
                                   (self.src_rep, self.src_lca,
                                    self.src_mask)):
                m = (rep[bi] == v) & mask[bi]
                if m.any():
                    r, _ = np.nonzero(m)
                    rep[bi][m] = self.piv[bi][r]
                    lca[bi][m] = self.piv[bi][r]
                    mask[bi][m] = False
        # v as pivot: drop its -f(0) diagonal correction (sentinel row n)
        self.pivots[self.pivots == v] = self.n
        # blank v's leaf slots (ids -> pad sentinel, lca row+col -> sentinel)
        for bi in range(len(self.leaf_ids)):
            m = self.leaf_ids[bi] == v
            if m.any():
                r, c = np.nonzero(m)
                self.leaf_ids[bi][m] = self.n
                self.leaf_mask[bi][m] = False
                self.leaf_lca[bi][r, c, :] = self.n
                self.leaf_lca[bi][r, :, c] = self.n
        # v neither contributes mass nor receives field (pending entries
        # from earlier inserts in this op batch are filtered the same way)
        keep = self.tgt_scatter != v
        self.tgt_scatter = self.tgt_scatter[keep]
        self.tgt_gather = self.tgt_gather[keep]
        keep = self.src_gather != v
        self.src_gather = self.src_gather[keep]
        self.src_seg = self.src_seg[keep]
        self.new_tgt = [e_ for e_ in self.new_tgt if e_[3] != v]
        self.new_src = [e_ for e_ in self.new_src if e_[3] != v]
        # remove v's edge and root path; only v's own path references the
        # edge (the root side survives), so the CSR stays consistent
        assert np.all(self.path_rows[self.path_edges == e] == v)
        keep = self.path_rows != v
        self.path_rows = self.path_rows[keep]
        self.path_edges = self.path_edges[keep]
        self.edges_u = np.delete(self.edges_u, e)
        self.edges_v = np.delete(self.edges_v, e)
        self.edge_w = np.delete(self.edge_w, e)
        self.path_edges[self.path_edges > e] -= 1
        self.ghosts.append(v)
        self._depth = None

    def reweight(self, edge_w):
        edge_w = np.asarray(edge_w, np.float64)
        if edge_w.shape != self.edge_w.shape:
            raise ValueError(
                f"reweight: expected {self.edge_w.shape[0]} edge weights "
                f"(current edge count), got {edge_w.shape}")
        self.edge_w = edge_w.copy()
        self.dirty_weights = True
        self._depth = None

    # -- finish: materialize flat entries, emit (spec', params') ------------

    def finish(self, spec, params):
        if self.dirty_weights:
            # a reweight moved every vertex in the metric: re-derive ALL
            # distances from the CSR + LCA tables (ftfi.reweight, host-side)
            depth = self.depth()

            def pair(u, v, l):
                return depth[u] + depth[v] - 2.0 * depth[l]

            for bi in range(len(self.piv)):
                pv = self.piv[bi][:, None]
                self.tgt_d[bi] = pair(pv, self.tgt_rep[bi], self.tgt_lca[bi])
                self.src_d[bi] = pair(pv, self.src_rep[bi], self.src_lca[bi])
                self.cross_touched.add(bi)
            for bi in range(len(self.leaf_ids)):
                ids = self.leaf_ids[bi]
                self.leaf_d[bi] = pair(ids[:, :, None], ids[:, None, :],
                                       self.leaf_lca[bi])
                self.leaf_touched.add(bi)

        # materialize the deferred flat entries against the FINAL layout,
        # remapping the pre-existing entries once iff any bucket grew
        tgt_off, tgt_U = self._offs(self.tgt_mask)
        src_off, src_U = self._offs(self.src_mask)
        if self.grew_cross:
            self.tgt_gather = _remap_flat(self.tgt_gather, self.tgt_off0,
                                          self.tgt_U0, tgt_off, tgt_U)
            self.src_seg = _remap_flat(self.src_seg, self.src_off0,
                                       self.src_U0, src_off, src_U)
        if self.new_tgt:
            b, r, c, v = (np.asarray(a, np.int64)
                          for a in zip(*self.new_tgt))
            self.tgt_gather = np.concatenate(
                [self.tgt_gather, _i32(tgt_off[b] + r * tgt_U[b] + c)])
            self.tgt_scatter = np.concatenate([self.tgt_scatter, _i32(v)])
        if self.new_src:
            b, r, c, v = (np.asarray(a, np.int64)
                          for a in zip(*self.new_src))
            self.src_seg = np.concatenate(
                [self.src_seg, _i32(src_off[b] + r * src_U[b] + c)])
            self.src_gather = np.concatenate([self.src_gather, _i32(v)])

        new_spec = dataclasses.replace(
            spec,
            n=self.n,
            tree_sizes=tuple(self.tree_sizes),
            fingerprint=self.fingerprint,
            pivots=_i32(self.pivots),
            cross_tgt_mask=tuple(self.tgt_mask),
            cross_src_mask=tuple(self.src_mask),
            cross_tgt_off=tuple(int(o) for o in tgt_off[:-1]),
            cross_src_off=tuple(int(o) for o in src_off[:-1]),
            cross_tgt_d0=tuple(self.tgt_d),
            cross_src_d0=tuple(self.src_d),
            leaf_ids=tuple(_i32(a) for a in self.leaf_ids),
            leaf_mask=tuple(self.leaf_mask),
            leaf_dists0=tuple(self.leaf_d),
            src_gather=_i32(self.src_gather),
            src_seg=_i32(self.src_seg),
            n_src_groups=int(src_off[-1]),
            tgt_gather=_i32(self.tgt_gather),
            tgt_scatter=_i32(self.tgt_scatter),
            n_tgt_groups=int(tgt_off[-1]),
            num_edges=int(self.edge_w.size),
            path_rows=_i32(self.path_rows),
            path_edges=_i32(self.path_edges),
            cross_piv=tuple(_i32(p) for p in self.piv),
            cross_tgt_rep=tuple(_i32(r) for r in self.tgt_rep),
            cross_tgt_lca=tuple(_i32(a) for a in self.tgt_lca),
            cross_src_rep=tuple(_i32(r) for r in self.src_rep),
            cross_src_lca=tuple(_i32(a) for a in self.src_lca),
            leaf_lca=tuple(_i32(a) for a in self.leaf_lca),
            edges_u=_i32(self.edges_u),
            edges_v=_i32(self.edges_v),
            edge_w0=self.edge_w.copy(),
            ghosts=np.asarray(self.ghosts, np.int32),
        )
        from repro.core.plan_api import PlanParams, _birth_params

        if params is None:
            return new_spec, _birth_params(new_spec)
        # params: re-upload only touched buckets — in ONE batched
        # device_put (per-array dispatch overhead dominates the byte cost
        # at these sizes) — while untouched buckets keep the input params'
        # device arrays (their values are unchanged). Mesh-placed params
        # (NamedSharding) keep their placement on shape-preserving edits so
        # a pjit'd step over them recompiles nothing and re-transfers
        # nothing; buckets that changed shape fall back to the default
        # placement (the enclosing pjit re-constrains them).
        import jax
        from jax.sharding import NamedSharding

        def _kept(old, new):
            s = getattr(old, "sharding", None)
            if (isinstance(s, NamedSharding)
                    and tuple(getattr(old, "shape", ())) == np.shape(new)):
                return s
            return None

        ct, lt = sorted(self.cross_touched), sorted(self.leaf_touched)
        jobs = ([(("t", i), self.tgt_d[i], params.cross_tgt_d[i])
                 for i in ct]
                + [(("s", i), self.src_d[i], params.cross_src_d[i])
                   for i in ct]
                + [(("l", i), self.leaf_d[i], params.leaf_dists[i])
                   for i in lt])
        plain = [(k, a) for k, a, old in jobs if _kept(old, a) is None]
        kept = [(k, a, _kept(old, a)) for k, a, old in jobs
                if _kept(old, a) is not None]
        up: dict = {}
        if plain:
            for (k, _), dev in zip(plain,
                                   jax.device_put([a for _, a in plain])):
                up[k] = dev
        if kept:
            put = jax.device_put([a for _, a, _ in kept],
                                 [s for _, _, s in kept])
            for (k, _, _), dev in zip(kept, put):
                up[k] = dev
        ctd = tuple(up.get(("t", i), params.cross_tgt_d[i])
                    for i in range(len(self.tgt_d)))
        csd = tuple(up.get(("s", i), params.cross_src_d[i])
                    for i in range(len(self.src_d)))
        ld = tuple(up.get(("l", i), params.leaf_dists[i])
                   for i in range(len(self.leaf_d)))
        new_params = PlanParams(cross_tgt_d=ctd, cross_src_d=csd,
                                leaf_dists=ld, tree_w=params.tree_w)
        return new_spec, new_params


def update_plan(spec, params, ops):
    """Apply a sequence of structural/weight edits to a compiled plan.

    ops: iterable of
      ("insert_leaf", parent, weight)  new vertex appended at the end of
                                       parent's tree block (its global id is
                                       that block's old end; later trees
                                       shift up by one)
      ("delete_leaf", vertex)          degree-1 non-root vertex; its row
                                       stays allocated (output exactly 0,
                                       input ignored) and is listed in
                                       spec'.ghosts
      ("reweight", edge_w)             replace all edge weights (packed
                                       per-tree order, CURRENT edge count)

    Returns (spec', params') — exact for the edited tree/forest, verified
    against from-scratch rebuilds in tests. The provenance fingerprint is
    chained per op: sha1(old_fingerprint + repr(op)), so identical edit
    histories map to identical fingerprints. Requires a plan built with
    `reweightable=True` (update tables + LCA derivation present)."""
    st = _State(spec)
    for op in ops:
        kind = op[0]
        if kind == "insert_leaf":
            st.insert_leaf(op[1], op[2])
        elif kind == "delete_leaf":
            st.delete_leaf(op[1])
        elif kind == "reweight":
            st.reweight(op[1])
        else:
            raise ValueError(f"unknown update op: {op[0]!r}")
        st.fingerprint = hashlib.sha1(
            (st.fingerprint + repr((kind,) + tuple(
                np.asarray(a).tolist() if isinstance(a, np.ndarray) else a
                for a in op[1:]))).encode()).hexdigest()
    new_spec, new_params = st.finish(spec, params)
    # the patched plan feeds the same unchecked fused dispatch as a loaded
    # artifact: bounds/consistency-check it under the plan_guard policy
    # before anyone executes it
    from repro.core import plan_guard

    plan_guard.validate(new_spec, new_params, where="update_plan")
    return new_spec, new_params
