"""Plan-artifact validation: the trust boundary in front of the executor.

`ftfi.load_plan`, the disk plan cache, and `ftfi.update_plan` all hand
index arrays to the fused gather/segment-sum/scatter dispatch, which does
ZERO bounds checking — a bit-flipped `src_gather` entry silently reads
garbage (or traps) instead of failing loudly. `check_spec(spec, params)`
bounds-checks every index array against its target extent, verifies
bucket-offset monotonicity and mask/shape agreement, ghost-mask
consistency, reweight/update-table coherence, and schema/fingerprint
integrity; `validate(...)` applies the policy knob:

  strict   (default) raise `PlanValidationError` on the first bad artifact
  warn     log a `PlanGuardWarning` and report failure (caller rejects/
           demotes: the disk cache treats it as a miss and rebuilds)
  off      skip validation entirely (trusted artifacts, benchmarking)

The policy comes from `FTFI_PLAN_GUARD` (env) or `set_policy(...)`;
`stats()` exposes the counters the serve banner surfaces. Every check is a
vectorized single pass (min/max/any), so validating costs a few percent of
plan *assembly* — see `check_bench --suite robustness`, which gates the
overhead at <= 5% of a warm `pre_plan_s`.
"""
from __future__ import annotations

import os
import warnings

import numpy as np

_ENV_POLICY = "FTFI_PLAN_GUARD"
_POLICIES = ("strict", "warn", "off")
_policy_override: str | None = None

_stats = {"validations": 0, "failures": 0, "raised": 0, "warned": 0}


class PlanValidationError(ValueError):
    """A plan artifact failed validation: its index arrays, bucket layout,
    or metadata are inconsistent and MUST NOT reach the fused executor."""


class PlanGuardWarning(UserWarning):
    """Non-strict policy: a plan artifact failed validation and was
    rejected (rebuilt/demoted) instead of raising."""


def set_policy(policy: str | None) -> None:
    """Programmatic policy override; `None` follows FTFI_PLAN_GUARD again."""
    global _policy_override
    if policy is not None and policy not in _POLICIES:
        raise ValueError(f"unknown plan-guard policy {policy!r}; "
                         f"expected one of {_POLICIES}")
    _policy_override = policy


def policy() -> str:
    if _policy_override is not None:
        return _policy_override
    p = os.environ.get(_ENV_POLICY, "strict").strip().lower()
    return p if p in _POLICIES else "strict"


def stats() -> dict:
    return dict(_stats)


def reset_stats() -> None:
    for k in _stats:
        _stats[k] = 0


# ----------------------------------------------------------------------------
# checks (pure: return a list of issue strings, never raise)
# ----------------------------------------------------------------------------

# Every integer index array on a PlanSpec, by field kind. The executor and
# the update/reweight paths address at most n+1 <= 2^31 rows, so these are
# int32 end-to-end — int64 doubles artifact size and device transfer for
# nothing (the dtype-discipline check below and `repro.analysis` both gate
# on it).
_INDEX_FIELDS = (
    "pivots", "src_gather", "src_seg", "tgt_gather", "tgt_scatter",
    "children", "root_refs", "job_bucket", "job_row", "leaf_bucket",
    "leaf_row", "path_rows", "path_edges", "ghosts", "edges_u", "edges_v",
)
_INDEX_TUPLE_FIELDS = (
    "leaf_ids", "cross_piv", "cross_tgt_rep", "cross_tgt_lca",
    "cross_src_rep", "cross_src_lca", "leaf_lca",
)


def _iter_index_arrays(spec):
    """Yield (field_name, array) for every index array on the spec."""
    for name in _INDEX_FIELDS:
        a = getattr(spec, name, None)
        if a is not None:
            yield name, a
    for name in _INDEX_TUPLE_FIELDS:
        val = getattr(spec, name, None)
        if val is None:
            continue
        for i, a in enumerate(val):
            yield f"{name}[{i}]", a


def check_index_dtypes(spec) -> list[str]:
    """Flag any integer index array that is not int32 (dtype discipline)."""
    issues = []
    for name, a in _iter_index_arrays(spec):
        a = np.asarray(a)
        if np.issubdtype(a.dtype, np.integer) and a.dtype != np.int32:
            issues.append(f"{name}: index array dtype {a.dtype}, expected "
                          f"int32 (wastes memory/bandwidth end-to-end)")
    return issues


def coerce_index_dtypes(spec):
    """Downcast non-int32 integer index arrays to int32, bounds-guarded.

    Returns ``(new_spec, coerced_field_names)``; raises
    :class:`PlanValidationError` if any value does not fit in int32 (a
    corrupt artifact, not a dtype drift). Used by `load_plan` so pre-schema-4
    artifacts (which saved int64 update tables) land in canonical form."""
    import dataclasses

    i32 = np.iinfo(np.int32)
    replace: dict = {}
    coerced: list[str] = []

    def fix(name, a):
        a = np.asarray(a)
        if not np.issubdtype(a.dtype, np.integer) or a.dtype == np.int32:
            return a, False
        if a.size and (int(a.min()) < i32.min or int(a.max()) > i32.max):
            raise PlanValidationError(
                f"{name}: index values span [{a.min()}, {a.max()}], which "
                f"does not fit int32 — refusing to downcast a corrupt "
                f"artifact")
        return a.astype(np.int32), True

    for name in _INDEX_FIELDS:
        a = getattr(spec, name, None)
        if a is None:
            continue
        b, did = fix(name, a)
        if did:
            replace[name] = b
            coerced.append(name)
    for name in _INDEX_TUPLE_FIELDS:
        val = getattr(spec, name, None)
        if val is None:
            continue
        out, any_did = [], False
        for i, a in enumerate(val):
            b, did = fix(f"{name}[{i}]", a)
            out.append(b)
            any_did = any_did or did
        if any_did:
            replace[name] = tuple(out)
            coerced.append(name)
    if not replace:
        return spec, []
    return dataclasses.replace(spec, **replace), coerced


def _idx_in(name, arr, lo, hi, issues):
    """All entries of integer array `arr` in [lo, hi)? One min/max pass."""
    if arr is None or arr.size == 0:
        return
    if not np.issubdtype(arr.dtype, np.integer):
        issues.append(f"{name}: dtype {arr.dtype} is not integral")
        return
    mn, mx = int(arr.min()), int(arr.max())
    if mn < lo or mx >= hi:
        issues.append(f"{name}: values span [{mn}, {mx}] outside the valid "
                      f"range [{lo}, {hi})")


def _offsets_ok(name, offs, masks, total, issues):
    """Bucket offsets must be the exact running sum of B_i * U_i (monotone
    by construction) and `total` their final value."""
    if len(offs) != len(masks):
        issues.append(f"{name}: {len(offs)} offsets for {len(masks)} buckets")
        return
    expect = 0
    for i, (off, m) in enumerate(zip(offs, masks)):
        if int(off) != expect:
            issues.append(f"{name}[{i}]: offset {int(off)} != running flat "
                          f"size {expect} (non-monotonic or corrupt layout)")
            return
        expect += int(m.shape[0]) * int(m.shape[1])
    if int(total) != expect:
        issues.append(f"{name}: group total {int(total)} != flat layout "
                      f"size {expect}")


def check_spec(spec, params=None, max_issues: int = 16) -> list[str]:
    """Every inconsistency that could make the fused executor read or write
    out of bounds (or silently mis-integrate), as human-readable strings.
    Purely host-side numpy; does not raise."""
    issues: list[str] = []

    def done() -> bool:
        return len(issues) >= max_issues

    # -- schema / provenance integrity --------------------------------------
    n = spec.n
    if not isinstance(n, (int, np.integer)) or n < 1:
        issues.append(f"n={n!r}: not a positive integer")
        return issues  # nothing below is meaningful
    if not (isinstance(spec.fingerprint, str) and spec.fingerprint
            and all(c in "0123456789abcdef" for c in spec.fingerprint)):
        issues.append(f"fingerprint {spec.fingerprint!r}: not a hex digest")
    if len(spec.tree_sizes) != spec.num_trees:
        issues.append(f"num_trees={spec.num_trees} but "
                      f"{len(spec.tree_sizes)} tree_sizes")
    if sum(int(t) for t in spec.tree_sizes) != n:
        issues.append(f"tree_sizes sum {sum(spec.tree_sizes)} != n={n}")
    # -- mesh / shard-layout provenance -------------------------------------
    # A plan saved with `save_plan(..., mesh=...)` records the mesh it was
    # laid out for; executing it on a process that cannot form that mesh
    # (fewer devices, newer incompatible shard layout) must fail at load,
    # not deep inside shard_map with an opaque collective error.
    shard_layout = int(getattr(spec, "shard_layout", 0) or 0)
    mesh_devices = int(getattr(spec, "mesh_devices", 0) or 0)
    if shard_layout:
        from repro.core.plan_shard import SHARD_LAYOUT_VERSION

        if shard_layout > SHARD_LAYOUT_VERSION:
            issues.append(
                f"shard_layout={shard_layout}: artifact uses a newer shard "
                f"layout than this build supports "
                f"(SHARD_LAYOUT_VERSION={SHARD_LAYOUT_VERSION})")
        if mesh_devices:
            import jax

            avail = jax.device_count()
            if mesh_devices > avail:
                issues.append(
                    f"mesh_devices={mesh_devices}: sharded artifact needs "
                    f"{mesh_devices} devices but only {avail} are visible "
                    f"(axes {tuple(getattr(spec, 'mesh_axes', ()) or ())})")
    if done():
        return issues

    nb = len(spec.cross_tgt_mask)
    nl = len(spec.leaf_ids)
    for name, want in (("cross_src_mask", nb), ("cross_tgt_d0", nb),
                       ("cross_src_d0", nb), ("leaf_mask", nl),
                       ("leaf_dists0", nl)):
        if len(getattr(spec, name)) != want:
            issues.append(f"{name}: {len(getattr(spec, name))} buckets, "
                          f"expected {want}")
    if done():
        return issues

    # -- per-bucket shape agreement -----------------------------------------
    for i in range(nb):
        tm, sm = spec.cross_tgt_mask[i], spec.cross_src_mask[i]
        if tm.dtype != bool or sm.dtype != bool:
            issues.append(f"cross bucket {i}: masks are not boolean")
        if tm.shape[0] != sm.shape[0]:
            issues.append(f"cross bucket {i}: tgt rows {tm.shape[0]} != "
                          f"src rows {sm.shape[0]}")
        if spec.cross_tgt_d0[i].shape != tm.shape:
            issues.append(f"cross bucket {i}: tgt_d0 shape "
                          f"{spec.cross_tgt_d0[i].shape} != mask {tm.shape}")
        if spec.cross_src_d0[i].shape != sm.shape:
            issues.append(f"cross bucket {i}: src_d0 shape "
                          f"{spec.cross_src_d0[i].shape} != mask {sm.shape}")
        if done():
            return issues
    for i in range(nl):
        ids, m, d = spec.leaf_ids[i], spec.leaf_mask[i], spec.leaf_dists0[i]
        B, K = ids.shape
        if m.shape != (B, K) or m.dtype != bool:
            issues.append(f"leaf bucket {i}: mask shape/dtype mismatch")
        if d.shape != (B, K, K):
            issues.append(f"leaf bucket {i}: dists shape {d.shape} != "
                          f"({B}, {K}, {K})")
        _idx_in(f"leaf_ids[{i}]", ids, 0, n + 1, issues)
        if m.shape == ids.shape and ids.size and m.any():
            live_max = int(ids[m].max()) if m.any() else -1
            if live_max >= n:
                issues.append(f"leaf_ids[{i}]: live (unmasked) slot points "
                              f"at pad row {live_max} >= n={n}")
        if done():
            return issues

    # -- bucket-offset monotonicity / flat-layout totals --------------------
    _offsets_ok("cross_src_off", spec.cross_src_off, spec.cross_src_mask,
                spec.n_src_groups, issues)
    _offsets_ok("cross_tgt_off", spec.cross_tgt_off, spec.cross_tgt_mask,
                spec.n_tgt_groups, issues)
    if done():
        return issues

    # -- index dtype discipline: int32 end-to-end ---------------------------
    issues.extend(check_index_dtypes(spec))
    if done():
        return issues

    # -- fused executor index arrays: every gather/scatter bounds-checked ---
    # gather FROM Xpad (n+1 rows incl. the pad row) / scatter INTO out (same)
    _idx_in("pivots", spec.pivots, 0, n + 1, issues)
    _idx_in("src_gather", spec.src_gather, 0, n + 1, issues)
    _idx_in("tgt_scatter", spec.tgt_scatter, 0, n + 1, issues)
    # segment/group ids against their group extents
    _idx_in("src_seg", spec.src_seg, 0, max(spec.n_src_groups, 1), issues)
    _idx_in("tgt_gather", spec.tgt_gather, 0, max(spec.n_tgt_groups, 1),
            issues)
    if spec.src_gather.shape != spec.src_seg.shape:
        issues.append(f"src_gather/src_seg length mismatch: "
                      f"{spec.src_gather.shape} vs {spec.src_seg.shape}")
    if spec.tgt_gather.shape != spec.tgt_scatter.shape:
        issues.append(f"tgt_gather/tgt_scatter length mismatch: "
                      f"{spec.tgt_gather.shape} vs {spec.tgt_scatter.shape}")
    if done():
        return issues

    # -- ghost-mask consistency ---------------------------------------------
    if spec.ghosts is not None and spec.ghosts.size:
        _idx_in("ghosts", spec.ghosts, 0, n, issues)
        g = np.unique(spec.ghosts)
        if g.size != spec.ghosts.size:
            issues.append("ghosts: duplicated vertex ids")
        for name, arr in (("src_gather", spec.src_gather),
                          ("tgt_scatter", spec.tgt_scatter)):
            if arr.size and np.isin(arr, g).any():
                issues.append(f"{name}: references deleted (ghost) vertices "
                              "— their rows must carry no flat entries")
        for i in range(nl):
            m = spec.leaf_mask[i]
            if m.any() and np.isin(spec.leaf_ids[i][m], g).any():
                issues.append(f"leaf_ids[{i}]: live slot references a ghost")
        if done():
            return issues

    # -- reweight tables ----------------------------------------------------
    if spec.path_rows is not None:
        _idx_in("path_rows", spec.path_rows, 0, n, issues)
        _idx_in("path_edges", spec.path_edges, 0, max(spec.num_edges, 1),
                issues)
        if spec.path_rows.shape != spec.path_edges.shape:
            issues.append("path_rows/path_edges length mismatch")
        for name in ("cross_piv", "cross_tgt_rep", "cross_tgt_lca",
                     "cross_src_rep", "cross_src_lca", "leaf_lca"):
            val = getattr(spec, name)
            if val is None:
                issues.append(f"{name}: missing on a reweightable spec")
                continue
            for i, a in enumerate(val):
                _idx_in(f"{name}[{i}]", a, 0, n + 1, issues)
                if done():
                    return issues
    if spec.edges_u is not None:
        for name in ("edges_u", "edges_v"):
            a = getattr(spec, name)
            if a.shape[0] != spec.num_edges:
                issues.append(f"{name}: {a.shape[0]} entries != "
                              f"num_edges={spec.num_edges}")
            _idx_in(name, a, 0, n, issues)
        if spec.edge_w0 is not None and np.asarray(spec.edge_w0).size:
            w = np.asarray(spec.edge_w0)
            if not np.isfinite(w).all():
                issues.append("edge_w0: non-finite edge weights")

    # -- update tables ------------------------------------------------------
    if spec.children is not None:
        num_internal = spec.children.shape[0]
        if spec.pivots.shape[0] != num_internal:
            issues.append(f"children: {num_internal} internal nodes but "
                          f"{spec.pivots.shape[0]} pivots")
        if spec.job_bucket is not None:
            _idx_in("job_bucket", spec.job_bucket, 0, max(nb, 1), issues)
        if spec.leaf_bucket is not None:
            _idx_in("leaf_bucket", spec.leaf_bucket, 0, max(nl, 1), issues)
    if done():
        return issues

    # -- params: the dynamic half must match the static layout --------------
    if params is not None:
        for name, want in (("cross_tgt_d", nb), ("cross_src_d", nb),
                           ("leaf_dists", nl)):
            val = getattr(params, name)
            if len(val) != want:
                issues.append(f"params.{name}: {len(val)} buckets, "
                              f"expected {want}")
                continue
            shapes = ([m.shape for m in spec.cross_tgt_mask],
                      [m.shape for m in spec.cross_src_mask],
                      [d.shape for d in spec.leaf_dists0])[
                          ("cross_tgt_d", "cross_src_d",
                           "leaf_dists").index(name)]
            for i, a in enumerate(val):
                a = np.asarray(a)
                if tuple(a.shape) != tuple(shapes[i]):
                    issues.append(f"params.{name}[{i}]: shape {a.shape} != "
                                  f"spec layout {tuple(shapes[i])}")
                elif not np.isfinite(a).all():
                    # masked/pad slots legitimately carry garbage values but
                    # never non-finite ones: NaN * 0-mass still poisons sums
                    issues.append(f"params.{name}[{i}]: non-finite distances")
                if done():
                    return issues
        if params.tree_w is not None:
            tw = np.asarray(params.tree_w)
            if tw.shape != (spec.num_trees,):
                issues.append(f"params.tree_w: shape {tw.shape} != "
                              f"({spec.num_trees},)")
            elif not np.isfinite(tw).all():
                issues.append("params.tree_w: non-finite weights")
    return issues


def validate(spec, params=None, *, where: str = "plan",
             policy_override: str | None = None) -> bool:
    """Apply the policy to `check_spec`: True = safe to execute.

    strict -> raise PlanValidationError; warn -> PlanGuardWarning + False
    (callers reject: cache miss, load failure, demotion); off -> True
    without checking."""
    pol = policy_override if policy_override is not None else policy()
    if pol == "off":
        return True
    _stats["validations"] += 1
    issues = check_spec(spec, params)
    if not issues:
        return True
    _stats["failures"] += 1
    msg = (f"{where}: plan artifact failed validation "
           f"({len(issues)} issue{'s' if len(issues) > 1 else ''}):\n  "
           + "\n  ".join(issues))
    if pol == "strict":
        _stats["raised"] += 1
        raise PlanValidationError(msg)
    _stats["warned"] += 1
    warnings.warn(msg, PlanGuardWarning, stacklevel=2)
    return False
