"""Functional plan API: static `PlanSpec` + differentiable `PlanParams`.

The legacy `IntegrationPlan` is an opaque Python object whose distances live
in numpy CrossBuckets and whose compiled closures capture it — invisible to
`jit`/`grad`/`vmap` and unable to cross process or device boundaries. This
module factors every plan into

  PlanSpec    hashable, static: index arrays, bucket layout, masks, grid
              metadata, provenance (content hash, seed, leaf_size) and —
              for reweightable builds — the (pivot, representative, LCA)
              tables plus the root-path edge CSR that re-derive every
              distance from edge weights. Registered as a zero-leaf pytree
              (the spec IS the aux data), so it rides through jit/vmap as a
              static argument keyed by content digest.

  PlanParams  dynamic: leaf/cross distances and per-tree output weights as
              jnp arrays — traceable, differentiable, shardable,
              checkpointable.

Pure entry points (also exposed as `repro.ftfi`):

  build(tree_or_forest, ...)      -> (spec, params)
  apply(spec, params, fn, X)      -> Y            (jit/vmap/grad-safe)
  fastmult(spec, fn)              -> (params, X) -> Y   (jittable)
  reweight(spec, edge_w)          -> PlanParams   (differentiable in edge_w)
  update_plan(spec, params, ops)  -> (spec', params')  incremental edits
  save_plan / load_plan           npz round trip, zero IT rebuild at load

Reweight exactness: the IT decomposition is purely combinatorial (it covers
every vertex pair regardless of weights), so recomputing distances as
d(u,v) = depth[u] + depth[v] - 2 depth[lca(u,v)] with depth = root-path edge
sums yields the TRUE integration for ANY positive edge weights — provided
each distance slot maps to one vertex. `build(..., reweightable=True)`
therefore expands distance groups to per-vertex slots (and disables the
grid/Hankel engine, whose integer grid would not survive retraining).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
from typing import Callable

import numpy as np
import jax
import jax.numpy as jnp

from repro.analysis import trace_guard
from repro.core.engines.spec import FamilySpec, spec_of
from repro.core.integrate import (CrossBucket, IntegrationPlan, LeafBucket,
                                  compile_forest_plan, compile_plan)

KERNEL_MODES = ("poly", "exp", "expq", "rational")

_SAVE_VERSION = 1
# PlanSpec field-layout generation, mixed into disk-cache keys (NOT the npz
# version: old artifacts still load — absent fields default to None)
# 4: update tables (children/root_refs) are int32 like every other index
#    array — bumping the schema misses stale disk-cache entries so they
#    rebuild in canonical form instead of round-tripping int64
_SPEC_SCHEMA = 4


# ----------------------------------------------------------------------------
# PlanSpec / PlanParams
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False, repr=False)
class PlanSpec:
    """Static half of a plan. Hashable by content digest; every array is
    host-side numpy and never traced. Tuples are indexed by cross/leaf
    bucket."""

    n: int
    num_trees: int
    tree_sizes: tuple
    leaf_size: int
    seed: int
    fingerprint: str
    grid_h: float | None
    reweightable: bool
    # cross buckets (static layout; build-time distances kept for the
    # grid/Hankel engine, which requires host-side integer grid indices)
    cross_tgt_mask: tuple  # of (B, Ut) bool
    cross_src_mask: tuple  # of (B, Us) bool
    cross_src_off: tuple
    cross_tgt_off: tuple
    cross_tgt_d0: tuple  # of (B, Ut) float64
    cross_src_d0: tuple
    # leaf buckets
    leaf_ids: tuple  # of (B, K) int32, padded with n
    leaf_mask: tuple  # of (B, K) bool
    leaf_dists0: tuple  # of (B, K, K) float64
    # fused executor index arrays
    pivots: np.ndarray
    src_gather: np.ndarray
    src_seg: np.ndarray
    n_src_groups: int
    tgt_gather: np.ndarray
    tgt_scatter: np.ndarray
    n_tgt_groups: int
    num_cross_jobs: int
    # reweight tables (only for reweightable builds)
    num_edges: int = 0
    path_rows: np.ndarray | None = None  # (P,) vertex per root-path entry
    path_edges: np.ndarray | None = None  # (P,) edge id per entry
    cross_piv: tuple | None = None  # of (B,) pivot vertex per job row
    cross_tgt_rep: tuple | None = None  # of (B, Ut) representative vertex
    cross_tgt_lca: tuple | None = None  # of (B, Ut) lca(piv, rep)
    cross_src_rep: tuple | None = None
    cross_src_lca: tuple | None = None
    leaf_lca: tuple | None = None  # of (B, K, K) lca(ids_i, ids_j)
    # update tables (only when compiled by this codebase's assembler; they
    # let `update_plan` patch single leaves without a rebuild)
    children: np.ndarray | None = None  # (I, 2) canonical IT child refs
    root_refs: np.ndarray | None = None  # (num_trees,) per-tree root ref
    job_bucket: np.ndarray | None = None  # (2I,) bucket index per cross job
    job_row: np.ndarray | None = None  # (2I,) row within bucket
    leaf_bucket: np.ndarray | None = None  # (L,) bucket per leaf node
    leaf_row: np.ndarray | None = None  # (L,) row within leaf bucket
    edges_u: np.ndarray | None = None  # (E,) packed edge endpoints (global)
    edges_v: np.ndarray | None = None
    edge_w0: np.ndarray | None = None  # (E,) build-time edge weights
    ghosts: np.ndarray | None = None  # deleted-vertex ids (update_plan)
    # mesh/device provenance (0/empty = artifact not bound to a mesh):
    # recorded by `save_plan(..., mesh=...)` so plan_guard / apply_sharded
    # can reject a sharded artifact on a mismatched mesh up front
    mesh_devices: int = 0
    mesh_axes: tuple = ()
    shard_layout: int = 0

    def __post_init__(self):
        # digest is lazy: hashing tens of MB of index arrays costs more than
        # vectorized assembly itself, and incremental updates / cache hits
        # often never need it
        object.__setattr__(self, "_digest", None)

    @property
    def digest(self) -> str:
        if self._digest is None:
            h = hashlib.sha1()
            for f in dataclasses.fields(self):
                _mix(h, getattr(self, f.name))
            object.__setattr__(self, "_digest", h.hexdigest())
        return self._digest

    @property
    def provenance(self) -> dict:
        return {"fingerprint": self.fingerprint, "seed": self.seed,
                "leaf_size": self.leaf_size, "n": self.n,
                "num_trees": self.num_trees, "grid_h": self.grid_h,
                "reweightable": self.reweightable,
                "mesh_devices": self.mesh_devices,
                "mesh_axes": tuple(self.mesh_axes),
                "shard_layout": self.shard_layout}

    def __hash__(self):
        return hash(self.digest)

    def __eq__(self, other):
        return (type(other) is PlanSpec
                and other.digest == self.digest)

    def __repr__(self):
        return (f"PlanSpec(n={self.n}, num_trees={self.num_trees}, "
                f"leaf_size={self.leaf_size}, seed={self.seed}, "
                f"grid_h={self.grid_h}, reweightable={self.reweightable}, "
                f"sha={self.digest[:12]})")


def _mix(h, val):
    if val is None:
        h.update(b"\x00N")
    elif isinstance(val, np.ndarray):
        h.update(str(val.dtype).encode())
        h.update(np.int64(val.shape).tobytes())
        h.update(np.ascontiguousarray(val).tobytes())
    elif isinstance(val, (tuple, list)):
        h.update(b"\x00T%d" % len(val))
        for v in val:
            _mix(h, v)
    else:
        h.update(repr(val).encode())


@dataclasses.dataclass
class PlanParams:
    """Dynamic half of a plan: jnp arrays, registered as pytree leaves.

    `tree_w` is the per-tree output weight vector (None = all ones): the
    multiply is linear, so scaling tree t's output rows equals scaling its
    mask — FRT averaging weights, per-request temperatures, learnable
    per-graph gains all land here."""

    cross_tgt_d: tuple  # of (B, Ut)
    cross_src_d: tuple  # of (B, Us)
    leaf_dists: tuple  # of (B, K, K)
    tree_w: object | None = None  # (num_trees,) or None


jax.tree_util.register_pytree_node(
    PlanParams,
    lambda p: ((p.cross_tgt_d, p.cross_src_d, p.leaf_dists, p.tree_w), None),
    lambda _, c: PlanParams(*c),
)

# zero-leaf pytree: the spec IS the (hashable) aux data, so a (spec, params)
# pair flattens to params leaves only and jit retrace keys on spec equality
jax.tree_util.register_pytree_node(
    PlanSpec, lambda s: ((), s), lambda s, _: s)


# ----------------------------------------------------------------------------
# specialize: IntegrationPlan -> (PlanSpec, PlanParams), memoized on the plan
# ----------------------------------------------------------------------------


def specialize(plan: IntegrationPlan):
    """Split a compiled `IntegrationPlan` into its functional (spec, params)
    pair. Memoized on the plan object, so content-cached plans hand every
    Integrator the same device arrays (one transfer per topology)."""
    cached = getattr(plan, "_spec_params", None)
    if cached is not None:
        return cached
    rw = getattr(plan, "rw", None) or {}
    upd = getattr(plan, "upd", None) or {}
    spec = PlanSpec(
        n=plan.n,
        num_trees=max(len(plan.tree_sizes), 1),
        tree_sizes=tuple(plan.tree_sizes) or (plan.n,),
        leaf_size=plan.leaf_size,
        seed=plan.seed,
        fingerprint=plan.fingerprint,
        grid_h=plan.grid_h,
        reweightable=plan.reweightable,
        cross_tgt_mask=tuple(cb.tgt_d_mask for cb in plan.cross_buckets),
        cross_src_mask=tuple(cb.src_d_mask for cb in plan.cross_buckets),
        cross_src_off=tuple(cb.src_off for cb in plan.cross_buckets),
        cross_tgt_off=tuple(cb.tgt_off for cb in plan.cross_buckets),
        cross_tgt_d0=tuple(cb.tgt_d for cb in plan.cross_buckets),
        cross_src_d0=tuple(cb.src_d for cb in plan.cross_buckets),
        leaf_ids=tuple(lb.ids for lb in plan.leaf_buckets),
        leaf_mask=tuple(lb.mask for lb in plan.leaf_buckets),
        leaf_dists0=tuple(lb.dists for lb in plan.leaf_buckets),
        pivots=plan.pivots,
        src_gather=plan.src_gather,
        src_seg=plan.src_seg,
        n_src_groups=plan.n_src_groups,
        tgt_gather=plan.tgt_gather,
        tgt_scatter=plan.tgt_scatter,
        n_tgt_groups=plan.n_tgt_groups,
        num_cross_jobs=plan.num_cross_jobs,
        num_edges=int(rw.get("num_edges", 0)),
        path_rows=rw.get("path_rows"),
        path_edges=rw.get("path_edges"),
        cross_piv=(tuple(cb.piv for cb in plan.cross_buckets)
                   if rw else None),
        cross_tgt_rep=(tuple(cb.tgt_rep for cb in plan.cross_buckets)
                       if rw else None),
        cross_tgt_lca=tuple(rw["cross_tgt_lca"]) if rw else None,
        cross_src_rep=(tuple(cb.src_rep for cb in plan.cross_buckets)
                       if rw else None),
        cross_src_lca=tuple(rw["cross_src_lca"]) if rw else None,
        leaf_lca=tuple(rw["leaf_lca"]) if rw else None,
        children=upd.get("children"),
        root_refs=upd.get("root_refs"),
        job_bucket=upd.get("job_bucket"),
        job_row=upd.get("job_row"),
        leaf_bucket=upd.get("leaf_bucket"),
        leaf_row=upd.get("leaf_row"),
        edges_u=rw.get("edges_u"),
        edges_v=rw.get("edges_v"),
        edge_w0=rw.get("edge_w0"),
        ghosts=np.zeros(0, np.int32) if upd else None,
    )
    params = _birth_params(spec)
    plan._spec_params = (spec, params)
    return spec, params


def _birth_params(spec: PlanSpec) -> PlanParams:
    # lazy specialize may first fire INSIDE a jit trace (the engine's spec/
    # params properties); without this guard the float64->float32
    # canonicalization becomes a traced op and the memoized params would
    # leak tracers out of that trace
    with jax.ensure_compile_time_eval():
        return PlanParams(
            cross_tgt_d=tuple(jnp.asarray(d) for d in spec.cross_tgt_d0),
            cross_src_d=tuple(jnp.asarray(d) for d in spec.cross_src_d0),
            leaf_dists=tuple(jnp.asarray(d) for d in spec.leaf_dists0),
            tree_w=None,
        )


def plan_from_spec(spec: PlanSpec, params: PlanParams | None = None
                   ) -> IntegrationPlan:
    """Reconstruct a legacy `IntegrationPlan` from (spec, params) — the
    facade path for loaded artifacts: zero IT rebuild by construction."""
    cbs = []
    for i in range(len(spec.cross_tgt_d0)):
        cbs.append(CrossBucket(
            tgt_d=spec.cross_tgt_d0[i], tgt_d_mask=spec.cross_tgt_mask[i],
            src_d=spec.cross_src_d0[i], src_d_mask=spec.cross_src_mask[i],
            src_off=spec.cross_src_off[i], tgt_off=spec.cross_tgt_off[i],
            piv=spec.cross_piv[i] if spec.cross_piv else None,
            tgt_rep=spec.cross_tgt_rep[i] if spec.cross_tgt_rep else None,
            src_rep=spec.cross_src_rep[i] if spec.cross_src_rep else None,
        ))
    lbs = [LeafBucket(ids=spec.leaf_ids[i], mask=spec.leaf_mask[i],
                      dists=spec.leaf_dists0[i])
           for i in range(len(spec.leaf_ids))]
    plan = IntegrationPlan(
        n=spec.n, cross_buckets=cbs, leaf_buckets=lbs, pivots=spec.pivots,
        grid_h=spec.grid_h, src_gather=spec.src_gather, src_seg=spec.src_seg,
        n_src_groups=spec.n_src_groups, tgt_gather=spec.tgt_gather,
        tgt_scatter=spec.tgt_scatter, n_tgt_groups=spec.n_tgt_groups,
        num_cross_jobs=spec.num_cross_jobs, fingerprint=spec.fingerprint,
        leaf_size=spec.leaf_size, seed=spec.seed,
        tree_sizes=spec.tree_sizes, reweightable=spec.reweightable)
    if spec.path_rows is not None:
        plan.rw = {"path_rows": spec.path_rows,
                   "path_edges": spec.path_edges,
                   "num_edges": spec.num_edges,
                   "cross_tgt_lca": list(spec.cross_tgt_lca),
                   "cross_src_lca": list(spec.cross_src_lca),
                   "leaf_lca": list(spec.leaf_lca)}
        if spec.edges_u is not None:
            plan.rw.update(edges_u=spec.edges_u, edges_v=spec.edges_v,
                           edge_w0=spec.edge_w0)
    if spec.children is not None:
        plan.upd = {"children": spec.children, "root_refs": spec.root_refs,
                    "job_bucket": spec.job_bucket, "job_row": spec.job_row,
                    "leaf_bucket": spec.leaf_bucket,
                    "leaf_row": spec.leaf_row}
    plan._spec_params = (spec, params if params is not None
                         else _birth_params(spec))
    return plan


# ----------------------------------------------------------------------------
# build
# ----------------------------------------------------------------------------


def build(tree_or_forest, *, leaf_size: int = 64, seed: int = 0,
          reweightable: bool = False, detect_grid_spacing: bool = True,
          use_cache: bool = True):
    """Compile a tree or `Forest` into a functional (spec, params) pair.

    `reweightable=True` additionally records the (pivot, representative,
    LCA) tables and root-path edge CSR that let `reweight(spec, edge_w)`
    re-derive `params` differentiably from edge weights — at the cost of
    per-vertex (uncollapsed) distance groups and no grid/Hankel engine."""
    from repro.graphs.graph import Forest

    if isinstance(tree_or_forest, Forest):
        plan = compile_forest_plan(
            tree_or_forest, leaf_size=leaf_size, seed=seed,
            detect_grid_spacing=detect_grid_spacing, use_cache=use_cache,
            reweightable=reweightable)
    else:
        plan = compile_plan(
            tree_or_forest, leaf_size=leaf_size, seed=seed,
            detect_grid_spacing=detect_grid_spacing, use_cache=use_cache,
            reweightable=reweightable)
    return specialize(plan)


# ----------------------------------------------------------------------------
# batched cross engines (moved here from engines/plan.py; re-exported there)
# ----------------------------------------------------------------------------


def chebyshev_batched_matvec(fn_eval, tgt_d, tgt_mask, src_d, src_mask, Xp,
                             degree: int = 32):
    """Batched low-rank multiply via per-node 2D Chebyshev interpolation."""
    big = 1e30
    x_lo = jnp.min(jnp.where(tgt_mask, tgt_d, big), axis=1)  # (B,)
    x_hi = jnp.max(jnp.where(tgt_mask, tgt_d, -big), axis=1)
    y_lo = jnp.min(jnp.where(src_mask, src_d, big), axis=1)
    y_hi = jnp.max(jnp.where(src_mask, src_d, -big), axis=1)
    r = degree
    k = np.arange(r)
    t = np.cos((2 * k + 1) * np.pi / (2 * r))  # (r,)
    xc = (x_lo[:, None] + x_hi[:, None]) / 2 + (x_hi - x_lo)[:, None] / 2 * t  # (B, r)
    yc = (y_lo[:, None] + y_hi[:, None]) / 2 + (y_hi - y_lo)[:, None] / 2 * t
    Bmat = fn_eval(xc[:, :, None] + yc[:, None, :])  # (B, r, r)
    Lx = _lagrange_batched(tgt_d, xc)  # (B, Kx, r)
    Ly = _lagrange_batched(src_d, yc)  # (B, Ky, r)
    tmp = jnp.einsum("bkr,bkd->brd", Ly, Xp)
    tmp = jnp.einsum("bqr,brd->bqd", Bmat, tmp)
    return jnp.einsum("bkq,bqd->bkd", Lx, tmp)


def _lagrange_batched(pts, nodes):
    r = nodes.shape[1]
    k = np.arange(r)
    w = ((-1.0) ** k) * np.sin((2 * k + 1) * np.pi / (2 * r))  # (r,)
    diff = pts[:, :, None] - nodes[:, None, :]  # (B, K, r)
    small = jnp.abs(diff) < 1e-12
    diff = jnp.where(small, 1.0, diff)
    terms = w[None, None, :] / diff
    L = terms / jnp.sum(terms, axis=-1, keepdims=True)
    any_small = jnp.any(small, axis=-1, keepdims=True)
    return jnp.where(any_small, small.astype(L.dtype), L)


def polynomial_batched_matvec(coeffs, tgt_d, tgt_mask, src_d, src_mask, Xp):
    """Exact batched multiply for f = polynomial(coeffs) — differentiable
    w.r.t. coeffs. O((Kt+Ks) * deg) per node."""
    coeffs = jnp.asarray(coeffs)
    Bdeg = coeffs.shape[0] - 1
    xpow = _powers_b(tgt_d, Bdeg)  # (B, Kt, deg+1)
    ypow = _powers_b(src_d, Bdeg)  # (B, Ks, deg+1)
    ypow = ypow * src_mask[:, :, None]
    S = jnp.einsum("bku,bkd->bud", ypow, Xp)  # (B, deg+1, d)
    Wrows = []
    for l in range(Bdeg + 1):
        acc = 0.0
        for tt in range(l, Bdeg + 1):
            acc = acc + coeffs[tt] * math.comb(tt, l) * S[:, tt - l]
        Wrows.append(acc)
    W = jnp.stack(Wrows, axis=1)  # (B, deg+1, d)
    return jnp.einsum("bkl,bld->bkd", xpow, W)


def _powers_b(x, B):
    pows = [jnp.ones_like(x)]
    for _ in range(B):
        pows.append(pows[-1] * x)
    return jnp.stack(pows, axis=-1)


def exponential_batched_matvec(lam, scale, tgt_d, tgt_mask, src_d, src_mask,
                               Xp):
    """Exact rank-1 multiply for f = scale * exp(lam s), numerically shifted.
    Padded source groups carry zero mass in Xp, so no source mask is needed."""
    ly = lam * src_d  # (B, Us)
    m = jnp.max(jnp.where(src_mask, ly, -jnp.inf), axis=1, keepdims=True)
    t = jnp.einsum("bu,bud->bd", jnp.exp(ly - m) * src_mask, Xp)  # (B, d)
    return scale * jnp.exp(lam * tgt_d + m)[:, :, None] * t[:, None, :]


def hankel_batched_matvec(fn_eval, h: float, tgt_d0: np.ndarray,
                          src_d0: np.ndarray, Xp):
    """Exact multiply for ANY f on grid-aligned distances (spacing h).

    The integer grid indices come from the host-side (numpy) build-time
    distance arrays, so every shape below is static under jit: M embeds into
    a Hankel matrix and the multiply becomes an FFT correlation with
    F[k] = f(k h) — the paper's rational-weight embedding (App. A.2.3),
    batched over IT nodes. Requires static distances by construction, which
    is why reweightable specs never select this engine."""
    it = np.rint(tgt_d0 / h).astype(np.int64)  # (B, Ut); padded -> 0
    isrc = np.rint(src_d0 / h).astype(np.int64)  # (B, Us)
    Ms = int(isrc.max()) + 1 if isrc.size else 1
    L = (int(it.max()) if it.size else 0) + Ms  # covers all k + m
    F = fn_eval(h * jnp.arange(L, dtype=Xp.dtype))  # (L,)
    B, Us, d = Xp.shape
    bidx = np.arange(B)[:, None]
    # scatter source mass onto the grid: P[b, m] = sum_{u: isrc[b,u]=m} Xp[b,u]
    P = jnp.zeros((B, Ms, d), Xp.dtype).at[bidx, isrc].add(Xp)
    n = 1 << int(np.ceil(np.log2(L + Ms)))
    Ff = jnp.fft.rfft(F, n=n)  # (n//2+1,)
    Pf = jnp.fft.rfft(P[:, ::-1], n=n, axis=1)  # (B, n//2+1, d)
    full = jnp.fft.irfft(Ff[None, :, None] * Pf, n=n, axis=1)
    out_full = full[:, Ms - 1 : Ms - 1 + L]  # (B, L, d): out[b,k]=sum F[k+m]P[m]
    return jnp.take_along_axis(out_full, jnp.asarray(it)[:, :, None], axis=1)


# ----------------------------------------------------------------------------
# engine selection + the pure executor
# ----------------------------------------------------------------------------


def select_cross(spec: PlanSpec, fspec: FamilySpec, backend: str = "plan",
                 degree: int = 32, pallas_opts: dict | None = None):
    """(engine_name, cross_multiply) for this (spec, f-family, backend).

    cross_multiply(i, tgt_d, tgt_mask, src_d, src_mask, Xp) -> (B, Ut, d)
    receives the bucket index plus the *params* distance arrays (traceable),
    so every engine except the grid/Hankel one differentiates through —
    and flows gradients into — reweighted distances.

    `backend="auto"` resolves by problem size through the degradation
    ladder: the fused pallas kernel only wins past
    `ladder.AUTO_PALLAS_MIN_N` vertices (BENCH_ftfi_runtime.json shows it
    *slower* than the plan engine at n=1000), so small plans pick "plan"."""
    if backend == "auto":
        from repro.core import ladder

        backend = ladder.effective_backend("auto", n=spec.n)
    if backend == "pallas" and fspec.mode in KERNEL_MODES:
        opts = dict(pallas_opts or {})
        coeffs = jnp.asarray(np.asarray(fspec.coeffs, np.float32))
        mode, scale = fspec.mode, fspec.scale

        def cross(i, tgt_d, tgt_mask, src_d, src_mask, Xp):
            from repro.kernels.fdist_matvec.ops import fdist_matvec_batched

            out = fdist_matvec_batched(
                tgt_d.astype(jnp.float32), src_d.astype(jnp.float32),
                Xp.astype(jnp.float32), coeffs, mode=mode, **opts)
            # the kernel's rational family is unit-scaled: 1 / (1 + c0 s^2)
            return out * scale if mode == "rational" else out

        return f"fdist_matvec:{fspec.mode}", cross
    if fspec.mode == "poly":
        cs = fspec.coeffs

        def cross(i, tgt_d, tgt_mask, src_d, src_mask, Xp):
            return polynomial_batched_matvec(cs, tgt_d, tgt_mask, src_d,
                                             src_mask, Xp)

        return "polynomial", cross
    if fspec.mode == "exp":
        lam, scale = fspec.coeffs

        def cross(i, tgt_d, tgt_mask, src_d, src_mask, Xp):
            return exponential_batched_matvec(lam, scale, tgt_d, tgt_mask,
                                              src_d, src_mask, Xp)

        return "exponential", cross
    if spec.grid_h is not None and not spec.reweightable:
        h, fe = spec.grid_h, fspec.fn_eval

        def cross(i, tgt_d, tgt_mask, src_d, src_mask, Xp):
            return hankel_batched_matvec(fe, h, spec.cross_tgt_d0[i],
                                         spec.cross_src_d0[i], Xp)

        return "hankel_fft", cross
    fe = fspec.fn_eval

    def cross(i, tgt_d, tgt_mask, src_d, src_mask, Xp):
        return chebyshev_batched_matvec(fe, tgt_d, tgt_mask, src_d, src_mask,
                                        Xp, degree=degree)

    return "chebyshev", cross


def _execute(spec: PlanSpec, params: PlanParams, fn_eval: Callable,
             cross_multiply: Callable, X):
    """The pure fused executor: one gather + segment-sum (Eq. 3), one cross
    dispatch per size bucket, one gather + scatter-add (Eq. 4), diagonal
    corrections, per-tree output weights. Everything dynamic comes from
    `params`; everything indexing/shaping from `spec`."""
    X = jnp.asarray(X)
    squeeze = X.ndim == 1
    if squeeze:
        X = X[:, None]
    d = X.shape[1]
    Xpad = jnp.concatenate([X, jnp.zeros((1, d), X.dtype)], axis=0)
    out = jnp.zeros_like(Xpad)

    for i in range(len(spec.leaf_ids)):
        ids, mask = spec.leaf_ids[i], spec.leaf_mask[i]
        Xl = Xpad[ids]  # (B, K, d)
        M = fn_eval(params.leaf_dists[i])  # (B, K, K)
        pair_mask = mask[:, :, None] & mask[:, None, :]
        M = jnp.where(jnp.asarray(pair_mask), M, 0.0)
        contrib = jnp.einsum("bij,bjd->bid", M, Xl)
        out = out.at[ids].add(contrib * mask[:, :, None])

    if spec.n_src_groups:
        # Eq. 3 for every node at once: X'[g] = sum of source-vertex fields
        # per distance group (pivot/pad groups are empty -> zero)
        Xp_flat = jax.ops.segment_sum(Xpad[spec.src_gather], spec.src_seg,
                                      num_segments=spec.n_src_groups)
        parts = []
        for i in range(len(spec.cross_src_mask)):
            B, Us = spec.cross_src_mask[i].shape
            Ut = spec.cross_tgt_mask[i].shape[1]
            off = spec.cross_src_off[i]
            Xp = Xp_flat[off:off + B * Us].reshape(B, Us, d)
            res = cross_multiply(
                i, params.cross_tgt_d[i], jnp.asarray(spec.cross_tgt_mask[i]),
                params.cross_src_d[i], jnp.asarray(spec.cross_src_mask[i]),
                Xp)
            parts.append(res.reshape(B * Ut, d))
        cross_flat = (jnp.concatenate(parts, axis=0) if len(parts) > 1
                      else parts[0])
        # Eq. 4 for every node at once: gather each target's group value and
        # scatter-add into the output field
        out = out.at[spec.tgt_scatter].add(cross_flat[spec.tgt_gather])

    # diagonal corrections: -f(0) X[p] once per internal node
    f0 = fn_eval(jnp.zeros((1,)))[0]
    out = out.at[spec.pivots].add(-f0 * Xpad[spec.pivots])

    res = out[:-1]
    if params.tree_w is not None:
        w = jnp.repeat(jnp.asarray(params.tree_w),
                       np.asarray(spec.tree_sizes, np.int64),
                       total_repeat_length=spec.n)
        res = res * w[:, None].astype(res.dtype)
    return res[:, 0] if squeeze else res


def _fspec(fn) -> FamilySpec:
    return fn if isinstance(fn, FamilySpec) else spec_of(fn)


def apply(spec: PlanSpec, params: PlanParams, fn, X, *,
          backend: str = "plan", degree: int = 32,
          pallas_opts: dict | None = None, mesh=None,
          axis: str | None = None):
    """Pure integration: Y = M_f X with distances/weights from `params`.

    jit/vmap/grad-safe: `spec` is static (pytree aux), `params`/`X` are
    traced. `fn` is a CordialFn, FamilySpec, or traceable callable.
    `backend` picks the cross-engine family: "plan" (exact LDR + Hankel on
    grids + Chebyshev), "pallas" (fused fdist_matvec kernel for the
    in-kernel families), or "auto" (size-resolved through the ladder). The
    host backend remains facade-only (numpy).

    `mesh` (optionally with `axis`) routes through the multi-device
    shard_map executor — see `plan_shard.apply_sharded`."""
    if mesh is not None:
        from repro.core.plan_shard import apply_sharded

        return apply_sharded(spec, params, fn, X, mesh=mesh, axis=axis,
                             backend=backend, degree=degree,
                             pallas_opts=pallas_opts)
    fspec = _fspec(fn)
    _, cross = select_cross(spec, fspec, backend=backend, degree=degree,
                            pallas_opts=pallas_opts)
    return _execute(spec, params, fspec.fn_eval, cross, X)


def fastmult(spec: PlanSpec, fn, *, backend: str = "plan", degree: int = 32,
             pallas_opts: dict | None = None) -> Callable:
    """Jittable (params, X) -> Y closure with the engine choice baked in.

    Unlike the legacy `Integrator.fastmult` (which captured plan state in an
    opaque closure), the returned function is pure: params cross jit
    boundaries explicitly, so it vmaps over batched fields, shards, and
    back-propagates into reweighted distances."""
    fspec = _fspec(fn)
    _, cross = select_cross(spec, fspec, backend=backend, degree=degree,
                            pallas_opts=pallas_opts)
    fe = fspec.fn_eval

    def fm(params, X):
        if isinstance(X, jax.core.Tracer):
            # trace-time only: one record per compile, none per cached call
            trace_guard.record("ftfi.fastmult", detail=spec.digest[:12])
        return _execute(spec, params, fe, cross, X)

    return fm


def describe(spec: PlanSpec, fn, backend: str = "plan", degree: int = 32
             ) -> dict:
    name, _ = select_cross(spec, _fspec(fn), backend=backend, degree=degree)
    return {"api": "ftfi", "backend": backend, "cross_engine": name,
            "grid_h": spec.grid_h, "num_trees": spec.num_trees,
            "reweightable": spec.reweightable}


# ----------------------------------------------------------------------------
# reweight: edge weights -> PlanParams (differentiable)
# ----------------------------------------------------------------------------


def reweight(spec: PlanSpec, edge_w, tree_w=None) -> PlanParams:
    """Re-derive every plan distance from edge weights, differentiably.

    depth[v] = sum of edge weights on v's root path (one gather +
    segment-sum over the spec's root-path CSR), then every distance slot is
    d(u, v) = depth[u] + depth[v] - 2 depth[lca(u, v)] via the build-time
    (pivot, representative, LCA) tables. Exact for ANY positive weights on
    the same topology — the IT decomposition is combinatorial — so tree
    metrics (and hence topo-attention RPE distances) become learnable
    parameters. Requires `build(..., reweightable=True)`.

    `edge_w` is (num_edges,) in packed per-tree edge order (the
    concatenation of each tree's `weights` array); `tree_w` optionally sets
    per-tree output weights on the returned params."""
    if spec.path_rows is None:
        raise ValueError(
            "spec was not built with reweightable=True: rebuild via "
            "ftfi.build(tree, reweightable=True) to record the distance "
            "derivation tables")
    edge_w = jnp.asarray(edge_w)
    if edge_w.shape != (spec.num_edges,):
        raise ValueError(
            f"edge_w must have shape ({spec.num_edges},) — packed per-tree "
            f"edge order — got {edge_w.shape}")
    depth = jax.ops.segment_sum(edge_w[spec.path_edges], spec.path_rows,
                                num_segments=spec.n)
    dpad = jnp.concatenate([depth, jnp.zeros((1,), depth.dtype)])

    def _pair(u, v, l):
        return dpad[u] + dpad[v] - 2.0 * dpad[l]

    ctd = tuple(
        _pair(spec.cross_piv[i][:, None], spec.cross_tgt_rep[i],
              spec.cross_tgt_lca[i])
        for i in range(len(spec.cross_tgt_rep)))
    csd = tuple(
        _pair(spec.cross_piv[i][:, None], spec.cross_src_rep[i],
              spec.cross_src_lca[i])
        for i in range(len(spec.cross_src_rep)))
    ld = tuple(
        _pair(spec.leaf_ids[i][:, :, None].astype(np.int64),
              spec.leaf_ids[i][:, None, :].astype(np.int64),
              spec.leaf_lca[i])
        for i in range(len(spec.leaf_ids)))
    return PlanParams(cross_tgt_d=ctd, cross_src_d=csd, leaf_dists=ld,
                      tree_w=None if tree_w is None else jnp.asarray(tree_w))


# ----------------------------------------------------------------------------
# serialization
# ----------------------------------------------------------------------------

_SPEC_ARRAY_FIELDS = ("pivots", "src_gather", "src_seg", "tgt_gather",
                      "tgt_scatter", "path_rows", "path_edges",
                      # update tables (absent in pre-schema-2 artifacts;
                      # loader defaults them to None)
                      "children", "root_refs", "job_bucket", "job_row",
                      "leaf_bucket", "leaf_row", "edges_u", "edges_v",
                      "edge_w0", "ghosts")
_SPEC_TUPLE_FIELDS = ("cross_tgt_mask", "cross_src_mask", "cross_tgt_d0",
                      "cross_src_d0", "leaf_ids", "leaf_mask", "leaf_dists0",
                      "cross_piv", "cross_tgt_rep", "cross_tgt_lca",
                      "cross_src_rep", "cross_src_lca", "leaf_lca")
_SPEC_SCALAR_FIELDS = ("n", "num_trees", "tree_sizes", "leaf_size", "seed",
                       "fingerprint", "grid_h", "reweightable",
                       "cross_src_off", "cross_tgt_off", "n_src_groups",
                       "n_tgt_groups", "num_cross_jobs", "num_edges",
                       "mesh_devices", "mesh_axes", "shard_layout")
# absent in pre-schema-3 artifacts; the loader falls back to these
_SPEC_SCALAR_DEFAULTS = {"mesh_devices": 0, "mesh_axes": (),
                         "shard_layout": 0}


def save_plan(path, spec: PlanSpec, params: PlanParams, *,
              mesh=None) -> None:
    """Serialize (spec, params) to one .npz artifact (no pickle).

    The artifact is self-contained: `load_plan` reconstructs both halves
    with zero IT rebuild, and a load -> apply reproduces results bit-for-bit
    (params are saved post-conversion, so the loaded arrays are the same
    bits the builder's executor consumed).

    `mesh` stamps mesh/device provenance (device count, axis names, shard
    layout version) into the artifact: loading it onto a mismatched mesh
    then fails fast in `plan_guard` / `apply_sharded` instead of crashing
    at gather time."""
    if mesh is not None:
        from repro.core.plan_shard import SHARD_LAYOUT_VERSION

        spec = dataclasses.replace(
            spec, mesh_devices=int(mesh.devices.size),
            mesh_axes=tuple(str(a) for a in mesh.axis_names),
            shard_layout=SHARD_LAYOUT_VERSION)
    arrays: dict = {}
    meta: dict = {"version": _SAVE_VERSION}
    for name in _SPEC_SCALAR_FIELDS:
        meta[name] = getattr(spec, name)
    for name in _SPEC_ARRAY_FIELDS:
        val = getattr(spec, name)
        meta[f"has_{name}"] = val is not None
        if val is not None:
            arrays[f"s_{name}"] = val
    for name in _SPEC_TUPLE_FIELDS:
        val = getattr(spec, name)
        meta[f"len_{name}"] = -1 if val is None else len(val)
        if val is not None:
            for i, a in enumerate(val):
                arrays[f"s_{name}_{i}"] = a
    for name in ("cross_tgt_d", "cross_src_d", "leaf_dists"):
        val = getattr(params, name)
        for i, a in enumerate(val):
            arrays[f"p_{name}_{i}"] = np.asarray(a)
    meta["has_tree_w"] = params.tree_w is not None
    if params.tree_w is not None:
        arrays["p_tree_w"] = np.asarray(params.tree_w)
    arrays["__meta__"] = np.array(json.dumps(meta))
    with open(path, "wb") as fh:
        np.savez_compressed(fh, **arrays)


def load_plan(path, validate: bool = True):
    """Deserialize a `save_plan` artifact -> (spec, params). Never touches
    the IT/plan builders: serving restarts pay one file read, not an
    O(N log N) decomposition.

    The artifact is UNTRUSTED input (disk cache, registry download, operator
    handoff): a truncated/bit-flipped file raises a clear
    `PlanValidationError` instead of feeding garbage indices to the fused
    executor. `validate=True` (default) additionally runs the full
    `plan_guard` bounds/consistency pass under the configured policy;
    malformed-container errors (torn zip, missing members, bad metadata)
    always raise `PlanValidationError` regardless of policy."""
    from repro.core.plan_guard import PlanValidationError

    try:
        with np.load(path, allow_pickle=False) as z:
            meta = json.loads(str(z["__meta__"][()]))
            if meta.get("version") != _SAVE_VERSION:
                raise PlanValidationError(
                    f"unsupported plan artifact version: "
                    f"{meta.get('version')!r}")
            kwargs: dict = {}
            for name in _SPEC_SCALAR_FIELDS:
                val = meta.get(name, _SPEC_SCALAR_DEFAULTS.get(name))
                if isinstance(val, list):
                    val = tuple(val)
                kwargs[name] = val
            for name in _SPEC_ARRAY_FIELDS:
                kwargs[name] = (z[f"s_{name}"]
                                if meta.get(f"has_{name}", False) else None)
            for name in _SPEC_TUPLE_FIELDS:
                ln = meta[f"len_{name}"]
                kwargs[name] = (None if ln < 0 else
                                tuple(z[f"s_{name}_{i}"] for i in range(ln)))
            spec = PlanSpec(**kwargs)
            nb = meta["len_cross_tgt_d0"]
            nl = meta["len_leaf_dists0"]
            params = PlanParams(
                cross_tgt_d=tuple(jnp.asarray(z[f"p_cross_tgt_d_{i}"])
                                  for i in range(nb)),
                cross_src_d=tuple(jnp.asarray(z[f"p_cross_src_d_{i}"])
                                  for i in range(nb)),
                leaf_dists=tuple(jnp.asarray(z[f"p_leaf_dists_{i}"])
                                 for i in range(nl)),
                tree_w=(jnp.asarray(z["p_tree_w"]) if meta["has_tree_w"]
                        else None),
            )
    except PlanValidationError:
        raise
    except Exception as e:
        # torn zip / missing npz member / mangled json / wrong dtype: one
        # clear error class so callers (disk cache, serving) reject cleanly
        raise PlanValidationError(
            f"load_plan({path!s}): corrupt or truncated plan artifact "
            f"({type(e).__name__}: {e})") from e
    # canonicalize dtype drift from older artifacts (schema <= 3 saved the
    # update tables as int64): bounds-guarded downcast, never silent wrap
    from repro.core import plan_guard

    spec, _coerced = plan_guard.coerce_index_dtypes(spec)
    if validate:
        plan_guard.validate(spec, params, where=f"load_plan({path!s})")
    return spec, params


# re-export: incremental edits live in their own module but belong to this
# API surface (imported at the bottom to avoid a circular import)
from repro.core.plan_update import update_plan  # noqa: E402,F401
