"""Disk-persistent LRU plan cache.

`compile_plan` / `compile_forest_plan` consult this cache after the
in-memory BoundedLRU misses and BEFORE any IT build: a hit is one
`load_plan` npz read reconstructed through `plan_from_spec` (zero IT
rebuild), which turns cold *process* starts — serving restarts, benchmark
reruns, per-request trees that recur across workers — into a file read.

Configuration (environment, overridable programmatically):

  FTFI_PLAN_CACHE          cache directory; unset/empty -> cache disabled
  FTFI_PLAN_CACHE_MAX_MB   total size budget in MB (default 512); the
                           least-recently-USED artifacts (hits touch mtime)
                           are evicted once the budget is exceeded.
                           Non-numeric or non-positive values warn once
                           and fall back to the default, never crash.

Artifacts are the standard `save_plan` npz format keyed by a sha1 over the
full compile key (content fingerprint(s), leaf_size, seed, grid detection,
reweightable) plus the serialization schema version — so incompatible
artifacts from older code versions can never be loaded. Writes are atomic
(tmp file + os.replace) and every cache error degrades to a miss: a
corrupt or torn artifact is deleted and the plan is rebuilt.
"""
from __future__ import annotations

import hashlib
import os
import tempfile
import warnings

_ENV_DIR = "FTFI_PLAN_CACHE"
_ENV_MAX_MB = "FTFI_PLAN_CACHE_MAX_MB"
_DEFAULT_MAX_MB = 512.0
_PREFIX = "ftfi-plan-"

_UNSET = object()
_dir_override: object = _UNSET
_max_mb_override: object = _UNSET
_warned_max_mb: str | None = None
_stats = {"hits": 0, "misses": 0, "stores": 0, "evictions": 0,
          "errors": 0, "validation_rejects": 0}


def configure(directory, max_mb: float | None = None) -> None:
    """Programmatic override of the environment configuration:
    `configure("/path")` enables the cache there, `configure(None)`
    disables it. `max_mb` optionally overrides the size budget."""
    global _dir_override, _max_mb_override
    _dir_override = os.fspath(directory) if directory else None
    if max_mb is not None:
        _max_mb_override = float(max_mb)


def reset_to_env() -> None:
    """Drop programmatic overrides: follow FTFI_PLAN_CACHE(_MAX_MB) again."""
    global _dir_override, _max_mb_override
    _dir_override = _UNSET
    _max_mb_override = _UNSET


def cache_dir() -> str | None:
    if _dir_override is not _UNSET:
        return _dir_override  # type: ignore[return-value]
    return os.environ.get(_ENV_DIR) or None


def enabled() -> bool:
    return cache_dir() is not None


def _max_bytes() -> int:
    if _max_mb_override is not _UNSET:
        return int(float(_max_mb_override) * 1e6)  # type: ignore[arg-type]
    raw = os.environ.get(_ENV_MAX_MB)
    if raw is None:
        return int(_DEFAULT_MAX_MB * 1e6)
    # defensive parse: an operator typo in the env must degrade to the
    # default budget with one warning, never crash the serving process or
    # silently evict everything (a negative/zero budget would)
    global _warned_max_mb
    try:
        mb = float(raw)
        if mb <= 0 or mb != mb:  # reject <= 0 and NaN
            raise ValueError(f"non-positive budget {mb!r}")
    except ValueError as e:
        if _warned_max_mb != raw:  # warn once per distinct bad value
            _warned_max_mb = raw
            warnings.warn(
                f"{_ENV_MAX_MB}={raw!r} is not a positive number ({e}); "
                f"using the default {_DEFAULT_MAX_MB:.0f} MB budget",
                UserWarning, stacklevel=2)
        return int(_DEFAULT_MAX_MB * 1e6)
    return int(mb * 1e6)


def key_str(key) -> str:
    """Stable hex digest of a compile-cache key tuple. The serialization
    schema version is mixed in so artifacts written by an incompatible
    PlanSpec layout are unreachable rather than mis-loaded."""
    from repro.core.plan_api import _SAVE_VERSION, _SPEC_SCHEMA

    h = hashlib.sha1()
    h.update(f"v{_SAVE_VERSION}.{_SPEC_SCHEMA}|".encode())
    h.update(repr(key).encode())
    return h.hexdigest()


def _path(keyhex: str) -> str:
    return os.path.join(cache_dir(), f"{_PREFIX}{keyhex}.npz")


def _entries(directory: str) -> list:
    """(mtime, size, path) for every cache artifact in `directory`."""
    out = []
    try:
        names = os.listdir(directory)
    except OSError:
        return out
    for name in names:
        if not (name.startswith(_PREFIX) and name.endswith(".npz")):
            continue
        p = os.path.join(directory, name)
        try:
            st = os.stat(p)
        except OSError:
            continue
        out.append((st.st_mtime, st.st_size, p))
    return out


def load(keyhex: str):
    """(spec, params) on hit — touching the artifact's mtime for LRU — or
    None. Unreadable artifacts are deleted and count as misses."""
    if not enabled():
        return None
    path = _path(keyhex)
    if not os.path.exists(path):
        _stats["misses"] += 1
        return None
    from repro.core.plan_api import load_plan
    from repro.core.plan_guard import PlanValidationError

    try:
        # load_plan runs the full plan_guard pass in "strict" mode here
        # regardless of the global policy: a cache hit has a free fallback
        # (rebuild), so a bad artifact is ALWAYS a miss, never an executor
        # input — counted separately from torn-file errors
        spec, params = load_plan(path, validate=False)
        from repro.core import plan_guard

        plan_guard.validate(spec, params, where=f"plan_cache({path})",
                            policy_override="strict")
        os.utime(path)  # LRU: a hit makes the artifact most-recently-used
    except Exception as e:
        if isinstance(e, PlanValidationError):
            _stats["validation_rejects"] += 1
        _stats["errors"] += 1
        _stats["misses"] += 1
        try:
            os.remove(path)
        except OSError:
            pass
        return None
    _stats["hits"] += 1
    return spec, params


def store(keyhex: str, spec, params) -> None:
    """Atomically write one artifact, then evict least-recently-used
    artifacts until the directory is back under the size budget. Errors
    (read-only dir, disk full, races) are swallowed: the cache is an
    optimization, never a correctness dependency."""
    if not enabled():
        return
    directory = cache_dir()
    from repro.core.plan_api import save_plan

    try:
        os.makedirs(directory, exist_ok=True)
        fd, tmp = tempfile.mkstemp(suffix=".npz.tmp", dir=directory)
        try:
            os.close(fd)
            save_plan(tmp, spec, params)
            # fsync BEFORE the atomic rename: without it a hard kill can
            # leave a fully-renamed but truncated artifact (the rename can
            # hit disk before the data does), which would then be served as
            # a "valid" cache file until the guard rejects it
            fd = os.open(tmp, os.O_RDONLY)
            try:
                os.fsync(fd)
            finally:
                os.close(fd)
            os.replace(tmp, _path(keyhex))
        finally:
            if os.path.exists(tmp):
                os.remove(tmp)
    except Exception:
        _stats["errors"] += 1
        return
    _stats["stores"] += 1
    _evict(directory)


def _evict(directory: str) -> None:
    budget = _max_bytes()
    entries = sorted(_entries(directory))  # oldest mtime first
    total = sum(size for _, size, _ in entries)
    for _, size, path in entries:
        if total <= budget:
            break
        try:
            os.remove(path)
        except OSError:
            continue
        total -= size
        _stats["evictions"] += 1


def clear() -> None:
    """Remove every cache artifact (cache disabled -> no-op)."""
    directory = cache_dir()
    if directory is None:
        return
    for _, _, path in _entries(directory):
        try:
            os.remove(path)
        except OSError:
            pass


def stats() -> dict:
    directory = cache_dir()
    entries = _entries(directory) if directory else []
    return {"dir": directory, "enabled": directory is not None,
            "entries": len(entries),
            "bytes": int(sum(size for _, size, _ in entries)),
            "max_bytes": _max_bytes(), **_stats}
