"""ShapeDtypeStruct input specs for every (arch x shape) cell — the dry-run
stand-ins (weak-type-correct, shardable, no device allocation)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, get_config
from repro.launch.sharding import logical_to_spec
from repro.models import api
from repro.models.layers import dtype_of


def batch_specs(cfg, shape_name: str):
    """Input ShapeDtypeStructs for the step function of this cell."""
    sh = SHAPES[shape_name]
    B, L = sh["global_batch"], sh["seq_len"]
    kind = sh["kind"]
    i32 = jnp.int32
    if kind in ("train", "prefill"):
        batch = {"tokens": jax.ShapeDtypeStruct((B, L), i32)}
        if cfg.family == "vlm":
            P_ = cfg.num_prefix_embeddings
            batch["tokens"] = jax.ShapeDtypeStruct((B, L - P_), i32)
            batch["patch_embeds"] = jax.ShapeDtypeStruct((B, P_, 1024), jnp.bfloat16)
        if cfg.is_encdec:
            batch["src_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.max_source_len, 1024), jnp.bfloat16)
        return batch
    # decode: one token + KV/state cache of length L
    token = jax.ShapeDtypeStruct((B, 1), i32)
    cache = jax.eval_shape(lambda: api.init_cache(cfg, B, L))
    pos = jax.ShapeDtypeStruct((), i32)
    return {"token": token, "cache": cache, "pos": pos}


def params_shapes(cfg):
    return jax.eval_shape(lambda: api.init_params(cfg, jax.random.PRNGKey(0)))


def batch_shardings(cfg, shape_name: str, mesh):
    """NamedShardings for the batch pytree (batch dim over (pod, data))."""
    sh = SHAPES[shape_name]
    kind = sh["kind"]
    dp = logical_to_spec(("batch",))[0]
    seq = logical_to_spec(("seq_shard",))[0]

    def ns(spec):
        return NamedSharding(mesh, spec)

    if kind in ("train", "prefill"):
        out = {"tokens": ns(P(dp, None))}
        if cfg.family == "vlm":
            out["patch_embeds"] = ns(P(dp, None, None))
        if cfg.is_encdec:
            out["src_embeds"] = ns(P(dp, None, None))
        return out
    B = sh["global_batch"]
    ndev_dp = 1
    if dp is not None:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        names = dp if isinstance(dp, tuple) else (dp,)
        for n in names:
            ndev_dp *= sizes[n]
    batch_shardable = B % max(ndev_dp, 1) == 0 and B >= ndev_dp

    def cache_spec(leaf):
        # leaf leading dims: [layers?, batch, length/positions, ...]
        nd = leaf.ndim
        spec = [None] * nd
        shp = leaf.shape
        # find the batch dim: first dim equal to B
        for i, s in enumerate(shp):
            if s == B:
                if batch_shardable:
                    spec[i] = dp
                elif i + 1 < nd and shp[i + 1] == sh["seq_len"]:
                    spec[i + 1] = seq  # batch=1 long-context: shard sequence
                break
        # shard a heads-like dim over model where divisible
        model_sz = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
        for i in range(nd - 1, 0, -1):
            if spec[i] is None and shp[i] in (cfg.num_heads, cfg.num_kv_heads,
                                              cfg.d_inner, cfg.lru_width):
                if shp[i] % model_sz == 0:
                    spec[i] = "model"
                    break
        return NamedSharding(mesh, P(*spec))

    cache = jax.tree.map(cache_spec, batch_specs(cfg, shape_name)["cache"])
    return {
        "token": ns(P(dp, None)) if batch_shardable else ns(P(None, None)),
        "cache": cache,
        "pos": ns(P()),
    }
