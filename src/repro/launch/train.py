"""Training CLI:  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b
   [--smoke] [--steps N] [--batch B] [--seq L] [--variant topo] ...

With --smoke a reduced config runs end-to-end on local devices; the full
configs are what the multi-pod dry-run lowers for the production mesh (this
CLI accepts them unchanged when pointed at real hardware).
"""
from __future__ import annotations

import argparse

from repro.configs.base import get_config, get_smoke_config
from repro.optim.adamw import AdamWConfig
from repro.train.loop import TrainLoopConfig, run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--variant", default=None,
                    choices=[None, "full", "performer", "topo"])
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    overrides = {"dtype": "float32"} if args.smoke else {}
    if args.variant:
        overrides["attention_variant"] = args.variant
        overrides["topo_dist_scale"] = 1.0 / args.seq
    if overrides:
        cfg = cfg.replace(**overrides)

    loop = TrainLoopConfig(
        steps=args.steps, batch_size=args.batch, seq_len=args.seq,
        microbatches=args.microbatches, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, seed=args.seed,
        compress_grads=args.compress_grads)
    opt = AdamWConfig(lr=args.lr, total_steps=args.steps,
                      warmup_steps=max(1, args.steps // 20))
    res = run_training(cfg, loop, opt)
    print(f"final loss: {res['losses'][-1]:.4f} "
          f"(first: {res['losses'][0]:.4f}); "
          f"stragglers flagged: {len(res['straggler_events'])}")


if __name__ == "__main__":
    main()
