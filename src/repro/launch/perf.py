import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimbing driver: re-analyze a cell under config overrides and
append (hypothesis, before/after roofline terms) to results/perf.json.

  PYTHONPATH=src python -m repro.launch.perf --cell granite_34b:train_4k \
      --tag chunked_attn --set attn_impl=chunked
"""
import argparse
import json

import jax

from repro.configs.base import SHAPES
from repro.launch.dryrun import analyze_cell, cell_config, extrapolated_cost, lower_cell_cfg
from repro.launch.mesh import make_production_mesh
from repro.roofline.analysis import roofline_terms, collective_bytes_from_hlo


def parse_val(v: str):
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            pass
    if v in ("true", "false"):
        return v == "true"
    return v


def analyze_with_overrides(arch, shape, overrides, mesh):
    cfg, note = cell_config(arch, shape, "auto")
    if overrides:
        cfg = cfg.replace(**overrides)
    # full-depth compile for memory analysis
    lowered, compiled, _, _ = lower_cell_cfg(cfg, shape, mesh)
    mem = compiled.memory_analysis()
    rec = {
        "arch": arch, "shape": shape, "overrides": overrides,
        "peak_bytes_per_device": (getattr(mem, "argument_size_in_bytes", 0)
                                  + getattr(mem, "output_size_in_bytes", 0)
                                  + getattr(mem, "temp_size_in_bytes", 0)),
    }
    rec.update(extrapolated_cost(cfg, shape, mesh))
    n_chips = int(mesh.devices.size)
    rec["n_chips"] = n_chips
    rec.update(roofline_terms(rec, cfg, SHAPES[shape], n_chips))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--tag", required=True)
    ap.add_argument("--set", nargs="*", default=[])
    ap.add_argument("--out", default="results/perf.json")
    args = ap.parse_args()
    arch, shape = args.cell.split(":")
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = parse_val(v)
    mesh = make_production_mesh(multi_pod=False)
    rec = analyze_with_overrides(arch, shape, overrides, mesh)
    rec["tag"] = args.tag
    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    results.append(rec)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)
    print(json.dumps({k: rec[k] for k in
                      ("tag", "compute_s", "memory_s", "collective_s",
                       "dominant", "useful_flops_ratio",
                       "peak_bytes_per_device")}, indent=1))


if __name__ == "__main__":
    main()
