"""Logical-axis sharding: MaxText-style rules mapping logical names to mesh axes.

Models annotate activations with `shard(x, ("batch", "seq", "model_ff"))` and
declare parameter specs by path-regex. With no active rules (CPU unit tests)
everything is a no-op, so model code runs unchanged on one device.
"""
from __future__ import annotations

import contextlib
import contextvars
import re

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis name -> mesh axis (or tuple of mesh axes, or None)
DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "seq": None,
    "seq_shard": "data",  # long-context decode: sequence over data axis
    "embed": None,  # activation d_model stays unsharded (megatron style)
    "seq_sp": "model",  # sequence-parallel residual stream (opt-in per cfg)
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ff": "model",
    "vocab": "model",
    "experts": "model",
    "expert_capacity": None,
    "inner": "model",  # ssm / lru inner channels
    "state": None,
    "kv_lora": None,
    "frames": None,
    # FTFI plan axes (core.plan_shard): the plan's vertex index space is
    # cut into per-device leaf blocks over `data`; cross-bucket source /
    # target group spaces follow their jobs onto the same axis; whole trees
    # of a packed Forest land per shard ("tree"); batched field columns ride
    # the batch axes
    "plan_leaves": "data",
    "cross_src": "data",
    "cross_tgt": "data",
    "tree": "data",
    "field_batch": ("pod", "data"),
}

_rules_var: contextvars.ContextVar = contextvars.ContextVar("rules", default=None)
_mesh_var: contextvars.ContextVar = contextvars.ContextVar("mesh", default=None)


@contextlib.contextmanager
def use_sharding(mesh: Mesh, rules: dict | None = None, overrides: dict | None = None):
    r = dict(DEFAULT_RULES if rules is None else rules)
    if overrides:
        r.update(overrides)
    # drop mesh axes that don't exist (e.g. "pod" on the single-pod mesh)
    axis_names = set(mesh.axis_names)

    def _filter(ax):
        if ax is None:
            return None
        if isinstance(ax, tuple):
            ax = tuple(a for a in ax if a in axis_names)
            return ax if ax else None
        return ax if ax in axis_names else None

    r = {k: _filter(v) for k, v in r.items()}
    t1 = _rules_var.set(r)
    t2 = _mesh_var.set(mesh)
    try:
        yield
    finally:
        _rules_var.reset(t1)
        _mesh_var.reset(t2)


def logical_to_spec(logical: tuple) -> P:
    rules = _rules_var.get()
    if rules is None:
        return P()
    axes = []
    used = set()
    for name in logical:
        ax = rules.get(name) if name is not None else None
        # an axis may be consumed only once per spec
        if ax is not None:
            key = tuple(ax) if isinstance(ax, tuple) else (ax,)
            if any(a in used for a in key):
                ax = None
            else:
                used.update(key)
        axes.append(ax)
    return P(*axes)


def shard(x, logical: tuple):
    """with_sharding_constraint by logical names; no-op without active rules.

    Axes whose mesh extent does not divide the array dim are dropped (e.g.
    kv_heads=8 on a 16-way model axis -> left to SPMD propagation), which
    avoids GSPMD's 'involuntary full rematerialization' fallback."""
    rules = _rules_var.get()
    mesh = _mesh_var.get()
    if rules is None or mesh is None:
        return x
    spec = logical_to_spec(logical)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    fixed = []
    for i, ax in enumerate(spec):
        if ax is None or i >= x.ndim:
            fixed.append(None)
            continue
        total = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            total *= sizes[a]
        fixed.append(ax if x.shape[i] % total == 0 else None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*fixed)))


# ----------------------------------------------------------------------------
# parameter specs by path pattern
# ----------------------------------------------------------------------------

# Order matters: first match wins. Patterns run against '/'-joined param paths.
# Leading layer-stack dims are handled by `stacked` markers in the model's
# param builders (they prepend None).
PARAM_RULES: list[tuple[str, tuple]] = [
    (r"embed/table", ("vocab", "embed")),
    (r"lm_head/kernel", ("embed", "vocab")),
    (r"(attn|cross_attn)/(wq|wkv|wk|wv)\b.*", ("embed", "heads")),
    (r"(attn|cross_attn)/wo", ("heads", "embed")),
    (r"attn/w_dq", ("embed", None)),
    (r"attn/w_uq", (None, "heads")),
    (r"attn/w_dkv", ("embed", None)),
    (r"attn/w_ukv", (None, "heads")),
    (r"attn/w_kr", ("embed", None)),
    (r"mlp/w_(in|gate)", ("embed", "ff")),
    (r"mlp/w_out", ("ff", "embed")),
    (r"moe/router", ("embed", "experts")),
    (r"moe/experts_w_(in|gate)", ("experts", "embed", None)),
    (r"moe/experts_w_out", ("experts", None, "embed")),
    (r"moe/shared_w_(in|gate)", ("embed", "ff")),
    (r"moe/shared_w_out", ("ff", "embed")),
    (r"ssm/in_proj", ("embed", "inner")),
    (r"ssm/conv_w", ("inner", None)),
    (r"ssm/x_proj", ("inner", None)),
    (r"ssm/dt_proj", (None, "inner")),
    (r"ssm/(A_log|D|conv_b|dt_bias)", ("inner",)),
    (r"ssm/out_proj", ("inner", "embed")),
    (r"lru/in_proj", ("embed", "inner")),
    (r"lru/conv_w", ("inner", None)),
    (r"lru/(a_param|gate_w|gate_b|input_w|input_b)", ("inner",)),
    (r"lru/gates", ("inner", None)),
    (r"lru/out_proj", ("inner", "embed")),
    (r"topo/.*", (None,)),  # 3 scalars/layer: replicated
    (r".*(norm|scale|bias)\b.*", (None,)),
    (r".*", (None,)),
]


def param_spec_for_path(path: str, ndim: int, stacked: bool) -> P:
    rules = _rules_var.get()
    if rules is None:
        return P()
    for pat, logical in PARAM_RULES:
        if re.search(pat, path):
            names = list(logical)
            break
    else:  # pragma: no cover
        names = []
    # pad/trim to ndim (minus the stack dim)
    eff = ndim - (1 if stacked else 0)
    if len(names) < eff:
        names = names + [None] * (eff - len(names))
    names = names[:eff]
    if stacked:
        names = [None] + names
    axes = [logical_to_spec((n,))[0] if n else None for n in names]
    return P(*axes)


def tree_param_specs(params, stacked_prefixes=("blocks",)):
    """PartitionSpec pytree matching `params` (path-based rules).
    Non-divisible dims fall back to replication."""
    mesh = _mesh_var.get()
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh else {}
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    specs = []
    for path, leaf in flat:
        spath = "/".join(
            p.key if hasattr(p, "key") else str(p) for p in path)
        stacked = any(spath.startswith(pfx) for pfx in stacked_prefixes)
        spec = param_spec_for_path(spath, leaf.ndim, stacked)
        fixed = []
        for i, ax in enumerate(spec):
            if ax is None:
                fixed.append(None)
                continue
            total = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                total *= sizes.get(a, 1)
            fixed.append(ax if leaf.shape[i] % total == 0 else None)
        specs.append(P(*fixed))
    return jax.tree_util.tree_unflatten(treedef, specs)


def shard_q_heads(x):
    """Attention-query sharding with context-parallel fallback: prefer heads
    over the model axis; if num_heads doesn't divide it (llava 56, qwen2 12,
    recurrentgemma 10), shard the QUERY sequence dim instead — rows of the
    attention matrix are independent, so Lq-sharding is always legal and
    keeps the (B, H, Lq, Lk) logits partitioned. x: (B, L, H, hd)."""
    rules = _rules_var.get()
    mesh = _mesh_var.get()
    if rules is None or mesh is None:
        return x
    dp = rules.get("batch")
    model_ax = rules.get("heads")
    if model_ax is None:
        return shard(x, ("batch", None, None, None))
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    msize = 1
    for a in (model_ax if isinstance(model_ax, tuple) else (model_ax,)):
        msize *= sizes[a]
    B, L, H = x.shape[0], x.shape[1], x.shape[2]
    if H % msize == 0:
        spec = P(dp, None, model_ax, None)
    elif L % msize == 0 and L > 1:
        spec = P(dp, model_ax, None, None)
    else:
        spec = P(dp, None, None, None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def batch_axes():
    """Mesh axes bound to the logical 'batch' axis (tuple), or None."""
    rules = _rules_var.get()
    if rules is None:
        return None
    ax = rules.get("batch")
    if ax is None:
        return None
    return ax if isinstance(ax, tuple) else (ax,)


def named_sharding(spec: P):
    mesh = _mesh_var.get()
    return NamedSharding(mesh, spec)


def current_mesh():
    return _mesh_var.get()


def plan_axis(mesh=None) -> str | None:
    """Mesh axis carrying the FTFI `plan_leaves` logical axis (leaf-block
    sharding of the plan executor). Falls back to "data" (or the mesh's
    first axis) when the active rules don't bind it."""
    rules = _rules_var.get()
    ax = (rules or DEFAULT_RULES).get("plan_leaves", "data")
    if isinstance(ax, tuple):
        ax = ax[0] if ax else None
    mesh = mesh if mesh is not None else _mesh_var.get()
    if mesh is not None and ax not in mesh.axis_names:
        ax = "data" if "data" in mesh.axis_names else mesh.axis_names[0]
    return ax
