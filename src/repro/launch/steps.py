"""Step functions lowered by the dry-run and driven by the train loop."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import api
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update


def make_train_step(cfg, opt_cfg: AdamWConfig):
    def train_step(params, opt_state, batch):
        def lf(p):
            loss, metrics = api.loss_fn(cfg, p, batch)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
        params, opt_state, opt_metrics = adamw_update(grads, opt_state, params,
                                                      opt_cfg)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg):
    def prefill_step(params, batch):
        return api.prefill_fn(cfg, params, batch)

    return prefill_step


def make_serve_step(cfg, seq_len: int):
    def serve_step(params, cache, token, pos):
        logits, cache = api.decode_fn(cfg, params, cache, token, pos, seq_len)
        new_token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return new_token, cache

    return serve_step
