import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT-lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent without real
hardware: jax.jit(step).lower(**ShapeDtypeStructs).compile() must succeed on
the 16x16 single-pod mesh AND the 2x16x16 multi-pod mesh, and we extract
  - memory_analysis()  (bytes/device: proves it fits)
  - cost_analysis()    (HLO flops/bytes for the roofline)
  - collective bytes   (parsed from the compiled HLO text)
Results append incrementally to results/dryrun.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
      [--mesh single|multi|both] [--variant full|topo|auto] [--out PATH]
"""
import argparse
import json
import time
import traceback

import jax
import numpy as np

from repro.configs.base import ARCHS, SHAPES, get_config
from repro.launch import sharding as SH
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import batch_shardings, batch_specs, params_shapes
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
from repro.optim.adamw import AdamWConfig
from repro.roofline.analysis import collective_bytes_from_hlo, roofline_terms

DRY_ARCHS = [a for a in ARCHS if a != "topovit_b16"]

# archs that are natively sub-quadratic (run long_500k as-is); all others run
# long_500k under the paper's topo variant (DESIGN §5 long_500k policy)
NATIVE_SUBQUADRATIC = {"falcon_mamba_7b", "recurrentgemma_2b"}


def cell_config(arch: str, shape: str, variant: str = "auto"):
    cfg = get_config(arch)
    note = ""
    if variant == "auto":
        if shape == "long_500k" and arch not in NATIVE_SUBQUADRATIC:
            cfg = cfg.replace(attention_variant="topo",
                              topo_dist_scale=1.0 / SHAPES[shape]["seq_len"])
            note = "topo-variant (paper technique enables 500k decode)"
    elif variant != "full":
        cfg = cfg.replace(attention_variant=variant,
                          topo_dist_scale=1.0 / SHAPES[shape]["seq_len"])
        note = f"{variant}-variant"
    return cfg, note


def lower_cell(arch: str, shape: str, mesh, variant: str = "auto"):
    """Returns (lowered, compiled, cfg, note)."""
    cfg, note = cell_config(arch, shape, variant)
    lowered, compiled, _, _ = lower_cell_cfg(cfg, shape, mesh)
    return lowered, compiled, cfg, note


def depth_variants(cfg):
    """Two reduced-depth UNROLLED configs for exact per-layer cost
    extrapolation (XLA cost_analysis counts while-loop bodies once, so the
    scanned full-depth compile under-reports flops/bytes/collectives).
    Returns (cfg_small, cfg_large, n_small, n_large, n_full)."""
    if cfg.family == "hybrid":
        return (cfg.replace(num_superblocks=1, scan_layers=False),
                cfg.replace(num_superblocks=2, scan_layers=False),
                1, 2, cfg.num_superblocks)
    if cfg.is_encdec:
        return (cfg.replace(encoder_layers=2, decoder_layers=2,
                            scan_layers=False),
                cfg.replace(encoder_layers=4, decoder_layers=4,
                            scan_layers=False),
                2, 4, cfg.encoder_layers)
    if cfg.family == "moe":
        fd = cfg.first_dense_layers
        return (cfg.replace(num_layers=fd + 1, scan_layers=False),
                cfg.replace(num_layers=fd + 3, scan_layers=False),
                fd + 1, fd + 3, cfg.num_layers)
    return (cfg.replace(num_layers=2, scan_layers=False),
            cfg.replace(num_layers=4, scan_layers=False),
            2, 4, cfg.num_layers)


def _cost_of(cfg, shape, mesh):
    lowered, compiled, _, _ = lower_cell_cfg(cfg, shape, mesh)
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    return {
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "collective_bytes": collective_bytes_from_hlo(hlo),
    }


def extrapolated_cost(cfg, shape, mesh) -> dict:
    c_small, c_large, n_s, n_l, n_f = depth_variants(cfg)
    small = _cost_of(c_small, shape, mesh)
    large = _cost_of(c_large, shape, mesh)
    out = {}
    for k in small:
        # fusion differences can make the per-layer delta slightly negative
        # on tiny decode programs; cost is monotone in depth, so clamp.
        per = max((large[k] - small[k]) / (n_l - n_s), 0.0)
        out[k] = max(small[k] + (n_f - n_s) * per, large[k])
    return out


def lower_cell_cfg(cfg, shape: str, mesh):
    """lower_cell but with an explicit (possibly depth-reduced) config."""
    kind = SHAPES[shape]["kind"]
    with SH.use_sharding(mesh):
        pshapes = params_shapes(cfg)
        pspecs = SH.tree_param_specs(pshapes, stacked_prefixes=("blocks",))
        pshard = jax.tree.map(lambda s: SH.named_sharding(s), pspecs)
        bspecs = batch_specs(cfg, shape)
        if kind == "train":
            from repro.optim.adamw import AdamWState, adamw_init

            opt_cfg = AdamWConfig()
            step = make_train_step(cfg, opt_cfg)
            opt_shapes = jax.eval_shape(adamw_init, pshapes)
            opt_shard = AdamWState(
                step=SH.named_sharding(jax.sharding.PartitionSpec()),
                mu=pshard, nu=pshard)
            bshard = batch_shardings(cfg, shape, mesh)
            jitted = jax.jit(step, in_shardings=(pshard, opt_shard, bshard),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(pshapes, opt_shapes, bspecs)
        elif kind == "prefill":
            step = make_prefill_step(cfg)
            bshard = batch_shardings(cfg, shape, mesh)
            jitted = jax.jit(step, in_shardings=(pshard, bshard))
            lowered = jitted.lower(pshapes, bspecs)
        else:
            step = make_serve_step(cfg, SHAPES[shape]["seq_len"])
            bshard = batch_shardings(cfg, shape, mesh)
            jitted = jax.jit(step,
                             in_shardings=(pshard, bshard["cache"],
                                           bshard["token"], bshard["pos"]),
                             donate_argnums=(1,))
            lowered = jitted.lower(pshapes, bspecs["cache"], bspecs["token"],
                                   bspecs["pos"])
        compiled = lowered.compile()
    return lowered, compiled, cfg, ""


def analyze_cell(arch: str, shape: str, mesh, mesh_name: str,
                 variant: str = "auto", extrapolate: bool = True) -> dict:
    t0 = time.time()
    lowered, compiled, cfg, note = lower_cell(arch, shape, mesh, variant)
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    n_chips = int(np.prod(mesh.devices.shape))
    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_name, "note": note,
        "variant": cfg.attention_variant,
        "compile_s": round(t_compile, 1),
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "collective_bytes": collective_bytes_from_hlo(hlo),
        "argument_size_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_size_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_size_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "peak_bytes_per_device": (getattr(mem, "argument_size_in_bytes", 0)
                                  + getattr(mem, "output_size_in_bytes", 0)
                                  + getattr(mem, "temp_size_in_bytes", 0)),
        "n_chips": n_chips,
    }
    if extrapolate:
        try:
            rec.update(extrapolated_cost(cfg, shape, mesh))
            rec["cost_mode"] = "depth-extrapolated"
        except Exception as e:  # keep the scanned-body numbers as fallback
            rec["cost_mode"] = f"scan-body-only ({type(e).__name__})"
    else:
        rec["cost_mode"] = "scan-body-only"
    rec.update(roofline_terms(rec, cfg, SHAPES[shape], n_chips))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--variant", default="auto")
    ap.add_argument("--out", default="results/dryrun.json")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else DRY_ARCHS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"], r.get("variant_req", "auto"))
            for r in results}

    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        mesh_name = "2x16x16" if multi else "16x16"
        for arch in archs:
            for shape in shapes:
                key = (arch, shape, mesh_name, args.variant)
                if key in done:
                    continue
                print(f"=== {arch} x {shape} x {mesh_name} ===", flush=True)
                try:
                    # roofline extrapolation only for the single-pod table;
                    # the multi-pod pass proves the "pod" axis shards
                    rec = analyze_cell(arch, shape, mesh, mesh_name,
                                       args.variant, extrapolate=not multi)
                    rec["variant_req"] = args.variant
                    rec["status"] = "ok"
                    print(f"  ok: {rec['compile_s']}s compile, "
                          f"{rec['peak_bytes_per_device']/2**30:.2f} GiB/dev, "
                          f"flops={rec['flops']:.3e} coll={rec['collective_bytes']:.3e}",
                          flush=True)
                except Exception as e:
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "variant_req": args.variant,
                           "status": f"error: {type(e).__name__}: {e}"}
                results.append(rec)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    n_ok = sum(1 for r in results if r.get("status") == "ok")
    print(f"\n{n_ok}/{len(results)} cells ok -> {args.out}")


if __name__ == "__main__":
    main()
