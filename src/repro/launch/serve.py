"""Serving CLI:  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b
   --smoke --requests 8 --max-new 16"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config, get_smoke_config
from repro.models import api
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--variant", default=None)
    ap.add_argument("--plan", default=None,
                    help="ftfi.save_plan artifact (.npz) to serve with — "
                         "loads the integration plan instead of rebuilding "
                         "the IT at startup")
    ap.add_argument("--prefill-mode", choices=("fused", "replay"),
                    default="fused",
                    help="fused: one prefill-into-cache call per admission "
                         "group (mid-wave admission); replay: legacy "
                         "token-by-token prompt replay through decode")
    args = ap.parse_args()

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    if args.smoke:
        cfg = cfg.replace(dtype="float32")
    if args.variant:
        cfg = cfg.replace(attention_variant=args.variant,
                          topo_dist_scale=1.0 / args.max_len)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_slots=args.slots,
                      max_len=args.max_len, plan=args.plan,
                      prefill_mode=args.prefill_mode)
    print(f"serving {args.arch} | slots={args.slots} max_len={args.max_len} "
          f"variant={cfg.attention_variant} prefill={eng.prefill_mode}")
    print(eng.plan_banner())
    rng = np.random.default_rng(0)
    reqs = []
    for r in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, size=8).tolist()
        reqs.append(Request(rid=r, prompt=prompt,
                            max_new_tokens=args.max_new))
        eng.submit(reqs[-1])
    t0 = time.time()
    ticks = eng.run()
    dt = time.time() - t0
    # report what was actually generated (evicted retries, truncation, and
    # failures all mean the old `requests * max_new` figure over-reports)
    st = eng.stats()
    gen_tokens = sum(len(r.out) for r in reqs)
    print(f"served {st['completed']}/{args.requests} requests "
          f"({st['failed']} failed, {st['truncated']} truncated) / "
          f"{gen_tokens} generated tokens in {ticks} ticks, {dt:.2f}s "
          f"({gen_tokens / dt:.1f} tok/s generated; "
          f"prefill {st['prefill_tokens'] / dt:.1f} tok/s, "
          f"decode {st['decode_tokens'] / dt:.1f} tok/s)")
    print(eng.health_banner())


if __name__ == "__main__":
    main()
