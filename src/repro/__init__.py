"""repro: Fast Tree-Field Integrators (NeurIPS 2024) as a JAX framework."""
__version__ = "0.1.0"
