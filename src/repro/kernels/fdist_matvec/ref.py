"""Pure-jnp oracle for the fused f-distance matvec kernel."""
from __future__ import annotations

import jax.numpy as jnp


def f_eval(s, coeffs, mode: str):
    if mode == "poly":
        acc = jnp.zeros_like(s)
        for t in range(coeffs.shape[0] - 1, -1, -1):
            acc = acc * s + coeffs[t]
        return acc
    if mode == "exp":
        return coeffs[1] * jnp.exp(coeffs[0] * s)
    if mode == "expq":
        return jnp.exp(coeffs[0] * s * s + coeffs[1] * s + coeffs[2])
    if mode == "rational":
        return 1.0 / (1.0 + coeffs[0] * s * s)
    raise ValueError(mode)


def fdist_matvec_ref(x, y, v, coeffs, mode: str = "poly"):
    s = x.astype(jnp.float32)[:, None] + y.astype(jnp.float32)[None, :]
    m = f_eval(s, coeffs.astype(jnp.float32), mode)
    return (m @ v.astype(jnp.float32)).astype(v.dtype)
