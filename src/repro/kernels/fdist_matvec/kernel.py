"""Fused f-distance matvec Pallas kernel — the paper's core operation.

Computes out[i, :] = sum_j f(x_i + y_j) * V[j, :] WITHOUT materializing the
(a, b) matrix M = [f(x_i + y_j)] in HBM: each grid step builds one
(blk_a, blk_b) tile of M on the fly in VMEM from the 1-D distance vectors
and feeds it straight into the MXU. This is the TPU-native reading of the
paper's LDR insight — structure means "recompute cheaply instead of
storing" (DESIGN §3): HBM traffic drops from O(a*b) to O(a + b + b*d).

f families supported in-kernel (static `mode`):
  poly     — f(s) = sum_t coeffs[t] s^t            (Sec 3.2.1, 0-cordial)
  exp      — f(s) = coeffs[1] * exp(coeffs[0]*s)   (rank-1 family)
  expq     — f(s) = exp(u s^2 + v s + w)           (best ViT variant)
  rational — f(s) = 1 / (1 + coeffs[0] * s^2)      (mesh interpolation)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _f_tile(s, coeffs, mode: str):
    if mode == "poly":
        acc = jnp.zeros_like(s)
        for t in range(coeffs.shape[0] - 1, -1, -1):
            acc = acc * s + coeffs[t]
        return acc
    if mode == "exp":
        return coeffs[1] * jnp.exp(coeffs[0] * s)
    if mode == "expq":
        return jnp.exp(coeffs[0] * s * s + coeffs[1] * s + coeffs[2])
    if mode == "rational":
        return 1.0 / (1.0 + coeffs[0] * s * s)
    raise ValueError(mode)


def _fdist_kernel(x_ref, y_ref, v_ref, c_ref, o_ref, acc_ref, *,
                  mode: str, nb: int, j_axis: int = 1):
    """Shared body: `j_axis` is the grid axis that sweeps source blocks
    (1 for the single-job kernel, 2 when a leading batch axis is present)."""
    j = pl.program_id(j_axis)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    s = x_ref[...] + y_ref[...]  # (blk_a, 1) + (1, blk_b) -> (blk_a, blk_b)
    m = _f_tile(s, c_ref[...], mode)  # tile of M — exists only in VMEM
    acc_ref[...] += jnp.dot(m, v_ref[...], preferred_element_type=jnp.float32)

    @pl.when(j == nb - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("mode", "blk_a", "blk_b",
                                             "interpret"))
def fdist_matvec_batched_pallas(x, y, v, coeffs, *, mode: str = "poly",
                                blk_a: int = 128, blk_b: int = 128,
                                interpret: bool = False):
    """Batched fused f-distance matvec: one pallas_call over a whole bucket
    of IT cross jobs. x: (B, a), y: (B, b), v: (B, b, d) -> out (B, a, d).

    This is the kernel the plan executor's `pallas` backend feeds: each grid
    step (n, i, j) builds one (blk_a, blk_b) tile of M_n = [f(x_n,i + y_n,j)]
    in VMEM and accumulates M_n V_n without ever materializing M_n in HBM.
    Padded tail entries (x=y=0, v=0) contribute exactly zero.
    """
    B, a = x.shape
    b = y.shape[1]
    d = v.shape[2]
    blk_a = min(blk_a, a)
    blk_b = min(blk_b, b)
    pad_a = (-a) % blk_a
    pad_b = (-b) % blk_b
    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), (0, pad_a)))[:, :, None]
    yp = jnp.pad(y.astype(jnp.float32), ((0, 0), (0, pad_b)))[:, None, :]
    vp = jnp.pad(v.astype(jnp.float32), ((0, 0), (0, pad_b), (0, 0)))
    na = (a + pad_a) // blk_a
    nb = (b + pad_b) // blk_b
    out = pl.pallas_call(
        functools.partial(_fdist_kernel, mode=mode, nb=nb, j_axis=2),
        grid=(B, na, nb),
        in_specs=[
            pl.BlockSpec((None, blk_a, 1), lambda n, i, j: (n, i, 0)),
            pl.BlockSpec((None, 1, blk_b), lambda n, i, j: (n, 0, j)),
            pl.BlockSpec((None, blk_b, d), lambda n, i, j: (n, j, 0)),
            pl.BlockSpec((coeffs.shape[0],), lambda n, i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((None, blk_a, d), lambda n, i, j: (n, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, a + pad_a, d), v.dtype),
        scratch_shapes=[pltpu.VMEM((blk_a, d), jnp.float32)],
        interpret=interpret,
    )(xp, yp, vp, coeffs.astype(jnp.float32))
    return out[:, :a]


@functools.partial(jax.jit, static_argnames=("mode", "blk_a", "blk_b",
                                             "interpret"))
def fdist_matvec_pallas(x, y, v, coeffs, *, mode: str = "poly",
                        blk_a: int = 256, blk_b: int = 256,
                        interpret: bool = False):
    """x: (a,), y: (b,), v: (b, d), coeffs: (k,) -> out (a, d)."""
    a, b = x.shape[0], y.shape[0]
    d = v.shape[1]
    blk_a = min(blk_a, a)
    blk_b = min(blk_b, b)
    pad_a = (-a) % blk_a
    pad_b = (-b) % blk_b
    xp = jnp.pad(x.astype(jnp.float32), (0, pad_a)).reshape(-1, 1)
    yp = jnp.pad(y.astype(jnp.float32), (0, pad_b)).reshape(1, -1)
    vp = jnp.pad(v.astype(jnp.float32), ((0, pad_b), (0, 0)))
    na = (a + pad_a) // blk_a
    nb = (b + pad_b) // blk_b
    out = pl.pallas_call(
        functools.partial(_fdist_kernel, mode=mode, nb=nb),
        grid=(na, nb),
        in_specs=[
            pl.BlockSpec((blk_a, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, blk_b), lambda i, j: (0, j)),
            pl.BlockSpec((blk_b, d), lambda i, j: (j, 0)),
            pl.BlockSpec((coeffs.shape[0],), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((blk_a, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((a + pad_a, d), v.dtype),
        scratch_shapes=[pltpu.VMEM((blk_a, d), jnp.float32)],
        interpret=interpret,
    )(xp, yp, vp, coeffs.astype(jnp.float32))
    return out[:a]
