"""Jit'd public wrappers: pick the Pallas kernel on TPU, interpret-mode
(= Python execution of the same kernel body) elsewhere for validation."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.kernels.fdist_matvec.kernel import (fdist_matvec_batched_pallas,
                                               fdist_matvec_pallas)


def fdist_matvec(x, y, v, coeffs, mode: str = "poly", blk_a: int = 256,
                 blk_b: int = 256):
    on_tpu = jax.default_backend() == "tpu"
    return fdist_matvec_pallas(x, y, v, coeffs, mode=mode, blk_a=blk_a,
                               blk_b=blk_b, interpret=not on_tpu)


def fdist_matvec_batched(x, y, v, coeffs, mode: str = "poly",
                         blk_a: int = 128, blk_b: int = 128,
                         interpret: bool | None = None):
    """Bucketed form used by the plan executor: (B, a) x (B, b) x (B, b, d)
    -> (B, a, d). `interpret=None` auto-selects: compiled on TPU, interpreted
    elsewhere (bit-exact kernel semantics on CPU for tests/CI)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return fdist_matvec_batched_pallas(x, y, v, coeffs, mode=mode,
                                       blk_a=blk_a, blk_b=blk_b,
                                       interpret=interpret)


def fdist_matvec_batched_sharded(x, y, v, coeffs, *, mesh, axis=None,
                                 mode: str = "poly", blk_a: int = 128,
                                 blk_b: int = 128,
                                 interpret: bool | None = None):
    """`fdist_matvec_batched` under shard_map: the bucket (leaf-block) dim
    is split over the mesh's plan axis (`data` by default), each device
    running the same kernel on its B/D slab with no collectives — buckets
    are independent by construction. Ragged bucket counts are zero-padded
    to a multiple of the axis size (pad slabs produce rows that are sliced
    off). Exact: per-slab outputs are the single-device outputs."""
    from repro.launch import sharding

    axis = axis or sharding.plan_axis(mesh)
    D = mesh.shape[axis]
    B = x.shape[0]
    if D == 1:
        return fdist_matvec_batched(x, y, v, coeffs, mode=mode, blk_a=blk_a,
                                    blk_b=blk_b, interpret=interpret)
    pad = (-B) % D
    if pad:
        x, y, v = (jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
                   for a in (jnp.asarray(x), jnp.asarray(y), jnp.asarray(v)))

    def local(xl, yl, vl, cl):
        return fdist_matvec_batched(xl, yl, vl, cl, mode=mode, blk_a=blk_a,
                                    blk_b=blk_b, interpret=interpret)

    out = shard_map(local, mesh=mesh,
                    in_specs=(P(axis), P(axis), P(axis), P()),
                    out_specs=P(axis), check_rep=False)(x, y, v, coeffs)
    return out[:B]
