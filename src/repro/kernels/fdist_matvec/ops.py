"""Jit'd public wrappers: pick the Pallas kernel on TPU, interpret-mode
(= Python execution of the same kernel body) elsewhere for validation."""
from __future__ import annotations

import jax

from repro.kernels.fdist_matvec.kernel import (fdist_matvec_batched_pallas,
                                               fdist_matvec_pallas)


def fdist_matvec(x, y, v, coeffs, mode: str = "poly", blk_a: int = 256,
                 blk_b: int = 256):
    on_tpu = jax.default_backend() == "tpu"
    return fdist_matvec_pallas(x, y, v, coeffs, mode=mode, blk_a=blk_a,
                               blk_b=blk_b, interpret=not on_tpu)


def fdist_matvec_batched(x, y, v, coeffs, mode: str = "poly",
                         blk_a: int = 128, blk_b: int = 128,
                         interpret: bool | None = None):
    """Bucketed form used by the plan executor: (B, a) x (B, b) x (B, b, d)
    -> (B, a, d). `interpret=None` auto-selects: compiled on TPU, interpreted
    elsewhere (bit-exact kernel semantics on CPU for tests/CI)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return fdist_matvec_batched_pallas(x, y, v, coeffs, mode=mode,
                                       blk_a=blk_a, blk_b=blk_b,
                                       interpret=interpret)
