"""Jit'd public wrapper: picks the Pallas kernel on TPU, interpret-mode
(= Python execution of the same kernel body) elsewhere for validation."""
from __future__ import annotations

import jax

from repro.kernels.fdist_matvec.kernel import fdist_matvec_pallas
from repro.kernels.fdist_matvec.ref import fdist_matvec_ref


def fdist_matvec(x, y, v, coeffs, mode: str = "poly", blk_a: int = 256,
                 blk_b: int = 256):
    on_tpu = jax.default_backend() == "tpu"
    return fdist_matvec_pallas(x, y, v, coeffs, mode=mode, blk_a=blk_a,
                               blk_b=blk_b, interpret=not on_tpu)
