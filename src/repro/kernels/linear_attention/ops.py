from __future__ import annotations

import jax

from repro.kernels.linear_attention.kernel import linear_attention_pallas


def linear_attention(qf, kf, v, log_gamma, chunk: int = 256):
    on_tpu = jax.default_backend() == "tpu"
    return linear_attention_pallas(qf, kf, v, log_gamma, chunk=chunk,
                                   interpret=not on_tpu)
