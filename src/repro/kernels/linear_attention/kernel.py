"""Causal (gamma-decayed) linear attention Pallas kernel.

This is the hot path of the paper's Topological Performer for sequences:
masked linear attention with the separable g=exp mask gamma^(i-j) (and
gamma=1 = plain FAVOR+). Grid = (B*H, L chunks), chunk dim sequential; the
(m, hd) KV state and (m,) normalizer persist in VMEM scratch; within a chunk
the causal part is a masked (C, C) quadratic — the standard chunked-scan
linear-attention schedule, with the decay folded into the intra-chunk mask
and the state update (RetNet-style), matching models.attention's XLA path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams


def _lin_attn_kernel(q_ref, k_ref, v_ref, g_ref, num_ref, den_ref,
                     s_ref, z_ref, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)
        z_ref[...] = jnp.zeros_like(z_ref)

    lg = g_ref[0]  # log gamma (<= 0); block (None, 1) squeezes to (1,)
    q = q_ref[...].astype(jnp.float32)  # (C, m)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)  # (C, hd)
    i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    j = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    dmat = jnp.where(i >= j, jnp.exp(lg * (i - j).astype(jnp.float32)), 0.0)
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * dmat
    num_in = jnp.dot(scores, v, preferred_element_type=jnp.float32)
    den_in = jnp.sum(scores, axis=1)
    # inter-chunk: state decayed to each local position
    pos = jax.lax.broadcasted_iota(jnp.float32, (chunk, 1), 0)
    q_dec = q * jnp.exp(lg * pos)
    num_x = jnp.dot(q_dec, s_ref[...], preferred_element_type=jnp.float32)
    den_x = jnp.dot(q_dec, z_ref[...].reshape(-1, 1),
                    preferred_element_type=jnp.float32)[:, 0]
    num_ref[...] = (num_in + num_x).astype(num_ref.dtype)
    den_ref[...] = (den_in + den_x).reshape(1, -1).astype(den_ref.dtype)
    # update state: S' = gamma^C S + sum_t gamma^(C-t) k_t v_t^T
    k_dec = k * jnp.exp(lg * (chunk - pos))
    gC = jnp.exp(lg * chunk)
    s_ref[...] = gC * s_ref[...] + jnp.dot(k_dec.T, v,
                                           preferred_element_type=jnp.float32)
    z_ref[...] = gC * z_ref[...] + jnp.sum(k_dec, axis=0)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def linear_attention_pallas(qf, kf, v, log_gamma, *, chunk: int = 256,
                            interpret: bool = False):
    """qf/kf: (B, H, L, m); v: (B, H, L, hd); log_gamma: (H,) <= 0.
    Returns (num (B,H,L,hd), den (B,H,L))."""
    B, H, L, m = qf.shape
    hd = v.shape[-1]
    chunk = min(chunk, L)
    assert L % chunk == 0
    qr = qf.reshape(B * H, L, m)
    kr = kf.reshape(B * H, L, m)
    vr = v.reshape(B * H, L, hd)
    lg = jnp.broadcast_to(jnp.asarray(log_gamma, jnp.float32).reshape(1, -1),
                          (B, H)).reshape(B * H, 1)
    num, den = pl.pallas_call(
        functools.partial(_lin_attn_kernel, chunk=chunk),
        grid=(B * H, L // chunk),
        in_specs=[
            pl.BlockSpec((None, chunk, m), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, chunk, m), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, 1), lambda b, c: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, 1, chunk), lambda b, c: (b, 0, c)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, L, hd), jnp.float32),
            jax.ShapeDtypeStruct((B * H, 1, L), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((m, hd), jnp.float32),
                        pltpu.VMEM((m,), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr, lg)
    return num.reshape(B, H, L, hd), den.reshape(B, H, L)
