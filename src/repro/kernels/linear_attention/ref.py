"""Pure-jnp oracle: O(L^2) masked linear attention numerator/denominator."""
from __future__ import annotations

import jax.numpy as jnp


def linear_attention_ref(qf, kf, v, log_gamma):
    B, H, L, m = qf.shape
    i = jnp.arange(L)
    lg = jnp.asarray(log_gamma, jnp.float32).reshape(1, -1, 1, 1)
    mask = jnp.where(i[:, None] >= i[None, :],
                     jnp.exp(lg * (i[:, None] - i[None, :])), 0.0)
    scores = jnp.einsum("bhqm,bhkm->bhqk", qf.astype(jnp.float32),
                        kf.astype(jnp.float32)) * mask
    num = jnp.einsum("bhqk,bhkd->bhqd", scores, v.astype(jnp.float32))
    den = jnp.sum(scores, axis=-1)
    return num, den
