from repro.kernels.topo_linear_attention.ops import topo_linear_attention  # noqa: F401
from repro.kernels.topo_linear_attention.ref import topo_linear_attention_ref  # noqa: F401
