"""Public fused topological masked linear attention (paper Alg. 1).

`topo_linear_attention` computes the whole masked linear-attention step
out = (M ⊙ phi(Q)phi(K)^T) V / rowsum(M ⊙ phi(Q)phi(K)^T) for the sequence
mask M = [f(i-j)] (causal) or [f(|i-j|)] (bidirectional) in one fused pass
over chunks of L:

  * on TPU the Pallas kernel (kernel.py) runs compiled; the backward pass
    rides a custom VJP that differentiates the mathematically identical XLA
    twin below (same chunk schedule, same separable expansion), so the 3
    learnable mask scalars train end-to-end through the fused forward;
  * off-TPU the XLA twin is selected directly (the `_sdpa_chunked` precedent:
    a lax.scan chunked scan with identical math, exact to fp32 rounding) —
    the Pallas kernel remains exercisable anywhere via
    `use_kernel=True, interpret=True` (tests/CI).

Mask families (selected from `g` and the coefficient count, both paths):
  separable — g=exp, deg<=1: gamma^(i-j) relative-decay state (exact);
  rank      — any g / low-degree polynomial: on-the-fly rank-R Chebyshev
              separable expansion of f for the cross-chunk tail
              (core.masks.chebyshev_separable_tables), exact within-chunk
              tile — spectral accuracy for the paper's smooth masks.

Coefficients are per-head (H, t+1) (a synced (t+1,) vector broadcasts), i.e.
both synced and asynced mask parameterizations ride the same kernel.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import masks as MK
from repro.kernels.topo_linear_attention.kernel import (
    topo_attention_sweep_pallas)


class TopoSpec(NamedTuple):
    """Static (hashable) configuration threaded through the custom VJP."""
    g: str
    dist_scale: float
    causal: bool
    chunk: int
    rank: int
    eps: float
    interpret: bool


def _round_up(n: int, k: int) -> int:
    return ((n + k - 1) // k) * k


def _is_separable(g: str, coeffs) -> bool:
    return g == "exp" and coeffs.shape[-1] <= 2


def _prepare(spec: TopoSpec, coeffs, Lp: int):
    """Precompute the per-head mask ingredients for both sweep directions.

    Returns (lg, alpha, beta, dmat_inc, dmat_strict): `lg` (H,) for the
    separable decay mode (alpha/beta None), or rank-R position tables
    (H, Lp, R) with lg None. The dmats are the exact (H, C, C) within-chunk
    tiles (inclusive diagonal / strict). All pieces are differentiable in
    `coeffs`; in decay mode the e^{a0} mask factor is folded into kf by
    `_pad_inputs` (it cancels in the normalization except where the eps
    denominator clamp binds).
    """
    import numpy as np

    C = spec.chunk
    if _is_separable(spec.g, coeffs):
        H = coeffs.shape[0]
        lg = (coeffs[:, 1] * spec.dist_scale if coeffs.shape[-1] > 1
              else jnp.zeros((H,), jnp.float32))
        # within-chunk tile from gamma^(i-j) alone: a0 cancels in the
        # normalization and the cross-chunk state carries no a0 either
        d = np.arange(C)[:, None] - np.arange(C)[None, :]
        vals = jnp.exp(lg[:, None, None] * jnp.asarray(d, jnp.float32))
        dmat_inc = jnp.where(jnp.asarray(d >= 0), vals, 0.0)
        dmat_strict = jnp.where(jnp.asarray(d > 0), vals, 0.0)
        return lg, None, None, dmat_inc, dmat_strict
    alpha, beta = MK.chebyshev_separable_tables(
        spec.g, coeffs, Lp, spec.dist_scale, spec.rank)
    dmat_inc = MK.sequence_mask_matrix(spec.g, coeffs, C, spec.dist_scale)
    dmat_strict = MK.sequence_mask_matrix(spec.g, coeffs, C, spec.dist_scale,
                                          strict=True)
    return None, alpha, beta, dmat_inc, dmat_strict


def _pad_inputs(spec: TopoSpec, qf, kf, v, coeffs):
    L = qf.shape[2]
    Lp = _round_up(L, spec.chunk)
    pad = ((0, 0), (0, 0), (0, Lp - L), (0, 0))
    kf = kf.astype(jnp.float32)
    if _is_separable(spec.g, coeffs):
        # decay mode carries gamma^(i-j) only; fold the mask's e^{a0} factor
        # into kf so num/den match the other impls even where the eps
        # denominator clamp binds
        kf = kf * jnp.exp(coeffs[:, 0])[None, :, None, None]
    return (jnp.pad(qf.astype(jnp.float32), pad),
            jnp.pad(kf, pad),
            jnp.pad(v.astype(jnp.float32), pad), Lp)


def _flip(t):
    return jnp.flip(t, axis=2) if t is not None else None


def _pallas_forward(spec: TopoSpec, qf, kf, v, coeffs):
    """Fused forward: one sweep (causal) or two fused sweeps (bidirectional,
    the second combining + normalizing in-kernel via residual inputs)."""
    L = qf.shape[2]
    qp, kp, vp, Lp = _pad_inputs(spec, qf, kf, v, coeffs)
    lg, alpha, beta, dmat_inc, dmat_strict = _prepare(spec, coeffs, Lp)
    kw = dict(chunk=spec.chunk, eps=spec.eps, interpret=spec.interpret)
    if spec.causal:
        out = topo_attention_sweep_pallas(
            qp, kp, vp, dmat_inc, log_gamma=lg, alpha=alpha, beta=beta,
            normalize=True, **kw)
        return out[:, :, :L]
    num, den = topo_attention_sweep_pallas(
        qp, kp, vp, dmat_inc, log_gamma=lg, alpha=alpha, beta=beta,
        normalize=False, **kw)
    # Reversed strict sweep covers j > i; the forward partials ride in as
    # residuals so the combine + normalization stays in-kernel. The rank
    # tables are NOT flipped: the reversed sweep indexes row p' = Lp-1-p, and
    # alpha[Lp-1-i]·beta[Lp-1-j] ~= f((Lp-1-i) - (Lp-1-j)) = f(j - i) — the
    # correct (positive) anticausal distance. Flipping them along L would
    # evaluate f(i - j) instead and corrupt any odd-coefficient mask.
    out_rev = topo_attention_sweep_pallas(
        _flip(qp), _flip(kp), _flip(vp), dmat_strict, log_gamma=lg,
        alpha=alpha, beta=beta,
        res_num=_flip(num), res_den=jnp.flip(den, axis=2),
        normalize=True, **kw)
    return _flip(out_rev)[:, :, :L]


# ----------------------------------------------------------------------------
# XLA twin (lax.scan, identical chunk schedule) — CPU/GPU path and the
# differentiation surface of the fused kernel's custom VJP
# ----------------------------------------------------------------------------


def _sweep_xla(qp, kp, vp, dmat, lg=None, alpha=None, beta=None):
    """One causal sweep over chunks; returns (num, den) pre-normalization."""
    B, H, Lp, m = qp.shape
    hd = vp.shape[-1]
    C = dmat.shape[-1]
    nC = Lp // C
    qc = qp.reshape(B, H, nC, C, m).transpose(2, 0, 1, 3, 4)
    kc = kp.reshape(B, H, nC, C, m).transpose(2, 0, 1, 3, 4)
    vc = vp.reshape(B, H, nC, C, hd).transpose(2, 0, 1, 3, 4)
    if lg is not None:
        i = jnp.arange(C, dtype=jnp.float32)
        decq = jnp.exp(lg[:, None] * i[None, :])          # (H, C)
        deck = jnp.exp(lg[:, None] * (C - i[None, :]))
        gC = jnp.exp(lg * C)

        def step(carry, inp):
            S, z = carry  # (B,H,m,hd), (B,H,m)
            q, k, v = inp
            scores = jnp.einsum("bhim,bhjm->bhij", q, k) * dmat[None]
            num = jnp.einsum("bhij,bhjd->bhid", scores, v)
            den = jnp.sum(scores, axis=-1)
            qd = q * decq[None, :, :, None]
            num += jnp.einsum("bhim,bhmd->bhid", qd, S)
            den += jnp.einsum("bhim,bhm->bhi", qd, z)
            kd = k * deck[None, :, :, None]
            S = S * gC[None, :, None, None] + jnp.einsum(
                "bhjm,bhjd->bhmd", kd, v)
            z = z * gC[None, :, None] + jnp.sum(kd, axis=2)
            return (S, z), (num, den)

        carry0 = (jnp.zeros((B, H, m, hd), jnp.float32),
                  jnp.zeros((B, H, m), jnp.float32))
        xs = (qc, kc, vc)
    else:
        R = alpha.shape[-1]
        ac = alpha.reshape(H, nC, C, R).transpose(1, 0, 2, 3)
        bc = beta.reshape(H, nC, C, R).transpose(1, 0, 2, 3)

        def step(carry, inp):
            S, z = carry  # (B,H,R,m,hd), (B,H,R,m)
            q, k, v, a, b = inp
            scores = jnp.einsum("bhim,bhjm->bhij", q, k) * dmat[None]
            num = jnp.einsum("bhij,bhjd->bhid", scores, v)
            den = jnp.sum(scores, axis=-1)
            num += jnp.einsum("bhim,hir,bhrmd->bhid", q, a, S)
            den += jnp.einsum("bhim,hir,bhrm->bhi", q, a, z)
            S = S + jnp.einsum("bhjm,hjr,bhjd->bhrmd", k, b, v)
            z = z + jnp.einsum("bhjm,hjr->bhrm", k, b)
            return (S, z), (num, den)

        carry0 = (jnp.zeros((B, H, R, m, hd), jnp.float32),
                  jnp.zeros((B, H, R, m), jnp.float32))
        xs = (qc, kc, vc, ac, bc)
    _, (num, den) = jax.lax.scan(step, carry0, xs)
    num = num.transpose(1, 2, 0, 3, 4).reshape(B, H, Lp, hd)
    den = den.transpose(1, 2, 0, 3).reshape(B, H, Lp)
    return num, den


def _xla_forward(spec: TopoSpec, qf, kf, v, coeffs):
    L = qf.shape[2]
    qp, kp, vp, Lp = _pad_inputs(spec, qf, kf, v, coeffs)
    lg, alpha, beta, dmat_inc, dmat_strict = _prepare(spec, coeffs, Lp)
    num, den = _sweep_xla(qp, kp, vp, dmat_inc, lg, alpha, beta)
    if not spec.causal:
        # tables deliberately unflipped — see the comment in _pallas_forward
        nb, db = _sweep_xla(_flip(qp), _flip(kp), _flip(vp), dmat_strict,
                            lg, alpha, beta)
        num = num + _flip(nb)
        den = den + jnp.flip(db, axis=2)
    den = jnp.where(jnp.abs(den) < spec.eps, spec.eps, den)
    return (num / den[..., None])[:, :, :L]


# ----------------------------------------------------------------------------
# custom VJP: fused Pallas forward, XLA-twin backward
# ----------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _fused(spec, qf, kf, v, coeffs):
    return _pallas_forward(spec, qf, kf, v, coeffs)


def _fused_fwd(spec, qf, kf, v, coeffs):
    return _pallas_forward(spec, qf, kf, v, coeffs), (qf, kf, v, coeffs)


def _fused_bwd(spec, res, ct):
    qf, kf, v, coeffs = res
    _, vjp = jax.vjp(functools.partial(_xla_forward, spec), qf, kf, v, coeffs)
    return vjp(ct)


_fused.defvjp(_fused_fwd, _fused_bwd)


# ----------------------------------------------------------------------------
# public entry
# ----------------------------------------------------------------------------


def topo_linear_attention(qf, kf, v, coeffs, *, g: str = "exp",
                          dist_scale: float = 1.0, causal: bool = True,
                          chunk: int = 128, rank: int = 16,
                          eps: float = 1e-6, use_kernel: bool | None = None,
                          interpret: bool | None = None):
    """Fused Alg.-1 masked linear attention over the sequence mask.

    qf/kf: (B, H, L, m) nonneg phi features; v: (B, H, L, hd);
    coeffs: (H, t+1) or (t+1,) effective mask coefficients (already
    constraint-shaped, e.g. attention.topo_mask_coeffs). Any L (padded to a
    chunk multiple internally), any head count. Returns (B, H, L, hd) f32.

    use_kernel=None auto-selects the compiled Pallas kernel on TPU and the
    XLA twin elsewhere; use_kernel=True + interpret=True runs the kernel
    body in interpret mode anywhere (parity tests).
    """
    qf = jnp.asarray(qf)
    B, H, L, m = qf.shape
    coeffs = jnp.asarray(coeffs, jnp.float32)
    if coeffs.ndim == 1:
        coeffs = jnp.broadcast_to(coeffs[None], (H, coeffs.shape[0]))
    on_tpu = jax.default_backend() == "tpu"
    if use_kernel is None:
        use_kernel = on_tpu
    if interpret is None:
        interpret = not on_tpu
    C = min(chunk, _round_up(L, 8))
    spec = TopoSpec(g, float(dist_scale), bool(causal), C, int(rank),
                    float(eps), bool(interpret))
    if use_kernel:
        return _fused(spec, qf, kf, v, coeffs)
    return _xla_forward(spec, qf, kf, v, coeffs)


def topo_linear_attention_sharded(qf, kf, v, coeffs, *, mesh,
                                  batch_axis: str = "data",
                                  head_axis: str = "model", **kw):
    """`topo_linear_attention` under shard_map: batch over the mesh's data
    axis and heads over its model axis. Every (batch, head) pair's masked
    linear-attention sweep is independent — each device runs the identical
    fused sweep on its (B/d, H/m) slab with zero collectives, so the result
    is bit-identical to the single-device call. An axis whose extent does
    not divide the corresponding dim is dropped (that dim runs replicated),
    mirroring `launch.sharding.shard`'s divisibility fallback."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    qf = jnp.asarray(qf)
    B, H = qf.shape[0], qf.shape[1]
    coeffs = jnp.asarray(coeffs, jnp.float32)
    if coeffs.ndim == 1:
        coeffs = jnp.broadcast_to(coeffs[None], (H, coeffs.shape[0]))
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    ba = batch_axis if B % sizes.get(batch_axis, 1) == 0 else None
    ha = head_axis if H % sizes.get(head_axis, 1) == 0 else None
    if ba is None and ha is None:
        return topo_linear_attention(qf, kf, v, coeffs, **kw)

    def local(q, k, vv, c):
        return topo_linear_attention(q, k, vv, c, **kw)

    io = P(ba, ha)
    return shard_map(local, mesh=mesh, in_specs=(io, io, io, P(ha)),
                     out_specs=io, check_rep=False)(qf, kf, v, coeffs)
