"""Fused topological masked linear-attention Pallas kernel (paper Alg. 1).

One causal (prefix) sweep over chunks of L fuses the whole masked
linear-attention step for the sequence mask M = [f(i - j)]:

  * the phi-feature outer products k ⊗ v,
  * the masked prefix (lower-triangular Toeplitz) accumulation for both the
    numerator and the denominator,
  * and the normalized output num / den,

without ever materializing the (L, m*hd) expanded field the host-side
fft chunk-loop path streams through HBM. Grid = (B, H, L chunks) with the
chunk axis sequential; the running KV state and normalizer persist in VMEM
scratch across chunks.

Two state parameterizations (static `mode` of the sweep):
  decay — separable g=exp, deg<=1 masks gamma^(i-j): the state is decayed by
          gamma^C per chunk (RetNet-style relative decays — numerically safe
          for any L);
  rank  — general low-degree-polynomial masks via an on-the-fly rank-R
          separable expansion f(i-j) ~= sum_r alpha_r(i) beta_r(j)
          (Chebyshev tables from core.masks.chebyshev_separable_tables):
          the state carries R stacked (m, hd) moments.

Within-chunk the EXACT mask tile f(i-j) (precomputed (H, C, C) `dmat`, which
also encodes causal vs strict) is applied as a masked quadratic; only the
cross-chunk tail rides the separable state. Bidirectional masks compose two
sweeps (forward inclusive + reversed strict) — the second sweep takes the
first's num/den as residual inputs so the combine + normalization stays fused.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams


def _unpack(refs, n_in, normalize):
    ins, rest = refs[:n_in], refs[n_in:]
    if normalize:
        outs, scratch = rest[:1], rest[1:]
    else:
        outs, scratch = rest[:2], rest[2:]
    return ins, outs, scratch


def _emit(num, den, outs, combine, normalize, res, eps):
    if combine:
        rn, rd = res
        num = num + rn[...]
        den = den + rd[...][0]
    if normalize:
        (out_ref,) = outs
        den = jnp.where(jnp.abs(den) < eps, eps, den)
        out_ref[...] = (num / den[:, None]).astype(out_ref.dtype)
    else:
        num_ref, den_ref = outs
        num_ref[...] = num.astype(num_ref.dtype)
        den_ref[...] = den.reshape(1, -1).astype(den_ref.dtype)


def _decay_kernel(*refs, chunk: int, eps: float, combine: bool,
                  normalize: bool):
    n_in = 5 + (2 if combine else 0)
    ins, outs, (s_ref, z_ref) = _unpack(refs, n_in, normalize)
    dmat_ref, q_ref, k_ref, v_ref, g_ref = ins[:5]
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)
        z_ref[...] = jnp.zeros_like(z_ref)

    lg = g_ref[0]  # log gamma (<= 0)
    q = q_ref[...].astype(jnp.float32)  # (C, m)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)  # (C, hd)
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * dmat_ref[...]
    num = jnp.dot(scores, v, preferred_element_type=jnp.float32)
    den = jnp.sum(scores, axis=1)
    # inter-chunk: state decayed to each local position
    pos = jax.lax.broadcasted_iota(jnp.float32, (chunk, 1), 0)
    q_dec = q * jnp.exp(lg * pos)
    num += jnp.dot(q_dec, s_ref[...], preferred_element_type=jnp.float32)
    den += jnp.dot(q_dec, z_ref[...], preferred_element_type=jnp.float32)[:, 0]
    _emit(num, den, outs, combine, normalize, ins[5:], eps)
    # S' = gamma^C S + sum_t gamma^(C-t) k_t v_t^T
    k_dec = k * jnp.exp(lg * (chunk - pos))
    gC = jnp.exp(lg * chunk)
    s_ref[...] = gC * s_ref[...] + jnp.dot(k_dec.T, v,
                                           preferred_element_type=jnp.float32)
    z_ref[...] = gC * z_ref[...] + jnp.sum(k_dec, axis=0)[:, None]


def _rank_kernel(*refs, chunk: int, rank: int, eps: float, combine: bool,
                 normalize: bool):
    n_in = 6 + (2 if combine else 0)
    ins, outs, (s_ref, z_ref) = _unpack(refs, n_in, normalize)
    dmat_ref, q_ref, k_ref, v_ref, a_ref, b_ref = ins[:6]
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)
        z_ref[...] = jnp.zeros_like(z_ref)

    q = q_ref[...].astype(jnp.float32)  # (C, m)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)  # (C, hd)
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * dmat_ref[...]
    num = jnp.dot(scores, v, preferred_element_type=jnp.float32)
    den = jnp.sum(scores, axis=1)
    # inter-chunk: alpha-weighted read of the R stacked (m, hd) moments
    a = a_ref[...]  # (C, R) position table
    qa = jnp.concatenate([a[:, r:r + 1] * q for r in range(rank)], axis=1)
    num += jnp.dot(qa, s_ref[...], preferred_element_type=jnp.float32)
    den += jnp.dot(qa, z_ref[...], preferred_element_type=jnp.float32)[:, 0]
    _emit(num, den, outs, combine, normalize, ins[6:], eps)
    b = b_ref[...]  # (C, R)
    kb = jnp.concatenate([b[:, r:r + 1] * k for r in range(rank)], axis=1)
    s_ref[...] += jnp.dot(kb.T, v, preferred_element_type=jnp.float32)
    z_ref[...] += jnp.sum(kb, axis=0)[:, None]


@functools.partial(jax.jit,
                   static_argnames=("normalize", "chunk", "eps", "interpret"))
def topo_attention_sweep_pallas(qf, kf, v, dmat, *, log_gamma=None,
                                alpha=None, beta=None, res_num=None,
                                res_den=None, normalize: bool = True,
                                chunk: int = 128, eps: float = 1e-6,
                                interpret: bool = False):
    """One fused causal sweep. qf/kf: (B, H, L, m); v: (B, H, L, hd);
    dmat: (H, C, C) exact within-chunk mask tile (encodes causal/strict).

    Exactly one of `log_gamma` (H,) [decay mode] or `alpha`+`beta` (H, L, R)
    position tables [rank mode] selects the cross-chunk state. Optional
    res_num (B, H, L, hd) / res_den (B, H, L) are added before normalization
    (the bidirectional combine). L must be a multiple of `chunk` (ops pads).

    Returns out (B, H, L, hd) f32 if normalize, else (num, den (B, H, L)).
    """
    B, H, L, m = qf.shape
    hd = v.shape[-1]
    C = chunk
    assert L % C == 0, f"L={L} must be a multiple of chunk={C}"
    nC = L // C
    decay = log_gamma is not None
    assert decay != (alpha is not None), "pass log_gamma XOR alpha/beta"
    combine = res_num is not None

    q_spec = pl.BlockSpec((None, None, C, m), lambda b, h, c: (b, h, c, 0))
    v_spec = pl.BlockSpec((None, None, C, hd), lambda b, h, c: (b, h, c, 0))
    den_spec = pl.BlockSpec((None, None, 1, C), lambda b, h, c: (b, h, 0, c))
    in_specs = [pl.BlockSpec((None, C, C), lambda b, h, c: (h, 0, 0)),
                q_spec, q_spec, v_spec]
    inputs = [dmat.astype(jnp.float32), qf, kf, v]
    if decay:
        body = functools.partial(_decay_kernel, chunk=C, eps=eps,
                                 combine=combine, normalize=normalize)
        in_specs.append(pl.BlockSpec((None, 1), lambda b, h, c: (h, 0)))
        inputs.append(jnp.asarray(log_gamma, jnp.float32).reshape(H, 1))
        scratch = [pltpu.VMEM((m, hd), jnp.float32),
                   pltpu.VMEM((m, 1), jnp.float32)]
    else:
        R = alpha.shape[-1]
        body = functools.partial(_rank_kernel, chunk=C, rank=R, eps=eps,
                                 combine=combine, normalize=normalize)
        tab_spec = pl.BlockSpec((None, C, R), lambda b, h, c: (h, c, 0))
        in_specs += [tab_spec, tab_spec]
        inputs += [alpha.astype(jnp.float32), beta.astype(jnp.float32)]
        scratch = [pltpu.VMEM((R * m, hd), jnp.float32),
                   pltpu.VMEM((R * m, 1), jnp.float32)]
    if combine:
        in_specs += [v_spec, den_spec]
        inputs += [res_num.astype(jnp.float32),
                   res_den.astype(jnp.float32).reshape(B, H, 1, L)]
    if normalize:
        out_specs = [v_spec]
        out_shape = [jax.ShapeDtypeStruct((B, H, L, hd), jnp.float32)]
    else:
        out_specs = [v_spec, den_spec]
        out_shape = [jax.ShapeDtypeStruct((B, H, L, hd), jnp.float32),
                     jax.ShapeDtypeStruct((B, H, 1, L), jnp.float32)]
    got = pl.pallas_call(
        body,
        grid=(B, H, nC),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*inputs)
    if normalize:
        return got[0]
    return got[0], got[1].reshape(B, H, L)
