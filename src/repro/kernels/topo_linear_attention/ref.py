"""Pure-jnp oracle for topological masked linear attention (Alg. 1).

Materializes the full (H, L, L) sequence mask M = [f(dist(i, j))] and runs the
O(L^2) masked quadratic — exact for any g/degree, causal or bidirectional.
This is the parity standard every other impl (fft chunk-loop, fused
pallas/XLA) is tested against.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.masks import _poly_mask_eval


def sequence_topo_mask(g: str, coeffs, L: int, dist_scale: float = 1.0,
                       causal: bool = True):
    """Dense (..., L, L) mask f(i-j) (causal, zero above diagonal) or
    f(|i-j|) (bidirectional). coeffs: (..., t+1)."""
    idx = np.arange(L)
    d = idx[:, None] - idx[None, :]
    dist = d if causal else np.abs(d)
    vals = _poly_mask_eval(g, coeffs,
                           jnp.asarray(dist, jnp.float32) * dist_scale)
    if causal:
        vals = jnp.where(jnp.asarray(d >= 0), vals, 0.0)
    return vals


def topo_linear_attention_ref(qf, kf, v, coeffs, *, g: str = "exp",
                              dist_scale: float = 1.0, causal: bool = True,
                              eps: float = 1e-6):
    """qf/kf: (B, H, L, m) nonneg features; v: (B, H, L, hd);
    coeffs: (H, t+1) effective (post-constraint) mask coefficients.
    Returns the normalized attention output (B, H, L, hd) in float32."""
    L = qf.shape[-2]
    M = sequence_topo_mask(g, coeffs, L, dist_scale, causal)  # (H, L, L)
    scores = jnp.einsum("bhim,bhjm->bhij", qf.astype(jnp.float32),
                        kf.astype(jnp.float32)) * M[None]
    num = jnp.einsum("bhij,bhjd->bhid", scores, v.astype(jnp.float32))
    den = jnp.sum(scores, axis=-1)
    den = jnp.where(jnp.abs(den) < eps, eps, den)
    return num / den[..., None]
