"""Causal flash attention (forward) Pallas kernel.

Online-softmax tiling: grid = (batch*heads, num_q_blocks); each step streams
KV blocks through VMEM with running (max, sum, acc) statistics, so the
(L, L) score matrix never exists. For causal masking the KV loop stops at
the query block (work is triangular, not square).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, blk_q: int, blk_k: int,
                  scale: float, causal: bool, seq_len: int):
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale  # (blk_q, hd)
    hd = q.shape[-1]
    nk_total = seq_len // blk_k
    if causal:
        nk = jnp.minimum(((qi + 1) * blk_q + blk_k - 1) // blk_k, nk_total)
    else:
        nk = nk_total

    def body(kb, carry):
        m, l, acc = carry
        k = k_ref[pl.ds(kb * blk_k, blk_k), :].astype(jnp.float32)
        v = v_ref[pl.ds(kb * blk_k, blk_k), :].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        if causal:
            qpos = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32,
                                                         (blk_q, blk_k), 0)
            kpos = kb * blk_k + jax.lax.broadcasted_iota(jnp.int32,
                                                         (blk_q, blk_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((blk_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((blk_q,), jnp.float32)
    a0 = jnp.zeros((blk_q, hd), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nk, body, (m0, l0, a0))
    l = jnp.where(l == 0.0, 1.0, l)
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "blk_q", "blk_k",
                                             "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True, blk_q: int = 256,
                           blk_k: int = 256, interpret: bool = False):
    """q/k/v: (B, H, L, hd) -> (B, H, L, hd). L must divide by blocks."""
    B, H, L, hd = q.shape
    blk_q = min(blk_q, L)
    blk_k = min(blk_k, L)
    assert L % blk_q == 0 and L % blk_k == 0
    scale = 1.0 / math.sqrt(hd)
    qf = q.reshape(B * H, L, hd)
    kf = k.reshape(B * H, L, hd)
    vf = v.reshape(B * H, L, hd)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, blk_q=blk_q, blk_k=blk_k,
                          scale=scale, causal=causal, seq_len=L),
        grid=(B * H, L // blk_q),
        in_specs=[
            pl.BlockSpec((None, blk_q, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, L, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, L, hd), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, blk_q, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, L, hd), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, L, hd)
