"""Pure-jnp oracle for flash attention."""
from __future__ import annotations

import math

import jax.numpy as jnp


def attention_ref(q, k, v, causal: bool = True):
    B, H, L, hd = q.shape
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    if causal:
        i = jnp.arange(L)
        s = jnp.where(i[:, None] >= i[None, :], s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
