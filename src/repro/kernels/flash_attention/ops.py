from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas


def flash_attention(q, k, v, causal: bool = True):
    on_tpu = jax.default_backend() == "tpu"
    return flash_attention_pallas(q, k, v, causal=causal,
                                  interpret=not on_tpu)
