"""Mamba selective-scan Pallas kernel (chunked sequential grid).

Grid = (batch, d_inner blocks, L chunks) with the L dimension sequential:
the (blk_d, N) hidden state lives in VMEM scratch and persists across chunk
steps; each chunk walks its timesteps with a fori_loop. HBM traffic is the
inputs/outputs only — the (L, d, N) discretized tensors are built on the fly
per timestep in VMEM (the same "structure = recompute" move as fdist_matvec).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams


def _scan_kernel(u_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, h_ref, *,
                 chunk: int):
    li = pl.program_id(2)

    @pl.when(li == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = a_ref[...]  # (blk_d, N)
    D = d_ref[...]  # (1, blk_d)

    def step(t, h):
        u_t = u_ref[t, :].astype(jnp.float32)  # (blk_d,)
        dt_t = dt_ref[t, :].astype(jnp.float32)
        b_t = b_ref[t, :].astype(jnp.float32)  # (N,)
        c_t = c_ref[t, :].astype(jnp.float32)
        dA = jnp.exp(dt_t[:, None] * A)  # (blk_d, N)
        h = dA * h + (dt_t * u_t)[:, None] * b_t[None, :]
        y = jnp.sum(h * c_t[None, :], axis=-1) + u_t * D[0]
        y_ref[t, :] = y.astype(y_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, chunk, step, h_ref[...])


@functools.partial(jax.jit, static_argnames=("chunk", "blk_d", "interpret"))
def selective_scan_pallas(u, dt, A, B, C, D, *, chunk: int = 128,
                          blk_d: int = 512, interpret: bool = False):
    """u, dt: (Bt, L, din); A: (din, N); B, C: (Bt, L, N); D: (din,).
    Returns y: (Bt, L, din). L % chunk == 0, din % blk_d == 0 required."""
    Bt, L, din = u.shape
    N = A.shape[1]
    chunk = min(chunk, L)
    blk_d = min(blk_d, din)
    assert L % chunk == 0 and din % blk_d == 0
    grid = (Bt, din // blk_d, L // chunk)
    out = pl.pallas_call(
        functools.partial(_scan_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, chunk, blk_d), lambda b, dblk, l: (b, l, dblk)),
            pl.BlockSpec((None, chunk, blk_d), lambda b, dblk, l: (b, l, dblk)),
            pl.BlockSpec((blk_d, N), lambda b, dblk, l: (dblk, 0)),
            pl.BlockSpec((None, chunk, N), lambda b, dblk, l: (b, l, 0)),
            pl.BlockSpec((None, chunk, N), lambda b, dblk, l: (b, l, 0)),
            pl.BlockSpec((1, blk_d), lambda b, dblk, l: (0, dblk)),
        ],
        out_specs=pl.BlockSpec((None, chunk, blk_d),
                               lambda b, dblk, l: (b, l, dblk)),
        out_shape=jax.ShapeDtypeStruct((Bt, L, din), u.dtype),
        scratch_shapes=[pltpu.VMEM((blk_d, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(u, dt, A.astype(jnp.float32), B, C, D.reshape(1, -1).astype(jnp.float32))
    return out
