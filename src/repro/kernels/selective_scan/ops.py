from __future__ import annotations

import jax

from repro.kernels.selective_scan.kernel import selective_scan_pallas


def selective_scan(u, dt, A, B, C, D, chunk: int = 128, blk_d: int = 512):
    on_tpu = jax.default_backend() == "tpu"
    return selective_scan_pallas(u, dt, A, B, C, D, chunk=chunk, blk_d=blk_d,
                                 interpret=not on_tpu)
