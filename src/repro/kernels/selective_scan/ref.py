"""Pure-jnp oracle: sequential selective scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(u, dt, A, B, C, D):
    Bt, L, din = u.shape
    N = A.shape[1]

    def step(h, inp):
        u_t, dt_t, b_t, c_t = inp
        dA = jnp.exp(dt_t[..., None] * A[None])  # (Bt, din, N)
        h = dA * h + (dt_t * u_t)[..., None] * b_t[:, None, :]
        y = jnp.sum(h * c_t[:, None, :], axis=-1) + u_t * D[None]
        return h, y

    h0 = jnp.zeros((Bt, din, N), jnp.float32)
    xs = (u.swapaxes(0, 1).astype(jnp.float32),
          dt.swapaxes(0, 1).astype(jnp.float32),
          B.swapaxes(0, 1).astype(jnp.float32),
          C.swapaxes(0, 1).astype(jnp.float32))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1).astype(u.dtype)
