"""Roofline analysis from compiled dry-run artifacts (TPU v5e targets).

Terms (seconds), from the per-device SPMD program:
  compute    = HLO_flops / peak_flops          (197 TFLOP/s bf16 / chip)
  memory     = HLO_bytes_accessed / HBM_bw     (819 GB/s / chip)
  collective = collective operand bytes / ICI  (~50 GB/s / link)
collective bytes are parsed from the compiled HLO text (cost_analysis does
not report them): sum of operand sizes of all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute ops.
"""
from __future__ import annotations

import re

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

# result-shape form: %all-reduce.5 = bf16[16,512]{1,0} all-reduce(
# also matches tuple-result async starts: ... = (bf16[..], bf16[..]) all-gather-start(
_COLL_LINE_RE = re.compile(
    r"= *(\(?[a-z0-9, \[\]{}()]*?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_TENSOR_RE = re.compile(r"\b([a-z]?[a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _tensor_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))  # [num_groups, group_size]
    return 1


def _iter_collectives(hlo: str):
    """Yields (kind, operand_bytes) per collective instruction.

    Result shapes are parsed from the instruction's LHS (operand types are
    not printed in optimized HLO); operand size is reconstructed from the
    result and the replica-group size: all-gather operand = result/g,
    reduce-scatter operand = result*g, others operand = result. `-done` ops
    are skipped so async pairs are not double counted."""
    for line in hlo.splitlines():
        m = _COLL_LINE_RE.search(line)
        if not m:
            continue
        result_spec, kind = m.group(1), m.group(2)
        sizes = [_tensor_bytes(d, s) for d, s in _TENSOR_RE.findall(result_spec)]
        if not sizes:
            continue
        g = _group_size(line)
        is_start = bool(m.group(3)) and len(sizes) >= 2
        if is_start:
            # async start tuples carry (operand, result): the operand is the
            # smaller entry for all-gather, equal for all-reduce, larger for
            # reduce-scatter
            op_bytes = max(sizes) if kind == "reduce-scatter" else min(sizes)
        else:
            res_bytes = sum(sizes)
            if kind == "all-gather":
                op_bytes = res_bytes // max(g, 1)
            elif kind == "reduce-scatter":
                op_bytes = res_bytes * g
            else:
                op_bytes = res_bytes
        yield kind, op_bytes


def collective_bytes_from_hlo(hlo: str) -> float:
    """Sum of operand bytes over all collective ops (per-device program)."""
    return float(sum(b for _, b in _iter_collectives(hlo)))


def collective_breakdown(hlo: str) -> dict:
    per_kind: dict[str, float] = {}
    counts: dict[str, int] = {}
    for kind, b in _iter_collectives(hlo):
        per_kind[kind] = per_kind.get(kind, 0) + b
        counts[kind] = counts.get(kind, 0) + 1
    return {"bytes": per_kind, "counts": counts}


def count_params(cfg) -> tuple[int, int]:
    """(total, active) parameter counts straight from the config."""
    import jax
    from repro.models import api

    shapes = jax.eval_shape(lambda: api.init_params(cfg, jax.random.PRNGKey(0)))
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    total = sum(l.size for _, l in flat)
    inactive = 0
    for path, leaf in flat:
        spath = "/".join(str(getattr(p, "key", p)) for p in path)
        if "experts_w" in spath:
            frac_active = cfg.top_k / max(cfg.num_experts, 1)
            inactive += int(leaf.size * (1.0 - frac_active))
    return total, total - inactive


def model_flops(cfg, shape: dict) -> float:
    """Ideal matmul flops: 6·N·tokens (train) / 2·N·tokens (inference),
    charging each parameter group for the tokens that actually flow through
    it: embedding lookups are free; the LM head runs per *logit* position
    (all tokens in training, one per sequence at prefill/decode); encoder
    params see src frames and only when the encoder runs."""
    import jax
    from repro.models import api

    B, L, kind = shape["global_batch"], shape["seq_len"], shape["kind"]
    mult = 6.0 if kind == "train" else 2.0
    shapes = jax.eval_shape(lambda: api.init_params(cfg, jax.random.PRNGKey(0)))
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    enc = head = embed = body = 0
    frac_active = cfg.top_k / max(cfg.num_experts, 1) if cfg.moe else 1.0
    for path, leaf in flat:
        spath = "/".join(str(getattr(p, "key", p)) for p in path)
        if "blocks_enc" in spath or "frontend_proj" in spath:
            enc += leaf.size
        elif "lm_head" in spath:
            head += leaf.size
        elif spath.startswith("embed"):
            embed += leaf.size
        elif "experts_w" in spath:
            body += int(leaf.size * frac_active)
        else:
            body += leaf.size
    if cfg.tie_embeddings:
        head = embed  # tied: the unembed matmul reuses the table
    tokens = B * (L if kind != "decode" else 1)
    logit_pos = B * L if kind == "train" else B
    total = mult * body * tokens + mult * head * logit_pos
    if cfg.is_encdec and kind != "decode":
        total += mult * enc * B * cfg.max_source_len
    return float(total)


def roofline_terms(rec: dict, cfg, shape: dict, n_chips: int) -> dict:
    compute_s = rec["flops"] / PEAK_FLOPS
    memory_s = rec["bytes_accessed"] / HBM_BW
    collective_s = rec["collective_bytes"] / ICI_BW
    dominant = max(
        [("compute", compute_s), ("memory", memory_s),
         ("collective", collective_s)], key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, shape)
    hlo_total = rec["flops"] * n_chips
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops": mf,
        "useful_flops_ratio": (mf / hlo_total) if hlo_total else 0.0,
        "roofline_bound_s": max(compute_s, memory_s, collective_s),
    }
