"""Graph substrate: weighted graphs/trees, MST, traversals, mesh generators."""
from repro.graphs.graph import Forest, Graph, WeightedTree  # noqa: F401
from repro.graphs.mst import minimum_spanning_tree  # noqa: F401
from repro.graphs.traverse import (  # noqa: F401
    TreeLCA,
    tree_distances_from,
    tree_pair_distances,
    tree_all_pairs,
    dijkstra,
    graph_all_pairs,
)
