"""Traversals: tree distances (single-source, sampled-pair, all-pairs), Dijkstra.

Host-side numpy. Tree single-source is O(N); sampled pairs use binary-lifting
LCA (O(N log N) build, O(log N)/query); all-pairs is the BTFI/oracle path,
O(N^2) time and memory, computed row-blocked with the Euler-interval update
  dist(v, u) = dist(parent(v), u) ± w(v, parent)
(minus inside subtree(v), plus outside) — used only for validation and the
brute-force baselines the paper compares against.
"""
from __future__ import annotations

import heapq

import numpy as np

from repro.graphs.graph import Graph, WeightedTree


def tree_bfs_order(tree: WeightedTree, root: int = 0):
    """DFS preorder from root. Returns (order, parent, parent_w)."""
    indptr, indices, data = tree.csr()
    n = tree.num_vertices
    parent = -np.ones(n, dtype=np.int64)
    parent_w = np.zeros(n, dtype=np.float64)
    order = np.empty(n, dtype=np.int64)
    visited = np.zeros(n, dtype=bool)
    stack = [root]
    visited[root] = True
    k = 0
    while stack:
        u = stack.pop()
        order[k] = u
        k += 1
        for ei in range(indptr[u], indptr[u + 1]):
            v = indices[ei]
            if not visited[v]:
                visited[v] = True
                parent[v] = u
                parent_w[v] = data[ei]
                stack.append(v)
    if k != n:
        raise ValueError("tree is disconnected")
    return order, parent, parent_w


def tree_distances_from(tree: WeightedTree, source: int) -> np.ndarray:
    """Shortest-path distances from `source` to every vertex (O(N))."""
    order, parent, parent_w = tree_bfs_order(tree, source)
    dist = np.zeros(tree.num_vertices, dtype=np.float64)
    for u in order[1:]:
        dist[u] = dist[parent[u]] + parent_w[u]
    return dist


class TreeLCA:
    """Binary-lifting LCA with O(N log N) build; batched O(log N) queries."""

    def __init__(self, tree: WeightedTree, root: int = 0):
        n = tree.num_vertices
        order, parent, parent_w = tree_bfs_order(tree, root)
        self.d_root = np.zeros(n, dtype=np.float64)
        self.depth = np.zeros(n, dtype=np.int64)
        for u in order[1:]:
            self.d_root[u] = self.d_root[parent[u]] + parent_w[u]
            self.depth[u] = self.depth[parent[u]] + 1
        LOG = max(1, int(np.ceil(np.log2(max(n, 2)))) + 1)
        up = np.zeros((LOG, n), dtype=np.int64)
        up[0] = np.where(parent < 0, np.arange(n), parent)
        for k in range(1, LOG):
            up[k] = up[k - 1][up[k - 1]]
        self.up = up

    def lca(self, u: np.ndarray, v: np.ndarray) -> np.ndarray:
        u = np.asarray(u, dtype=np.int64).copy()
        v = np.asarray(v, dtype=np.int64).copy()
        up, depth = self.up, self.depth
        swap = depth[u] < depth[v]
        u[swap], v[swap] = v[swap], u[swap]
        diff = depth[u] - depth[v]
        for k in range(up.shape[0]):
            sel = ((diff >> k) & 1) == 1
            u[sel] = up[k][u[sel]]
        same = u == v
        for k in range(up.shape[0] - 1, -1, -1):
            differs = ~same & (up[k][u] != up[k][v])
            u[differs] = up[k][u[differs]]
            v[differs] = up[k][v[differs]]
        return np.where(same, u, up[0][u])

    def distance(self, u: np.ndarray, v: np.ndarray) -> np.ndarray:
        a = self.lca(u, v)
        return self.d_root[u] + self.d_root[v] - 2.0 * self.d_root[a]


def tree_pair_distances(tree: WeightedTree, us: np.ndarray, vs: np.ndarray):
    """Distances for sampled vertex pairs (Sec 4.3 training data)."""
    return TreeLCA(tree).distance(us, vs)


def _euler_intervals(tree: WeightedTree, root: int = 0):
    """Returns (euler_pos, tin, tout, order, parent, parent_w): vertex v's
    subtree occupies euler positions [tin[v], tout[v])."""
    indptr, indices, data = tree.csr()
    n = tree.num_vertices
    parent = -np.ones(n, dtype=np.int64)
    parent_w = np.zeros(n, dtype=np.float64)
    tin = np.zeros(n, dtype=np.int64)
    tout = np.zeros(n, dtype=np.int64)
    order = np.empty(n, dtype=np.int64)
    visited = np.zeros(n, dtype=bool)
    # iterative DFS with explicit post-processing for tout
    stack = [(root, False)]
    visited[root] = True
    t = 0
    k = 0
    while stack:
        u, processed = stack.pop()
        if processed:
            tout[u] = t
            continue
        tin[u] = t
        t += 1
        order[k] = u
        k += 1
        stack.append((u, True))
        for ei in range(indptr[u], indptr[u + 1]):
            v = indices[ei]
            if not visited[v]:
                visited[v] = True
                parent[v] = u
                parent_w[v] = data[ei]
                stack.append((v, False))
    euler_pos = tin  # each vertex appears once at position tin
    return euler_pos, tin, tout, order, parent, parent_w


def tree_all_pairs(tree: WeightedTree, dtype=np.float64) -> np.ndarray:
    """All-pairs tree distances (O(N^2)); the BTFI preprocessing oracle."""
    n = tree.num_vertices
    euler_pos, tin, tout, order, parent, parent_w = _euler_intervals(tree)
    dist_e = np.zeros((n, n), dtype=dtype)  # rows: vertex id, cols: euler order
    root = order[0]
    # root row: distances from root, laid out in euler order
    d_root = np.zeros(n, dtype=np.float64)
    for u in order[1:]:
        d_root[u] = d_root[parent[u]] + parent_w[u]
    row = np.empty(n, dtype=dtype)
    row[euler_pos] = d_root.astype(dtype)
    dist_e[root] = row
    for u in order[1:]:
        w = dtype(parent_w[u])
        r = dist_e[parent[u]] + w
        r[tin[u]:tout[u]] -= dtype(2.0) * w
        dist_e[u] = r
    # un-permute columns back to vertex ids: out[u, v] = dist_e[u, euler_pos[v]]
    return dist_e[:, euler_pos]


def dijkstra(g: Graph, source: int) -> np.ndarray:
    """Single-source shortest paths on a weighted graph (binary heap)."""
    indptr, indices, data = g.csr()
    n = g.num_vertices
    dist = np.full(n, np.inf)
    dist[source] = 0.0
    done = np.zeros(n, dtype=bool)
    heap = [(0.0, source)]
    while heap:
        d, u = heapq.heappop(heap)
        if done[u]:
            continue
        done[u] = True
        for ei in range(indptr[u], indptr[u + 1]):
            v = indices[ei]
            nd = d + data[ei]
            if nd < dist[v]:
                dist[v] = nd
                heapq.heappush(heap, (nd, v))
    return dist


def graph_all_pairs(g: Graph) -> np.ndarray:
    """All-pairs shortest paths (N Dijkstra runs) — baseline/oracle only."""
    return np.stack([dijkstra(g, s) for s in range(g.num_vertices)])
