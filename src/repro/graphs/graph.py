"""Weighted undirected graphs and trees (host-side numpy; no jax here).

All heavy per-field computation happens in JAX; graph *construction* and
decomposition are host-side preprocessing (built once per topology, reused for
any number of tensor fields — matching the paper's IT amortization argument).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Graph:
    """Undirected weighted graph in COO form with a CSR adjacency view."""

    num_vertices: int
    edges_u: np.ndarray  # (E,) int32
    edges_v: np.ndarray  # (E,) int32
    weights: np.ndarray  # (E,) float64, positive

    # CSR adjacency (built lazily)
    _indptr: np.ndarray | None = None
    _indices: np.ndarray | None = None
    _data: np.ndarray | None = None

    def __post_init__(self):
        self.edges_u = np.asarray(self.edges_u, dtype=np.int32)
        self.edges_v = np.asarray(self.edges_v, dtype=np.int32)
        self.weights = np.asarray(self.weights, dtype=np.float64)
        if self.weights.size and self.weights.min() <= 0:
            raise ValueError("edge weights must be positive")

    @property
    def num_edges(self) -> int:
        return int(self.edges_u.shape[0])

    def csr(self):
        """Symmetric CSR adjacency: (indptr, indices, data)."""
        if self._indptr is None:
            n = self.num_vertices
            u = np.concatenate([self.edges_u, self.edges_v])
            v = np.concatenate([self.edges_v, self.edges_u])
            w = np.concatenate([self.weights, self.weights])
            order = np.argsort(u, kind="stable")
            u, v, w = u[order], v[order], w[order]
            indptr = np.zeros(n + 1, dtype=np.int64)
            np.add.at(indptr, u + 1, 1)
            np.cumsum(indptr, out=indptr)
            self._indptr, self._indices, self._data = indptr, v, w
        return self._indptr, self._indices, self._data


class WeightedTree(Graph):
    """A connected acyclic Graph (N-1 edges). Construction validates tree-ness."""

    def __post_init__(self):
        super().__post_init__()
        if self.num_edges != self.num_vertices - 1:
            raise ValueError(
                f"tree must have N-1 edges, got {self.num_edges} for N={self.num_vertices}"
            )

    def induced_subtree(self, vertex_ids: np.ndarray) -> tuple["WeightedTree", np.ndarray]:
        """Sub-tree induced by `vertex_ids` (must be connected in the tree).

        Returns (subtree with local ids 0..k-1, local->global id map).
        """
        vertex_ids = np.asarray(vertex_ids, dtype=np.int32)
        glob_to_loc = -np.ones(self.num_vertices, dtype=np.int32)
        glob_to_loc[vertex_ids] = np.arange(vertex_ids.size, dtype=np.int32)
        mask = (glob_to_loc[self.edges_u] >= 0) & (glob_to_loc[self.edges_v] >= 0)
        sub = WeightedTree(
            num_vertices=int(vertex_ids.size),
            edges_u=glob_to_loc[self.edges_u[mask]],
            edges_v=glob_to_loc[self.edges_v[mask]],
            weights=self.weights[mask],
        )
        return sub, vertex_ids


class Forest:
    """An ordered collection of `WeightedTree`s integrated as ONE unit.

    The packed-field layout is the concatenation of the per-tree vertex
    spaces: vertex v of tree t lives at global row `offsets[t] + v`, so a
    packed field has shape (sum_t n_t, d) and a forest integration is a
    block-diagonal multiply — every tree's M_f applied to its own rows, with
    zero cross-tree coupling. `compile_forest_plan` (repro.core.integrate)
    compiles the whole forest into one fused IntegrationPlan;
    `Integrator.from_forest` is the public entry point.
    """

    def __init__(self, trees):
        trees = list(trees)
        if not trees:
            raise ValueError("Forest needs at least one tree")
        for t in trees:
            if not isinstance(t, WeightedTree):
                raise TypeError(
                    f"Forest members must be WeightedTree, got {type(t).__name__}")
        self.trees = trees
        sizes = np.array([t.num_vertices for t in trees], dtype=np.int64)
        self.offsets = np.zeros(sizes.size + 1, dtype=np.int64)
        np.cumsum(sizes, out=self.offsets[1:])

    @property
    def num_trees(self) -> int:
        return len(self.trees)

    @property
    def num_vertices(self) -> int:
        """Total vertices across the forest (the packed-field length)."""
        return int(self.offsets[-1])

    @property
    def tree_sizes(self) -> np.ndarray:
        return np.diff(self.offsets)

    def pack(self, fields) -> np.ndarray:
        """Stack per-tree fields [(n_t, ...)] into one packed (N, ...) field."""
        fields = [np.asarray(f) for f in fields]
        if len(fields) != self.num_trees:
            raise ValueError(
                f"expected {self.num_trees} fields, got {len(fields)}")
        for t, f in enumerate(fields):
            if f.shape[0] != int(self.offsets[t + 1] - self.offsets[t]):
                raise ValueError(
                    f"field {t}: {f.shape[0]} rows != tree size "
                    f"{int(self.offsets[t + 1] - self.offsets[t])}")
        return np.concatenate(fields, axis=0)

    def unpack(self, X) -> list:
        """Split a packed (N, ...) array into per-tree views [(n_t, ...)]."""
        X = np.asarray(X)
        if X.shape[0] != self.num_vertices:
            raise ValueError(
                f"packed field has {X.shape[0]} rows, forest has "
                f"{self.num_vertices} vertices")
        return [X[self.offsets[t]:self.offsets[t + 1]]
                for t in range(self.num_trees)]

    def broadcast(self, per_tree) -> np.ndarray:
        """Broadcast per-tree coefficients (K,) or (K, d) to per-vertex rows
        (N,) / (N, d) of the packed layout — e.g. FRT averaging weights or
        per-request mask scales applied to a packed field/output."""
        per_tree = np.asarray(per_tree)
        if per_tree.shape[0] != self.num_trees:
            raise ValueError(
                f"expected leading dim {self.num_trees}, got {per_tree.shape}")
        return np.repeat(per_tree, self.tree_sizes, axis=0)

    def __repr__(self):
        return (f"Forest(num_trees={self.num_trees}, "
                f"num_vertices={self.num_vertices})")


# ----------------------------------------------------------------------------
# Generators (procedural substitutes for the paper's datasets; see DESIGN §7)
# ----------------------------------------------------------------------------

def path_graph(n: int, weights: np.ndarray | None = None) -> WeightedTree:
    w = np.ones(n - 1) if weights is None else np.asarray(weights, dtype=np.float64)
    return WeightedTree(n, np.arange(n - 1), np.arange(1, n), w)


def random_tree(n: int, seed: int = 0, weight_range=(0.1, 1.0)) -> WeightedTree:
    """Uniform random attachment tree with random weights."""
    rng = np.random.default_rng(seed)
    parents = np.array([rng.integers(0, i) for i in range(1, n)], dtype=np.int32)
    w = rng.uniform(*weight_range, size=n - 1)
    return WeightedTree(n, parents, np.arange(1, n, dtype=np.int32), w)


def caterpillar_tree(n: int, seed: int = 0) -> WeightedTree:
    """Path spine with leaves — adversarial for naive separators."""
    rng = np.random.default_rng(seed)
    spine = n // 2
    u = list(range(spine - 1))
    v = list(range(1, spine))
    for leaf in range(spine, n):
        u.append(int(rng.integers(0, spine)))
        v.append(leaf)
    w = rng.uniform(0.1, 1.0, size=n - 1)
    return WeightedTree(n, np.array(u), np.array(v), w)


def star_tree(n: int, seed: int = 0) -> WeightedTree:
    rng = np.random.default_rng(seed)
    return WeightedTree(
        n, np.zeros(n - 1, dtype=np.int32), np.arange(1, n, dtype=np.int32),
        rng.uniform(0.1, 1.0, size=n - 1),
    )


def synthetic_graph(n: int, extra_edges: int, seed: int = 0,
                    weight_range=(0.1, 1.0)) -> Graph:
    """Paper Sec 4.1: path graph + random extra edges with random weights."""
    rng = np.random.default_rng(seed)
    u = list(range(n - 1))
    v = list(range(1, n))
    seen = set(zip(u, v))
    added = 0
    while added < extra_edges:
        a, b = int(rng.integers(0, n)), int(rng.integers(0, n))
        if a == b:
            continue
        a, b = min(a, b), max(a, b)
        if (a, b) in seen:
            continue
        seen.add((a, b))
        u.append(a)
        v.append(b)
        added += 1
    w = rng.uniform(*weight_range, size=len(u))
    return Graph(n, np.array(u), np.array(v), w)


def grid_graph(rows: int, cols: int, seed: int | None = None) -> Graph:
    """2D grid graph (the TopoViT image-patch encoding). Unit or jittered weights."""
    idx = np.arange(rows * cols).reshape(rows, cols)
    u = np.concatenate([idx[:, :-1].ravel(), idx[:-1, :].ravel()])
    v = np.concatenate([idx[:, 1:].ravel(), idx[1:, :].ravel()])
    if seed is None:
        w = np.ones(u.size)
    else:
        w = np.random.default_rng(seed).uniform(0.5, 1.5, size=u.size)
    return Graph(rows * cols, u, v, w)


def random_graph_family(kind: str, n: int, seed: int) -> Graph:
    """Graph-classification families (substitute for TUDatasets; DESIGN §7).

    Three structurally distinct families whose f-distance spectra differ:
      'ring_lattice'  — Watts-Strogatz-like ring with shortcuts
      'pref_attach'   — Barabasi-Albert-like preferential attachment
      'community'     — two dense communities with a sparse bridge
    """
    rng = np.random.default_rng(seed)
    if kind == "ring_lattice":
        u = list(range(n)) + list(range(n))
        v = [(i + 1) % n for i in range(n)] + [(i + 2) % n for i in range(n)]
        nshort = max(1, n // 10)
        for _ in range(nshort):
            a, b = rng.integers(0, n, size=2)
            if a != b:
                u.append(int(a)); v.append(int(b))
    elif kind == "pref_attach":
        u, v = [0], [1]
        degree = [1, 1]
        for newv in range(2, n):
            for _ in range(2):
                probs = np.array(degree) / sum(degree)
                t = int(rng.choice(newv, p=probs))
                u.append(t); v.append(newv)
                degree[t] += 1
            degree.append(2)
    elif kind == "community":
        half = n // 2
        u, v = [], []
        for comm in (range(half), range(half, n)):
            comm = list(comm)
            for i in comm:
                for _ in range(3):
                    j = int(rng.choice(comm))
                    if i != j:
                        u.append(i); v.append(j)
        u.append(0); v.append(half)  # bridge
        # ensure connectivity inside communities via a spine
        u += list(range(n - 1)); v += list(range(1, n))
    else:
        raise ValueError(kind)
    # dedupe
    uu, vv = np.minimum(u, v), np.maximum(u, v)
    pairs = np.unique(np.stack([uu, vv], 1), axis=0)
    pairs = pairs[pairs[:, 0] != pairs[:, 1]]
    w = rng.uniform(0.5, 1.5, size=pairs.shape[0])
    return Graph(n, pairs[:, 0], pairs[:, 1], w)
