"""Procedural 3D meshes + vertex normals (Thingi10K substitute; DESIGN §7)."""
from __future__ import annotations

import numpy as np

from repro.graphs.graph import Graph


def icosphere(subdivisions: int = 3) -> tuple[np.ndarray, np.ndarray]:
    """Returns (vertices (V,3), faces (F,3)) of a unit icosphere."""
    t = (1.0 + np.sqrt(5.0)) / 2.0
    verts = np.array(
        [
            [-1, t, 0], [1, t, 0], [-1, -t, 0], [1, -t, 0],
            [0, -1, t], [0, 1, t], [0, -1, -t], [0, 1, -t],
            [t, 0, -1], [t, 0, 1], [-t, 0, -1], [-t, 0, 1],
        ],
        dtype=np.float64,
    )
    verts /= np.linalg.norm(verts, axis=1, keepdims=True)
    faces = np.array(
        [
            [0, 11, 5], [0, 5, 1], [0, 1, 7], [0, 7, 10], [0, 10, 11],
            [1, 5, 9], [5, 11, 4], [11, 10, 2], [10, 7, 6], [7, 1, 8],
            [3, 9, 4], [3, 4, 2], [3, 2, 6], [3, 6, 8], [3, 8, 9],
            [4, 9, 5], [2, 4, 11], [6, 2, 10], [8, 6, 7], [9, 8, 1],
        ],
        dtype=np.int64,
    )
    for _ in range(subdivisions):
        verts, faces = _subdivide(verts, faces)
        verts /= np.linalg.norm(verts, axis=1, keepdims=True)
    return verts, faces


def _subdivide(verts, faces):
    edge_mid: dict[tuple[int, int], int] = {}
    new_verts = list(verts)

    def midpoint(a, b):
        key = (min(a, b), max(a, b))
        if key not in edge_mid:
            edge_mid[key] = len(new_verts)
            new_verts.append((verts[a] + verts[b]) / 2.0)
        return edge_mid[key]

    new_faces = []
    for a, b, c in faces:
        ab, bc, ca = midpoint(a, b), midpoint(b, c), midpoint(c, a)
        new_faces += [[a, ab, ca], [b, bc, ab], [c, ca, bc], [ab, bc, ca]]
    return np.array(new_verts), np.array(new_faces, dtype=np.int64)


def torus_mesh(major_n: int = 48, minor_n: int = 24, R: float = 1.0,
               r: float = 0.35) -> tuple[np.ndarray, np.ndarray]:
    """Parametric torus triangulation."""
    us = np.linspace(0, 2 * np.pi, major_n, endpoint=False)
    vs = np.linspace(0, 2 * np.pi, minor_n, endpoint=False)
    uu, vv = np.meshgrid(us, vs, indexing="ij")
    x = (R + r * np.cos(vv)) * np.cos(uu)
    y = (R + r * np.cos(vv)) * np.sin(uu)
    z = r * np.sin(vv)
    verts = np.stack([x.ravel(), y.ravel(), z.ravel()], axis=1)
    faces = []
    for i in range(major_n):
        for j in range(minor_n):
            a = i * minor_n + j
            b = ((i + 1) % major_n) * minor_n + j
            c = i * minor_n + (j + 1) % minor_n
            d = ((i + 1) % major_n) * minor_n + (j + 1) % minor_n
            faces += [[a, b, c], [b, d, c]]
    return verts, np.array(faces, dtype=np.int64)


def vertex_normals(verts: np.ndarray, faces: np.ndarray) -> np.ndarray:
    """Area-weighted vertex normals from face normals."""
    fn = np.cross(verts[faces[:, 1]] - verts[faces[:, 0]],
                  verts[faces[:, 2]] - verts[faces[:, 0]])
    vn = np.zeros_like(verts)
    for k in range(3):
        np.add.at(vn, faces[:, k], fn)
    norms = np.linalg.norm(vn, axis=1, keepdims=True)
    norms[norms == 0] = 1.0
    return vn / norms


def mesh_graph(verts: np.ndarray, faces: np.ndarray) -> Graph:
    """Edge graph of a triangle mesh; weights = Euclidean edge lengths."""
    e = np.concatenate([faces[:, [0, 1]], faces[:, [1, 2]], faces[:, [2, 0]]])
    e = np.sort(e, axis=1)
    e = np.unique(e, axis=0)
    w = np.linalg.norm(verts[e[:, 0]] - verts[e[:, 1]], axis=1)
    w = np.maximum(w, 1e-9)
    return Graph(verts.shape[0], e[:, 0], e[:, 1], w)
