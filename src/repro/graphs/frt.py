"""FRT trees (Fakcharoenphol–Rao–Talwar 2004): randomized O(log n)-distortion
hierarchically-separated tree embeddings — the paper's Fig-4 baseline.

The HST's leaves are the graph vertices; internal nodes are cluster ids.
Returned as a WeightedTree over (n_leaves + n_internal) vertices with
`leaf_ids` mapping graph vertex -> tree vertex, so FTFI runs on it directly
(field zero on internal nodes).
"""
from __future__ import annotations

import numpy as np

from repro.graphs.graph import Graph, WeightedTree
from repro.graphs.traverse import graph_all_pairs


def frt_tree(g: Graph, seed: int = 0):
    """Returns (tree, leaf_ids) — leaf_ids[v] is the tree vertex of graph
    vertex v (identity: leaves occupy ids 0..n-1)."""
    rng = np.random.default_rng(seed)
    D = graph_all_pairs(g)
    n = g.num_vertices
    diam = float(D.max())
    beta = float(rng.uniform(1.0, 2.0))
    perm = rng.permutation(n)

    # levels: delta_i = beta * 2^i ; top level has one cluster of radius >= diam
    top = 0
    while beta * (2.0 ** top) < diam:
        top += 1

    edges_u, edges_v, weights = [], [], []
    next_id = n  # internal node ids start after the leaves

    def build(members: np.ndarray, level: int) -> int:
        """Returns the tree node id representing this cluster."""
        nonlocal next_id
        if members.size == 1:
            return int(members[0])
        if level < -60:  # duplicate points (zero distance): numeric guard
            root = int(members[0])
            for m in members[1:]:
                edges_u.append(root)
                edges_v.append(int(m))
                weights.append(1e-12)
            return root
        node = next_id
        next_id += 1
        delta_child = beta * (2.0 ** (level - 1))
        # edge weight = parent's delta: guarantees d_T(u,v) >= 2*delta_level
        # >= d_G(u,v) for pairs separated at this level (domination)
        w_edge = beta * (2.0 ** level)
        # partition: each member joins the first center (in perm order)
        # within distance delta_child
        assigned = np.full(members.size, -1, dtype=np.int64)
        for rank, c in enumerate(perm):
            mask = (assigned == -1) & (D[c, members] < delta_child)
            assigned[mask] = rank
            if (assigned != -1).all():
                break
        for rank in np.unique(assigned):
            sub = members[assigned == rank]
            child = build(sub, level - 1)
            edges_u.append(node)
            edges_v.append(child)
            weights.append(w_edge)
        return node

    root = build(np.arange(n), top)
    tree = WeightedTree(next_id, np.array(edges_u), np.array(edges_v),
                        np.array(weights))
    return tree, np.arange(n)


def frt_integrate(g: Graph, fn, X: np.ndarray, seed: int = 0, leaf_size=64):
    """f-integration of a leaf field using the FRT tree metric."""
    from repro.core.integrate import FTFI

    tree, leaf_ids = frt_tree(g, seed)
    Xfull = np.zeros((tree.num_vertices,) + X.shape[1:], dtype=X.dtype)
    Xfull[leaf_ids] = X
    out = FTFI(tree, leaf_size=leaf_size).integrate(fn, Xfull)
    return out[leaf_ids]
