"""FRT trees (Fakcharoenphol–Rao–Talwar 2004): randomized O(log n)-distortion
hierarchically-separated tree embeddings — the paper's Fig-4 baseline.

The HST's leaves are the graph vertices; internal nodes are cluster ids.
Returned as a WeightedTree over (n_leaves + n_internal) vertices with
`leaf_ids` mapping graph vertex -> tree vertex, so FTFI runs on it directly
(field zero on internal nodes).

The FRT guarantee is in EXPECTATION over the random permutation/radius, so
the paper's Fig-4 metric approximation averages over k sampled trees:
`frt_forest` samples k trees and `frt_integrate_forest` runs them as ONE
fused forest integration (one jit dispatch for all k trees), averaging the
per-tree leaf outputs.
"""
from __future__ import annotations

import numpy as np

from repro.graphs.graph import Forest, Graph, WeightedTree
from repro.graphs.traverse import graph_all_pairs


def frt_tree(g: Graph, seed: int = 0, D: np.ndarray | None = None):
    """Returns (tree, leaf_ids) — leaf_ids[v] is the tree vertex of graph
    vertex v (identity: leaves occupy ids 0..n-1). `D` is the all-pairs
    graph metric; pass it in when sampling many trees of one graph (the
    Dijkstra sweep dominates construction and is seed-independent)."""
    rng = np.random.default_rng(seed)
    if D is None:
        D = graph_all_pairs(g)
    n = g.num_vertices
    diam = float(D.max())
    beta = float(rng.uniform(1.0, 2.0))
    perm = rng.permutation(n)

    # levels: delta_i = beta * 2^i ; top level has one cluster of radius >= diam
    top = 0
    while beta * (2.0 ** top) < diam:
        top += 1

    edges_u, edges_v, weights = [], [], []
    next_id = n  # internal node ids start after the leaves

    def build(members: np.ndarray, level: int) -> int:
        """Returns the tree node id representing this cluster."""
        nonlocal next_id
        if members.size == 1:
            return int(members[0])
        if level < -60:  # duplicate points (zero distance): numeric guard
            root = int(members[0])
            for m in members[1:]:
                edges_u.append(root)
                edges_v.append(int(m))
                weights.append(1e-12)
            return root
        node = next_id
        next_id += 1
        delta_child = beta * (2.0 ** (level - 1))
        # edge weight = parent's delta: guarantees d_T(u,v) >= 2*delta_level
        # >= d_G(u,v) for pairs separated at this level (domination)
        w_edge = beta * (2.0 ** level)
        # partition: each member joins the first center (in perm order)
        # within distance delta_child
        assigned = np.full(members.size, -1, dtype=np.int64)
        for rank, c in enumerate(perm):
            mask = (assigned == -1) & (D[c, members] < delta_child)
            assigned[mask] = rank
            if (assigned != -1).all():
                break
        for rank in np.unique(assigned):
            sub = members[assigned == rank]
            child = build(sub, level - 1)
            edges_u.append(node)
            edges_v.append(child)
            weights.append(w_edge)
        return node

    root = build(np.arange(n), top)
    tree = WeightedTree(next_id, np.array(edges_u), np.array(edges_v),
                        np.array(weights))
    return tree, np.arange(n)


def frt_integrate(g: Graph, fn, X: np.ndarray, seed: int = 0, leaf_size=64):
    """f-integration of a leaf field using ONE sampled FRT tree metric."""
    from repro.core.integrate import FTFI

    tree, leaf_ids = frt_tree(g, seed)
    Xfull = np.zeros((tree.num_vertices,) + X.shape[1:], dtype=X.dtype)
    Xfull[leaf_ids] = X
    out = FTFI(tree, leaf_size=leaf_size).integrate(fn, Xfull)
    return out[leaf_ids]


def frt_forest(g: Graph, num_trees: int, seed: int = 0,
               D: np.ndarray | None = None):
    """Sample `num_trees` independent FRT trees of `g` as one `Forest`.

    The seed-independent all-pairs metric is computed ONCE and shared by
    every sample (pass `D` to reuse an already-computed metric). Returns
    (forest, leaf_ids): graph vertex v of tree t sits at packed row
    `forest.offsets[t] + leaf_ids[v]` (leaf ids are the identity 0..n-1)."""
    if D is None:
        D = graph_all_pairs(g)
    trees = [frt_tree(g, seed=seed + 977 * t, D=D)[0]
             for t in range(num_trees)]
    return Forest(trees), np.arange(g.num_vertices)


def forest_leaf_integrate(forest: Forest, leaf_ids: np.ndarray, integrator,
                          fn, X: np.ndarray) -> np.ndarray:
    """One fused integration of a leaf field over every tree of an FRT
    forest, averaged: the field is replicated into each tree's block at
    `offsets[t] + leaf_ids` (zero on internal cluster vertices), one
    `integrator.integrate` call covers all trees, and the per-tree leaf
    outputs are meaned. Reused by callers that sweep many f over one
    prebuilt forest (e.g. the Fig-4 bench)."""
    X = np.asarray(X)
    off = forest.offsets
    Xp = np.zeros((forest.num_vertices,) + X.shape[1:], dtype=X.dtype)
    for t in range(forest.num_trees):
        Xp[off[t] + leaf_ids] = X
    out = np.asarray(integrator.integrate(fn, Xp))
    return np.mean(np.stack([out[off[t] + leaf_ids]
                             for t in range(forest.num_trees)]), axis=0)


def frt_integrate_forest(g: Graph, fn, X: np.ndarray, num_trees: int = 8,
                         seed: int = 0, leaf_size: int = 64,
                         backend: str = "plan"):
    """Averaged f-integration over `num_trees` sampled FRT tree metrics as
    ONE batched forest integration (Fig. 4's expectation estimate)."""
    from repro.core.engines import Integrator

    forest, leaf_ids = frt_forest(g, num_trees, seed=seed)
    integ = Integrator.from_forest(forest, backend=backend,
                                   leaf_size=leaf_size)
    return forest_leaf_integrate(forest, leaf_ids, integ, fn, X)
