"""Kruskal minimum spanning tree with union-find (numpy, O(E log E))."""
from __future__ import annotations

import numpy as np

from repro.graphs.graph import Graph, WeightedTree


class _UnionFind:
    def __init__(self, n: int):
        self.parent = np.arange(n, dtype=np.int64)
        self.rank = np.zeros(n, dtype=np.int8)

    def find(self, x: int) -> int:
        p = self.parent
        root = x
        while p[root] != root:
            root = p[root]
        while p[x] != root:  # path compression
            p[x], x = root, p[x]
        return root

    def union(self, a: int, b: int) -> bool:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return False
        if self.rank[ra] < self.rank[rb]:
            ra, rb = rb, ra
        self.parent[rb] = ra
        if self.rank[ra] == self.rank[rb]:
            self.rank[ra] += 1
        return True


def minimum_spanning_tree(g: Graph) -> WeightedTree:
    """Kruskal MST. Raises if the graph is disconnected."""
    order = np.argsort(g.weights, kind="stable")
    uf = _UnionFind(g.num_vertices)
    keep = np.zeros(g.num_edges, dtype=bool)
    taken = 0
    for e in order:
        if uf.union(int(g.edges_u[e]), int(g.edges_v[e])):
            keep[e] = True
            taken += 1
            if taken == g.num_vertices - 1:
                break
    if taken != g.num_vertices - 1:
        raise ValueError("graph is disconnected: MST does not exist")
    return WeightedTree(
        g.num_vertices, g.edges_u[keep], g.edges_v[keep], g.weights[keep]
    )
