"""Minimum spanning trees: per-graph Kruskal with union-find (O(E log E)),
and a vectorized Borůvka `minimum_spanning_forest` that computes EVERY
graph's MST in one pass over the disjoint union — the multi-graph analogue
of the flat-IT level sweep (no per-graph Python loop)."""
from __future__ import annotations

import numpy as np

from repro.graphs.graph import Graph, WeightedTree


class _UnionFind:
    def __init__(self, n: int):
        self.parent = np.arange(n, dtype=np.int64)
        self.rank = np.zeros(n, dtype=np.int8)

    def find(self, x: int) -> int:
        p = self.parent
        root = x
        while p[root] != root:
            root = p[root]
        while p[x] != root:  # path compression
            p[x], x = root, p[x]
        return root

    def union(self, a: int, b: int) -> bool:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return False
        if self.rank[ra] < self.rank[rb]:
            ra, rb = rb, ra
        self.parent[rb] = ra
        if self.rank[ra] == self.rank[rb]:
            self.rank[ra] += 1
        return True


def minimum_spanning_tree(g: Graph) -> WeightedTree:
    """Kruskal MST. Raises if the graph is disconnected."""
    order = np.argsort(g.weights, kind="stable")
    uf = _UnionFind(g.num_vertices)
    keep = np.zeros(g.num_edges, dtype=bool)
    taken = 0
    for e in order:
        if uf.union(int(g.edges_u[e]), int(g.edges_v[e])):
            keep[e] = True
            taken += 1
            if taken == g.num_vertices - 1:
                break
    if taken != g.num_vertices - 1:
        raise ValueError("graph is disconnected: MST does not exist")
    return WeightedTree(
        g.num_vertices, g.edges_u[keep], g.edges_v[keep], g.weights[keep]
    )


def minimum_spanning_forest(graphs) -> list:
    """MSTs of MANY graphs in one vectorized Borůvka sweep.

    All edge lists are concatenated into one disjoint-union graph (vertex
    ids offset per graph) and O(log n) Borůvka rounds run as whole-array
    numpy passes: each round every component picks its minimum outgoing edge
    under the strict total order (weight, edge index) — the tie-break makes
    the chosen MST unique, matching `minimum_spanning_tree`'s stable-sort
    Kruskal whenever weights are distinct — and components merge by pointer
    jumping. ~10 array ops per round regardless of how many graphs.

    Returns a list of per-graph `WeightedTree`s (local vertex ids); raises if
    any graph is disconnected."""
    graphs = list(graphs)
    sizes = np.array([g.num_vertices for g in graphs], dtype=np.int64)
    off = np.zeros(sizes.size + 1, np.int64)
    np.cumsum(sizes, out=off[1:])
    N = int(off[-1])
    u = np.concatenate([g.edges_u.astype(np.int64) + off[i]
                        for i, g in enumerate(graphs)])
    v = np.concatenate([g.edges_v.astype(np.int64) + off[i]
                        for i, g in enumerate(graphs)])
    w = np.concatenate([g.weights for g in graphs])
    E = u.size
    gid = np.repeat(np.arange(sizes.size), [g.num_edges for g in graphs])

    order = np.argsort(w, kind="stable")  # strict total order (w, edge idx)
    rank = np.empty(E, np.int64)
    rank[order] = np.arange(E)

    comp = np.arange(N)
    keep = np.zeros(E, dtype=bool)
    # live edge set shrinks geometrically: intra-component edges are dropped
    # each round so late rounds touch only the few remaining bridges
    lu, lv, lrank = u, v, rank
    while True:
        cu, cv = comp[lu], comp[lv]
        alive = cu != cv
        if not alive.any():
            break
        cu, cv, lrank = cu[alive], cv[alive], lrank[alive]
        lu, lv = lu[alive], lv[alive]
        best = np.full(N, E, np.int64)  # per component root: best edge rank
        np.minimum.at(best, cu, lrank)
        np.minimum.at(best, cv, lrank)
        picks = np.flatnonzero(best < E)  # component roots that found an edge
        eids = order[best[picks]]
        keep[eids] = True  # duplicates (mutual picks) collapse in the bool
        a, b = comp[u[eids]], comp[v[eids]]
        ptr = np.arange(N)
        ptr[picks] = np.where(a == picks, b, a)  # root -> opposite root
        # the pick graph has out-degree 1; its only cycles are mutual picks
        # (strict total order), broken by rooting the smaller label
        mutual = ptr[ptr] == np.arange(N)
        root = mutual & (np.arange(N) < ptr)
        ptr[root] = np.flatnonzero(root)
        while True:  # pointer jumping to the new component roots
            nxt = ptr[ptr]
            if np.array_equal(nxt, ptr):
                break
            ptr = nxt
        comp = ptr[comp]

    trees = []
    kept_gid = gid[keep]
    ku = (u[keep] - off[kept_gid]).astype(np.int32)
    kv = (v[keep] - off[kept_gid]).astype(np.int32)
    kw = w[keep]
    bounds = np.searchsorted(kept_gid, np.arange(sizes.size + 1))
    for i, g in enumerate(graphs):
        lo, hi = bounds[i], bounds[i + 1]
        if hi - lo != g.num_vertices - 1:
            raise ValueError(
                f"graph {i} is disconnected: MST does not exist")
        trees.append(WeightedTree(g.num_vertices, ku[lo:hi], kv[lo:hi],
                                  kw[lo:hi]))
    return trees
