from repro.configs.base import ARCHS, SHAPES, ModelConfig, get_config, get_smoke_config  # noqa: F401
