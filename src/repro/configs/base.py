"""Model configuration schema + registry (--arch lookup)."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention
    attention_variant: str = "full"  # full | performer | topo
    attn_impl: str = "naive"  # naive (materialized scores) | chunked (flash)
    performer_phi: str = "relu"  # relu | sq | quart | exp
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    attn_logit_softcap: float = 0.0

    # topological (paper) masking
    topo_g: str = "exp"
    topo_degree: int = 1  # t: #poly coeffs - 1; (t+1)+1(scale)=3 params synced
    topo_synced: bool = True
    topo_dist_scale: float = 1.0 / 256.0
    # sequence-mask attention impl: ref (dense O(L^2) oracle) | fft
    # (separable scan / Toeplitz-FFT column chunks) | pallas (fused kernel)
    topo_attn_impl: str = "fft"
    # tree/grid Integrator backend override for the ViT path (None: follow
    # topo_attn_impl — pallas -> pallas, else plan)
    topo_backend: Optional[str] = None
    # multi-device: run the topo plan executor under shard_map on the active
    # launch.sharding mesh (leaf blocks over the plan axis); no-op without a
    # mesh or on one device
    topo_shard_plan: bool = False

    # mlp
    mlp_act: str = "silu"  # silu (SwiGLU) | gelu (GeGLU)

    # MoE
    moe: bool = False
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    first_dense_layers: int = 0
    router_aux_loss: float = 0.001
    moe_groups: int = 1  # data-local dispatch groups (§Perf iteration B)

    # MLA (deepseek)
    mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # SSM (mamba-1)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0

    # hybrid (recurrentgemma)
    superblock: tuple = ()  # e.g. ("rec", "rec", "attn")
    num_superblocks: int = 0
    tail_blocks: tuple = ()
    lru_width: int = 0
    local_window: int = 0

    # encoder-decoder
    is_encdec: bool = False
    encoder_layers: int = 0
    decoder_layers: int = 0
    max_source_len: int = 3072  # encoder memory length (audio frames)

    # multimodal stub frontend
    frontend: Optional[str] = None  # audio | vision
    num_prefix_embeddings: int = 0  # patch/frame embeddings fed directly

    # norm / misc
    norm_eps: float = 1e-6
    remat_policy: str = "dots"  # dots | nothing (full remat) | none (no remat)
    seq_sharded_residuals: bool = False  # Megatron-SP residual stream
    tie_embeddings: bool = False
    emb_scale: bool = False  # gemma scales embeddings by sqrt(d)
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True

    # MTP (deepseek-v3 multi-token prediction) — extra head depth
    mtp_depth: int = 0

    def padded_vocab(self, multiple: int = 256) -> int:
        v = self.vocab_size
        return ((v + multiple - 1) // multiple) * multiple

    @property
    def d_inner(self) -> int:  # mamba
        return self.ssm_expand * self.d_model

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ----------------------------------------------------------------------------
# registry
# ----------------------------------------------------------------------------

ARCHS = [
    "falcon_mamba_7b",
    "seamless_m4t_medium",
    "recurrentgemma_2b",
    "llava_next_34b",
    "granite_34b",
    "qwen2_1_5b",
    "llama3_2_1b",
    "gemma_7b",
    "deepseek_v2_lite_16b",
    "deepseek_v3_671b",
    "topovit_b16",
]

_ALIASES = {
    "falcon-mamba-7b": "falcon_mamba_7b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "llava-next-34b": "llava_next_34b",
    "granite-34b": "granite_34b",
    "qwen2-1.5b": "qwen2_1_5b",
    "llama3.2-1b": "llama3_2_1b",
    "gemma-7b": "gemma_7b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "topovit-b16": "topovit_b16",
}


def get_config(arch: str, **overrides) -> ModelConfig:
    mod_name = _ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    cfg = mod.CONFIG
    return cfg.replace(**overrides) if overrides else cfg


def get_smoke_config(arch: str, **overrides) -> ModelConfig:
    mod_name = _ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    cfg = mod.SMOKE_CONFIG
    return cfg.replace(**overrides) if overrides else cfg


# input shapes assigned to the LM family (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}
