"""TopoViT-B/16 (the paper's own architecture, Sec 4.4 / Table 5):
12L, d_model=768, 12H, d_ff=3072, 196 patches (224/16), Performer attention
with tree-based topological masking (3 learnable scalars per layer)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="topovit-b16",
    family="dense",
    num_layers=12,
    d_model=768,
    num_heads=12, num_kv_heads=12, head_dim=64,
    d_ff=3072,
    vocab_size=1000,  # classes (vit head)
    attention_variant="topo",
    performer_phi="relu",
    topo_g="exp",
    topo_degree=2,
    topo_synced=True,
    topo_dist_scale=1.0 / 16.0,
    num_prefix_embeddings=196,
    mlp_act="gelu",
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, num_prefix_embeddings=16)
