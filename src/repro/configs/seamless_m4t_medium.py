"""seamless-m4t-medium [audio]: 12L enc + 12L dec, d_model=1024, 16H (MHA),
d_ff=4096, vocab=256206. [arXiv:2308.11596] Audio frontend is a stub:
input_specs provides precomputed (B, S, 1024) frame embeddings; the encoder
memory length is max_source_len=3072 frames (architectural max), while the
assigned seq_len applies to the decoder stack (DESIGN §5)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    is_encdec=True,
    num_layers=24,
    encoder_layers=12,
    decoder_layers=12,
    d_model=1024,
    num_heads=16, num_kv_heads=16, head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    max_source_len=3072,
    frontend="audio",
)

SMOKE_CONFIG = CONFIG.replace(
    encoder_layers=2, decoder_layers=2, num_layers=4, d_model=64,
    num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
    max_source_len=24)
