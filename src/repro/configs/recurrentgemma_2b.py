"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000 — RG-LRU + local attention, pattern (rec, rec, attn).
[arXiv:2402.19427] 26 = 8 superblocks x (rec,rec,attn) + tail (rec,rec)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10, num_kv_heads=1, head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    superblock=("rec", "rec", "attn"),
    num_superblocks=8,
    tail_blocks=("rec", "rec"),
    lru_width=2560,
    local_window=2048,
    mlp_act="gelu",
    tie_embeddings=True,
    emb_scale=True,
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=5, num_superblocks=1, tail_blocks=("rec",), d_model=64,
    num_heads=4, num_kv_heads=1, head_dim=16, d_ff=128, vocab_size=512,
    lru_width=64, local_window=8)
