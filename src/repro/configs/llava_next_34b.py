"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 — anyres tiling. [hf:llava-hf] Vision tower is a stub:
input_specs provides (B, P, 1024) patch embeddings (P=1152, 2 anyres tiles);
the backbone prepends a 2-layer mm_projector (DESIGN §5)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56, num_kv_heads=8, head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    frontend="vision",
    num_prefix_embeddings=1152,
    rope_theta=5000000.0,
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, num_prefix_embeddings=16)
