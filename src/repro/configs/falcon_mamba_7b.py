"""falcon-mamba-7b [ssm]: 64L d_model=4096 (attn-free) vocab=65024, state=16.
[arXiv:2410.05355] Mamba-1 architecture; paper technique inapplicable to the
token mixer (DESIGN §5) — built without FTFI masking."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=1, num_kv_heads=1, head_dim=1,  # unused (attention-free)
    d_ff=0,
    vocab_size=65024,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    dt_rank=256,
    tie_embeddings=True,
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2, d_model=64, dt_rank=8, vocab_size=512, ssm_state=4)
