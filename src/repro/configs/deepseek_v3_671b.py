"""deepseek-v3-671b [moe]: 61L d_model=7168 128H, MLA (kv_lora=512,
q_lora=1536), MoE 256 routed top-8 + 1 shared, expert d_ff=2048,
vocab=129280, MTP. [arXiv:2412.19437] First 3 layers dense (d_ff=18432)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128, num_kv_heads=128, head_dim=128,
    d_ff=18432,  # dense first layers
    vocab_size=129280,
    moe=True,
    num_experts=256,
    num_shared_experts=1,
    top_k=8,
    moe_d_ff=2048,
    first_dense_layers=3,
    mla=True,
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    mtp_depth=1,
    capacity_factor=1.0,
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=3, first_dense_layers=1, d_model=64, num_heads=4,
    num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512, num_experts=8,
    top_k=2, moe_d_ff=32, num_shared_experts=1, kv_lora_rank=32,
    q_lora_rank=48, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
    mtp_depth=1)
