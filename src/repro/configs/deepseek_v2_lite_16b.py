"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H, MLA kv_lora=512,
MoE 64 routed top-6 + 2 shared, expert d_ff=1408, vocab=102400.
[arXiv:2405.04434] Layer 0 is dense (d_ff=10944)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16, num_kv_heads=16, head_dim=128,
    d_ff=10944,  # dense first layer
    vocab_size=102400,
    moe=True,
    num_experts=64,
    num_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    first_dense_layers=1,
    mla=True,
    kv_lora_rank=512,
    q_lora_rank=0,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512, num_experts=8, top_k=2, moe_d_ff=32,
    num_shared_experts=1, kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
    v_head_dim=16)
