"""AdamW + cosine schedule + global-norm clipping, pure JAX pytrees.

Kept deliberately optax-shaped (init/update pair over pytrees) so the train
loop composes transforms (e.g. optim.compress wraps the gradient stream).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray  # () int32
    mu: object  # pytree like params
    nu: object


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    clip_norm: float = 1.0


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def cosine_schedule(step, cfg: AdamWConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gnorm


def adamw_update(grads, state: AdamWState, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = cosine_schedule(step, cfg)
    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g), state.nu, grads)
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        return (p - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                          + cfg.weight_decay * p)).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(step, mu, nu), {"grad_norm": gnorm, "lr": lr}
