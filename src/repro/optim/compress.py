"""int8 error-feedback gradient compression (distributed-optimization trick).

On a real fleet this wraps the DP all-reduce: each worker quantizes its
gradient shard to int8 with a per-tensor scale, keeps the quantization
residual locally, and adds it back into the next step's gradient
(error feedback keeps the scheme unbiased-in-the-limit; convergence is
asserted by tests/test_training.py). Under jit the quantize/dequantize pair
sits exactly where the all-reduce boundary is, so bytes on the wire drop 4x
(f32) / 2x (bf16).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class CompressorState(NamedTuple):
    residual: object  # pytree like grads


def compressor_init(params) -> CompressorState:
    return CompressorState(residual=jax.tree.map(
        lambda p: jnp.zeros_like(p, dtype=jnp.float32), params))


def _quantize_dequantize(g):
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def compress_grads(grads, state: CompressorState):
    """Returns (decompressed grads as seen post-all-reduce, new state)."""

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        deq = _quantize_dequantize(gf)
        return deq.astype(g.dtype), gf - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(state.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_r = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return new_g, CompressorState(residual=new_r)
