from repro.train.loop import TrainLoopConfig, run_training  # noqa: F401
