"""Fault-tolerant training loop.

Production posture (1000+ nodes; DESIGN §6):
  - deterministic host-sharded data keyed by global step -> restart resumes
    bit-identically (asserted by tests/test_fault_tolerance.py);
  - atomic checkpoints every `ckpt_every` steps, keep-k, auto-resume;
  - straggler watchdog: EMA step time, outliers logged (on real fleets this
    feeds the health controller that drains the slow host);
  - optional int8 error-feedback gradient compression around the DP
    all-reduce (optim.compress);
  - microbatching (gradient accumulation) via lax.scan inside the step;
  - crash injection hook (`fail_at_step`) for the restart test.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.synthetic import SyntheticLMStream
from repro.models import api
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.compress import CompressorState, compress_grads, compressor_init


@dataclasses.dataclass
class TrainLoopConfig:
    steps: int = 100
    batch_size: int = 8
    seq_len: int = 128
    microbatches: int = 1
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    seed: int = 0
    log_every: int = 10
    fail_at_step: int | None = None  # crash injection (tests)
    compress_grads: bool = False
    straggler_factor: float = 2.0


class StragglerWatchdog:
    def __init__(self, factor: float = 2.0, warmup: int = 5):
        self.ema = None
        self.factor = factor
        self.warmup = warmup
        self.count = 0
        self.events: list[tuple[int, float, float]] = []

    def observe(self, step: int, dt: float):
        self.count += 1
        if self.ema is None:
            self.ema = dt
            return False
        is_slow = (self.count > self.warmup) and dt > self.factor * self.ema
        if is_slow:
            self.events.append((step, dt, self.ema))
        # slow steps should not poison the baseline
        alpha = 0.1 if not is_slow else 0.01
        self.ema = (1 - alpha) * self.ema + alpha * dt
        return is_slow


def make_accumulating_step(cfg, opt_cfg: AdamWConfig, microbatches: int,
                           use_compression: bool):
    """train_step with gradient accumulation over the leading microbatch dim."""

    def step(params, opt_state, comp_state, batch):
        def lf(p, mb):
            loss, _ = api.loss_fn(cfg, p, mb)
            return loss

        if microbatches == 1:
            loss, grads = jax.value_and_grad(lf)(params, batch)
        else:
            def acc(carry, mb):
                l, g = jax.value_and_grad(lf)(params, mb)
                return None, (l, g)

            _, (losses, grads) = jax.lax.scan(acc, None, batch)
            loss = jnp.mean(losses)
            grads = jax.tree.map(lambda g: jnp.mean(g, axis=0), grads)
        if use_compression:
            grads, comp_state = compress_grads(grads, comp_state)
        params, opt_state, metrics = adamw_update(grads, opt_state, params,
                                                  opt_cfg)
        return params, opt_state, comp_state, dict(metrics, loss=loss)

    return step


def run_training(model_cfg, loop_cfg: TrainLoopConfig,
                 opt_cfg: AdamWConfig | None = None, verbose: bool = True):
    """Returns dict with final params, per-step losses, watchdog events."""
    opt_cfg = opt_cfg or AdamWConfig(total_steps=loop_cfg.steps, warmup_steps=max(
        1, loop_cfg.steps // 20))
    key = jax.random.PRNGKey(loop_cfg.seed)
    params = api.init_params(model_cfg, key)
    opt_state = adamw_init(params)
    comp_state = (compressor_init(params) if loop_cfg.compress_grads else None)

    mgr = CheckpointManager(loop_cfg.ckpt_dir, keep=loop_cfg.keep)
    start_step = 0
    restored = mgr.restore(params, opt_state)
    if restored is not None:
        params = restored["params"]
        if "opt" in restored:
            opt_state = restored["opt"]
        start_step = restored["step"]
        if verbose:
            print(f"[resume] restored checkpoint at step {start_step}")

    stream = SyntheticLMStream(
        model_cfg.vocab_size, loop_cfg.batch_size, loop_cfg.seq_len,
        seed=loop_cfg.seed,
        vlm_prefix=(model_cfg.num_prefix_embeddings
                    if model_cfg.family == "vlm" else 0),
        encdec_src=(model_cfg.max_source_len if model_cfg.is_encdec else 0))

    step_fn = jax.jit(make_accumulating_step(
        model_cfg, opt_cfg, loop_cfg.microbatches,
        loop_cfg.compress_grads), donate_argnums=(0, 1, 2))

    watchdog = StragglerWatchdog(loop_cfg.straggler_factor)
    losses = []
    for step in range(start_step, loop_cfg.steps):
        if loop_cfg.fail_at_step is not None and step == loop_cfg.fail_at_step:
            raise RuntimeError(f"injected failure at step {step}")
        batch = stream.batch_at(step)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        if loop_cfg.microbatches > 1:
            batch = jax.tree.map(
                lambda a: a.reshape((loop_cfg.microbatches,
                                     a.shape[0] // loop_cfg.microbatches)
                                    + a.shape[1:]), batch)
        t0 = time.time()
        params, opt_state, comp_state, metrics = step_fn(
            params, opt_state, comp_state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        slow = watchdog.observe(step, dt)
        losses.append(loss)
        if verbose and (step % loop_cfg.log_every == 0 or slow):
            tag = " [STRAGGLER]" if slow else ""
            print(f"step {step:5d} loss {loss:.4f} "
                  f"({dt*1e3:.0f} ms){tag}", flush=True)
        if (step + 1) % loop_cfg.ckpt_every == 0 or step + 1 == loop_cfg.steps:
            mgr.save(step + 1, params, opt_state)
    return {"params": params, "losses": np.array(losses),
            "straggler_events": watchdog.events, "final_step": loop_cfg.steps}
