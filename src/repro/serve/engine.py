"""Batched serving engine: slot-based continuous batching over a fixed-size
decode batch (vLLM-style, simplified to the JAX static-shape world).

Requests join free slots; every engine tick runs one jitted decode step for
the whole batch; finished sequences (EOS or max_len) free their slot. The KV
cache is allocated once at engine construction (paged at slot granularity).

Prefill is FUSED: whole (right-padded) prompts run through one jitted
`api.prefill_into_cache` call per admission group, which writes KV/state
directly into the paged cache and returns the first generated token — no
token-by-token replay through decode. Prompt lengths bucket to the next
power of two, so one traced program serves each bucket. Decode takes a
per-slot position VECTOR, which is what makes mid-wave admission legal: a
request joining a freed slot starts at its own position while its neighbors
keep decoding at theirs (`prefill_mode="replay"` restores the old
fresh-wave lockstep path, and encoder-decoder models always use it).

Topological masking is first-class: a request may carry its own prompt tree
(`Request(tree=...)`) or name a registered plan by content sha
(`Request(plan_sha=...)` + a `PlanRegistry`). All live trees are packed into
ONE forest plan — block-diagonal, zero cross-request coupling — patched
incrementally on eviction via `ftfi.update_plan` and validated by the plan
guard on every swap (see `repro.serve.forest_masks`).

Fault isolation (README "Failure modes and the degradation ladder"): a
failing slot is evicted and its request re-queued with bounded retry +
exponential backoff instead of killing the whole batch; a prefill or
decode-step crash evicts the group/wave but leaves the engine serviceable;
per-request deadlines bound queue + decode time. A request stopped by the
`S - 1` cache boundary completes with `truncated=True` (counted in
`stats()["truncated"]`) instead of masquerading as a full answer, and
`run()` exhausting `max_ticks` fails every in-flight/queued request with an
explicit "engine stopped" error rather than silently dropping them.
`stats()` is the engine health snapshot (retries, evictions, truncations,
prefill/decode token counters, demotions, cache/validation counters)
surfaced in the serve banner. Greedy decode is deterministic, so a retried
request replays from scratch and lands on the exact tokens it would have
produced — the fused prefill path is bit-identical to replay under greedy
argmax.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import trace_guard
from repro.models import api
from repro.serve.forest_masks import ForestMaskManager, PlanRegistry
from repro.testing import faults


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new_tokens: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    # robustness knobs/outcome (per-request overrides of engine defaults)
    max_retries: int | None = None   # None -> engine default
    deadline_ticks: int | None = None  # ticks from submit() until expiry
    retries: int = 0
    error: str | None = None         # set iff done without a full answer
    truncated: bool = False          # done, but stopped by the cache bound
    # topological masking: a per-request tree over the prompt tokens, given
    # directly or by content sha into the engine's PlanRegistry
    tree: object = None              # WeightedTree | None
    plan_sha: str | None = None


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


class ServeEngine:
    def __init__(self, cfg, params, batch_slots: int = 4, max_len: int = 256,
                 eos_id: int | None = None, plan=None,
                 max_retries: int = 2, retry_backoff: int = 1,
                 prefill_mode: str = "fused", registry=None,
                 mask_leaf_size: int = 8):
        """`plan` optionally preloads a functional integration plan — an
        `ftfi.save_plan` artifact path or a (PlanSpec, PlanParams) pair —
        so topological-mask serving never rebuilds the IT at startup:
        square (patch-grid) plans are installed as the ViT grid integrator,
        and the provenance (content hash, seed, leaf_size) is surfaced in
        `plan_banner()` for the serve log. Either form is validated by the
        plan guard before anything dereferences its index arrays.

        Plans compiled on demand (per-request trees packed into the forest
        mask, `compile_plan` masks) additionally consult the disk-persistent
        plan cache when `FTFI_PLAN_CACHE` is configured, so even cold
        engine processes serving recurring topologies skip the IT rebuild;
        `plan_banner()` reports the cache status.

        `max_retries` bounds how many times a faulted request is re-queued
        before it is failed (`Request.error` set); `retry_backoff` scales
        the exponential re-admission delay (backoff * 2**(retries-1) ticks).

        `prefill_mode` selects "fused" (default: one prefill call per
        admission group, mid-wave admission) or "replay" (the legacy
        fresh-wave path that feeds prompts token-by-token through decode;
        forced for encoder-decoder models). `registry` (a `PlanRegistry` or
        a directory path) resolves `Request.plan_sha` topologies;
        `mask_leaf_size` is the forest plan's leaf size.
        """
        self.cfg = cfg
        self.params = params
        self.plan_spec = self.plan_params = None
        self.plan_grid_side = None  # set iff the plan serves the ViT grid
        if plan is not None:
            if isinstance(plan, (str, bytes)) or hasattr(plan, "__fspath__"):
                from repro import ftfi

                plan = ftfi.load_plan(plan)  # validated inside load_plan
            else:
                from repro.core import plan_guard

                plan_guard.validate(plan[0], plan[1],
                                    where="ServeEngine(plan=...)")
            self.plan_spec, self.plan_params = plan
            side = int(round(np.sqrt(self.plan_spec.n)))
            # install only when the plan actually covers THIS model's patch
            # grid — a square n from some other model must not be claimed
            # as served (its masks would still rebuild the IT on demand)
            if (side * side == self.plan_spec.n
                    and getattr(cfg, "num_prefix_embeddings", None)
                    == self.plan_spec.n):
                from repro.models import attention as A
                from repro.models import vit

                self.plan_grid_side = vit.install_grid_plan(
                    self.plan_spec, self.plan_params,
                    backend=A.resolve_topo_backend(cfg))
        self.B = batch_slots
        self.S = max_len
        self.eos = eos_id
        self.max_retries = int(max_retries)
        self.retry_backoff = max(0, int(retry_backoff))
        if prefill_mode not in ("fused", "replay"):
            raise ValueError(f"prefill_mode must be 'fused' or 'replay', "
                             f"got {prefill_mode!r}")
        if cfg.is_encdec:
            prefill_mode = "replay"  # fused prefill is decoder-only
        self.prefill_mode = prefill_mode
        if registry is not None and not isinstance(registry, PlanRegistry):
            registry = PlanRegistry(registry, leaf_size=mask_leaf_size)
        self.registry = registry
        self.masks = ForestMaskManager(self.B, leaf_size=mask_leaf_size)
        self.cache = api.init_cache(cfg, self.B, self.S)
        self.slot_req: list[Request | None] = [None] * self.B
        self.slot_pos = np.zeros(self.B, dtype=np.int64)
        def _decode_fn(params, cache, tok, pos):
            trace_guard.record("serve.decode")  # body runs only on compile
            return api.decode_fn(cfg, params, cache, tok, pos, self.S)

        def _prefill_fn(params, cache, tokens, lengths):
            # one compile per pow2 prompt bucket, then shape-stable
            trace_guard.record("serve.prefill", detail=f"L{tokens.shape[1]}")
            return api.prefill_into_cache(cfg, params, cache, tokens,
                                          lengths, self.S)

        self._decode = jax.jit(_decode_fn)
        self._prefill = jax.jit(_prefill_fn)

        def _prefill_tree_fn(params, cache, tokens, lengths, spec, pp,
                             pack, unpack):
            from repro.core import masks as M

            trace_guard.record("serve.prefill_tree",
                               detail=f"L{tokens.shape[1]}")

            tree_mask = {
                "make_fastmult": lambda coeffs: M.make_tree_fastmult(
                    (spec, pp), cfg.topo_g, coeffs, cfg.topo_dist_scale),
                "pack": pack, "unpack": unpack,
            }
            return api.prefill_into_cache(cfg, params, cache, tokens,
                                          lengths, self.S,
                                          tree_mask=tree_mask)

        # spec rides through jit as a zero-leaf pytree (static, keyed by
        # content digest); params/pack/unpack trace, so membership churn
        # only retraces when the forest SHAPE changes
        self._prefill_tree = jax.jit(_prefill_tree_fn)
        self.queue: list[Request] = []
        self._tick = 0
        self._stats = {
            "ticks": 0, "completed": 0, "failed": 0, "retries": 0,
            "evictions": 0, "step_failures": 0, "slot_faults": 0,
            "deadline_expired": 0, "truncated": 0, "stopped_inflight": 0,
            "prefill_calls": 0, "prefill_failures": 0,
            "prefill_tokens": 0, "decode_tokens": 0,
            "prefill_s": 0.0, "decode_s": 0.0,
        }

    def plan_banner(self) -> str:
        """Provenance lines for the serve log: which integration plan this
        engine serves with, where it came from, and whether on-demand
        compiles are backed by the disk plan cache."""
        from repro.core import plan_cache

        if plan_cache.enabled():
            st = plan_cache.stats()
            cache_line = (f"plan-cache: {st['dir']} "
                          f"({st['entries']} entries, "
                          f"{st['bytes'] / 1e6:.1f}/"
                          f"{st['max_bytes'] / 1e6:.0f} MB)")
        else:
            cache_line = "plan-cache: disabled (set FTFI_PLAN_CACHE)"
        if self.plan_spec is None:
            return f"plan: none (no preloaded integration plan)\n{cache_line}"
        s = self.plan_spec
        if self.plan_grid_side is not None:
            status = (f"installed as {self.plan_grid_side}x"
                      f"{self.plan_grid_side} grid integrator — "
                      "zero IT rebuild")
        else:
            status = ("loaded, NOT installed: plan does not cover this "
                      "model's patch grid; consume via Integrator.from_plan")
        return (f"plan: sha={s.fingerprint[:12]} seed={s.seed} "
                f"leaf_size={s.leaf_size} n={s.n} trees={s.num_trees} "
                f"grid_h={s.grid_h} reweightable={s.reweightable} "
                f"({status})\n{cache_line}")

    def stats(self) -> dict:
        """Engine health snapshot: serving counters plus the robustness
        counters of the layers underneath (degradation ladder, plan guard,
        disk plan cache, forest-mask manager)."""
        from repro.core import ladder, plan_cache, plan_guard

        lst = ladder.stats()
        return {
            **self._stats,
            "ladder": lst,
            "plan_guard": plan_guard.stats(),
            "plan_cache": plan_cache.stats() if plan_cache.enabled() else None,
            "forest_masks": dict(self.masks.stats),
        }

    def health_banner(self) -> str:
        """One-line health summary for the serve log."""
        st = self.stats()
        lad = st["ladder"]
        blocked = ",".join(sorted(lad["blocked"])) or "none"
        return (f"health: ticks={st['ticks']} done={st['completed']} "
                f"failed={st['failed']} retries={st['retries']} "
                f"evictions={st['evictions']} "
                f"truncated={st['truncated']} "
                f"stopped={st['stopped_inflight']} "
                f"demotions={lad['demotions']} blocked={blocked} "
                f"validations={st['plan_guard']['validations']} "
                f"(rejected {st['plan_guard']['failures']}) "
                f"{self.mesh_banner()}")

    def mesh_banner(self) -> str:
        """Mesh/device provenance segment: how many devices this process
        sees versus what the preloaded plan artifact was sharded for."""
        import jax

        from repro.core.plan_shard import SHARD_LAYOUT_VERSION

        seg = f"devices={jax.device_count()}"
        s = self.plan_spec
        if s is not None and int(getattr(s, "shard_layout", 0) or 0):
            axes = ",".join(getattr(s, "mesh_axes", ()) or ()) or "-"
            seg += (f" plan_mesh={int(s.mesh_devices)}({axes}) "
                    f"shard_layout=v{int(s.shard_layout)}/"
                    f"v{SHARD_LAYOUT_VERSION}")
        else:
            seg += " plan_mesh=unsharded"
        return seg

    def submit(self, req: Request):
        req._submit_tick = self._tick
        req._not_before = self._tick
        self.queue.append(req)

    # -- failure handling ---------------------------------------------------

    def _fail(self, req: Request, reason: str) -> None:
        req.done = True
        req.error = reason
        self._stats["failed"] += 1

    def _deadline_left(self, req: Request) -> int | None:
        if req.deadline_ticks is None:
            return None
        return req._submit_tick + req.deadline_ticks - self._tick

    def _evict(self, slot: int, reason: str) -> None:
        """Per-request isolation: free the slot and either re-queue the
        request (bounded retry, exponential backoff, output replayed from
        scratch — greedy decode is deterministic) or fail it."""
        req = self.slot_req[slot]
        self.slot_req[slot] = None
        self.slot_pos[slot] = 0
        self.masks.evict(slot)
        if req is None:
            return
        self._stats["evictions"] += 1
        req.retries += 1
        req.out = []
        req.truncated = False
        req._pending_prompt = None
        limit = self.max_retries if req.max_retries is None else req.max_retries
        if req.retries > limit:
            self._fail(req, f"failed after {limit} retries: {reason}")
        else:
            self._stats["retries"] += 1
            req._not_before = (self._tick
                               + self.retry_backoff * 2 ** (req.retries - 1))
            self.queue.append(req)

    # -- admission ----------------------------------------------------------

    def _validate_request(self, req: Request) -> str | None:
        """Admission-time request validation; returns an error string (the
        request fails cleanly) or None (admissible; `req._tree` resolved)."""
        req._tree = None
        if not req.prompt:
            return "empty prompt"
        if len(req.prompt) >= self.S:
            return (f"prompt length {len(req.prompt)} >= max_len {self.S} "
                    "(no room to generate)")
        tree = req.tree
        if tree is None and req.plan_sha is not None:
            if self.registry is None:
                return (f"request names plan_sha={req.plan_sha} but the "
                        "engine has no plan registry")
            try:
                tree = self.registry.resolve_tree(req.plan_sha)
            except Exception as e:
                return (f"plan_sha {req.plan_sha} unresolved: "
                        f"{type(e).__name__}: {e}")
        if tree is not None:
            if self.prefill_mode != "fused":
                return "tree-masked requests require prefill_mode='fused'"
            if self.cfg.attention_variant != "topo":
                return ("tree-masked requests require "
                        "attention_variant='topo', engine serves "
                        f"{self.cfg.attention_variant!r}")
            if tree.num_vertices != len(req.prompt):
                return (f"tree has {tree.num_vertices} vertices for a "
                        f"{len(req.prompt)}-token prompt")
        req._tree = tree
        return None

    def _admit(self) -> list[int]:
        """Admit queued requests into free slots (FIFO). Fused prefill makes
        mid-wave admission legal — every slot decodes at its own position —
        so any free slot is fair game on any tick. Replay mode keeps the
        legacy fresh-wave rule (admission only when no slot is active: the
        lockstep scalar-position decode needs the whole wave at pos 0).
        Queued requests still in retry backoff stay queued; expired
        deadlines and invalid requests (empty/oversized prompt, unresolvable
        tree) fail here. Returns the admitted slots."""
        admitted: list[int] = []
        if (self.prefill_mode == "replay"
                and any(r is not None for r in self.slot_req)):
            return admitted
        still_queued: list[Request] = []
        free = [s for s in range(self.B) if self.slot_req[s] is None]
        for req in self.queue:
            left = self._deadline_left(req)
            if left is not None and left <= 0:
                self._stats["deadline_expired"] += 1
                self._fail(req, f"deadline expired after "
                                f"{req.deadline_ticks} ticks in queue")
                continue
            if not free or req._not_before > self._tick:
                still_queued.append(req)
                continue
            err = self._validate_request(req)
            if err is not None:
                self._fail(req, err)
                continue
            slot = free[0]
            if req._tree is not None:
                try:
                    self.masks.admit(slot, req._tree)
                except Exception as e:
                    self._fail(req, f"forest-mask admit failed: "
                                    f"{type(e).__name__}: {e}")
                    continue
            free.pop(0)
            self.slot_req[slot] = req
            self.slot_pos[slot] = 0
            req._pending_prompt = (list(req.prompt)
                                   if self.prefill_mode == "replay" else None)
            admitted.append(slot)
        self.queue = still_queued
        return admitted

    # -- fused prefill ------------------------------------------------------

    def _prefill_admitted(self, slots: list[int]) -> None:
        """Run fused prefill for freshly admitted slots: one jitted call per
        group (plain and tree-masked prompts prefill separately — the tree
        group threads the packed forest plan through the topo layers)."""
        plain = [s for s in slots if self.slot_req[s]._tree is None]
        treed = [s for s in slots if self.slot_req[s]._tree is not None]
        for group, use_tree in ((plain, False), (treed, True)):
            if group:
                self._prefill_group(group, use_tree)

    def _prefill_group(self, group: list[int], use_tree: bool) -> None:
        reqs = {s: self.slot_req[s] for s in group}
        Lp = min(self.S, _next_pow2(max(
            8, max(len(r.prompt) for r in reqs.values()))))
        tokens = np.zeros((self.B, Lp), dtype=np.int32)
        lengths = np.zeros((self.B,), dtype=np.int32)
        for s, req in reqs.items():
            tokens[s, :len(req.prompt)] = req.prompt
            lengths[s] = len(req.prompt)
        t0 = time.perf_counter()
        try:
            faults.fire("serve.prefill", tick=self._tick)
            if use_tree:
                pack, unpack = self.masks.pack_maps(Lp, group, self.B)
                logits, cache = self._prefill_tree(
                    self.params, self.cache, jnp.asarray(tokens),
                    jnp.asarray(lengths), self.masks.spec, self.masks.params,
                    jnp.asarray(pack), jnp.asarray(unpack))
            else:
                logits, cache = self._prefill(
                    self.params, self.cache, jnp.asarray(tokens),
                    jnp.asarray(lengths))
            logits_np = np.asarray(jax.device_get(logits), dtype=np.float32)
        except Exception as e:
            # group failure: the engine survives, the group is re-queued
            self._stats["prefill_failures"] += 1
            reason = f"prefill failed: {type(e).__name__}: {e}"
            for s in group:
                self._evict(s, reason)
            return
        self.cache = cache
        self._stats["prefill_calls"] += 1
        self._stats["prefill_s"] += time.perf_counter() - t0
        logits_np = faults.transform("serve.prefill_logits", logits_np,
                                     tick=self._tick)
        finite = np.isfinite(logits_np).all(axis=-1)
        nxt = np.argmax(logits_np, axis=-1)
        for s in group:
            req = reqs[s]
            if not finite[s]:
                self._stats["slot_faults"] += 1
                self._evict(s, "non-finite prefill logits")
                continue
            req.out.append(int(nxt[s]))
            self._stats["prefill_tokens"] += len(req.prompt)
            self.slot_pos[s] = len(req.prompt)
            self._finish_if_done(s)

    # -- completion ---------------------------------------------------------

    def _finish_if_done(self, s: int) -> None:
        """Completion check for slot `s`: EOS, max_new_tokens, or the cache
        boundary. Hitting `S - 1` before the request's budget marks the
        answer `truncated` (counted) instead of passing it off as full."""
        req = self.slot_req[s]
        if req is None or (self.prefill_mode == "replay"
                           and req._pending_prompt):
            return
        hit_eos = (self.eos is not None and req.out
                   and req.out[-1] == self.eos)
        full = len(req.out) >= req.max_new_tokens
        at_bound = self.slot_pos[s] >= self.S - 1
        if not (hit_eos or full or at_bound):
            return
        if at_bound and not (hit_eos or full):
            req.truncated = True
            self._stats["truncated"] += 1
        req.done = True
        self._stats["completed"] += 1
        self.slot_req[s] = None
        self.slot_pos[s] = 0
        self.masks.evict(s)

    def step(self):
        """One engine tick: admit + fused-prefill new requests, then one
        batched decode feeding every active slot its next token at its OWN
        position. Faults are contained: a prefill/decode crash evicts (and
        re-queues) the group/wave, a non-finite logits row evicts only that
        slot. A freshly prefilled slot joins the same tick's decode with its
        real first token (an admission tick therefore yields two tokens for
        the new request)."""
        self._tick += 1
        self._stats["ticks"] += 1
        admitted = self._admit()
        # enforce per-request deadlines on the active wave too (covers a
        # wave stalled by repeated step failures)
        for s in range(self.B):
            req = self.slot_req[s]
            if req is None:
                continue
            left = self._deadline_left(req)
            if left is not None and left <= 0:
                self._stats["deadline_expired"] += 1
                self.slot_req[s] = None
                self.slot_pos[s] = 0
                self.masks.evict(s)
                self._stats["evictions"] += 1
                self._fail(req, f"deadline expired after "
                                f"{req.deadline_ticks} ticks")
        admitted = [s for s in admitted if self.slot_req[s] is not None]
        if admitted and self.prefill_mode == "fused":
            self._prefill_admitted(admitted)
        active = [s for s in range(self.B) if self.slot_req[s] is not None]
        if not active:
            return False
        # each slot feeds its next token at its own position: prompt replay
        # (replay mode) or its latest generation (fused mode / post-prompt).
        # The position vector is what keeps mid-wave admission sound —
        # inactive rows decode junk at pos 0, overwritten by the next
        # prefill before anything reads it.
        toks = np.zeros((self.B, 1), dtype=np.int32)
        for s in active:
            req = self.slot_req[s]
            if req._pending_prompt:
                toks[s, 0] = req._pending_prompt[0]
            elif req.out:
                toks[s, 0] = req.out[-1]
        pos = np.clip(self.slot_pos, 0, self.S - 1).astype(np.int32)
        t0 = time.perf_counter()
        try:
            faults.fire("serve.step", tick=self._tick)
            logits, cache = self._decode(
                self.params, self.cache, jnp.asarray(toks),
                jnp.asarray(pos, jnp.int32))
            logits_np = np.asarray(jax.device_get(logits[:, -1, :]),
                                   dtype=np.float32)
        except Exception as e:
            # whole-step failure: the engine survives, the wave is re-queued
            self._stats["step_failures"] += 1
            reason = f"decode step failed: {type(e).__name__}: {e}"
            for s in active:
                self._evict(s, reason)
            return True
        self.cache = cache
        self._stats["decode_s"] += time.perf_counter() - t0
        logits_np = faults.transform("serve.logits", logits_np,
                                     tick=self._tick)
        finite = np.isfinite(logits_np).all(axis=-1)
        nxt = np.argmax(logits_np, axis=-1)
        for s in active:
            req = self.slot_req[s]
            if not finite[s]:
                # per-slot corruption: only this request is touched
                self._stats["slot_faults"] += 1
                self._evict(s, "non-finite logits")
                continue
            if req._pending_prompt:
                req._pending_prompt.pop(0)
                self._stats["prefill_tokens"] += 1
                if not req._pending_prompt:
                    req.out.append(int(nxt[s]))
                    self._stats["decode_tokens"] += 1
            else:
                req.out.append(int(nxt[s]))
                self._stats["decode_tokens"] += 1
            self.slot_pos[s] += 1
            self._finish_if_done(s)
        return True

    def run(self, max_ticks: int = 10000):
        """Tick until drained or `max_ticks`. Exhausting the tick budget
        with work still in flight is an engine stop, not a quiet return:
        every in-flight and queued request is failed with an explicit
        "engine stopped" error (counted in `stats()["stopped_inflight"]`
        and the health banner) so callers never see a hung request."""
        ticks = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        leftovers = ([r for r in self.slot_req if r is not None]
                     + list(self.queue))
        if leftovers:
            for req in leftovers:
                self._stats["stopped_inflight"] += 1
                self._fail(req, f"engine stopped: max_ticks={max_ticks} "
                                "exhausted before completion")
            self.slot_req = [None] * self.B
            self.slot_pos[:] = 0
            self.queue = []
            for s in range(self.B):
                self.masks.evict(s)
        return ticks
