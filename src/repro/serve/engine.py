"""Batched serving engine: slot-based continuous batching over a fixed-size
decode batch (vLLM-style, simplified to the JAX static-shape world).

Requests join free slots; every engine tick runs one jitted decode step for
the whole batch; finished sequences (EOS or max_len) free their slot. The KV
cache is allocated once at engine construction (paged at slot granularity).
Prefill uses the cacheless prefill path then replays tokens through decode to
warm the slot's cache — simple and correct; a fused prefill-into-cache step
is the natural production optimization on top of this layout.

Fault isolation (README "Failure modes and the degradation ladder"): a
failing slot is evicted and its request re-queued with bounded retry +
exponential backoff instead of killing the whole batch; a decode-step crash
evicts the wave but leaves the engine serviceable; per-request deadlines
bound queue + decode time; `stats()` is the engine health snapshot
(retries, evictions, demotions, cache/validation counters) surfaced in the
serve banner. Greedy decode is deterministic, so a retried request replays
from scratch and lands on the exact tokens it would have produced.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.testing import faults


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new_tokens: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    # robustness knobs/outcome (per-request overrides of engine defaults)
    max_retries: int | None = None   # None -> engine default
    deadline_ticks: int | None = None  # ticks from submit() until expiry
    retries: int = 0
    error: str | None = None         # set iff done without a full answer


class ServeEngine:
    def __init__(self, cfg, params, batch_slots: int = 4, max_len: int = 256,
                 eos_id: int | None = None, plan=None,
                 max_retries: int = 2, retry_backoff: int = 1):
        """`plan` optionally preloads a functional integration plan — an
        `ftfi.save_plan` artifact path or a (PlanSpec, PlanParams) pair —
        so topological-mask serving never rebuilds the IT at startup:
        square (patch-grid) plans are installed as the ViT grid integrator,
        and the provenance (content hash, seed, leaf_size) is surfaced in
        `plan_banner()` for the serve log. Either form is validated by the
        plan guard before anything dereferences its index arrays.

        Plans compiled on demand (e.g. per-request topological masks going
        through `compile_plan`) additionally consult the disk-persistent
        plan cache when `FTFI_PLAN_CACHE` is configured, so even cold
        engine processes serving recurring topologies skip the IT rebuild;
        `plan_banner()` reports the cache status.

        `max_retries` bounds how many times a faulted request is re-queued
        before it is failed (`Request.error` set); `retry_backoff` scales
        the exponential re-admission delay (backoff * 2**(retries-1) ticks).
        """
        self.cfg = cfg
        self.params = params
        self.plan_spec = self.plan_params = None
        self.plan_grid_side = None  # set iff the plan serves the ViT grid
        if plan is not None:
            if isinstance(plan, (str, bytes)) or hasattr(plan, "__fspath__"):
                from repro import ftfi

                plan = ftfi.load_plan(plan)  # validated inside load_plan
            else:
                from repro.core import plan_guard

                plan_guard.validate(plan[0], plan[1],
                                    where="ServeEngine(plan=...)")
            self.plan_spec, self.plan_params = plan
            side = int(round(np.sqrt(self.plan_spec.n)))
            # install only when the plan actually covers THIS model's patch
            # grid — a square n from some other model must not be claimed
            # as served (its masks would still rebuild the IT on demand)
            if (side * side == self.plan_spec.n
                    and getattr(cfg, "num_prefix_embeddings", None)
                    == self.plan_spec.n):
                from repro.models import attention as A
                from repro.models import vit

                self.plan_grid_side = vit.install_grid_plan(
                    self.plan_spec, self.plan_params,
                    backend=A.resolve_topo_backend(cfg))
        self.B = batch_slots
        self.S = max_len
        self.eos = eos_id
        self.max_retries = int(max_retries)
        self.retry_backoff = max(0, int(retry_backoff))
        self.cache = api.init_cache(cfg, self.B, self.S)
        self.slot_req: list[Request | None] = [None] * self.B
        self.slot_pos = np.zeros(self.B, dtype=np.int64)
        self._decode = jax.jit(
            lambda params, cache, tok, pos: api.decode_fn(
                cfg, params, cache, tok, pos, self.S))
        self.queue: list[Request] = []
        self._tick = 0
        self._stats = {
            "ticks": 0, "completed": 0, "failed": 0, "retries": 0,
            "evictions": 0, "step_failures": 0, "slot_faults": 0,
            "deadline_expired": 0,
        }

    def plan_banner(self) -> str:
        """Provenance lines for the serve log: which integration plan this
        engine serves with, where it came from, and whether on-demand
        compiles are backed by the disk plan cache."""
        from repro.core import plan_cache

        if plan_cache.enabled():
            st = plan_cache.stats()
            cache_line = (f"plan-cache: {st['dir']} "
                          f"({st['entries']} entries, "
                          f"{st['bytes'] / 1e6:.1f}/"
                          f"{st['max_bytes'] / 1e6:.0f} MB)")
        else:
            cache_line = "plan-cache: disabled (set FTFI_PLAN_CACHE)"
        if self.plan_spec is None:
            return f"plan: none (no preloaded integration plan)\n{cache_line}"
        s = self.plan_spec
        if self.plan_grid_side is not None:
            status = (f"installed as {self.plan_grid_side}x"
                      f"{self.plan_grid_side} grid integrator — "
                      "zero IT rebuild")
        else:
            status = ("loaded, NOT installed: plan does not cover this "
                      "model's patch grid; consume via Integrator.from_plan")
        return (f"plan: sha={s.fingerprint[:12]} seed={s.seed} "
                f"leaf_size={s.leaf_size} n={s.n} trees={s.num_trees} "
                f"grid_h={s.grid_h} reweightable={s.reweightable} "
                f"({status})\n{cache_line}")

    def stats(self) -> dict:
        """Engine health snapshot: serving counters plus the robustness
        counters of the layers underneath (degradation ladder, plan guard,
        disk plan cache)."""
        from repro.core import ladder, plan_cache, plan_guard

        lst = ladder.stats()
        return {
            **self._stats,
            "ladder": lst,
            "plan_guard": plan_guard.stats(),
            "plan_cache": plan_cache.stats() if plan_cache.enabled() else None,
        }

    def health_banner(self) -> str:
        """One-line health summary for the serve log."""
        st = self.stats()
        lad = st["ladder"]
        blocked = ",".join(sorted(lad["blocked"])) or "none"
        return (f"health: ticks={st['ticks']} done={st['completed']} "
                f"failed={st['failed']} retries={st['retries']} "
                f"evictions={st['evictions']} "
                f"demotions={lad['demotions']} blocked={blocked} "
                f"validations={st['plan_guard']['validations']} "
                f"(rejected {st['plan_guard']['failures']}) "
                f"{self.mesh_banner()}")

    def mesh_banner(self) -> str:
        """Mesh/device provenance segment: how many devices this process
        sees versus what the preloaded plan artifact was sharded for."""
        import jax

        from repro.core.plan_shard import SHARD_LAYOUT_VERSION

        seg = f"devices={jax.device_count()}"
        s = self.plan_spec
        if s is not None and int(getattr(s, "shard_layout", 0) or 0):
            axes = ",".join(getattr(s, "mesh_axes", ()) or ()) or "-"
            seg += (f" plan_mesh={int(s.mesh_devices)}({axes}) "
                    f"shard_layout=v{int(s.shard_layout)}/"
                    f"v{SHARD_LAYOUT_VERSION}")
        else:
            seg += " plan_mesh=unsharded"
        return seg

    def submit(self, req: Request):
        req._submit_tick = self._tick
        req._not_before = self._tick
        self.queue.append(req)

    # -- failure handling ---------------------------------------------------

    def _fail(self, req: Request, reason: str) -> None:
        req.done = True
        req.error = reason
        self._stats["failed"] += 1

    def _deadline_left(self, req: Request) -> int | None:
        if req.deadline_ticks is None:
            return None
        return req._submit_tick + req.deadline_ticks - self._tick

    def _evict(self, slot: int, reason: str) -> None:
        """Per-request isolation: free the slot and either re-queue the
        request (bounded retry, exponential backoff, output replayed from
        scratch — greedy decode is deterministic) or fail it."""
        req = self.slot_req[slot]
        self.slot_req[slot] = None
        self.slot_pos[slot] = 0
        if req is None:
            return
        self._stats["evictions"] += 1
        req.retries += 1
        req.out = []
        req._pending_prompt = None
        limit = self.max_retries if req.max_retries is None else req.max_retries
        if req.retries > limit:
            self._fail(req, f"failed after {limit} retries: {reason}")
        else:
            self._stats["retries"] += 1
            req._not_before = (self._tick
                               + self.retry_backoff * 2 ** (req.retries - 1))
            self.queue.append(req)

    def _admit(self):
        """Admit a fresh wave. Admission happens ONLY when no slot is active:
        every request in a wave starts at pos 0, which is what makes the
        lockstep `pos = max(slot_pos[active])` decode correct — a request
        admitted into a freed slot mid-wave would write its tokens at the
        PREVIOUS wave's positions and attend to another request's KV cache.
        Queued requests still in retry backoff or past their deadline are
        skipped/failed here."""
        if any(r is not None for r in self.slot_req):
            return
        still_queued: list[Request] = []
        free = list(range(self.B))
        for req in self.queue:
            left = self._deadline_left(req)
            if left is not None and left <= 0:
                self._stats["deadline_expired"] += 1
                self._fail(req, f"deadline expired after "
                                f"{req.deadline_ticks} ticks in queue")
                continue
            if free and req._not_before <= self._tick:
                slot = free.pop(0)
                self.slot_req[slot] = req
                self.slot_pos[slot] = 0
                req._pending_prompt = list(req.prompt)
            else:
                still_queued.append(req)
        self.queue = still_queued

    def step(self):
        """One engine tick: feed each active slot its next token. Faults are
        contained: a decode-step crash evicts (and re-queues) the wave, a
        non-finite logits row evicts only that slot."""
        self._tick += 1
        self._stats["ticks"] += 1
        self._admit()
        active = [s for s in range(self.B) if self.slot_req[s] is not None]
        if not active:
            return False
        # enforce per-request deadlines on the active wave too (covers a
        # wave stalled by repeated step failures)
        for s in active:
            req = self.slot_req[s]
            left = self._deadline_left(req)
            if left is not None and left <= 0:
                self._stats["deadline_expired"] += 1
                self.slot_req[s] = None
                self.slot_pos[s] = 0
                self._stats["evictions"] += 1
                self._fail(req, f"deadline expired after "
                                f"{req.deadline_ticks} ticks")
        active = [s for s in range(self.B) if self.slot_req[s] is not None]
        if not active:
            return False
        # all slots share one global step; each slot feeds prompt tokens until
        # exhausted, then its own generations. Positions are per-slot; the
        # jitted step uses the max pos (slots at earlier pos simply have
        # stale-but-masked cache above their own pos). Lockstep holds because
        # _admit only starts fresh waves (all at pos 0).
        toks = np.zeros((self.B, 1), dtype=np.int32)
        for s in active:
            req = self.slot_req[s]
            if req._pending_prompt:
                toks[s, 0] = req._pending_prompt[0]
            elif req.out:
                toks[s, 0] = req.out[-1]
        pos = int(self.slot_pos[active].max())
        try:
            faults.fire("serve.step", tick=self._tick)
            logits, cache = self._decode(
                self.params, self.cache, jnp.asarray(toks),
                jnp.asarray(pos, jnp.int32))
            logits_np = np.asarray(jax.device_get(logits[:, -1, :]),
                                   dtype=np.float32)
        except Exception as e:
            # whole-step failure: the engine survives, the wave is re-queued
            self._stats["step_failures"] += 1
            reason = f"decode step failed: {type(e).__name__}: {e}"
            for s in active:
                self._evict(s, reason)
            return True
        self.cache = cache
        logits_np = faults.transform("serve.logits", logits_np,
                                     tick=self._tick)
        finite = np.isfinite(logits_np).all(axis=-1)
        nxt = np.argmax(logits_np, axis=-1)
        for s in active:
            req = self.slot_req[s]
            if not finite[s]:
                # per-slot corruption: only this request is touched
                self._stats["slot_faults"] += 1
                self._evict(s, "non-finite logits")
                continue
            if req._pending_prompt:
                req._pending_prompt.pop(0)
                if not req._pending_prompt:
                    req.out.append(int(nxt[s]))
            else:
                req.out.append(int(nxt[s]))
            self.slot_pos[s] += 1
            hit_eos = self.eos is not None and req.out and req.out[-1] == self.eos
            if (len(req.out) >= req.max_new_tokens or hit_eos
                    or self.slot_pos[s] >= self.S - 1):
                req.done = True
                self._stats["completed"] += 1
                self.slot_req[s] = None
        return True

    def run(self, max_ticks: int = 10000):
        ticks = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        return ticks
