"""Batched serving engine: slot-based continuous batching over a fixed-size
decode batch (vLLM-style, simplified to the JAX static-shape world).

Requests join free slots; every engine tick runs one jitted decode step for
the whole batch; finished sequences (EOS or max_len) free their slot. The KV
cache is allocated once at engine construction (paged at slot granularity).
Prefill uses the cacheless prefill path then replays tokens through decode to
warm the slot's cache — simple and correct; a fused prefill-into-cache step
is the production optimization documented in DESIGN §6.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new_tokens: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg, params, batch_slots: int = 4, max_len: int = 256,
                 eos_id: int | None = None, plan=None):
        """`plan` optionally preloads a functional integration plan — an
        `ftfi.save_plan` artifact path or a (PlanSpec, PlanParams) pair —
        so topological-mask serving never rebuilds the IT at startup:
        square (patch-grid) plans are installed as the ViT grid integrator,
        and the provenance (content hash, seed, leaf_size) is surfaced in
        `plan_banner()` for the serve log.

        Plans compiled on demand (e.g. per-request topological masks going
        through `compile_plan`) additionally consult the disk-persistent
        plan cache when `FTFI_PLAN_CACHE` is configured, so even cold
        engine processes serving recurring topologies skip the IT rebuild;
        `plan_banner()` reports the cache status."""
        self.cfg = cfg
        self.params = params
        self.plan_spec = self.plan_params = None
        self.plan_grid_side = None  # set iff the plan serves the ViT grid
        if plan is not None:
            if isinstance(plan, (str, bytes)) or hasattr(plan, "__fspath__"):
                from repro import ftfi

                plan = ftfi.load_plan(plan)
            self.plan_spec, self.plan_params = plan
            side = int(round(np.sqrt(self.plan_spec.n)))
            # install only when the plan actually covers THIS model's patch
            # grid — a square n from some other model must not be claimed
            # as served (its masks would still rebuild the IT on demand)
            if (side * side == self.plan_spec.n
                    and getattr(cfg, "num_prefix_embeddings", None)
                    == self.plan_spec.n):
                from repro.models import attention as A
                from repro.models import vit

                self.plan_grid_side = vit.install_grid_plan(
                    self.plan_spec, self.plan_params,
                    backend=A.resolve_topo_backend(cfg))
        self.B = batch_slots
        self.S = max_len
        self.eos = eos_id
        self.cache = api.init_cache(cfg, self.B, self.S)
        self.slot_req: list[Request | None] = [None] * self.B
        self.slot_pos = np.zeros(self.B, dtype=np.int64)
        self._decode = jax.jit(
            lambda params, cache, tok, pos: api.decode_fn(
                cfg, params, cache, tok, pos, self.S))
        self.queue: list[Request] = []

    def plan_banner(self) -> str:
        """Provenance lines for the serve log: which integration plan this
        engine serves with, where it came from, and whether on-demand
        compiles are backed by the disk plan cache."""
        from repro.core import plan_cache

        if plan_cache.enabled():
            st = plan_cache.stats()
            cache_line = (f"plan-cache: {st['dir']} "
                          f"({st['entries']} entries, "
                          f"{st['bytes'] / 1e6:.1f}/"
                          f"{st['max_bytes'] / 1e6:.0f} MB)")
        else:
            cache_line = "plan-cache: disabled (set FTFI_PLAN_CACHE)"
        if self.plan_spec is None:
            return f"plan: none (no preloaded integration plan)\n{cache_line}"
        s = self.plan_spec
        if self.plan_grid_side is not None:
            status = (f"installed as {self.plan_grid_side}x"
                      f"{self.plan_grid_side} grid integrator — "
                      "zero IT rebuild")
        else:
            status = ("loaded, NOT installed: plan does not cover this "
                      "model's patch grid; consume via Integrator.from_plan")
        return (f"plan: sha={s.fingerprint[:12]} seed={s.seed} "
                f"leaf_size={s.leaf_size} n={s.n} trees={s.num_trees} "
                f"grid_h={s.grid_h} reweightable={s.reweightable} "
                f"({status})\n{cache_line}")

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.B):
            if self.slot_req[slot] is None and self.queue:
                req = self.queue.pop(0)
                self.slot_req[slot] = req
                self.slot_pos[slot] = 0
                req._pending_prompt = list(req.prompt)

    def step(self):
        """One engine tick: feed each active slot its next token."""
        self._admit()
        active = [s for s in range(self.B) if self.slot_req[s] is not None]
        if not active:
            return False
        # all slots share one global step; each slot feeds prompt tokens until
        # exhausted, then its own generations. Positions are per-slot; the
        # jitted step uses the max pos (slots at earlier pos simply have
        # stale-but-masked cache above their own pos).
        toks = np.zeros((self.B, 1), dtype=np.int32)
        for s in range(self.B):
            req = self.slot_req[s]
            if req is None:
                continue
            if req._pending_prompt:
                toks[s, 0] = req._pending_prompt[0]
            else:
                toks[s, 0] = req.out[-1]
        pos = int(self.slot_pos[active].max())
        # NOTE: per-slot positions require per-slot pos support; for the
        # simplified engine all admitted slots advance in lockstep, which we
        # guarantee by admitting only at pos 0 (fresh batch waves).
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(toks),
            jnp.asarray(pos, jnp.int32))
        nxt = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))
        for s in active:
            req = self.slot_req[s]
            if req._pending_prompt:
                req._pending_prompt.pop(0)
                if not req._pending_prompt:
                    req.out.append(int(nxt[s]))
            else:
                req.out.append(int(nxt[s]))
            self.slot_pos[s] += 1
            hit_eos = self.eos is not None and req.out and req.out[-1] == self.eos
            if (len(req.out) >= req.max_new_tokens or hit_eos
                    or self.slot_pos[s] >= self.S - 1):
                req.done = True
                self.slot_req[s] = None
        return True

    def run(self, max_ticks: int = 10000):
        done = []
        ticks = 0
        while (self.queue or any(self.slot_req)) and ticks < max_ticks:
            self.step()
            ticks += 1
        return ticks
