"""Forest-masked serving: one packed FTFI plan over every live slot's tree.

Topological masking as a first-class serving feature. Each request may carry
its own `WeightedTree` over its prompt tokens; the engine packs the live
slots' trees into ONE `Forest` and compiles a single block-diagonal
integration plan (`compile_forest_plan` via `ftfi.build`), so a batched
tree-masked prefill is one fused plan execution instead of per-request
rebuilds. The forest layout is block-diagonal — zero cross-tree coupling —
so rows belonging to other slots (or to ghost rows left by incremental
deletes) are mathematically neutral for any slot's attention output.

Membership churn is handled the cheap way wherever the plan layout allows:

* **admit** repacks (a join changes the packed row space) — full
  `ftfi.build(forest, reweightable=True)`, content-addressed through the
  disk plan cache when configured;
* **evict** patches the live plan in place with `ftfi.update_plan`
  delete_leaf ops (leaves-first peel down to the tree root, whose row the
  incremental engine cannot remove — it stays as a masked ghost); when the
  ghost fraction passes `rebuild_ghost_frac` the manager recompiles.

Every installed plan — built, patched, or loaded from the registry — goes
through `plan_guard.validate` before the engine dereferences it.

`PlanRegistry` is the content-addressed artifact store: `put(tree)` compiles
once and persists a `ftfi.save_plan` npz plus a tree sidecar keyed by the
plan fingerprint, so requests can name their topology by sha
(`Request(plan_sha=...)`) and a serving process never rebuilds a known tree.
"""
from __future__ import annotations

import pathlib

import numpy as np

from repro import ftfi
from repro.core import plan_guard
from repro.graphs.graph import Forest, WeightedTree


class PlanRegistry:
    """Content-addressed store of per-request tree plans.

    Layout: `<root>/plan-<sha>.npz` (a validated `ftfi.save_plan` artifact)
    and `<root>/tree-<sha>.npz` (the raw tree: the forest manager needs the
    topology itself to pack live slots, not just the single-tree plan).
    `sha` is the first 12 hex chars of the compiled plan fingerprint, so the
    name certifies the content.
    """

    def __init__(self, root, leaf_size: int = 8):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.leaf_size = int(leaf_size)
        self._trees: dict[str, WeightedTree] = {}

    def put(self, tree: WeightedTree) -> str:
        """Compile + persist `tree`; returns its content sha (idempotent)."""
        spec, params = ftfi.build(tree, leaf_size=self.leaf_size,
                                  reweightable=True)
        sha = spec.fingerprint[:12]
        plan_p = self.root / f"plan-{sha}.npz"
        if not plan_p.exists():
            ftfi.save_plan(plan_p, spec, params)
        tree_p = self.root / f"tree-{sha}.npz"
        if not tree_p.exists():
            np.savez(tree_p, num_vertices=np.int64(tree.num_vertices),
                     edges_u=np.asarray(tree.edges_u),
                     edges_v=np.asarray(tree.edges_v),
                     weights=np.asarray(tree.weights))
        self._trees[sha] = tree
        return sha

    def resolve(self, sha: str):
        """sha -> validated (spec, params); PlanValidationError on damage."""
        return ftfi.load_plan(self.root / f"plan-{sha}.npz")

    def resolve_tree(self, sha: str) -> WeightedTree:
        """sha -> the raw WeightedTree (from the sidecar; cached)."""
        if sha not in self._trees:
            p = self.root / f"tree-{sha}.npz"
            if not p.exists():
                raise KeyError(f"plan registry has no tree for sha {sha}")
            with np.load(p) as z:
                self._trees[sha] = WeightedTree(
                    num_vertices=int(z["num_vertices"]),
                    edges_u=z["edges_u"], edges_v=z["edges_v"],
                    weights=z["weights"])
        return self._trees[sha]


def _peel_order(tree: WeightedTree, keep_local: int) -> list[int]:
    """Leaves-first deletion order for every vertex except `keep_local`.

    Each emitted vertex has degree 1 at its turn, which is exactly what
    `update_plan`'s delete_leaf requires."""
    n = tree.num_vertices
    adj: list[set] = [set() for _ in range(n)]
    for u, v in zip(tree.edges_u, tree.edges_v):
        adj[int(u)].add(int(v))
        adj[int(v)].add(int(u))
    order: list[int] = []
    frontier = [v for v in range(n) if len(adj[v]) == 1 and v != keep_local]
    while frontier:
        v = frontier.pop()
        order.append(v)
        for u in adj[v]:
            adj[u].discard(v)
            if len(adj[u]) == 1 and u != keep_local:
                frontier.append(u)
        adj[v].clear()
    return order


class ForestMaskManager:
    """Tracks which slot serves which tree and keeps ONE packed forest plan
    (spec, params) current across admissions and evictions.

    Offsets are per-slot row offsets into the packed space; `pack_maps`
    produces the (pack, unpack) index maps `_topo_tree_masked_attention`
    consumes for a given prefill group. A group's maps cover ONLY that
    group's slots: other live blocks (and ghost rows) carry junk but the
    block-diagonal mask gives them zero coupling with the group's rows.
    """

    def __init__(self, num_slots: int, leaf_size: int = 8,
                 rebuild_ghost_frac: float = 0.5):
        self.B = int(num_slots)
        self.leaf_size = int(leaf_size)
        self.rebuild_ghost_frac = float(rebuild_ghost_frac)
        self.slot_tree: list[WeightedTree | None] = [None] * self.B
        self.slot_offset = np.full(self.B, -1, dtype=np.int64)
        self.spec = self.params = None
        self.stats = {"builds": 0, "incremental_evictions": 0,
                      "ghost_rebuilds": 0, "fallback_rebuilds": 0,
                      "swaps_validated": 0}

    # -- plan membership ----------------------------------------------------

    def any_active(self) -> bool:
        return any(t is not None for t in self.slot_tree)

    def admit(self, slot: int, tree: WeightedTree) -> None:
        """Install `tree` for `slot`. Joins always repack: appending to a
        packed forest would need an insert_leaf cascade per vertex AND a
        root graft the incremental engine doesn't support, while a fresh
        forest compile is cached (memory + optional disk plan cache)."""
        self.slot_tree[slot] = tree
        self._rebuild()

    def evict(self, slot: int) -> None:
        """Drop `slot`'s tree. Patches the live plan incrementally — other
        slots keep their row offsets — unless ghosts pile up or the
        incremental engine refuses (then a full rebuild, counted)."""
        tree = self.slot_tree[slot]
        if tree is None:
            return
        self.slot_tree[slot] = None
        if not self.any_active():
            self.spec = self.params = None
            self.slot_offset[:] = -1
            return
        off = int(self.slot_offset[slot])
        roots = self._plan_roots()
        keep = 0
        for v in range(tree.num_vertices):
            if off + v in roots:
                keep = v
                break
        ops = [("delete_leaf", off + v) for v in _peel_order(tree, keep)]
        try:
            self.spec, self.params = ftfi.update_plan(self.spec, self.params,
                                                      ops)
            self.stats["incremental_evictions"] += 1
            self.stats["swaps_validated"] += 1  # update_plan validates
        except (ValueError, ftfi.PlanValidationError):
            self.stats["fallback_rebuilds"] += 1
            self._rebuild()
            return
        self.slot_offset[slot] = -1
        ghosts = self.spec.ghosts
        n_ghost = 0 if ghosts is None else len(ghosts)
        if n_ghost > self.rebuild_ghost_frac * self.spec.n:
            self.stats["ghost_rebuilds"] += 1
            self._rebuild()

    def _plan_roots(self) -> set:
        """Vertices absent from the root-path CSR = the per-tree plan roots
        (delete_leaf cannot remove them)."""
        if self.spec is None or self.spec.path_rows is None:
            return set()
        return set(range(self.spec.n)) - set(
            int(v) for v in np.unique(self.spec.path_rows))

    def _rebuild(self) -> None:
        live = [(s, t) for s, t in enumerate(self.slot_tree) if t is not None]
        self.slot_offset[:] = -1
        if not live:
            self.spec = self.params = None
            return
        forest = Forest([t for _, t in live])
        self.spec, self.params = ftfi.build(forest, leaf_size=self.leaf_size,
                                            reweightable=True)
        plan_guard.validate(self.spec, self.params,
                            where="forest-mask swap")
        self.stats["builds"] += 1
        self.stats["swaps_validated"] += 1
        for (s, _), off in zip(live, forest.offsets[:-1]):
            self.slot_offset[s] = int(off)

    # -- index maps for the attention layer ---------------------------------

    def pack_maps(self, Lp: int, slots: list[int], batch_size: int):
        """(pack (N,), unpack (batch_size*Lp,)) int32 maps for a prefill
        group over the engine's full slot batch (batch row == slot index).

        Only the listed `slots`' blocks are mapped — every other packed row
        (other live slots mid-decode, ghosts) stays -1 and therefore
        contributes zero mass and receives zero field; every other batch
        row's tokens stay -1 and get zero attention output (those rows are
        length-0 padding in the prefill call anyway)."""
        if self.spec is None:
            raise RuntimeError("pack_maps called with no live forest plan")
        N = int(self.spec.n)
        pack = np.full(N, -1, dtype=np.int32)
        unpack = np.full(batch_size * Lp, -1, dtype=np.int32)
        for s in slots:
            tree = self.slot_tree[s]
            off = int(self.slot_offset[s])
            if tree is None or off < 0:
                raise RuntimeError(f"slot {s} has no tree in the forest plan")
            n = tree.num_vertices
            idx = np.arange(n, dtype=np.int32)
            pack[off + idx] = s * Lp + idx
            unpack[s * Lp + idx] = off + idx
        return pack, unpack
