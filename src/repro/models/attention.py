"""Attention variants: full softmax (GQA/MQA/local/MLA), Performer (FAVOR+
deterministic phi), and Topological Performer — the paper's technique
(Sec 4.4 / Alg. 1) as a first-class option.

Sequence topological masks are f(|i-j|) with f = g(sum_t a_t x^t):
  - train/prefill: exact — separable decay path (g=exp, t<=1) or the
    Toeplitz-FFT Algorithm-1 path (any g, t) chunked over feature columns;
  - decode: O(1)-state cordial recurrences; non-separable f uses a Chebyshev
    rank-R separable expansion (spectral accuracy) — beyond-paper (DESIGN §3).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.sharding import shard, shard_q_heads
from repro.models.layers import apply_rope, dense_init, rms_norm, softcap


# ----------------------------------------------------------------------------
# params
# ----------------------------------------------------------------------------


def attn_init(key, cfg, dtype=jnp.float32):
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 8)
    p = {
        "wq": dense_init(ks[0], (d, H * hd), dtype=dtype),
        "wk": dense_init(ks[1], (d, KV * hd), dtype=dtype),
        "wv": dense_init(ks[2], (d, KV * hd), dtype=dtype),
        "wo": dense_init(ks[3], (H * hd, d), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
    return p


def mla_init(key, cfg, dtype=jnp.float32):
    d, H = cfg.d_model, cfg.num_heads
    nope, rope, vdim = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    r_kv, r_q = cfg.kv_lora_rank, cfg.q_lora_rank
    ks = jax.random.split(key, 9)
    p = {
        "w_dkv": dense_init(ks[0], (d, r_kv), dtype=dtype),
        "kv_norm": jnp.zeros((r_kv,), dtype),
        "w_ukv": dense_init(ks[1], (r_kv, H * (nope + vdim)), dtype=dtype),
        "w_kr": dense_init(ks[2], (d, rope), dtype=dtype),
        "wo": dense_init(ks[3], (H * vdim, d), dtype=dtype),
    }
    if r_q > 0:
        p["w_dq"] = dense_init(ks[4], (d, r_q), dtype=dtype)
        p["q_norm"] = jnp.zeros((r_q,), dtype)
        p["w_uq"] = dense_init(ks[5], (r_q, H * (nope + rope)), dtype=dtype)
    else:
        p["wq"] = dense_init(ks[6], (d, H * (nope + rope)), dtype=dtype)
    return p


def topo_init(key, cfg, dtype=jnp.float32):
    """3 learnable scalars (synced) or 3/head (asynced): [a_0..a_t] + scale."""
    t = cfg.topo_degree
    lead = () if cfg.topo_synced else (cfg.num_heads,)
    coeffs = np.zeros(lead + (t + 1,), dtype=np.float32)
    coeffs[..., 0] = 0.0
    if t >= 1:
        coeffs[..., 1] = -1.0  # init: decaying mask
    return {"coeffs": jnp.asarray(coeffs, dtype),
            "logit_scale": jnp.zeros(lead, dtype)}


# ----------------------------------------------------------------------------
# full softmax attention (GQA / MQA; optional local window)
# ----------------------------------------------------------------------------


def _positions_vec(pos, B):
    """Decode positions as a (B,) int32 vector. A scalar () broadcasts to the
    whole batch (lockstep decode); a (B,) vector passes through unchanged —
    per-slot positions are what make mid-wave admission legal in the serve
    engine (each slot writes/masks its own KV row independently)."""
    p = jnp.asarray(pos, jnp.int32)
    if p.ndim == 0:
        p = jnp.broadcast_to(p, (B,))
    return p


def _project_qkv(cfg, p, x, positions, rope=True):
    B, L, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, L, H, hd)
    k = k.reshape(B, L, KV, hd)
    v = v.reshape(B, L, KV, hd)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = shard_q_heads(q)
    k = shard(k, ("batch", "seq", "kv_heads", "head_dim"))
    v = shard(v, ("batch", "seq", "kv_heads", "head_dim"))
    return q, k, v


def _sdpa(cfg, q, k, v, mask):
    """q: (B,Lq,H,hd); k,v: (B,Lk,KV,hd); mask: (1|B, 1, Lq, Lk) bool."""
    B, Lq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Lq, KV, G, hd)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    logits = softcap(logits, cfg.attn_logit_softcap)
    logits = jnp.where(mask[:, :, None], logits, -1e30)  # mask: (B,1,Lq,Lk)->(B,1,1,Lq,Lk)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w.astype(v.dtype), v)
    return out.reshape(B, Lq, H, hd)


def _sdpa_chunked(cfg, q, k, v, causal: bool, window: int, blk: int = 512):
    """Flash-style attention in plain XLA: lax.scan over KV blocks with
    online-softmax stats. Never materializes the (Lq, Lk) score matrix —
    peak temp drops from O(L^2) to O(L * blk). Exact (fp32 statistics).
    This is the dry-run/CPU twin of kernels/flash_attention (Pallas is the
    TPU hot path); selected via cfg.attn_impl == 'chunked'."""
    B, Lq, H, hd = q.shape
    Lk = k.shape[1]
    KV = k.shape[2]
    G = H // KV
    blk = min(blk, Lk)
    if Lk % blk:  # fall back when blocks don't tile
        return None
    nblk = Lk // blk
    qg = (q.reshape(B, Lq, KV, G, hd).astype(jnp.float32)
          / math.sqrt(hd)).transpose(0, 2, 3, 1, 4)  # (B,KV,G,Lq,hd)
    kb = k.reshape(B, nblk, blk, KV, hd).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(B, nblk, blk, KV, v.shape[-1]).transpose(1, 0, 3, 2, 4)
    qpos = jnp.arange(Lq)

    def step(carry, inp):
        m, l, acc = carry
        kc, vc, bi = inp
        s = jnp.einsum("bkgqh,bksh->bkgqs", qg, kc.astype(jnp.float32))
        s = softcap(s, cfg.attn_logit_softcap)
        kpos = bi * blk + jnp.arange(blk)
        mask = jnp.ones((Lq, blk), bool)
        if causal:
            mask = mask & (qpos[:, None] >= kpos[None, :])
        if window and window > 0:
            mask = mask & (qpos[:, None] - kpos[None, :] < window)
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        pexp = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(pexp, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bksh->bkgqh", pexp, vc.astype(jnp.float32))
        return (m_new, l_new, acc_new), ()

    vd = v.shape[-1]  # may differ from hd (MLA: qk 192, v 128)
    m0 = jnp.full((B, KV, G, Lq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Lq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Lq, vd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (kb, vb, jnp.arange(nblk)))
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l[..., None]).transpose(0, 3, 1, 2, 4)
    return out.reshape(B, Lq, H, vd).astype(q.dtype)


def full_attention_train(cfg, p, x, positions, causal=True, window=0,
                         rope=True, kv_x=None, kv_positions=None):
    """Training/prefill attention; kv_x enables cross-attention."""
    B, L, _ = x.shape
    if kv_x is None:
        q, k, v = _project_qkv(cfg, p, x, positions, rope=rope)
        Lk = L
        kpos = positions
    else:
        q, _, _ = _project_qkv(cfg, p, x, positions, rope=rope)  # reuse wq
        # cross: keys/values from encoder memory
        KV, hd = cfg.num_kv_heads, cfg.head_dim
        k = (kv_x @ p["wk"]).reshape(kv_x.shape[0], kv_x.shape[1], KV, hd)
        v = (kv_x @ p["wv"]).reshape(kv_x.shape[0], kv_x.shape[1], KV, hd)
        Lk = kv_x.shape[1]
        kpos = kv_positions
    if getattr(cfg, "attn_impl", "naive") == "chunked":
        # positions are contiguous aranges at every call site, so the
        # chunked path's internally-derived masks are equivalent
        out = _sdpa_chunked(cfg, q, k, v, causal, window)
        if out is not None:
            return out.reshape(x.shape[0], L, -1) @ p["wo"]
    qi = positions[..., :, None] if positions.ndim > 1 else positions[:, None]
    ki = (kpos[..., None, :] if kpos.ndim > 1 else kpos[None, :])
    mask = jnp.ones((1, L, Lk), bool)
    if causal:
        mask = mask & (qi >= ki)
    if window and window > 0:
        mask = mask & (qi - ki < window)
    mask = jnp.broadcast_to(mask, (x.shape[0],) + mask.shape[1:]) if mask.shape[0] != x.shape[0] else mask
    out = _sdpa(cfg, q, k, v, mask[:, None] if mask.ndim == 3 else mask)
    B_, Lq, H, hd = out.shape
    return out.reshape(B_, Lq, H * hd) @ p["wo"]


def full_attention_decode(cfg, p, x, pos, cache, window=0, rope=True):
    """One-token decode. cache: {'k','v'} (B,S,KV,hd); pos: () or (B,) int32
    (per-slot positions — each batch row writes and masks its own row)."""
    B = x.shape[0]
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    pos_v = _positions_vec(pos, B)
    positions = pos_v[:, None]
    q, k_new, v_new = _project_qkv(cfg, p, x, positions, rope=rope)
    S = cache["k"].shape[1]
    rows = jnp.arange(B)
    k = cache["k"].at[rows, pos_v].set(k_new[:, 0].astype(cache["k"].dtype))
    v = cache["v"].at[rows, pos_v].set(v_new[:, 0].astype(cache["v"].dtype))
    idx = jnp.arange(S)
    mask = idx[None, None, :] <= pos_v[:, None, None]  # (B,1,S)
    if window and window > 0:
        mask = mask & (idx[None, None, :] > pos_v[:, None, None] - window)
    out = _sdpa(cfg, q, k, v, mask[:, None])
    out = out.reshape(B, 1, H * hd) @ p["wo"]
    return out, {"k": k, "v": v}


def full_attention_prefill(cfg, p, x, positions, lengths, cache,
                           window=0, rope=True):
    """Whole-prompt prefill that writes KV rows [0, Lp) straight into the
    decode cache (the fused replacement for replaying prompt tokens through
    decode). x: (B, Lp, d); lengths: (B,) — rows with lengths[b] == 0 keep
    their cache untouched (they belong to other live slots). Rows at or past
    lengths[b] may hold junk keys: decode at position q rewrites row q before
    its own causal mask can see it, so they are always overwritten-before-
    read. Returns (out (B, Lp, d), new_cache)."""
    B, Lp, _ = x.shape
    q, k_new, v_new = _project_qkv(cfg, p, x, positions, rope=rope)
    idx = jnp.arange(Lp)
    mask = (idx[:, None] >= idx[None, :])[None]  # causal (1,Lp,Lp)
    if window and window > 0:
        mask = mask & (idx[None, :, None] - idx[None, None, :] < window)
    out = _sdpa(cfg, q, k_new, v_new, mask[:, None])
    out = out.reshape(B, Lp, -1) @ p["wo"]
    valid = (lengths > 0)[:, None, None, None]
    k = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), 0, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), 0, axis=1)
    return out, {"k": jnp.where(valid, k, cache["k"]),
                 "v": jnp.where(valid, v, cache["v"])}


def local_attention_decode_init(cfg, B, dtype):
    W = cfg.local_window
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    return {"k": jnp.zeros((B, W, KV, hd), dtype),
            "v": jnp.zeros((B, W, KV, hd), dtype),
            "kpos": jnp.full((B, W), -1, jnp.int32)}


def local_attention_decode(cfg, p, x, pos, cache):
    """Sliding-window decode with a per-slot ring buffer of size W (positions
    stored alongside keys; RoPE applied at write time with the true
    position). pos: () or (B,) int32."""
    B = x.shape[0]
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    W = cfg.local_window
    pos_v = _positions_vec(pos, B)
    positions = pos_v[:, None]
    q, k_new, v_new = _project_qkv(cfg, p, x, positions)
    slot = jnp.mod(pos_v, W)
    rows = jnp.arange(B)
    k = cache["k"].at[rows, slot].set(k_new[:, 0].astype(cache["k"].dtype))
    v = cache["v"].at[rows, slot].set(v_new[:, 0].astype(cache["v"].dtype))
    kpos = cache["kpos"].at[rows, slot].set(pos_v)
    mask = (kpos >= 0) & (kpos <= pos_v[:, None])  # ring enforces the window
    out = _sdpa(cfg, q, k, v, mask[:, None, None, :])
    out = out.reshape(B, 1, H * hd) @ p["wo"]
    return out, {"k": k, "v": v, "kpos": kpos}


def local_attention_prefill(cfg, p, x, positions, lengths, cache):
    """Fused prefill for the sliding-window ring buffer: attention over the
    prompt with the window mask, then the last min(W, lengths[b]) tokens of
    each valid row are scattered into their ring slots (position p lives at
    p % W) with kpos = -1 everywhere else. Unlike the (B, S) cache, junk
    rows here WOULD be visible to later decode steps, so the ring is built
    explicitly from valid tokens only."""
    B, Lp, _ = x.shape
    W = cfg.local_window
    q, k_new, v_new = _project_qkv(cfg, p, x, positions)
    idx = jnp.arange(Lp)
    mask = ((idx[:, None] >= idx[None, :])
            & (idx[:, None] - idx[None, :] < W))[None]
    out = _sdpa(cfg, q, k_new, v_new, mask[:, None])
    out = out.reshape(B, Lp, -1) @ p["wo"]
    widx = lengths[:, None] - W + jnp.arange(W)[None, :]  # (B, W) positions
    valid_w = (widx >= 0) & (lengths[:, None] > 0)
    gidx = jnp.clip(widx, 0, max(Lp - 1, 0))
    rows = jnp.arange(B)[:, None]
    kg = jnp.where(valid_w[..., None, None], k_new[rows, gidx], 0.0)
    vg = jnp.where(valid_w[..., None, None], v_new[rows, gidx], 0.0)
    # W consecutive positions hit W distinct ring slots: scatter is safe
    slot_idx = jnp.mod(widx, W)
    ring_k = jnp.zeros_like(cache["k"]).at[rows, slot_idx].set(
        kg.astype(cache["k"].dtype))
    ring_v = jnp.zeros_like(cache["v"]).at[rows, slot_idx].set(
        vg.astype(cache["v"].dtype))
    ring_p = jnp.full_like(cache["kpos"], -1).at[rows, slot_idx].set(
        jnp.where(valid_w, widx, -1).astype(jnp.int32))
    valid = lengths > 0
    return out, {
        "k": jnp.where(valid[:, None, None, None], ring_k, cache["k"]),
        "v": jnp.where(valid[:, None, None, None], ring_v, cache["v"]),
        "kpos": jnp.where(valid[:, None], ring_p, cache["kpos"]),
    }


# ----------------------------------------------------------------------------
# MLA (DeepSeek multi-head latent attention)
# ----------------------------------------------------------------------------


def _mla_q(cfg, p, x, positions):
    B, L, _ = x.shape
    H, nope, rope = cfg.num_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    if cfg.q_lora_rank > 0:
        ql = rms_norm(x @ p["w_dq"], p["q_norm"], cfg.norm_eps, plus_one=True)
        q = ql @ p["w_uq"]
    else:
        q = x @ p["wq"]
    q = q.reshape(B, L, H, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_attention_train(cfg, p, x, positions, causal=True):
    B, L, _ = x.shape
    H = cfg.num_heads
    nope, rope, vdim = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(cfg, p, x, positions)
    ckv = rms_norm(x @ p["w_dkv"], p["kv_norm"], cfg.norm_eps, plus_one=True)
    kv = (ckv @ p["w_ukv"]).reshape(B, L, H, nope + vdim)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    k_rope = apply_rope((x @ p["w_kr"]).reshape(B, L, 1, rope), positions,
                        cfg.rope_theta)
    k_nope = shard(k_nope, ("batch", "seq", "heads", None))
    if getattr(cfg, "attn_impl", "naive") == "chunked":
        # pack nope+rope into one head_dim and run the flash path (§Perf B3):
        # identical math, no (L, L) logits in HBM
        q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)
        k_cat = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (B, L, H, rope))], axis=-1)
        out = _sdpa_chunked(cfg, q_cat, k_cat, v, causal, 0)
        if out is not None:
            return out.reshape(B, L, H * vdim) @ p["wo"]
    scale = 1.0 / math.sqrt(nope + rope)
    logits = (jnp.einsum("blhn,bshn->bhls", q_nope.astype(jnp.float32),
                         k_nope.astype(jnp.float32))
              + jnp.einsum("blhr,bsxr->bhls", q_rope.astype(jnp.float32),
                           k_rope.astype(jnp.float32))) * scale
    if causal:
        qi = jnp.arange(L)
        logits = jnp.where(qi[None, None, :, None] >= qi[None, None, None, :],
                           logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhls,bshv->blhv", w.astype(v.dtype), v)
    return out.reshape(B, L, H * vdim) @ p["wo"]


def mla_attention_decode(cfg, p, x, pos, cache):
    """Absorbed-matmul decode: cache holds only (c_kv, k_rope) — the MLA win.

    q_nope is absorbed through W_uk so scores and values are computed in the
    r_kv-dim latent space; per-step cost is O(S * (r_kv + rope) * H).
    """
    B = x.shape[0]
    H = cfg.num_heads
    nope, rope, vdim, r_kv = (cfg.qk_nope_dim, cfg.qk_rope_dim,
                              cfg.v_head_dim, cfg.kv_lora_rank)
    pos_v = _positions_vec(pos, B)
    positions = pos_v[:, None]
    q_nope, q_rope = _mla_q(cfg, p, x, positions)  # (B,1,H,*)
    ckv_new = rms_norm(x @ p["w_dkv"], p["kv_norm"], cfg.norm_eps, plus_one=True)
    krope_new = apply_rope((x @ p["w_kr"]).reshape(B, 1, 1, rope), positions,
                           cfg.rope_theta)
    rows = jnp.arange(B)
    ckv = cache["ckv"].at[rows, pos_v].set(
        ckv_new[:, 0].astype(cache["ckv"].dtype))
    krope = cache["krope"].at[rows, pos_v].set(
        krope_new[:, 0, 0].astype(cache["krope"].dtype))
    # absorb: W_ukv columns split into per-head W_uk (r,nope) and W_uv (r,vdim)
    w_ukv = p["w_ukv"].reshape(r_kv, H, nope + vdim)
    w_uk, w_uv = w_ukv[..., :nope], w_ukv[..., nope:]
    q_lat = jnp.einsum("blhn,rhn->blhr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))  # (B,1,H,r_kv)
    scale = 1.0 / math.sqrt(nope + rope)
    logits = (jnp.einsum("blhr,bsr->bhls", q_lat, ckv.astype(jnp.float32))
              + jnp.einsum("blhr,bsr->bhls", q_rope.astype(jnp.float32),
                           krope.astype(jnp.float32))) * scale
    S = ckv.shape[1]
    mask = jnp.arange(S)[None, None, None, :] <= pos_v[:, None, None, None]
    logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out_lat = jnp.einsum("bhls,bsr->blhr", w, ckv.astype(jnp.float32))
    out = jnp.einsum("blhr,rhv->blhv", out_lat, w_uv.astype(jnp.float32))
    out = out.astype(x.dtype).reshape(B, 1, H * vdim) @ p["wo"]
    return out, {"ckv": ckv, "krope": krope}


def mla_attention_prefill(cfg, p, x, positions, lengths, cache):
    """Fused MLA prefill: train-path attention over the prompt plus a direct
    write of the latent (c_kv, k_rope) rows [0, Lp) into the decode cache.
    Junk rows past lengths[b] are overwritten-before-read exactly as in
    `full_attention_prefill`; rows with lengths[b] == 0 are untouched."""
    B, Lp, _ = x.shape
    rope = cfg.qk_rope_dim
    out = mla_attention_train(cfg, p, x, positions, causal=True)
    ckv_new = rms_norm(x @ p["w_dkv"], p["kv_norm"], cfg.norm_eps,
                       plus_one=True)
    krope_new = apply_rope((x @ p["w_kr"]).reshape(B, Lp, 1, rope), positions,
                           cfg.rope_theta)[:, :, 0]
    ckv = jax.lax.dynamic_update_slice_in_dim(
        cache["ckv"], ckv_new.astype(cache["ckv"].dtype), 0, axis=1)
    krope = jax.lax.dynamic_update_slice_in_dim(
        cache["krope"], krope_new.astype(cache["krope"].dtype), 0, axis=1)
    valid = (lengths > 0)[:, None, None]
    return out, {"ckv": jnp.where(valid, ckv, cache["ckv"]),
                 "krope": jnp.where(valid, krope, cache["krope"])}


# ----------------------------------------------------------------------------
# Performer features (deterministic phi, paper Table 1)
# ----------------------------------------------------------------------------


def phi_features(x, kind: str):
    """Elementwise nonneg feature map applied to hd^-1/4-scaled q/k."""
    hd = x.shape[-1]
    x = x.astype(jnp.float32) * (hd ** -0.25)
    if kind == "relu":
        return jax.nn.relu(x) + 1e-6
    if kind == "sq":
        return jnp.square(x)
    if kind == "quart":
        return jnp.square(jnp.square(x))
    if kind == "exp":
        return jnp.exp(jnp.clip(x, -20.0, 8.0))
    raise ValueError(kind)


def causal_linear_attention(qf, kf, v, log_gamma=None, chunk=256):
    """Unmasked (or gamma-decayed) causal linear attention, chunked scan.

    qf/kf: (B,L,H,m) nonneg; v: (B,L,H,hd); log_gamma: per-head () or (H,)
    log decay (mask gamma^(i-j), the separable g=exp,t=1 topological mask).
    Returns (num (B,L,H,hd), den (B,L,H)).
    """
    B, L, H, m = qf.shape
    hd = v.shape[-1]
    C = min(chunk, L)
    assert L % C == 0, f"L={L} must be divisible by chunk={C}"
    nC = L // C
    qf_ = qf.reshape(B, nC, C, H, m).transpose(1, 0, 2, 3, 4)
    kf_ = kf.reshape(B, nC, C, H, m).transpose(1, 0, 2, 3, 4)
    v_ = v.reshape(B, nC, C, H, hd).transpose(1, 0, 2, 3, 4)
    i = jnp.arange(C, dtype=jnp.float32)
    if log_gamma is None:
        lg = jnp.zeros((H,), jnp.float32)
    else:
        lg = jnp.broadcast_to(jnp.asarray(log_gamma, jnp.float32), (H,))
    # within-chunk decay factors
    dmat = jnp.exp(lg[None, None, :] * (i[:, None, None] - i[None, :, None]))  # (C,C,H)
    tri = (i[:, None] >= i[None, :])[..., None]
    dmat = jnp.where(tri, dmat, 0.0)
    q_in = jnp.exp(lg[None, :] * i[:, None])  # decay of state across chunk (C,H)
    k_out = jnp.exp(lg[None, :] * (C - i[:, None]))  # contribution into next state

    def step(carry, inp):
        S, z = carry  # (B,H,m,hd), (B,H,m)
        qc, kc, vc = inp  # (B,C,H,m/hd)
        qcf = qc.astype(jnp.float32)
        kcf = kc.astype(jnp.float32)
        vcf = vc.astype(jnp.float32)
        # intra-chunk masked quadratic
        scores = jnp.einsum("bchm,bdhm->bcdh", qcf, kcf) * dmat[None]
        num_in = jnp.einsum("bcdh,bdhv->bchv", scores, vcf)
        den_in = jnp.sum(scores, axis=2)  # (B,C,H)
        # inter-chunk from carried state
        num_x = jnp.einsum("bchm,bhmv->bchv", qcf * q_in[None, :, :, None], S)
        den_x = jnp.einsum("bchm,bhm->bch", qcf * q_in[None, :, :, None], z)
        # update state
        gC = jnp.exp(lg * C)
        S = S * gC[None, :, None, None] + jnp.einsum(
            "bchm,bchv->bhmv", kcf * k_out[None, :, :, None], vcf)
        z = z * gC[None, :, None] + jnp.sum(kcf * k_out[None, :, :, None], axis=1)
        return (S, z), (num_in + num_x, den_in + den_x)

    S0 = jnp.zeros((B, H, m, hd), jnp.float32)
    z0 = jnp.zeros((B, H, m), jnp.float32)
    _, (num, den) = jax.lax.scan(step, (S0, z0), (qf_, kf_, v_))
    num = num.transpose(1, 0, 2, 3, 4).reshape(B, L, H, hd)
    den = den.transpose(1, 0, 2, 3).reshape(B, L, H)
    return num, den


def linear_attention_output(num, den, eps=1e-6):
    den = jnp.where(jnp.abs(den) < eps, eps, den)
    return (num / den[..., None]).astype(num.dtype)


# ----------------------------------------------------------------------------
# Topological Performer: masks f(|i-j|) on the token path metric
# ----------------------------------------------------------------------------


def topo_mask_coeffs(cfg, p_topo):
    """Effective coefficients (H, t+1) and per-head scale, stability-shaped:
    the degree-1 coefficient is forced <= 0 (decay) via -softplus."""
    c = p_topo["coeffs"].astype(jnp.float32)
    if c.ndim == 1:
        c = jnp.broadcast_to(c[None], (cfg.num_heads, c.shape[0]))
    out = [c[:, 0]]
    if c.shape[1] > 1:
        out.append(-jax.nn.softplus(c[:, 1]))
    for t in range(2, c.shape[1]):
        out.append(-jax.nn.softplus(c[:, t]) if cfg.topo_g == "exp" else c[:, t])
    return jnp.stack(out, axis=1)  # (H, t+1)


def topo_logit_scale(cfg, p_topo):
    """Per-head feature temperature e^{logit_scale} — the remaining learnable
    mask scalar. Applied to q BEFORE phi (a post-phi score scale would cancel
    exactly in the num/den normalization); identity at init (logit_scale=0)."""
    ls = p_topo["logit_scale"].astype(jnp.float32)
    return jnp.broadcast_to(jnp.exp(ls), (cfg.num_heads,))


def resolve_topo_backend(cfg, backend: str | None = None) -> str:
    """Integrator/plan backend for tree- and grid-based topological masks,
    shared by the ViT grid path and plan-serving. Resolution follows the
    topo impl axis: explicit `backend` arg > cfg.topo_backend >
    cfg.topo_attn_impl ("pallas" -> the fused fdist_matvec executor
    backend, anything else -> "plan") — then filtered through the
    degradation ladder, so a rung that already failed a health probe
    (`ladder.block_backend`) is never selected again this process."""
    from repro.core import ladder

    req = (backend or getattr(cfg, "topo_backend", None)
           or ("pallas" if getattr(cfg, "topo_attn_impl", "fft") == "pallas"
               else "plan"))
    return ladder.effective_backend(req) if req in ladder.LADDER else req


def topo_attention_train(cfg, p, p_topo, x, positions, causal=True):
    """Masked linear attention (Alg. 1) with the sequence topological mask.

    Impl dispatch (cfg.topo_attn_impl):
      ref    — dense (L, L) mask oracle, O(L^2) (tests/tiny L);
      fft    — separable-decay chunked scan (g=exp, deg<=1) or the
               Toeplitz-FFT Algorithm-1 path chunked over feature columns;
      pallas — fused kernels/topo_linear_attention step (Pallas on TPU, its
               XLA chunked-scan twin elsewhere).
    """
    B, L, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x, positions, rope=False)
    k, v = _expand_kv(cfg, k, v)
    scale = topo_logit_scale(cfg, p_topo)  # (H,)
    qf = phi_features(q * scale[None, None, :, None], cfg.performer_phi)
    kf = phi_features(k, cfg.performer_phi)
    # multi-device: the masked linear-attention sweep is independent per
    # (batch, head) — keep the phi fields partitioned batch-over-data and
    # heads-over-model so pjit never gathers the full (B, L, H, m) field
    qf = shard(qf, ("field_batch", None, "heads", None))
    kf = shard(kf, ("field_batch", None, "heads", None))
    v = shard(v, ("field_batch", None, "heads", None))
    coeffs = topo_mask_coeffs(cfg, p_topo)  # (H, t+1)
    s = cfg.topo_dist_scale
    impl = getattr(cfg, "topo_attn_impl", "fft")
    if impl not in ("ref", "fft", "pallas"):
        raise ValueError(f"cfg.topo_attn_impl={impl!r}: expected one of "
                         "'ref', 'fft', 'pallas'")
    if impl in ("pallas", "ref"):
        if impl == "pallas":
            from repro.kernels.topo_linear_attention.ops import (
                topo_linear_attention as fn)
        else:
            from repro.kernels.topo_linear_attention.ref import (
                topo_linear_attention_ref as fn)
        out = fn(qf.transpose(0, 2, 1, 3), kf.transpose(0, 2, 1, 3),
                 v.transpose(0, 2, 1, 3).astype(jnp.float32), coeffs,
                 g=cfg.topo_g, dist_scale=s,
                 causal=causal).transpose(0, 2, 1, 3)
    elif cfg.topo_g == "exp" and cfg.topo_degree <= 1:
        # separable: mask = e^{a0} gamma^(i-j). The e^{a0} factor cancels in
        # the normalization EXCEPT where the eps denominator clamp binds —
        # fold it into kf so num/den match the other impls bit-for-bit there
        kf = kf * jnp.exp(coeffs[:, 0])[None, None, :, None]
        log_gamma = coeffs[:, 1] * s if coeffs.shape[1] > 1 else jnp.zeros(cfg.num_heads)
        if causal:
            num, den = causal_linear_attention(qf, kf, v, log_gamma)
        else:
            nf, df = causal_linear_attention(qf, kf, v, log_gamma)
            nb, db = causal_linear_attention(qf[:, ::-1], kf[:, ::-1], v[:, ::-1], log_gamma)
            # forward + backward - diagonal (counted twice)
            diag = jnp.einsum("blhm,blhm->blh", qf, kf)
            num = nf + nb[:, ::-1] - diag[..., None] * v.astype(jnp.float32)
            den = df + db[:, ::-1] - diag
        out = linear_attention_output(num, den)
    else:
        out = _topo_fft_attention(cfg, qf, kf, v, coeffs, causal)
    out = shard(out, ("field_batch", None, "heads", None))
    H, hd = cfg.num_heads, cfg.head_dim
    out = out.astype(x.dtype).reshape(B, L, H * hd) @ p["wo"]
    return out


def _expand_kv(cfg, k, v):
    G = cfg.num_heads // cfg.num_kv_heads
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    return k, v


def _topo_fft_attention(cfg, qf, kf, v, coeffs, causal, col_chunk=8):
    """Algorithm 1 with Toeplitz-FFT FastMult, chunked over feature columns.

    Exact for any g/degree; memory O(B L H chunk*hd) instead of O(B L H m hd).
    Accumulation is float32 end-to-end: inputs are upcast once, the single
    `num` accumulator is allocated once in fp32, and the denominator needs no
    column chunking at all — one fastmult over the m feature columns (only
    the k⊗v expansion is chunked, since that is what blows up memory).
    """
    from repro.core.masks import sequence_mask_values

    B, L, H, m = qf.shape
    hd = v.shape[-1]
    from repro.core.toeplitz import causal_toeplitz_matvec, symmetric_toeplitz_matvec
    F = sequence_mask_values(cfg.topo_g, coeffs, L, cfg.topo_dist_scale)  # (H, L)
    fastmult = causal_toeplitz_matvec if causal else symmetric_toeplitz_matvec
    Fb = F[None]  # (1,H,L)
    qf32, kf32, v32 = (t.astype(jnp.float32) for t in (qf, kf, v))
    d2 = fastmult(Fb, kf32.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    den = jnp.einsum("blhm,blhm->blh", qf32, d2)
    num = jnp.zeros((B, L, H, hd), jnp.float32)
    for c0 in range(0, m, col_chunk):
        c1 = min(c0 + col_chunk, m)
        kc = kf32[..., c0:c1]  # (B,L,H,c)
        v1 = kc[..., None] * v32[..., None, :]  # (B,L,H,c,hd)
        v1 = v1.reshape(B, L, H, -1).transpose(0, 2, 1, 3)  # (B,H,L,c*hd)
        d1 = fastmult(Fb, v1).transpose(0, 2, 1, 3).reshape(B, L, H, c1 - c0, hd)
        num = num + jnp.einsum("blhc,blhcv->blhv", qf32[..., c0:c1], d1)
    assert num.dtype == jnp.float32 and den.dtype == jnp.float32, (
        "topo fft accumulators must stay fp32")
    return linear_attention_output(num, den)


# --- decode: cordial / Chebyshev-separable O(1) states -----------------------


def topo_decomposition(cfg, coeffs, L: int, rank: int = 24):
    """f(i-j) = sum_r alpha_r(i) beta_r(j) for i,j in [0,L).

    Exact rank-1 for g=exp,t<=1; otherwise the Chebyshev rank-`rank`
    expansion of (i,j) -> f(i-j) on [0,L)^2 (spectral accuracy for smooth f)
    shared with the fused attention kernel
    (core.masks.chebyshev_separable_expansion) — decode states and the fused
    train/prefill path are built from the SAME node grid and Bmat, but decode
    Lagrange-evaluates only the single queried position (O(1) per token, not
    an O(L) table rebuild per step).
    Returns (alpha(pos)->(H,R), beta(pos)->(H,R)).
    """
    from repro.core.masks import chebyshev_separable_expansion

    s = cfg.topo_dist_scale
    H = coeffs.shape[0]
    if cfg.topo_g == "exp" and cfg.topo_degree <= 1:
        a1 = coeffs[:, 1] if coeffs.shape[1] > 1 else jnp.zeros(H)

        def alpha(pos):
            return jnp.exp(a1 * s * pos)[..., None]  # (H,1)

        def beta(pos):
            return jnp.exp(-a1 * s * pos)[..., None]

        return alpha, beta, 1
    nodes, Bmat = chebyshev_separable_expansion(cfg.topo_g, coeffs, L, s, rank)
    nodes = jnp.asarray(nodes)

    def lagr(pos):  # pos: () -> (rank,)
        from repro.core.engines.plan import _lagrange_batched
        pts = jnp.reshape(jnp.asarray(pos, jnp.float32), (1, 1))
        return _lagrange_batched(pts, nodes[None, :])[0, 0]

    def alpha(pos):
        return jnp.einsum("r,hrq->hq", lagr(pos), Bmat)  # (H, rank)

    def beta(pos):
        return jnp.broadcast_to(lagr(pos)[None], (H, rank))

    return alpha, beta, rank


def topo_decode_init(cfg, B, L, dtype=jnp.float32, rank: int = 24):
    H, hd = cfg.num_heads, cfg.head_dim
    m = hd  # deterministic elementwise phi keeps feature dim = head_dim
    R = 1 if (cfg.topo_g == "exp" and cfg.topo_degree <= 1) else rank
    return {
        "S": jnp.zeros((B, H, R, m, hd), dtype),
        "z": jnp.zeros((B, H, R, m), dtype),
    }


def topo_attention_decode(cfg, p, p_topo, x, pos, cache, L: int, rank: int = 24):
    """O(1)-state masked linear attention decode step. pos: () or (B,) —
    alpha/beta are evaluated per slot position (vmapped), so slots at
    different sequence depths share one batched step."""
    B = x.shape[0]
    H, hd = cfg.num_heads, cfg.head_dim
    pos_v = _positions_vec(pos, B)
    positions = pos_v[:, None]
    q, k, v = _project_qkv(cfg, p, x, positions, rope=False)
    k, v = _expand_kv(cfg, k, v)
    scale = topo_logit_scale(cfg, p_topo)  # (H,)
    qf = phi_features(q[:, 0] * scale[None, :, None], cfg.performer_phi)
    kf = phi_features(k[:, 0], cfg.performer_phi)
    coeffs = topo_mask_coeffs(cfg, p_topo)
    alpha, beta, R = topo_decomposition(cfg, coeffs, L, rank)
    pos_f = pos_v.astype(jnp.float32)
    b = jax.vmap(beta)(pos_f)  # (B,H,R)
    S = cache["S"] + b[:, :, :, None, None] * (
        kf[:, :, None, :, None] * v[:, 0].astype(jnp.float32)[:, :, None, None, :])
    z = cache["z"] + b[:, :, :, None] * kf[:, :, None, :]
    a = jax.vmap(alpha)(pos_f)  # (B,H,R)
    num = jnp.einsum("bhm,bhrmv,bhr->bhv", qf, S, a)
    den = jnp.einsum("bhm,bhrm,bhr->bh", qf, z, a)
    den = jnp.where(jnp.abs(den) < 1e-6, 1e-6, den)
    out = (num / den[..., None]).astype(x.dtype).reshape(B, 1, H * hd) @ p["wo"]
    return out, {"S": S, "z": z}


def topo_attention_prefill(cfg, p, p_topo, x, positions, lengths, cache,
                           L: int, rank: int = 24, tree_mask=None):
    """Fused topo prefill: exact train-path attention over the prompt plus
    the closed-form cordial decode state for the prompt tokens,

        S = sum_{j < len_b} beta(j) kf_j (x) v_j,
        z = sum_{j < len_b} beta(j) kf_j,

    written (set, not accumulated) into the cache so a reused slot never
    inherits a previous request's state. Rows with lengths[b] == 0 keep
    their state untouched.

    `tree_mask` (optional) replaces the sequence Toeplitz mask with a
    per-request tree mask served from a packed forest plan (see
    serve.forest_masks): {'make_fastmult': coeffs -> FastMult over the
    packed row space, 'pack': (N,) packed-row -> flat b*Lp+l token index
    (-1 = foreign block), 'unpack': (B*Lp,) token -> packed row (-1 = not
    in a tree)}. The prompt attends bidirectionally under the tree metric
    (prefix-LM style — the prompt is completed context); generated tokens
    continue through the causal cordial recurrence."""
    B, Lp, _ = x.shape
    H, hd = cfg.num_heads, cfg.head_dim
    if tree_mask is None:
        out = topo_attention_train(cfg, p, p_topo, x, positions, causal=True)
    else:
        out = _topo_tree_masked_attention(cfg, p, p_topo, x, positions,
                                          tree_mask)
    q, k, v = _project_qkv(cfg, p, x, positions, rope=False)
    k, v = _expand_kv(cfg, k, v)
    kf = phi_features(k, cfg.performer_phi)  # (B,Lp,H,m)
    coeffs = topo_mask_coeffs(cfg, p_topo)
    alpha, beta, R = topo_decomposition(cfg, coeffs, L, rank)
    bet = jax.vmap(beta)(jnp.arange(Lp, dtype=jnp.float32))  # (Lp,H,R)
    vmask = (jnp.arange(Lp)[None, :] < lengths[:, None]).astype(jnp.float32)
    S = jnp.einsum("blhm,blhv,lhr,bl->bhrmv", kf,
                   v.astype(jnp.float32), bet, vmask)
    z = jnp.einsum("blhm,lhr,bl->bhrm", kf, bet, vmask)
    valid = lengths > 0
    return out, {
        "S": jnp.where(valid[:, None, None, None, None],
                       S.astype(cache["S"].dtype), cache["S"]),
        "z": jnp.where(valid[:, None, None, None],
                       z.astype(cache["z"].dtype), cache["z"]),
    }


def _topo_tree_masked_attention(cfg, p, p_topo, x, positions, tree_mask):
    """Masked linear attention (Alg. 1) under per-request TREE masks: tokens
    are packed into their forest rows, ONE block-diagonal plan execution
    applies every request's own M_t = [f(dist_{T_t}(i, j))], and outputs
    scatter back to (B, Lp). Tokens outside any tree block get zero
    attention output (their rows are junk padding by construction)."""
    from repro.core.masks import masked_linear_attention

    B, Lp, _ = x.shape
    H, hd = cfg.num_heads, cfg.head_dim
    q, k, v = _project_qkv(cfg, p, x, positions, rope=False)
    k, v = _expand_kv(cfg, k, v)
    scale = topo_logit_scale(cfg, p_topo)
    qf = phi_features(q * scale[None, None, :, None], cfg.performer_phi)
    kf = phi_features(k, cfg.performer_phi)
    m = qf.shape[-1]
    pack = tree_mask["pack"]      # (N,) packed row -> flat token (or -1)
    unpack = tree_mask["unpack"]  # (B*Lp,) flat token -> packed row (or -1)
    take = jnp.clip(pack, 0)
    in_tree = (pack >= 0).astype(jnp.float32)[:, None, None]
    qp = jnp.moveaxis(qf.reshape(B * Lp, H, m)[take] * in_tree, 1, 0)
    kp = jnp.moveaxis(kf.reshape(B * Lp, H, m)[take] * in_tree, 1, 0)
    vp = jnp.moveaxis(
        v.astype(jnp.float32).reshape(B * Lp, H, hd)[take] * in_tree, 1, 0)
    coeffs = topo_mask_coeffs(cfg, p_topo)  # (H, t+1)
    mk = tree_mask["make_fastmult"]
    if cfg.topo_synced:
        out_p = masked_linear_attention(qp, kp, vp, mk(coeffs[0]))
    else:
        out_p = jnp.stack([
            masked_linear_attention(qp[h], kp[h], vp[h], mk(coeffs[h]))
            for h in range(H)])
    sel = jnp.clip(unpack, 0)
    out_tok = jnp.moveaxis(out_p, 0, 1)[sel]  # (B*Lp, H, hd)
    out_tok = out_tok * (unpack >= 0).astype(out_tok.dtype)[:, None, None]
    out = out_tok.reshape(B, Lp, H, hd)
    return out.astype(x.dtype).reshape(B, Lp, H * hd) @ p["wo"]


# --- plain performer decode (unmasked linear attention state) ----------------


def performer_decode_init(cfg, B, dtype=jnp.float32):
    H, hd = cfg.num_heads, cfg.head_dim
    return {"S": jnp.zeros((B, H, hd, hd), dtype), "z": jnp.zeros((B, H, hd), dtype)}


def performer_attention_train(cfg, p, x, positions, causal=True):
    B, L, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x, positions, rope=False)
    k, v = _expand_kv(cfg, k, v)
    qf = phi_features(q, cfg.performer_phi)
    kf = phi_features(k, cfg.performer_phi)
    if causal:
        num, den = causal_linear_attention(qf, kf, v)
    else:
        kv = jnp.einsum("blhm,blhv->bhmv", kf, v.astype(jnp.float32))
        num = jnp.einsum("blhm,bhmv->blhv", qf, kv)
        den = jnp.einsum("blhm,bhm->blh", qf, jnp.sum(kf, axis=1))
    out = linear_attention_output(num, den)
    return out.astype(x.dtype).reshape(B, L, -1) @ p["wo"]


def performer_attention_decode(cfg, p, x, pos, cache):
    B = x.shape[0]
    H, hd = cfg.num_heads, cfg.head_dim
    positions = _positions_vec(pos, B)[:, None]
    q, k, v = _project_qkv(cfg, p, x, positions, rope=False)
    k, v = _expand_kv(cfg, k, v)
    qf = phi_features(q[:, 0], cfg.performer_phi)
    kf = phi_features(k[:, 0], cfg.performer_phi)
    S = cache["S"] + kf[..., None] * v[:, 0].astype(jnp.float32)[..., None, :]
    z = cache["z"] + kf
    num = jnp.einsum("bhm,bhmv->bhv", qf, S)
    den = jnp.einsum("bhm,bhm->bh", qf, z)
    den = jnp.where(jnp.abs(den) < 1e-6, 1e-6, den)
    out = (num / den[..., None]).astype(x.dtype).reshape(B, 1, H * hd) @ p["wo"]
    return out, {"S": S, "z": z}


def performer_attention_prefill(cfg, p, x, positions, lengths, cache):
    """Fused performer prefill: train-path attention over the prompt plus the
    closed-form linear-attention state (beta = 1) for the prompt tokens,
    overwriting any stale state in reused slots."""
    B, Lp, _ = x.shape
    out = performer_attention_train(cfg, p, x, positions, causal=True)
    _, k, v = _project_qkv(cfg, p, x, positions, rope=False)
    k, v = _expand_kv(cfg, k, v)
    kf = phi_features(k, cfg.performer_phi)  # (B,Lp,H,m)
    vmask = (jnp.arange(Lp)[None, :] < lengths[:, None]).astype(jnp.float32)
    S = jnp.einsum("blhm,blhv,bl->bhmv", kf, v.astype(jnp.float32), vmask)
    z = jnp.einsum("blhm,bl->bhm", kf, vmask)
    valid = lengths > 0
    return out, {
        "S": jnp.where(valid[:, None, None, None],
                       S.astype(cache["S"].dtype), cache["S"]),
        "z": jnp.where(valid[:, None, None],
                       z.astype(cache["z"].dtype), cache["z"]),
    }
