"""Mixture-of-Experts FFN with expert parallelism (DeepSeek-style).

Dispatch is sort-based (no (T, E, C) one-hots): flatten (token, k)
assignments, sort by expert, compute position-in-expert from sorted segment
offsets, scatter into an (E, C, d) buffer whose expert axis is sharded over
the `model` mesh axis (EP); XLA inserts the all-to-alls from the sharding
constraints. Capacity overflow drops lowest-priority assignments (standard
capacity-factor semantics); aux load-balancing loss included.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch.sharding import shard
from repro.models.layers import dense_init


def moe_init(key, cfg, dtype=jnp.float32):
    d, E, ffe = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 8)
    p = {
        "router": dense_init(ks[0], (d, E), scale=0.02, dtype=jnp.float32),
        "experts_w_gate": dense_init(ks[1], (E, d, ffe), dtype=dtype),
        "experts_w_in": dense_init(ks[2], (E, d, ffe), dtype=dtype),
        "experts_w_out": dense_init(ks[3], (E, ffe, d), dtype=dtype),
    }
    if cfg.num_shared_experts > 0:
        ffs = cfg.moe_d_ff * cfg.num_shared_experts
        p["shared_w_gate"] = dense_init(ks[4], (d, ffs), dtype=dtype)
        p["shared_w_in"] = dense_init(ks[5], (d, ffs), dtype=dtype)
        p["shared_w_out"] = dense_init(ks[6], (ffs, d), dtype=dtype)
    return p


def _dispatch_combine(cfg, p, xt):
    """Per-group dispatch -> expert FFN -> combine. xt: (T, d) -> ((T, d), aux).

    Sort-based capacity dispatch; the (E, C, d) buffer carries the
    ("experts", capacity, embed) sharding constraint so the expert axis is
    EP-sharded; when this function is vmapped over data-local groups
    (moe_groups > 1) the scatter/gather stay group-local and the only
    cross-device traffic is the buffer's data<->expert all-to-all."""
    T, d = xt.shape
    E, K = cfg.num_experts, cfg.top_k

    logits = (xt.astype(jnp.float32)) @ p["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)  # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32), axis=0)
    aux = jnp.sum(me * ce) * E * cfg.router_aux_loss

    C = int(cfg.capacity_factor * K * T / E)
    C = max(8, min(C, T))

    flat_expert = expert_ids.reshape(-1)  # (T*K,)
    flat_gate = gate_vals.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(T), K)

    # position within expert via sort (stable: earlier tokens keep priority)
    order = jnp.argsort(flat_expert, stable=True)
    sorted_e = flat_expert[order]
    idx = jnp.arange(T * K)
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    pos_sorted = idx - seg_start[sorted_e]
    pos = jnp.zeros_like(pos_sorted).at[order].set(pos_sorted)

    keep = pos < C
    safe_pos = jnp.where(keep, pos, C - 1)

    buf = jnp.zeros((E, C, d), xt.dtype)
    buf = buf.at[flat_expert, safe_pos].add(
        jnp.where(keep[:, None], xt[flat_tok], 0.0).astype(xt.dtype))
    buf = shard(buf, ("experts", "expert_capacity", "embed"))

    actf = jax.nn.silu
    h = actf(jnp.einsum("ecd,edf->ecf", buf, p["experts_w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", buf, p["experts_w_in"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["experts_w_out"])
    out_buf = shard(out_buf, ("experts", "expert_capacity", "embed"))

    gathered = out_buf[flat_expert, safe_pos]  # (T*K, d)
    weighted = gathered * (flat_gate * keep)[:, None].astype(xt.dtype)
    yt = jnp.zeros((T, d), xt.dtype).at[flat_tok].add(weighted)
    return yt, aux


def moe_block(cfg, p, x):
    """x: (B, L, d) -> (B, L, d) plus aux loss (scalar).

    moe_groups > 1 splits tokens into data-local groups (vmapped dispatch):
    the scatter/gather index ops become batch-sharded (GSPMD keeps them
    local) and the dispatch buffers meet the expert sharding through one
    all-to-all instead of replicating the token tensor (§Perf iteration B).
    Per-group capacity C/G preserves total capacity."""
    B, L, d = x.shape
    T = B * L
    G = max(1, getattr(cfg, "moe_groups", 1))
    if T % G:
        G = 1
    xt = x.reshape(T, d)
    if G == 1:
        yt, aux = _dispatch_combine(cfg, p, xt)
    else:
        from repro.launch.sharding import batch_axes

        xg = xt.reshape(G, T // G, d)
        xg = shard(xg, ("batch", None, "embed"))
        # spmd_axis_name shards the vmapped group dim over the data axes:
        # without it, vmapped sharding constraints force the G dim
        # REPLICATED and the expert einsums lose all data parallelism
        # (measured 16x flop overcompute; §Perf B2)
        dp = batch_axes()
        vfn = jax.vmap(lambda t: _dispatch_combine(cfg, p, t),
                       spmd_axis_name=dp if dp and len(dp) > 1 else
                       (dp[0] if dp else None))
        yg, auxg = vfn(xg)
        yg = shard(yg, ("batch", None, "embed"))
        yt, aux = yg.reshape(T, d), jnp.mean(auxg)

    if cfg.num_shared_experts > 0:
        actf = jax.nn.silu
        hs = actf(xt @ p["shared_w_gate"]) * (xt @ p["shared_w_in"])
        yt = yt + hs @ p["shared_w_out"]

    return yt.reshape(B, L, d), aux
