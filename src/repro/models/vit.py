"""Topological Vision Transformer (paper Sec 4.4, TopViT with trees).

Performer attention with the RPE mask M = [f(dist_MST(i,j))] over the
2D-grid-graph MST of image patches, applied through Algorithm 1 with the
IT-plan FastMult (exact). 3 learnable mask scalars per layer (synced).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.engines import Integrator
from repro.core.masks import make_tree_fastmult, masked_linear_attention
from repro.graphs.graph import grid_graph
from repro.graphs.mst import minimum_spanning_tree
from repro.models import attention as A
from repro.models.layers import dense_init, dtype_of, gated_mlp, gated_mlp_init, rms_norm


from repro.core.lru import BoundedLRU

_GRID_INTEGRATOR_CACHE = BoundedLRU(8)
_GRID_DIST_CACHE = BoundedLRU(4)


def install_grid_plan(spec, params, backend: str = "plan") -> int:
    """Adopt a prebuilt/loaded functional plan (e.g. an `ftfi.load_plan`
    artifact) as the grid integrator for its side length: subsequent
    `build_grid_integrator` / `build_grid_plan` calls reuse it with ZERO IT
    rebuild. Returns the grid side. Serving startup uses this to trade the
    O(N log N) decomposition for one artifact read."""
    side = int(round(np.sqrt(spec.n)))
    if side * side != spec.n:
        raise ValueError(
            f"plan covers n={spec.n} vertices: not a square patch grid")
    _GRID_INTEGRATOR_CACHE.put(
        (side, backend),
        Integrator.from_plan(spec, params, backend=backend, leaf_size=16))
    return side


def build_grid_integrator(cfg, backend: str | None = None) -> Integrator:
    """Integrator over the patch-grid MST (built once per config). The MST of
    a unit-weight grid graph is grid-aligned (grid_h == 1), so general mask
    functions ride the exact Hankel/FFT cross engine automatically.

    Backend resolution is `attention.resolve_topo_backend` (explicit arg >
    cfg.topo_backend > cfg.topo_attn_impl). Memoized per (grid side,
    backend): repeated mask rebuilds return the same Integrator, so its plan
    and compiled fastmult closures are reused (the underlying IT/plan
    construction is additionally content-hash cached), and a plan installed
    via `install_grid_plan` is served from here without any IT build."""
    side = int(round(np.sqrt(cfg.num_prefix_embeddings)))
    assert side * side == cfg.num_prefix_embeddings
    backend = A.resolve_topo_backend(cfg, backend)
    key = (side, backend)
    integ = _GRID_INTEGRATOR_CACHE.get(key)
    if integ is None:
        mst = minimum_spanning_tree(grid_graph(side, side))
        integ = Integrator(mst, backend=backend, leaf_size=16)
        # degradation ladder: health-probe compiled rungs once per (side,
        # backend) BEFORE live traffic sees them — a kernel that fails to
        # launch (or emits non-finite fields) blocks that rung globally and
        # this grid quietly serves from the next one down
        if backend in ("pallas",):
            from repro.core import ladder

            reason = ladder.probe_backend(integ.spec, integ.params, backend)
            if reason is not None:
                ladder.block_backend(backend, f"grid {side}x{side} probe: "
                                     f"{reason}")
                backend = ladder.effective_backend(backend)
                key = (side, backend)
                integ = _GRID_INTEGRATOR_CACHE.get(key)
                if integ is None:
                    integ = Integrator(mst, backend=backend, leaf_size=16)
                    _GRID_INTEGRATOR_CACHE.put(key, integ)
                return integ
        _GRID_INTEGRATOR_CACHE.put(key, integ)
    return integ


def build_grid_plan(cfg, backend: str | None = None):
    """Functional face of the grid integrator: the (PlanSpec, PlanParams)
    pair of the patch-grid MST plan — what `ftfi.apply`/`ftfi.save_plan`
    consume. Same memoization as `build_grid_integrator` (the pair is split
    off the identical content-cached plan)."""
    integ = build_grid_integrator(cfg, backend)
    return integ.spec, integ.params


def _grid_tree_distances(side: int):
    """Dense (L, L) MST path-distance matrix for the ref impl (tests/tiny L)."""
    D = _GRID_DIST_CACHE.get(side)
    if D is None:
        from repro.graphs.traverse import tree_all_pairs
        D = np.asarray(tree_all_pairs(
            minimum_spanning_tree(grid_graph(side, side))), np.float32)
        _GRID_DIST_CACHE.put(side, D)
    return D


def _vit_block_init(key, cfg, dtype):
    ks = jax.random.split(key, 4)
    return {
        "attn_norm": {"scale": jnp.zeros((cfg.d_model,), dtype)},
        "attn": A.attn_init(ks[0], cfg, dtype),
        "topo": A.topo_init(ks[1], cfg, dtype),
        "mlp_norm": {"scale": jnp.zeros((cfg.d_model,), dtype)},
        "mlp": gated_mlp_init(ks[2], cfg.d_model, cfg.d_ff, dtype),
    }


def init_params(cfg, key, num_classes: int = 1000, patch_dim: int = 768):
    dtype = dtype_of(cfg)
    ks = jax.random.split(key, 6)
    blocks = jax.vmap(lambda k: _vit_block_init(k, cfg, dtype))(
        jax.random.split(ks[0], cfg.num_layers))
    L = cfg.num_prefix_embeddings
    return {
        "patch_proj": {"kernel": dense_init(ks[1], (patch_dim, cfg.d_model),
                                            dtype=dtype),
                       "bias": jnp.zeros((cfg.d_model,), dtype)},
        "pos_embed": (jax.random.normal(ks[2], (L, cfg.d_model)) * 0.02
                      ).astype(dtype),
        "blocks": blocks,
        "final_norm": {"scale": jnp.zeros((cfg.d_model,), dtype)},
        "head": {"kernel": dense_init(ks[3], (cfg.d_model, num_classes),
                                      dtype=dtype),
                 "bias": jnp.zeros((num_classes,), dtype)},
    }


def topo_vit_attention(cfg, p, p_topo, x, integ):
    """Grid-MST masked linear attention. The cfg.topo_attn_impl axis rides
    through here too: `ref` materializes the dense tree mask (oracle), any
    other impl runs Algorithm 1 with the IT-plan FastMult — whose executor
    backend (plan vs fused pallas fdist_matvec) was picked when `integ` was
    built (build_grid_integrator)."""
    B, L, _ = x.shape
    q, k, v = A._project_qkv(cfg, p["attn"], x,
                             jnp.zeros((B, L), jnp.int32), rope=False)
    scale = A.topo_logit_scale(cfg, p_topo)  # (H,)
    qf = A.phi_features(q * scale[None, None, :, None], cfg.performer_phi)
    kf = A.phi_features(k, cfg.performer_phi)
    coeffs = A.topo_mask_coeffs(cfg, p_topo)[0]  # synced: same across heads
    # (B,L,H,m) -> heads folded into batch for Alg. 1
    qf_ = qf.transpose(0, 2, 1, 3)
    kf_ = kf.transpose(0, 2, 1, 3)
    v_ = v.transpose(0, 2, 1, 3).astype(jnp.float32)
    if getattr(cfg, "topo_attn_impl", "fft") == "ref":
        from repro.core.masks import mask_f, masked_attention_bruteforce
        D = jnp.asarray(_grid_tree_distances(int(round(np.sqrt(L)))))
        out = masked_attention_bruteforce(
            qf_, kf_, v_, mask_f(cfg.topo_g, coeffs, cfg.topo_dist_scale)(D))
    else:
        fastmult = make_tree_fastmult(
            integ, cfg.topo_g, coeffs, cfg.topo_dist_scale,
            sharded=getattr(cfg, "topo_shard_plan", False))
        out = masked_linear_attention(qf_, kf_, v_, fastmult)
    out = out.transpose(0, 2, 1, 3).reshape(B, L, -1).astype(x.dtype)
    return out @ p["attn"]["wo"]


def forward(cfg, params, patches, integ):
    """patches: (B, L, patch_dim) -> logits (B, num_classes).
    `integ` is the grid Integrator from build_grid_integrator."""
    x = patches.astype(dtype_of(cfg)) @ params["patch_proj"]["kernel"]
    x = x + params["patch_proj"]["bias"] + params["pos_embed"][None]
    B, L, _ = x.shape

    def body(x, p):
        h = rms_norm(x, p["attn_norm"]["scale"], cfg.norm_eps, plus_one=True)
        if cfg.attention_variant == "topo":
            x = x + topo_vit_attention(cfg, p, p["topo"], h, integ)
        else:
            x = x + A.performer_attention_train(
                cfg, p["attn"], h,
                jnp.zeros((B, L), jnp.int32), causal=False)
        h = rms_norm(x, p["mlp_norm"]["scale"], cfg.norm_eps, plus_one=True)
        x = x + gated_mlp(p["mlp"], h, cfg.mlp_act)
        return x, ()

    # plan arrays are numpy constants: python loop over stacked params
    n = jax.tree.leaves(params["blocks"])[0].shape[0]
    for i in range(n):
        layer = jax.tree.map(lambda a: a[i], params["blocks"])
        x, _ = body(x, layer)
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps, plus_one=True)
    pooled = jnp.mean(x, axis=1)
    return pooled @ params["head"]["kernel"] + params["head"]["bias"]
