"""Public model API: family dispatch for init / train / prefill / decode."""
from __future__ import annotations

import jax

from repro.models import encdec, lm


def init_params(cfg, key):
    if cfg.is_encdec:
        return encdec.init_params(cfg, key)
    return lm.init_params(cfg, key)


def loss_fn(cfg, params, batch):
    """Returns (loss, metrics)."""
    if cfg.is_encdec:
        return encdec.forward_train(cfg, params, batch)
    return lm.forward_train(cfg, params, batch)


def prefill_fn(cfg, params, batch):
    """Last-position logits (B, 1, V)."""
    if cfg.is_encdec:
        return encdec.forward_prefill(cfg, params, batch)
    return lm.forward_prefill(cfg, params, batch)


def prefill_into_cache(cfg, params, cache, tokens, lengths, S, tree_mask=None):
    """Fused prefill: run whole (right-padded) prompts through one forward
    pass AND write the decode cache. Returns (last-real-token logits (B, V),
    new_cache). Rows with lengths[b] == 0 keep their cache untouched.
    Encoder-decoder families don't support this path (the engine falls back
    to decode replay)."""
    if cfg.is_encdec:
        raise NotImplementedError(
            "fused prefill-into-cache is decoder-only; encdec serves via "
            "decode replay")
    return lm.forward_prefill_into_cache(cfg, params, cache, tokens, lengths,
                                         S, tree_mask=tree_mask)


def init_cache(cfg, B, S):
    if cfg.is_encdec:
        return encdec.init_decode_cache(cfg, B, S)
    return lm.init_decode_cache(cfg, B, S)


def decode_fn(cfg, params, cache, token, pos, S):
    """One decode step: (logits (B,1,V), new_cache)."""
    if cfg.is_encdec:
        return encdec.forward_decode(cfg, params, cache, token, pos, S)
    return lm.forward_decode(cfg, params, cache, token, pos, S)


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))
