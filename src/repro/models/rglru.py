"""RG-LRU recurrent block (RecurrentGemma), pure JAX.

Block: x -> (gate branch, recurrent branch); recurrent branch = causal
conv1d -> RG-LRU; output = GeLU(gate) * lru_out -> out_proj.

RG-LRU:  r_t = sigmoid(W_r x_t);  i_t = sigmoid(W_i x_t)
         a_t = exp(-c * softplus(Lambda) * r_t)
         h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch.sharding import shard
from repro.models.layers import dense_init

_RGLRU_C = 8.0


def lru_init(key, cfg, dtype=jnp.float32):
    d, w = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * w), dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (w, 4)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        # recurrence/input gates act on the conv output (w -> 2w, diagonal-ish
        # dense as in the reference implementation)
        "gates": dense_init(ks[2], (w, 2 * w), dtype=dtype),
        "a_param": jnp.log(jnp.expm1(
            jnp.clip(jax.random.uniform(ks[3], (w,), minval=0.9, maxval=0.999),
                     1e-4, None))).astype(dtype),
        "out_proj": dense_init(ks[4], (w, d), dtype=dtype),
    }


def _conv1d(x, w, b):
    K = w.shape[1]
    xpad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    return sum(xpad[:, k:k + x.shape[1], :] * w.T[k][None, None, :]
               for k in range(K)) + b


def _rglru_scan(x, r, i, a_param, h0=None):
    """x/r/i: (B, L, w) fp32. Linear recurrence via associative scan."""
    log_a = -_RGLRU_C * jax.nn.softplus(a_param)[None, None, :] * r  # (B,L,w) <= 0
    a = jnp.exp(log_a)
    gated = i * x
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12, None)) * gated

    def assoc(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)
    acc_a, acc_b = jax.lax.associative_scan(assoc, (a, b), axis=1)
    return acc_b, acc_b[:, -1]


def lru_block_train(cfg, p, x):
    B, L, _ = x.shape
    xz = x @ p["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = shard(xin, ("batch", "seq", "inner"))
    xin = _conv1d(xin, p["conv_w"], p["conv_b"])
    g = xin @ p["gates"]
    r, i = jnp.split(jax.nn.sigmoid(g.astype(jnp.float32)), 2, axis=-1)
    h, _ = _rglru_scan(xin.astype(jnp.float32), r, i,
                       p["a_param"].astype(jnp.float32))
    y = h.astype(x.dtype) * jax.nn.gelu(z)
    return y @ p["out_proj"]


def lru_block_prefill(cfg, p, x, lengths, cache):
    """Fused prefill: one RG-LRU scan over the (right-padded) prompt that
    also yields the decode state. Padded positions are neutralized by
    forcing r = i = 0 there (a = 1, input contribution exactly 0 — the
    recurrence passes through), so the final state equals the state after
    each row's last real token. Rows with lengths[b] == 0 are untouched."""
    B, L, _ = x.shape
    xz = x @ p["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = shard(xin, ("batch", "seq", "inner"))
    xc = _conv1d(xin, p["conv_w"], p["conv_b"])
    g = xc @ p["gates"]
    r, i = jnp.split(jax.nn.sigmoid(g.astype(jnp.float32)), 2, axis=-1)
    vmask = (jnp.arange(L)[None, :] < lengths[:, None]
             ).astype(jnp.float32)[..., None]
    r = r * vmask
    i = i * vmask
    h, h_fin = _rglru_scan(xc.astype(jnp.float32), r, i,
                           p["a_param"].astype(jnp.float32))
    y = (h.astype(x.dtype) * jax.nn.gelu(z)) @ p["out_proj"]
    K = p["conv_w"].shape[1]
    cidx = lengths[:, None] - (K - 1) + jnp.arange(K - 1)[None, :]
    cvalid = cidx >= 0
    rows = jnp.arange(B)[:, None]
    conv = jnp.where(cvalid[..., None],
                     xin[rows, jnp.clip(cidx, 0, max(L - 1, 0))],
                     0.0).astype(cache["conv"].dtype)
    valid = lengths > 0
    return y, {
        "conv": jnp.where(valid[:, None, None], conv, cache["conv"]),
        "h": jnp.where(valid[:, None], h_fin, cache["h"]),
    }


def lru_decode_init(cfg, B, dtype=jnp.float32):
    w, K = cfg.lru_width, 4
    return {"conv": jnp.zeros((B, K - 1, w), dtype),
            "h": jnp.zeros((B, w), jnp.float32)}


def lru_block_decode(cfg, p, x, cache):
    B = x.shape[0]
    xz = x @ p["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)  # (B,1,w)
    conv_buf = jnp.concatenate([cache["conv"], xin.astype(cache["conv"].dtype)], axis=1)
    K = p["conv_w"].shape[1]
    xc = jnp.einsum("bkc,ck->bc", conv_buf[:, -K:], p["conv_w"]) + p["conv_b"]
    g = xc @ p["gates"]
    r, i = jnp.split(jax.nn.sigmoid(g.astype(jnp.float32)), 2, axis=-1)
    log_a = -_RGLRU_C * jax.nn.softplus(p["a_param"].astype(jnp.float32))[None] * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12, None)) * (
        i * xc.astype(jnp.float32))
    h = a * cache["h"] + b
    y = (h.astype(x.dtype) * jax.nn.gelu(z[:, 0]))[:, None, :]
    return y @ p["out_proj"], {"conv": conv_buf[:, 1:], "h": h}
