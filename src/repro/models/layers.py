"""Shared neural layers (pure JAX, param pytrees = nested dicts)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch.sharding import shard


def dtype_of(cfg):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[cfg.dtype]


def dense_init(key, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else 1.0 / jnp.sqrt(fan_in)
    return (jax.random.normal(key, shape) * s).astype(dtype)


def rms_norm(x, scale, eps=1e-6, plus_one=False):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    w = (1.0 + scale) if plus_one else scale
    return (y * w).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., L, H, hd), positions: broadcastable to (..., L)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., L, hd/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., None, :]
    cos = cos[..., None, :]  # (..., L, 1, hd/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def gated_mlp_init(key, d_model, d_ff, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype=dtype),
        "w_in": dense_init(k2, (d_model, d_ff), dtype=dtype),
        "w_out": dense_init(k3, (d_ff, d_model), dtype=dtype),
    }


def gated_mlp(p, x, act: str = "silu"):
    actf = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[act]
    h = actf(x @ p["w_gate"]) * (x @ p["w_in"])
    h = shard(h, ("batch", "seq", "ff"))
    return h @ p["w_out"]


def embed_init(key, vocab, d_model, dtype=jnp.float32):
    return {"table": (jax.random.normal(key, (vocab, d_model)) * 0.02).astype(dtype)}


def softcap(logits, cap: float):
    if cap and cap > 0:
        return cap * jnp.tanh(logits / cap)
    return logits


def cross_entropy_loss(logits, labels, vocab_size: int, z_loss: float = 1e-4):
    """Mean next-token CE in fp32, with z-loss; labels < 0 are masked."""
    logits = logits.astype(jnp.float32)
    mask = (labels >= 0) & (labels < vocab_size)
    labels_c = jnp.clip(labels, 0, vocab_size - 1)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels_c[..., None], axis=-1)[..., 0]
    nll = logz - gold + z_loss * jnp.square(logz)
    nll = jnp.where(mask, nll, 0.0)
    denom = jnp.maximum(jnp.sum(mask), 1)
    return jnp.sum(nll) / denom
