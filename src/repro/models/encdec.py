"""Encoder–decoder transformer (seamless-m4t backbone; audio frontend stubbed).

Encoder: bidirectional self-attn + MLP. Decoder: causal self-attn +
cross-attn + MLP. Topological masking (paper) applies to both self-attention
stacks (bidirectional Toeplitz on the encoder, causal on the decoder);
cross-attention stays softmax — the two modalities share no tree metric
(DESIGN §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch.sharding import shard
from repro.models import attention as A
from repro.models.layers import (cross_entropy_loss, dense_init, dtype_of,
                                 embed_init, gated_mlp, gated_mlp_init, rms_norm)


def _enc_block_init(key, cfg, dtype):
    ks = jax.random.split(key, 4)
    p = {
        "attn_norm": {"scale": jnp.zeros((cfg.d_model,), dtype)},
        "attn": A.attn_init(ks[0], cfg, dtype),
        "mlp_norm": {"scale": jnp.zeros((cfg.d_model,), dtype)},
        "mlp": gated_mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype),
    }
    if cfg.attention_variant == "topo":
        p["topo"] = A.topo_init(ks[2], cfg, dtype)
    return p


def _dec_block_init(key, cfg, dtype):
    ks = jax.random.split(key, 5)
    p = _enc_block_init(ks[0], cfg, dtype)
    p["cross_norm"] = {"scale": jnp.zeros((cfg.d_model,), dtype)}
    p["cross_attn"] = A.attn_init(ks[1], cfg, dtype)
    return p


def init_params(cfg, key):
    dtype = dtype_of(cfg)
    V = cfg.padded_vocab()
    ks = jax.random.split(key, 8)
    enc = jax.vmap(lambda k: _enc_block_init(k, cfg, dtype))(
        jax.random.split(ks[0], cfg.encoder_layers))
    dec = jax.vmap(lambda k: _dec_block_init(k, cfg, dtype))(
        jax.random.split(ks[1], cfg.decoder_layers))
    return {
        "frontend_proj": {"kernel": dense_init(ks[2], (1024, cfg.d_model),
                                               dtype=dtype)},
        "embed": embed_init(ks[3], V, cfg.d_model, dtype),
        "blocks_enc": enc,
        "blocks_dec": dec,
        "enc_final_norm": {"scale": jnp.zeros((cfg.d_model,), dtype)},
        "final_norm": {"scale": jnp.zeros((cfg.d_model,), dtype)},
        "lm_head": {"kernel": dense_init(ks[4], (cfg.d_model, V), dtype=dtype)},
    }


def _self_attn(cfg, p, x, positions, causal):
    h = rms_norm(x, p["attn_norm"]["scale"], cfg.norm_eps, plus_one=True)
    if cfg.attention_variant == "topo":
        return A.topo_attention_train(cfg, p["attn"], p["topo"], h, positions,
                                      causal=causal)
    if cfg.attention_variant == "performer":
        return A.performer_attention_train(cfg, p["attn"], h, positions,
                                           causal=causal)
    return A.full_attention_train(cfg, p["attn"], h, positions, causal=causal)


def encode(cfg, params, src_embeds):
    """src_embeds: (B, S, 1024) stub frontend output -> (B, S, d)."""
    x = src_embeds.astype(dtype_of(cfg)) @ params["frontend_proj"]["kernel"]
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = shard(x, ("batch", "seq", "embed"))

    def body(x, p):
        x = x + _self_attn(cfg, p, x, positions, causal=False)
        h = rms_norm(x, p["mlp_norm"]["scale"], cfg.norm_eps, plus_one=True)
        x = x + gated_mlp(p["mlp"], h, cfg.mlp_act)
        return shard(x, ("batch", "seq", "embed")), ()

    body_r = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_r, x, params["blocks_enc"])
    return rms_norm(x, params["enc_final_norm"]["scale"], cfg.norm_eps,
                    plus_one=True)


def _decode_stack(cfg, params, x, positions, memory, mem_positions):
    def body(x, p):
        x = x + _self_attn(cfg, p, x, positions, causal=True)
        h = rms_norm(x, p["cross_norm"]["scale"], cfg.norm_eps, plus_one=True)
        x = x + A.full_attention_train(cfg, p["cross_attn"], h, positions,
                                       causal=False, rope=False,
                                       kv_x=memory, kv_positions=mem_positions)
        h = rms_norm(x, p["mlp_norm"]["scale"], cfg.norm_eps, plus_one=True)
        x = x + gated_mlp(p["mlp"], h, cfg.mlp_act)
        return shard(x, ("batch", "seq", "embed")), ()

    body_r = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_r, x, params["blocks_dec"])
    return x


def forward_train(cfg, params, batch):
    """batch: {'src_embeds': (B,S,1024), 'tokens': (B,L)}."""
    memory = encode(cfg, params, batch["src_embeds"])
    tokens = batch["tokens"]
    B, L = tokens.shape
    x = params["embed"]["table"][tokens]
    positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32)[None], (B, L))
    mem_positions = jnp.broadcast_to(
        jnp.arange(memory.shape[1], dtype=jnp.int32)[None], memory.shape[:2])
    x = _decode_stack(cfg, params, x, positions, memory, mem_positions)
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps, plus_one=True)
    logits = x @ params["lm_head"]["kernel"]
    logits = shard(logits, ("batch", "seq", "vocab"))
    loss = cross_entropy_loss(logits[:, :-1], tokens[:, 1:], cfg.padded_vocab())
    return loss, {}


def forward_prefill(cfg, params, batch):
    memory = encode(cfg, params, batch["src_embeds"])
    tokens = batch["tokens"]
    B, L = tokens.shape
    x = params["embed"]["table"][tokens]
    positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32)[None], (B, L))
    mem_positions = jnp.broadcast_to(
        jnp.arange(memory.shape[1], dtype=jnp.int32)[None], memory.shape[:2])
    x = _decode_stack(cfg, params, x, positions, memory, mem_positions)
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps, plus_one=True)
    return x[:, -1:, :] @ params["lm_head"]["kernel"]


def init_decode_cache(cfg, B, S):
    """Self-attn caches per decoder layer + precomputed cross K/V memory."""
    dtype = dtype_of(cfg)
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    n = cfg.decoder_layers
    if cfg.attention_variant == "topo":
        one = A.topo_decode_init(cfg, B, S)
    elif cfg.attention_variant == "performer":
        one = A.performer_decode_init(cfg, B)
    else:
        one = {"k": jnp.zeros((B, S, KV, hd), dtype),
               "v": jnp.zeros((B, S, KV, hd), dtype)}
    stack = jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), one)
    Sm = cfg.max_source_len
    return {
        "self": stack,
        "cross_k": jnp.zeros((n, B, Sm, KV, hd), dtype),
        "cross_v": jnp.zeros((n, B, Sm, KV, hd), dtype),
    }


def forward_decode(cfg, params, cache, token, pos, S):
    x = params["embed"]["table"][token]  # (B,1,d)
    B = token.shape[0]
    Sm = cache["cross_k"].shape[2]
    mem_mask = jnp.ones((1, 1, 1, Sm), bool)

    def body(x, pc):
        p, c_self, ck, cv = pc
        h = rms_norm(x, p["attn_norm"]["scale"], cfg.norm_eps, plus_one=True)
        if cfg.attention_variant == "topo":
            y, c_self = A.topo_attention_decode(cfg, p["attn"], p["topo"], h,
                                                pos, c_self, L=S)
        elif cfg.attention_variant == "performer":
            y, c_self = A.performer_attention_decode(cfg, p["attn"], h, pos,
                                                     c_self)
        else:
            y, c_self = A.full_attention_decode(cfg, p["attn"], h, pos, c_self)
        x = x + y
        h = rms_norm(x, p["cross_norm"]["scale"], cfg.norm_eps, plus_one=True)
        q = (h @ p["cross_attn"]["wq"]).reshape(B, 1, cfg.num_heads, cfg.head_dim)
        y = A._sdpa(cfg, q, ck, cv, mem_mask)
        x = x + y.reshape(B, 1, -1) @ p["cross_attn"]["wo"]
        h = rms_norm(x, p["mlp_norm"]["scale"], cfg.norm_eps, plus_one=True)
        x = x + gated_mlp(p["mlp"], h, cfg.mlp_act)
        return x, c_self

    x, new_self = jax.lax.scan(
        body, x, (params["blocks_dec"], cache["self"],
                  cache["cross_k"], cache["cross_v"]))
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps, plus_one=True)
    logits = x @ params["lm_head"]["kernel"]
    new_cache = dict(cache)
    new_cache["self"] = new_self
    return logits, new_cache
