"""Decoder-only LM assembly for all assigned families.

Layers are lax.scan-stacked (compile time and HLO size O(1) in depth) with
optional per-layer remat. Families:
  dense   — [norm->attn, norm->mlp] x L
  moe     — first_dense_layers dense blocks, then MoE blocks (scan)
  ssm     — mamba blocks (no MLP, as mamba-1)
  hybrid  — superblocks (rec, rec, attn) x num_superblocks + tail rec blocks
  vlm     — dense backbone over [projected patch embeddings ; text tokens]
Attention variant per config: full | performer | topo (the paper's technique).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.launch.sharding import shard
from repro.models import attention as A
from repro.models import moe as MOE
from repro.models import rglru as RG
from repro.models import ssm as SSM
from repro.models.layers import (cross_entropy_loss, dense_init, dtype_of,
                                 embed_init, gated_mlp, gated_mlp_init,
                                 rms_norm)


# ----------------------------------------------------------------------------
# block init/apply by kind
# ----------------------------------------------------------------------------


def _block_init(key, cfg, kind: str, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    p = {}
    if kind in ("attn_mlp", "attn_local_mlp", "attn_only"):
        p["attn_norm"] = {"scale": jnp.zeros((d,), dtype)}
        p["attn"] = (A.mla_init(ks[0], cfg, dtype) if cfg.mla
                     else A.attn_init(ks[0], cfg, dtype))
        if cfg.attention_variant == "topo":
            p["topo"] = A.topo_init(ks[1], cfg, dtype)
        if kind != "attn_only":
            p["mlp_norm"] = {"scale": jnp.zeros((d,), dtype)}
            p["mlp"] = gated_mlp_init(ks[2], d, cfg.d_ff, dtype)
    elif kind == "moe":
        p["attn_norm"] = {"scale": jnp.zeros((d,), dtype)}
        p["attn"] = (A.mla_init(ks[0], cfg, dtype) if cfg.mla
                     else A.attn_init(ks[0], cfg, dtype))
        if cfg.attention_variant == "topo":
            p["topo"] = A.topo_init(ks[1], cfg, dtype)
        p["mlp_norm"] = {"scale": jnp.zeros((d,), dtype)}
        p["moe"] = MOE.moe_init(ks[2], cfg, dtype)
    elif kind == "mamba":
        p["norm"] = {"scale": jnp.zeros((d,), dtype)}
        p["ssm"] = SSM.ssm_init(ks[0], cfg, dtype)
    elif kind == "rec_mlp":
        p["norm"] = {"scale": jnp.zeros((d,), dtype)}
        p["lru"] = RG.lru_init(ks[0], cfg, dtype)
        p["mlp_norm"] = {"scale": jnp.zeros((d,), dtype)}
        p["mlp"] = gated_mlp_init(ks[2], d, cfg.d_ff, dtype)
    else:
        raise ValueError(kind)
    return p


def _attn_train(cfg, p, x, positions, causal=True, window=0):
    h = rms_norm(x, p["attn_norm"]["scale"], cfg.norm_eps, plus_one=True)
    if cfg.mla:
        return A.mla_attention_train(cfg, p["attn"], h, positions, causal=causal)
    if cfg.attention_variant == "topo":
        return A.topo_attention_train(cfg, p["attn"], p["topo"], h, positions,
                                      causal=causal)
    if cfg.attention_variant == "performer":
        return A.performer_attention_train(cfg, p["attn"], h, positions,
                                           causal=causal)
    return A.full_attention_train(cfg, p["attn"], h, positions, causal=causal,
                                  window=window)


def _block_train(cfg, kind, p, x, positions, window=0):
    """Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn_mlp", "attn_local_mlp", "attn_only", "moe"):
        w = window if kind == "attn_local_mlp" else 0
        x = x + _attn_train(cfg, p, x, positions, window=w)
        if kind == "moe":
            h = rms_norm(x, p["mlp_norm"]["scale"], cfg.norm_eps, plus_one=True)
            y, aux = MOE.moe_block(cfg, p["moe"], h)
            x = x + y
        elif kind != "attn_only":
            h = rms_norm(x, p["mlp_norm"]["scale"], cfg.norm_eps, plus_one=True)
            x = x + gated_mlp(p["mlp"], h, cfg.mlp_act)
    elif kind == "mamba":
        h = rms_norm(x, p["norm"]["scale"], cfg.norm_eps, plus_one=True)
        x = x + SSM.mamba_block_train(cfg, p["ssm"], h)
    elif kind == "rec_mlp":
        h = rms_norm(x, p["norm"]["scale"], cfg.norm_eps, plus_one=True)
        x = x + RG.lru_block_train(cfg, p["lru"], h)
        h = rms_norm(x, p["mlp_norm"]["scale"], cfg.norm_eps, plus_one=True)
        x = x + gated_mlp(p["mlp"], h, cfg.mlp_act)
    else:
        raise ValueError(kind)
    seq_name = ("seq_sp" if getattr(cfg, "seq_sharded_residuals", False)
                else "seq")
    x = shard(x, ("batch", seq_name, "embed"))
    return x, aux


def _block_decode(cfg, kind, p, x, pos, cache, S, window=0):
    """x: (B, 1, d). Returns (x, new_cache)."""
    if kind in ("attn_mlp", "attn_local_mlp", "attn_only", "moe"):
        h = rms_norm(x, p["attn_norm"]["scale"], cfg.norm_eps, plus_one=True)
        if cfg.mla:
            y, cache = A.mla_attention_decode(cfg, p["attn"], h, pos, cache)
        elif cfg.attention_variant == "topo":
            y, cache = A.topo_attention_decode(cfg, p["attn"], p["topo"], h,
                                               pos, cache, L=S)
        elif cfg.attention_variant == "performer":
            y, cache = A.performer_attention_decode(cfg, p["attn"], h, pos, cache)
        elif kind == "attn_local_mlp":
            y, cache = A.local_attention_decode(cfg, p["attn"], h, pos, cache)
        else:
            y, cache = A.full_attention_decode(cfg, p["attn"], h, pos, cache)
        x = x + y
        if kind == "moe":
            h = rms_norm(x, p["mlp_norm"]["scale"], cfg.norm_eps, plus_one=True)
            y, _ = MOE.moe_block(cfg, p["moe"], h)
            x = x + y
        elif kind != "attn_only":
            h = rms_norm(x, p["mlp_norm"]["scale"], cfg.norm_eps, plus_one=True)
            x = x + gated_mlp(p["mlp"], h, cfg.mlp_act)
    elif kind == "mamba":
        h = rms_norm(x, p["norm"]["scale"], cfg.norm_eps, plus_one=True)
        y, cache = SSM.mamba_block_decode(cfg, p["ssm"], h, cache)
        x = x + y
    elif kind == "rec_mlp":
        h = rms_norm(x, p["norm"]["scale"], cfg.norm_eps, plus_one=True)
        y, cache = RG.lru_block_decode(cfg, p["lru"], h, cache)
        x = x + y
        h = rms_norm(x, p["mlp_norm"]["scale"], cfg.norm_eps, plus_one=True)
        x = x + gated_mlp(p["mlp"], h, cfg.mlp_act)
    return x, cache


def _block_prefill(cfg, kind, p, x, positions, lengths, cache, S, window=0,
                   tree_mask=None):
    """Whole-prompt forward (same math as `_block_train`) that also writes
    the decode cache for positions [0, lengths[b]). x: (B, Lp, d) right-
    padded; rows with lengths[b] == 0 leave their cache untouched (they
    belong to other live serve slots). Returns (x, new_cache)."""
    if kind in ("attn_mlp", "attn_local_mlp", "attn_only", "moe"):
        h = rms_norm(x, p["attn_norm"]["scale"], cfg.norm_eps, plus_one=True)
        if cfg.mla:
            y, cache = A.mla_attention_prefill(cfg, p["attn"], h, positions,
                                               lengths, cache)
        elif cfg.attention_variant == "topo":
            y, cache = A.topo_attention_prefill(cfg, p["attn"], p["topo"], h,
                                                positions, lengths, cache,
                                                L=S, tree_mask=tree_mask)
        elif cfg.attention_variant == "performer":
            y, cache = A.performer_attention_prefill(cfg, p["attn"], h,
                                                     positions, lengths, cache)
        elif kind == "attn_local_mlp":
            y, cache = A.local_attention_prefill(cfg, p["attn"], h, positions,
                                                 lengths, cache)
        else:
            y, cache = A.full_attention_prefill(cfg, p["attn"], h, positions,
                                                lengths, cache)
        x = x + y
        if kind == "moe":
            h = rms_norm(x, p["mlp_norm"]["scale"], cfg.norm_eps, plus_one=True)
            y, _ = MOE.moe_block(cfg, p["moe"], h)
            x = x + y
        elif kind != "attn_only":
            h = rms_norm(x, p["mlp_norm"]["scale"], cfg.norm_eps, plus_one=True)
            x = x + gated_mlp(p["mlp"], h, cfg.mlp_act)
    elif kind == "mamba":
        h = rms_norm(x, p["norm"]["scale"], cfg.norm_eps, plus_one=True)
        y, cache = SSM.mamba_block_prefill(cfg, p["ssm"], h, lengths, cache)
        x = x + y
    elif kind == "rec_mlp":
        h = rms_norm(x, p["norm"]["scale"], cfg.norm_eps, plus_one=True)
        y, cache = RG.lru_block_prefill(cfg, p["lru"], h, lengths, cache)
        x = x + y
        h = rms_norm(x, p["mlp_norm"]["scale"], cfg.norm_eps, plus_one=True)
        x = x + gated_mlp(p["mlp"], h, cfg.mlp_act)
    else:
        raise ValueError(kind)
    return x, cache


def _block_cache_init(cfg, kind, B, S, dtype):
    if kind in ("attn_mlp", "attn_local_mlp", "attn_only", "moe"):
        if cfg.mla:
            return {"ckv": jnp.zeros((B, S, cfg.kv_lora_rank), dtype),
                    "krope": jnp.zeros((B, S, cfg.qk_rope_dim), dtype)}
        if cfg.attention_variant == "topo":
            return A.topo_decode_init(cfg, B, S)
        if cfg.attention_variant == "performer":
            return A.performer_decode_init(cfg, B)
        if kind == "attn_local_mlp":
            return A.local_attention_decode_init(cfg, B, dtype)
        return {"k": jnp.zeros((B, S, cfg.num_kv_heads, cfg.head_dim), dtype),
                "v": jnp.zeros((B, S, cfg.num_kv_heads, cfg.head_dim), dtype)}
    if kind == "mamba":
        return SSM.mamba_decode_init(cfg, B, dtype)
    if kind == "rec_mlp":
        return RG.lru_decode_init(cfg, B, dtype)
    raise ValueError(kind)


# ----------------------------------------------------------------------------
# layer stack description per family
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StackDesc:
    """(kind, count, scanned) segments, executed in order."""
    segments: tuple  # of (kind, count, scan: bool)


def stack_desc(cfg) -> StackDesc:
    if cfg.family in ("dense", "vlm"):
        return StackDesc((("attn_mlp", cfg.num_layers, cfg.scan_layers),))
    if cfg.family == "moe":
        segs = []
        if cfg.first_dense_layers:
            segs.append(("attn_mlp", cfg.first_dense_layers, False))
        segs.append(("moe", cfg.num_layers - cfg.first_dense_layers,
                     cfg.scan_layers))
        return StackDesc(tuple(segs))
    if cfg.family == "ssm":
        return StackDesc((("mamba", cfg.num_layers, cfg.scan_layers),))
    if cfg.family == "hybrid":
        segs = []
        for _ in range(len(cfg.superblock)):
            pass
        # scan over superblocks: represented as alternating scanned segments
        return StackDesc((("hybrid_superblocks", cfg.num_superblocks,
                           cfg.scan_layers),
                          ("hybrid_tail", len(cfg.tail_blocks), False)))
    raise ValueError(cfg.family)


# ----------------------------------------------------------------------------
# params init
# ----------------------------------------------------------------------------


def init_params(cfg, key):
    dtype = dtype_of(cfg)
    V = cfg.padded_vocab()
    keys = jax.random.split(key, 16)
    params = {"embed": embed_init(keys[0], V, cfg.d_model, dtype)}

    def stacked_init(k, kind, n):
        return jax.vmap(lambda kk: _block_init(kk, cfg, kind, dtype))(
            jax.random.split(k, n))

    ki = iter(jax.random.split(keys[1], 32))
    for si, (kind, count, scanned) in enumerate(stack_desc(cfg).segments):
        if count == 0:
            continue
        if kind == "hybrid_superblocks":
            sb = {}
            for bi, bkind in enumerate(cfg.superblock):
                kk = next(ki)
                sb[f"b{bi}_{bkind}"] = (
                    jax.vmap(lambda x: _block_init(
                        x, cfg, "rec_mlp" if bkind == "rec" else "attn_local_mlp",
                        dtype))(jax.random.split(kk, count)))
            params[f"blocks{si}"] = sb
        elif kind == "hybrid_tail":
            for bi, bkind in enumerate(cfg.tail_blocks):
                params[f"tail{bi}"] = _block_init(
                    next(ki), cfg,
                    "rec_mlp" if bkind == "rec" else "attn_local_mlp", dtype)
        else:
            # params are ALWAYS stacked; cfg.scan_layers only selects the
            # execution strategy (lax.scan vs unrolled indexing)
            params[f"blocks{si}"] = stacked_init(next(ki), kind, count)
    params["final_norm"] = {"scale": jnp.zeros((cfg.d_model,), dtype)}
    if not cfg.tie_embeddings:
        params["lm_head"] = {"kernel": dense_init(keys[2], (cfg.d_model, V),
                                                  dtype=dtype)}
    if cfg.family == "vlm":
        params["mm_projector"] = {
            "w1": dense_init(keys[3], (1024, cfg.d_model), dtype=dtype),
            "w2": dense_init(keys[4], (cfg.d_model, cfg.d_model), dtype=dtype),
        }
    if cfg.mtp_depth > 0:
        params["mtp_proj"] = {"kernel": dense_init(
            keys[5], (2 * cfg.d_model, cfg.d_model), dtype=dtype)}
        params["mtp_block"] = _block_init(keys[6], cfg, "attn_mlp", dtype)
        params["mtp_norm"] = {"scale": jnp.zeros((cfg.d_model,), dtype)}
    return params


# ----------------------------------------------------------------------------
# forward (train / prefill)
# ----------------------------------------------------------------------------


def _maybe_remat(f, cfg):
    pol = getattr(cfg, "remat_policy", "dots")
    if not cfg.remat or pol == "none":
        return f
    if pol == "nothing":  # full recompute: minimum live activations
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.nothing_saveable)
    return jax.checkpoint(
        f, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)


def _run_stack(cfg, params, x, positions):
    """Shared trunk for train/prefill. Returns (x, total_aux)."""
    total_aux = jnp.zeros((), jnp.float32)
    for si, (kind, count, scanned) in enumerate(stack_desc(cfg).segments):
        if count == 0:
            continue
        if kind == "hybrid_superblocks":
            sb = params[f"blocks{si}"]

            def superblock(x, layer_p):
                aux = jnp.zeros((), jnp.float32)
                for bi, bkind in enumerate(cfg.superblock):
                    bk = "rec_mlp" if bkind == "rec" else "attn_local_mlp"
                    x, a = _block_train(cfg, bk, layer_p[f"b{bi}_{bkind}"], x,
                                        positions, window=cfg.local_window)
                    aux = aux + a
                return x, aux

            body = _maybe_remat(superblock, cfg)
            if scanned:
                x, auxs = jax.lax.scan(lambda c, p: body(c, p), x, sb)
                total_aux = total_aux + jnp.sum(auxs)
            else:
                for j in range(count):
                    x, a = body(x, jax.tree.map(lambda t: t[j], sb))
                    total_aux = total_aux + a
        elif kind == "hybrid_tail":
            for bi, bkind in enumerate(cfg.tail_blocks):
                bk = "rec_mlp" if bkind == "rec" else "attn_local_mlp"
                x, a = _block_train(cfg, bk, params[f"tail{bi}"], x, positions,
                                    window=cfg.local_window)
                total_aux = total_aux + a
        else:
            def body_fn(x, layer_p, _kind=kind):
                return _block_train(cfg, _kind, layer_p, x, positions)

            body = _maybe_remat(body_fn, cfg)
            if scanned:
                x, auxs = jax.lax.scan(body, x, params[f"blocks{si}"])
                total_aux = total_aux + jnp.sum(auxs)
            else:
                for j in range(count):
                    x, a = body(x, jax.tree.map(lambda t: t[j],
                                                params[f"blocks{si}"]))
                    total_aux = total_aux + a
    return x, total_aux


def embed_tokens(cfg, params, tokens):
    x = params["embed"]["table"][tokens]
    if cfg.emb_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def unembed(cfg, params, x):
    table = (params["embed"]["table"] if cfg.tie_embeddings
             else params["lm_head"]["kernel"])
    if cfg.tie_embeddings:
        logits = x @ table.T
    else:
        logits = x @ table
    return shard(logits, ("batch", "seq", "vocab"))


def forward_train(cfg, params, batch):
    """batch: {'tokens': (B, L)} (+ 'patch_embeds' (B, P, 1024) for vlm).
    Returns (loss, metrics)."""
    tokens = batch["tokens"]
    B = tokens.shape[0]
    if cfg.family == "vlm":
        patches = batch["patch_embeds"]
        pe = jax.nn.gelu(patches.astype(dtype_of(cfg)) @ params["mm_projector"]["w1"])
        pe = pe @ params["mm_projector"]["w2"]
        te = embed_tokens(cfg, params, tokens)
        x = jnp.concatenate([pe, te], axis=1)
        P = patches.shape[1]
    else:
        x = embed_tokens(cfg, params, tokens)
        P = 0
    L = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32)[None], (B, L))
    x = shard(x, ("batch", "seq", "embed"))
    x, aux = _run_stack(cfg, params, x, positions)
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps, plus_one=True)
    logits = unembed(cfg, params, x)
    # next-token loss over the text region
    txt_logits = logits[:, P:, :]
    loss = cross_entropy_loss(txt_logits[:, :-1], tokens[:, 1:],
                              cfg.padded_vocab())
    if cfg.mtp_depth > 0:
        loss = loss + 0.3 * _mtp_loss(cfg, params, x[:, P:], tokens, positions[:, P:])
    loss = loss + aux
    return loss, {"aux": aux}


def _mtp_loss(cfg, params, h, tokens, positions):
    """DeepSeek-V3-style 1-step multi-token prediction head."""
    emb_next = embed_tokens(cfg, params, tokens)
    # combine h_t with emb(t+1) to predict t+2
    hcat = jnp.concatenate([h[:, :-1], emb_next[:, 1:]], axis=-1)
    hp = hcat @ params["mtp_proj"]["kernel"]
    hp, _ = _block_train(cfg, "attn_mlp", params["mtp_block"], hp,
                         positions[:, :-1])
    hp = rms_norm(hp, params["mtp_norm"]["scale"], cfg.norm_eps, plus_one=True)
    logits = unembed(cfg, params, hp)
    return cross_entropy_loss(logits[:, :-1], tokens[:, 2:], cfg.padded_vocab())


def forward_prefill(cfg, params, batch):
    """Prefill: logits for the last position (cacheless dry-run form —
    cache construction is exercised by serve.engine)."""
    cfgp = cfg
    tokens = batch["tokens"]
    B = tokens.shape[0]
    if cfg.family == "vlm":
        patches = batch["patch_embeds"]
        pe = jax.nn.gelu(patches.astype(dtype_of(cfg)) @ params["mm_projector"]["w1"])
        pe = pe @ params["mm_projector"]["w2"]
        x = jnp.concatenate([pe, embed_tokens(cfg, params, tokens)], axis=1)
    else:
        x = embed_tokens(cfg, params, tokens)
    L = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32)[None], (B, L))
    x, _ = _run_stack(cfgp, params, x, positions)
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps, plus_one=True)
    return unembed(cfg, params, x[:, -1:, :])


# ----------------------------------------------------------------------------
# decode
# ----------------------------------------------------------------------------


def init_decode_cache(cfg, B, S):
    dtype = dtype_of(cfg)
    cache = {}
    for si, (kind, count, scanned) in enumerate(stack_desc(cfg).segments):
        if count == 0:
            continue
        if kind == "hybrid_superblocks":
            sb = {}
            for bi, bkind in enumerate(cfg.superblock):
                bk = "rec_mlp" if bkind == "rec" else "attn_local_mlp"
                one = _block_cache_init(cfg, bk, B, S, dtype)
                sb[f"b{bi}_{bkind}"] = jax.tree.map(
                    lambda a: jnp.broadcast_to(a[None], (count,) + a.shape), one)
            cache[f"blocks{si}"] = sb
        elif kind == "hybrid_tail":
            for bi, bkind in enumerate(cfg.tail_blocks):
                bk = "rec_mlp" if bkind == "rec" else "attn_local_mlp"
                cache[f"tail{bi}"] = _block_cache_init(cfg, bk, B, S, dtype)
        else:
            one = _block_cache_init(cfg, kind, B, S, dtype)
            cache[f"blocks{si}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (count,) + a.shape), one)
    return cache


def forward_decode(cfg, params, cache, token, pos, S):
    """token: (B, 1) int32; pos: () int32. Returns (logits (B,1,V), cache)."""
    x = embed_tokens(cfg, params, token)
    new_cache = {}
    for si, (kind, count, scanned) in enumerate(stack_desc(cfg).segments):
        if count == 0:
            continue
        if kind == "hybrid_superblocks":
            sb_p = params[f"blocks{si}"]
            sb_c = cache[f"blocks{si}"]

            def sb_body(x, pc):
                layer_p, layer_c = pc
                new_c = {}
                for bi, bkind in enumerate(cfg.superblock):
                    bk = "rec_mlp" if bkind == "rec" else "attn_local_mlp"
                    key = f"b{bi}_{bkind}"
                    x, c = _block_decode(cfg, bk, layer_p[key], x, pos,
                                         layer_c[key], S, window=cfg.local_window)
                    new_c[key] = c
                return x, new_c

            if scanned:
                x, nc = jax.lax.scan(sb_body, x, (sb_p, sb_c))
            else:
                ncs = []
                for j in range(count):
                    x, c = sb_body(x, jax.tree.map(lambda t: t[j], (sb_p, sb_c)))
                    ncs.append(c)
                nc = jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)
            new_cache[f"blocks{si}"] = nc
        elif kind == "hybrid_tail":
            for bi, bkind in enumerate(cfg.tail_blocks):
                bk = "rec_mlp" if bkind == "rec" else "attn_local_mlp"
                x, c = _block_decode(cfg, bk, params[f"tail{bi}"], x, pos,
                                     cache[f"tail{bi}"], S,
                                     window=cfg.local_window)
                new_cache[f"tail{bi}"] = c
        else:
            def body(x, pc, _kind=kind):
                layer_p, layer_c = pc
                return _block_decode(cfg, _kind, layer_p, x, pos, layer_c, S)

            if scanned:
                x, nc = jax.lax.scan(body, x, (params[f"blocks{si}"],
                                               cache[f"blocks{si}"]))
            else:
                ncs = []
                for j in range(count):
                    x, c = body(x, jax.tree.map(
                        lambda t: t[j], (params[f"blocks{si}"],
                                         cache[f"blocks{si}"])))
                    ncs.append(c)
                nc = jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)
            new_cache[f"blocks{si}"] = nc
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps, plus_one=True)
    logits = unembed(cfg, params, x)
    return logits, new_cache


def forward_prefill_into_cache(cfg, params, cache, tokens, lengths, S,
                               tree_mask=None):
    """Fused prefill: run the whole (right-padded) prompt batch through one
    forward pass AND write each row's KV / recurrent state into the decode
    cache — replacing the token-by-token decode replay loop.

    tokens: (B, Lp) int32, right-padded; lengths: (B,) int32 — rows with
    lengths[b] == 0 are not part of this prefill group and keep their cache
    untouched (they may belong to other live serve slots). tree_mask (topo
    only) applies a packed-forest FTFI mask over the prompt region. Returns
    (logits (B, V) for each row's last real token, new_cache)."""
    B, Lp = tokens.shape
    x = embed_tokens(cfg, params, tokens)
    positions = jnp.broadcast_to(jnp.arange(Lp, dtype=jnp.int32)[None], (B, Lp))
    x = shard(x, ("batch", "seq", "embed"))
    new_cache = {}
    for si, (kind, count, scanned) in enumerate(stack_desc(cfg).segments):
        if count == 0:
            continue
        if kind == "hybrid_superblocks":
            sb_p = params[f"blocks{si}"]
            sb_c = cache[f"blocks{si}"]

            def sb_body(x, pc):
                layer_p, layer_c = pc
                new_c = {}
                for bi, bkind in enumerate(cfg.superblock):
                    bk = "rec_mlp" if bkind == "rec" else "attn_local_mlp"
                    key = f"b{bi}_{bkind}"
                    x, c = _block_prefill(cfg, bk, layer_p[key], x, positions,
                                          lengths, layer_c[key], S,
                                          window=cfg.local_window)
                    new_c[key] = c
                return x, new_c

            if scanned:
                x, nc = jax.lax.scan(sb_body, x, (sb_p, sb_c))
            else:
                ncs = []
                for j in range(count):
                    x, c = sb_body(x, jax.tree.map(lambda t: t[j], (sb_p, sb_c)))
                    ncs.append(c)
                nc = jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)
            new_cache[f"blocks{si}"] = nc
        elif kind == "hybrid_tail":
            for bi, bkind in enumerate(cfg.tail_blocks):
                bk = "rec_mlp" if bkind == "rec" else "attn_local_mlp"
                x, c = _block_prefill(cfg, bk, params[f"tail{bi}"], x,
                                      positions, lengths, cache[f"tail{bi}"],
                                      S, window=cfg.local_window)
                new_cache[f"tail{bi}"] = c
        else:
            def body(x, pc, _kind=kind):
                layer_p, layer_c = pc
                return _block_prefill(cfg, _kind, layer_p, x, positions,
                                      lengths, layer_c, S,
                                      tree_mask=tree_mask)

            if scanned:
                x, nc = jax.lax.scan(body, x, (params[f"blocks{si}"],
                                               cache[f"blocks{si}"]))
            else:
                ncs = []
                for j in range(count):
                    x, c = body(x, jax.tree.map(
                        lambda t: t[j], (params[f"blocks{si}"],
                                         cache[f"blocks{si}"])))
                    ncs.append(c)
                nc = jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)
            new_cache[f"blocks{si}"] = nc
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps, plus_one=True)
    last = jnp.clip(lengths - 1, 0, Lp - 1)
    x_last = x[jnp.arange(B), last][:, None, :]  # (B, 1, d)
    logits = unembed(cfg, params, x_last)[:, 0]
    return logits, new_cache
