"""Mamba-1 selective SSM block (falcon-mamba-7b), pure JAX.

Selective scan runs chunked: `lax.scan` across chunks carrying (B, d_inner, N)
state; within a chunk an associative scan materializes at most
(chunk, d_inner, N) — the standard memory shape for TPU/long-context.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch.sharding import shard
from repro.models.layers import dense_init


def ssm_init(key, cfg, dtype=jnp.float32):
    d, din, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    dt_rank = cfg.dt_rank or max(1, d // 16)
    ks = jax.random.split(key, 8)
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (din, 1))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * din), dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (din, cfg.ssm_conv)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((din,), dtype),
        "x_proj": dense_init(ks[2], (din, dt_rank + 2 * N), dtype=dtype),
        "dt_proj": dense_init(ks[3], (dt_rank, din), dtype=dtype),
        "dt_bias": jnp.asarray(
            jnp.log(jnp.expm1(jnp.clip(
                jax.random.uniform(ks[4], (din,), minval=1e-3, maxval=0.1),
                1e-4, None))), dtype),
        "A_log": jnp.log(A).astype(dtype),
        "D": jnp.ones((din,), dtype),
        "out_proj": dense_init(ks[5], (din, d), dtype=dtype),
    }


def _causal_conv1d(x, w, b):
    """x: (B, L, C); w: (C, K) depthwise causal conv."""
    K = w.shape[1]
    xpad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    # depthwise conv as sum of shifted scalings (K is tiny: 4);
    # w[:, K-1] multiplies the current token (matches the decode ring buffer)
    out = sum(xpad[:, k:k + x.shape[1], :] * w.T[k][None, None, :]
              for k in range(K))
    return out + b


def selective_scan(u, dt, A, Bm, Cm, D, chunk: int = 256, h0=None):
    """u: (B, L, din); dt: (B, L, din); A: (din, N); Bm/Cm: (B, L, N).

    h_t = exp(dt_t A) h_{t-1} + dt_t B_t u_t ;  y_t = C_t . h_t + D u_t.
    Returns (y (B, L, din), h_final (B, din, N)).
    """
    B, L, din = u.shape
    N = A.shape[1]
    C = min(chunk, L)
    assert L % C == 0
    nC = L // C
    dA = jnp.exp(dt[..., None] * A[None, None])  # (B, L, din, N)
    dBu = (dt * u)[..., None] * Bm[:, :, None, :]  # (B, L, din, N)
    dA_ = dA.reshape(B, nC, C, din, N).transpose(1, 0, 2, 3, 4)
    dBu_ = dBu.reshape(B, nC, C, din, N).transpose(1, 0, 2, 3, 4)
    Cm_ = Cm.reshape(B, nC, C, N).transpose(1, 0, 2, 3)

    def assoc(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    def step(h, inp):
        da, dbu, cm = inp  # (B,C,din,N), (B,C,din,N), (B,C,N)
        acc_a, acc_b = jax.lax.associative_scan(assoc, (da, dbu), axis=1)
        hs = acc_a * h[:, None] + acc_b  # (B,C,din,N)
        y = jnp.einsum("bcdn,bcn->bcd", hs, cm)
        return hs[:, -1], y

    h = h0 if h0 is not None else jnp.zeros((B, din, N), jnp.float32)
    h, ys = jax.lax.scan(step, h, (dA_.astype(jnp.float32),
                                   dBu_.astype(jnp.float32),
                                   Cm_.astype(jnp.float32)))
    y = ys.transpose(1, 0, 2, 3).reshape(B, L, din)
    return (y + u * D[None, None]).astype(u.dtype), h


def mamba_block_train(cfg, p, x, cache=None):
    """x: (B, L, d) -> (B, L, d). cache unused in train (returns None)."""
    B, L, _ = x.shape
    din = cfg.d_inner
    xz = x @ p["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = shard(xin, ("batch", "seq", "inner"))
    xin = _causal_conv1d(xin, p["conv_w"], p["conv_b"])
    xin = jax.nn.silu(xin)
    dt_rank = p["dt_proj"].shape[0]
    N = cfg.ssm_state
    proj = xin @ p["x_proj"]  # (B, L, dt_rank + 2N)
    dt_low, Bm, Cm = jnp.split(proj, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(dt_low @ p["dt_proj"] + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, _ = selective_scan(xin.astype(jnp.float32), dt.astype(jnp.float32), A,
                          Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                          p["D"].astype(jnp.float32))
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ p["out_proj"]


def mamba_block_prefill(cfg, p, x, lengths, cache):
    """Fused prefill: one selective scan over the (right-padded) prompt that
    also produces the decode state. Padded positions are neutralized through
    dt = 0 (dA = 1, dBu = 0 — the state passes through unchanged), so
    h_final is exactly the state after the last REAL token of each row. The
    conv ring holds the last K-1 real conv inputs (zeros where the prompt is
    shorter, matching `mamba_decode_init`). Rows with lengths[b] == 0 keep
    their cache untouched. Returns (y (B, L, d_model-in), new_cache)."""
    B, L, _ = x.shape
    din = cfg.d_inner
    xz = x @ p["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = shard(xin, ("batch", "seq", "inner"))
    xc = _causal_conv1d(xin, p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc)
    dt_rank = p["dt_proj"].shape[0]
    N = cfg.ssm_state
    proj = xc @ p["x_proj"]
    dt_low, Bm, Cm = jnp.split(proj, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(dt_low @ p["dt_proj"] + p["dt_bias"])
    vmask = (jnp.arange(L)[None, :] < lengths[:, None])
    dt = dt * vmask[..., None].astype(dt.dtype)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, h = selective_scan(xc.astype(jnp.float32), dt.astype(jnp.float32), A,
                          Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                          p["D"].astype(jnp.float32))
    y = y.astype(x.dtype) * jax.nn.silu(z)
    y = y @ p["out_proj"]
    # conv ring: raw xin at positions [len-K+1, len), zeros where negative
    K = cfg.ssm_conv
    cidx = lengths[:, None] - (K - 1) + jnp.arange(K - 1)[None, :]  # (B,K-1)
    cvalid = cidx >= 0
    rows = jnp.arange(B)[:, None]
    conv = jnp.where(cvalid[..., None],
                     xin[rows, jnp.clip(cidx, 0, max(L - 1, 0))],
                     0.0).astype(cache["conv"].dtype)
    valid = lengths > 0
    return y, {
        "conv": jnp.where(valid[:, None, None], conv, cache["conv"]),
        "h": jnp.where(valid[:, None, None], h, cache["h"]),
    }


def mamba_decode_init(cfg, B, dtype=jnp.float32):
    din, N, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    return {
        "conv": jnp.zeros((B, K - 1, din), dtype),
        "h": jnp.zeros((B, din, N), jnp.float32),
    }


def mamba_block_decode(cfg, p, x, cache):
    """x: (B, 1, d); O(1) state update."""
    B = x.shape[0]
    N = cfg.ssm_state
    xz = x @ p["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)  # (B,1,din)
    conv_buf = jnp.concatenate([cache["conv"], xin.astype(cache["conv"].dtype)], axis=1)
    K = cfg.ssm_conv
    w = p["conv_w"]  # (din, K)
    xc = jnp.einsum("bkc,ck->bc", conv_buf[:, -K:], w) + p["conv_b"]
    xc = jax.nn.silu(xc)[:, None, :]  # (B,1,din)
    dt_rank = p["dt_proj"].shape[0]
    proj = xc @ p["x_proj"]
    dt_low, Bm, Cm = jnp.split(proj, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(dt_low @ p["dt_proj"] + p["dt_bias"])  # (B,1,din)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt[..., None] * A[None, None])[:, 0]  # (B,din,N)
    dBu = ((dt * xc)[..., None] * Bm[:, :, None, :])[:, 0]
    h = dA.astype(jnp.float32) * cache["h"] + dBu.astype(jnp.float32)
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0].astype(jnp.float32))
    y = (y + xc[:, 0].astype(jnp.float32) * p["D"][None]).astype(x.dtype)
    y = (y * jax.nn.silu(z[:, 0]))[:, None, :]
    out = y @ p["out_proj"]
    return out, {"conv": conv_buf[:, 1:], "h": h}
