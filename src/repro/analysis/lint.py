"""AST lint for repo invariants the type system can't see.

Rules (suppress a line with a ``# noqa: repro-lint`` comment):

* **frozen-mutation** — no attribute assignment to the frozen ``ITNode`` /
  ``PlanSpec`` dataclasses: ``node.left = ...``, ``spec.pivots = ...`` or
  ``object.__setattr__(...)`` anywhere outside ``plan_api.py`` /
  ``integrator_tree.py`` (the dataclasses' own ``__post_init__`` /
  digest-memo sites).
* **legacy-np-random** — no ``np.random.<fn>()`` module-level legacy API;
  randomness must flow through seeded ``np.random.default_rng`` /
  ``Generator`` objects (or jax PRNG keys).
* **traced-host-read** — inside ``src/repro/{core,kernels,models}``, no
  ``.item()`` and no ``float()/int()/bool()`` wrapped around a ``jnp.``
  expression: forcing a traced value to a python scalar either crashes
  under jit or silently forces a device sync.
* **x64-flip** — no ``jax.config.update("jax_enable_x64", ...)`` (or
  ``enable_x64`` context managers) inside ``src/``; precision policy is
  set by the launcher/tests only.

Pure ``ast`` — no third-party dependencies, so the lint runs anywhere the
repo imports.
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path

# Frozen dataclass field names (ITNode + PlanSpec).  Attribute *writes* to
# these names on a non-self object are flagged; the name sets are disjoint
# enough from mutable-object vocabulary that false positives are rare, and
# noqa covers the rest.
FROZEN_FIELDS = frozenset({
    # ITNode
    "vertex_ids", "depth", "leaf_dists", "pivot", "left", "right",
    "left_ids", "right_ids", "left_d", "right_d", "left_id_d", "right_id_d",
    "left_sorted_ids", "left_seg_starts", "right_sorted_ids",
    "right_seg_starts",
    # PlanSpec
    "pivots", "src_gather", "src_seg", "tgt_gather", "tgt_scatter",
    "children", "root_refs", "job_bucket", "job_row", "leaf_bucket",
    "leaf_row", "path_rows", "path_edges", "cross_piv", "reps", "lcas",
})

LEGACY_NP_RANDOM = frozenset({
    "rand", "randn", "randint", "random", "random_sample", "seed",
    "uniform", "normal", "choice", "permutation", "shuffle", "standard_normal",
    "beta", "binomial", "exponential", "poisson",
})

# files allowed to call object.__setattr__ (frozen-dataclass internals)
SETATTR_ALLOWED = ("plan_api.py", "integrator_tree.py")

# subpackages where host reads of traced values are forbidden
TRACED_SUBPKGS = ("core", "kernels", "models")

NOQA = "noqa: repro-lint"


@dataclasses.dataclass
class LintError:
    path: str
    line: int
    rule: str
    detail: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.detail}"


def _has_jnp(node: ast.AST) -> bool:
    """True if the expression tree references a ``jnp.``/``jax.numpy`` name."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and sub.id in ("jnp", "lax"):
            return True
        if isinstance(sub, ast.Attribute):
            # jax.numpy..., jax.lax...
            root = sub
            parts = []
            while isinstance(root, ast.Attribute):
                parts.append(root.attr)
                root = root.value
            if isinstance(root, ast.Name) and root.id == "jax" and (
                    "numpy" in parts or "lax" in parts):
                return True
    return False


def _attr_chain(node: ast.AST) -> list[str]:
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return parts[::-1]


def check_source(src: str, path: str = "<string>") -> list[LintError]:
    """Lint one python source string; ``path`` controls the per-directory
    rule scoping and appears in the errors."""
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [LintError(path, e.lineno or 0, "syntax", str(e.msg))]

    lines = src.splitlines()

    def suppressed(lineno: int) -> bool:
        return 0 < lineno <= len(lines) and NOQA in lines[lineno - 1]

    p = Path(path)
    fname = p.name
    in_src = "src" in p.parts and "tests" not in p.parts
    in_traced = in_src and any(sp in p.parts for sp in TRACED_SUBPKGS)
    errors: list[LintError] = []

    def err(node: ast.AST, rule: str, detail: str) -> None:
        if not suppressed(node.lineno):
            errors.append(LintError(path, node.lineno, rule, detail))

    for node in ast.walk(tree):
        # --- frozen-mutation: obj.field = ... on frozen field names ---
        if isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = node.targets if isinstance(node, ast.Assign) else [node.target]
            for t in targets:
                if (isinstance(t, ast.Attribute)
                        and t.attr in FROZEN_FIELDS
                        and isinstance(t.value, ast.Name)
                        and t.value.id != "self"):
                    err(t, "frozen-mutation",
                        f"assignment to frozen field '{t.value.id}.{t.attr}' "
                        f"(ITNode/PlanSpec are immutable; use dataclasses.replace)")

        # --- calls ---
        if isinstance(node, ast.Call):
            chain = _attr_chain(node.func)

            # object.__setattr__(spec, "field", ...) outside allowed files
            if chain[-2:] == ["object", "__setattr__"] or chain == ["object", "__setattr__"]:
                if fname not in SETATTR_ALLOWED:
                    err(node, "frozen-mutation",
                        "object.__setattr__ bypasses frozen dataclasses "
                        f"(only {SETATTR_ALLOWED} may)")

            # np.random.<legacy>() — any file
            if (len(chain) >= 3 and chain[0] in ("np", "numpy")
                    and chain[1] == "random" and chain[2] in LEGACY_NP_RANDOM):
                err(node, "legacy-np-random",
                    f"legacy global-state API np.random.{chain[2]}; use a "
                    f"seeded np.random.default_rng(...) Generator")

            if in_traced:
                # .item() anywhere in the traced subpackages
                if (isinstance(node.func, ast.Attribute)
                        and node.func.attr == "item"):
                    err(node, "traced-host-read",
                        ".item() forces a host sync / fails under jit")
                # float(/int(/bool( around a jnp expression
                if (isinstance(node.func, ast.Name)
                        and node.func.id in ("float", "int", "bool")
                        and node.args and _has_jnp(node.args[0])):
                    err(node, "traced-host-read",
                        f"{node.func.id}() on a jax expression fails under "
                        f"jit; keep it an array or mark static")

            # jax.config.update("jax_enable_x64", ...) inside src/
            if in_src:
                is_cfg = (chain[-2:] == ["config", "update"]
                          and (len(chain) < 3 or chain[0] == "jax"))
                if is_cfg and node.args:
                    a0 = node.args[0]
                    if (isinstance(a0, ast.Constant)
                            and a0.value == "jax_enable_x64"):
                        err(node, "x64-flip",
                            "jax_enable_x64 flip inside src/ changes global "
                            "precision for every caller; tests only")

        # with jax.experimental.enable_x64(): inside src/
        if in_src and isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                ctx = item.context_expr
                if isinstance(ctx, ast.Call):
                    ch = _attr_chain(ctx.func)
                    if ch and ch[-1] in ("enable_x64", "disable_x64"):
                        err(node, "x64-flip",
                            f"{ch[-1]}() context inside src/; precision "
                            f"policy belongs to the launcher/tests")

    return errors


def check_paths(paths: list[str | Path]) -> list[LintError]:
    """Lint every ``.py`` under the given files/directories."""
    errors: list[LintError] = []
    for p in paths:
        p = Path(p)
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            try:
                src = f.read_text()
            except OSError as e:
                errors.append(LintError(str(f), 0, "io", str(e)))
                continue
            errors.extend(check_source(src, str(f)))
    return errors
