"""Static analysis for the FTFI repo: jaxpr auditor, retrace sentinel,
AST lint.  ``python -m repro.analysis --all`` runs every pass and diffs
against ``ANALYSIS_BUDGETS.json``.

``trace_guard`` is imported eagerly (pure stdlib — core modules hook into
it at import time); the jax-heavy passes load lazily so ``import
repro.core`` never pays for them.
"""
from repro.analysis import trace_guard  # noqa: F401  (light, eager)

_LAZY = ("jaxpr_audit", "lint", "entry_points", "runner")


def __getattr__(name):
    if name in _LAZY:
        import importlib
        mod = importlib.import_module(f"repro.analysis.{name}")
        globals()[name] = mod
        return mod
    raise AttributeError(f"module 'repro.analysis' has no attribute {name!r}")


__all__ = ["trace_guard", *_LAZY]
