"""Retrace sentinel: compile-count accounting for the shared jit closures.

The repo's hot paths are all served from memoized jit closures — the
`plan_api.fastmult` memos on the backends, the `masks.make_tree_fastmult`
LRU, the serve engine's decode/prefill buckets.  A cache-key bug (or an
unhashable static arg, or a python scalar that should have been an array)
turns any of them into a silent retrace-per-call, which never fails a
correctness test but destroys serving latency.

This module is the cheap tripwire.  Instrumented sites call
:func:`record` from *inside* the traced body, so the counter bumps exactly
once per trace (jax executes the python body only when it compiles — the
pattern proven by ``_PlanFastMult``'s trace counter).  Cache layers call
:func:`record` with an ``event=`` tag for hit/miss accounting.  Tests and
the CLI then wrap a workload in :func:`expect_stable` (fail on any retrace
of a declared-stable site) or diff :func:`stats` against the
``trace_guard`` section of ``ANALYSIS_BUDGETS.json`` via :func:`check`.

Pure stdlib — core modules import this at module scope without pulling in
jax, so instrumentation adds zero import cost and only trace-time runtime
cost (i.e. none on the cached path).
"""
from __future__ import annotations

import threading
from contextlib import contextmanager

__all__ = [
    "RetraceError", "record", "compiles", "stats", "reset",
    "declare_stable", "expect_stable", "check", "snapshot",
]


class RetraceError(AssertionError):
    """A declared-stable entry point retraced."""


_lock = threading.Lock()
_counts: dict[str, int] = {}          # site -> total records
_by_key: dict[tuple[str, str], int] = {}  # (site, detail) -> records
_stable: dict[str, int] = {}          # site -> max allowed compiles


def record(site: str, detail: str = "", event: str = "compile") -> None:
    """Record one compile (or cache event) at ``site``.

    Call this from inside a jitted function body: jax only runs the python
    body while tracing, so the count equals the number of compiles.  For
    cache layers, pass ``event="hit"``/``event="miss"`` — those are
    accounted under ``site:hit`` / ``site:miss`` and never trip stability
    checks on ``site`` itself.
    """
    key = site if event == "compile" else f"{site}:{event}"
    with _lock:
        _counts[key] = _counts.get(key, 0) + 1
        if detail:
            _by_key[(key, detail)] = _by_key.get((key, detail), 0) + 1


def compiles(site: str) -> int:
    with _lock:
        return _counts.get(site, 0)


def stats() -> dict:
    """Snapshot of all counters: {"sites": {site: n}, "keys": {...}}."""
    with _lock:
        keys = {f"{s} [{d}]": n for (s, d), n in sorted(_by_key.items())}
        return {"sites": dict(sorted(_counts.items())), "keys": keys}


def snapshot() -> dict[str, int]:
    with _lock:
        return dict(_counts)


def reset() -> None:
    with _lock:
        _counts.clear()
        _by_key.clear()
        _stable.clear()


def declare_stable(site: str, max_compiles: int = 1) -> None:
    """Declare that ``site`` may compile at most ``max_compiles`` times
    (checked by :func:`check`)."""
    with _lock:
        _stable[site] = int(max_compiles)


@contextmanager
def expect_stable(*sites: str, max_compiles: int = 0):
    """Fail with :class:`RetraceError` if any of ``sites`` compiles more
    than ``max_compiles`` times inside the block.

    ``max_compiles=0`` is the steady-state assertion: the closure was
    already traced, re-running the workload must be pure cache hits.
    """
    before = snapshot()
    yield
    after = snapshot()
    bad = []
    for s in sites:
        delta = after.get(s, 0) - before.get(s, 0)
        if delta > max_compiles:
            bad.append(f"{s}: {delta} compiles (budget {max_compiles})")
    if bad:
        raise RetraceError(
            "retrace budget exceeded: " + "; ".join(bad))


def check(budgets: dict[str, int] | None = None) -> list[str]:
    """Diff recorded compile counts against per-site budgets.

    ``budgets`` maps site -> max compiles; sites previously registered via
    :func:`declare_stable` are merged in.  Returns a list of violation
    strings (empty = clean).
    """
    with _lock:
        merged = dict(_stable)
        counts = dict(_counts)
    if budgets:
        merged.update({k: int(v) for k, v in budgets.items()})
    issues = []
    for site, limit in sorted(merged.items()):
        n = counts.get(site, 0)
        if n > limit:
            issues.append(
                f"trace_guard: {site} compiled {n}x (budget {limit})")
    return issues
