"""Registered audit entry points: every public compiled surface of the repo.

Each entry is a zero-arg builder returning ``(fn, args)`` — small enough to
trace in seconds on CPU, shaped exactly like the production path (same code
route, same engines, same shard_map wrapping).  ``python -m repro.analysis
--audit`` traces each one and diffs the census against its section of
``ANALYSIS_BUDGETS.json``; tests iterate the same registry so the budget
file and the test suite can never drift apart.

Sections: ``core`` (ftfi functional API + backends), ``kernels`` (Pallas
ops), ``models`` (train steps / forwards), ``serve`` (prefill), ``sharded``
(shard_map paths — need >= 8 devices, skipped otherwise).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np


class SkipEntry(Exception):
    """Entry point not traceable in this environment (e.g. too few devices)."""


@dataclasses.dataclass
class EntryPoint:
    name: str
    section: str
    build: Callable[[], tuple[Callable, tuple]]
    doc: str = ""


REGISTRY: dict[str, EntryPoint] = {}


def entry(name: str, section: str, doc: str = ""):
    def deco(fn):
        REGISTRY[name] = EntryPoint(name, section, fn, doc)
        return fn

    return deco


def by_section(section: str) -> list[EntryPoint]:
    return [e for e in REGISTRY.values() if e.section == section]


def _require_devices(n: int) -> None:
    import jax
    if len(jax.devices()) < n:
        raise SkipEntry(f"needs >= {n} devices, have {len(jax.devices())} "
                        f"(set XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _rng():
    return np.random.default_rng(0)


def _mesh24():
    import jax
    _require_devices(8)
    return jax.make_mesh((2, 4), ("data", "model"))


# ---------------------------------------------------------------------------
# core: ftfi functional API + plan engines
# ---------------------------------------------------------------------------

@entry("ftfi.fastmult.tree", "core",
       "fused plan executor, structured exp cross engine")
def _ftfi_fastmult_tree():
    import repro.ftfi as ftfi
    from repro.core import cordial as C
    from repro.graphs.graph import random_tree

    spec, params = ftfi.build(random_tree(96, seed=0))
    X = _rng().standard_normal((96, 4), dtype=np.float32)
    return ftfi.fastmult(spec, C.Exponential(-0.5)), (params, X)


@entry("ftfi.apply.chebyshev", "core",
       "raw-callable f via the batched Chebyshev cross engine")
def _ftfi_apply_cheb():
    import repro.ftfi as ftfi
    from repro.graphs.graph import random_tree

    spec, _params = ftfi.build(random_tree(96, seed=1))
    X = _rng().standard_normal((96, 2), dtype=np.float32)

    def fwd(params, X):
        return ftfi.apply(spec, params, lambda s: 1.0 / (1.0 + s * s), X)

    return fwd, (_params, X)


@entry("ftfi.fastmult.forest", "core",
       "many trees packed into one fused plan dispatch")
def _ftfi_fastmult_forest():
    import repro.ftfi as ftfi
    from repro.core import cordial as C
    from repro.graphs.graph import Forest, random_tree

    fo = Forest([random_tree(40 + 7 * i, seed=i) for i in range(3)])
    spec, params = ftfi.build(fo)
    X = _rng().standard_normal((spec.n, 3), dtype=np.float32)
    return ftfi.fastmult(spec, C.Exponential(-0.3)), (params, X)


@entry("ftfi.reweight.grad", "core",
       "edge-weight gradient through reweight + apply (learnable metrics)")
def _ftfi_reweight_grad():
    import jax
    import jax.numpy as jnp
    import repro.ftfi as ftfi
    from repro.core import cordial as C
    from repro.graphs.graph import random_tree

    t = random_tree(64, seed=2)
    spec, _ = ftfi.build(t, reweightable=True)
    X = _rng().standard_normal((64, 2), dtype=np.float32)
    w0 = np.asarray(t.weights, np.float32)

    def loss(w, X):
        p = ftfi.reweight(spec, w)
        return jnp.sum(ftfi.apply(spec, p, C.Exponential(-0.5), X) ** 2)

    return jax.grad(loss), (w0, X)


@entry("engines.plan.fastmult", "core",
       "Integrator facade over PlanBackend (params ride the closure)")
def _engine_plan():
    from repro.core.engines.base import Integrator
    from repro.core import cordial as C
    from repro.graphs.graph import random_tree

    integ = Integrator(random_tree(80, seed=3), backend="plan")
    pf = integ.fastmult(C.Exponential(-0.5))
    X = _rng().standard_normal((80, 2), dtype=np.float32)
    return (lambda X: pf(X)), (X,)


@entry("engines.pallas.fastmult", "core",
       "Integrator facade over PallasBackend (interpret off-TPU)")
def _engine_pallas():
    from repro.core.engines.base import Integrator
    from repro.core import cordial as C
    from repro.graphs.graph import random_tree

    integ = Integrator(random_tree(80, seed=4), backend="pallas")
    pf = integ.fastmult(C.Exponential(-0.5))
    X = _rng().standard_normal((80, 2), dtype=np.float32)
    return (lambda X: pf(X)), (X,)


# ---------------------------------------------------------------------------
# kernels
# ---------------------------------------------------------------------------

@entry("kernels.fdist_matvec_batched", "kernels",
       "bucketed fused distance-matvec Pallas kernel")
def _fdist():
    from repro.kernels.fdist_matvec.ops import fdist_matvec_batched

    r = _rng()
    x = r.standard_normal((4, 32), dtype=np.float32)
    y = r.standard_normal((4, 48), dtype=np.float32)
    v = r.standard_normal((4, 48, 2), dtype=np.float32)
    coeffs = np.asarray([1.0, -0.5, 0.25], np.float32)

    def fwd(x, y, v, coeffs):
        return fdist_matvec_batched(x, y, v, coeffs, mode="poly")

    return fwd, (x, y, v, coeffs)


@entry("kernels.topo_linear_attention.causal_exp", "kernels",
       "fused Alg.-1 masked linear attention, separable exp decay")
def _topo_attn_exp():
    from repro.kernels.topo_linear_attention.ops import topo_linear_attention

    r = _rng()
    qf = np.abs(r.standard_normal((1, 2, 64, 8), dtype=np.float32))
    kf = np.abs(r.standard_normal((1, 2, 64, 8), dtype=np.float32))
    v = r.standard_normal((1, 2, 64, 4), dtype=np.float32)
    coeffs = np.asarray([1.0, -0.5], np.float32)

    def fwd(qf, kf, v, coeffs):
        return topo_linear_attention(qf, kf, v, coeffs, g="exp", causal=True)

    return fwd, (qf, kf, v, coeffs)


@entry("kernels.topo_linear_attention.bidir_rank", "kernels",
       "rank-R Chebyshev mask path, bidirectional")
def _topo_attn_rank():
    from repro.kernels.topo_linear_attention.ops import topo_linear_attention

    r = _rng()
    qf = np.abs(r.standard_normal((1, 2, 64, 8), dtype=np.float32))
    kf = np.abs(r.standard_normal((1, 2, 64, 8), dtype=np.float32))
    v = r.standard_normal((1, 2, 64, 4), dtype=np.float32)
    coeffs = np.asarray([1.0, -0.5, 0.25, -0.1], np.float32)

    def fwd(qf, kf, v, coeffs):
        return topo_linear_attention(qf, kf, v, coeffs, g="exp",
                                     causal=False, rank=8)

    return fwd, (qf, kf, v, coeffs)


# ---------------------------------------------------------------------------
# models + serve
# ---------------------------------------------------------------------------

def _lm_setup(**over):
    import jax
    import jax.numpy as jnp
    from repro.configs.base import get_smoke_config
    from repro.models import api

    cfg = get_smoke_config("llama3_2_1b").replace(dtype="float32", **over)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jnp.asarray(
        _rng().integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
    return cfg, params, tokens


@entry("models.lm.train_step", "models", "LM train step (loss+grad+adamw)")
def _lm_train():
    from repro.launch.steps import make_train_step
    from repro.optim.adamw import AdamWConfig, adamw_init

    cfg, params, tokens = _lm_setup()
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10,
                       weight_decay=0.0)
    step = make_train_step(cfg, ocfg)
    return step, (params, adamw_init(params), {"tokens": tokens})


@entry("models.topolm.train_step", "models",
       "topo-attention LM train step (fft mask impl)")
def _topolm_train():
    from repro.launch.steps import make_train_step
    from repro.optim.adamw import AdamWConfig, adamw_init

    cfg, params, tokens = _lm_setup(attention_variant="topo",
                                    topo_attn_impl="fft")
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10,
                       weight_decay=0.0)
    step = make_train_step(cfg, ocfg)
    return step, (params, adamw_init(params), {"tokens": tokens})


@entry("models.topovit.forward", "models",
       "TopoViT forward with the 3-scalar RPE tree mask")
def _vit_forward():
    import jax
    import jax.numpy as jnp
    from repro.configs.base import get_smoke_config
    from repro.models import vit

    cfg = get_smoke_config("topovit_b16").replace(dtype="float32")
    integ = vit.build_grid_integrator(cfg)
    params = vit.init_params(cfg, jax.random.PRNGKey(0), num_classes=10,
                             patch_dim=48)
    patches = jnp.asarray(
        _rng().standard_normal((2, cfg.num_prefix_embeddings, 48)),
        jnp.float32)

    def fwd(params, patches):
        return vit.forward(cfg, params, patches, integ)

    return fwd, (params, patches)


@entry("serve.prefill_into_cache", "serve",
       "fused whole-prompt prefill (one call per pow2 bucket)")
def _prefill():
    import jax.numpy as jnp
    from repro.models import api

    cfg, params, tokens = _lm_setup()
    S = 32
    cache = api.init_cache(cfg, 2, S)
    lengths = jnp.asarray([16, 9], jnp.int32)

    def fwd(params, cache, tokens, lengths):
        return api.prefill_into_cache(cfg, params, cache, tokens, lengths, S)

    return fwd, (params, cache, tokens, lengths)


# ---------------------------------------------------------------------------
# sharded paths (>= 8 devices; the CLI forces 8 fake CPU devices)
# ---------------------------------------------------------------------------

@entry("sharded.ftfi.fastmult.tree", "sharded",
       "shard_map executor: 1 all_to_all halo + 1 psum_scatter reduce")
def _sharded_tree():
    import repro.ftfi as ftfi
    from repro.core import cordial as C
    from repro.graphs.graph import random_tree

    mesh = _mesh24()
    spec, params = ftfi.build(random_tree(120, seed=1))
    X = _rng().standard_normal((120, 2), dtype=np.float32)
    fm = ftfi.sharded_fastmult(spec, C.Exponential(-0.5), mesh=mesh)
    return fm, (params, X)


@entry("sharded.ftfi.fastmult.forest", "sharded",
       "sharded forest plan: same two-collective discipline")
def _sharded_forest():
    import repro.ftfi as ftfi
    from repro.core import cordial as C
    from repro.graphs.graph import Forest, random_tree

    mesh = _mesh24()
    fo = Forest([random_tree(40 + 7 * i, seed=i) for i in range(3)])
    spec, params = ftfi.build(fo)
    X = _rng().standard_normal((spec.n, 3), dtype=np.float32)
    fm = ftfi.sharded_fastmult(spec, C.Exponential(-0.4), mesh=mesh)
    return fm, (params, X)


@entry("sharded.models.topovit.forward", "sharded",
       "TopoViT forward with cfg.topo_shard_plan on a (2,4) mesh")
def _sharded_vit():
    import jax
    import jax.numpy as jnp
    from repro.configs.base import get_smoke_config
    from repro.launch import sharding as SH
    from repro.models import vit

    mesh = _mesh24()
    cfg = get_smoke_config("topovit_b16").replace(dtype="float32")
    integ = vit.build_grid_integrator(cfg)
    params = vit.init_params(cfg, jax.random.PRNGKey(0), num_classes=10,
                             patch_dim=48)
    patches = jnp.asarray(
        _rng().standard_normal((2, cfg.num_prefix_embeddings, 48)),
        jnp.float32)
    cfg_s = cfg.replace(topo_shard_plan=True)

    def fwd(params, patches):
        with SH.use_sharding(mesh):
            return vit.forward(cfg_s, params, patches, integ)

    return fwd, (params, patches)
