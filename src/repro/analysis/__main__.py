"""CLI: ``python -m repro.analysis [--all | --audit | --lint | --trace-guard]``.

Exit status 0 iff every requested pass is clean against
``ANALYSIS_BUDGETS.json``.  ``--json PATH`` writes the full structured
report (the CI artifact).  ``--write-budgets`` re-derives the observed
collective census into the budgets file — the intentional-change flow:
run it, eyeball the diff, commit.

Argument parsing happens *before* jax is imported so the sharded entry
points can force 8 fake CPU devices via XLA_FLAGS.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _parse(argv):
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="FTFI static analysis: jaxpr audits, retrace guard, "
                    "AST lint, diffed against ANALYSIS_BUDGETS.json")
    ap.add_argument("--all", action="store_true",
                    help="run every pass (audit + lint + trace-guard)")
    ap.add_argument("--audit", action="store_true", help="jaxpr audits")
    ap.add_argument("--lint", action="store_true", help="AST lint")
    ap.add_argument("--trace-guard", action="store_true",
                    help="retrace-sentinel workload")
    ap.add_argument("--entry", action="append", default=None,
                    metavar="NAME", help="audit only this entry point "
                    "(repeatable); implies --audit")
    ap.add_argument("--section", action="append", default=None,
                    help="audit only these sections (core/kernels/models/"
                         "serve/sharded)")
    ap.add_argument("--budgets", default=None,
                    help="path to ANALYSIS_BUDGETS.json (default: search "
                         "upward from cwd)")
    ap.add_argument("--lint-paths", nargs="*", default=None,
                    help="files/dirs to lint (default: <repo>/src)")
    ap.add_argument("--json", dest="json_out", default=None,
                    metavar="PATH", help="write the structured report here")
    ap.add_argument("--write-budgets", action="store_true",
                    help="update the budgets file's collective counts to "
                         "the observed census (intentional-change flow)")
    ap.add_argument("--devices", type=int, default=8,
                    help="fake CPU devices to request for sharded audits "
                         "(default 8; 0 = leave XLA_FLAGS alone)")
    args = ap.parse_args(argv)
    if args.entry:
        args.audit = True
    if args.all or not (args.audit or args.lint or args.trace_guard):
        args.audit = args.lint = args.trace_guard = True
    return args


def main(argv=None) -> int:
    args = _parse(sys.argv[1:] if argv is None else argv)

    if args.audit and args.devices and "jax" not in sys.modules:
        flag = f"--xla_force_host_platform_device_count={args.devices}"
        prev = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in prev:
            os.environ["XLA_FLAGS"] = (prev + " " + flag).strip()

    from repro.analysis import runner

    report = runner.run_all(
        budgets_path=args.budgets, lint_paths=args.lint_paths,
        names=args.entry, sections=args.section, do_audit=args.audit,
        do_lint=args.lint, do_trace=args.trace_guard)

    if args.write_budgets and args.audit:
        path = runner.find_budgets_path(args.budgets)
        budgets = runner.load_budgets(args.budgets)
        for rep in report["audit"]["reports"]:
            ent = budgets.setdefault("entry_points", {}).setdefault(
                rep["name"], {})
            ent["collectives"] = rep["collectives"]
        with open(path, "w") as f:
            json.dump(budgets, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"budgets updated: {path}")
        # collective-count findings are now intentional; re-diff
        report = runner.run_all(
            budgets_path=args.budgets, lint_paths=args.lint_paths,
            names=args.entry, sections=args.section, do_audit=args.audit,
            do_lint=args.lint, do_trace=args.trace_guard)

    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)

    # human summary
    if args.audit:
        a = report["audit"]
        print(f"audit: {len(a['reports'])} entry point(s), "
              f"{len(a['skipped'])} skipped")
        for rep in a["reports"]:
            cols = ", ".join(f"{k}={v}" for k, v in
                             sorted(rep["collectives"].items())) or "-"
            status = "ok" if rep["ok"] else "FAIL"
            print(f"  [{status}] {rep['name']}  collectives: {cols}  "
                  f"consts: {rep['const_bytes']}B")
        for sk in a["skipped"]:
            print(f"  [skip] {sk['name']}: {sk['reason']}")
    if args.lint:
        print(f"lint: {len(report['lint']['issues'])} issue(s) in "
              f"{', '.join(report['lint']['paths'])}")
    if args.trace_guard:
        sites = report["trace_guard"]["stats"]["sites"]
        print(f"trace-guard: {len(report['trace_guard']['issues'])} "
              f"issue(s); compiles: "
              + (", ".join(f"{k}={v}" for k, v in sites.items()) or "-"))

    if report["issues"]:
        print(f"\n{len(report['issues'])} issue(s):", file=sys.stderr)
        for issue in report["issues"]:
            print(f"  - {issue}", file=sys.stderr)
        return 1
    print("\nstatic analysis clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
