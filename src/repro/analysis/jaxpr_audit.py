"""Structural jaxpr auditor for FTFI entry points.

Walks the traced :class:`ClosedJaxpr` of an entry point — recursing into
``pjit`` / ``shard_map`` / ``scan`` / ``while`` / ``cond`` / ``custom_vjp``
call equations through their jaxpr-valued params, *not* by string-matching
the pretty-printer — and checks four program invariants against a declared
budget:

* **collective census** — exact counts per collective primitive
  (``all_to_all``, ``psum_scatter``/``reduce_scatter``, ``all_gather``,
  ``psum``, ``ppermute``, ...).  Any collective not named in the budget
  must appear zero times, so a hidden ``all_gather`` on a sharded path is
  a structured finding, not a substring miss.
* **dtype discipline** — no wide dtypes (f64 / c128 / i64 / u64) on any
  equation output or constvar aval, and f32 accumulators under bf16
  inputs on reduction primitives.
* **baked-in-constant audit** — closure-captured arrays above a size
  threshold.  Float consts are gated separately and tightly: a big float
  const is the classic "weights traced as constants" retrace/memory bug,
  while int32/bool plan index arrays are *intended* trace-time constants.
* **host-callback / debug detection** — ``debug_print`` and friends never
  belong on a production path.

The report is a plain dataclass that serializes to JSON for the CI
artifact; ``audit(...)`` raises nothing — gating is the caller's choice.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterator

import numpy as np

import jax
import jax.extend.core  # noqa: F401  (makes jax.extend.core resolvable on 0.4.x)

# Collective primitive names as they appear in jaxprs.  ``psum_scatter``
# is spelled ``reduce_scatter`` by the lowering; budgets may use either.
COLLECTIVE_PRIMS = frozenset({
    "all_gather", "all_gather_invariant", "all_to_all", "psum", "psum2",
    "reduce_scatter", "ppermute", "pgather", "pbroadcast", "pmax", "pmin",
    "pdot", "axis_index",
})
_ALIASES = {"psum_scatter": "reduce_scatter"}

# Reductions that must accumulate in >= fp32 when fed bf16/fp16 inputs.
ACCUM_PRIMS = frozenset({
    "reduce_sum", "cumsum", "cumlogsumexp", "add_any", "scatter-add",
    "dot_general",
})

WIDE_DTYPES = frozenset({"float64", "complex128", "int64", "uint64"})
_LOW_PRECISION = frozenset({"bfloat16", "float16"})

DEFAULT_BUDGET: dict[str, Any] = {
    "collectives": {},              # prim -> exact count; unlisted -> 0
    "allow_dtypes": [],             # extra wide dtypes to tolerate
    "max_float_const_bytes": 1 << 20,   # 1 MiB of float consts
    "max_const_bytes": 64 << 20,        # 64 MiB total (index arrays OK)
    "require_f32_accum": True,
    "allow_callbacks": False,
}


@dataclasses.dataclass
class Finding:
    kind: str      # collective | wide_dtype | bf16_accum | big_const | callback
    where: str     # eqn path, e.g. "pjit/shard_map/scan"
    detail: str

    def __str__(self) -> str:
        return f"[{self.kind}] {self.where}: {self.detail}"


@dataclasses.dataclass
class AuditReport:
    name: str
    collectives: dict[str, int]
    prim_counts: dict[str, int]
    const_bytes: int
    float_const_bytes: int
    biggest_const: dict | None
    findings: list[Finding]

    @property
    def ok(self) -> bool:
        return not self.findings

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["ok"] = self.ok
        return d

    def summary(self) -> str:
        status = "ok" if self.ok else f"{len(self.findings)} finding(s)"
        cols = ", ".join(f"{k}={v}" for k, v in sorted(self.collectives.items())) or "none"
        lines = [f"{self.name}: {status}  collectives: {cols}  "
                 f"consts: {self.const_bytes}B ({self.float_const_bytes}B float)"]
        lines += [f"  - {f}" for f in self.findings]
        return "\n".join(lines)


def _as_closed(fn_or_jaxpr, *args, **kwargs):
    if isinstance(fn_or_jaxpr, jax.extend.core.ClosedJaxpr):
        return fn_or_jaxpr
    return jax.make_jaxpr(fn_or_jaxpr, **kwargs)(*args)


def _sub_jaxprs(eqn) -> Iterator[tuple[Any, list]]:
    """Yield (inner Jaxpr, consts) for every jaxpr-valued param of ``eqn``.

    Covers pjit/shard_map (``jaxpr``), scan/while/cond (``jaxpr`` /
    ``cond_jaxpr`` / ``body_jaxpr`` / ``branches``), custom_vjp/jvp
    (``call_jaxpr``/``fun_jaxpr``) and pallas_call — anything whose params
    carry a Jaxpr or ClosedJaxpr, including tuples/lists of them.
    """
    Closed = jax.extend.core.ClosedJaxpr
    Open = jax.extend.core.Jaxpr
    for val in eqn.params.values():
        items = val if isinstance(val, (tuple, list)) else (val,)
        for item in items:
            if isinstance(item, Closed):
                yield item.jaxpr, item.consts
            elif isinstance(item, Open):
                yield item, []
            elif callable(item) and hasattr(item, "call_jaxpr"):
                cj = item.call_jaxpr  # lu.WrappedFun-ish wrappers
                if isinstance(cj, Closed):
                    yield cj.jaxpr, cj.consts


def iter_eqns(jaxpr, path: tuple[str, ...] = ()) -> Iterator[tuple[Any, tuple[str, ...]]]:
    """Depth-first walk of every equation, yielding (eqn, path)."""
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        yield eqn, path
        for sub, _consts in _sub_jaxprs(eqn):
            yield from iter_eqns(sub, path + (name,))


def _all_consts(closed) -> list[tuple[Any, Any, tuple[str, ...]]]:
    """(const, aval-or-None, path) for top-level and nested consts."""
    out = [(c, v.aval, ()) for c, v in
           zip(closed.consts, closed.jaxpr.constvars)]
    seen: set[int] = set()
    for eqn, path in iter_eqns(closed.jaxpr):
        for sub, consts in _sub_jaxprs(eqn):
            for c, v in zip(consts, sub.constvars):
                if id(c) in seen:
                    continue
                seen.add(id(c))
                out.append((c, v.aval, path + (eqn.primitive.name,)))
    return out


def collective_census(closed) -> dict[str, int]:
    census: dict[str, int] = {}
    for eqn, _path in iter_eqns(closed.jaxpr):
        name = eqn.primitive.name
        if name in COLLECTIVE_PRIMS:
            census[name] = census.get(name, 0) + 1
    return census


def _aval_dtype(aval) -> str | None:
    dt = getattr(aval, "dtype", None)
    return None if dt is None else str(dt)


def _const_nbytes(c) -> int:
    try:
        arr = np.asarray(c)
    except Exception:
        return 0
    return int(arr.nbytes)


def audit(fn_or_jaxpr, *args, name: str = "entry",
          budget: dict | None = None, static_argnums=(),
          **make_jaxpr_kwargs) -> AuditReport:
    """Trace ``fn`` on ``args`` (or take a prebuilt ClosedJaxpr) and audit
    it against ``budget`` (missing keys fall back to :data:`DEFAULT_BUDGET`).
    """
    b = dict(DEFAULT_BUDGET)
    b.update(budget or {})
    if static_argnums:
        make_jaxpr_kwargs["static_argnums"] = static_argnums
    closed = _as_closed(fn_or_jaxpr, *args, **make_jaxpr_kwargs)

    findings: list[Finding] = []
    prim_counts: dict[str, int] = {}
    allow_dtypes = set(b.get("allow_dtypes") or ())
    forbidden = WIDE_DTYPES - allow_dtypes

    # --- pass 1: per-equation census + dtype + callback ---
    for eqn, path in iter_eqns(closed.jaxpr):
        pname = eqn.primitive.name
        prim_counts[pname] = prim_counts.get(pname, 0) + 1
        where = "/".join(path + (pname,)) or pname

        if not b["allow_callbacks"] and (
                "callback" in pname or pname.startswith("debug_")):
            findings.append(Finding(
                "callback", where,
                f"host callback / debug primitive '{pname}' in traced program"))

        for ov in eqn.outvars:
            dt = _aval_dtype(getattr(ov, "aval", None))
            if dt in forbidden:
                findings.append(Finding(
                    "wide_dtype", where, f"equation output has dtype {dt}"))
                break  # one finding per eqn is enough

        if b["require_f32_accum"] and pname in ACCUM_PRIMS:
            in_dts = {_aval_dtype(getattr(v, "aval", None))
                      for v in eqn.invars}
            out_dts = {_aval_dtype(getattr(v, "aval", None))
                       for v in eqn.outvars}
            if in_dts & _LOW_PRECISION and out_dts & _LOW_PRECISION:
                acc = eqn.params.get("preferred_element_type")
                if acc is None or str(np.dtype(acc)) in _LOW_PRECISION:
                    findings.append(Finding(
                        "bf16_accum", where,
                        f"{pname} accumulates in {sorted(out_dts & _LOW_PRECISION)} "
                        f"under low-precision inputs (want fp32 accumulator)"))

    # --- pass 2: collective budget diff ---
    census = collective_census(closed)
    declared = {_ALIASES.get(k, k): int(v)
                for k, v in (b.get("collectives") or {}).items()}
    for prim in sorted(set(census) | set(declared)):
        want, got = declared.get(prim, 0), census.get(prim, 0)
        if got != want:
            findings.append(Finding(
                "collective", prim,
                f"{got} occurrence(s) of '{prim}' (budget {want})"))

    # --- pass 3: constvar dtypes + baked-in-constant audit ---
    total = fl_total = 0
    biggest: dict | None = None
    max_fl = int(b["max_float_const_bytes"])
    for c, aval, path in _all_consts(closed):
        where = "/".join(path + ("const",)) or "const"
        dt = _aval_dtype(aval)
        if dt in forbidden:
            findings.append(Finding(
                "wide_dtype", where, f"captured constant traced as {dt}"))
        nb = _const_nbytes(c)
        total += nb
        arr_dt = getattr(np.asarray(c), "dtype", None) if nb else None
        is_float = arr_dt is not None and arr_dt.kind in "fc"
        if is_float:
            fl_total += nb
        if biggest is None or nb > biggest["bytes"]:
            biggest = {"bytes": nb, "dtype": str(arr_dt), "where": where,
                       "shape": list(getattr(np.asarray(c), "shape", ()))}
        if is_float and nb > max_fl:
            findings.append(Finding(
                "big_const", where,
                f"{nb} B {arr_dt} array baked into the trace as a constant "
                f"(budget {max_fl} B) — weights traced as constants?"))
    if total > int(b["max_const_bytes"]):
        findings.append(Finding(
            "big_const", "const",
            f"total captured constants {total} B exceed budget "
            f"{int(b['max_const_bytes'])} B"))

    return AuditReport(name=name, collectives=census,
                       prim_counts=dict(sorted(prim_counts.items())),
                       const_bytes=total, float_const_bytes=fl_total,
                       biggest_const=biggest, findings=findings)


def assert_clean(fn_or_jaxpr, *args, name: str = "entry",
                 budget: dict | None = None, **kw) -> AuditReport:
    """:func:`audit`, raising ``AssertionError`` with the full report on
    any finding — the one-liner tests use."""
    rep = audit(fn_or_jaxpr, *args, name=name, budget=budget, **kw)
    assert rep.ok, rep.summary()
    return rep
