"""Assemble the full static-analysis run: audits + lint + trace guard,
diffed against ``ANALYSIS_BUDGETS.json``.

Used by the CLI (``python -m repro.analysis``) and by tests — both consume
the same ``run_*`` functions so the CI gate and the test suite can't
drift.
"""
from __future__ import annotations

import json
import os
from pathlib import Path

BUDGETS_FILENAME = "ANALYSIS_BUDGETS.json"


def find_budgets_path(explicit: str | None = None) -> Path:
    if explicit:
        return Path(explicit)
    env = os.environ.get("ANALYSIS_BUDGETS")
    if env:
        return Path(env)
    here = Path.cwd()
    for d in (here, *here.parents):
        cand = d / BUDGETS_FILENAME
        if cand.exists():
            return cand
    # package-relative fallback: src/repro/analysis -> repo root
    return Path(__file__).resolve().parents[3] / BUDGETS_FILENAME


def load_budgets(path: str | None = None) -> dict:
    p = find_budgets_path(path)
    with open(p) as f:
        return json.load(f)


# ---------------------------------------------------------------------------
# audits
# ---------------------------------------------------------------------------


def run_audits(budgets: dict, names: list[str] | None = None,
               sections: list[str] | None = None) -> dict:
    """Trace + audit every registered entry point (or the named subset).

    Returns ``{"reports": [...], "skipped": [...], "issues": [...]}`` where
    each report is an ``AuditReport.to_dict()``.  An entry name present in
    the registry but missing from the budgets file is itself an issue —
    budgets must cover every registered surface.
    """
    from repro.analysis import entry_points, jaxpr_audit

    entry_budgets = budgets.get("entry_points", {})
    todo = list(entry_points.REGISTRY.values())
    if sections:
        todo = [e for e in todo if e.section in sections]
    if names:
        todo = [e for e in todo if e.name in names]
        missing = set(names) - {e.name for e in todo}
        if missing:
            raise KeyError(f"unknown entry point(s): {sorted(missing)}; "
                           f"known: {sorted(entry_points.REGISTRY)}")

    reports, skipped, issues = [], [], []
    for ep in todo:
        if ep.name not in entry_budgets:
            issues.append(f"audit: no budget declared for registered entry "
                          f"point '{ep.name}' in {BUDGETS_FILENAME}")
            continue
        try:
            fn, args = ep.build()
        except entry_points.SkipEntry as e:
            skipped.append({"name": ep.name, "reason": str(e)})
            continue
        rep = jaxpr_audit.audit(fn, *args, name=ep.name,
                                budget=entry_budgets[ep.name])
        reports.append(rep.to_dict())
        issues.extend(f"audit[{ep.name}]: {f['kind']} at {f['where']}: "
                      f"{f['detail']}" for f in rep.to_dict()["findings"])
    return {"reports": reports, "skipped": skipped, "issues": issues}


# ---------------------------------------------------------------------------
# lint
# ---------------------------------------------------------------------------


def run_lint(paths: list[str] | None = None) -> dict:
    from repro.analysis import lint

    if not paths:
        root = find_budgets_path().parent
        paths = [str(root / "src")]
    errors = lint.check_paths(paths)
    return {"paths": [str(p) for p in paths],
            "issues": [str(e) for e in errors]}


# ---------------------------------------------------------------------------
# trace guard workload
# ---------------------------------------------------------------------------


def run_trace_guard(budgets: dict) -> dict:
    """Exercise every memoized jit-closure layer twice and assert the
    second pass is compile-free, then diff total compile counts against the
    ``trace_guard`` budget section."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    import repro.ftfi as ftfi
    from repro.analysis import trace_guard as tg
    from repro.core import cordial as C
    from repro.core import masks
    from repro.core.engines.base import Integrator
    from repro.graphs.graph import random_tree

    tg.reset()
    issues: list[str] = []
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.standard_normal((64, 2)), jnp.float32)

    def stable(*sites, max_compiles=0):
        return tg.expect_stable(*sites, max_compiles=max_compiles)

    # 1. backend fastmult memo (Integrator facade)
    tree = random_tree(64, seed=0)
    integ = Integrator(tree, backend="plan")
    pf = integ.fastmult(C.Exponential(-0.5))
    pf(X)  # first call compiles
    try:
        with stable("engines.plan.fastmult"):
            pf(X)
            pf(X)
            integ.fastmult(C.Exponential(-0.5))(X)  # memo returns same closure
    except tg.RetraceError as e:
        issues.append(f"trace_guard[backend-memo]: {e}")

    # 2. functional fastmult under an outer jit
    spec, params = ftfi.build(tree)
    fm = jax.jit(ftfi.fastmult(spec, C.Exponential(-0.5)))
    fm(params, X)
    try:
        with stable("ftfi.fastmult"):
            fm(params, X)
    except tg.RetraceError as e:
        issues.append(f"trace_guard[ftfi-fastmult]: {e}")

    # 3. mask-closure LRU (serving / eval rebuild path)
    coeffs = np.asarray([1.0, -0.5], np.float32)
    F = jnp.asarray(rng.standard_normal((2, 64, 3)), jnp.float32)
    mfm = masks.make_tree_fastmult(integ, "exp", coeffs, 1.0)
    mfm(F)  # new f family -> exactly one compile
    hits0 = tg.compiles("masks.tree_fastmult:hit")
    try:
        with stable("engines.plan.fastmult", "ftfi.fastmult"):
            masks.make_tree_fastmult(integ, "exp", coeffs, 1.0)(F)
            mfm(F)
    except tg.RetraceError as e:
        issues.append(f"trace_guard[mask-memo]: {e}")
    if tg.compiles("masks.tree_fastmult:hit") <= hits0:
        issues.append("trace_guard[mask-memo]: rebuilding an identical mask "
                      "closure missed the _TREE_FM_CACHE")

    # 4. serve decode / prefill buckets
    try:
        from repro.configs.base import get_smoke_config
        from repro.models import api
        from repro.serve.engine import ServeEngine

        cfg = get_smoke_config("llama3_2_1b").replace(dtype="float32")
        sparams = api.init_params(cfg, jax.random.PRNGKey(0))
        eng = ServeEngine(cfg, sparams, batch_slots=2, max_len=32)
        tok = jnp.zeros((2, 1), jnp.int32)
        pos = jnp.zeros((2,), jnp.int32)
        _, _ = eng._decode(sparams, eng.cache, tok, pos)
        toks = jnp.zeros((2, 8), jnp.int32)
        lengths = jnp.asarray([8, 5], jnp.int32)
        eng._prefill(sparams, eng.cache, toks, lengths)
        with stable("serve.decode", "serve.prefill"):
            eng._decode(sparams, eng.cache, tok, pos)
            eng._prefill(sparams, eng.cache, toks, lengths)
        with stable("serve.prefill", max_compiles=1):
            # a new pow2 bucket is ONE new compile, then stable
            big = jnp.zeros((2, 16), jnp.int32)
            eng._prefill(sparams, eng.cache, big, lengths)
            eng._prefill(sparams, eng.cache, big, lengths)
    except tg.RetraceError as e:
        issues.append(f"trace_guard[serve-buckets]: {e}")

    issues.extend(tg.check(budgets.get("trace_guard")))
    return {"stats": tg.stats(), "issues": issues}


# ---------------------------------------------------------------------------
# the full run
# ---------------------------------------------------------------------------


def run_all(budgets_path: str | None = None,
            lint_paths: list[str] | None = None,
            names: list[str] | None = None,
            sections: list[str] | None = None,
            do_audit: bool = True, do_lint: bool = True,
            do_trace: bool = True) -> dict:
    budgets = load_budgets(budgets_path)
    out: dict = {"budgets_file": str(find_budgets_path(budgets_path)),
                 "issues": []}
    if do_audit:
        out["audit"] = run_audits(budgets, names=names, sections=sections)
        out["issues"] += out["audit"]["issues"]
    if do_lint:
        out["lint"] = run_lint(lint_paths)
        out["issues"] += out["lint"]["issues"]
    if do_trace:
        out["trace_guard"] = run_trace_guard(budgets)
        out["issues"] += out["trace_guard"]["issues"]
    out["ok"] = not out["issues"]
    return out
