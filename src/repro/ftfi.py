"""Public functional plan API (see `repro.core.plan_api` for the engine).

    from repro import ftfi

    spec, params = ftfi.build(tree)                  # static + dynamic halves
    Y = ftfi.apply(spec, params, Exponential(-0.5), X)
    fm = jax.jit(ftfi.fastmult(spec, fn))            # (params, X) -> Y
    ftfi.save_plan("plan.npz", spec, params)
    spec, params = ftfi.load_plan("plan.npz")        # zero IT rebuild

    # learnable tree metrics
    spec, params = ftfi.build(tree, reweightable=True)
    params = ftfi.reweight(spec, edge_w)             # differentiable in edge_w

    # incremental edits: patch a compiled plan instead of rebuilding
    spec, params = ftfi.update_plan(spec, params, [
        ("insert_leaf", parent, w),   # new leaf under `parent`
        ("delete_leaf", v),           # degree-1 vertex -> zeroed ghost row
        ("reweight", edge_w),         # replace all edge weights
    ])

    # disk-persistent plan cache: set FTFI_PLAN_CACHE=/path (or call
    # ftfi.plan_cache.configure(path)) and every build/Integrator over a
    # known topology becomes one npz read; LRU-evicted past
    # FTFI_PLAN_CACHE_MAX_MB (default 512)

    # robustness layer (see README "Failure modes and the degradation
    # ladder"): artifacts are validated on load/cache-hit/update under the
    # FTFI_PLAN_GUARD policy (strict|warn|off), and the resilient entry
    # points demote pallas -> plan -> host on kernel failure or non-finite
    # output instead of crashing
    ftfi.validate(spec, params)                      # PlanValidationError
    Y = ftfi.apply_resilient(spec, params, fn, X, backend="pallas")
    fm = ftfi.resilient_fastmult(spec, fn)           # sticky demotions

    # multi-device execution (see README "Multi-device execution"): the
    # plan's index space is cut into per-device leaf blocks and run under
    # shard_map — one all_to_all moves the halo rows, one psum_scatter
    # reduces the partial outputs; exact (1e-6 parity vs single device)
    with launch.sharding.use_sharding(mesh):         # or pass mesh=...
        Y = ftfi.apply_sharded(spec, params, fn, X)
        fm = jax.jit(ftfi.sharded_fastmult(spec, fn, mesh=mesh))
    ftfi.shard_stats(spec, num_shards)               # block/halo/work stats
"""
from repro.core import ladder, plan_cache, plan_guard  # noqa: F401
from repro.core.ladder import (  # noqa: F401
    BackendDemotionWarning, apply_resilient, resilient_fastmult)
from repro.core.plan_api import (  # noqa: F401
    KERNEL_MODES, PlanParams, PlanSpec, apply, build, describe, fastmult,
    load_plan, plan_from_spec, reweight, save_plan, specialize, update_plan)
from repro.core.plan_guard import PlanValidationError, validate  # noqa: F401
from repro.core.plan_shard import (  # noqa: F401
    SHARD_LAYOUT_VERSION, apply_sharded, partition_plan, shard_stats,
    sharded_fastmult)
