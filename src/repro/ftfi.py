"""Public functional plan API (see `repro.core.plan_api` for the engine).

    from repro import ftfi

    spec, params = ftfi.build(tree)                  # static + dynamic halves
    Y = ftfi.apply(spec, params, Exponential(-0.5), X)
    fm = jax.jit(ftfi.fastmult(spec, fn))            # (params, X) -> Y
    ftfi.save_plan("plan.npz", spec, params)
    spec, params = ftfi.load_plan("plan.npz")        # zero IT rebuild

    # learnable tree metrics
    spec, params = ftfi.build(tree, reweightable=True)
    params = ftfi.reweight(spec, edge_w)             # differentiable in edge_w
"""
from repro.core.plan_api import (  # noqa: F401
    KERNEL_MODES, PlanParams, PlanSpec, apply, build, describe, fastmult,
    load_plan, plan_from_spec, reweight, save_plan, specialize)
