"""Public functional plan API (see `repro.core.plan_api` for the engine).

    from repro import ftfi

    spec, params = ftfi.build(tree)                  # static + dynamic halves
    Y = ftfi.apply(spec, params, Exponential(-0.5), X)
    fm = jax.jit(ftfi.fastmult(spec, fn))            # (params, X) -> Y
    ftfi.save_plan("plan.npz", spec, params)
    spec, params = ftfi.load_plan("plan.npz")        # zero IT rebuild

    # learnable tree metrics
    spec, params = ftfi.build(tree, reweightable=True)
    params = ftfi.reweight(spec, edge_w)             # differentiable in edge_w

    # incremental edits: patch a compiled plan instead of rebuilding
    spec, params = ftfi.update_plan(spec, params, [
        ("insert_leaf", parent, w),   # new leaf under `parent`
        ("delete_leaf", v),           # degree-1 vertex -> zeroed ghost row
        ("reweight", edge_w),         # replace all edge weights
    ])

    # disk-persistent plan cache: set FTFI_PLAN_CACHE=/path (or call
    # ftfi.plan_cache.configure(path)) and every build/Integrator over a
    # known topology becomes one npz read; LRU-evicted past
    # FTFI_PLAN_CACHE_MAX_MB (default 512)
"""
from repro.core import plan_cache  # noqa: F401
from repro.core.plan_api import (  # noqa: F401
    KERNEL_MODES, PlanParams, PlanSpec, apply, build, describe, fastmult,
    load_plan, plan_from_spec, reweight, save_plan, specialize, update_plan)
