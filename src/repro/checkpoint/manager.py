"""Fault-tolerant checkpointing: atomic (tmp+rename), keep-k, auto-resume,
mesh-reshard on restore (elastic re-scale).

Arrays are saved in *logical* (unsharded) layout via device_get, so a restore
may use ANY mesh/sharding — the elastic-scaling path. For multi-host
deployments each host would save its addressable shards (the manager's
`shard_layout` hook); on this single-process container the logical layout is
also the physical one.
"""
from __future__ import annotations

import json
import os
import shutil
import time

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        it = sorted(tree.items())  # matches jax tree_flatten's sorted-key order
    elif isinstance(tree, (list, tuple)) and not hasattr(tree, "_fields"):
        it = ((str(i), v) for i, v in enumerate(tree))
    elif hasattr(tree, "_fields"):  # NamedTuple
        it = zip(tree._fields, tree)
    else:
        return {prefix.rstrip("."): tree}
    for k, v in it:
        out.update(_flatten(v, f"{prefix}{k}."))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def save(self, step: int, params, opt_state=None, extra: dict | None = None):
        tmp = self._step_dir(step) + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        blobs = {"params": params}
        if opt_state is not None:
            blobs["opt"] = opt_state
        for name, tree in blobs.items():
            flat = _flatten(tree)
            arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
            np.savez(os.path.join(tmp, f"{name}.npz"), **arrays)
        meta = {"step": step, "time": time.time(), "extra": extra or {}}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        final = self._step_dir(step)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self):
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like_params, like_opt=None, step: int | None = None,
                shardings=None, opt_shardings=None):
        """Restore into the structure of `like_*`; optionally device_put with
        new shardings (elastic re-mesh)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            return None
        d = self._step_dir(step)

        def load(name, like, shard_tree):
            with np.load(os.path.join(d, f"{name}.npz")) as z:
                flat_like = _flatten(like)
                flat_shard = _flatten(shard_tree) if shard_tree is not None else None
                loaded = {}
                for k, ref in flat_like.items():
                    arr = z[k]
                    if arr.dtype != ref.dtype:
                        arr = arr.astype(ref.dtype)
                    if flat_shard is not None:
                        loaded[k] = jax.device_put(arr, flat_shard[k])
                    else:
                        loaded[k] = jax.numpy.asarray(arr)
                # unflatten into the reference structure
                leaves_ref, treedef = jax.tree_util.tree_flatten(like)
                keys = list(_flatten(like).keys())
                return jax.tree_util.tree_unflatten(
                    treedef, [loaded[k] for k in keys])

        params = load("params", like_params, shardings)
        out = {"step": step, "params": params}
        if like_opt is not None and os.path.exists(os.path.join(d, "opt.npz")):
            out["opt"] = load("opt", like_opt, opt_shardings)
        with open(os.path.join(d, "meta.json")) as f:
            out["meta"] = json.load(f)
        return out
